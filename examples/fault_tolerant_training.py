"""Fault-tolerance demo: train, kill mid-run, resume from the latest atomic
checkpoint, and verify the loss trajectory is EXACTLY what an uninterrupted
run produces ((seed, step)-keyed data + checkpointed optimizer state).

    PYTHONPATH=src python examples/fault_tolerant_training.py
"""

import tempfile

import numpy as np

from repro.configs import get_config
from repro.models.config import reduced
from repro.train.trainer import train


def main():
    cfg = reduced(get_config("smollm-135m"), n_layers=2, vocab_size=256)
    steps = 30

    with tempfile.TemporaryDirectory() as tmp:
        print("[a] uninterrupted run ...")
        _, hist_a, wd = train(cfg, steps=steps, global_batch=8, seq_len=32,
                              ckpt_dir=f"{tmp}/a", ckpt_every=10,
                              log=lambda s: None)

        print("[b] run killed at step 15 ...")
        train(cfg, steps=15, global_batch=8, seq_len=32,
              ckpt_dir=f"{tmp}/b", ckpt_every=5, log=lambda s: None)

        print("[b] restarted — resumes from step 15 checkpoint ...")
        _, hist_b, _ = train(cfg, steps=steps, global_batch=8, seq_len=32,
                             ckpt_dir=f"{tmp}/b", ckpt_every=5,
                             log=lambda s: None)

    np.testing.assert_allclose(hist_a[-1], hist_b[-1], rtol=1e-4)
    print(f"final losses identical: {hist_a[-1]:.5f} == {hist_b[-1]:.5f}")
    print(f"step-time p50 {wd.p50*1e3:.0f} ms; stragglers flagged: {len(wd.flagged)}")


if __name__ == "__main__":
    main()
