"""End-to-end driver (the paper's kind = PTQ inference): train a small LM on
the synthetic corpus, quantize it W4A4 with LRC, and SERVE batched requests
through the continuous-batching engine — comparing PPL and greedy outputs of
the FP and quantized models.

The serving step uses the paged KV cache: ``--page-size`` sets the page
granularity and ``--prefill-chunk`` enables chunked prefill (long prompts
advance one chunk per engine step, interleaved with batched decode).  Both
knobs change scheduling/placement only — greedy outputs are bitwise
identical across settings (docs/serving.md).

    PYTHONPATH=src python examples/serve_quantized.py [--steps 200] \
        [--page-size 16] [--prefill-chunk 8]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as model_lib
from repro.models.config import reduced
from repro.data.loader import batches, calib_sequences
from repro.quant.calibrate import quantize_model
from repro.quant.policy import QuantPolicy
from repro.serve.engine import Request, ServeEngine
from repro.train.trainer import train


def ppl(cfg, params, n=3, bsz=8, seq=64):
    total_ll, total_n = 0.0, 0
    it = batches(cfg, bsz, seq, seed=99)
    for _ in range(n):
        _, batch = next(it)
        logits = model_lib.forward(cfg, params, batch)
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
        ll = jnp.take_along_axis(lp, batch["tokens"][:, 1:, None], axis=-1)
        total_ll += float(jnp.sum(ll))
        total_n += ll.size
    return float(np.exp(-total_ll / total_n))


def _positive_int(s):
    v = int(s)
    if v <= 0:
        raise argparse.ArgumentTypeError(f"must be a positive integer, got {s}")
    return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--page-size", type=_positive_int, default=16,
                    help="paged-KV page granularity in tokens")
    ap.add_argument("--prefill-chunk", type=_positive_int, default=None,
                    help="chunked-prefill width; default = whole prompt")
    args = ap.parse_args()

    cfg = reduced(get_config("smollm-135m"), n_layers=4, d_model=128,
                  n_heads=4, n_kv_heads=2, head_dim=32, d_ff=384,
                  vocab_size=512, tie_embeddings=False)
    print(f"[1/4] training a {cfg.n_params()/1e6:.1f}M-param llama-family LM "
          f"for {args.steps} steps ...")
    state, history, _ = train(cfg, steps=args.steps, global_batch=16,
                              seq_len=64, lr=3e-3, log=lambda s: None)
    print(f"      loss {history[0]:.3f} -> {history[-1]:.3f}")

    print("[2/4] LRC W4A4 calibration (rotate + per-layer Alg.1) ...")
    calib = calib_sequences(cfg, n_seq=24, seq_len=96, seed=123)
    policy = QuantPolicy(bits=4, act_bits=4, rank_frac=0.10, impl="sim",
                         clip_ratio=0.9, correction="lrc")
    t0 = time.time()
    qparams = quantize_model(cfg, state.params, calib, policy)
    print(f"      quantized in {time.time()-t0:.1f}s")

    print("[3/4] quality: PPL fp vs W4A4+LRC")
    p_fp = ppl(cfg, state.params)
    p_q = ppl(cfg, qparams)
    print(f"      fp={p_fp:.3f}  w4a4+lrc={p_q:.3f}  (+{100*(p_q/p_fp-1):.1f}%)")

    print("[4/4] serving batched requests through the quantized model ...")
    rng = np.random.default_rng(0)
    eng = ServeEngine(cfg, qparams, batch_slots=4, max_seq=96,
                      page_size=args.page_size,
                      prefill_chunk=args.prefill_chunk)
    n_req, new_toks = 8, 24
    for i in range(n_req):
        eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                           max_new_tokens=new_toks))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in done.values())
    print(f"      {len(done)} requests, {total} tokens in {dt:.1f}s "
          f"({total/dt:.1f} tok/s on 1 CPU core, sim path)")
    assert len(done) == n_req
    # run() returns structured terminal records — on the happy path every
    # one is FINISHED with clean timings and no captured error
    assert all(rec.ok and rec.error_kind is None for rec in done.values()), \
        {r: (rec.status.value, rec.error_kind) for r, rec in done.items()}
    h = eng.health()
    assert h["counters"]["retries"] == 0 and not h["stalled"]


if __name__ == "__main__":
    main()
