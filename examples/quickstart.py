"""Quickstart: LRC on a single layer, end to end, in ~a minute on CPU.

Builds calibration statistics for one weight matrix, runs the paper's three
solvers (QuaRot/GPTQ baseline, SVD correction, LRC) and prints the
reconstruction losses — the layer-level version of Table 1.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core.numerics import ensure_x64
from repro.core.quantizers import QuantSpec
from repro.core.stats import accumulate_stats, finalize_stats, init_stats
from repro.core.lrc import (
    lrc_solve,
    quantize_baseline,
    reconstruction_loss,
    svd_correction,
)

ensure_x64()


def main():
    rng = np.random.default_rng(0)
    d_in, d_out, n_tokens = 96, 80, 8192

    # LLM-like activations: correlated features + a few outlier channels
    mix = rng.standard_normal((d_in, d_in)) * 0.25 + np.eye(d_in)
    x = rng.standard_normal((n_tokens, d_in)) @ mix
    x[:, ::13] *= 6.0  # outlier channels (what QuaRot/LRC fight)
    w = rng.standard_normal((d_out, d_in)) / np.sqrt(d_in)

    spec_a = QuantSpec(bits=4)  # activation quantizer Q_a (W4A4)
    spec_w = QuantSpec(bits=4)

    stats = init_stats(d_in)
    for i in range(0, n_tokens, 2048):  # online accumulation (Alg 1, l.3-5)
        stats = accumulate_stats(stats, jnp.asarray(x[i : i + 2048]), spec_a)
    stats = finalize_stats(stats)

    k = max(1, int(0.10 * min(d_in, d_out)))  # paper's 10% rank budget

    _, _, w_quarot = quantize_baseline(w, stats, spec_w, hessian="x")
    loss_quarot = reconstruction_loss(w, stats, w_hat=w_quarot)

    u_s, v_s = svd_correction(w, w_quarot, k)
    loss_svd = reconstruction_loss(w, stats, w_hat=w_quarot, u=u_s, v=v_s)

    res = lrc_solve(jnp.asarray(w), stats, spec_w, k=k, iters=1)
    res5 = lrc_solve(jnp.asarray(w), stats, spec_w, k=k, iters=5)

    signal = reconstruction_loss(w, stats)
    print(f"signal power            : {signal:10.4f}")
    print(f"QuaRot (GPTQ, no corr)  : {loss_quarot:10.4f}")
    print(f"  + SVD rank-{k:<3d}       : {loss_svd:10.4f}")
    print(f"  + LRC rank-{k:<3d} (T=1) : {res.losses[-1]:10.4f}")
    print(f"  + LRC rank-{k:<3d} (T=5) : {res5.losses[-1]:10.4f}")
    print(f"  oracle (perfect Ŵ)    : {res.oracle_loss:10.4f}")
    gain = 100 * (1 - res.losses[-1] / loss_quarot)
    print(f"\nLRC removes {gain:.1f}% of the QuaRot reconstruction error "
          f"with a {k}/{min(d_in, d_out)} rank budget.")
    assert res.losses[-1] < loss_svd < loss_quarot or res.losses[-1] < loss_quarot


if __name__ == "__main__":
    main()
