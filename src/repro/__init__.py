"""repro — production-grade JAX framework implementing
"Low-Rank Correction for Quantized LLMs" (LRC; Scetbon & Hensman, 2024).

Public surface:
  repro.core       — LRC algorithm, quantizers, rotations, GPTQ
  repro.quant      — quantized-layer pytrees and forward paths
  repro.models     — the 10 assigned architectures
  repro.kernels    — Pallas TPU kernels (w4a4+lowrank, hadamard, actquant)
  repro.launch     — mesh / dryrun / train / serve / quantize entry points
"""

__version__ = "1.0.0"
