from repro.distributed.sharding import (
    batch_pspec,
    cache_pspecs,
    param_pspecs,
    to_shardings,
)
