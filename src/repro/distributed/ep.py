"""Expert parallelism: capacity-based sort dispatch under shard_map.

Pattern ("EP without all-to-all"): tokens are replicated across the "model"
axis (they are only data-sharded), routed-expert weights are sharded over
"model" (E_local = E / tp per device).  Each device

  1. selects the (token, slot) pairs routed to ITS experts,
  2. argsorts them by local expert id and packs into an (E_local, C, D)
     capacity buffer (overflow dropped — standard capacity-factor semantics),
  3. runs the grouped GEMM over its local experts,
  4. scatters the outputs back to token positions weighted by the router
     probs, and
  5. psum's over "model" so every device ends with the combined output.

The only inter-device communication is the final psum — the same collective
a row-parallel TP matmul needs — so MoE layers add no *extra* collective
phases, and the per-device FLOPs are the true top-k expert FLOPs (no E×
one-hot-GEMM inflation).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.jaxcompat import get_abstract_mesh, shard_map


def _capacity(n_tokens: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(n_tokens * top_k * factor / max(1, n_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8


def _local_expert_compute(cfg, weights_local, xbuf):
    """xbuf: (E_local, C, D) -> (E_local, C, D) through each local expert."""
    import dataclasses

    from repro.quant.qlinear import QLinear, qlinear_apply

    def one(wg, wu, wd, xb):
        if isinstance(wg, QLinear):
            # we are already inside ep's shard_map body: strip any TP tag so
            # qlinear_apply cannot recurse into a nested shard_map
            wg, wu, wd = (dataclasses.replace(w, parallel=None)
                          for w in (wg, wu, wd))
            g = qlinear_apply(wg, xb)
            u = qlinear_apply(wu, xb)
            h = jax.nn.silu(g) * u
            return qlinear_apply(wd, h)
        g = xb @ wg.astype(xb.dtype)
        u = xb @ wu.astype(xb.dtype)
        h = jax.nn.silu(g) * u
        return h @ wd.astype(xb.dtype)

    return jax.vmap(one)(weights_local["wg"], weights_local["wu"], weights_local["wd"], xbuf)


def experts_ep(cfg, p, x, weights, top_idx, axis: str = "model",
               with_stats: bool = False):
    """x: (T, D) tokens (replicated over ``axis``); weights: (T, E) router
    weights; top_idx: (T, K).  Expert weights p["experts"] sharded over
    ``axis`` on their leading dim.  Returns (T, D), or
    ``((T, D), dropped)`` with ``with_stats`` — ``dropped`` is the global
    int32 count of (token, slot) assignments past expert capacity this
    call (the same psum the combine already needs; no extra collective)."""
    axis = axis or "model"
    mesh = get_abstract_mesh()
    tp = mesh.shape[axis]
    e_total = cfg.n_experts
    e_local = e_total // tp
    t, d = x.shape
    k = top_idx.shape[-1]
    cap = _capacity(t, k, e_total, cfg.capacity_factor)

    def local_fn(xl, wl, idxl, experts_local):
        # which shard am I
        me = jax.lax.axis_index(axis)
        lo = me * e_local
        flat_idx = idxl.reshape(-1)  # (T*K,) global expert ids
        flat_tok = jnp.repeat(jnp.arange(t), k)
        flat_w = jnp.take_along_axis(wl, idxl, axis=-1).reshape(-1)
        mine = (flat_idx >= lo) & (flat_idx < lo + e_local)
        local_e = jnp.where(mine, flat_idx - lo, e_local)  # e_local = trash bin
        # slot within expert via stable sort order
        order = jnp.argsort(local_e, stable=True)
        sorted_e = local_e[order]
        # position of each sorted element within its expert group
        pos_in_group = jnp.arange(t * k) - jnp.searchsorted(sorted_e, sorted_e, side="left")
        keep = (sorted_e < e_local) & (pos_in_group < cap)
        dst_e = jnp.where(keep, sorted_e, e_local)
        dst_c = jnp.where(keep, pos_in_group, 0)
        src_tok = flat_tok[order]
        # gather tokens into (E_local+1, C, D); last row is the trash bin
        xbuf = jnp.zeros((e_local + 1, cap, d), xl.dtype)
        xbuf = xbuf.at[dst_e, dst_c].set(jnp.where(keep[:, None], xl[src_tok], 0.0))
        ybuf = _local_expert_compute(cfg, experts_local, xbuf[:e_local])
        # scatter back, weighted
        contrib = ybuf[dst_e.clip(0, e_local - 1), dst_c] * jnp.where(
            keep, flat_w[order], 0.0
        )[:, None].astype(x.dtype)
        out = jnp.zeros_like(xl).at[src_tok].add(contrib)
        if with_stats:
            # capacity-overflow accounting: assignments routed to MY experts
            # minus those that landed in a capacity slot.  Summed alongside
            # the combine psum — the collective count stays at one.
            dropped = (mine.sum().astype(jnp.int32)
                       - keep.sum().astype(jnp.int32))
            return (jax.lax.psum(out, axis),
                    jax.lax.psum(dropped, axis))
        return jax.lax.psum(out, axis)

    in_specs = (
        P(),  # x replicated over the manual axis
        P(),
        P(),
        jax.tree.map(lambda _: _expert_spec(axis), p["experts"]),
    )
    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(), P()) if with_stats else P(),
        check_vma=False,
        axis_names={axis},
    )
    return fn(x, weights.astype(x.dtype), top_idx, p["experts"])


def _expert_spec(axis):
    return P(axis)  # shard leading (expert) dim; rest replicated
