"""Tensor-parallel W4A4+LRC forward under shard_map.

The fused quantized matmul (``ops.w4a4_lrc_forward`` via ``qlinear_apply``)
is threaded through a mesh "model" axis in the two classic flavours, and the
low-rank factors U/V follow the weight's sharding so the LRC epilogue adds
ZERO extra collectives (the same invariant ``ep.py`` maintains for MoE):

  column-parallel (wq/wk/wv/wg/wu — the (None, "tp") rules):
      W  N-sharded:   qweight (K//2, N/tp), w_scale (N/tp,)
      U  N-sharded:   u (N/tp, R)            — rows follow the output shard
      V  replicated:  v (K, R)
      x  replicated → local y is an exact column block of the global y.
      NO collective: output stays "model"-sharded for the next op.

  row-parallel (wo/wd — the ("tp", None) rules):
      W  K-sharded:   qweight (K/tp//2, N)
      U  replicated:  u (N, R)
      V  K-sharded:   v (K/tp, R)            — x_s @ V_s is a partial of xV
      x  K-sharded  → local y = Ŵ_s·Q_a(x_s) + U·(V_sᵀ x_s) is a PARTIAL sum
      of the global output, with the LRC partial already merged in, so ONE
      ``psum`` finishes both the GEMM and the correction.

Because every shard sees its own local (K, N, R), the kernel plan resolves
through ``KernelContext``'s shape-keyed overrides at the LOCAL shape — each
shard gets its own feasible fused tiling with no extra plumbing.

Numerics contract (documented in docs/serving.md):
  * column-parallel outputs are BITWISE identical to single-device (each
    shard computes an independent output-column block over the full K);
  * replicate-tagged layers (no rule, or an infeasible one) also run under
    shard_map — x is gathered to replicated and every shard runs the
    identical full-shape apply, which is BITWISE.  (Left to GSPMD, a
    replicated weight against a sharded producer may be lowered as a split
    contraction + all-reduce, which is not.)
  * row-parallel outputs match to a few ulp: the partial (GEMM + LRC,
    both K-sharded) stays f32 through the psum and is rounded to the
    activation dtype once, but the blocked K reduction reassociates the
    f32 sum (~eps_f32), and — the dominant term when low-rank factors are
    present — the bf16-STORED V means each shard's x_s@V_s partial is
    re-rounded to bf16 before the psum, where single-device rounds the
    full-K contraction once.  Net drift is a few ulp of the LR storage
    dtype (bf16), f32-ulp-level for LRC-free layers.  Downstream 4-bit
    activation quantizers can amplify a residual shift into a code flip,
    so end-to-end logits are close but not bitwise.  Row-parallel REQUIRES group-wise activation scales
    with ``act_group`` dividing K/tp (the quantization grid is then
    shard-invariant); per-token scales over a local K slice would be a
    semantics shift, so ``tp_feasible`` refuses and the layer replicates.
    Net: a mesh run with per-token scales (act_group=None) replicates the
    row layers and is bitwise at every QLinear boundary; a run with group
    scales is fully sharded with exactly one psum per row layer and
    ulp-level drift there.  END-TO-END the mesh engine is ulp-close but
    not guaranteed bitwise vs the single-device engine: the two are
    different XLA programs, and fusion/FMA grouping at resharding
    boundaries (e.g. rope next to a pool scatter) can differ by 1 ulp even
    in fully replicated sections.  What IS hard-guaranteed: run-to-run
    determinism of a given mesh (same program, same seed → bitwise
    identical token streams), which is what the recovery/chaos suites pin.
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.jaxcompat import get_abstract_mesh, make_mesh, shard_map
from repro.distributed.sharding import param_pspecs, to_shardings
from repro.quant.qlinear import QLinear


def parse_mesh(text: str) -> dict:
    """``"model=4,data=2"`` → {"model": 4, "data": 2} (order preserved)."""
    out: dict = {}
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(f"bad mesh axis {part!r}; expected name=size")
        name, _, size = part.partition("=")
        out[name.strip()] = int(size)
    if not out:
        raise ValueError(f"empty mesh spec {text!r}")
    return out


def build_mesh(spec) -> Mesh:
    """Mesh from a ``parse_mesh`` dict (or spec string); needs
    prod(sizes) == device count."""
    if isinstance(spec, str):
        spec = parse_mesh(spec)
    axes = tuple(spec.keys())
    shape = tuple(int(spec[a]) for a in axes)
    need = math.prod(shape)
    have = jax.device_count()
    if need != have:
        raise ValueError(
            f"mesh {dict(spec)} needs {need} devices, have {have} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=N on CPU)")
    return make_mesh(shape, axes)


def _axis_size(mesh, axis: str) -> int:
    try:
        return int(mesh.shape[axis])
    except (KeyError, TypeError):
        return 1


def parallel_kind(qweight_spec: P, axis: str = "model") -> Optional[str]:
    """Classify a qweight PartitionSpec (trailing dims (K//2, N)) as
    "column" (N sharded), "row" (K sharded) or None (replicated/expert)."""
    sp = tuple(qweight_spec) if qweight_spec is not None else ()
    if len(sp) < 2:
        return None
    sp = sp + (None,) * 2  # defensive: short specs mean trailing None
    sp = sp[: max(2, len(tuple(qweight_spec)))]
    lead, k_ax, n_ax = sp[:-2], sp[-2], sp[-1]
    if any(a == axis for a in lead):
        return None  # expert/stacked-lead sharding is EP territory
    if n_ax == axis and k_ax != axis:
        return "column"
    if k_ax == axis and n_ax != axis:
        return "row"
    return None


def tp_feasible(q: QLinear, kind: str, tp: int) -> bool:
    """Can this QLinear actually run ``kind``-parallel over ``tp`` shards?"""
    if tp <= 1:
        return False
    if kind == "column":
        if q.d_out % tp:
            return False
        if q.u is not None and q.u.shape[-2] % tp:
            return False
        return True
    if kind == "row":
        if q.qweight.shape[-2] % tp:  # packed K//2 must split
            return False
        if q.v is not None and q.v.shape[-2] % tp:
            return False
        if q.act_group is None:
            # per-token scales see only the local K slice — a semantics
            # shift, not a rounding change.  Row-parallel needs group-wise
            # activation scales so the quantization grid is shard-invariant.
            return False
        if (q.d_in // tp) % q.act_group:
            return False  # group boundary would straddle shards
        return True
    return False


def _strip(q: QLinear) -> QLinear:
    return dataclasses.replace(q, parallel=None)


def _field_specs(q: QLinear, kind: str, axis: str) -> QLinear:
    """QLinear-shaped pytree of PartitionSpecs for shard_map in_specs.
    Built by replacing the array fields, so the treedef (static metadata)
    matches the argument exactly."""
    if kind == "replicate":
        return dataclasses.replace(
            q,
            qweight=P(None, None),
            w_scale=P(None),
            u=None if q.u is None else P(None, None),
            v=None if q.v is None else P(None, None),
        )
    if kind == "column":
        return dataclasses.replace(
            q,
            qweight=P(None, axis),
            w_scale=P(axis),
            u=None if q.u is None else P(axis, None),
            v=None if q.v is None else P(None, None),
        )
    return dataclasses.replace(
        q,
        qweight=P(axis, None),
        w_scale=P(None),
        u=None if q.u is None else P(None, None),
        v=None if q.v is None else P(axis, None),
    )


def tp_qlinear_apply(q: QLinear, x: jnp.ndarray, axis: str = "model"):
    """Apply a ``parallel``-tagged QLinear under the ambient mesh.

    Falls back to the plain single-device apply when no mesh is active or
    the axis is trivial/infeasible, so tagged params stay runnable anywhere.
    """
    from repro.quant.qlinear import qlinear_apply

    kind = q.parallel
    mesh = get_abstract_mesh()
    tp = _axis_size(mesh, axis) if mesh is not None else 1
    if mesh is None or kind not in ("column", "row", "replicate") \
            or (kind != "replicate" and not tp_feasible(q, kind, tp)):
        return qlinear_apply(_strip(q), x)

    nlead = x.ndim - 1
    if kind == "replicate":
        # untagged-by-rule / infeasible layers still run under shard_map so
        # their numerics are pinned: x is gathered to replicated (exact data
        # movement) and every shard runs the identical full-shape apply.
        # Leaving these to GSPMD can silently split the contraction against
        # a sharded producer (partial dots + all-reduce), breaking the
        # bitwise contract.
        def local_fn(xl, ql):
            return qlinear_apply(_strip(ql), xl)

        x_spec = P(*([None] * (nlead + 1)))
        out_spec = P(*([None] * (nlead + 1)))
    elif kind == "column":
        def local_fn(xl, ql):
            return qlinear_apply(_strip(ql), xl)

        x_spec = P(*([None] * (nlead + 1)))
        out_spec = P(*([None] * nlead), axis)
    else:
        def local_fn(xl, ql):
            # local GEMM partial + local LRC partial (K-sharded V) are both
            # in y already — ONE psum finishes the row-parallel matmul AND
            # the low-rank correction.  The partial stays f32 through the
            # psum (bf16 x upcasts losslessly; every impl computes y in f32
            # and rounds only at the end) so the output is rounded to the
            # activation dtype ONCE, like single-device — pre-rounding the
            # partials would lose mantissa to cancellation across shards.
            y = qlinear_apply(_strip(ql), xl.astype(jnp.float32))
            y = jax.lax.psum(y, axis)
            return y.astype(xl.dtype)

        x_spec = P(*([None] * nlead), axis)
        out_spec = P(*([None] * (nlead + 1)))

    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(x_spec, _field_specs(q, kind, axis)),
        out_specs=out_spec,
        check_vma=False,
        axis_names={axis},
    )
    return fn(x, q)


def local_kn_r(q: QLinear, kind: Optional[str], tp: int):
    """Per-shard (K, N, R) seen by the kernel plan under ``kind`` TP."""
    r = 0 if q.u is None else int(q.u.shape[-1])
    k, n = int(q.d_in), int(q.d_out)
    if kind == "column" and tp > 1:
        return (k, n // tp, r)
    if kind == "row" and tp > 1:
        return (k // tp, n, r)
    return (k, n, r)


def shard_params(params, mesh: Mesh, *, axis: str = "model",
                 replicate_dense: bool = True):
    """Tag + place a param tree for mesh serving.

    Every QLinear leaf whose sharding rule N- or K-shards the quantized
    weight gets ``parallel`` set ("column"/"row") and its fields device_put
    with the matching NamedShardings; infeasible leaves (divisibility,
    act_group straddling shards) fall back to replication with a warning.
    Non-QLinear leaves are replicated when ``replicate_dense`` (keeps dense
    matmuls bitwise identical to single-device — GSPMD never splits a
    contraction) or placed per the full rule table otherwise (MoE/EP).

    Returns ``(params, plan)`` where plan is a list of per-QLinear dicts
    (path, parallel, global/local (K, N, R)) for health()/introspection.
    """
    tp = _axis_size(mesh, axis)
    specs = param_pspecs(params, mesh)
    plan: list = []
    repl = NamedSharding(mesh, P())

    def _place(path, leaf, spec):
        from repro.distributed.sharding import _path_str
        if isinstance(leaf, QLinear):
            sp = tuple(spec.qweight) if spec.qweight is not None else ()
            if any(a == axis for a in sp[:-2]):
                # EP leaf: the leading (expert) dim is sharded.  Leave it
                # UNtagged — ep.py's shard_map owns these, and a TP tag
                # would nest shard_map inside its vmap'd body — and place
                # it per the rule spec so each device holds E/tp experts.
                plan.append({
                    "path": _path_str(path),
                    "parallel": "ep",
                    "global_knr": local_kn_r(leaf, None, 1),
                    "local_knr": local_kn_r(leaf, None, 1),
                    "act_group": leaf.act_group,
                    "impl": leaf.impl,
                    "ctx": leaf.ctx,
                })
                shardings = jax.tree.map(
                    lambda s: NamedSharding(mesh, s), spec,
                    is_leaf=lambda s: isinstance(s, P))
                return jax.device_put(leaf, shardings)
            kind = parallel_kind(spec.qweight, axis)
            if kind is not None and not tp_feasible(leaf, kind, tp):
                warnings.warn(
                    f"{_path_str(path)}: {kind}-parallel infeasible over "
                    f"{axis}={tp} (shape/act_group divisibility); "
                    "replicating", stacklevel=2)
                kind = None
            # replicated leaves are still TAGGED ("replicate") so they run
            # under shard_map — GSPMD left alone may split a replicated
            # weight against a sharded activation producer, which is not
            # bitwise.  Placement is plain replication either way.
            tagged = dataclasses.replace(leaf, parallel=kind or "replicate")
            if kind is None:
                shardings = jax.tree.map(lambda _: repl, tagged)
            else:
                shardings = jax.tree.map(
                    lambda s: NamedSharding(mesh, s),
                    _stacked_field_specs(tagged, kind, axis, spec),
                    is_leaf=lambda s: isinstance(s, P))
            plan.append({
                "path": _path_str(path),
                "parallel": kind,
                "global_knr": local_kn_r(leaf, None, 1),
                "local_knr": local_kn_r(leaf, kind, tp),
                "act_group": leaf.act_group,
                "impl": leaf.impl,
                "ctx": leaf.ctx,
            })
            return jax.device_put(tagged, shardings)
        if replicate_dense:
            return jax.device_put(leaf, repl)
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    out = jax.tree_util.tree_map_with_path(
        _place, params, specs,
        is_leaf=lambda l: isinstance(l, QLinear))
    return out, plan


def _stacked_field_specs(q: QLinear, kind: str, axis: str, guarded: QLinear):
    """Placement specs for a possibly layer-stacked QLinear: the trailing
    two dims follow ``_field_specs``; leading (scan) dims stay unsharded.
    ``guarded`` (the param_pspecs result) supplies the lead-dim count."""
    flat = _field_specs(q, kind, axis)

    def pad(spec, g_spec, arr):
        if spec is None or arr is None:
            return None
        lead = arr.ndim - len(tuple(spec))
        return P(*([None] * lead), *tuple(spec))

    return dataclasses.replace(
        q,
        qweight=pad(flat.qweight, guarded.qweight, q.qweight),
        w_scale=pad(flat.w_scale, guarded.w_scale, q.w_scale),
        u=pad(flat.u, guarded.u, q.u),
        v=pad(flat.v, guarded.v, q.v),
    )


def shard_kv_pool(pool, mesh: Mesh, data_axis: str = "data"):
    """Replicated-then-data-sharded KV paging: every leaf is replicated over
    "model"; the page axis (dim 1 of (L, NP, P, ...) pools) is sharded over
    ``data_axis`` when the page count divides it.  Page gathers/scatters are
    pure data movement, so this never perturbs decode numerics."""
    dsz = _axis_size(mesh, data_axis)

    def _one(leaf):
        if getattr(leaf, "ndim", 0) >= 2 and dsz > 1 \
                and leaf.shape[1] % dsz == 0:
            spec = P(None, data_axis)
        else:
            spec = P()
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree.map(_one, pool)
