"""Logical-axis sharding rules (MaxText-style) → PartitionSpecs.

Mapping philosophy (mesh axes: ["pod"], "data", "model"):
  * TP  — fused head / FF-hidden / expert / vocab dims → "model";
  * DP  — batch → ("pod","data") (multi-pod) or "data";
  * EP  — routed-expert leading dim → "model";
  * SP  — sequence → "data" when the batch cannot fill the DP axis
          (long-context decode / small-batch prefill);
  * stacked-layer leading dims (scan) are never sharded.

Every rule is divisibility-guarded: a dim that does not divide the mesh axis
size falls back to replication instead of failing to lower — e.g. smollm's 3
KV heads are replicated while its fused 192-wide kv projection still shards.
"""

from __future__ import annotations

import re
import warnings
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (regex on "/"-joined param path) -> per-dim axis plan, applied to the
# TRAILING dims (stacked layer dims are auto-prefixed with None).
# axis entries: "tp" | "dp" | None
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed$", ("tp", None)),  # (vocab, d)
    (r"dec_pos$", (None, None)),
    (r"lm_head$", (None, "tp")),  # (d, vocab)
    (r"attn/w[qkv]$", (None, "tp")),
    (r"attn/wo$", ("tp", None)),
    (r"xattn/w[qkv]$", (None, "tp")),
    (r"xattn/wo$", ("tp", None)),
    # MLA
    (r"attn/wq_a$", (None, None)),
    (r"attn/wq_b$", (None, "tp")),
    (r"attn/wkv_a$", (None, None)),
    (r"attn/wkv_b$", (None, "tp")),
    # MLP / shared experts
    (r"(mlp|shared)/w[gui]$", (None, "tp")),
    (r"(mlp|shared)/(wd|wo)$", ("tp", None)),
    # MoE (EP over experts)
    (r"router$", (None, None)),
    (r"experts/w[gu]$", ("tp", None, None)),
    (r"experts/wd$", ("tp", None, None)),
    # Mamba2
    (r"in_proj$", (None, "tp")),
    (r"conv_w$", (None, "tp")),
    (r"conv_b$", ("tp",)),
    (r"out_norm$", ("tp",)),
    (r"out_proj$", ("tp", None)),
    # MTP projector
    (r"mtp/proj$", (None, "tp")),
]

_AXIS_MAP = {
    "tp": "model",
    "dp_single": "data",
    "dp_multi": ("pod", "data"),
    "sp": "data",
}


def _mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


class ShardingFallback(UserWarning):
    """A rule wanted to shard a dim that does not divide its mesh axis; the
    dim fell back to replication.  Structured fields for tooling:
    ``path`` (param path), ``dim_index``, ``dim``, ``axis``, ``axis_size``."""

    def __init__(self, path: str, dim_index: int, dim: int, axis, axis_size: int):
        self.path = path
        self.dim_index = dim_index
        self.dim = dim
        self.axis = axis
        self.axis_size = axis_size
        super().__init__(
            f"{path or '<unnamed>'}: dim {dim_index} of size {dim} does not "
            f"divide mesh axis {axis!r} (size {axis_size}); replicating")


def _guard(shape, plan, mesh: Mesh, path: str = ""):
    """Drop plan entries whose dim does not divide the mesh axis size,
    emitting a structured :class:`ShardingFallback` warning for each drop
    (silent only when the axis is trivially size 1)."""
    out = []
    for i, (dim, axis) in enumerate(zip(shape, plan)):
        size = _mesh_axis_size(mesh, axis)
        if axis is None or dim % size != 0:
            if axis is not None and size > 1:
                warnings.warn(ShardingFallback(path, i, dim, axis, size),
                              stacklevel=3)
            out.append(None)
        else:
            out.append(axis)
    return P(*out)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


_STACKED = re.compile(r"(^|/)(layers|moe_layers|dense_layers|enc_layers|dec_layers)(/|$)")


def _resolve(axes_plan, mesh: Mesh, dp_axis):
    resolved = []
    for a in axes_plan:
        if a == "tp":
            resolved.append(_AXIS_MAP["tp"])
        elif a == "dp":
            resolved.append(dp_axis)
        else:
            resolved.append(a)
    return tuple(resolved)


def param_pspecs(params_tree, mesh: Mesh, multi_pod: bool = False):
    """PartitionSpec tree matching ``params_tree`` (arrays or
    ShapeDtypeStructs; QLinear leaves handled field-wise by the registered
    pytree flattening)."""

    def spec_one(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        n_stack = 1 if _STACKED.search(ps) else 0
        # QLinear fields carry their own suffix in the path (qweight/w_scale/u/v)
        for pat, plan in _PARAM_RULES:
            base = pat[:-1] if pat.endswith("$") else pat  # strip inner anchor
            m = re.search(base + r"(/(qweight|w_scale|u|v))?$", ps)
            if m:
                plan = _qlinear_adjust(plan, m.group(2), shape, n_stack)
                full = (None,) * n_stack + _resolve(plan, mesh, None)
                full = full[: len(shape)] + (None,) * max(0, len(shape) - len(full))
                return _guard(shape, full, mesh, path=ps)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(spec_one, params_tree)


def describe_sharding(params_tree, mesh: Mesh, multi_pod: bool = False):
    """The fully resolved plan as data (mirroring ``ctx.explain`` for kernel
    plans): one row per array leaf — ``{"path", "shape", "spec",
    "fallbacks": [ShardingFallback, ...]}`` — with divisibility fallbacks
    captured instead of warned.  Works on real arrays or ShapeDtypeStructs
    (``jax.eval_shape`` trees), so the plan is introspectable without
    materialising a model."""
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always", ShardingFallback)
        specs = param_pspecs(params_tree, mesh, multi_pod=multi_pod)
    fb_by_path: dict = {}
    for w in caught:
        if isinstance(w.message, ShardingFallback):
            fb_by_path.setdefault(w.message.path, []).append(w.message)

    rows = []

    def collect(path, leaf, spec):
        ps = _path_str(path)
        rows.append({
            "path": ps,
            "shape": tuple(leaf.shape),
            "spec": spec,
            "fallbacks": fb_by_path.get(ps, []),
        })
        return spec

    jax.tree_util.tree_map_with_path(collect, params_tree, specs)
    return rows


def _qlinear_adjust(plan, field: Optional[str], shape, n_stack: int):
    """Map a base weight's (..., in, out) plan onto QLinear fields:
    qweight (..., in//2, out) keeps the plan; w_scale (..., out) takes the
    out axis; u (..., out, k) takes the out axis; v (..., in, k) the in axis.
    Leading (e.g. expert) plan entries are preserved."""
    if field in (None, "/qweight", "qweight"):
        return plan
    if len(plan) < 2:
        return plan
    lead = tuple(plan[:-2])
    a_in, a_out = plan[-2], plan[-1]
    if field.endswith("w_scale"):
        return lead + (a_out,)
    if field.endswith("u"):
        return lead + (a_out, None)
    if field.endswith("v"):
        return lead + (a_in, None)
    return plan


def batch_pspec(mesh: Mesh, multi_pod: bool, global_batch: int, shard_seq: bool = False):
    """Spec for (B, S[, ...]) batch arrays.  When the batch cannot fill the
    DP axis (long-context), shard the sequence dim instead (SP)."""
    dp = _AXIS_MAP["dp_multi"] if multi_pod else _AXIS_MAP["dp_single"]
    dp_size = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        dp_size *= mesh.shape[a]
    if global_batch % dp_size == 0 and not shard_seq:
        return P(dp, None)
    if shard_seq or global_batch % dp_size:
        return P(None, _AXIS_MAP["sp"])
    return P(dp, None)


def cache_pspecs(cache_tree, mesh: Mesh, multi_pod: bool, global_batch: int):
    """KV/state caches: batch over DP when divisible; the head/feature dim
    over "model" when divisible; stacked layer dim unsharded."""
    dp = _AXIS_MAP["dp_multi"] if multi_pod else _AXIS_MAP["dp_single"]

    def spec_one(path, leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        ps = _path_str(path)
        plan = [None] * len(shape)
        # layout conventions: (L, B, S, H, hd) | (A, B, S, H, hd) |
        # (L, B, K-1, conv) | (L, B, H, N, P) | (B, S, D) enc_out
        if len(shape) >= 2:
            bdim = 0 if ps.endswith("enc_out") else 1
            if bdim < len(shape) and shape[bdim] == global_batch:
                plan[bdim] = dp
        # shard a trailing "feature-like" dim over model.  Prefer the HEADS
        # dim (ndim-2) over head_dim (ndim-1) — head_dim-sharded caches force
        # partial-logit all-reduces in attention (§Perf); never shard the
        # sequence dim (index 2 of stacked caches).
        candidates = [d for d in (len(shape) - 2, len(shape) - 1)
                      if d > 1 and not (d == 2 and len(shape) >= 4)]
        for d in candidates:
            if plan[d] is None and shape[d] % mesh.shape["model"] == 0 and shape[d] >= mesh.shape["model"]:
                plan[d] = "model"
                break
        return _guard(shape, tuple(plan), mesh, path=ps)

    return jax.tree_util.tree_map_with_path(spec_one, cache_tree)


def to_shardings(pspec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
