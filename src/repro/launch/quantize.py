"""LRC calibration launcher: quantize a model checkpoint W4A4 + low-rank.

    PYTHONPATH=src python -m repro.launch.quantize --arch smollm-135m \
        [--rank-frac 0.10] [--iters 1] [--method gptq] [--resume-dir tmp/]
"""

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--rank-frac", type=float, default=0.10)
    ap.add_argument("--iters", type=int, default=1)
    ap.add_argument("--method", default="gptq", choices=["gptq", "rtn"])
    ap.add_argument("--correction", default="lrc", choices=["lrc", "svd", "none"])
    ap.add_argument("--act-group", type=int, default=0)
    ap.add_argument("--ckpt", default=None, help="model checkpoint to load")
    ap.add_argument("--out", default="results/quantized")
    ap.add_argument("--resume-dir", default=None,
                    help="per-layer calibration resume directory")
    ap.add_argument("--calib-seqs", type=int, default=24)
    ap.add_argument("--calib-len", type=int, default=96)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import model as model_lib
    from repro.models.config import reduced as reduce_cfg
    from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
    from repro.data.loader import calib_sequences
    from repro.quant.calibrate import quantize_model
    from repro.quant.policy import QuantPolicy

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    if args.ckpt:
        like = jax.eval_shape(lambda k: model_lib.init_params(cfg, k),
                              jax.ShapeDtypeStruct((2,), jnp.uint32))
        params = load_checkpoint(args.ckpt, like)
    else:
        params = model_lib.init_params(cfg, jax.random.PRNGKey(0))

    calib = calib_sequences(cfg, n_seq=args.calib_seqs, seq_len=args.calib_len)
    policy = QuantPolicy(
        bits=4, act_bits=4, rank_frac=args.rank_frac,
        act_group=args.act_group or None, impl="sim",
        lrc_iters=args.iters, quant_method=args.method,
        correction=args.correction,
    )

    def progress(done, total):
        print(f"  layer {done + 1 if isinstance(done, int) else done}/{total}", flush=True)

    qparams = quantize_model(cfg, params, calib, policy,
                             resume_dir=args.resume_dir, progress=progress)
    path = save_checkpoint(args.out, 0, qparams)
    print(f"quantized params saved to {path}")


if __name__ == "__main__":
    main()
