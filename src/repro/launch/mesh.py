"""Production mesh definition.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips across 2 pods — the
"pod" axis carries pure data parallelism (gradient all-reduce crosses the
inter-pod DCN/ICI boundary once per step).

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).
"""

from __future__ import annotations

from repro.core.jaxcompat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests on forced host devices."""
    return make_mesh(shape, axes)
