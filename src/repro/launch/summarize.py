"""Aggregate results/dryrun/*.json into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.summarize [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def fmt_b(x):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if x < 1024:
            return f"{x:.1f}{unit}"
        x /= 1024
    return f"{x:.1f}PB"


def load(dir_path: Path):
    recs = []
    for p in sorted(dir_path.glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def table(recs, mesh_filter: str):
    rows = []
    for r in recs:
        if r.get("status") != "ok" or r.get("mesh") != mesh_filter:
            continue
        mem = r.get("memory", {})
        hbm = (mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
               + mem.get("output_size_in_bytes", 0))
        rows.append(dict(
            arch=r["arch"], shape=r["shape"], kind=r["kind"],
            compute=r["compute_term_s"], memory=r["memory_term_s"],
            coll=r["collective_term_s"], bottleneck=r["bottleneck"],
            bound=r["step_time_bound_s"], useful=r["useful_flops_ratio"],
            frac=r["roofline_fraction"], hbm=hbm,
        ))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--markdown", action="store_true", default=True)
    args = ap.parse_args()
    recs = load(Path(args.dir))
    for mesh in ("16x16", "2x16x16"):
        rows = table(recs, mesh)
        if not rows:
            continue
        print(f"\n### Mesh {mesh} ({'256' if mesh == '16x16' else '512'} chips)\n")
        print("| arch | shape | compute | memory | collective | bottleneck | "
              "step bound | useful | roofline | HBM/dev |")
        print("|---|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            print(
                f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute'])} | "
                f"{fmt_s(r['memory'])} | {fmt_s(r['coll'])} | **{r['bottleneck']}** | "
                f"{fmt_s(r['bound'])} | {r['useful']:.3f} | {r['frac']:.4f} | "
                f"{fmt_b(r['hbm'])} |"
            )
    fails = [r for r in recs if r.get("status") != "ok"]
    if fails:
        print("\nFAILURES:")
        for r in fails:
            print(f"  {r['arch']} x {r['shape']}: {r.get('error', '?')}")


if __name__ == "__main__":
    main()
