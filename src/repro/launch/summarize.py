"""Aggregate results/dryrun/*.json into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.summarize [--dir results/dryrun]

With ``--sharding`` the tool instead prints the fully resolved mesh
placement plan for one architecture — param path → PartitionSpec, with
every divisibility fallback (a rule that wanted to shard a dim that does
not divide its mesh axis) marked inline — without materialising a model
(``jax.eval_shape`` over an AbstractMesh, so no devices are needed):

    PYTHONPATH=src python -m repro.launch.summarize \
        --sharding smollm-135m --mesh model=4,data=2 [--full]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def fmt_b(x):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if x < 1024:
            return f"{x:.1f}{unit}"
        x /= 1024
    return f"{x:.1f}PB"


def load(dir_path: Path):
    recs = []
    for p in sorted(dir_path.glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def table(recs, mesh_filter: str):
    rows = []
    for r in recs:
        if r.get("status") != "ok" or r.get("mesh") != mesh_filter:
            continue
        mem = r.get("memory", {})
        hbm = (mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
               + mem.get("output_size_in_bytes", 0))
        rows.append(dict(
            arch=r["arch"], shape=r["shape"], kind=r["kind"],
            compute=r["compute_term_s"], memory=r["memory_term_s"],
            coll=r["collective_term_s"], bottleneck=r["bottleneck"],
            bound=r["step_time_bound_s"], useful=r["useful_flops_ratio"],
            frac=r["roofline_fraction"], hbm=hbm,
        ))
    return rows


def sharding_report(arch: str, mesh_spec: str, use_reduced: bool) -> int:
    """Print path → PartitionSpec for every param of ``arch`` under the
    mesh, flagging divisibility fallbacks.  Exit 0 always — fallbacks are
    a property of the (config, mesh) pair, not an error."""
    import jax

    from repro.configs import get_config
    from repro.core.jaxcompat import abstract_mesh
    from repro.distributed.sharding import describe_sharding
    from repro.distributed.tp import parse_mesh
    from repro.models import model as model_lib
    from repro.models.config import reduced

    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg)
    spec = parse_mesh(mesh_spec)
    mesh = abstract_mesh(tuple(spec.values()), tuple(spec.keys()))
    tree = jax.eval_shape(
        lambda: model_lib.init_params(cfg, jax.random.PRNGKey(0)))
    rows = describe_sharding(tree, mesh)
    n_fb = sum(len(r["fallbacks"]) for r in rows)
    print(f"sharding plan: {cfg.name}"
          f"{' (reduced)' if use_reduced else ''} on mesh {dict(spec)} "
          f"({len(rows)} leaves, {n_fb} divisibility fallback(s))\n")
    wpath = max(len(r["path"]) for r in rows)
    wshape = max(len(str(r["shape"])) for r in rows)
    for r in rows:
        mark = ""
        if r["fallbacks"]:
            mark = "  <- " + "; ".join(
                f"dim {f.dim_index} ({f.dim}) !% {f.axis}={f.axis_size}"
                for f in r["fallbacks"])
        print(f"  {r['path']:<{wpath}}  {str(r['shape']):<{wshape}}  "
              f"{r['spec']}{mark}")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--markdown", action="store_true", default=True)
    ap.add_argument("--sharding", default=None, metavar="ARCH",
                    help="print the resolved param-path -> PartitionSpec "
                         "plan for ARCH under --mesh instead of the dryrun "
                         "tables (divisibility fallbacks marked inline)")
    ap.add_argument("--mesh", default="model=4,data=2",
                    help="mesh axes for --sharding, e.g. model=4,data=2 "
                         "(AbstractMesh — no devices needed)")
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-reduced) config for --sharding")
    args = ap.parse_args()
    if args.sharding:
        raise SystemExit(sharding_report(args.sharding, args.mesh,
                                         not args.full))
    recs = load(Path(args.dir))
    for mesh in ("16x16", "2x16x16"):
        rows = table(recs, mesh)
        if not rows:
            continue
        print(f"\n### Mesh {mesh} ({'256' if mesh == '16x16' else '512'} chips)\n")
        print("| arch | shape | compute | memory | collective | bottleneck | "
              "step bound | useful | roofline | HBM/dev |")
        print("|---|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            print(
                f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute'])} | "
                f"{fmt_s(r['memory'])} | {fmt_s(r['coll'])} | **{r['bottleneck']}** | "
                f"{fmt_s(r['bound'])} | {r['useful']:.3f} | {r['frac']:.4f} | "
                f"{fmt_b(r['hbm'])} |"
            )
    fails = [r for r in recs if r.get("status") != "ok"]
    if fails:
        print("\nFAILURES:")
        for r in fails:
            print(f"  {r['arch']} x {r['shape']}: {r.get('error', '?')}")


if __name__ == "__main__":
    main()
