"""Serving launcher: batched requests through a (quantized) model.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        [--quantize [--act-group G]] [--requests 8] [--new-tokens 16] \
        [--page-size 16] [--kv-pages N] [--prefill-chunk C] \
        [--kv-dtype int8|int4 --kv-group G] \
        [--mesh model=N,data=M] \
        [--block-table results/block_table.json] [--vmem-budget BYTES] \
        [--deadline-s 30] [--retries 2] [--queue-bound 64] \
        [--inject-faults K --fault-seed S --parity-check]

Mesh-sharded serving (docs/serving.md, "Sharded serving"): ``--mesh``
builds a device mesh (prod(sizes) must equal the visible device count —
on CPU set ``XLA_FLAGS=--xla_force_host_platform_device_count=N``) and
serves through it: column/row-parallel shard_map QLinear forwards with
the low-rank factors following the weight shard (zero extra collectives),
replicated-then-data-sharded KV paging, and expert-parallel MoE dispatch
when the expert count divides the "model" axis.  ``--act-group`` selects
group-wise activation scales at calibration time — REQUIRED for
row-parallel sharding (per-token scales over a local K slice would shift
semantics, so those layers replicate instead).  Both chaos harnesses run
under the mesh unchanged: a given mesh is run-to-run deterministic, so
the recovery parity contract holds shard-count by shard-count.

KV-cache knobs (docs/serving.md): ``--page-size`` sets the paged-KV page
granularity, ``--kv-pages`` shrinks the shared page pool (admission then
accounts in available pages, not max_seq), ``--prefill-chunk`` enables
chunked prefill so long prompts interleave with ongoing decode.
``--kv-dtype int8|int4`` stores pages quantized (plus f32 scale planes
under the same block tables; ``--kv-group`` sets the scale granularity
along head_dim) with dequant fused into the attention inner loop — see
docs/serving.md "KV quantization".  Crash recovery reads the KV spec back
from the journal's open record, so a restore never needs the flags.

The kernel execution config (--block-table / --vmem-budget) is assembled
into one immutable ``KernelContext`` handed to the engine — no
process-global kernel state is mutated, so several launchers/engines can
coexist with different plan tables.  ``--impl`` selects the QLinear
execution path separately, via the engine's ``retag_qlinear_impl`` pass
(it is NOT recorded on the context).

Robustness knobs map 1:1 onto the engine's request lifecycle
(serve/lifecycle.py): per-request deadlines, bounded retries with
backoff, a bounded admission queue, and a stall watchdog.  With
``--inject-faults K`` a seeded ``FaultInjector`` (serve/faults.py)
targets K of the N requests with hard faults; the launcher then asserts
the structured split — exactly K FAILED/TIMED_OUT records, N-K FINISHED
— and exits non-zero on any mismatch or engine crash.  ``--parity-check``
additionally replays the same requests fault-free and asserts the
untargeted completions are bitwise identical.  CI runs this as the
chaos-smoke step.

Crash-recovery chaos (docs/serving.md, "Crash recovery")::

    PYTHONPATH=src python -m repro.launch.serve --requests 8 \
        --journal /tmp/rec/journal.wal --ckpt-dir /tmp/rec \
        --snapshot-every 4 --crash-after 2 [--crash-phase decode] \
        --parity-check

``--crash-after K`` schedules one seeded ``process_crash`` fault: the
engine dies (``SimulatedCrash`` unwinds ``run()``) on the K-th hit of the
chosen phase for a seed-picked rid, mid-flight, leaving only the
write-ahead journal and the last snapshot.  The launcher then calls
``ServeEngine.restore`` and asserts the recovery contract: the journal
replays cleanly, every request terminates EXACTLY once (``collate``
rejects double delivery or double terminals), and — with
``--parity-check`` — every token stream is bitwise identical to an
uninterrupted fault-free run.  Exits non-zero (and dumps
``results/serve_recovery_failure.json``) on any violation.  ``--journal``
and ``--ckpt-dir`` also work without ``--crash-after`` to journal /
snapshot a normal serve run.
"""

import argparse
import json
import os
import sys
import time


def _positive_int(s):
    v = int(s)
    if v <= 0:
        raise argparse.ArgumentTypeError(f"must be a positive integer, got {s}")
    return v


def build_context(block_table=None, vmem_budget=None):
    """CLI flags -> KernelContext (None when no flag was given); the shared
    mapping lives in repro.kernels.context.context_from_flags."""
    from repro.kernels.context import context_from_flags

    return context_from_flags(block_table, vmem_budget)


def _print_failure_summary(done, health, injector=None):
    from repro.serve.lifecycle import RequestState

    by_status = {}
    for rec in done.values():
        by_status.setdefault(rec.status.value, []).append(rec)
    print("request status: " + "  ".join(
        f"{status}={len(recs)}" for status, recs in sorted(by_status.items())))
    for rec in sorted(done.values(), key=lambda r: r.rid):
        if rec.status is RequestState.FINISHED:
            continue
        print(f"  rid {rec.rid}: {rec.status.value} "
              f"[{rec.error_kind}] after {rec.retries} retries, "
              f"{rec.new_tokens} token(s) — {rec.error}")
    counters = health["counters"]
    print(f"engine health: retries={counters['retries']} "
          f"slot_failures={counters['slot_failures']} "
          f"dead_slots={health['dead_slots']} "
          f"steps={counters['steps']} stalled={health['stalled']}")
    if injector is not None:
        print(f"fault injector: {json.dumps(injector.summary())}")


def _dump_recovery_failure(path, payload):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
    print(f"wrote failure report to {path}", file=sys.stderr)


def _crash_recovery_harness(args, cfg, params, ctx, run_engine,
                            mesh=None) -> int:
    """Kill the engine mid-run with a seeded process_crash, restore from
    journal+snapshot, and assert the recovery contract (exactly-once
    terminals; bitwise-equal streams with --parity-check).  Returns the
    process exit code."""
    import tempfile

    import numpy as np
    from repro.serve.engine import ServeEngine
    from repro.serve.faults import FaultInjector, FaultSpec, SimulatedCrash
    from repro.serve.journal import (JournalCorruption, JournalWriter,
                                     collate, read_journal)
    from repro.serve.lifecycle import RequestState

    workdir = args.ckpt_dir or tempfile.mkdtemp(prefix="serve_recovery_")
    jpath = args.journal or os.path.join(workdir, "journal.wal")
    snap_dir = os.path.join(workdir, "snapshots")
    snap_every = args.snapshot_every or 4
    rng = np.random.default_rng(args.fault_seed)
    crash_rid = int(rng.integers(0, args.requests))
    spec = FaultSpec(kind="process_crash", phase=args.crash_phase,
                     rid=crash_rid, at_call=args.crash_after)
    print(f"recovery chaos: scheduled process_crash at {args.crash_phase} "
          f"hit {args.crash_after} of rid {crash_rid} "
          f"(seed {args.fault_seed}); journal={jpath} "
          f"snapshots={snap_dir} every {snap_every} steps")

    crashed = None
    try:
        run_engine(FaultInjector([spec]),
                   journal=JournalWriter(jpath, overwrite=True),
                   snapshot_dir=snap_dir, snapshot_every=snap_every)
    except SimulatedCrash as e:
        crashed = e
    if crashed is None:
        print(f"RECOVERY CHAOS MISBEHAVED: the crash point was never hit "
              f"(rid {crash_rid} finished in fewer than "
              f"{args.crash_after + 1} {args.crash_phase} calls?)",
              file=sys.stderr)
        return 1
    print(f"engine died as scheduled: {crashed}")

    t0 = time.time()
    try:
        eng = ServeEngine.restore(cfg, params, jpath, snapshot_dir=snap_dir,
                                  snapshot_every=snap_every,
                                  kernel_impl=args.impl, ctx=ctx,
                                  max_retries=args.retries,
                                  stall_patience=args.stall_patience,
                                  mesh=mesh)
        done = eng.run()
        eng.journal.close()
        col = collate(read_journal(jpath).records)
    except JournalCorruption as e:
        print(f"RECOVERY FAILED: {e}", file=sys.stderr)
        _dump_recovery_failure("results/serve_recovery_failure.json",
                               {"error": str(e), "journal": jpath})
        return 1
    dt = time.time() - t0
    n_resumed = len(col.recovers)
    print(f"restored + drained in {dt:.2f}s "
          f"({len(done)} records, {n_resumed} recover marker(s))")

    problems = []
    # exactly-once termination: collate() above already raised on a double
    # terminal or a non-contiguous token stream; what remains is coverage
    missing = [rid for rid in range(args.requests) if rid not in col.terminals]
    if missing:
        problems.append(f"rids {missing} never reached a journaled terminal")
    not_finished = [r.rid for r in done.values()
                    if r.status is not RequestState.FINISHED]
    if not_finished:
        problems.append(f"rids {not_finished} did not finish cleanly: "
                        f"{[str(done[r].status) for r in not_finished]}")
    for rid, rec in done.items():
        if col.tokens.get(rid, []) != rec.out_tokens:
            problems.append(f"rid {rid}: journal stream != record stream")

    if args.parity_check:
        _, clean = run_engine(None)
        mismatched = [rid for rid in sorted(clean)
                      if done[rid].out_tokens != clean[rid].out_tokens]
        if mismatched:
            problems.append(f"streams for rids {mismatched} are not "
                            f"bitwise equal to the uninterrupted run")
        else:
            print(f"parity OK: all {len(clean)} recovered streams bitwise "
                  f"identical to the uninterrupted run (crash target "
                  f"rid {crash_rid} included)")

    if problems:
        for p in problems:
            print(f"RECOVERY VIOLATION: {p}", file=sys.stderr)
        _dump_recovery_failure(
            "results/serve_recovery_failure.json",
            {"problems": problems, "journal": jpath,
             "health": eng.health(),
             "records": {rid: {"status": str(r.status),
                               "tokens": r.out_tokens,
                               "error_kind": r.error_kind}
                         for rid, r in sorted(done.items())}})
        return 1
    print(f"recovery chaos OK: {len(done)} requests terminated exactly "
          f"once across the crash")
    return 0


def main():
    from repro.kernels.context import vmem_budget_arg

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--page-size", type=_positive_int, default=16,
                    help="paged-KV page granularity in tokens; pages are "
                         "allocated lazily as sequences cross page "
                         "boundaries and freed on terminal transitions")
    ap.add_argument("--kv-pages", type=_positive_int, default=None,
                    help="total pages in the shared KV pool (default sizes "
                         "the pool so exhaustion is impossible: "
                         "slots*ceil(max_seq/page_size)+1).  Shrinking it "
                         "makes admission account in available pages and "
                         "surfaces kv_pages_exhausted failures")
    ap.add_argument("--prefill-chunk", type=_positive_int, default=None,
                    help="chunked prefill width in tokens; long prompts "
                         "prefill one chunk per engine step, interleaved "
                         "with ongoing batched decode (default: whole "
                         "prompt in one forward)")
    ap.add_argument("--kv-dtype", default="f32",
                    choices=("f32", "bf16", "int8", "int4"),
                    help="KV-cache storage dtype (serve/kvquant.KVSpec): "
                         "int8/int4 store quantized pages plus f32 scale "
                         "planes under the same block tables, with dequant "
                         "fused into the attention gather; f32 (default) "
                         "is bitwise identical to the pre-KVSpec engine")
    ap.add_argument("--kv-group", type=_positive_int, default=None,
                    help="scale-group size along head_dim for quantized "
                         "--kv-dtype (e.g. 128); default: one scale per "
                         "(token, kv-head)")
    ap.add_argument("--impl", default="auto",
                    choices=("auto", "sim", "int8", "pallas", "fused"),
                    help="QLinear execution path for decode; auto = pallas "
                         "kernels on TPU (single-kernel fused forward per "
                         "the plan table), calibrated impl on CPU; fused "
                         "pins the single-kernel path")
    ap.add_argument("--block-table", default=None,
                    help="path to measured autotune winners "
                         "(results/block_table.json from "
                         "benchmarks/autotune_blocks.py) to overlay on the "
                         "analytic kernel plan table; may carry 'vmem' "
                         "(budget overrides) and 'layers' (per-layer plan "
                         "overrides) entries")
    ap.add_argument("--vmem-budget", type=vmem_budget_arg, default=None,
                    help="override the kernel VMEM working-set budgets "
                         "(positive bytes) used by plan resolution — both "
                         "the fused single-kernel budget and the chained "
                         "prologue budget; applied after --block-table, so "
                         "the CLI wins.  Use to probe real-TPU ceilings.")
    # -- request-lifecycle knobs (serve/lifecycle.py) -----------------------
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock deadline in seconds; "
                         "expired requests come back as TIMED_OUT records")
    ap.add_argument("--retries", type=int, default=2,
                    help="bounded per-step retry budget before a request "
                         "is FAILED and its slot quarantined")
    ap.add_argument("--retry-backoff-s", type=float, default=0.0,
                    help="base backoff between retries (doubles per attempt)")
    ap.add_argument("--queue-bound", type=int, default=None,
                    help="admission-queue depth limit; overflow is handled "
                         "per --queue-policy as REJECTED records")
    ap.add_argument("--queue-policy", default="reject_new",
                    choices=("reject_new", "drop_oldest"))
    ap.add_argument("--stall-patience", type=int, default=64,
                    help="steps without progress before the watchdog aborts "
                         "run() with a stall report")
    # -- chaos (serve/faults.py) --------------------------------------------
    ap.add_argument("--inject-faults", type=int, default=0, metavar="K",
                    help="target K of the N requests with seeded hard "
                         "faults; the run then ASSERTS exactly K "
                         "FAILED/TIMED_OUT + N-K FINISHED records and "
                         "exits 1 on mismatch")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--fault-kinds", default="exception,nan_logits,cache_corruption",
                    help="comma-separated hard fault kinds to sample from "
                         "(slow_step only fails requests via --deadline-s, "
                         "so it is not in the default pool)")
    ap.add_argument("--fault-phase", default="decode",
                    choices=("prefill", "decode", "sampling"))
    ap.add_argument("--parity-check", action="store_true",
                    help="replay the same requests fault-free and assert "
                         "the untargeted completions are bitwise identical")
    # -- crash recovery (serve/journal.py + engine snapshot/restore) --------
    ap.add_argument("--journal", default=None, metavar="PATH",
                    help="write-ahead request journal path; every submit/"
                         "token/terminal is fsync'd here before it becomes "
                         "visible (enables crash recovery)")
    ap.add_argument("--ckpt-dir", default=None, metavar="DIR",
                    help="engine snapshot directory (atomic tmp-rename "
                         "checkpoints of the paged pool / caches + "
                         "allocator + lifecycle state)")
    ap.add_argument("--snapshot-every", type=int, default=0, metavar="N",
                    help="snapshot the engine every N steps (step "
                         "boundaries only); requires --ckpt-dir")
    ap.add_argument("--crash-after", type=int, default=None, metavar="K",
                    help="crash-recovery chaos: kill the engine with a "
                         "seeded process_crash on the K-th --crash-phase "
                         "hit of a seed-picked rid, then restore from "
                         "journal+snapshot and assert every request "
                         "terminates exactly once (bitwise-equal streams "
                         "with --parity-check)")
    ap.add_argument("--crash-phase", default="decode",
                    choices=("prefill", "decode", "sampling"))
    # -- mesh-sharded serving (distributed/tp.py + ep.py) -------------------
    ap.add_argument("--mesh", default=None, metavar="SPEC",
                    help="serve through a device mesh, e.g. model=4,data=2 "
                         "(prod of sizes must equal the device count; on "
                         "CPU set XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N).  Column/row-parallel QLinear "
                         "forwards under shard_map, data-sharded KV pages, "
                         "expert-parallel MoE when n_experts divides "
                         "'model'")
    ap.add_argument("--act-group", type=_positive_int, default=None,
                    help="group-wise activation scales for --quantize "
                         "(paper Table 2 g, e.g. 16/128).  Required for "
                         "row-parallel TP: the group grid must divide the "
                         "local K slice, or those layers replicate")
    args = ap.parse_args()
    if args.crash_after is not None and args.crash_after < 0:
        ap.error("--crash-after must be >= 0")
    if args.crash_after is not None and args.inject_faults:
        ap.error("--crash-after and --inject-faults are separate chaos "
                 "harnesses; pick one")

    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import model as model_lib
    from repro.models.config import reduced as reduce_cfg
    from repro.serve.engine import ServeEngine
    from repro.serve.faults import FaultInjector
    from repro.serve.kvquant import KVSpec
    from repro.serve.lifecycle import Request, RequestState

    ctx = build_context(args.block_table, args.vmem_budget)
    if args.block_table:
        print(f"loaded kernel plan table from {args.block_table}")
    if args.vmem_budget is not None:
        print(f"kernel VMEM budgets set to {args.vmem_budget} bytes")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))

    if args.quantize:
        from repro.data.loader import calib_sequences
        from repro.quant.calibrate import quantize_model
        from repro.quant.policy import QuantPolicy

        calib = calib_sequences(cfg, n_seq=16, seq_len=64)
        params = quantize_model(
            cfg, params, calib,
            QuantPolicy(rank_frac=0.10, impl="sim", clip_ratio=0.9,
                        act_group=args.act_group),
        )
        print("serving the W4A4+LRC quantized model"
              + (f" (act_group={args.act_group})" if args.act_group else ""))

    mesh = None
    if args.mesh:
        from repro.distributed.tp import build_mesh

        mesh = build_mesh(args.mesh)
        print(f"serving through mesh {dict(mesh.shape)} "
              f"({jax.device_count()} devices)")

    injector = None
    if args.inject_faults > 0:
        kinds = tuple(k.strip() for k in args.fault_kinds.split(",") if k.strip())
        injector = FaultInjector.sample(
            range(args.requests), k=args.inject_faults, seed=args.fault_seed,
            kinds=kinds, phase=args.fault_phase,
            repeat=args.retries + 4,  # outlast the retry budget
        )
        print(f"injecting seeded faults (seed {args.fault_seed}) into "
              f"{args.inject_faults}/{args.requests} requests: "
              f"rids {sorted(injector.targets)}")

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
               for _ in range(args.requests)]

    kv_spec = KVSpec.from_flags(args.kv_dtype, args.kv_group)
    if kv_spec.is_quantized:
        print(f"KV cache stored as {kv_spec.describe()} "
              f"(dequant fused into the attention gather)")

    def run_engine(inj, **crash_safety):
        eng = ServeEngine(
            cfg, params, batch_slots=args.slots, max_seq=args.max_seq,
            page_size=args.page_size, kv_pages=args.kv_pages,
            prefill_chunk=args.prefill_chunk, kv_spec=kv_spec,
            kernel_impl=args.impl, ctx=ctx,
            max_retries=args.retries, retry_backoff_s=args.retry_backoff_s,
            queue_limit=args.queue_bound, queue_policy=args.queue_policy,
            default_deadline_s=args.deadline_s,
            stall_patience=args.stall_patience, injector=inj,
            mesh=mesh,
            **crash_safety,
        )
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p.copy(),
                               max_new_tokens=args.new_tokens))
        return eng, eng.run()

    if args.crash_after is not None:
        sys.exit(_crash_recovery_harness(args, cfg, params, ctx, run_engine,
                                         mesh=mesh))

    crash_safety = {}
    if args.journal:
        from repro.serve.journal import JournalWriter

        crash_safety["journal"] = JournalWriter(args.journal, overwrite=True)
    if args.ckpt_dir:
        crash_safety.update(snapshot_dir=args.ckpt_dir,
                            snapshot_every=args.snapshot_every)

    t0 = time.time()
    eng, done = run_engine(injector, **crash_safety)
    if eng.journal is not None:
        eng.journal.close()
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in done.values())
    finished = [r for r in done.values() if r.ok]
    print(f"{len(done)} requests ({len(finished)} finished), {total} tokens, "
          f"{dt:.2f}s -> {total / max(dt, 1e-9):.1f} tok/s")
    kv = eng.health()["kv"]
    if "bytes_per_token" in kv:
        print(f"kv cache: {kv['layout']}, "
              f"{kv['bytes_per_token']} B/token (all layers, K+V incl. "
              f"scale planes)")
    mh = eng.health()["mesh"]
    if mh is not None:
        kinds = {}
        for p in mh["decode_plans"].values():
            key = p["parallel"] or "replicated"
            kinds[key] = kinds.get(key, 0) + p["layers"]
        print(f"mesh: axes={mh['axes']} moe_impl={mh['moe_impl']} "
              f"ep_dropped={mh['ep_dropped']} layers_by_kind={kinds}")
    _print_failure_summary(done, eng.health(), injector)

    ok = True
    if injector is not None:
        # the acceptance split: exactly K structured failures, N-K clean
        failures = {r.rid for r in done.values()
                    if r.status in (RequestState.FAILED, RequestState.TIMED_OUT)}
        expect = injector.targets
        if failures != expect or len(finished) != args.requests - len(expect):
            print(f"CHAOS MISMATCH: expected failures {sorted(expect)}, "
                  f"got {sorted(failures)} "
                  f"({len(finished)} finished)", file=sys.stderr)
            ok = False
        else:
            print(f"chaos split OK: {len(expect)} structured failures, "
                  f"{len(finished)} completions, engine exited cleanly")
        if args.parity_check:
            _, clean = run_engine(None)
            mismatched = [
                rid for rid in sorted(set(done) - expect)
                if done[rid].out_tokens != clean[rid].out_tokens
            ]
            if mismatched:
                print(f"PARITY MISMATCH for untargeted rids {mismatched}",
                      file=sys.stderr)
                ok = False
            else:
                print(f"parity OK: {len(set(done) - expect)} untargeted "
                      f"requests bitwise identical to the fault-free run")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
