"""Serving launcher: batched requests through a (quantized) model.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        [--quantize] [--requests 8] [--new-tokens 16] \
        [--block-table results/block_table.json] [--vmem-budget BYTES]

The kernel execution config (--block-table / --vmem-budget) is assembled
into one immutable ``KernelContext`` handed to the engine — no
process-global kernel state is mutated, so several launchers/engines can
coexist with different plan tables.  ``--impl`` selects the QLinear
execution path separately, via the engine's ``retag_qlinear_impl`` pass
(it is NOT recorded on the context).
"""

import argparse
import time


def build_context(block_table=None, vmem_budget=None):
    """CLI flags -> KernelContext (None when no flag was given); the shared
    mapping lives in repro.kernels.context.context_from_flags."""
    from repro.kernels.context import context_from_flags

    return context_from_flags(block_table, vmem_budget)


def main():
    from repro.kernels.context import vmem_budget_arg

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--impl", default="auto",
                    choices=("auto", "sim", "int8", "pallas", "fused"),
                    help="QLinear execution path for decode; auto = pallas "
                         "kernels on TPU (single-kernel fused forward per "
                         "the plan table), calibrated impl on CPU; fused "
                         "pins the single-kernel path")
    ap.add_argument("--block-table", default=None,
                    help="path to measured autotune winners "
                         "(results/block_table.json from "
                         "benchmarks/autotune_blocks.py) to overlay on the "
                         "analytic kernel plan table; may carry 'vmem' "
                         "(budget overrides) and 'layers' (per-layer plan "
                         "overrides) entries")
    ap.add_argument("--vmem-budget", type=vmem_budget_arg, default=None,
                    help="override the kernel VMEM working-set budgets "
                         "(positive bytes) used by plan resolution — both "
                         "the fused single-kernel budget and the chained "
                         "prologue budget; applied after --block-table, so "
                         "the CLI wins.  Use to probe real-TPU ceilings.")
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import model as model_lib
    from repro.models.config import reduced as reduce_cfg
    from repro.serve.engine import Request, ServeEngine

    ctx = build_context(args.block_table, args.vmem_budget)
    if args.block_table:
        print(f"loaded kernel plan table from {args.block_table}")
    if args.vmem_budget is not None:
        print(f"kernel VMEM budgets set to {args.vmem_budget} bytes")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))

    if args.quantize:
        from repro.data.loader import calib_sequences
        from repro.quant.calibrate import quantize_model
        from repro.quant.policy import QuantPolicy

        calib = calib_sequences(cfg, n_seq=16, seq_len=64)
        params = quantize_model(
            cfg, params, calib,
            QuantPolicy(rank_frac=0.10, impl="sim", clip_ratio=0.9),
        )
        print("serving the W4A4+LRC quantized model")

    eng = ServeEngine(cfg, params, batch_slots=args.slots, max_seq=args.max_seq,
                      kernel_impl=args.impl, ctx=ctx)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
            max_new_tokens=args.new_tokens,
        ))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in done.values())
    print(f"{len(done)} requests, {total} tokens, {dt:.2f}s "
          f"-> {total / dt:.1f} tok/s")


if __name__ == "__main__":
    main()
