"""Serving launcher: batched requests through a (quantized) model.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        [--quantize] [--requests 8] [--new-tokens 16] \
        [--page-size 16] [--kv-pages N] [--prefill-chunk C] \
        [--block-table results/block_table.json] [--vmem-budget BYTES] \
        [--deadline-s 30] [--retries 2] [--queue-bound 64] \
        [--inject-faults K --fault-seed S --parity-check]

KV-cache knobs (docs/serving.md): ``--page-size`` sets the paged-KV page
granularity, ``--kv-pages`` shrinks the shared page pool (admission then
accounts in available pages, not max_seq), ``--prefill-chunk`` enables
chunked prefill so long prompts interleave with ongoing decode.

The kernel execution config (--block-table / --vmem-budget) is assembled
into one immutable ``KernelContext`` handed to the engine — no
process-global kernel state is mutated, so several launchers/engines can
coexist with different plan tables.  ``--impl`` selects the QLinear
execution path separately, via the engine's ``retag_qlinear_impl`` pass
(it is NOT recorded on the context).

Robustness knobs map 1:1 onto the engine's request lifecycle
(serve/lifecycle.py): per-request deadlines, bounded retries with
backoff, a bounded admission queue, and a stall watchdog.  With
``--inject-faults K`` a seeded ``FaultInjector`` (serve/faults.py)
targets K of the N requests with hard faults; the launcher then asserts
the structured split — exactly K FAILED/TIMED_OUT records, N-K FINISHED
— and exits non-zero on any mismatch or engine crash.  ``--parity-check``
additionally replays the same requests fault-free and asserts the
untargeted completions are bitwise identical.  CI runs this as the
chaos-smoke step.
"""

import argparse
import json
import sys
import time


def _positive_int(s):
    v = int(s)
    if v <= 0:
        raise argparse.ArgumentTypeError(f"must be a positive integer, got {s}")
    return v


def build_context(block_table=None, vmem_budget=None):
    """CLI flags -> KernelContext (None when no flag was given); the shared
    mapping lives in repro.kernels.context.context_from_flags."""
    from repro.kernels.context import context_from_flags

    return context_from_flags(block_table, vmem_budget)


def _print_failure_summary(done, health, injector=None):
    from repro.serve.lifecycle import RequestState

    by_status = {}
    for rec in done.values():
        by_status.setdefault(rec.status.value, []).append(rec)
    print("request status: " + "  ".join(
        f"{status}={len(recs)}" for status, recs in sorted(by_status.items())))
    for rec in sorted(done.values(), key=lambda r: r.rid):
        if rec.status is RequestState.FINISHED:
            continue
        print(f"  rid {rec.rid}: {rec.status.value} "
              f"[{rec.error_kind}] after {rec.retries} retries, "
              f"{rec.new_tokens} token(s) — {rec.error}")
    counters = health["counters"]
    print(f"engine health: retries={counters['retries']} "
          f"slot_failures={counters['slot_failures']} "
          f"dead_slots={health['dead_slots']} "
          f"steps={counters['steps']} stalled={health['stalled']}")
    if injector is not None:
        print(f"fault injector: {json.dumps(injector.summary())}")


def main():
    from repro.kernels.context import vmem_budget_arg

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--page-size", type=_positive_int, default=16,
                    help="paged-KV page granularity in tokens; pages are "
                         "allocated lazily as sequences cross page "
                         "boundaries and freed on terminal transitions")
    ap.add_argument("--kv-pages", type=_positive_int, default=None,
                    help="total pages in the shared KV pool (default sizes "
                         "the pool so exhaustion is impossible: "
                         "slots*ceil(max_seq/page_size)+1).  Shrinking it "
                         "makes admission account in available pages and "
                         "surfaces kv_pages_exhausted failures")
    ap.add_argument("--prefill-chunk", type=_positive_int, default=None,
                    help="chunked prefill width in tokens; long prompts "
                         "prefill one chunk per engine step, interleaved "
                         "with ongoing batched decode (default: whole "
                         "prompt in one forward)")
    ap.add_argument("--impl", default="auto",
                    choices=("auto", "sim", "int8", "pallas", "fused"),
                    help="QLinear execution path for decode; auto = pallas "
                         "kernels on TPU (single-kernel fused forward per "
                         "the plan table), calibrated impl on CPU; fused "
                         "pins the single-kernel path")
    ap.add_argument("--block-table", default=None,
                    help="path to measured autotune winners "
                         "(results/block_table.json from "
                         "benchmarks/autotune_blocks.py) to overlay on the "
                         "analytic kernel plan table; may carry 'vmem' "
                         "(budget overrides) and 'layers' (per-layer plan "
                         "overrides) entries")
    ap.add_argument("--vmem-budget", type=vmem_budget_arg, default=None,
                    help="override the kernel VMEM working-set budgets "
                         "(positive bytes) used by plan resolution — both "
                         "the fused single-kernel budget and the chained "
                         "prologue budget; applied after --block-table, so "
                         "the CLI wins.  Use to probe real-TPU ceilings.")
    # -- request-lifecycle knobs (serve/lifecycle.py) -----------------------
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock deadline in seconds; "
                         "expired requests come back as TIMED_OUT records")
    ap.add_argument("--retries", type=int, default=2,
                    help="bounded per-step retry budget before a request "
                         "is FAILED and its slot quarantined")
    ap.add_argument("--retry-backoff-s", type=float, default=0.0,
                    help="base backoff between retries (doubles per attempt)")
    ap.add_argument("--queue-bound", type=int, default=None,
                    help="admission-queue depth limit; overflow is handled "
                         "per --queue-policy as REJECTED records")
    ap.add_argument("--queue-policy", default="reject_new",
                    choices=("reject_new", "drop_oldest"))
    ap.add_argument("--stall-patience", type=int, default=64,
                    help="steps without progress before the watchdog aborts "
                         "run() with a stall report")
    # -- chaos (serve/faults.py) --------------------------------------------
    ap.add_argument("--inject-faults", type=int, default=0, metavar="K",
                    help="target K of the N requests with seeded hard "
                         "faults; the run then ASSERTS exactly K "
                         "FAILED/TIMED_OUT + N-K FINISHED records and "
                         "exits 1 on mismatch")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--fault-kinds", default="exception,nan_logits,cache_corruption",
                    help="comma-separated hard fault kinds to sample from "
                         "(slow_step only fails requests via --deadline-s, "
                         "so it is not in the default pool)")
    ap.add_argument("--fault-phase", default="decode",
                    choices=("prefill", "decode", "sampling"))
    ap.add_argument("--parity-check", action="store_true",
                    help="replay the same requests fault-free and assert "
                         "the untargeted completions are bitwise identical")
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import model as model_lib
    from repro.models.config import reduced as reduce_cfg
    from repro.serve.engine import ServeEngine
    from repro.serve.faults import FaultInjector
    from repro.serve.lifecycle import Request, RequestState

    ctx = build_context(args.block_table, args.vmem_budget)
    if args.block_table:
        print(f"loaded kernel plan table from {args.block_table}")
    if args.vmem_budget is not None:
        print(f"kernel VMEM budgets set to {args.vmem_budget} bytes")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))

    if args.quantize:
        from repro.data.loader import calib_sequences
        from repro.quant.calibrate import quantize_model
        from repro.quant.policy import QuantPolicy

        calib = calib_sequences(cfg, n_seq=16, seq_len=64)
        params = quantize_model(
            cfg, params, calib,
            QuantPolicy(rank_frac=0.10, impl="sim", clip_ratio=0.9),
        )
        print("serving the W4A4+LRC quantized model")

    injector = None
    if args.inject_faults > 0:
        kinds = tuple(k.strip() for k in args.fault_kinds.split(",") if k.strip())
        injector = FaultInjector.sample(
            range(args.requests), k=args.inject_faults, seed=args.fault_seed,
            kinds=kinds, phase=args.fault_phase,
            repeat=args.retries + 4,  # outlast the retry budget
        )
        print(f"injecting seeded faults (seed {args.fault_seed}) into "
              f"{args.inject_faults}/{args.requests} requests: "
              f"rids {sorted(injector.targets)}")

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
               for _ in range(args.requests)]

    def run_engine(inj):
        eng = ServeEngine(
            cfg, params, batch_slots=args.slots, max_seq=args.max_seq,
            page_size=args.page_size, kv_pages=args.kv_pages,
            prefill_chunk=args.prefill_chunk,
            kernel_impl=args.impl, ctx=ctx,
            max_retries=args.retries, retry_backoff_s=args.retry_backoff_s,
            queue_limit=args.queue_bound, queue_policy=args.queue_policy,
            default_deadline_s=args.deadline_s,
            stall_patience=args.stall_patience, injector=inj,
        )
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p.copy(),
                               max_new_tokens=args.new_tokens))
        return eng, eng.run()

    t0 = time.time()
    eng, done = run_engine(injector)
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in done.values())
    finished = [r for r in done.values() if r.ok]
    print(f"{len(done)} requests ({len(finished)} finished), {total} tokens, "
          f"{dt:.2f}s -> {total / max(dt, 1e-9):.1f} tok/s")
    _print_failure_summary(done, eng.health(), injector)

    ok = True
    if injector is not None:
        # the acceptance split: exactly K structured failures, N-K clean
        failures = {r.rid for r in done.values()
                    if r.status in (RequestState.FAILED, RequestState.TIMED_OUT)}
        expect = injector.targets
        if failures != expect or len(finished) != args.requests - len(expect):
            print(f"CHAOS MISMATCH: expected failures {sorted(expect)}, "
                  f"got {sorted(failures)} "
                  f"({len(finished)} finished)", file=sys.stderr)
            ok = False
        else:
            print(f"chaos split OK: {len(expect)} structured failures, "
                  f"{len(finished)} completions, engine exited cleanly")
        if args.parity_check:
            _, clean = run_engine(None)
            mismatched = [
                rid for rid in sorted(set(done) - expect)
                if done[rid].out_tokens != clean[rid].out_tokens
            ]
            if mismatched:
                print(f"PARITY MISMATCH for untargeted rids {mismatched}",
                      file=sys.stderr)
                ok = False
            else:
                print(f"parity OK: {len(set(done) - expect)} untargeted "
                      f"requests bitwise identical to the fault-free run")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
