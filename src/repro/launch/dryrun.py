import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init).  Placeholder host devices exist ONLY for this dry-run.

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.configs.shapes import SHAPES, applicable  # noqa: E402
from repro.core.jaxcompat import set_mesh  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import (  # noqa: E402
    combine_costs,
    extract_costs,
    memory_info,
    model_flops,
    roofline_from_costs,
)
from repro.launch.specs import build_cell  # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _compile_cell(arch, shape_name, mesh, multi_pod, cfg_override=None):
    cell = build_cell(arch, shape_name, mesh, multi_pod, cfg_override=cfg_override)
    with set_mesh(mesh):
        jitted = jax.jit(cell["fn"], in_shardings=cell["in_shardings"])
        lowered = jitted.lower(*cell["args"])
        compiled = lowered.compile()
    return compiled, cell


def _shrunk(cfg, **depth):
    return dataclasses.replace(cfg, unroll=True, **depth)


def extrapolated_costs(arch, shape_name, mesh, multi_pod, base_cfg=None):
    """Per-device flops/bytes/collectives at FULL depth, from 2-3 small
    UNROLLED compiles (XLA cost_analysis counts while bodies once — see
    repro.models.remat.scan_layers).  Exact for homogeneous layer stacks.
    ``base_cfg``: optional config override (§Perf hillclimb variants)."""
    cfg = base_cfg if base_cfg is not None else get_config(arch)
    fam = cfg.family

    def costs_for(cfg_k):
        compiled, _ = _compile_cell(arch, shape_name, mesh, multi_pod, cfg_override=cfg_k)
        return extract_costs(compiled)

    if fam == "moe":
        nd, nm = cfg.n_dense_layers, cfg.n_layers - cfg.n_dense_layers
        a = costs_for(_shrunk(cfg, n_layers=2, n_dense_layers=1))  # 1d + 1m
        b = costs_for(_shrunk(cfg, n_layers=3, n_dense_layers=1))  # 1d + 2m
        c = costs_for(_shrunk(cfg, n_layers=3, n_dense_layers=2))  # 2d + 1m
        moe_unit = combine_costs(b, a, 1.0, -1.0)
        dense_unit = combine_costs(c, a, 1.0, -1.0)
        base = combine_costs(a, combine_costs(moe_unit, dense_unit, 1.0, 1.0), 1.0, -1.0)
        total = combine_costs(base, moe_unit, 1.0, float(nm))
        total = combine_costs(total, dense_unit, 1.0, float(nd))
        return total
    if fam == "hybrid":
        e = cfg.hybrid_attn_every
        c6 = costs_for(_shrunk(cfg, n_layers=e))          # base + e·m + 1·app
        c7 = costs_for(_shrunk(cfg, n_layers=e + 1))      # + 1 mamba
        c12 = costs_for(_shrunk(cfg, n_layers=2 * e))     # base + 2e·m + 2·app
        m_unit = combine_costs(c7, c6, 1.0, -1.0)
        # app = (c12 - c6) - e·m
        app_unit = combine_costs(combine_costs(c12, c6, 1.0, -1.0), m_unit, 1.0, -float(e))
        base = combine_costs(c6, combine_costs(m_unit, app_unit, float(e), 1.0), 1.0, -1.0)
        n_apps = cfg.n_layers // e
        total = combine_costs(base, m_unit, 1.0, float(cfg.n_layers))
        total = combine_costs(total, app_unit, 1.0, float(n_apps))
        return total
    if fam == "encdec":
        a = costs_for(_shrunk(cfg, n_layers=1, n_encoder_layers=1))
        b = costs_for(_shrunk(cfg, n_layers=2, n_encoder_layers=1))
        c = costs_for(_shrunk(cfg, n_layers=1, n_encoder_layers=2))
        dec_unit = combine_costs(b, a, 1.0, -1.0)
        enc_unit = combine_costs(c, a, 1.0, -1.0)
        base = combine_costs(a, combine_costs(dec_unit, enc_unit, 1.0, 1.0), 1.0, -1.0)
        total = combine_costs(base, dec_unit, 1.0, float(cfg.n_layers))
        total = combine_costs(total, enc_unit, 1.0, float(cfg.n_encoder_layers))
        return total
    # dense / vlm / ssm: homogeneous stack
    a = costs_for(_shrunk(cfg, n_layers=1))
    b = costs_for(_shrunk(cfg, n_layers=2))
    unit = combine_costs(b, a, 1.0, -1.0)
    base = combine_costs(a, unit, 1.0, -1.0)
    return combine_costs(base, unit, 1.0, float(cfg.n_layers))


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             with_costs: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    # 1) FULL-depth scanned compile: proves lowering + gives true memory
    compiled, cell = _compile_cell(arch, shape_name, mesh, multi_pod)
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    print(mem)  # proves it fits (bytes-per-device breakdown)
    print({k: compiled.cost_analysis()[k]
           for k in ("flops", "bytes accessed")
           if k in compiled.cost_analysis()})
    mem_rec = memory_info(compiled)
    # 2) cost extrapolation from small unrolled variants
    if with_costs:
        costs = extrapolated_costs(arch, shape_name, mesh, multi_pod)
    else:
        costs = extract_costs(compiled)
    mf = model_flops(get_config(arch), SHAPES[shape_name])
    rf = roofline_from_costs(costs, mf, n_chips, mem_rec)
    rec = dict(
        arch=arch,
        shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16",
        n_chips=n_chips,
        kind=cell["kind"],
        status="ok",
        compile_s=round(t_compile, 1),
        total_s=round(time.time() - t0, 1),
        **rf,
    )
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}.json"
    (out_dir / name).write_text(json.dumps(rec, indent=2, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--no-costs", action="store_true",
                    help="skip the unrolled cost extrapolation (compile-only)")
    args = ap.parse_args()
    out_dir = Path(args.out)

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for shape in SHAPES:
                if applicable(cfg, shape):
                    cells.append((arch, shape, args.multi_pod))
    else:
        cells.append((args.arch, args.shape, args.multi_pod))

    failures = 0
    for arch, shape, mp in cells:
        name = f"{arch}__{shape}__{'multi' if mp else 'single'}.json"
        if args.skip_done and (out_dir / name).exists():
            rec = json.loads((out_dir / name).read_text())
            if rec.get("status") == "ok":
                print(f"[skip] {name}")
                continue
        print(f"=== {arch} × {shape} ({'2x16x16' if mp else '16x16'}) ===", flush=True)
        try:
            rec = run_cell(arch, shape, mp, out_dir, with_costs=not args.no_costs)
            print(
                f"  ok: bottleneck={rec['bottleneck']} "
                f"step_bound={rec['step_time_bound_s']:.4f}s "
                f"useful={rec['useful_flops_ratio']:.3f} "
                f"roofline_frac={rec['roofline_fraction']:.3f} "
                f"(total {rec['total_s']}s)",
                flush=True,
            )
        except Exception as e:  # noqa: BLE001
            failures += 1
            out_dir.mkdir(parents=True, exist_ok=True)
            (out_dir / name).write_text(
                json.dumps(
                    dict(arch=arch, shape=shape, status="fail",
                         error=f"{type(e).__name__}: {e}"),
                    indent=2,
                )
            )
            print(f"  FAIL: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
