"""Roofline-term derivation from the compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

`cost_analysis()` on the SPMD-partitioned module reports PER-DEVICE flops and
bytes (verified experimentally), so the per-chip division is already done;
collective bytes are summed over the per-device HLO's collective operands.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.
"""

from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s/link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1,
    "s4": 0.5, "u4": 0.5,
    "pred": 1, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
# an HLO instruction definition: "%name = <shape> opcode(operands...)"
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\],{}:#\s*]+?))\s+([\w\-]+)\("
)


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes of every collective op in the (per-device) HLO."""
    result_bytes: Dict[str, float] = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if m:
            result_bytes[m.group(1)] = _shape_bytes(m.group(2))
    totals = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if not m:
            continue
        op = m.group(3)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):  # e.g. all-reduce-start
                kind = c
                break
        if kind is None:
            continue
        counts[kind] += 1
        # operand list: between the op's '(' and its matching ')'
        body = ln[m.end():]
        depth = 1
        args = ""
        for ch in body:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            args += ch
        got = 0.0
        for tok in re.findall(r"%?([\w.\-]+)", args):
            if tok in result_bytes:
                got += result_bytes[tok]
        if got == 0.0:
            got = _shape_bytes(m.group(2))  # fall back to result size
        totals[kind] += got
    totals["_counts"] = counts  # type: ignore
    return totals


def extract_costs(compiled) -> dict:
    """Per-device flops / bytes / collective-bytes of one compiled artifact.
    NOTE: scanned (while-loop) bodies are counted ONCE by XLA — callers must
    use UNROLLED variants (cfg.unroll) and extrapolate for scanned models."""
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return dict(
        flops=float(cost.get("flops", 0.0)),
        bytes=float(cost.get("bytes accessed", 0.0)),
        coll={k: v for k, v in coll.items() if not k.startswith("_")},
        coll_counts=dict(coll.get("_counts", {})),
    )


def combine_costs(a: dict, b: dict, fa: float, fb: float) -> dict:
    """fa·a + fb·b, fieldwise."""
    out = dict(
        flops=fa * a["flops"] + fb * b["flops"],
        bytes=fa * a["bytes"] + fb * b["bytes"],
        coll={k: fa * a["coll"].get(k, 0.0) + fb * b["coll"].get(k, 0.0)
              for k in set(a["coll"]) | set(b["coll"])},
        coll_counts={k: fa * a["coll_counts"].get(k, 0) + fb * b["coll_counts"].get(k, 0)
                     for k in set(a["coll_counts"]) | set(b["coll_counts"])},
    )
    return out


def memory_info(compiled) -> dict:
    mem = compiled.memory_analysis()
    info = {}
    if mem is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            info[f] = int(getattr(mem, f, 0))
    return info


def roofline_from_costs(costs: dict, model_flops_total: float, n_chips: int,
                        mem_info: dict | None = None) -> dict:
    flops_dev = costs["flops"]
    bytes_dev = costs["bytes"]
    coll_dev = sum(costs["coll"].values())
    compute_t = flops_dev / PEAK_FLOPS
    memory_t = bytes_dev / HBM_BW
    coll_t = coll_dev / LINK_BW
    terms = dict(compute=compute_t, memory=memory_t, collective=coll_t)
    bottleneck = max(terms, key=terms.get)
    hlo_total = flops_dev * n_chips
    return dict(
        flops_per_device=flops_dev,
        bytes_per_device=bytes_dev,
        collective_bytes_per_device=coll_dev,
        collective_breakdown=costs["coll"],
        collective_counts=costs["coll_counts"],
        compute_term_s=compute_t,
        memory_term_s=memory_t,
        collective_term_s=coll_t,
        bottleneck=bottleneck,
        step_time_bound_s=max(terms.values()),
        model_flops_total=model_flops_total,
        hlo_flops_total=hlo_total,
        useful_flops_ratio=(model_flops_total / hlo_total) if hlo_total else 0.0,
        roofline_fraction=(
            (model_flops_total / n_chips / PEAK_FLOPS) / max(terms.values())
            if max(terms.values()) > 0
            else 0.0
        ),
        memory=mem_info or {},
    )


def prologue_intermediate_bytes(m: int, k: int, r: int = 0,
                                act_group: int = None) -> int:
    """Bytes of ONE copy of the prologue's intermediates for an (M, K)
    block: int8 xq + the f32 scales (per-token (M, 1) column, or the
    per-group (M, K/g) scale plane when ``act_group`` is set) + the f32 xv
    projection.  THE one spelling of the term — both the activation-byte
    model below and the latency model
    (benchmarks/latency_kernels._roofline_time) derive from it, so a
    byte-model change can never update one and silently miss the other."""
    n_s = 1 if act_group is None else k // act_group
    return m * k + 4 * m * n_s + (4 * m * r if r else 0)


def prologue_activation_bytes(m: int, k: int, r: int = 0, *,
                              rotate: bool = True, fused: bool = None,
                              path: str = None,
                              act_bytes: int = 2,
                              act_group: int = None) -> float:
    """Activation-side HBM traffic of the W4A4+LRC forward for an (M, K)
    activation block, up to (excluding) the output-tile write — i.e. every
    intermediate the GEMM's consumption of xq/sx/xv implies.

    path="unfused" — three independent passes: the WHT kernel reads x and
    writes the rotated copy; the quantizer re-reads it and writes xq/sx; the
    (x·V) projection re-reads it once more and writes xv; the GEMM kernel
    then reads xq/sx/xv back from HBM.
    path="chained" — kernels/prologue.py → kernels/w4a4.py: ONE read of x
    emits xq/sx/xv (the rotated copy never exists in HBM), but the GEMM
    kernel still reads the M×K xq (+ sx/xv) back — one full round-trip.
    path="fused"   — kernels/fused_gemm.py single kernel, resident-prologue
    variant: ONE read of x; xq/sx/xv live and die in VMEM scratch.  The
    chained→fused delta is exactly the eliminated M×K write+read (plus the
    sx/xv round-trip).
    path="fused_stream" — the same single kernel with the streamed prologue
    (no f32 row slab in VMEM; rotate=False only): the prologue sweep reads
    the x chunks once for the amax fold and the first GEMM visit re-streams
    them — TWO reads of x, still strictly below chained (the xq/sx/xv
    round-trip never happens).

    ``act_group`` switches the per-token (M, 1) scale for the per-group
    (M, K/g) scale plane (paper Table 2): the scale term of the
    intermediate traffic grows K/g-fold on the paths that round-trip the
    prologue outputs through HBM (chained / unfused); the fused paths keep
    the plane in VMEM, so their bytes are granularity-independent.

    ``fused`` is the legacy boolean spelling (True ≡ "chained", the PR 1
    fusion; False ≡ "unfused").  Weight-side bytes (V itself, the packed W)
    are identical in all layouts and excluded — this isolates exactly the
    traffic fusion removes; K-chunk V re-reads live in the latency model
    (benchmarks/latency_kernels._roofline_time).
    """
    if path is None:
        path = "chained" if fused else "unfused"
    a = m * k * act_bytes  # one full read or write of the activation block
    out = prologue_intermediate_bytes(m, k, r, act_group=act_group)
    if path == "fused":
        return a  # single kernel: x in, everything else VMEM-resident
    if path == "fused_stream":
        return 2 * a  # amax sweep + quantize/project re-stream of x
    if path == "chained":
        return a + 2 * out  # prologue writes xq/sx/xv; the GEMM reads them
    if path != "unfused":
        raise ValueError(f"unknown path {path!r}; "
                         "expected fused | fused_stream | chained | unfused")
    total = a + 2 * out  # quantizer pass + GEMM-side re-read
    if rotate:
        total += 2 * a  # WHT pass: read x, write the rotated copy to HBM
    if r:
        total += a  # projection pass re-reads the (rotated) activations
    return total


def attention_kv_bytes(context_len: int, n_kv_heads: int, head_dim: int,
                       kv_dtype: str = "f32", kv_group: int = None) -> int:
    """HBM bytes ONE decode token's attention reads from the KV stream of a
    ``context_len``-token context, for one layer: K and V pages (at the
    spec's storage width) plus, for quantized specs, their f32 scale
    planes.  THE one spelling of the attention-byte model — derived from
    ``KVSpec.kv_bytes_per_token`` (the same function ``health()["kv"]``
    reports), so the roofline columns in benchmarks/latency_kernels.py and
    the serving telemetry can never disagree.  The flash gather streams
    each page exactly once (online softmax), so read bytes = stored bytes.
    """
    from repro.serve.kvquant import KVSpec

    spec = KVSpec(dtype=kv_dtype,
                  group=kv_group if kv_dtype in ("int8", "int4") else None)
    return context_len * spec.kv_bytes_per_token(n_kv_heads, head_dim)


def tp_psum_bytes_per_token(n_out: int, tp: int,
                            dtype_bytes: int = 4) -> float:
    """Per-token ICI payload of the ONE row-parallel ``psum`` a TP W4A4+LRC
    layer emits (distributed/tp.py): a ring all-reduce moves
    ``2·(tp-1)/tp`` of the f32 partial per device, and the LRC partial is
    already merged into the same payload (the zero-extra-collective
    invariant), so the payload is exactly the (N,)-wide output row.  THE
    one spelling of the TP comms-byte model — the ``comms_kb_`` columns in
    benchmarks/latency_kernels.py and the CI regression gate derive from
    it, so payload growth (e.g. an accidental second collective or an
    un-merged LRC psum) cannot land silently."""
    if tp <= 1:
        return 0.0
    return 2.0 * (tp - 1) / tp * n_out * dtype_bytes


def ep_combine_bytes_per_token(d_model: int, tp: int,
                               dtype_bytes: int = 4) -> float:
    """Per-token ICI payload of the EP combine (distributed/ep.py): the
    capacity dispatch is local (tokens are replicated over "model"), so the
    ONLY collective is the final psum of the (d_model,)-wide combined
    output — the same ring all-reduce payload shape as a row-parallel
    matmul.  The with_stats drop counter rides the same psum phase, so it
    adds 4 bytes, not a collective — excluded here as noise."""
    if tp <= 1:
        return 0.0
    return 2.0 * (tp - 1) / tp * d_model * dtype_bytes


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference); N = active matmul
    params (embedding lookup excluded), D = tokens processed."""
    n_eff = cfg.n_active_params() - cfg.vocab_size * cfg.d_model
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n_eff * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n_eff * d
    # decode: one token per sequence
    return 2.0 * n_eff * shape.global_batch


def main(argv=None) -> int:
    """Plan-introspection CLI: build a KernelContext from the same flags the
    serving launcher takes and print ``ctx.explain`` (resolved kernel path,
    tiles, prologue variant and VMEM fit per regime) plus the roofline
    latency of each path for one (M, K, N, R) layer shape.

        PYTHONPATH=src python -m repro.launch.roofline \\
            --shape 16 4096 11008 128 --rotate \\
            [--block-table results/block_table.json] [--vmem-budget BYTES]
    """
    import argparse

    from repro.kernels.context import (KernelContext, context_from_flags,
                                       vmem_budget_arg)

    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("--shape", nargs=4, type=int, required=True,
                    metavar=("M", "K", "N", "R"),
                    help="the (M, K, N, R) layer problem to explain")
    ap.add_argument("--rotate", action="store_true",
                    help="resolve with the online rotation (pins the "
                         "resident prologue variant)")
    ap.add_argument("--act-group", type=int, default=None,
                    help="group-wise activation scales (paper Table 2 g, "
                         "e.g. 128): resolve with bk snapped to a multiple "
                         "of the group and the (M, K/g) scale plane in the "
                         "working set")
    ap.add_argument("--layer", default=None,
                    help="layer name for per-layer override lookup in the "
                         "context's 'layers' table")
    ap.add_argument("--block-table", default=None,
                    help="block-table JSON (regime plans + optional 'vmem' "
                         "budgets + 'layers' overrides) to build the "
                         "context from")
    ap.add_argument("--vmem-budget", type=vmem_budget_arg, default=None,
                    help="override both VMEM working-set budgets (positive "
                         "bytes); applied after --block-table")
    ap.add_argument("--impl", default=None,
                    choices=("auto", "fused", "chained", "unfused"),
                    help="default kernel path recorded on the context")
    args = ap.parse_args(argv)

    ctx = context_from_flags(args.block_table, args.vmem_budget,
                             args.impl) or KernelContext()

    m, k, n, r = args.shape
    print(ctx.explain(m, k, n, r, rotate=args.rotate, layer=args.layer,
                      act_group=args.act_group))

    try:  # benchmarks/ lives at the repo root, not under src/
        from benchmarks.latency_kernels import _roofline_time
    except ImportError:
        return 0

    print("roofline latency (v5e byte/FLOP model):")
    for path in ("fused", "fused_stream", "chained", "unfused"):
        t = _roofline_time(m, k, n, r, path, ctx=ctx,
                           act_group=args.act_group)
        print(f"  {path:12s} {t * 1e6:9.1f} us")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
