"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 100 --batch 16 --seq 128 [--reduced] [--devices 8]

``--devices N`` forces N host devices and jits the step with the production
sharding rules on a (data × model) mesh — the single-process rehearsal of the
multi-pod launch (real pods: same code under jax.distributed.initialize).
"""

import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh", default=None, help="e.g. 4x2 (data x model)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}"
        )

    import jax
    from repro.configs import get_config
    from repro.models.config import reduced as reduce_cfg

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)

    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        from repro.core.jaxcompat import make_mesh, set_mesh

        mesh = make_mesh((d, m), ("data", "model"))
        from repro.distributed.sharding import batch_pspec, param_pspecs, to_shardings
        from repro.train.steps import init_train_state, make_train_step
        from repro.train.optimizer import AdamWState
        from repro.train.steps import TrainState
        from repro.data.loader import batches
        from jax.sharding import PartitionSpec as P

        with set_mesh(mesh):
            state = init_train_state(cfg, jax.random.PRNGKey(0))
            pspecs = param_pspecs(state.params, mesh, False)
            sspecs = TrainState(params=pspecs,
                                opt=AdamWState(step=P(), mu=pspecs, nu=pspecs))
            state = jax.device_put(state, to_shardings(sspecs, mesh))
            step_fn = jax.jit(
                make_train_step(cfg, base_lr=args.lr, total_steps=args.steps,
                                microbatches=args.microbatches),
                in_shardings=(to_shardings(sspecs, mesh), None),
            )
            for step, batch in batches(cfg, args.batch, args.seq):
                if step >= args.steps:
                    break
                state, metrics = step_fn(state, batch)
                if step % 10 == 0:
                    print(f"step {step}: loss={float(metrics['loss']):.4f}")
        return

    from repro.train.trainer import train

    train(cfg, steps=args.steps, global_batch=args.batch, seq_len=args.seq,
          lr=args.lr, ckpt_dir=args.ckpt_dir, microbatches=args.microbatches)


if __name__ == "__main__":
    sys.exit(main())
