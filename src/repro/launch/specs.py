"""Dry-run cell construction: for an (arch × shape × mesh) cell, build the
step function, ShapeDtypeStruct inputs and input shardings.

Train cells lower the full ``train_step`` (loss→grads→AdamW, remat=full,
EP dispatch for MoE).  Prefill/decode cells lower the QUANTIZED serve path —
packed-int4 weights + f32 scales + bf16 U/V low-rank correction — i.e. the
paper's W4A4+LRC deployment artifact, not the fp model.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.shapes import SHAPES, applicable
from repro.distributed.sharding import (
    batch_pspec,
    cache_pspecs,
    param_pspecs,
    to_shardings,
)
from repro.models import model as model_lib
from repro.quant.policy import QuantPolicy
from repro.quant.shell import quantize_shell
from repro.train.steps import TrainState, init_train_state, make_train_step
from repro.train.optimizer import AdamWState


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _batch_specs(cfg, shape, kind: str):
    """ShapeDtypeStructs for the input batch of a given shape/kind."""
    b, s = shape.global_batch, shape.seq_len
    if kind == "decode":
        batch = {"tokens": _sds((b, 1), jnp.int32)}
        return batch
    batch = {"tokens": _sds((b, s), jnp.int32)}
    if cfg.family == "encdec":
        t_enc = max(8, s // cfg.encoder_downsample)
        batch["frames"] = _sds((b, t_enc, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = _sds((b, cfg.n_prefix_tokens, cfg.d_model), jnp.bfloat16)
    return batch


def _prep_cfg(cfg, kind: str):
    upd = {}
    if cfg.family == "moe":
        upd["moe_impl"] = "ep"
        # §Perf exp-3: weight-absorbed MLA wins in the decode regime only
        # (prefill pays wider latent scores); ship absorb-on-decode.
        if kind == "decode":
            upd["mla_absorb"] = True
    if kind == "train":
        upd["remat"] = "full"
    upd["dtype"] = "bfloat16"
    return dataclasses.replace(cfg, **upd) if upd else cfg


def build_cell(arch: str, shape_name: str, mesh, multi_pod: bool,
               policy: QuantPolicy | None = None, cfg_override=None):
    """Returns dict(fn, args tuple of SDS trees, in_shardings tuple).
    ``cfg_override``: a depth-shrunk/unrolled variant for cost extrapolation."""
    base_cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = SHAPES[shape_name]
    if not applicable(base_cfg, shape_name):
        raise ValueError(f"{arch} × {shape_name} is skipped (full attention at 500k)")
    kind = shape.kind
    cfg = _prep_cfg(base_cfg, kind)
    policy = policy or QuantPolicy(impl="int8", act_group=None, rank_frac=0.10)
    b, s = shape.global_batch, shape.seq_len

    if kind == "train":
        state_shapes = jax.eval_shape(
            lambda k: init_train_state(cfg, k), _sds((2,), jnp.uint32)
        )
        batch = _batch_specs(cfg, shape, kind)
        step = make_train_step(cfg, microbatches=1)
        pspecs = param_pspecs(state_shapes.params, mesh, multi_pod)
        state_specs = TrainState(
            params=pspecs,
            opt=AdamWState(step=P(), mu=pspecs, nu=pspecs),
        )
        bspec = batch_pspec(mesh, multi_pod, b)
        batch_specs = {k: _pad_spec(bspec, v) for k, v in batch.items()}
        return dict(
            fn=step,
            args=(state_shapes, batch),
            in_shardings=(
                to_shardings(state_specs, mesh),
                to_shardings(batch_specs, mesh),
            ),
            cfg=cfg,
            kind=kind,
        )

    # ---- serve cells: quantized params ----
    qparams_shapes = jax.eval_shape(
        lambda k: quantize_shell(model_lib.init_params(cfg, k, max_seq=s), policy),
        _sds((2,), jnp.uint32),
    )
    enc_len = max(8, s // cfg.encoder_downsample) if cfg.family == "encdec" else 0
    cache_len = s + (cfg.n_prefix_tokens if cfg.family == "vlm" else 0)
    cache_shapes = jax.eval_shape(
        partial(model_lib.init_cache, cfg, b, cache_len, jnp.bfloat16, enc_len=enc_len)
    )
    ppspecs = param_pspecs(qparams_shapes, mesh, multi_pod)
    cspecs = cache_pspecs(cache_shapes, mesh, multi_pod, b)
    shard_seq = (kind == "prefill" and b == 1)

    if kind == "prefill":
        batch = _batch_specs(cfg, shape, kind)
        bspec = batch_pspec(mesh, multi_pod, b, shard_seq=shard_seq)
        batch_specs = {k: _pad_spec(bspec, v) for k, v in batch.items()}

        def fn(params, batch, cache):
            return model_lib.prefill(cfg, params, batch, cache)

        return dict(
            fn=fn,
            args=(qparams_shapes, batch, cache_shapes),
            in_shardings=(
                to_shardings(ppspecs, mesh),
                to_shardings(batch_specs, mesh),
                to_shardings(cspecs, mesh),
            ),
            cfg=cfg,
            kind=kind,
        )

    # decode
    tokens = _sds((b, 1), jnp.int32)

    def fn(params, tokens, cache):
        return model_lib.decode_step(cfg, params, tokens, cache)

    return dict(
        fn=fn,
        args=(qparams_shapes, tokens, cache_shapes),
        in_shardings=(
            to_shardings(ppspecs, mesh),
            None,  # tiny token ids: let GSPMD place them
            to_shardings(cspecs, mesh),
        ),
        cfg=cfg,
        kind=kind,
    )


def _pad_spec(bspec: P, sds):
    """Extend a (B, S) spec with None for trailing dims (frames/patches)."""
    nd = len(sds.shape)
    entries = list(bspec) + [None] * (nd - len(bspec))
    return P(*entries[:nd])
