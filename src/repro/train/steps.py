"""Train step factory: loss → grads → AdamW, with optional microbatch
gradient accumulation (scan) and remat (cfg.remat).

The returned step is pure: ``(state, batch) -> (state, metrics)`` and is the
function lowered by the dry-run for the ``train_4k`` cells.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.train.optimizer import AdamWState, adamw_init, adamw_update, cosine_lr


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: AdamWState


def init_train_state(cfg, key, state_dtype=jnp.float32) -> TrainState:
    params = model_lib.init_params(cfg, key)
    return TrainState(params=params, opt=adamw_init(params, state_dtype))


def make_train_step(
    cfg,
    base_lr: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10_000,
    microbatches: int = 1,
    remat: str = "full",
):
    cfg = dataclasses.replace(cfg, remat=remat)

    def loss_for(params, batch):
        return model_lib.loss_fn(cfg, params, batch)

    def train_step(state: TrainState, batch):
        if microbatches > 1:
            # split the leading batch dim and accumulate grads with a scan
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc(carry, mbatch):
                loss, grads = jax.value_and_grad(loss_for)(state.params, mbatch)
                return (
                    carry[0] + loss / microbatches,
                    jax.tree.map(lambda a, g: a + g / microbatches, carry[1], grads),
                ), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), _ = jax.lax.scan(acc, (jnp.zeros(()), zero), mb)
        else:
            loss, grads = jax.value_and_grad(loss_for)(state.params, batch)
        lr = cosine_lr(state.opt.step, base_lr, warmup, total_steps)
        params, opt, gnorm = adamw_update(state.params, grads, state.opt, lr)
        metrics = dict(loss=loss, grad_norm=gnorm, lr=lr)
        return TrainState(params=params, opt=opt), metrics

    return train_step
