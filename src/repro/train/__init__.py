from repro.train.optimizer import AdamWState, adamw_init, adamw_update, cosine_lr
from repro.train.steps import make_train_step, TrainState
