"""AdamW + cosine schedule (pure pytree implementation — no external deps).

``state_dtype`` controls the moment dtype: float32 (default) or bfloat16
("bf16 optimizer state" mode for the giant MoE configs — halves optimizer
memory, the distributed-memory trick recorded in DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params, state_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def cosine_lr(step, base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    step = step.astype(jnp.float32)
    warm = base_lr * step / jnp.maximum(1.0, warmup)
    prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree))
    )


def adamw_update(
    params,
    grads,
    state: AdamWState,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    b1c = 1 - b1 ** step.astype(jnp.float32)
    b2c = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m32 = b1 * m32 + (1 - b1) * g
        v32 = b2 * v32 + (1 - b2) * g * g
        u = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + eps)
        # decoupled weight decay on matrices only
        if p.ndim >= 2:
            u = u + weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return newp, m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), gnorm
