"""Gradient compression for the cross-pod all-reduce.

int8 quantized psum with error feedback (residual carried between steps):
the gradient is scaled per-leaf by its absmax, rounded to int8, summed across
the data axis in int32, and de-scaled; the quantization residual is added
back into the next step's gradient.  Cuts the inter-pod gradient traffic 4×
(bf16→int8 effective) — the distributed-optimization trick for the 2-pod
mesh where the "pod" axis crosses the slow inter-pod links.

Exposed as a shard_map-compatible transform around the grad tree; OFF by
default (train_step flag).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compressed_psum(grads, residual, axis_name: str):
    """Returns (mean-reduced grads, new residual). Call inside shard_map /
    pjit with ``axis_name`` bound."""

    def one(g, r):
        g = g.astype(jnp.float32) + r
        # shared scale across shards (a tiny f32 pmax) so int32 partial sums
        # are commensurable
        amax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(g / scale), -128, 127).astype(jnp.int8)
        new_r = g - q.astype(jnp.float32) * scale  # error feedback
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return (total.astype(jnp.float32) * scale) / n, new_r

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_res = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return new_g, new_res


def zero_residual(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
