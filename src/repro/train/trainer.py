"""Training loop with the fault-tolerance hooks:

 - CheckpointManager (atomic save-every-N, keep-k, resume-from-latest),
 - deterministic (seed, step)-keyed data → exact replay after restart,
 - straggler watchdog: per-step wall times tracked; steps slower than
   ``straggler_factor`` × running median are logged (on a real pod this feeds
   the hot-swap / preemption policy — here it is injected and asserted in
   tests).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from repro.data.loader import batches
from repro.train.steps import init_train_state, make_train_step


@dataclass
class StragglerWatchdog:
    factor: float = 3.0
    times: list = field(default_factory=list)
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        med = float(np.median(self.times[-64:]))
        slow = len(self.times) > 8 and dt > self.factor * med
        if slow:
            self.flagged.append((step, dt, med))
        return slow

    @property
    def p50(self):
        return float(np.median(self.times)) if self.times else 0.0


def train(
    cfg,
    steps: int = 100,
    global_batch: int = 8,
    seq_len: int = 128,
    lr: float = 3e-4,
    seed: int = 0,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 50,
    microbatches: int = 1,
    log: Callable[[str], None] = print,
    hook: Optional[Callable] = None,
):
    """Single-host training driver (the multi-pod path goes through
    launch/train.py which jits with explicit shardings)."""
    state = init_train_state(cfg, jax.random.PRNGKey(seed))
    step_fn = jax.jit(make_train_step(cfg, base_lr=lr, total_steps=steps,
                                      microbatches=microbatches, remat="none"))
    start = 0
    mgr = CheckpointManager(ckpt_dir, every=ckpt_every) if ckpt_dir else None
    if mgr is not None:
        got_step, got = mgr.restore_latest(state)
        if got is not None:
            state, start = got, got_step
            log(f"[resume] from step {start}")

    watchdog = StragglerWatchdog()
    history = []
    it = batches(cfg, global_batch, seq_len, seed=seed, start_step=start)
    for step, batch in it:
        if step >= steps:
            break
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        slow = watchdog.observe(step, dt)
        history.append(loss)
        if slow:
            log(f"[straggler] step {step}: {dt:.3f}s > {watchdog.factor}x median")
        if step % 20 == 0:
            log(f"step {step}: loss={loss:.4f} ({dt:.2f}s)")
        if mgr is not None:
            mgr.maybe_save(step + 1, state)
        if hook is not None:
            hook(step, state)
    return state, history, watchdog
