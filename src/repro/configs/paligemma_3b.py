"""PaliGemma-3B — SigLIP frontend (stubbed patch embeddings) + gemma
backbone, MQA (kv=1), prefix-LM attention. [arXiv:2407.07726; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    act="gelu",
    rope_theta=10000.0,
    tie_embeddings=True,
    embed_scale=True,
    n_prefix_tokens=256,   # 224x224 / 14x14 SigLIP patches
)
