"""Zamba2-7B — Mamba2 stack + shared attention blocks. [arXiv:2411.15242; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,          # 3584 / 32
    d_ff=14336,
    vocab_size=32000,
    act="gelu",
    rope_theta=10000.0,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=2,
    ssm_chunk=128,
    hybrid_attn_every=6,
)
