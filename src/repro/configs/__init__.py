"""Assigned-architecture registry: ``get_config(arch_id)``.

Each module defines CONFIG with the exact published hyper-parameters
([source; verified-tier] in its docstring).
"""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "smollm-135m",
    "phi4-mini-3.8b",
    "phi3-mini-3.8b",
    "gemma-7b",
    "deepseek-v2-236b",
    "deepseek-v3-671b",
    "zamba2-7b",
    "whisper-medium",
    "mamba2-370m",
    "paligemma-3b",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
