"""DeepSeek-V2 236B — MLA (kv_lora=512) + MoE 160 routed top-6, 2 shared.
[arXiv:2405.04434; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=1536,            # routed-expert width (spec)
    vocab_size=102400,
    act="silu",
    rope_theta=10000.0,
    attn_kind="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    n_experts=160,
    n_shared_experts=2,
    moe_top_k=6,
    d_expert=1536,
    n_dense_layers=1,
    d_ff_dense=12288,
    router_fn="softmax",
)
