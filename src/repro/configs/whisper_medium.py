"""Whisper-medium — enc-dec, conv frontend stubbed (precomputed frame
embeddings, ×4 downsample). [arXiv:2212.04356; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    act="gelu",
    rope_theta=0.0,        # whisper: absolute positions, no rope
    is_encoder_decoder=True,
    n_encoder_layers=24,
    encoder_downsample=4,
)
