"""Assigned input-shape sets (seq_len × global_batch) and per-arch
applicability (DESIGN.md §Arch-applicability)."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable(arch_cfg, shape_name: str) -> bool:
    """long_500k needs sub-quadratic attention — SSM/hybrid only."""
    if shape_name == "long_500k":
        return arch_cfg.sub_quadratic()
    return True


def cells(arch_cfg):
    return [s for s in SHAPES if applicable(arch_cfg, s)]
