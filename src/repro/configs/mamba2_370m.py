"""Mamba2-370M — attention-free SSD. [arXiv:2405.21060; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    attn_kind="none",
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_chunk=128,
)
