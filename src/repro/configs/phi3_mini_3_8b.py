"""Phi-3-mini 3.8B — RoPE SwiGLU (kv=32 i.e. MHA). [arXiv:2404.14219; unverified]

This is the paper's own primary evaluation model (Phi-3 mini-4k-instruct).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    act="silu",
    rope_theta=10000.0,
)
