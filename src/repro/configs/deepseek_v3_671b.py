"""DeepSeek-V3 671B — MLA + MoE 256 routed top-8, 1 shared, MTP.
[arXiv:2412.19437; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=2048,            # routed-expert width (spec)
    vocab_size=129280,
    act="silu",
    rope_theta=10000.0,
    attn_kind="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    n_experts=256,
    n_shared_experts=1,
    moe_top_k=8,
    d_expert=2048,
    n_dense_layers=3,
    d_ff_dense=18432,
    router_fn="sigmoid",
    mtp_depth=1,
)
