"""Deterministic synthetic corpus (offline container: no WikiText on disk).

A Zipf-distributed unigram mixed with a first-order Markov chain gives the
token stream enough structure that (i) a small LM trained on it reaches a
clearly-below-uniform loss (so PPL deltas from quantization are measurable)
and (ii) calibration activations develop the correlated, outlier-bearing
statistics the paper's method exploits.  Fully keyed by (seed) — exact replay
after restart (fault-tolerance requirement)."""

from __future__ import annotations

import numpy as np


class SyntheticCorpus:
    def __init__(self, vocab_size: int, seed: int = 0, n_states: int = 64,
                 zipf_a: float = 1.8, bigram_p: float = 0.5):
        self.vocab_size = vocab_size
        self.seed = seed
        self.bigram_p = bigram_p
        rng = np.random.default_rng(seed)
        # peaked Zipf unigram: a small LM recovers the unigram entropy fast
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        self.unigram = ranks ** (-zipf_a)
        self.unigram /= self.unigram.sum()
        # deterministic bigram skeleton: a fixed random permutation — deeper
        # structure the model learns with attention
        self.perm = rng.permutation(vocab_size).astype(np.int32)

    def sequence(self, index: int, length: int) -> np.ndarray:
        """Deterministic sequence #index (independent of call order)."""
        rng = np.random.default_rng((self.seed, index))
        mix = rng.random(length)
        base = rng.choice(self.vocab_size, size=length, p=self.unigram)
        toks = np.empty(length, np.int32)
        toks[0] = base[0]
        for t in range(1, length):
            if mix[t] < self.bigram_p:
                toks[t] = self.perm[toks[t - 1]]  # learnable transition
            else:
                toks[t] = base[t]
        return toks

    def batch(self, start_index: int, batch: int, length: int) -> np.ndarray:
        return np.stack([self.sequence(start_index + i, length) for i in range(batch)])
