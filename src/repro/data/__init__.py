from repro.data.tokens import SyntheticCorpus
from repro.data.loader import batches, calib_sequences
