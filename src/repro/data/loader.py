"""Batch iterators: training batches keyed by (seed, step) — restart-exact —
and calibration sequences (the paper's 128 × 2048-token recipe, scaled)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.data.tokens import SyntheticCorpus


CORPUS_SEED = 0  # ONE corpus process; `seed` below selects disjoint
# sequence streams from it (train/eval/calib must share the transition law)


def batches(cfg, global_batch: int, seq_len: int, seed: int = 0, start_step: int = 0):
    """Infinite iterator of {tokens} batches; step-indexed for exact replay.
    ``seed`` picks a disjoint sequence stream of the SAME corpus."""
    corpus = SyntheticCorpus(cfg.vocab_size, seed=CORPUS_SEED)
    step = start_step
    stream = seed * 1_000_003
    while True:
        toks = corpus.batch(stream + step * global_batch, global_batch, seq_len)
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "encdec":
            rng = np.random.default_rng((seed, step, 1))
            t_enc = max(4, seq_len // cfg.encoder_downsample)
            batch["frames"] = jnp.asarray(
                rng.standard_normal((global_batch, t_enc, cfg.d_model)), jnp.float32
            )
        if cfg.family == "vlm":
            rng = np.random.default_rng((seed, step, 2))
            batch["patches"] = jnp.asarray(
                rng.standard_normal((global_batch, cfg.n_prefix_tokens, cfg.d_model)),
                jnp.float32,
            )
        yield step, batch
        step += 1


def calib_sequences(cfg, n_seq: int = 32, seq_len: int = 256, seed: int = 1):
    """Calibration token matrix (n_seq, seq_len) — paper: 128 random
    sequences of 2048 tokens (scaled down for CPU benchmarks)."""
    corpus = SyntheticCorpus(cfg.vocab_size, seed=CORPUS_SEED)
    return jnp.asarray(corpus.batch(900_000_000 + seed * 1_000_003, n_seq, seq_len))
