"""Token sampling (greedy / temperature / top-k) with a finite-ness guard."""

from __future__ import annotations

import jax
import jax.numpy as jnp


class NonFiniteLogitsError(FloatingPointError):
    """Non-finite logits reached the sampling boundary.

    W4A4+LRC inference is exactly the regime where activation outliers can
    blow through the quantized numerics (LQER, arXiv 2402.02446); argmax
    over NaN/Inf logits silently emits garbage tokens, so the serving
    engine samples with ``check_finite=True`` and turns this into a
    per-request structured failure instead of a corrupted completion.
    """


def sample_token(logits, key, temperature: float = 0.0, top_k: int = 0,
                 check_finite: bool = False):
    """logits: (B, V) -> (B,) int32.

    ``check_finite=True`` raises :class:`NonFiniteLogitsError` (with NaN /
    Inf counts for diagnosis) before any token is drawn from bad logits.
    The check synchronizes on the device value, which is why it is opt-in:
    the serving engine pays it once per step at the decode boundary.
    """
    if check_finite:
        finite = jnp.isfinite(logits)
        if not bool(jnp.all(finite)):
            n_nan = int(jnp.isnan(logits).sum())
            n_inf = int(jnp.isinf(logits).sum())
            raise NonFiniteLogitsError(
                f"non-finite logits at sampling boundary: {n_nan} NaN, "
                f"{n_inf} Inf of {logits.size} entries")
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[..., -1:]
        # dtype-aware mask: -1e30 overflows float16 (max ~6.5e4) to -inf and
        # can NaN through downstream softmax arithmetic
        logits = jnp.where(logits < cutoff, jnp.finfo(logits.dtype).min, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
