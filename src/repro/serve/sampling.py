"""Token sampling (greedy / temperature / top-k)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_token(logits, key, temperature: float = 0.0, top_k: int = 0):
    """logits: (B, V) -> (B,) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[..., -1:]
        # dtype-aware mask: -1e30 overflows float16 (max ~6.5e4) to -inf and
        # can NaN through downstream softmax arithmetic
        logits = jnp.where(logits < cutoff, jnp.finfo(logits.dtype).min, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
