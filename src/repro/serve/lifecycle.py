"""Request lifecycle for the serving engine: states, transitions, records.

Every request moves along this state machine — and ONLY along it; the
engine routes every status change through :meth:`Request.advance`, which
raises :class:`IllegalTransition` on any other edge:

    QUEUED ──> PREFILLING ──> DECODING ──> FINISHED
      │            │              ├──> FAILED / CANCELLED / TIMED_OUT
      │            ├──> FINISHED  (termination predicate already met by
      │            │               the prefill-sampled token: EOS at
      │            │               prefill, max_new_tokens == 1, seq cap)
      │            └──> FAILED / CANCELLED / TIMED_OUT
      └──> CANCELLED / TIMED_OUT / REJECTED

Terminal states are absorbing.  ``REJECTED`` is only reachable from
``QUEUED`` — admission control refuses bad input (oversized prompt,
out-of-vocab ids, non-positive token budget, full queue) at ``submit()``
time, before it can touch a slot cache.

This contract is what the upcoming batched-decode / paged-KV refactors
must preserve: however the caches are laid out, a request's observable
life is exactly one path through this graph, finalized as one
:class:`RequestRecord`.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, FrozenSet, List, Optional

import numpy as np


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"
    FAILED = "failed"
    CANCELLED = "cancelled"
    TIMED_OUT = "timed_out"
    REJECTED = "rejected"

    def __str__(self):  # "finished", not "RequestState.FINISHED", in reports
        return self.value


class ErrorKind(str, enum.Enum):
    """The error-kind taxonomy for ``RequestRecord.error_kind``.

    One documented vocabulary instead of string literals scattered through
    ``_classify_error`` and the tests.  Members are ``str`` subclasses, so
    ``record.error_kind == "kv_pages_exhausted"`` keeps working and the
    values serialize verbatim into journal terminal records.

    Admission kinds (``REJECTED`` at ``submit()`` time, never retried):
    ``duplicate_rid``, ``empty_prompt``, ``bad_token_ids``,
    ``prompt_too_long``, ``kv_capacity``, ``bad_token_budget``,
    ``bad_deadline``, ``queue_full``, ``queue_evicted``.

    Attempt-failure kinds (**retryable** within the engine's bounded retry
    budget — see :data:`RETRYABLE_KINDS` — then terminal as ``FAILED``):
    ``injected``, ``non_finite_logits``, ``kv_pages_exhausted``,
    ``exception``.

    Terminal-cause kinds (stamped directly on CANCELLED / TIMED_OUT /
    crash-drained records, never retried): ``cancelled``, ``deadline``,
    ``stall``, ``step_limit``, ``simulated_crash``.
    """

    # -- admission (REJECTED) ------------------------------------------------
    DUPLICATE_RID = "duplicate_rid"
    EMPTY_PROMPT = "empty_prompt"
    BAD_TOKEN_IDS = "bad_token_ids"
    PROMPT_TOO_LONG = "prompt_too_long"
    KV_CAPACITY = "kv_capacity"
    BAD_TOKEN_BUDGET = "bad_token_budget"
    BAD_DEADLINE = "bad_deadline"
    QUEUE_FULL = "queue_full"
    QUEUE_EVICTED = "queue_evicted"
    # -- attempt failures (retryable, then FAILED) ---------------------------
    INJECTED = "injected"
    NON_FINITE_LOGITS = "non_finite_logits"
    KV_PAGES_EXHAUSTED = "kv_pages_exhausted"
    EXCEPTION = "exception"
    # -- terminal causes -----------------------------------------------------
    CANCELLED = "cancelled"
    DEADLINE = "deadline"
    STALL = "stall"
    STEP_LIMIT = "step_limit"
    SIMULATED_CRASH = "simulated_crash"

    # plain-string rendering ("deadline", not "ErrorKind.DEADLINE") in
    # reports, f-strings and json payloads
    __str__ = str.__str__
    __format__ = str.__format__


RETRYABLE_KINDS: FrozenSet[ErrorKind] = frozenset({
    ErrorKind.INJECTED,
    ErrorKind.NON_FINITE_LOGITS,
    ErrorKind.KV_PAGES_EXHAUSTED,
    ErrorKind.EXCEPTION,
})


TERMINAL_STATES: FrozenSet[RequestState] = frozenset({
    RequestState.FINISHED,
    RequestState.FAILED,
    RequestState.CANCELLED,
    RequestState.TIMED_OUT,
    RequestState.REJECTED,
})

LEGAL_TRANSITIONS: Dict[RequestState, FrozenSet[RequestState]] = {
    RequestState.QUEUED: frozenset({
        RequestState.PREFILLING,
        RequestState.CANCELLED,
        RequestState.TIMED_OUT,
        RequestState.REJECTED,
    }),
    RequestState.PREFILLING: frozenset({
        RequestState.DECODING,
        RequestState.FINISHED,
        RequestState.FAILED,
        RequestState.CANCELLED,
        RequestState.TIMED_OUT,
    }),
    RequestState.DECODING: frozenset({
        RequestState.FINISHED,
        RequestState.FAILED,
        RequestState.CANCELLED,
        RequestState.TIMED_OUT,
    }),
    **{s: frozenset() for s in TERMINAL_STATES},
}


class IllegalTransition(RuntimeError):
    """A request was asked to move along an edge the state machine forbids."""


@dataclasses.dataclass
class Request:
    """One generation request plus its live lifecycle bookkeeping.

    ``deadline_s`` is a wall-clock budget measured from ``submit()``; the
    engine expires the request (wherever it is — queued, prefilling or
    decoding) once the engine clock passes ``submitted_at + deadline_s``.
    """

    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    deadline_s: Optional[float] = None
    out_tokens: list = dataclasses.field(default_factory=list)
    state: RequestState = RequestState.QUEUED
    # engine-clock timestamps (None until stamped)
    submitted_at: Optional[float] = None
    started_at: Optional[float] = None  # prefill start
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None  # any terminal state
    retries: int = 0
    error_kind: Optional[str] = None
    error: Optional[str] = None

    @property
    def done(self) -> bool:
        return self.state in TERMINAL_STATES

    def deadline_at(self) -> Optional[float]:
        if self.deadline_s is None or self.submitted_at is None:
            return None
        return self.submitted_at + self.deadline_s

    def advance(self, new_state: RequestState, now: Optional[float] = None):
        """Move to ``new_state``, enforcing the transition graph and
        stamping the phase timestamps."""
        if new_state not in LEGAL_TRANSITIONS[self.state]:
            raise IllegalTransition(
                f"request {self.rid}: {self.state.value} -> {new_state.value} "
                f"is not a legal transition (legal: "
                f"{sorted(s.value for s in LEGAL_TRANSITIONS[self.state]) or 'none — terminal'})"
            )
        self.state = new_state
        if new_state is RequestState.PREFILLING and self.started_at is None:
            self.started_at = now
        if new_state in TERMINAL_STATES and self.finished_at is None:
            self.finished_at = now


@dataclasses.dataclass
class RequestRecord:
    """Immutable-by-convention terminal record of one request.

    This is what ``ServeEngine.run()`` returns per rid: the terminal
    status, the emitted tokens, the captured error (for FAILED /
    TIMED_OUT / REJECTED), retry count, and coarse phase timings — the
    structured replacement for the old bare ``finished`` dict of live
    ``Request`` objects.
    """

    rid: int
    status: RequestState
    out_tokens: List[int]
    prompt_tokens: int
    new_tokens: int
    retries: int = 0
    error_kind: Optional[str] = None
    error: Optional[str] = None
    timings: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return self.status is RequestState.FINISHED

    @classmethod
    def from_request(cls, req: Request) -> "RequestRecord":
        if req.state not in TERMINAL_STATES:
            raise IllegalTransition(
                f"request {req.rid}: cannot build a terminal record in "
                f"non-terminal state {req.state.value}"
            )
        timings = {}
        if req.submitted_at is not None:
            if req.started_at is not None:
                timings["queue_s"] = req.started_at - req.submitted_at
            if req.first_token_at is not None:
                timings["first_token_s"] = req.first_token_at - req.submitted_at
            if req.finished_at is not None:
                timings["total_s"] = req.finished_at - req.submitted_at
        return cls(
            rid=req.rid,
            status=req.state,
            out_tokens=list(req.out_tokens),
            prompt_tokens=int(len(req.prompt)),
            new_tokens=len(req.out_tokens),
            retries=req.retries,
            error_kind=req.error_kind,
            error=req.error,
            timings=timings,
        )
