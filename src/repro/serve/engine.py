"""Batched serving engine (slot-based continuous batching).

A fixed pool of B slots shares one jitted decode_step; requests are admitted
into free slots (prefill writes their prompt into the slot's cache region),
decode steps advance ALL active slots together, finished slots are freed and
refilled from the queue — the standard continuous-batching pattern, sized for
the W4A4+LRC quantized model this framework serves.

Single jitted decode signature ⇒ one compilation; per-slot positions are
tracked host-side.  Works with FP or quantized (QLinear) params.

Simplification vs. a paged server: each slot owns a contiguous max_seq cache
region (no paging); for the dry-run shapes that is the assigned cache layout
anyway.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as model_lib
from repro.serve.sampling import sample_token


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, batch_slots: int = 4, max_seq: int = 256,
                 eos_id: Optional[int] = None, seed: int = 0,
                 kernel_impl: Optional[str] = "auto", ctx=None):
        assert cfg.family in ("dense", "vlm", "ssm", "hybrid", "moe"), cfg.family
        # Decode runs W4A4+LRC through the pallas kernels (single-kernel
        # fused forward at decode/mixed shapes, prologue→GEMM chain past the
        # VMEM gate) whenever a compiled backend is attached; "auto" keeps
        # the calibrated impl on CPU where the pallas interpreter would only
        # slow the reference semantics down.  Pass an explicit impl
        # ("fused"/"pallas"/"int8"/"sim") to force a path.
        #
        # ``ctx`` is this engine's KernelContext (block table, VMEM budgets,
        # default kernel path, per-layer plan overrides).  It is attached to
        # every QLinear leaf as pytree-static metadata, so two engines in
        # one process can serve under DIFFERENT plan tables/budgets without
        # touching any global; None uses the process-default context.
        # kernel_impl=None attaches the ctx WITHOUT touching the calibrated
        # impls.
        if kernel_impl is not None or ctx is not None:
            from repro.quant.qlinear import retag_qlinear_impl

            params = retag_qlinear_impl(params, kernel_impl, ctx=ctx)
        self.ctx = ctx
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.key = jax.random.PRNGKey(seed)
        self.cache = model_lib.init_cache(cfg, 1, max_seq, dtype=jnp.float32)
        # per-slot caches (B=1 each) so slots admit/evict independently
        self.slot_caches: List = [
            model_lib.init_cache(cfg, 1, max_seq, dtype=jnp.float32)
            for _ in range(batch_slots)
        ]
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}

        cfg_static = cfg

        @jax.jit
        def _prefill(params, tokens, cache):
            return model_lib.prefill(cfg_static, params, {"tokens": tokens}, cache)

        @jax.jit
        def _decode(params, tokens, cache):
            return model_lib.decode_step(cfg_static, params, tokens, cache)

        self._prefill = _prefill
        self._decode = _decode

    # -- public API ---------------------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    def run(self, max_steps: int = 1024):
        """Drive until queue + slots drain (or step limit)."""
        for _ in range(max_steps):
            self._admit()
            if not any(self.slot_req):
                if not self.queue:
                    break
                continue
            self._step()
        return self.finished

    # -- internals ----------------------------------------------------------

    def _admit(self):
        for i in range(self.b):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.pop(0)
                cache = model_lib.init_cache(self.cfg, 1, self.max_seq, dtype=jnp.float32)
                toks = jnp.asarray(req.prompt[None, :], jnp.int32)
                logits, cache = self._prefill(self.params, toks, cache)
                self.slot_caches[i] = cache
                self.slot_req[i] = req
                tok = self._sample(logits[:, -1])
                req.out_tokens.append(int(tok[0]))

    def _sample(self, logits):
        self.key, sub = jax.random.split(self.key)
        return sample_token(logits, sub, temperature=0.0)

    def _step(self):
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            last = jnp.asarray([[req.out_tokens[-1]]], jnp.int32)
            logits, cache = self._decode(self.params, last, self.slot_caches[i])
            self.slot_caches[i] = cache
            tok = int(self._sample(logits[:, -1])[0])
            req.out_tokens.append(tok)
            total = len(req.prompt) + len(req.out_tokens)
            if (
                len(req.out_tokens) >= req.max_new_tokens
                or (self.eos_id is not None and tok == self.eos_id)
                or total >= self.max_seq - 1
            ):
                req.done = True
                self.finished[req.rid] = req
                self.slot_req[i] = None
