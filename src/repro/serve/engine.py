"""Continuous-batching serving engine: ONE batched decode step over a paged
KV cache, with the hardened request lifecycle of ``serve/lifecycle.py``.

Design (the full guide lives in ``docs/serving.md``):

- **One decode call per step.**  All active slots advance through a single
  jitted forward per engine step — tokens ``(B, 1)``, an active-slot
  ``valid`` mask for empty / faulted slots — instead of B per-slot calls.
  ``counters["decode_calls"]`` counts exactly one per step with any active
  decoder, regardless of occupancy.
- **Paged KV cache** (attention families; ``model.PAGED_FAMILIES``).  Slots
  share one page pool (``model.init_paged_cache``); ``serve/paging.py``
  owns the free-list allocator and per-request page lists, the engine keeps
  a host-side ``(B, pages_per_slot)`` block table.  Pages are allocated at
  admission (prompt) and at decode-boundary crossings, freed as a unit on
  every terminal transition.  Page 0 is the reserved null page: writes for
  padding / inactive / faulted slots are redirected there, which is what
  makes a masked slot's garbage provably invisible to its neighbors.
- **Stacked decode** (``model.STACKED_FAMILIES``: recurrent state, no
  positional cache to page).  Slots live as rows of one stacked cache;
  prefill runs B=1 and is inserted via ``model.insert_cache_row``; decode
  is the same single batched call.
- **Legacy slot loop** (vlm / hybrid / moe).  Their caches carry a shared
  scalar offset that cannot differ per row, so they keep the per-slot
  contiguous caches and per-slot decode calls of the previous engine.
- **Chunked prefill** (paged mode, ``prefill_chunk=``).  A long prompt
  prefills in fixed-size chunks, one chunk per engine step, so decode for
  co-tenant requests keeps advancing between chunks instead of stalling
  behind one long prompt.  Chunks are padded to a fixed width (one trace),
  non-final chunks run a finite-logits check so corruption can never be
  committed silently, and only the final chunk samples.  The default
  (``None``) prefills the whole prompt in one chunk at admission.

The lifecycle contract is unchanged from the per-slot engine and the chaos
suite proves it still holds under paging:

- **Admission control.**  ``submit()`` validates prompts (length vs. the
  block-table width ``max_seq``, pool capacity in PAGES, token ids, budgets,
  deadlines, unique rid) with a bounded queue; at admission time a request
  additionally waits in queue (FIFO) until the free list covers its prompt
  — page-accounting backpressure instead of a blind slot grab.
- **Failure isolation.**  Faults are applied per slot: an injected
  exception drops the slot from the step's ``valid`` mask (its KV writes
  redirect to the null page), cache corruption poisons ONLY that request's
  pages (``FaultInjector.corrupt_pages``) or stacked row, and sampling is
  per-row.  A failed attempt commits nothing for that slot — its pages are
  rolled back to the pre-step pool, its length/tokens do not advance — so
  a retry restarts from clean committed state on the NEXT engine step
  (bounded by ``max_retries`` with exponential backoff, then the slot is
  quarantined and a FAILED record emitted; ``slot_failure_limit``
  consecutive request failures kill the slot).
- **Deadlines & budgets, liveness, fault injection.**  Unchanged: per-
  request deadlines checked queued and in flight, ``cancel()``, the stall
  watchdog + ``stall_report``, injectable clock/sleep, ``health()``
  snapshots (now including page-pool stats and the resolved decode-regime
  kernel plan at the REAL batched M = ``batch_slots``).

Sampling keys derive only from (engine seed, rid, token index), and masked
attention positions contribute exactly zero weight — together these make
the chaos suite's strongest assert hold: untargeted requests are bitwise
identical to a fault-free run, regardless of WHICH pages a request lands
on, which slot it occupies, or what its co-tenants are doing.

**Crash safety** (``journal=`` / ``snapshot_dir=`` / ``snapshot_every=``;
full guide in docs/serving.md, "Crash recovery"):

- every externally visible effect — an accepted submit, a committed token,
  a terminal record — is appended (fsync'd) to the write-ahead journal
  BEFORE the in-memory effect happens, so the journal is always at or
  ahead of engine state;
- ``snapshot()`` persists the full decode state (paged pool + allocator +
  block tables, or the stacked/slot caches) atomically through the
  checkpoint path, at engine-step boundaries only;
- ``ServeEngine.restore`` = latest restorable snapshot + journal replay:
  slots whose journaled token count matches the snapshot resume in place;
  anything newer than the snapshot (or with no usable snapshot at all)
  re-prefills over ``prompt + journaled tokens`` — and because sampling
  keys depend only on (seed, rid, token index), the recovered continuation
  is bitwise identical to the uninterrupted run, with every journaled
  token delivered exactly once.

``run()`` returns ``{rid: RequestRecord}`` — structured terminal records,
not live request objects.  Works with FP or quantized (QLinear) params.
"""

from __future__ import annotations

import functools
import json
import time
import warnings
from types import SimpleNamespace
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import (CheckpointError, CheckpointManager,
                                   load_leaf)
from repro.models import model as model_lib
from repro.serve.faults import FaultInjector, InjectedFault, SimulatedCrash
from repro.serve.kvquant import KVSpec
from repro.serve.journal import (JournalError, JournalWriter, collate,
                                 read_journal)
from repro.serve.lifecycle import (ErrorKind, Request, RequestRecord,
                                   RequestState, TERMINAL_STATES)
from repro.serve.paging import PageAllocator
from repro.serve.sampling import NonFiniteLogitsError, sample_token


class PagesExhausted(RuntimeError):
    """The free list could not cover a page allocation (admission raced, or
    the pool was sized below ``batch_slots * pages_per_slot``).  Retried
    like any transient fault — a co-tenant finishing frees pages — then
    surfaces as a FAILED record with ``error_kind == 'kv_pages_exhausted'``.
    """


@functools.lru_cache(maxsize=16)
def _model_fns(cfg, kv_spec: KVSpec = KVSpec(), moe_impl: str = "dense",
               with_stats: bool = False, mesh=None) -> SimpleNamespace:
    """Per-(config, kv-spec, moe-impl, mesh) jitted step functions, shared
    by every engine instance in the process (all key parts are hashable —
    ``mesh`` participates because shard_map captures the ambient mesh at
    TRACE time, so two engines over different meshes must not share traces)
    — N engines over the same key stop paying N compilations.

    ``traces`` counts retracings (incremented at trace time, not per call):
    the paged engine compiles exactly two ``paged`` traces per (config,
    kv spec) — one (1, chunk) prefill shape, one (B, 1) decode shape — and
    the test suite asserts that.  The f32 spec selects the pre-KVSpec trace
    verbatim (``transformer.paged_step`` branches at Python trace time), so
    its serving stays bitwise identical."""
    traces = {"prefill": 0, "decode": 0, "paged": 0}

    @jax.jit
    def _prefill(params, tokens, cache):
        traces["prefill"] += 1
        return model_lib.prefill(
            cfg, params, {"tokens": tokens, "moe_impl": moe_impl}, cache)

    @jax.jit
    def _decode(params, tokens, cache):
        traces["decode"] += 1
        return model_lib.decode_step(cfg, params, tokens, cache,
                                     moe_impl=moe_impl,
                                     with_stats=with_stats)

    @jax.jit
    def _paged(params, tokens, positions, valid, cache, block_table,
               sample_row):
        traces["paged"] += 1
        return model_lib.paged_step(cfg, params, tokens, positions, valid,
                                    cache, block_table, sample_row,
                                    kv_spec=kv_spec)

    return SimpleNamespace(prefill=_prefill, decode=_decode, paged=_paged,
                           traces=traces)


def _classify_error(e: BaseException) -> Tuple[ErrorKind, str]:
    if isinstance(e, InjectedFault):
        kind = ErrorKind.INJECTED
    elif isinstance(e, NonFiniteLogitsError):
        kind = ErrorKind.NON_FINITE_LOGITS
    elif isinstance(e, PagesExhausted):
        kind = ErrorKind.KV_PAGES_EXHAUSTED
    elif isinstance(e, SimulatedCrash):
        # a crash normally unwinds run() entirely; this only fires if a
        # caller catches it and asks for a post-mortem classification
        kind = ErrorKind.SIMULATED_CRASH
    else:
        kind = ErrorKind.EXCEPTION
    msg = f"{type(e).__name__}: {e}"
    return kind, msg[:500]


class ServeEngine:
    def __init__(self, cfg, params, batch_slots: int = 4, max_seq: int = 256,
                 eos_id: Optional[int] = None, seed: int = 0,
                 kernel_impl: Optional[str] = "auto", ctx=None, *,
                 kv_spec: Optional[KVSpec] = None,
                 page_size: int = 16, kv_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 max_retries: int = 2, retry_backoff_s: float = 0.0,
                 queue_limit: Optional[int] = None,
                 queue_policy: str = "reject_new",
                 default_deadline_s: Optional[float] = None,
                 slot_failure_limit: int = 3, stall_patience: int = 64,
                 injector: Optional[FaultInjector] = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep_fn: Callable[[float], None] = time.sleep,
                 journal: Optional[JournalWriter] = None,
                 snapshot_dir: Optional[str] = None,
                 snapshot_every: int = 0, snapshot_keep: int = 3,
                 mesh=None):
        assert cfg.family in ("dense", "vlm", "ssm", "hybrid", "moe"), cfg.family
        if queue_policy not in ("reject_new", "drop_oldest"):
            raise ValueError(f"unknown queue_policy {queue_policy!r}; "
                             f"one of ('reject_new', 'drop_oldest')")
        if max_retries < 0 or retry_backoff_s < 0:
            raise ValueError("max_retries and retry_backoff_s must be >= 0")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        if snapshot_every < 0:
            raise ValueError(f"snapshot_every must be >= 0, got {snapshot_every}")
        if snapshot_every and snapshot_dir is None:
            raise ValueError("snapshot_every > 0 requires snapshot_dir")
        # Decode runs W4A4+LRC through the pallas kernels (single-kernel
        # fused forward at decode/mixed shapes, prologue→GEMM chain past the
        # VMEM gate) whenever a compiled backend is attached; "auto" keeps
        # the calibrated impl on CPU where the pallas interpreter would only
        # slow the reference semantics down.  Pass an explicit impl
        # ("fused"/"pallas"/"int8"/"sim") to force a path.
        #
        # ``ctx`` is this engine's KernelContext (block table, VMEM budgets,
        # default kernel path, per-layer plan overrides).  It is attached to
        # every QLinear leaf as pytree-static metadata, so two engines in
        # one process can serve under DIFFERENT plan tables/budgets without
        # touching any global; None uses the process-default context.
        # kernel_impl=None attaches the ctx WITHOUT touching the calibrated
        # impls.
        if kernel_impl is not None or ctx is not None:
            from repro.quant.qlinear import retag_qlinear_impl

            params = retag_qlinear_impl(params, kernel_impl, ctx=ctx)
        # Mesh-sharded serving: tag + place the params (column/row-parallel
        # QLinears run the shard_map TP forward; everything else stays
        # replicated for dense families so non-collective math is bitwise
        # identical to single-device), and pick expert-parallel decode for
        # MoE configs when the expert count divides the "model" axis.
        # Ordering matters: retag FIRST (dataclasses.replace keeps array
        # identity, so placements survive), then shard.
        self.mesh = mesh
        self.tp_plan = None
        self._moe_impl = "dense"
        self._decode_stats = False
        self._ep_dropped = 0
        if mesh is not None:
            from repro.distributed import tp as tp_lib

            tp = tp_lib._axis_size(mesh, "model")
            if cfg.family == "moe" and tp > 1:
                if cfg.n_experts % tp == 0:
                    self._moe_impl = "ep"
                    self._decode_stats = True
                else:
                    warnings.warn(
                        f"n_experts={cfg.n_experts} does not divide "
                        f"model={tp}; MoE dispatch stays dense under the "
                        "mesh")
            params, self.tp_plan = tp_lib.shard_params(
                params, mesh, replicate_dense=(cfg.family != "moe"))
        self.ctx = ctx
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.seed = seed
        self.base_key = jax.random.PRNGKey(seed)

        # crash safety: write-ahead journal + snapshot schedule.  The open
        # record (below, once the mode is known) pins the shape config a
        # restored engine must be rebuilt with.
        self.journal = journal
        self.snapshot_every = snapshot_every
        self._ckpt = (CheckpointManager(snapshot_dir, every=1,
                                        keep=snapshot_keep)
                      if snapshot_dir is not None else None)
        self._journaled_submits: set = set()
        self._journaled_terminals: set = set()

        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.queue_limit = queue_limit
        self.queue_policy = queue_policy
        self.default_deadline_s = default_deadline_s
        self.slot_failure_limit = slot_failure_limit
        self.stall_patience = stall_patience
        self.injector = injector
        self.clock = clock
        self.sleep_fn = sleep_fn

        # family -> decode-state layout; see the module docstring
        if cfg.family in model_lib.PAGED_FAMILIES:
            self.mode = "paged"
        elif cfg.family in model_lib.STACKED_FAMILIES:
            self.mode = "stacked"
        else:
            self.mode = "slots"
        if prefill_chunk is not None and self.mode != "paged":
            raise ValueError(
                f"prefill_chunk requires a paged family "
                f"{model_lib.PAGED_FAMILIES}, not {cfg.family!r}")
        # KV storage spec: ONE axis of the cache layout for every mode.
        # The default (f32) reproduces the pre-KVSpec engine bitwise; float
        # specs route the storage dtype everywhere (paged pool, stacked and
        # per-slot caches alike); quantized specs need the paged layout —
        # recurrent / offset-carrying caches have no pages to quantize.
        self.kv_spec = kv_spec if kv_spec is not None else KVSpec()
        if self.kv_spec.is_quantized:
            if self.mode != "paged":
                raise ValueError(
                    f"kv dtype {self.kv_spec.dtype!r} requires the paged KV "
                    f"cache (families {model_lib.PAGED_FAMILIES}); "
                    f"{cfg.family!r} serves in {self.mode!r} mode")
            # surface bad geometry (odd head_dim for int4, group that does
            # not divide head_dim) at construction, not at first prefill
            self.kv_spec.packed_head_dim(cfg.head_dim)
            self.kv_spec.group_for(cfg.head_dim)
        self.page_size = page_size
        self.prefill_chunk = prefill_chunk
        self.alloc: Optional[PageAllocator] = None
        self.slot_caches: List = []
        if self.mode == "paged":
            # block-table width bounds positions to max_seq; the DEFAULT
            # pool exactly covers every slot at full length, so the free
            # list can only run dry when the caller shrinks kv_pages
            self.pages_per_slot = -(-max_seq // page_size)
            num_pages = (kv_pages if kv_pages is not None
                         else batch_slots * self.pages_per_slot + 1)
            self.alloc = PageAllocator(
                num_pages, page_size, sidecar=self.kv_spec.is_quantized)
            self.pool = model_lib.init_paged_cache(
                cfg, num_pages, page_size, dtype=jnp.float32,
                kv_spec=self.kv_spec)
            if mesh is not None:
                # replicated over "model", page axis data-sharded when it
                # divides — page gathers/scatters are pure data movement,
                # so placement never perturbs decode numerics
                from repro.distributed import tp as tp_lib

                self.pool = tp_lib.shard_kv_pool(self.pool, mesh)
            self.block_tables = np.zeros(
                (batch_slots, self.pages_per_slot), np.int32)
            self.lengths = np.zeros((batch_slots,), np.int32)
            self._prefill_off = [0] * batch_slots
        elif self.mode == "stacked":
            self.stacked_cache = model_lib.init_cache(
                cfg, batch_slots, max_seq, dtype=jnp.float32,
                kv_spec=self.kv_spec)
        else:
            # per-slot caches (B=1 each): these families' caches carry a
            # shared scalar offset, so slots cannot share a batched cache
            self.slot_caches = [self._fresh_cache() for _ in range(batch_slots)]

        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_fail_streak: List[int] = [0] * batch_slots
        self.slot_dead: List[bool] = [False] * batch_slots
        self.queue: List[Request] = []
        self.records: Dict[int, RequestRecord] = {}
        self.counters: Dict[str, int] = {
            "submitted": 0, "admitted": 0, "steps": 0, "retries": 0,
            "finished": 0, "failed": 0, "rejected": 0, "cancelled": 0,
            "timed_out": 0, "slot_failures": 0, "decode_calls": 0,
        }
        # rid -> consecutive failed attempts; a failed attempt retries on
        # the NEXT engine step (deferred retry) so the batched step stays
        # one forward per step even while some slot is flaky
        self._attempt_streak: Dict[int, int] = {}
        self._steps_since_progress = 0
        self.stall_report: Optional[dict] = None

        self._fns = _model_fns(cfg, self.kv_spec, self._moe_impl,
                               self._decode_stats, self.mesh)
        self._prefill = self._fns.prefill
        self._decode = self._fns.decode
        self._paged = self._fns.paged
        if self.mesh is not None:
            # every jitted call runs (and first traces) under the mesh, so
            # shard_map picks up the right ambient mesh at trace time
            from repro.core.jaxcompat import set_mesh

            def _with_mesh(fn, m=self.mesh):
                @functools.wraps(fn)
                def call(*args):
                    with set_mesh(m):
                        return fn(*args)
                return call

            self._prefill = _with_mesh(self._prefill)
            self._decode = _with_mesh(self._decode)
            self._paged = _with_mesh(self._paged)
        self.decode_plan = self._resolve_decode_plan()

        self._journal("open", mode=self.mode, family=cfg.family,
                      batch_slots=batch_slots, max_seq=max_seq,
                      eos_id=eos_id, seed=seed, page_size=page_size,
                      kv_pages=(None if self.alloc is None
                                else self.alloc.num_pages),
                      prefill_chunk=prefill_chunk,
                      **self.kv_spec.to_meta())

    # -- public API ---------------------------------------------------------

    def submit(self, req: Request) -> bool:
        """Validate and enqueue; returns False (with a ``REJECTED`` record)
        when admission control refuses the request."""
        now = self.clock()
        req.submitted_at = now
        if req.deadline_s is None:
            req.deadline_s = self.default_deadline_s
        err = self._validate(req)
        if err is not None:
            if err[0] is ErrorKind.DUPLICATE_RID:
                # a second record cannot be indexed under the same rid —
                # reject the duplicate in place, leaving the original
                # request's record/queue entry untouched
                req.error_kind, req.error = err
                req.advance(RequestState.REJECTED, now)
                self.counters["rejected"] += 1
                return False
            self._finalize(req, RequestState.REJECTED, *err)
            return False
        if self.queue_limit is not None and len(self.queue) >= self.queue_limit:
            if self.queue_policy == "drop_oldest":
                oldest = self.queue.pop(0)
                self._finalize(oldest, RequestState.REJECTED,
                               ErrorKind.QUEUE_EVICTED,
                               f"evicted by rid {req.rid} under drop_oldest "
                               f"(queue_limit={self.queue_limit})")
            else:
                self._finalize(req, RequestState.REJECTED,
                               ErrorKind.QUEUE_FULL,
                               f"queue at limit {self.queue_limit}")
                return False
        # WAL: the submit record is durable BEFORE the request becomes
        # engine state — a crash one instruction later replays it.
        # Rejected submits are deliberately NOT journaled: their REJECTED
        # record was already returned synchronously, so recovery owes them
        # nothing (and must not emit a second terminal for the rid).
        if self.journal is not None:
            self.journal.append(
                "submit", rid=req.rid,
                prompt=[int(t) for t in np.asarray(req.prompt)],
                max_new_tokens=int(req.max_new_tokens),
                temperature=float(req.temperature),
                deadline_s=req.deadline_s)
            self._journaled_submits.add(req.rid)
        self.counters["submitted"] += 1
        self.queue.append(req)
        return True

    def cancel(self, rid: int) -> bool:
        """Cancel a queued or in-flight request; False if unknown/terminal."""
        for qi, req in enumerate(self.queue):
            if req.rid == rid:
                self.queue.pop(qi)
                self._finalize(req, RequestState.CANCELLED,
                               ErrorKind.CANCELLED, "cancelled while queued")
                return True
        for i, req in enumerate(self.slot_req):
            if req is not None and req.rid == rid:
                # applied immediately: free the slot (and its pages), keep
                # emitted tokens
                self._release_slot(i)
                self._finalize(req, RequestState.CANCELLED,
                               ErrorKind.CANCELLED, "cancelled in flight")
                return True
        return False

    def run(self, max_steps: int = 1024) -> Dict[int, RequestRecord]:
        """Drive until queue + slots drain; never raises for per-request
        failures.  Exhausting ``max_steps`` returns the survivors as
        ``TIMED_OUT`` records; a detected stall aborts with
        ``self.stall_report`` set."""
        self.stall_report = None
        for _ in range(max_steps):
            self.counters["steps"] += 1
            progressed = self._expire_deadlines()
            progressed |= self._admit()
            if not any(r is not None for r in self.slot_req) and not self.queue:
                break
            progressed |= self._prefill_tick()
            progressed |= self._step()
            self._steps_since_progress = (
                0 if progressed else self._steps_since_progress + 1)
            stall = self._stall_reason()
            if stall is not None:
                self.stall_report = {"reason": stall, "health": self.health()}
                self._drain_unfinished(ErrorKind.STALL,
                                       f"run() aborted: {stall}")
                return self.records
            # snapshot at the step boundary ONLY: no forward is in flight,
            # lengths/pool/allocator are mutually consistent
            if (self.snapshot_every and self._ckpt is not None
                    and self.counters["steps"] % self.snapshot_every == 0):
                self.snapshot()
        else:
            self._drain_unfinished(
                ErrorKind.STEP_LIMIT,
                f"engine step budget ({max_steps}) exhausted")
        return self.records

    def health(self) -> dict:
        """Live snapshot: slot states, queue depth, counters, liveness,
        page-pool accounting, trace counts, and the decode-regime kernel
        plan resolved at the engine's REAL batched M (= ``batch_slots``)."""
        slots = []
        for i in range(self.b):
            req = self.slot_req[i]
            slots.append({
                "slot": i,
                "state": ("dead" if self.slot_dead[i]
                          else req.state.value if req is not None else "idle"),
                "rid": None if req is None else req.rid,
                "tokens": 0 if req is None else len(req.out_tokens),
                "fail_streak": self.slot_fail_streak[i],
            })
        return {
            "slots": slots,
            "queue_depth": len(self.queue),
            "dead_slots": sum(self.slot_dead),
            "counters": dict(self.counters),
            "steps_since_progress": self._steps_since_progress,
            "stalled": self.stall_report is not None,
            "mode": self.mode,
            "kv": self._kv_health(),
            "kv_pages": None if self.alloc is None else self.alloc.stats(),
            "traces": dict(self._fns.traces),
            "decode_plan": self.decode_plan,
            "mesh": self._mesh_health(),
            "journal_seq": None if self.journal is None else self.journal.seq,
        }

    def _mesh_health(self) -> Optional[dict]:
        """``health()["mesh"]``: axis sizes, the per-shard decode plan at
        every distinct LOCAL (K, N, R) a TP-tagged QLinear resolves to
        (mirroring ``decode_plan`` but at the shard's shapes, where the
        shape-keyed ctx overrides apply), and the EP capacity-overflow drop
        counter.  None when the engine is single-device."""
        if self.mesh is None:
            return None
        axes = {str(k): int(v) for k, v in dict(self.mesh.shape).items()}
        plans: Dict[str, dict] = {}
        for entry in (self.tp_plan or []):
            k, n, r = entry["local_knr"]
            key = f"{entry['parallel'] or 'replicated'}:{k}x{n}r{r}"
            if key in plans:
                plans[key]["layers"] += 1
                continue
            ctx = entry.get("ctx") or self.ctx
            if ctx is None:
                from repro.kernels import ops

                ctx = ops.default_context()
            plan = ctx.resolve_plan(self.b, k, n, r,
                                    act_group=entry.get("act_group"))
            plans[key] = {
                "parallel": entry["parallel"], "layers": 1,
                "local": {"m": self.b, "k": k, "n": n, "r": r},
                "path": plan.path, "bm": plan.bm, "bn": plan.bn,
                "bk": plan.bk, "br": plan.br, "variant": plan.variant,
            }
        return {
            "axes": axes,
            "moe_impl": self._moe_impl,
            "ep_dropped": int(self._ep_dropped),
            "decode_plans": plans,
        }

    def _kv_health(self) -> dict:
        """``health()["kv"]``: the effective KV storage scheme and its HBM
        cost.  ``bytes_per_token`` (paged mode) is the all-layer K+V
        footprint of one token — data plus scale planes — computed by the
        canonical ``KVSpec.kv_bytes_per_token`` spelling; stacked mode has
        no per-token cache, so it reports the per-slot recurrent-state
        bytes its spec actually produced instead."""
        info = {"dtype": self.kv_spec.dtype, "group": self.kv_spec.group,
                "layout": self.kv_spec.describe()}
        if self.mode == "paged":
            info["bytes_per_token"] = (
                self.cfg.n_layers * self.kv_spec.kv_bytes_per_token(
                    self.cfg.n_kv_heads, self.cfg.head_dim))
        elif self.mode == "stacked":
            leaves = jax.tree.leaves(self.stacked_cache)
            info["state_bytes_per_slot"] = int(
                sum(l.size * l.dtype.itemsize for l in leaves)) // self.b
        return info

    # -- kernel-plan introspection ------------------------------------------

    def _resolve_decode_plan(self) -> Optional[dict]:
        """The kernel plan the batched decode step actually runs: QLinear
        flattens (B, 1, K) activations to an (M=B, K) GEMM, so the plan must
        be resolved at M = ``batch_slots``, not the per-slot M=1 the old
        slot-loop engine implied.  Uses the largest QLinear in the params
        (the dominant GEMM of the step); None for FP params."""
        from repro.kernels.context import gemm_regime

        from repro.quant.qlinear import QLinear

        leaves = jax.tree.leaves(
            self.params, is_leaf=lambda x: isinstance(x, QLinear))
        qls = [l for l in leaves if isinstance(l, QLinear)]
        if not qls:
            return None
        q = max(qls, key=lambda l: l.d_in * l.d_out)
        ctx = q.ctx
        if ctx is None:
            from repro.kernels import ops
            ctx = ops.default_context()
        r = 0 if q.u is None else int(q.u.shape[1])
        plan = ctx.resolve_plan(self.b, q.d_in, q.d_out, r,
                                layer=q.name, act_group=q.act_group)
        return {
            "m": self.b, "k": q.d_in, "n": q.d_out, "r": r,
            "regime": gemm_regime(self.b), "impl": q.impl,
            "path": plan.path, "bm": plan.bm, "bn": plan.bn, "bk": plan.bk,
            "br": plan.br, "variant": plan.variant,
        }

    # -- admission ----------------------------------------------------------

    def _validate(self, req: Request) -> Optional[Tuple[ErrorKind, str]]:
        if (req.rid in self.records
                or any(q.rid == req.rid for q in self.queue)
                or any(r is not None and r.rid == req.rid for r in self.slot_req)):
            return (ErrorKind.DUPLICATE_RID,
                    f"rid {req.rid} already known to the engine")
        prompt = np.asarray(req.prompt)
        if prompt.ndim != 1 or prompt.size == 0:
            return (ErrorKind.EMPTY_PROMPT,
                    f"prompt must be a non-empty 1-D token "
                    f"array, got shape {prompt.shape}")
        if not np.issubdtype(prompt.dtype, np.integer):
            return (ErrorKind.BAD_TOKEN_IDS,
                    f"prompt dtype {prompt.dtype} is not integral")
        if prompt.min() < 0 or prompt.max() >= self.cfg.vocab_size:
            return (ErrorKind.BAD_TOKEN_IDS,
                    f"token ids outside [0, {self.cfg.vocab_size})")
        if len(prompt) >= self.max_seq:
            # max_seq bounds the position space (block-table width in paged
            # mode, contiguous cache region otherwise) — an oversized prompt
            # can never be admitted
            return (ErrorKind.PROMPT_TOO_LONG,
                    f"prompt length {len(prompt)} >= max_seq {self.max_seq}")
        if self.mode == "paged":
            # pool accounting: a prompt that needs more pages than the pool
            # HOLDS can never admit no matter how long it queues (transient
            # shortage is handled by FIFO backpressure in _admit instead)
            need = self.alloc.pages_for(len(prompt) + 1)
            if need > self.alloc.capacity:
                return (ErrorKind.KV_CAPACITY,
                        f"prompt needs {need} KV pages; pool capacity is "
                        f"{self.alloc.capacity} pages of {self.page_size}")
        if req.max_new_tokens < 1:
            return (ErrorKind.BAD_TOKEN_BUDGET,
                    f"max_new_tokens must be >= 1, got {req.max_new_tokens}")
        if req.deadline_s is not None and req.deadline_s <= 0:
            return (ErrorKind.BAD_DEADLINE,
                    f"deadline_s must be > 0, got {req.deadline_s}")
        return None

    def _admit(self) -> bool:
        progressed = False
        for i in range(self.b):
            # a slot that finishes/fails at prefill frees up immediately,
            # so keep pulling from the queue until it sticks or the queue
            # (or the slot's life) runs out
            while (not self.slot_dead[i] and self.slot_req[i] is None
                   and self.queue):
                if self.mode == "paged":
                    head = self.queue[0]
                    # a recovery-resumed request re-prefills over prompt +
                    # already-committed tokens, so charge the extended length
                    need = self.alloc.pages_for(
                        len(head.prompt) + len(head.out_tokens) + 1)
                    if need > self.alloc.free_pages:
                        # page-accounting backpressure: hold the queue in
                        # FIFO order until co-tenants free enough pages
                        # (all-idle implies all pages free, so this cannot
                        # deadlock for a prompt that passed _validate)
                        return progressed
                req = self.queue.pop(0)
                progressed = True
                self._admit_one(i, req)
        return progressed

    def _admit_one(self, i: int, req: Request):
        req.advance(RequestState.PREFILLING, self.clock())
        self.counters["admitted"] += 1
        self.slot_req[i] = req
        # audit only: slot placement never affects outputs, so replay
        # ignores admit records — but post-mortems want the mapping
        self._journal("admit", rid=req.rid, slot=i)
        if self.mode == "paged":
            self._prefill_off[i] = 0
            self.lengths[i] = 0
            self._prefill_advance(i)
        else:
            self._slot_prefill(i, req)

    def _prefill_tick(self) -> bool:
        """Advance every mid-prefill slot by one chunk (paged mode), or
        retry a whole-prompt prefill whose last attempt failed."""
        progressed = False
        for i in range(self.b):
            req = self.slot_req[i]
            if req is None or req.state is not RequestState.PREFILLING:
                continue
            if self.mode == "paged":
                progressed |= self._prefill_advance(i)
            else:
                progressed |= self._slot_prefill(i, req)
        return progressed

    # -- prefill ------------------------------------------------------------

    def _prefill_advance(self, i: int) -> bool:
        """One guarded prefill-chunk attempt for slot ``i`` (paged mode).
        Nothing is committed on failure: the pool reference, chunk offset
        and length are untouched, so the retry replays the same chunk from
        clean state."""
        req = self.slot_req[i]
        prompt = np.asarray(req.prompt, np.int32)
        if req.out_tokens:
            # recovery resume: requests restored mid-stream re-prefill over
            # prompt + every journaled token, so the KV pool covers positions
            # [0, n+k) and the final chunk samples token index k = len(out)
            # — exactly the key the uninterrupted run would have used.  In
            # normal operation out_tokens is always empty while PREFILLING.
            prompt = np.concatenate(
                [prompt, np.asarray(req.out_tokens, np.int32)])
        n_prompt = int(prompt.size)
        got = self.alloc.ensure(req.rid, n_prompt)
        if got is None:
            self._attempt_failed(i, req, PagesExhausted(
                f"free list cannot cover "
                f"{self.alloc.pages_for(n_prompt)} prompt page(s) for rid "
                f"{req.rid} ({self.alloc.free_pages} free of "
                f"{self.alloc.capacity})"))
            return True
        if got:
            self._write_block_row(i, req.rid)
        off = self._prefill_off[i]
        chunk = self.prefill_chunk or n_prompt
        n = min(chunk, n_prompt - off)
        final = off + n >= n_prompt
        fault = (self.injector.poll(req.rid, "prefill")
                 if self.injector is not None else None)
        try:
            pool_in = self.pool
            if fault is not None:
                if fault.kind == "slow_step":
                    self.injector.sleep(fault.seconds)
                elif fault.kind == "process_crash":
                    raise SimulatedCrash(
                        f"simulated crash at prefill of rid {req.rid} "
                        f"(chunk offset {off})")
                elif fault.kind == "exception":
                    raise InjectedFault(
                        f"injected prefill exception for rid {req.rid}")
                elif fault.kind == "cache_corruption":
                    pool_in = self.injector.corrupt_pages(
                        self.pool, self.alloc.pages_of(req.rid))
            tokens = np.zeros((1, chunk), np.int32)
            tokens[0, :n] = prompt[off:off + n]
            positions = off + np.arange(chunk, dtype=np.int32)[None, :]
            valid = (np.arange(chunk) < n)[None, :]
            srow = np.asarray([n - 1], np.int32)
            logits, new_pool = self._paged(
                self.params, jnp.asarray(tokens), jnp.asarray(positions),
                jnp.asarray(valid), pool_in,
                jnp.asarray(self.block_tables[i:i + 1]), jnp.asarray(srow))
            if fault is not None and fault.kind in ("nan_logits", "inf_logits"):
                logits = self.injector.corrupt_logits(logits, fault.kind)
            if final:
                sfault = (self.injector.poll(req.rid, "sampling")
                          if self.injector is not None else None)
                if sfault is not None:
                    if sfault.kind == "slow_step":
                        self.injector.sleep(sfault.seconds)
                    elif sfault.kind == "process_crash":
                        raise SimulatedCrash(
                            f"simulated crash at sampling of rid {req.rid}")
                    elif sfault.kind == "exception":
                        raise InjectedFault(
                            f"injected sampling exception for rid {req.rid}")
                tok = int(self._sample(req, logits[:, -1])[0])
            else:
                # non-final chunks never sample, but NaN must not reach the
                # committed pool — LQER-style blow-ups surface here, not
                # three chunks later in a co-tenant's decode
                self._check_finite(logits)
        except Exception as e:  # isolated: fails only this request
            self._attempt_failed(i, req, e)
            return True
        self.pool = new_pool
        self._prefill_off[i] = off + n
        self.lengths[i] = off + n
        self._attempt_streak.pop(req.rid, None)
        self.slot_fail_streak[i] = 0
        if final:
            self._finish_prefill(i, req, tok)
        return True

    def _slot_prefill(self, i: int, req: Request) -> bool:
        """One guarded whole-prompt B=1 prefill attempt (stacked / slots
        modes)."""
        stream = np.asarray(req.prompt, np.int32)
        if req.out_tokens:
            # recovery resume — see _prefill_advance for the arithmetic
            stream = np.concatenate(
                [stream, np.asarray(req.out_tokens, np.int32)])
        toks = jnp.asarray(stream[None, :], jnp.int32)
        fault = (self.injector.poll(req.rid, "prefill")
                 if self.injector is not None else None)
        try:
            cache_in = self._fresh_cache()
            if fault is not None:
                if fault.kind == "slow_step":
                    self.injector.sleep(fault.seconds)
                elif fault.kind == "process_crash":
                    raise SimulatedCrash(
                        f"simulated crash at prefill of rid {req.rid}")
                elif fault.kind == "exception":
                    raise InjectedFault(
                        f"injected prefill exception for rid {req.rid}")
                elif fault.kind == "cache_corruption":
                    cache_in = self.injector.corrupt_cache(cache_in)
            logits, new_cache = self._prefill(self.params, toks, cache_in)
            if fault is not None and fault.kind in ("nan_logits", "inf_logits"):
                logits = self.injector.corrupt_logits(logits, fault.kind)
            sfault = (self.injector.poll(req.rid, "sampling")
                      if self.injector is not None else None)
            if sfault is not None:
                if sfault.kind == "slow_step":
                    self.injector.sleep(sfault.seconds)
                elif sfault.kind == "process_crash":
                    raise SimulatedCrash(
                        f"simulated crash at sampling of rid {req.rid}")
                elif sfault.kind == "exception":
                    raise InjectedFault(
                        f"injected sampling exception for rid {req.rid}")
            tok = int(self._sample(req, logits[:, -1])[0])
        except Exception as e:  # isolated: fails only this request
            self._attempt_failed(i, req, e)
            return True
        if self.mode == "stacked":
            self.stacked_cache = model_lib.insert_cache_row(
                self.stacked_cache, new_cache, i)
        else:
            self.slot_caches[i] = new_cache
        self._attempt_streak.pop(req.rid, None)
        self.slot_fail_streak[i] = 0
        self._finish_prefill(i, req, tok)
        return True

    def _finish_prefill(self, i: int, req: Request, tok: int):
        self._commit_token(req, tok)
        req.first_token_at = self.clock()
        # the prefill-sampled token obeys the SAME termination predicate as
        # decode tokens: max_new_tokens=1 means one token, and an EOS
        # emitted at prefill ends the request
        if self._should_finish(req, tok):
            self._release_slot(i)
            self._finalize(req, RequestState.FINISHED)
        else:
            req.advance(RequestState.DECODING, self.clock())

    # -- stepping -----------------------------------------------------------

    def _step(self) -> bool:
        if self.mode == "slots":
            return self._step_slots()
        active = [i for i in range(self.b)
                  if self.slot_req[i] is not None
                  and self.slot_req[i].state is RequestState.DECODING]
        if not active:
            return False
        progressed = False
        faults: Dict[int, object] = {}
        if self.injector is not None:
            for i in active:
                f = self.injector.poll(self.slot_req[i].rid, "decode")
                if f is not None:
                    faults[i] = f
                    if f.kind == "slow_step":
                        self.injector.sleep(f.seconds)
                    elif f.kind == "process_crash":
                        raise SimulatedCrash(
                            f"simulated crash at decode of rid "
                            f"{self.slot_req[i].rid}")
        if self.mode == "paged":
            # decode-boundary crossings allocate before the forward; a dry
            # free list fails ONLY that slot's attempt (deferred retry —
            # a co-tenant may free pages by the next step)
            for i in list(active):
                req = self.slot_req[i]
                got = self.alloc.ensure(req.rid, int(self.lengths[i]) + 1)
                if got is None:
                    active.remove(i)
                    self._attempt_failed(i, req, PagesExhausted(
                        f"no free page for rid {req.rid} at position "
                        f"{int(self.lengths[i])} ({self.alloc.free_pages} "
                        f"free of {self.alloc.capacity})"))
                    progressed = True
                elif got:
                    self._write_block_row(i, req.rid)
            if not active:
                return progressed

        # injected exceptions fire "before the forward": the slot drops out
        # of the valid mask (paged) / gets its row rolled back (stacked),
        # so the ONE batched call still runs for everyone else
        excluded = {i for i in active
                    if i in faults and faults[i].kind == "exception"}
        included = [i for i in active if i not in excluded]
        corrupt = [i for i in included
                   if i in faults and faults[i].kind == "cache_corruption"]

        tokens = np.zeros((self.b, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slot_req[i].out_tokens[-1]

        self.counters["decode_calls"] += 1
        try:
            if self.mode == "paged":
                pool_in = self.pool
                for i in corrupt:
                    pool_in = self.injector.corrupt_pages(
                        pool_in, self.alloc.pages_of(self.slot_req[i].rid))
                valid = np.zeros((self.b, 1), bool)
                for i in included:
                    valid[i, 0] = True
                positions = self.lengths.astype(np.int32)[:, None]
                srow = np.zeros((self.b,), np.int32)
                logits, new_state = self._paged(
                    self.params, jnp.asarray(tokens), jnp.asarray(positions),
                    jnp.asarray(valid), pool_in,
                    jnp.asarray(self.block_tables), jnp.asarray(srow))
            else:
                cache_in = self.stacked_cache
                for i in corrupt:
                    cache_in = self.injector.corrupt_rows(cache_in, i)
                logits, new_state = self._decode(
                    self.params, jnp.asarray(tokens), cache_in)
        except Exception as e:
            # the one batched call itself died: no slot committed anything,
            # every active request gets a (retryable) failed attempt
            for i in active:
                self._attempt_failed(i, self.slot_req[i], e)
            return True

        # per-row outcomes first (no engine mutation), THEN the state
        # commit+rollback, THEN the bookkeeping — _slot_failure frees pages,
        # which must not happen before the rollback reads them
        outcomes: Dict[int, Tuple[str, object]] = {}
        for i in active:
            req = self.slot_req[i]
            f = faults.get(i)
            if i in excluded:
                outcomes[i] = ("fail", InjectedFault(
                    f"injected decode exception for rid {req.rid}"))
                continue
            row = logits[i:i + 1, -1]
            try:
                if f is not None and f.kind in ("nan_logits", "inf_logits"):
                    row = self.injector.corrupt_logits(row, f.kind)
                sfault = (self.injector.poll(req.rid, "sampling")
                          if self.injector is not None else None)
                if sfault is not None:
                    if sfault.kind == "slow_step":
                        self.injector.sleep(sfault.seconds)
                    elif sfault.kind == "process_crash":
                        # BaseException: escapes this per-request guard AND
                        # the step — nothing below commits
                        raise SimulatedCrash(
                            f"simulated crash at sampling of rid {req.rid}")
                    elif sfault.kind == "exception":
                        raise InjectedFault(
                            f"injected sampling exception for rid {req.rid}")
                outcomes[i] = ("ok", int(self._sample(req, row)[0]))
            except Exception as e:  # isolated: fails only this request
                outcomes[i] = ("fail", e)

        failed = [i for i in active if outcomes[i][0] == "fail"]
        if self.mode == "paged":
            # a failed attempt commits nothing: corrupted slots get their
            # pages restored from the pre-step pool (page-disjointness makes
            # the restore exact); excluded slots were never written (valid
            # mask → null page); other failures keep their length, so the
            # retry overwrites the same position
            rollback = sorted({p for i in failed if i in corrupt
                               for p in self.alloc.pages_of(self.slot_req[i].rid)})
            if rollback:
                ids = jnp.asarray(rollback, jnp.int32)
                new_state = jax.tree.map(
                    lambda new, old: new.at[:, ids].set(old[:, ids]),
                    new_state, self.pool)
            self.pool = new_state
        else:
            # stacked rows all advance in the batched call — roll back every
            # failed slot's row to the pre-step cache
            if failed:
                ids = jnp.asarray(failed, jnp.int32)
                new_state = jax.tree.map(
                    lambda new, old: new.at[:, ids].set(old[:, ids]),
                    new_state, self.stacked_cache)
            self.stacked_cache = new_state

        for i in active:
            req = self.slot_req[i]
            kind, val = outcomes[i]
            progressed = True  # a token OR a terminal/retry record is progress
            if kind == "fail":
                self._attempt_failed(i, req, val)
                continue
            self._attempt_streak.pop(req.rid, None)
            self.slot_fail_streak[i] = 0
            self._commit_token(req, val)
            if self.mode == "paged":
                self.lengths[i] += 1
            if self._should_finish(req, val):
                self._release_slot(i)
                self._finalize(req, RequestState.FINISHED)
        return progressed

    def _step_slots(self) -> bool:
        """Legacy per-slot decode loop for families whose caches carry a
        shared scalar offset (vlm/hybrid/moe) — see docs/serving.md."""
        progressed = False
        for i, req in enumerate(self.slot_req):
            if req is None or req.state is not RequestState.DECODING:
                continue
            last = jnp.asarray([[req.out_tokens[-1]]], jnp.int32)
            fault = (self.injector.poll(req.rid, "decode")
                     if self.injector is not None else None)
            self.counters["decode_calls"] += 1
            try:
                cache_in = self.slot_caches[i]
                if fault is not None:
                    if fault.kind == "slow_step":
                        self.injector.sleep(fault.seconds)
                    elif fault.kind == "process_crash":
                        raise SimulatedCrash(
                            f"simulated crash at decode of rid {req.rid}")
                    elif fault.kind == "exception":
                        raise InjectedFault(
                            f"injected decode exception for rid {req.rid}")
                    elif fault.kind == "cache_corruption":
                        cache_in = self.injector.corrupt_cache(cache_in)
                out = self._decode(self.params, last, cache_in)
                if self._decode_stats:
                    logits, new_cache, stats = out
                    self._ep_dropped += int(stats["ep_dropped"])
                else:
                    logits, new_cache = out
                if fault is not None and fault.kind in ("nan_logits", "inf_logits"):
                    logits = self.injector.corrupt_logits(logits, fault.kind)
                sfault = (self.injector.poll(req.rid, "sampling")
                          if self.injector is not None else None)
                if sfault is not None:
                    if sfault.kind == "slow_step":
                        self.injector.sleep(sfault.seconds)
                    elif sfault.kind == "process_crash":
                        raise SimulatedCrash(
                            f"simulated crash at sampling of rid {req.rid}")
                    elif sfault.kind == "exception":
                        raise InjectedFault(
                            f"injected sampling exception for rid {req.rid}")
                tok = int(self._sample(req, logits[:, -1])[0])
            except Exception as e:  # isolated: fails only this request
                self._attempt_failed(i, req, e)
                progressed = True
                continue
            self.slot_caches[i] = new_cache
            self._attempt_streak.pop(req.rid, None)
            self.slot_fail_streak[i] = 0
            self._commit_token(req, tok)
            progressed = True
            if self._should_finish(req, tok):
                self._release_slot(i)
                self._finalize(req, RequestState.FINISHED)
        return progressed

    # -- shared attempt / sampling helpers ----------------------------------

    def _attempt_failed(self, i: int, req: Request, e: BaseException):
        """Account one failed attempt.  Within the retry budget the request
        stays in its slot and the SAME phase replays next engine step from
        clean committed state (nothing was committed for it); past the
        budget it becomes a FAILED record via ``_slot_failure``."""
        streak = self._attempt_streak.get(req.rid, 0)
        if streak >= self.max_retries:
            self._attempt_streak.pop(req.rid, None)
            self._slot_failure(i, req, e)
            return
        self._attempt_streak[req.rid] = streak + 1
        req.retries += 1
        self.counters["retries"] += 1
        if self.retry_backoff_s > 0:
            self.sleep_fn(self.retry_backoff_s * (2 ** streak))

    def _check_finite(self, logits):
        if not bool(jnp.isfinite(logits).all()):
            n_nan = int(jnp.isnan(logits).sum())
            n_inf = int(jnp.isinf(logits).sum())
            raise NonFiniteLogitsError(
                f"non-finite logits at prefill-chunk boundary: {n_nan} NaN, "
                f"{n_inf} Inf of {logits.size} entries")

    def _sample(self, req: Request, logits):
        # key depends only on (engine seed, rid, token index): a request's
        # tokens are invariant to slot placement, co-tenants, page layout,
        # and retries — the property the chaos suite's bitwise-parity
        # asserts rely on
        key = jax.random.fold_in(
            jax.random.fold_in(self.base_key, req.rid), len(req.out_tokens))
        return sample_token(logits, key, temperature=req.temperature,
                            check_finite=True)

    def _should_finish(self, req: Request, tok: int) -> bool:
        total = len(req.prompt) + len(req.out_tokens)
        return (
            len(req.out_tokens) >= req.max_new_tokens
            or (self.eos_id is not None and tok == self.eos_id)
            or total >= self.max_seq - 1
        )

    # -- crash safety: journal hooks, snapshot, restore ----------------------

    def _journal(self, kind: str, **fields):
        if self.journal is not None:
            self.journal.append(kind, **fields)

    def _commit_token(self, req: Request, tok: int):
        """Durably journal the token at its stream index, THEN append it to
        the request — the WAL ordering that makes delivery exactly-once:
        a crash between the two replays the journaled token; a crash before
        the journal write never shows the token anywhere."""
        tok = int(tok)
        if self.journal is not None and req.rid in self._journaled_submits:
            self.journal.append("token", rid=req.rid,
                                idx=len(req.out_tokens), token=tok)
        req.out_tokens.append(tok)

    def _state_tree(self):
        """The mode-specific array state a snapshot persists (and the
        ``like`` tree a restore loads against)."""
        if self.mode == "paged":
            return {"pool": self.pool,
                    "block_tables": np.array(self.block_tables),
                    "lengths": np.array(self.lengths)}
        if self.mode == "stacked":
            return {"cache": self.stacked_cache}
        return {"slot_caches": self.slot_caches}

    def snapshot(self) -> Optional[str]:
        """Persist the full decode state through the atomic checkpoint path
        (``.tmp``-rename, keep-``snapshot_keep`` rotation): the KV pool /
        caches, the page allocator + block tables, slot lifecycle states,
        chunked-prefill offsets, queue order and counters.  Must run at an
        engine-step boundary — ``run()`` calls it every ``snapshot_every``
        steps, when no forward is in flight and lengths / pool / allocator
        are mutually consistent.  Returns the checkpoint path, or None when
        no ``snapshot_dir`` is configured."""
        if self._ckpt is None:
            return None
        meta = {
            "mode": self.mode,
            "seed": self.seed,
            "batch_slots": self.b,
            "max_seq": self.max_seq,
            "page_size": self.page_size,
            "prefill_chunk": self.prefill_chunk,
            **self.kv_spec.to_meta(),
            "counters": dict(self.counters),
            "slot_dead": [bool(x) for x in self.slot_dead],
            "slot_fail_streak": [int(x) for x in self.slot_fail_streak],
            "queue": [q.rid for q in self.queue],
            "journal_seq": None if self.journal is None else self.journal.seq,
            "slots": [
                None if req is None else {
                    "rid": req.rid,
                    "state": req.state.value,
                    "n_out": len(req.out_tokens),
                    "prefill_off": (self._prefill_off[i]
                                    if self.mode == "paged" else 0),
                }
                for i, req in enumerate(self.slot_req)
            ],
        }
        if self.mode == "paged":
            meta["alloc"] = self.alloc.to_state()
        tree = {
            "state": self._state_tree(),
            # the variable-length JSON rides as a uint8 leaf; restore reads
            # it back via load_leaf because the like-tree protocol needs
            # fixed shapes
            "meta": np.frombuffer(json.dumps(meta).encode(), np.uint8),
        }
        step = self.counters["steps"]
        path = self._ckpt.save(step, tree)
        self._journal("snapshot", step=step, path=str(path))
        return path

    def _clear_slot_state(self, i: int, rid: int):
        """Drop a restored slot whose snapshot KV cannot be reused (its
        owner terminated after the snapshot, or the journal is ahead of the
        snapshot for this rid)."""
        if self.mode == "paged":
            if self.alloc.holds(rid):
                self.alloc.free(rid)
            self.block_tables[i, :] = 0
            self.lengths[i] = 0
            self._prefill_off[i] = 0
        elif self.mode == "slots":
            self.slot_caches[i] = self._fresh_cache()

    @classmethod
    def restore(cls, cfg, params, journal_path, *,
                snapshot_dir: Optional[str] = None,
                snapshot_every: int = 0, snapshot_keep: int = 3,
                fsync: bool = True, **engine_kwargs) -> "ServeEngine":
        """Recover a crashed engine: replay the write-ahead journal (the
        request truth — what exists, what was delivered, what terminated),
        then graft on the newest restorable snapshot (the KV accelerator).

        - A slot whose journaled token count equals the snapshot's resumes
          IN PLACE from the restored pool/caches; mid-prefill slots resume
          at their chunk offset.
        - Anything the journal knows that the snapshot does not — tokens
          committed after the snapshot, requests still queued, no usable
          snapshot at all — re-enqueues for a re-prefill over ``prompt +
          journaled tokens``.  Sampling keys depend only on (seed, rid,
          token index), so the continuation is bitwise identical either
          way; already-journaled tokens are never re-delivered.
        - A missing / stale / corrupt snapshot degrades to journal-only
          recovery with a warning; a corrupt journal interior raises
          :class:`~repro.serve.journal.JournalCorruption` instead (replay
          past lost records could double-deliver).

        ``engine_kwargs`` passes through operational knobs (injector,
        clock, retry budgets, kernel_impl, ...); the shape config
        (batch_slots, max_seq, seed, paging) always comes from the
        journal's ``open`` record — recovery with mismatched shapes cannot
        be bitwise and is refused at the source."""
        if "journal" in engine_kwargs:
            raise JournalError("restore() owns the journal; do not pass one")
        if "kv_spec" in engine_kwargs:
            raise JournalError(
                "restore() reads the KV spec from the journal's open "
                "record; do not pass kv_spec")
        replay = read_journal(journal_path)
        col = collate(replay.records)
        if not col.opens:
            raise JournalError(
                f"journal {journal_path} has no open record — not a serve "
                f"journal (or its head was lost)")
        opened = col.opens[0]
        eng = cls(cfg, params,
                  batch_slots=int(opened["batch_slots"]),
                  max_seq=int(opened["max_seq"]),
                  eos_id=opened["eos_id"],
                  seed=int(opened["seed"]),
                  page_size=int(opened["page_size"]),
                  kv_pages=opened["kv_pages"],
                  prefill_chunk=opened["prefill_chunk"],
                  kv_spec=KVSpec.from_meta(opened),
                  snapshot_dir=snapshot_dir,
                  snapshot_every=snapshot_every,
                  snapshot_keep=snapshot_keep,
                  **engine_kwargs)
        if opened["mode"] != eng.mode:
            raise JournalError(
                f"journal was written by a {opened['mode']!r}-mode engine "
                f"but cfg {cfg.name!r} resolves to {eng.mode!r}")
        now = eng.clock()

        def make_req(rid: int) -> Request:
            sub = col.submits[rid]
            req = Request(rid=rid,
                          prompt=np.asarray(sub["prompt"], np.int32),
                          max_new_tokens=int(sub["max_new_tokens"]),
                          temperature=float(sub["temperature"]),
                          deadline_s=sub.get("deadline_s"))
            req.out_tokens = list(col.tokens.get(rid, []))
            # deadlines re-anchor at restore: the crash was the engine's
            # fault, so a recovered request gets its full budget back
            req.submitted_at = now
            return req

        # terminal records journaled before the crash re-materialize as
        # records (their phase timings died with the process)
        for rid, term in col.terminals.items():
            toks = col.tokens.get(rid, [])
            eng.records[rid] = RequestRecord(
                rid=rid, status=RequestState(term["status"]),
                out_tokens=list(toks),
                prompt_tokens=len(col.submits[rid]["prompt"]),
                new_tokens=len(toks), retries=int(term.get("retries", 0)),
                error_kind=term.get("error_kind"), error=term.get("error"),
                timings={})
        eng._journaled_submits = set(col.submits)
        eng._journaled_terminals = set(col.terminals)
        # re-attach the journal (truncating any torn tail) BEFORE any
        # restore-time finalization, so e.g. an already-satisfied request
        # journals its terminal record like any other
        eng.journal = JournalWriter.reopen(journal_path, replay, fsync=fsync)

        def settle(req: Request) -> bool:
            """A journaled stream that already satisfies the termination
            predicate (the crash fell between the last token commit and
            its terminal record) finalizes now — never re-decodes."""
            if req.out_tokens and eng._should_finish(req,
                                                     req.out_tokens[-1]):
                req.advance(RequestState.PREFILLING, now)
                req.first_token_at = now
                eng._finalize(req, RequestState.FINISHED)
                return True
            return False

        # -- snapshot graft: best effort; any damage degrades to journal-
        # only recovery (slower — full re-prefills — never incorrect)
        snap_step, state, meta = None, None, None
        if snapshot_dir is not None:
            try:
                step, tree = eng._ckpt.restore_latest(
                    {"state": eng._state_tree()})
                if step is not None:
                    raw = load_leaf(eng._ckpt.dir / f"step_{step:08d}",
                                    "meta")
                    meta = json.loads(np.asarray(raw, np.uint8)
                                      .tobytes().decode())
                    state = tree["state"]
                    snap_step = step
            except (CheckpointError, ValueError) as e:
                warnings.warn(f"snapshot restore failed ({e}); recovering "
                              f"from the journal alone")
                snap_step, state, meta = None, None, None
        if meta is not None and (meta.get("mode") != eng.mode
                                 or meta.get("seed") != eng.seed
                                 or meta.get("batch_slots") != eng.b
                                 or KVSpec.from_meta(meta) != eng.kv_spec):
            warnings.warn("snapshot belongs to a different engine config; "
                          "recovering from the journal alone")
            snap_step, state, meta = None, None, None
        if meta is not None and eng.mode == "paged":
            try:
                restored_alloc = PageAllocator.from_state(meta["alloc"])
            except (KeyError, ValueError, TypeError) as e:
                warnings.warn(f"snapshot allocator state is corrupt ({e}); "
                              f"recovering from the journal alone")
                snap_step, state, meta = None, None, None

        placed = set()
        if meta is not None:
            eng.counters = dict(meta["counters"])
            eng.slot_dead = [bool(x) for x in meta["slot_dead"]]
            eng.slot_fail_streak = [int(x) for x in meta["slot_fail_streak"]]
            if eng.mode == "paged":
                eng.alloc = restored_alloc
                eng.pool = state["pool"]
                if eng.mesh is not None:
                    # snapshot leaves come back host-committed; re-apply the
                    # replicated-then-data-sharded placement so the restored
                    # engine decodes under the same shardings it saved with
                    from repro.distributed import tp as tp_lib

                    eng.pool = tp_lib.shard_kv_pool(eng.pool, eng.mesh)
                eng.block_tables = np.asarray(state["block_tables"],
                                              np.int32).copy()
                eng.lengths = np.asarray(state["lengths"], np.int32).copy()
            elif eng.mode == "stacked":
                eng.stacked_cache = state["cache"]
            else:
                eng.slot_caches = list(state["slot_caches"])
            for i, s in enumerate(meta["slots"]):
                if s is None:
                    continue
                rid = int(s["rid"])
                k = len(col.tokens.get(rid, []))
                if rid in col.terminals:
                    # terminated after the snapshot — only its pages matter
                    eng._clear_slot_state(i, rid)
                elif (s["state"] == RequestState.DECODING.value
                        and s["n_out"] == k and k > 0):
                    req = make_req(rid)
                    if settle(req):
                        eng._clear_slot_state(i, rid)
                        placed.add(rid)
                        continue
                    # journal and snapshot agree: continue decoding in place
                    req.advance(RequestState.PREFILLING, now)
                    req.first_token_at = now
                    req.advance(RequestState.DECODING, now)
                    eng.slot_req[i] = req
                    if eng.mode == "paged":
                        eng._prefill_off[i] = int(s.get("prefill_off", 0))
                    placed.add(rid)
                elif (s["state"] == RequestState.PREFILLING.value
                        and s["n_out"] == 0 and k == 0):
                    # mid-prefill at the snapshot: the pool already holds
                    # chunks [0, prefill_off); resume the next chunk
                    req = make_req(rid)
                    req.advance(RequestState.PREFILLING, now)
                    eng.slot_req[i] = req
                    if eng.mode == "paged":
                        eng._prefill_off[i] = int(s.get("prefill_off", 0))
                    placed.add(rid)
                else:
                    # journal is AHEAD of the snapshot for this rid (tokens
                    # committed after it): the snapshot KV is stale — drop
                    # it and re-prefill prompt + journaled tokens
                    eng._clear_slot_state(i, rid)

        # everything pending and not resumed in place re-enqueues in the
        # original submission order (includes the journal-only path);
        # already-satisfied streams finalize instead
        requeued = []
        for rid in col.pending():
            if rid in placed:
                continue
            req = make_req(rid)
            if settle(req):
                placed.add(rid)
            else:
                eng.queue.append(req)
                requeued.append(rid)

        eng._journal(
            "recover", snapshot_step=snap_step, torn_tail=replay.torn_tail,
            resumed=sorted(placed), requeued=requeued)
        return eng

    # -- failure handling / lifecycle ---------------------------------------

    def _slot_failure(self, i: int, req: Request, e: BaseException):
        """Quarantine the slot (release it — paged mode frees the pages —
        and bump the failure streak; ``slot_failure_limit`` consecutive
        request failures kill it) and fail ONLY this request with the
        captured error."""
        kind, msg = _classify_error(e)
        self._release_slot(i)
        self.slot_fail_streak[i] += 1
        self.counters["slot_failures"] += 1
        if self.slot_fail_streak[i] >= self.slot_failure_limit:
            self.slot_dead[i] = True
        self._finalize(req, RequestState.FAILED, kind, msg)

    def _write_block_row(self, i: int, rid: int):
        row = np.zeros((self.pages_per_slot,), np.int32)
        pages = self.alloc.pages_of(rid)
        row[:len(pages)] = pages
        self.block_tables[i] = row

    def _release_slot(self, i: int):
        req = self.slot_req[i]
        self.slot_req[i] = None
        if req is not None:
            self._attempt_streak.pop(req.rid, None)
        if self.mode == "paged":
            # terminal transition returns the pages; freed pages may hold
            # stale values, which is safe because a new owner rewrites every
            # position below its length and the mask hides the rest
            if req is not None:
                self.alloc.free(req.rid)
            self.block_tables[i, :] = 0
            self.lengths[i] = 0
            self._prefill_off[i] = 0
        elif self.mode == "slots":
            self.slot_caches[i] = self._fresh_cache()
        # stacked: nothing to reset — admission overwrites the whole row

    def _fresh_cache(self):
        return model_lib.init_cache(self.cfg, 1, self.max_seq,
                                    dtype=jnp.float32, kv_spec=self.kv_spec)

    def _finalize(self, req: Request, status: RequestState,
                  error_kind: Optional[str] = None,
                  error: Optional[str] = None):
        self._attempt_streak.pop(req.rid, None)
        req.error_kind = error_kind
        req.error = error
        # WAL: the terminal record is durable before it becomes visible in
        # self.records — and a rid terminates in the journal exactly once,
        # even if it was already terminal at restore time
        if (self.journal is not None and req.rid in self._journaled_submits
                and req.rid not in self._journaled_terminals):
            self._journaled_terminals.add(req.rid)
            self.journal.append(
                "terminal", rid=req.rid, status=status.value,
                error_kind=(None if error_kind is None else str(error_kind)),
                error=error, retries=req.retries,
                n_tokens=len(req.out_tokens))
        req.advance(status, self.clock())
        self.records[req.rid] = RequestRecord.from_request(req)
        self.counters[status.value] = self.counters.get(status.value, 0) + 1

    def _expire_deadlines(self) -> bool:
        now = self.clock()
        progressed = False
        for req in [q for q in self.queue]:
            at = req.deadline_at()
            if at is not None and now >= at:
                self.queue.remove(req)
                self._finalize(req, RequestState.TIMED_OUT,
                               ErrorKind.DEADLINE,
                               f"deadline ({req.deadline_s:.3f}s) expired "
                               f"while queued")
                progressed = True
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            at = req.deadline_at()
            if at is not None and now >= at:
                self._release_slot(i)
                self._finalize(req, RequestState.TIMED_OUT,
                               ErrorKind.DEADLINE,
                               f"deadline ({req.deadline_s:.3f}s) expired "
                               f"after {len(req.out_tokens)} tokens")
                progressed = True
        return progressed

    def _stall_reason(self) -> Optional[str]:
        pending = bool(self.queue) or any(r is not None for r in self.slot_req)
        if pending and all(self.slot_dead):
            return (f"all {self.b} slots dead "
                    f"(slot_failure_limit={self.slot_failure_limit}) with "
                    f"{len(self.queue)} request(s) still queued")
        if self._steps_since_progress > self.stall_patience:
            return (f"no progress for {self._steps_since_progress} steps "
                    f"(stall_patience={self.stall_patience})")
        return None

    def _drain_unfinished(self, kind: str, msg: str):
        """Every request still queued or in a slot becomes a TIMED_OUT
        record — nothing silently vanishes from ``run()``'s return."""
        for i, req in enumerate(self.slot_req):
            if req is not None:
                self._release_slot(i)
                self._finalize(req, RequestState.TIMED_OUT, kind,
                               f"{msg}; in flight with "
                               f"{len(req.out_tokens)} token(s)")
        while self.queue:
            req = self.queue.pop(0)
            self._finalize(req, RequestState.TIMED_OUT, kind,
                           f"{msg}; still queued")
