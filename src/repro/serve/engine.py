"""Batched serving engine (slot-based continuous batching) with a hardened
request lifecycle.

A fixed pool of B slots shares one jitted decode_step; requests are admitted
into free slots (prefill writes their prompt into the slot's cache region),
decode steps advance ALL active slots together, finished slots are freed and
refilled from the queue — the standard continuous-batching pattern, sized for
the W4A4+LRC quantized model this framework serves.

On top of the happy path, the engine enforces the request lifecycle in
``serve/lifecycle.py``:

- **Admission control.**  ``submit()`` validates prompts (length vs.
  ``max_seq``, token ids vs. the vocab, positive token budget, positive
  deadline, unique rid) and enforces a bounded queue with a reject policy
  — bad input yields a ``REJECTED`` record instead of corrupting a slot
  cache deep inside prefill.
- **Failure isolation.**  Prefill/decode/sampling for one slot runs
  guarded: an exception or non-finite logits (NaN/Inf from quantized
  activation blow-ups) fails ONLY that request.  The step is retried up
  to ``max_retries`` with exponential backoff, then the slot is
  quarantined (cache reset, failure streak bumped — ``slot_failure_limit``
  consecutive request failures kill the slot) and a ``FAILED`` record with
  the captured error is emitted.  Slot caches are per-slot and never
  shared, so one request's corruption cannot leak into another's tokens.
- **Deadlines & budgets.**  Per-request wall-clock deadlines (checked
  while queued AND in flight) and token budgets; ``cancel(rid)`` works on
  queued and in-flight requests.
- **Liveness.**  ``health()`` snapshots slot states, queue depth,
  retry/failure counters and steps-since-progress; a stall watchdog
  aborts a wedged ``run()`` (e.g. every slot dead with work still queued)
  with a diagnosable ``stall_report`` instead of spinning to
  ``max_steps``.  When the step budget trips with requests still in
  flight, they are returned as ``TIMED_OUT`` records, not dropped.
- **Fault injection.**  A ``serve/faults.py`` injector can be threaded in
  (``injector=``) to fire deterministic exceptions / NaN bursts / slow
  steps / cache corruption at the phase boundaries — the chaos suite uses
  it to prove the isolation contract.  The clock and sleep are injectable
  (``clock=``, ``sleep_fn=``) so deadline/backoff behavior is testable
  without real waiting.

``run()`` returns ``{rid: RequestRecord}`` — structured terminal records
(status, error kind, timings, token counts), not live request objects.

Sampling keys are derived per (rid, token index) via ``fold_in``, so a
request's output never depends on which slot it landed in, what else was
in flight, or how many retries other requests burned — that is what makes
"untargeted requests are bitwise identical under chaos" provable.

Single jitted decode signature ⇒ one compilation, shared process-wide per
config; per-slot positions are tracked host-side.  Works with FP or
quantized (QLinear) params.

Simplification vs. a paged server: each slot owns a contiguous max_seq cache
region (no paging); for the dry-run shapes that is the assigned cache layout
anyway.
"""

from __future__ import annotations

import functools
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as model_lib
from repro.serve.faults import FaultInjector, InjectedFault
from repro.serve.lifecycle import (Request, RequestRecord, RequestState,
                                   TERMINAL_STATES)
from repro.serve.sampling import NonFiniteLogitsError, sample_token


@functools.lru_cache(maxsize=16)
def _model_fns(cfg) -> Tuple[Callable, Callable]:
    """Per-config jitted prefill/decode, shared by every engine instance in
    the process (cfg is a hashable static dataclass) — N engines over the
    same config stop paying N compilations."""

    @jax.jit
    def _prefill(params, tokens, cache):
        return model_lib.prefill(cfg, params, {"tokens": tokens}, cache)

    @jax.jit
    def _decode(params, tokens, cache):
        return model_lib.decode_step(cfg, params, tokens, cache)

    return _prefill, _decode


def _classify_error(e: BaseException) -> Tuple[str, str]:
    if isinstance(e, InjectedFault):
        kind = "injected"
    elif isinstance(e, NonFiniteLogitsError):
        kind = "non_finite_logits"
    else:
        kind = "exception"
    msg = f"{type(e).__name__}: {e}"
    return kind, msg[:500]


class ServeEngine:
    def __init__(self, cfg, params, batch_slots: int = 4, max_seq: int = 256,
                 eos_id: Optional[int] = None, seed: int = 0,
                 kernel_impl: Optional[str] = "auto", ctx=None, *,
                 max_retries: int = 2, retry_backoff_s: float = 0.0,
                 queue_limit: Optional[int] = None,
                 queue_policy: str = "reject_new",
                 default_deadline_s: Optional[float] = None,
                 slot_failure_limit: int = 3, stall_patience: int = 64,
                 injector: Optional[FaultInjector] = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep_fn: Callable[[float], None] = time.sleep):
        assert cfg.family in ("dense", "vlm", "ssm", "hybrid", "moe"), cfg.family
        if queue_policy not in ("reject_new", "drop_oldest"):
            raise ValueError(f"unknown queue_policy {queue_policy!r}; "
                             f"one of ('reject_new', 'drop_oldest')")
        if max_retries < 0 or retry_backoff_s < 0:
            raise ValueError("max_retries and retry_backoff_s must be >= 0")
        # Decode runs W4A4+LRC through the pallas kernels (single-kernel
        # fused forward at decode/mixed shapes, prologue→GEMM chain past the
        # VMEM gate) whenever a compiled backend is attached; "auto" keeps
        # the calibrated impl on CPU where the pallas interpreter would only
        # slow the reference semantics down.  Pass an explicit impl
        # ("fused"/"pallas"/"int8"/"sim") to force a path.
        #
        # ``ctx`` is this engine's KernelContext (block table, VMEM budgets,
        # default kernel path, per-layer plan overrides).  It is attached to
        # every QLinear leaf as pytree-static metadata, so two engines in
        # one process can serve under DIFFERENT plan tables/budgets without
        # touching any global; None uses the process-default context.
        # kernel_impl=None attaches the ctx WITHOUT touching the calibrated
        # impls.
        if kernel_impl is not None or ctx is not None:
            from repro.quant.qlinear import retag_qlinear_impl

            params = retag_qlinear_impl(params, kernel_impl, ctx=ctx)
        self.ctx = ctx
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.base_key = jax.random.PRNGKey(seed)

        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.queue_limit = queue_limit
        self.queue_policy = queue_policy
        self.default_deadline_s = default_deadline_s
        self.slot_failure_limit = slot_failure_limit
        self.stall_patience = stall_patience
        self.injector = injector
        self.clock = clock
        self.sleep_fn = sleep_fn

        # per-slot caches (B=1 each) so slots admit/evict independently and
        # one request's corruption can never leak into a neighbor
        self.slot_caches: List = [self._fresh_cache() for _ in range(batch_slots)]
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_fail_streak: List[int] = [0] * batch_slots
        self.slot_dead: List[bool] = [False] * batch_slots
        self.queue: List[Request] = []
        self.records: Dict[int, RequestRecord] = {}
        self.counters: Dict[str, int] = {
            "submitted": 0, "admitted": 0, "steps": 0, "retries": 0,
            "finished": 0, "failed": 0, "rejected": 0, "cancelled": 0,
            "timed_out": 0, "slot_failures": 0,
        }
        self._steps_since_progress = 0
        self.stall_report: Optional[dict] = None

        self._prefill, self._decode = _model_fns(cfg)

    # -- public API ---------------------------------------------------------

    def submit(self, req: Request) -> bool:
        """Validate and enqueue; returns False (with a ``REJECTED`` record)
        when admission control refuses the request."""
        now = self.clock()
        req.submitted_at = now
        if req.deadline_s is None:
            req.deadline_s = self.default_deadline_s
        err = self._validate(req)
        if err is not None:
            if err[0] == "duplicate_rid":
                # a second record cannot be indexed under the same rid —
                # reject the duplicate in place, leaving the original
                # request's record/queue entry untouched
                req.error_kind, req.error = err
                req.advance(RequestState.REJECTED, now)
                self.counters["rejected"] += 1
                return False
            self._finalize(req, RequestState.REJECTED, *err)
            return False
        if self.queue_limit is not None and len(self.queue) >= self.queue_limit:
            if self.queue_policy == "drop_oldest":
                oldest = self.queue.pop(0)
                self._finalize(oldest, RequestState.REJECTED, "queue_evicted",
                               f"evicted by rid {req.rid} under drop_oldest "
                               f"(queue_limit={self.queue_limit})")
            else:
                self._finalize(req, RequestState.REJECTED, "queue_full",
                               f"queue at limit {self.queue_limit}")
                return False
        self.counters["submitted"] += 1
        self.queue.append(req)
        return True

    def cancel(self, rid: int) -> bool:
        """Cancel a queued or in-flight request; False if unknown/terminal."""
        for qi, req in enumerate(self.queue):
            if req.rid == rid:
                self.queue.pop(qi)
                self._finalize(req, RequestState.CANCELLED, "cancelled",
                               "cancelled while queued")
                return True
        for i, req in enumerate(self.slot_req):
            if req is not None and req.rid == rid:
                # applied immediately: free the slot, keep emitted tokens
                self._release_slot(i)
                self._finalize(req, RequestState.CANCELLED, "cancelled",
                               "cancelled in flight")
                return True
        return False

    def run(self, max_steps: int = 1024) -> Dict[int, RequestRecord]:
        """Drive until queue + slots drain; never raises for per-request
        failures.  Exhausting ``max_steps`` returns the survivors as
        ``TIMED_OUT`` records; a detected stall aborts with
        ``self.stall_report`` set."""
        self.stall_report = None
        for _ in range(max_steps):
            self.counters["steps"] += 1
            progressed = self._expire_deadlines()
            progressed |= self._admit()
            if not any(r is not None for r in self.slot_req) and not self.queue:
                break
            progressed |= self._step()
            self._steps_since_progress = (
                0 if progressed else self._steps_since_progress + 1)
            stall = self._stall_reason()
            if stall is not None:
                self.stall_report = {"reason": stall, "health": self.health()}
                self._drain_unfinished("stall", f"run() aborted: {stall}")
                return self.records
        else:
            self._drain_unfinished(
                "step_limit", f"engine step budget ({max_steps}) exhausted")
        return self.records

    def health(self) -> dict:
        """Live snapshot: slot states, queue depth, counters, liveness."""
        slots = []
        for i in range(self.b):
            req = self.slot_req[i]
            slots.append({
                "slot": i,
                "state": ("dead" if self.slot_dead[i]
                          else req.state.value if req is not None else "idle"),
                "rid": None if req is None else req.rid,
                "tokens": 0 if req is None else len(req.out_tokens),
                "fail_streak": self.slot_fail_streak[i],
            })
        return {
            "slots": slots,
            "queue_depth": len(self.queue),
            "dead_slots": sum(self.slot_dead),
            "counters": dict(self.counters),
            "steps_since_progress": self._steps_since_progress,
            "stalled": self.stall_report is not None,
        }

    # -- admission ----------------------------------------------------------

    def _validate(self, req: Request) -> Optional[Tuple[str, str]]:
        if (req.rid in self.records
                or any(q.rid == req.rid for q in self.queue)
                or any(r is not None and r.rid == req.rid for r in self.slot_req)):
            return ("duplicate_rid", f"rid {req.rid} already known to the engine")
        prompt = np.asarray(req.prompt)
        if prompt.ndim != 1 or prompt.size == 0:
            return ("empty_prompt", f"prompt must be a non-empty 1-D token "
                                    f"array, got shape {prompt.shape}")
        if not np.issubdtype(prompt.dtype, np.integer):
            return ("bad_token_ids", f"prompt dtype {prompt.dtype} is not integral")
        if prompt.min() < 0 or prompt.max() >= self.cfg.vocab_size:
            return ("bad_token_ids",
                    f"token ids outside [0, {self.cfg.vocab_size})")
        if len(prompt) >= self.max_seq:
            # an oversized prompt would overflow the slot's contiguous
            # max_seq cache region deep inside prefill — refuse it here
            return ("prompt_too_long",
                    f"prompt length {len(prompt)} >= max_seq {self.max_seq}")
        if req.max_new_tokens < 1:
            return ("bad_token_budget",
                    f"max_new_tokens must be >= 1, got {req.max_new_tokens}")
        if req.deadline_s is not None and req.deadline_s <= 0:
            return ("bad_deadline", f"deadline_s must be > 0, got {req.deadline_s}")
        return None

    def _admit(self) -> bool:
        progressed = False
        for i in range(self.b):
            # a slot that finishes/fails at prefill frees up immediately,
            # so keep pulling from the queue until it sticks or the queue
            # (or the slot's life) runs out
            while (not self.slot_dead[i] and self.slot_req[i] is None
                   and self.queue):
                req = self.queue.pop(0)
                progressed = True
                self._admit_one(i, req)
        return progressed

    def _admit_one(self, i: int, req: Request):
        req.advance(RequestState.PREFILLING, self.clock())
        self.counters["admitted"] += 1
        cache = self._fresh_cache()
        toks = jnp.asarray(np.asarray(req.prompt)[None, :], jnp.int32)
        try:
            tok, cache = self._attempt(req, "prefill", self._prefill, toks, cache)
        except Exception as e:  # isolated: fails only this request
            self._slot_failure(i, req, e)
            return
        self.slot_caches[i] = cache
        self.slot_fail_streak[i] = 0
        req.out_tokens.append(tok)
        req.first_token_at = self.clock()
        # the prefill-sampled token obeys the SAME termination predicate as
        # decode tokens: max_new_tokens=1 means one token, and an EOS
        # emitted at prefill ends the request
        if self._should_finish(req, tok):
            self._release_slot(i)
            self._finalize(req, RequestState.FINISHED)
        else:
            req.advance(RequestState.DECODING, self.clock())
            self.slot_req[i] = req

    # -- stepping -----------------------------------------------------------

    def _step(self) -> bool:
        progressed = False
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            last = jnp.asarray([[req.out_tokens[-1]]], jnp.int32)
            try:
                tok, cache = self._attempt(req, "decode", self._decode, last,
                                           self.slot_caches[i])
            except Exception as e:  # isolated: fails only this request
                self._slot_failure(i, req, e)
                progressed = True  # a terminal record IS progress
                continue
            self.slot_caches[i] = cache
            self.slot_fail_streak[i] = 0
            req.out_tokens.append(tok)
            progressed = True
            if self._should_finish(req, tok):
                self._release_slot(i)
                self._finalize(req, RequestState.FINISHED)
        return progressed

    def _attempt(self, req: Request, phase: str, fn, tokens, cache):
        """One guarded forward+sample for one request, with bounded retries
        and exponential backoff.  Nothing is committed on failure — the
        caller's cache reference is untouched, so a retry restarts from
        clean state.  Raises the last error once the budget is spent."""
        attempt = 0
        while True:
            try:
                fault = (self.injector.poll(req.rid, phase)
                         if self.injector is not None else None)
                cache_in = cache
                if fault is not None:
                    if fault.kind == "slow_step":
                        self.injector.sleep(fault.seconds)
                    elif fault.kind == "exception":
                        raise InjectedFault(
                            f"injected {phase} exception for rid {req.rid}")
                    elif fault.kind == "cache_corruption":
                        cache_in = self.injector.corrupt_cache(cache)
                logits, new_cache = fn(self.params, tokens, cache_in)
                if fault is not None and fault.kind in ("nan_logits", "inf_logits"):
                    logits = self.injector.corrupt_logits(logits, fault.kind)
                sfault = (self.injector.poll(req.rid, "sampling")
                          if self.injector is not None else None)
                if sfault is not None:
                    if sfault.kind == "slow_step":
                        self.injector.sleep(sfault.seconds)
                    elif sfault.kind == "exception":
                        raise InjectedFault(
                            f"injected sampling exception for rid {req.rid}")
                tok = int(self._sample(req, logits[:, -1])[0])
                return tok, new_cache
            except Exception:
                attempt += 1
                if attempt > self.max_retries:
                    raise
                req.retries += 1
                self.counters["retries"] += 1
                if self.retry_backoff_s > 0:
                    self.sleep_fn(self.retry_backoff_s * (2 ** (attempt - 1)))

    def _sample(self, req: Request, logits):
        # key depends only on (engine seed, rid, token index): a request's
        # tokens are invariant to slot placement, co-tenants, and retries —
        # the property the chaos suite's bitwise-parity asserts rely on
        key = jax.random.fold_in(
            jax.random.fold_in(self.base_key, req.rid), len(req.out_tokens))
        return sample_token(logits, key, temperature=req.temperature,
                            check_finite=True)

    def _should_finish(self, req: Request, tok: int) -> bool:
        total = len(req.prompt) + len(req.out_tokens)
        return (
            len(req.out_tokens) >= req.max_new_tokens
            or (self.eos_id is not None and tok == self.eos_id)
            or total >= self.max_seq - 1
        )

    # -- failure handling / lifecycle ---------------------------------------

    def _slot_failure(self, i: int, req: Request, e: BaseException):
        """Quarantine the slot (reset its cache, bump the failure streak —
        ``slot_failure_limit`` consecutive request failures kill it) and
        fail ONLY this request with the captured error."""
        kind, msg = _classify_error(e)
        self._release_slot(i)
        self.slot_fail_streak[i] += 1
        self.counters["slot_failures"] += 1
        if self.slot_fail_streak[i] >= self.slot_failure_limit:
            self.slot_dead[i] = True
        self._finalize(req, RequestState.FAILED, kind, msg)

    def _release_slot(self, i: int):
        self.slot_req[i] = None
        self.slot_caches[i] = self._fresh_cache()

    def _fresh_cache(self):
        return model_lib.init_cache(self.cfg, 1, self.max_seq,
                                    dtype=jnp.float32)

    def _finalize(self, req: Request, status: RequestState,
                  error_kind: Optional[str] = None,
                  error: Optional[str] = None):
        req.error_kind = error_kind
        req.error = error
        req.advance(status, self.clock())
        self.records[req.rid] = RequestRecord.from_request(req)
        self.counters[status.value] = self.counters.get(status.value, 0) + 1

    def _expire_deadlines(self) -> bool:
        now = self.clock()
        progressed = False
        for req in [q for q in self.queue]:
            at = req.deadline_at()
            if at is not None and now >= at:
                self.queue.remove(req)
                self._finalize(req, RequestState.TIMED_OUT, "deadline",
                               f"deadline ({req.deadline_s:.3f}s) expired "
                               f"while queued")
                progressed = True
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            at = req.deadline_at()
            if at is not None and now >= at:
                self._release_slot(i)
                self._finalize(req, RequestState.TIMED_OUT, "deadline",
                               f"deadline ({req.deadline_s:.3f}s) expired "
                               f"after {len(req.out_tokens)} tokens")
                progressed = True
        return progressed

    def _stall_reason(self) -> Optional[str]:
        pending = bool(self.queue) or any(r is not None for r in self.slot_req)
        if pending and all(self.slot_dead):
            return (f"all {self.b} slots dead "
                    f"(slot_failure_limit={self.slot_failure_limit}) with "
                    f"{len(self.queue)} request(s) still queued")
        if self._steps_since_progress > self.stall_patience:
            return (f"no progress for {self._steps_since_progress} steps "
                    f"(stall_patience={self.stall_patience})")
        return None

    def _drain_unfinished(self, kind: str, msg: str):
        """Every request still queued or in a slot becomes a TIMED_OUT
        record — nothing silently vanishes from ``run()``'s return."""
        for i, req in enumerate(self.slot_req):
            if req is not None:
                self._release_slot(i)
                self._finalize(req, RequestState.TIMED_OUT, kind,
                               f"{msg}; in flight with "
                               f"{len(req.out_tokens)} token(s)")
        while self.queue:
            req = self.queue.pop(0)
            self._finalize(req, RequestState.TIMED_OUT, kind,
                           f"{msg}; still queued")
