"""Deterministic fault injection for the serving engine.

A :class:`FaultInjector` is handed to ``ServeEngine(injector=...)`` and
polled at the three request-phase boundaries (``prefill`` / ``decode`` /
``sampling``).  Every fault fires on a deterministic schedule — the n-th
time a given (rid, phase) boundary is hit — so a chaos run is exactly
reproducible: same specs (or same seed via :meth:`FaultInjector.sample`),
same engine seed, same records, and the untargeted requests' outputs are
bitwise identical to a fault-free run.

Fault kinds:

- ``exception``         raise :class:`InjectedFault` before the forward
- ``nan_logits``        overwrite a deterministic slice of the logits with NaN
- ``inf_logits``        same, with +Inf
- ``slow_step``         burn ``seconds`` of (injectable) wall clock — pairs
                        with per-request deadlines to produce TIMED_OUT
- ``cache_corruption``  poison every float leaf of the slot cache fed to
                        the forward (NaN), surfacing as non-finite logits
                        at the decode boundary — LQER-style activation
                        blow-ups in miniature
- ``process_crash``     raise :class:`SimulatedCrash` (a ``BaseException``)
                        at the seeded (rid, phase, hit) point — it escapes
                        the engine's per-request ``except Exception``
                        isolation ON PURPOSE, killing ``run()`` mid-step
                        exactly like a process death.  Pairs with the
                        write-ahead journal + snapshots (serve/journal.py,
                        ``ServeEngine.restore``) to drive the crash-chaos
                        recovery harness.

The low-rank-corrected W4A4 regime this repo serves is exactly where
activation outliers stress quantized numerics, so ``nan_logits`` /
``cache_corruption`` are not hypothetical failure shapes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

FAULT_KINDS = ("exception", "nan_logits", "inf_logits", "slow_step",
               "cache_corruption", "process_crash")
FAULT_PHASES = ("prefill", "decode", "sampling")
# sampling sees a token id, not logits or a cache — only control-flow
# faults make sense there
_SAMPLING_KINDS = ("exception", "slow_step", "process_crash")
# hard kinds deterministically fail a request once they outlast the retry
# budget; slow_step only fails via a deadline, and process_crash kills the
# whole engine rather than failing one request, so neither is sampled by
# the K-of-N chaos targeting
HARD_KINDS = ("exception", "nan_logits", "inf_logits", "cache_corruption")


class InjectedFault(RuntimeError):
    """Raised by the injector at an ``exception`` fault site."""


class SimulatedCrash(BaseException):
    """A simulated process death, raised at a ``process_crash`` fault site.

    Deliberately a ``BaseException``: the engine's per-request isolation
    catches ``Exception``, so a simulated crash — like a real SIGKILL —
    cannot be retried, quarantined, or converted into a FAILED record.  It
    unwinds straight out of ``ServeEngine.run()`` mid-step, leaving only
    what the write-ahead journal and the last snapshot persisted; the
    crash-chaos harness then proves ``ServeEngine.restore`` finishes every
    request exactly once, bitwise identical to an uninterrupted run."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: fire ``repeat`` consecutive times starting at
    the ``at_call``-th hit of the (rid, phase) boundary."""

    kind: str
    phase: str
    rid: int
    at_call: int = 0
    repeat: int = 1
    seconds: float = 0.0  # slow_step only

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; one of {FAULT_KINDS}")
        if self.phase not in FAULT_PHASES:
            raise ValueError(f"unknown fault phase {self.phase!r}; one of {FAULT_PHASES}")
        if self.phase == "sampling" and self.kind not in _SAMPLING_KINDS:
            raise ValueError(
                f"fault kind {self.kind!r} cannot fire at the sampling "
                f"boundary (no logits/cache there); use one of {_SAMPLING_KINDS}")
        if self.at_call < 0 or self.repeat < 1:
            raise ValueError(f"need at_call >= 0 and repeat >= 1, got "
                             f"at_call={self.at_call} repeat={self.repeat}")
        if self.kind == "slow_step" and self.seconds < 0:
            raise ValueError(f"slow_step needs seconds >= 0, got {self.seconds}")


class FaultInjector:
    """Seed-/schedule-driven fault source, polled by the engine.

    ``poll(rid, phase)`` increments the (rid, phase) hit counter and
    returns the matching :class:`FaultSpec` (or None); the engine applies
    the fault at the right point of the step.  Fired faults are logged in
    ``self.fired`` as ``(spec, hit_index)`` for post-mortem asserts.
    """

    def __init__(self, specs: Sequence[FaultSpec],
                 sleep_fn: Callable[[float], None] = time.sleep):
        self.specs: Tuple[FaultSpec, ...] = tuple(specs)
        self.sleep_fn = sleep_fn
        self._hits: Dict[Tuple[int, str], int] = {}
        self.fired: List[Tuple[FaultSpec, int]] = []

    @classmethod
    def sample(cls, rids: Sequence[int], k: int, seed: int,
               kinds: Sequence[str] = HARD_KINDS, phase: str = "decode",
               at_call_max: int = 3, repeat: int = 8, seconds: float = 0.05,
               sleep_fn: Callable[[float], None] = time.sleep) -> "FaultInjector":
        """Deterministically target ``k`` of ``rids``: the seed fixes which
        requests are hit, with which kind, and on which call.  ``repeat``
        defaults high enough to outlast any sane retry budget, so a
        sampled hard fault reliably FAILs its request."""
        if not 0 <= k <= len(rids):
            raise ValueError(f"need 0 <= k <= {len(rids)}, got {k}")
        rng = np.random.default_rng(seed)
        targets = sorted(int(r) for r in
                         rng.choice(np.asarray(list(rids)), size=k, replace=False))
        specs = [
            FaultSpec(kind=str(rng.choice(list(kinds))), phase=phase, rid=rid,
                      at_call=int(rng.integers(0, max(1, at_call_max))),
                      repeat=repeat, seconds=seconds)
            for rid in targets
        ]
        return cls(specs, sleep_fn=sleep_fn)

    @property
    def targets(self) -> frozenset:
        return frozenset(s.rid for s in self.specs)

    def poll(self, rid: int, phase: str) -> Optional[FaultSpec]:
        n = self._hits.get((rid, phase), 0)
        self._hits[(rid, phase)] = n + 1
        for spec in self.specs:
            if (spec.rid == rid and spec.phase == phase
                    and spec.at_call <= n < spec.at_call + spec.repeat):
                self.fired.append((spec, n))
                return spec
        return None

    def sleep(self, seconds: float):
        self.sleep_fn(seconds)

    # -- fault payloads ------------------------------------------------------

    @staticmethod
    def corrupt_logits(logits, kind: str):
        """A deterministic non-finite burst: every 7th vocab entry."""
        fill = float("nan") if kind == "nan_logits" else float("inf")
        return jnp.asarray(logits).at[..., ::7].set(fill)

    @staticmethod
    def corrupt_cache(cache):
        """Poison every float leaf (NaN everywhere) — integer leaves such
        as the cache offset keep their values so the corruption surfaces
        as non-finite activations, not a shape/index error."""
        def poison(leaf):
            leaf = jnp.asarray(leaf)
            if jnp.issubdtype(leaf.dtype, jnp.inexact):
                return jnp.full_like(leaf, float("nan"))
            return leaf
        return jax.tree.map(poison, cache)

    @staticmethod
    def corrupt_pages(cache, page_ids):
        """Poison ONLY the given pages of a paged KV pool (leaves shaped
        (L, NP, P, ...), page id on axis 1) — the page-scoped analogue of
        :meth:`corrupt_cache` for the shared pool, where poisoning every
        leaf would corrupt co-tenant requests and break the isolation the
        fault is meant to test."""
        ids = jnp.asarray(list(page_ids), jnp.int32)

        def poison(leaf):
            leaf = jnp.asarray(leaf)
            if jnp.issubdtype(leaf.dtype, jnp.inexact) and leaf.ndim >= 2:
                rows = jnp.full((leaf.shape[0], ids.shape[0]) + leaf.shape[2:],
                                float("nan"), leaf.dtype)
                return leaf.at[:, ids].set(rows)
            return leaf
        return jax.tree.map(poison, cache) if len(ids) else cache

    @staticmethod
    def corrupt_rows(cache, row: int):
        """Poison one batch row (axis 1 of every stacked leaf) — the
        per-request fault surface for stacked recurrent-state caches."""
        def poison(leaf):
            leaf = jnp.asarray(leaf)
            if jnp.issubdtype(leaf.dtype, jnp.inexact) and leaf.ndim >= 2:
                nan_row = jnp.full((leaf.shape[0],) + leaf.shape[2:],
                                   float("nan"), leaf.dtype)
                return leaf.at[:, row].set(nan_row)
            return leaf
        return jax.tree.map(poison, cache)

    def summary(self) -> Dict[str, object]:
        return {
            "specs": len(self.specs),
            "targets": sorted(self.targets),
            "fired": [(s.kind, s.phase, s.rid, n) for s, n in self.fired],
        }
