"""Paged-KV bookkeeping for the serving engine: a free-list page allocator
plus per-request block tables (the Lightllm/vLLM layout).

The engine owns ONE page pool per model (``model.init_paged_cache``); this
module owns which request holds which pages.  Pages are fixed-size
(``page_size`` token slots each); a request's KV for absolute positions
``[j*page_size, (j+1)*page_size)`` lives in the j-th page of its page list.
Pages are allocated lazily — prompt pages at admission, one page per
decode-boundary crossing — and freed as a unit when the request reaches a
terminal state.

Invariants (enforced by ``check()``, property-tested in
``tests/test_serve_paging.py``):

- **Conservation.**  Every page id in ``[1, num_pages)`` is at all times
  either on the free list or in exactly one request's page list:
  ``free_pages + sum(per-request pages) == capacity``.
- **No double allocation.**  A page never appears in two page lists, twice
  in one list, or on the free list while allocated.
- **Null page.**  Page 0 is reserved and never allocated; model-side writes
  for padding / inactive slots are redirected there, so ``capacity ==
  num_pages - 1``.
- **No double free.**  Freeing an unknown rid is a no-op returning 0;
  freeing twice cannot return a page to the free list twice.
- **Admission accounting.**  ``pages_for(n)`` is the exact number of pages
  a request holding ``n`` tokens needs; ``used_pages`` equals the sum of
  per-request page counts, which is what admission control charges against
  ``free_pages``.
- **Scale-sidecar lockstep** (``sidecar=True``, quantized KV specs).  A
  quantized pool carries f32 scale planes (``k_scale`` / ``v_scale``)
  indexed by the SAME page ids as the data pages — there is no second id
  space.  The allocator mirrors its full accounting (free list AND per-
  request lists) for the sidecar and ``check()`` asserts the two never
  diverge: a scale plane can never be freed, aliased or double-allocated
  independently of its data page.
"""

from __future__ import annotations

from typing import Dict, List, Optional

NULL_PAGE = 0


class PageAllocator:
    """Free-list allocator over ``num_pages - 1`` usable pages.

    The free list is LIFO (a stack), which deliberately recycles pages hot
    and out of order — the chaos suite's bitwise-parity asserts prove that
    outputs never depend on WHICH pages a request lands on."""

    def __init__(self, num_pages: int, page_size: int, sidecar: bool = False):
        if num_pages < 2:
            raise ValueError(f"num_pages must be >= 2 (page 0 is the "
                             f"reserved null page), got {num_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, NULL_PAGE, -1))
        self._owned: Dict[int, List[int]] = {}
        # quantized pools: mirrored accounting for the scale-plane sidecar
        # (same page ids, tracked independently so check() can prove the
        # two pools never drift)
        self.sidecar = bool(sidecar)
        self._side_free: Optional[List[int]] = (
            list(self._free) if self.sidecar else None)
        self._side_owned: Optional[Dict[int, List[int]]] = (
            {} if self.sidecar else None)

    # -- accounting ---------------------------------------------------------

    @property
    def capacity(self) -> int:
        """Total allocatable pages (excludes the null page)."""
        return self.num_pages - 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        """Sum of per-request page counts == capacity - free_pages."""
        return sum(len(v) for v in self._owned.values())

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` token slots (ceil division)."""
        return -(-max(n_tokens, 0) // self.page_size)

    def pages_of(self, rid: int) -> List[int]:
        """The request's page list (a copy), prompt-order."""
        return list(self._owned.get(rid, ()))

    def holds(self, rid: int) -> int:
        return len(self._owned.get(rid, ()))

    # -- alloc / free -------------------------------------------------------

    def ensure(self, rid: int, n_tokens: int) -> Optional[List[int]]:
        """Grow ``rid``'s page list to cover ``n_tokens`` token positions.

        Returns the (possibly empty) list of newly allocated page ids, or
        None — with NO partial allocation committed — if the free list
        cannot cover the growth.  Idempotent: ensuring an already-covered
        length allocates nothing."""
        need = self.pages_for(n_tokens) - self.holds(rid)
        if need <= 0:
            return []
        if need > len(self._free):
            return None
        fresh = [self._free.pop() for _ in range(need)]
        self._owned.setdefault(rid, []).extend(fresh)
        if self.sidecar:
            side = [self._side_free.pop() for _ in range(need)]
            self._side_owned.setdefault(rid, []).extend(side)
        return fresh

    def free(self, rid: int) -> int:
        """Return ALL of ``rid``'s pages to the free list (the terminal-state
        transition).  Unknown rid is a no-op; returns the page count freed.

        Raises ``ValueError`` if any page being returned is already on the
        free list or out of range — pushing such a page would silently break
        the conservation invariant (``free + held == capacity``) the fuzz
        suite checks, and the very next double allocation would hand one
        physical page to two requests.  This can only happen through state
        corruption (e.g. a damaged snapshot restored into ``from_state``),
        so it is an error, never a no-op."""
        pages = self._owned.get(rid)
        if not pages:
            self._owned.pop(rid, None)
            if self.sidecar:
                self._side_owned.pop(rid, None)
            return 0
        on_free = set(self._free)
        bad = [p for p in pages
               if p in on_free or not NULL_PAGE < p < self.num_pages]
        if bad:
            raise ValueError(
                f"double free: rid {rid} page list {pages} contains page(s) "
                f"{bad} already on the free list or out of range "
                f"[1, {self.num_pages}) — allocator state is corrupt")
        if self.sidecar:
            # validate the sidecar BEFORE either pool mutates — a failed
            # free must not leave data and scale accounting half-applied
            spages = self._side_owned.get(rid, [])
            on_side_free = set(self._side_free)
            sbad = [p for p in spages
                    if p in on_side_free or not NULL_PAGE < p < self.num_pages]
            if sbad:
                raise ValueError(
                    f"scale-plane double free: rid {rid} sidecar list "
                    f"{spages} contains page(s) {sbad} already free or out "
                    f"of range — sidecar state is corrupt")
            self._side_owned.pop(rid, None)
            self._side_free.extend(reversed(spages))
        del self._owned[rid]
        self._free.extend(reversed(pages))
        return len(pages)

    # -- snapshot / restore -------------------------------------------------

    def to_state(self) -> dict:
        """JSON-serializable snapshot of the full allocator state (free
        list order included — LIFO recycling survives a restore)."""
        state = {
            "num_pages": self.num_pages,
            "page_size": self.page_size,
            "free": list(self._free),
            "owned": {str(rid): list(pages)
                      for rid, pages in self._owned.items()},
            "sidecar": self.sidecar,
        }
        if self.sidecar:
            state["side_free"] = list(self._side_free)
            state["side_owned"] = {str(rid): list(pages)
                                   for rid, pages in self._side_owned.items()}
        return state

    @classmethod
    def from_state(cls, state: dict) -> "PageAllocator":
        """Rebuild an allocator from :meth:`to_state`, validating every
        conservation invariant — a corrupt snapshot raises ``ValueError``
        instead of silently double-allocating pages later.  (Pre-sidecar
        snapshots carry no ``sidecar`` key and restore as plain
        allocators.)"""
        alloc = cls(int(state["num_pages"]), int(state["page_size"]),
                    sidecar=bool(state.get("sidecar", False)))
        alloc._free = [int(p) for p in state["free"]]
        alloc._owned = {int(rid): [int(p) for p in pages]
                        for rid, pages in state["owned"].items()}
        if alloc.sidecar:
            alloc._side_free = [int(p) for p in state["side_free"]]
            alloc._side_owned = {int(rid): [int(p) for p in pages]
                                 for rid, pages in state["side_owned"].items()}
        try:
            alloc.check()
        except AssertionError as e:
            raise ValueError(f"corrupt allocator snapshot: {e}") from None
        return alloc

    # -- diagnostics --------------------------------------------------------

    def check(self) -> None:
        """Assert every invariant in the module docstring (test hook)."""
        seen = set(self._free)
        assert len(seen) == len(self._free), "free list holds duplicates"
        assert NULL_PAGE not in seen, "null page on the free list"
        for rid, pages in self._owned.items():
            assert pages, f"rid {rid} owns an empty page list"
            for p in pages:
                assert 0 < p < self.num_pages, f"page {p} out of range"
                assert p not in seen, f"page {p} owned twice (rid {rid})"
                seen.add(p)
        assert len(seen) == self.capacity, \
            f"page leak: {self.capacity - len(seen)} pages unaccounted"
        assert self.free_pages + self.used_pages == self.capacity
        if self.sidecar:
            # the sidecar must satisfy the SAME alias/double-free structure…
            sseen = set(self._side_free)
            assert len(sseen) == len(self._side_free), \
                "scale-plane free list holds duplicates"
            assert NULL_PAGE not in sseen, "null page on scale-plane free list"
            for rid, pages in self._side_owned.items():
                for p in pages:
                    assert 0 < p < self.num_pages, \
                        f"scale plane {p} out of range"
                    assert p not in sseen, \
                        f"scale plane {p} owned twice (rid {rid})"
                    sseen.add(p)
            assert len(sseen) == self.capacity, "scale-plane leak"
            # …and stay in LOCKSTEP with the page pool: same free-list
            # order (LIFO recycling is part of the state) and identical
            # per-request page lists
            assert self._side_free == self._free, \
                "scale-plane free list diverged from the page free list"
            assert self._side_owned == self._owned, \
                "scale-plane ownership diverged from page ownership"

    def stats(self) -> dict:
        return {
            "page_size": self.page_size,
            "capacity": self.capacity,
            "free": self.free_pages,
            "used": self.used_pages,
            "sidecar": self.sidecar,
            "per_request": {rid: len(v) for rid, v in self._owned.items()},
        }
