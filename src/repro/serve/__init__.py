"""Public serving API.

The error-kind taxonomy every ``RequestRecord.error_kind`` draws from is
:class:`~repro.serve.lifecycle.ErrorKind` — a documented str-enum (members
compare equal to their literal values, e.g. ``ErrorKind.DEADLINE ==
"deadline"``), with :data:`~repro.serve.lifecycle.RETRYABLE_KINDS` marking
the subset the engine retries before failing a request.

Crash safety lives in :mod:`repro.serve.journal` (the write-ahead request
journal) plus ``ServeEngine.snapshot`` / ``ServeEngine.restore``; the
``process_crash`` fault kind (:class:`~repro.serve.faults.SimulatedCrash`)
drives the recovery chaos harness in ``launch/serve.py``.
"""

from repro.serve.engine import PagesExhausted, ServeEngine
from repro.serve.faults import (FaultInjector, FaultSpec, InjectedFault,
                                SimulatedCrash)
from repro.serve.journal import (Collated, JournalCorruption, JournalError,
                                 JournalReplay, JournalWriter, collate,
                                 read_journal)
from repro.serve.kvquant import (KV_DTYPES, KVSpec, dequantize_kv,
                                 quantize_kv)
from repro.serve.lifecycle import (ErrorKind, IllegalTransition, Request,
                                   RequestRecord, RequestState,
                                   RETRYABLE_KINDS)
from repro.serve.paging import NULL_PAGE, PageAllocator
from repro.serve.sampling import NonFiniteLogitsError, sample_token
