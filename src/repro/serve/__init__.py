from repro.serve.engine import ServeEngine
from repro.serve.faults import FaultInjector, FaultSpec, InjectedFault
from repro.serve.lifecycle import (IllegalTransition, Request, RequestRecord,
                                   RequestState)
from repro.serve.sampling import NonFiniteLogitsError, sample_token
