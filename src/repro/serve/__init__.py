from repro.serve.engine import PagesExhausted, ServeEngine
from repro.serve.faults import FaultInjector, FaultSpec, InjectedFault
from repro.serve.lifecycle import (IllegalTransition, Request, RequestRecord,
                                   RequestState)
from repro.serve.paging import NULL_PAGE, PageAllocator
from repro.serve.sampling import NonFiniteLogitsError, sample_token
