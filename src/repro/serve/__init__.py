from repro.serve.engine import ServeEngine, Request
from repro.serve.sampling import sample_token
