"""Write-ahead request journal for crash-safe serving.

The journal is the engine's durability boundary: every externally visible
request effect — a submission accepted, a token committed, a terminal
record emitted — is appended (and fsync'd) here BEFORE the in-memory
effect happens.  After a crash, ``read_journal`` + ``collate`` reconstruct
the exact request truth: which requests exist, which tokens were already
delivered, which requests reached a terminal state.  Combined with an
engine snapshot (``ServeEngine.snapshot``) this gives bitwise replay
recovery — see docs/serving.md ("Crash recovery").

Record framing (append-only text, one record per line)::

    <crc32:8 hex> <json>\n

The CRC covers the JSON payload bytes, and every payload carries a
contiguous ``seq`` number.  On replay:

- a **torn tail** — an unterminated final line, or a final line whose CRC
  / JSON does not verify (a write cut mid-record by the crash) — is
  salvaged: the damaged tail is discarded and reported via
  ``JournalReplay.torn_tail``, and ``JournalWriter.reopen`` truncates the
  file back to the salvage point before appending continues;
- **mid-file damage** (a bad CRC, undecodable JSON, or a ``seq`` gap
  anywhere before the final record) raises :class:`JournalCorruption`
  naming the salvage point — replaying past lost records could
  double-deliver or drop tokens, so recovery refuses.

Record kinds (the full schema table lives in docs/serving.md):

``open``      engine construction: mode + the shape config a restored
              engine must be rebuilt with (batch_slots, max_seq, seed, …)
``submit``    full request payload (rid, prompt, budgets) — written before
              the request enters the queue
``admit``     rid -> slot placement (audit only; placement never affects
              outputs)
``token``     one committed token (rid, contiguous idx, token id) —
              written before the token is appended / delivered
``terminal``  one per rid, ever: status, error kind/message, retry count —
              written before the RequestRecord becomes visible
``snapshot``  marker that an engine snapshot completed (step, path)
``recover``   a restored engine re-attached to this journal (audit trail)
"""

from __future__ import annotations

import dataclasses
import json
import os
import zlib
from pathlib import Path
from typing import Dict, List, Optional

RECORD_KINDS = ("open", "submit", "admit", "token", "terminal", "snapshot",
                "recover")


class JournalError(RuntimeError):
    """Journal misuse or an unreplayable journal."""


class JournalCorruption(JournalError):
    """Damage before the final record — replaying past it could
    double-deliver or silently drop committed tokens, so recovery refuses
    and the message names the salvage point instead."""


@dataclasses.dataclass
class JournalReplay:
    """Result of :func:`read_journal`: every verified record plus the
    salvage point (``good_bytes`` / ``next_seq``) a writer may resume
    from.  ``torn_tail`` describes a discarded crash-torn final record
    (None for a cleanly terminated journal)."""

    records: List[dict]
    good_bytes: int
    next_seq: int
    torn_tail: Optional[str]


def _parse_line(line: bytes):
    """-> (record dict) or raises ValueError describing the damage."""
    if len(line) < 10 or line[8:9] != b" ":
        raise ValueError(f"malformed framing ({len(line)} byte line)")
    crc_hex, payload = line[:8], line[9:]
    try:
        want = int(crc_hex, 16)
    except ValueError:
        raise ValueError(f"non-hex checksum {crc_hex!r}")
    got = zlib.crc32(payload) & 0xFFFFFFFF
    if got != want:
        raise ValueError(f"checksum mismatch (stored {crc_hex.decode()}, "
                         f"computed {got:08x})")
    try:
        rec = json.loads(payload)
    except json.JSONDecodeError as e:
        raise ValueError(f"checksummed payload is not JSON: {e}")
    if not isinstance(rec, dict) or "kind" not in rec or "seq" not in rec:
        raise ValueError("record missing 'kind'/'seq'")
    return rec


def read_journal(path) -> JournalReplay:
    """Replay a journal, verifying framing, checksums and seq contiguity.

    Returns every verified record; a damaged FINAL record (the classic
    crash-torn tail) is discarded and reported, damage anywhere earlier
    raises :class:`JournalCorruption` naming the salvage point."""
    path = Path(path)
    if not path.exists():
        raise JournalError(f"no journal at {path}")
    data = path.read_bytes()
    records: List[dict] = []
    pos = 0
    while pos < len(data):
        nl = data.find(b"\n", pos)
        if nl == -1:
            return JournalReplay(
                records, pos, len(records),
                f"unterminated final record at byte {pos} "
                f"({len(data) - pos} trailing byte(s) discarded)")
        try:
            rec = _parse_line(data[pos:nl])
        except ValueError as why:
            if nl == len(data) - 1:
                # damage confined to the final record: a torn write
                return JournalReplay(
                    records, pos, len(records),
                    f"corrupt final record at byte {pos}: {why}")
            raise JournalCorruption(
                f"corrupt journal record {len(records)} at byte {pos} of "
                f"{path}: {why}; salvage point is the {len(records)} intact "
                f"record(s) / {pos} bytes before the damage — refusing to "
                f"replay past it") from None
        if rec["seq"] != len(records):
            # a seq gap means a WHOLE record vanished while later ones
            # survived — that is mid-file damage even on the final line
            raise JournalCorruption(
                f"journal sequence gap at byte {pos} of {path}: expected "
                f"seq {len(records)}, found {rec['seq']}; salvage point is "
                f"the {len(records)} record(s) before the gap")
        records.append(rec)
        pos = nl + 1
    return JournalReplay(records, pos, len(records), None)


class JournalWriter:
    """Append-only, fsync-per-record journal writer.

    A fresh writer refuses to clobber an existing non-empty journal
    (``overwrite=True`` to discard it); :meth:`reopen` resumes an existing
    journal after a crash, truncating any torn tail back to the salvage
    point first.  ``fsync=False`` drops the per-record fsync (tests);
    production keeps it — the WAL contract is that a record returned from
    :meth:`append` survives a process crash."""

    def __init__(self, path, *, fsync: bool = True, overwrite: bool = False):
        self.path = Path(path)
        self.fsync = fsync
        if self.path.exists() and self.path.stat().st_size and not overwrite:
            raise JournalError(
                f"journal {self.path} already exists and is non-empty; "
                f"recover with ServeEngine.restore / JournalWriter.reopen, "
                f"or pass overwrite=True to discard it")
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._f = open(self.path, "wb")
        self.seq = 0

    @classmethod
    def reopen(cls, path, replay: Optional[JournalReplay] = None,
               *, fsync: bool = True) -> "JournalWriter":
        """Resume appending to an existing journal: verify it (or reuse a
        :func:`read_journal` result), truncate any torn tail, continue the
        seq numbering."""
        if replay is None:
            replay = read_journal(path)
        w = cls.__new__(cls)
        w.path = Path(path)
        w.fsync = fsync
        w._f = open(w.path, "r+b")
        w._f.truncate(replay.good_bytes)
        w._f.seek(replay.good_bytes)
        w.seq = replay.next_seq
        return w

    def append(self, kind: str, **fields) -> int:
        """Durably append one record; returns its seq.  The record is on
        disk (fsync'd) before this returns — callers apply the in-memory
        effect only afterwards."""
        if kind not in RECORD_KINDS:
            raise JournalError(f"unknown record kind {kind!r}; "
                               f"one of {RECORD_KINDS}")
        seq = self.seq
        payload = json.dumps({"seq": seq, "kind": kind, **fields},
                             separators=(",", ":")).encode()
        crc = zlib.crc32(payload) & 0xFFFFFFFF
        self._f.write(b"%08x " % crc + payload + b"\n")
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        self.seq = seq + 1
        return seq

    def close(self):
        if not self._f.closed:
            self._f.flush()
            if self.fsync:
                os.fsync(self._f.fileno())
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


@dataclasses.dataclass
class Collated:
    """Request truth extracted from a verified record stream (see
    :func:`collate`): insertion-ordered submits, per-rid delivered token
    streams, at-most-one terminal per rid, plus the open/snapshot/recover
    audit trail."""

    opens: List[dict]
    submits: Dict[int, dict]      # rid -> submit record, submission order
    tokens: Dict[int, List[int]]  # rid -> delivered tokens, idx order
    terminals: Dict[int, dict]    # rid -> terminal record
    snapshots: List[dict]
    recovers: List[dict]

    def pending(self) -> List[int]:
        """Non-terminal rids in submission order — the work a recovery
        must finish."""
        return [rid for rid in self.submits if rid not in self.terminals]


def collate(records: List[dict]) -> Collated:
    """Fold a verified record stream into per-request truth, enforcing the
    delivery invariants recovery depends on: token indices are contiguous
    per rid (a duplicate or gap would double-deliver or drop a committed
    token), at most one terminal per rid, and no event precedes its
    submit or follows its terminal."""
    out = Collated([], {}, {}, {}, [], [])
    for rec in records:
        kind, seq = rec["kind"], rec["seq"]
        if kind == "open":
            out.opens.append(rec)
        elif kind == "submit":
            rid = rec["rid"]
            if rid in out.submits:
                raise JournalCorruption(
                    f"record {seq}: duplicate submit for rid {rid}")
            out.submits[rid] = rec
        elif kind == "token":
            rid = rec["rid"]
            if rid not in out.submits:
                raise JournalCorruption(
                    f"record {seq}: token for unknown rid {rid}")
            if rid in out.terminals:
                raise JournalCorruption(
                    f"record {seq}: token for rid {rid} after its terminal "
                    f"record — double delivery")
            stream = out.tokens.setdefault(rid, [])
            if rec["idx"] != len(stream):
                raise JournalCorruption(
                    f"record {seq}: token idx {rec['idx']} for rid {rid} "
                    f"breaks contiguity (have {len(stream)} token(s)) — "
                    f"replay would double-deliver or drop a committed token")
            stream.append(int(rec["token"]))
        elif kind == "terminal":
            rid = rec["rid"]
            if rid not in out.submits:
                raise JournalCorruption(
                    f"record {seq}: terminal for unknown rid {rid}")
            if rid in out.terminals:
                raise JournalCorruption(
                    f"record {seq}: second terminal record for rid {rid} — "
                    f"a request terminates exactly once")
            out.terminals[rid] = rec
        elif kind == "snapshot":
            out.snapshots.append(rec)
        elif kind == "recover":
            out.recovers.append(rec)
        # "admit" records are audit-only: placement never affects outputs
    return out
