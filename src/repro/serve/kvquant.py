"""KV-cache quantization: the ``KVSpec`` axis of the cache layout.

The paged KV pool (docs/serving.md "Paged KV cache") stores pages at full
f32 width by default; at long context that stream — not the W4A4 weight
stream — is the memory wall.  A :class:`KVSpec` makes the storage width a
first-class, static axis of the layout:

* ``dtype``  ∈ {``f32``, ``bf16``, ``int8``, ``int4``} — the pool's storage
  width.  ``int4`` packs two values per byte along ``head_dim`` (the
  ``core.quantizers.pack_int4`` nibble layout).
* ``group`` — scale granularity along ``head_dim``: ``None`` = one scale
  per (token, kv-head) (per-head absmax), or an integer ``g`` (paper
  Table 2 style, g = 128) giving ``head_dim // g`` scales per head.  ``g``
  is clamped to ``head_dim`` at use, so ``group=128`` on a 64-wide head
  degenerates to per-head exactly.

Quantized pools carry an f32 **scale-plane sidecar** — leaves
``k_scale`` / ``v_scale`` shaped ``(L, num_pages, page_size, n_kv_heads,
n_groups)`` — indexed by the SAME page ids as the data pool (one block
table, one allocator; ``PageAllocator`` asserts the sidecar accounting
stays in lockstep).  The scale planes are deliberately float: the engine's
page-scoped fault surface (``FaultInjector.corrupt_pages``) poisons float
leaves on the page axis, so a cache-corruption fault still reaches a
quantized pool through its scales.

``quantize_kv`` / ``dequantize_kv`` below are THE canonical spellings —
the jnp serving path (``models/common.py``), both flash-attention kernels
(``kernels/flash_attn.py``), and the accuracy harness all import these,
the same single-source discipline that keeps the three W4A4 GEMM paths
bitwise identical (``rowops.gemm_chunk_grouped``).  The reductions and the
scale-then-round operation order match ``kernels/rowops.py``'s group
bodies (``group_amax`` → ``amax_to_scale`` → clip(round(x/s))), applied
over ``head_dim`` instead of the GEMM's K axis.

The ``f32`` spec is the identity: no scale leaves, no extra ops, the pool
init/append/gather code paths are the exact pre-KVSpec code — bitwise
identical serving, which the chaos + crash-recovery contract relies on.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.core.quantizers import pack_int4, unpack_int4
from repro.kernels.rowops import amax_to_scale, dequant_rows_grouped

KV_DTYPES = ("f32", "bf16", "int8", "int4")
_FLOAT_DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16}
_BITS = {"int8": 8, "int4": 4}


@dataclasses.dataclass(frozen=True)
class KVSpec:
    """Static description of the KV-cache storage scheme.

    Frozen + hashable on purpose: the spec rides as a jit-static argument
    and as part of the ``_model_fns`` cache key, exactly like
    ``KernelContext``."""

    dtype: str = "f32"
    # Scale group along head_dim (quantized dtypes only); None = per-head.
    group: Optional[int] = None

    def __post_init__(self):
        if self.dtype not in KV_DTYPES:
            raise ValueError(
                f"unknown kv dtype {self.dtype!r}; one of {KV_DTYPES}")
        if self.group is not None:
            if not self.is_quantized:
                raise ValueError(
                    f"kv group={self.group} only applies to quantized kv "
                    f"dtypes, not {self.dtype!r}")
            if not (isinstance(self.group, int) and self.group > 0):
                raise ValueError(f"kv group must be a positive int, "
                                 f"got {self.group!r}")

    # -- classification ------------------------------------------------------

    @property
    def is_quantized(self) -> bool:
        return self.dtype in _BITS

    @property
    def bits(self) -> int:
        return _BITS[self.dtype]

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1

    @property
    def cache_dtype(self):
        """Storage dtype of a FLOAT spec's cache leaves (f32 / bf16).

        Quantized specs have no single cache dtype — use
        :meth:`pool_dtype` for the page pool and f32 for scale planes."""
        if self.is_quantized:
            raise ValueError(
                f"kv dtype {self.dtype!r} has no float cache dtype; "
                f"quantized specs only apply to the paged pool")
        return _FLOAT_DTYPES[self.dtype]

    @property
    def pool_dtype(self):
        """Element dtype of the paged K/V pool leaves."""
        if self.dtype == "int8":
            return jnp.int8
        if self.dtype == "int4":
            return jnp.uint8  # two nibbles per byte, pack_int4 layout
        return _FLOAT_DTYPES[self.dtype]

    # -- geometry ------------------------------------------------------------

    def group_for(self, head_dim: int) -> int:
        """Effective scale group: ``min(group, head_dim)`` (``group=None``
        → ``head_dim``, i.e. per-head).  Must divide ``head_dim``."""
        g = head_dim if self.group is None else min(self.group, head_dim)
        if head_dim % g != 0:
            raise ValueError(
                f"kv group {self.group} does not divide head_dim "
                f"{head_dim} (effective group {g})")
        return g

    def n_groups(self, head_dim: int) -> int:
        """Scales per (token, kv-head); 0 for float specs (no sidecar)."""
        if not self.is_quantized:
            return 0
        return head_dim // self.group_for(head_dim)

    def packed_head_dim(self, head_dim: int) -> int:
        """Last-axis width of a pool leaf (int4 packs two per byte)."""
        if self.dtype == "int4":
            if head_dim % 2 != 0:
                raise ValueError(f"int4 kv needs an even head_dim, "
                                 f"got {head_dim}")
            return head_dim // 2
        return head_dim

    def kv_bytes_per_token(self, n_kv_heads: int, head_dim: int) -> int:
        """HBM bytes ONE token's K+V occupy (data + scale planes).

        This is the per-token term of the roofline attention-bytes model
        (``launch/roofline.attention_kv_bytes``) and of
        ``health()["kv"]["bytes_per_token"]`` — one spelling, like
        ``prologue_intermediate_bytes``."""
        if self.dtype == "f32":
            per_head = 4 * head_dim
        elif self.dtype == "bf16":
            per_head = 2 * head_dim
        else:
            per_head = self.packed_head_dim(head_dim) \
                + 4 * self.n_groups(head_dim)
        return 2 * n_kv_heads * per_head  # K and V

    # -- serialization (journal open record / snapshot meta / CLI) -----------

    @classmethod
    def from_flags(cls, dtype: Optional[str], group: Optional[int]) -> "KVSpec":
        """Build from ``--kv-dtype`` / ``--kv-group`` (None → defaults)."""
        return cls(dtype=dtype or "f32", group=group)

    def to_meta(self) -> dict:
        return {"kv_dtype": self.dtype, "kv_group": self.group}

    @classmethod
    def from_meta(cls, meta: dict) -> "KVSpec":
        """Read a spec out of a journal open record or snapshot meta dict.
        Pre-KVSpec records carry neither key and decode to f32 — old
        journals stay replayable."""
        return cls(dtype=meta.get("kv_dtype", "f32"),
                   group=meta.get("kv_group"))

    def describe(self) -> str:
        if not self.is_quantized or self.group is None:
            return self.dtype
        return f"{self.dtype}-g{self.group}"


# ---------------------------------------------------------------------------
# the canonical quantize / dequantize spellings
# ---------------------------------------------------------------------------


def quantize_kv(x: jnp.ndarray, spec: KVSpec):
    """Quantize KV rows ``x (..., head_dim)`` → ``(q, scales)``.

    Per group of ``spec.group_for(head_dim)`` features: absmax →
    ``amax_to_scale`` (zero-guarded, clip ratio 1) → ``clip(round(x/s))``
    — the rowops group-body operation order.  ``q`` is int8 (or
    pack_int4'd uint8, two per byte along head_dim); ``scales`` is f32
    ``(..., n_groups)``.  Deterministic and placement-free: the engine's
    page/co-tenancy bitwise invariances extend to quantized specs because
    a token row always quantizes to the same bytes wherever it lands."""
    hd = x.shape[-1]
    g = spec.group_for(hd)
    n_g = hd // g
    xg = x.astype(jnp.float32).reshape(*x.shape[:-1], n_g, g)
    s = amax_to_scale(jnp.max(jnp.abs(xg), axis=-1), spec.qmax, 1.0)
    q = jnp.clip(jnp.round(xg / s[..., None]), -spec.qmax - 1, spec.qmax) \
        .astype(jnp.int8).reshape(*x.shape[:-1], hd)
    if spec.dtype == "int4":
        q = pack_int4(q)
    return q, s


def dequantize_kv(q: jnp.ndarray, scales: jnp.ndarray, spec: KVSpec,
                  head_dim: int) -> jnp.ndarray:
    """THE canonical dequant: (unpack →) group-reshape → ONE elementwise
    multiply by the scale plane → f32 ``(..., head_dim)``.

    Every consumer — the jnp paged serving path, the dense flash kernel,
    the paged GQA gather kernel, the accuracy harness — calls this, so the
    dequantized operands entering their attention math are bitwise
    identical (the ``gemm_chunk_grouped`` single-spelling discipline)."""
    if spec.dtype == "int4":
        q = unpack_int4(q)
    g = spec.group_for(head_dim)
    lead = q.shape[:-1]
    x = dequant_rows_grouped(q.reshape(-1, head_dim),
                             scales.reshape(-1, head_dim // g), g)
    return x.reshape(*lead, head_dim)
