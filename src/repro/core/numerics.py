"""Numerical configuration helpers.

The paper (§3, "Application of LRC on LLMs") found that computing the
calibration Hessians requires 64-bit precision.  JAX disables x64 by default;
`ensure_x64` flips the flag idempotently.  Model code always uses explicit
dtypes (bf16 / f32) and is unaffected by the global default.
"""

import jax


def ensure_x64() -> None:
    if not jax.config.jax_enable_x64:
        jax.config.update("jax_enable_x64", True)


def x64_enabled() -> bool:
    return bool(jax.config.jax_enable_x64)
