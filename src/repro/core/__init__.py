from repro.core.quantizers import (
    QuantSpec,
    quantize_weight_rtn,
    dequantize_weight,
    fake_quant_act,
    quantize_act,
    search_clip_ratio,
)
from repro.core.stats import CalibStats, init_stats, accumulate_stats, finalize_stats
from repro.core.gptq import gptq_quantize
from repro.core.lrc import (
    LRCResult,
    init_lr,
    update_lr,
    update_quant,
    lrc_solve,
    svd_correction,
    reconstruction_loss,
)
from repro.core.hadamard import hadamard_matrix, fwht, random_orthogonal
