"""GPTQ layer-wise quantization solver (Frantar et al., 2022).

Used as the subroutine of LRC's Ŵ-update (paper Alg. 2, line 5).  Only needs
the target weight matrix and the (damped) input second-moment H:

    min_{Ŵ ∈ C(b)}  || (W - Ŵ) X ||²   with  H = X Xᵀ.

Two implementations:
  * ``gptq_quantize``     — JAX ``lax.scan`` over columns (jit-compiled);
  * ``gptq_quantize_np``  — float64 numpy reference (blocked, matches the
                             official algorithm structure), used by tests.

Both follow the Cholesky form: with T the upper-triangular factor of H⁻¹
(H⁻¹ = Tᵀ T), quantize column i, propagate the scaled residual to columns
j > i via row T[i, :].
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.numerics import ensure_x64
from repro.core.quantizers import QuantSpec, weight_scales


def _hinv_chol_upper(h: jnp.ndarray, damp: float) -> jnp.ndarray:
    """Upper-triangular T with H⁻¹ = Tᵀ T (after damping)."""
    d = h.shape[0]
    h = h + damp * jnp.mean(jnp.diag(h)) * jnp.eye(d, dtype=h.dtype)
    l = jnp.linalg.cholesky(h)
    eye = jnp.eye(d, dtype=h.dtype)
    linv = jax.scipy.linalg.solve_triangular(l, eye, lower=True)
    hinv = linv.T @ linv  # H⁻¹ = L⁻ᵀ L⁻¹
    return jnp.linalg.cholesky(hinv).T


@partial(jax.jit, static_argnames=("bits",))
def _gptq_scan(wt, t_upper, scales, bits: int):
    """wt: (d_in, d_out) transposed weights; t_upper: (d_in, d_in)."""
    qmax = 2 ** (bits - 1) - 1
    qmin = -(2 ** (bits - 1))
    d_in = wt.shape[0]

    def step(w_carry, i):
        col = w_carry[i]  # (d_out,) current (residual-corrected) column i
        q = jnp.clip(jnp.round(col / scales), qmin, qmax)
        err = (col - q * scales) / t_upper[i, i]
        trow = t_upper[i]  # zero below/at diagonal handled by mask
        mask = (jnp.arange(d_in) > i).astype(w_carry.dtype)
        w_carry = w_carry - (trow * mask)[:, None] * err[None, :]
        return w_carry, q.astype(jnp.int8)

    _, qcols = jax.lax.scan(step, wt, jnp.arange(d_in))
    return qcols  # (d_in, d_out)


def gptq_quantize(
    w: jnp.ndarray,
    hessian: jnp.ndarray,
    spec: QuantSpec,
    damp: float = 0.01,
    act_order: bool = False,
):
    """Quantize ``w`` (d_out, d_in) against ``hessian`` (d_in, d_in).

    Returns (q int8, scales f32).  ``act_order``: process columns in order of
    decreasing hessian diagonal (GPTQ's ``desc_act``).
    """
    ensure_x64()
    w = jnp.asarray(w, jnp.float64)
    h = jnp.asarray(hessian, jnp.float64)
    d_in = w.shape[1]

    # Dead inputs: zero hessian diagonal ⇒ column never activates.
    dead = jnp.diag(h) <= 0.0
    h = jnp.where(jnp.eye(d_in, dtype=bool) & dead[None, :], 1.0, h)
    w = jnp.where(dead[None, :], 0.0, w)

    perm = None
    if act_order:
        perm = jnp.argsort(-jnp.diag(h))
        w = w[:, perm]
        h = h[perm][:, perm]

    scales = weight_scales(w, spec).astype(jnp.float64)[:, 0]  # per-row
    t_upper = _hinv_chol_upper(h, damp)
    qcols = _gptq_scan(w.T, t_upper, scales, spec.bits)
    q = qcols.T  # (d_out, d_in)

    if perm is not None:
        inv = jnp.argsort(perm)
        q = q[:, inv]
    return q, scales[:, None].astype(jnp.float32)


def gptq_quantize_np(
    w: np.ndarray,
    hessian: np.ndarray,
    spec: QuantSpec,
    damp: float = 0.01,
    block: int = 128,
) -> tuple[np.ndarray, np.ndarray]:
    """Blocked float64 numpy reference (official GPTQ structure)."""
    w = np.array(w, np.float64)
    h = np.array(hessian, np.float64)
    d_out, d_in = w.shape
    qmax = 2 ** (spec.bits - 1) - 1
    qmin = -(2 ** (spec.bits - 1))

    dead = np.diag(h) <= 0
    h[dead, dead] = 1.0
    w[:, dead] = 0.0
    h = h + damp * np.mean(np.diag(h)) * np.eye(d_in)

    amax = np.abs(w).max(axis=1, keepdims=True)
    amax[amax <= 0] = 1.0
    scales = amax / qmax  # (d_out, 1)

    l = np.linalg.cholesky(h)
    linv = np.linalg.solve(l, np.eye(d_in))
    hinv = linv.T @ linv
    t = np.linalg.cholesky(hinv).T  # upper

    q_out = np.zeros_like(w)
    for b0 in range(0, d_in, block):
        b1 = min(b0 + block, d_in)
        wblk = w[:, b0:b1].copy()
        err = np.zeros_like(wblk)
        for i in range(b1 - b0):
            col = wblk[:, i]
            q = np.clip(np.round(col / scales[:, 0]), qmin, qmax)
            q_out[:, b0 + i] = q
            e = (col - q * scales[:, 0]) / t[b0 + i, b0 + i]
            wblk[:, i:] -= np.outer(e, t[b0 + i, b0 + i : b1])
            err[:, i] = e
        w[:, b1:] -= err @ t[b0:b1, b1:]
    return q_out.astype(np.int8), scales.astype(np.float32)


def rtn_weight_quantize(w: jnp.ndarray, hessian, spec: QuantSpec):
    """Hessian-free round-to-nearest (the paper's Fig. 3 'RTN' ablation)."""
    from repro.core.quantizers import quantize_weight_rtn

    q, s = quantize_weight_rtn(jnp.asarray(w, jnp.float32), spec)
    return q, s
