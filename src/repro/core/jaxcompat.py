"""Version-compat wrappers for the jax mesh API.

The codebase targets the current mesh interface (``jax.sharding.
get_abstract_mesh`` / ``jax.set_mesh`` / ``AxisType``); the hermetic
container ships jax 0.4.37, which predates all three.  These helpers pick
the modern spelling when present and fall back to the 0.4-era equivalents,
so the models/serve/launch layers stay version-agnostic.
"""

from __future__ import annotations

import jax


def get_abstract_mesh():
    """Current surrounding mesh, or None when tracing without one.

    Modern jax returns an AbstractMesh (empty ⇒ no axis_names); 0.4.x tracks
    the physical mesh on the thread-local pjit environment instead.
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        return get()
    from jax._src.mesh import thread_resources  # jax<0.5

    m = thread_resources.env.physical_mesh
    return None if m.empty else m


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types when the API supports them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def set_mesh(mesh):
    """Context manager activating ``mesh`` (jax.set_mesh, or the Mesh object
    itself on 0.4.x where Mesh is its own context manager)."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh


def abstract_mesh(shape, axes):
    """jax.sharding.AbstractMesh across the 0.4 → current constructor change
    ((name, size) pairs vs. separate sizes/names + axis_types)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.sharding.AbstractMesh(
            tuple(shape), tuple(axes), axis_types=(axis_type.Auto,) * len(axes)
        )
    return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, axis_names=None):
    """jax.shard_map (current) / jax.experimental.shard_map (0.4.x).

    ``check_vma`` maps onto 0.4's ``check_rep``.  ``axis_names`` (partial
    manual axes) has no 0.4 equivalent — there shard_map is manual over every
    mesh axis, which is semantically equivalent for bodies whose specs leave
    the extra axes replicated."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kw = {}
        if check_vma is not None:
            kw["check_vma"] = check_vma
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as sm  # jax<0.5

    kw = {}
    if check_vma is not None:
        kw["check_rep"] = check_vma
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
