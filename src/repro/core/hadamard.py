"""Hadamard / orthogonal rotation utilities (QuaRot-style preprocessing).

LRC stage (1) applies QuaRot (Ashkboos et al., 2024): orthogonal rotations are
fused into the weights to suppress activation outliers while keeping the model
output exact.  We support:

  * fast Walsh-Hadamard transform (power-of-two sizes) — `fwht`,
  * Paley-I Hadamard matrices for sizes p+1 with p prime, p ≡ 3 (mod 4)
    (gives 12 = 11+1 and 20 = 19+1, covering d = 2^k * {12, 20}),
  * seeded random orthogonal factors for dims with no Hadamard factorization
    (QuaRot's random-orthogonal variant; exactness is preserved either way).

A dimension ``d`` is factored as ``d = m * 2^k`` with ``m`` the largest odd
factor; the rotation is ``R = Q_m ⊗ H_{2^k}`` (normalized), applied fast via
reshape to (..., m, 2^k): WHT over the last axis then a small dense matmul
over the m axis.
"""

from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def _split_pow2(n: int):
    """n -> (m, 2^k) with m odd."""
    k = 0
    while n % 2 == 0:
        n //= 2
        k += 1
    return n, 1 << k


@lru_cache(maxsize=None)
def _sylvester(n: int) -> np.ndarray:
    assert _is_pow2(n)
    h = np.array([[1.0]])
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return h


def _legendre(a: int, p: int) -> int:
    a %= p
    if a == 0:
        return 0
    r = pow(a, (p - 1) // 2, p)
    return 1 if r == 1 else -1


@lru_cache(maxsize=None)
def _paley1(p: int) -> np.ndarray:
    """Paley type-I Hadamard matrix of order p+1 (p prime, p ≡ 3 mod 4)."""
    assert p % 4 == 3
    q = np.array([[_legendre(i - j, p) for j in range(p)] for i in range(p)], float)
    s = np.zeros((p + 1, p + 1))
    s[0, 1:] = 1.0
    s[1:, 0] = -1.0
    s[1:, 1:] = q
    h = s + np.eye(p + 1)
    return h


def _is_prime(n: int) -> bool:
    if n < 2:
        return False
    for f in range(2, int(n**0.5) + 1):
        if n % f == 0:
            return False
    return True


def random_orthogonal(n: int, seed: int = 0) -> np.ndarray:
    """Seeded random orthogonal matrix (QR of a Gaussian), float64."""
    rng = np.random.default_rng(seed + 7919 * n)
    q, r = np.linalg.qr(rng.standard_normal((n, n)))
    # make deterministic sign convention
    q = q * np.sign(np.diag(r))[None, :]
    return q


@lru_cache(maxsize=None)
def odd_factor_matrix(m: int, seed: int = 0) -> np.ndarray:
    """Orthogonal (normalized) m×m factor for the odd part of a dimension:
    Paley-I Hadamard when m-1 is a prime ≡ 3 (mod 4), else seeded random
    orthogonal (QuaRot's Q-variant)."""
    if m == 1:
        return np.ones((1, 1))
    if _is_prime(m - 1) and (m - 1) % 4 == 3:
        return _paley1(m - 1) / np.sqrt(m)
    return random_orthogonal(m, seed)


@lru_cache(maxsize=None)
def hadamard_matrix(n: int, seed: int = 0) -> np.ndarray:
    """Orthogonal (normalized) rotation matrix of size n, materialized.

    Uses (Hadamard or random-orthogonal odd factor) ⊗ H_{2^k}.  Only for
    small/medium n (tests, analysis); the production path is
    :func:`apply_rotation`, which never materializes the n×n matrix.
    """
    assert n <= 8192, "materializing huge rotations is a bug; use apply_rotation"
    m, p2 = _split_pow2(n)
    if m == 1:
        return _sylvester(n) / np.sqrt(n)
    qm = odd_factor_matrix(m, seed)
    h2 = _sylvester(p2) / np.sqrt(p2) if p2 > 1 else np.ones((1, 1))
    return np.kron(qm, h2)


def fwht(x: jnp.ndarray, normalize: bool = True) -> jnp.ndarray:
    """Fast Walsh-Hadamard transform over the last axis (power-of-two dim).

    O(d log d); used as the jnp reference for the Pallas hadamard kernel and
    as the fast path of :func:`apply_rotation`.
    """
    d = x.shape[-1]
    assert _is_pow2(d), d
    orig_shape = x.shape
    h = 1
    y = x.reshape(-1, d)
    while h < d:
        y = y.reshape(-1, d // (2 * h), 2, h)
        a = y[..., 0, :]
        b = y[..., 1, :]
        y = jnp.stack([a + b, a - b], axis=-2)
        h *= 2
    y = y.reshape(orig_shape)
    if normalize:
        y = y / jnp.sqrt(jnp.asarray(d, dtype=x.dtype))
    return y


def apply_rotation(x: jnp.ndarray, n: int, seed: int = 0) -> jnp.ndarray:
    """y = x @ R with R = hadamard_matrix(n), applied fast.

    x: (..., n). Equivalent to ``x @ hadamard_matrix(n)`` (columns of R index
    the output)."""
    m, p2 = _split_pow2(n)
    orig_dtype = x.dtype
    x = x.astype(jnp.float32)
    if m == 1:
        y = fwht(x)  # H symmetric => x @ H == fwht(x)
        return y.astype(orig_dtype)
    # R = Q_m ⊗ H_{2^k}; index i = a * p2 + b
    xr = x.reshape(*x.shape[:-1], m, p2)
    if p2 > 1:
        xr = fwht(xr)
    qm = jnp.asarray(odd_factor_matrix(m, seed), jnp.float32)
    y = jnp.einsum("...ab,ac->...cb", xr, qm)
    return y.reshape(x.shape).astype(orig_dtype)
