"""Online calibration statistics (Algorithm 1, lines 3-5).

LRC never materializes the full activation matrix X (n ≈ 200k tokens); it
accumulates the second-moment matrices

    Σx  = Σ_t x_t x_tᵀ        (d_in, d_in)
    Σy  = Σ_t y_t y_tᵀ        y = Q_a(x)
    Σxy = Σ_t x_t y_tᵀ

in an online fashion over calibration batches (paper: "we accumulate batches
of activations X to avoid running out of memory").  Accumulation runs in
float64 (paper: "computation of these matrices required 64-bit precision").

In a multi-host calibration run the per-shard statistics are summed with
``jax.lax.psum`` over the data axis — provided by ``accumulate_stats(...,
axis_name=...)`` for use under shard_map/pjit.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.numerics import ensure_x64
from repro.core.quantizers import QuantSpec, quantize_act, dequantize_act


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CalibStats:
    """Pytree of accumulated second moments (float64)."""

    sxx: jnp.ndarray  # (d, d)
    syy: jnp.ndarray  # (d, d)
    sxy: jnp.ndarray  # (d, d)
    count: jnp.ndarray  # () number of tokens seen

    @property
    def d(self) -> int:
        return self.sxx.shape[0]


def init_stats(d: int) -> CalibStats:
    ensure_x64()
    z = jnp.zeros((d, d), jnp.float64)
    return CalibStats(sxx=z, syy=z, sxy=z, count=jnp.zeros((), jnp.float64))


def accumulate_stats(
    stats: CalibStats,
    x: jnp.ndarray,
    spec: QuantSpec,
    axis_name: Optional[str] = None,
) -> CalibStats:
    """Fold a batch of activations x (..., d) into the statistics.

    ``axis_name``: if set, psum the batch contribution across that mesh axis
    (data-parallel calibration).
    """
    x = x.reshape(-1, x.shape[-1]).astype(jnp.float64)
    q, s = quantize_act(x, spec)
    y = dequantize_act(q, s, spec).astype(jnp.float64)
    dxx = x.T @ x
    dyy = y.T @ y
    dxy = x.T @ y
    dn = jnp.asarray(x.shape[0], jnp.float64)
    if axis_name is not None:
        dxx = jax.lax.psum(dxx, axis_name)
        dyy = jax.lax.psum(dyy, axis_name)
        dxy = jax.lax.psum(dxy, axis_name)
        dn = jax.lax.psum(dn, axis_name)
    return CalibStats(
        sxx=stats.sxx + dxx,
        syy=stats.syy + dyy,
        sxy=stats.sxy + dxy,
        count=stats.count + dn,
    )


def finalize_stats(stats: CalibStats, eps_frac: float = 1e-2) -> CalibStats:
    """Add the paper's damping:  Σ ← Σ + (eps_frac/d)·Tr(Σ)·I  (§3 Numerical
    Stability; ε = 1e-2 · Tr(Σ)/d)."""
    d = stats.d
    eye = jnp.eye(d, dtype=jnp.float64)
    ex = eps_frac * jnp.trace(stats.sxx) / d
    ey = eps_frac * jnp.trace(stats.syy) / d
    return CalibStats(
        sxx=stats.sxx + ex * eye,
        syy=stats.syy + ey * eye,
        sxy=stats.sxy,
        count=stats.count,
    )
