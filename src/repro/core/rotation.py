"""QuaRot-style rotation fusion (LRC stage 1).

For a pre-norm transformer with RMSNorm, an orthogonal rotation R of the
residual stream can be fused into the weights with *exact* output
preservation:

  1. fold the RMSNorm per-channel scale γ into the following linear layers
     (W ← W · diag(γ)); the norm becomes a pure RMS (γ = 1), which commutes
     with any orthogonal R because ||Rᵀx|| = ||x||;
  2. rotate every residual-facing weight:
        readers (x → Wx):   W ← W R        (embedding-side input)
        writers (y → res):  W ← Rᵀ W       (output projections)
        embedding rows:     E ← E R
        lm head:            W ← W R

The framework-level application to each architecture lives in
`repro.quant.rotate_model`; this module holds the math and a tiny reference
MLP used by the exactness tests.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.hadamard import hadamard_matrix


def residual_rotation(d: int, seed: int = 0) -> jnp.ndarray:
    """The fused R1 rotation for a residual stream of width d (float32)."""
    return jnp.asarray(hadamard_matrix(d, seed), jnp.float32)


def rotate_in(w: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """Reader weight W (d_out, d_in): x is replaced by Rᵀx ⇒ W ← W R."""
    return (w.astype(jnp.float32) @ r).astype(w.dtype)


def rotate_out(w: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """Writer weight W (d_out, d_in) into the residual ⇒ W ← Rᵀ W."""
    return (r.T @ w.astype(jnp.float32)).astype(w.dtype)


def rotate_embedding(e: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """Embedding table (vocab, d): rows live in the residual stream ⇒ E ← E R."""
    return (e.astype(jnp.float32) @ r).astype(e.dtype)


def fold_rmsnorm_gamma(gamma: jnp.ndarray, readers: list) -> tuple:
    """Fold γ into every reader weight (W ← W diag(γ)); returns (ones, new
    readers)."""
    g = gamma.astype(jnp.float32)
    new = [(w.astype(jnp.float32) * g[None, :]).astype(w.dtype) for w in readers]
    return jnp.ones_like(gamma), new


def incoherence(w: jnp.ndarray) -> float:
    """max|W_ij| · sqrt(numel) / ||W||_F — the outlier measure rotations are
    meant to reduce (QuaRot §3)."""
    w = np.asarray(w, np.float64)
    return float(np.abs(w).max() * np.sqrt(w.size) / np.linalg.norm(w))
