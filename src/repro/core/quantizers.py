"""Quantization operators Q_b (weights) and Q_a (activations).

Paper §2: activations are quantized on the fly with a scale-then-round scheme,
rescaling each activation x by ``c * max(abs(x))`` and rounding to the nearest
integer; ``c`` (the clip ratio) is found by a simple hyper-parameter search.
Weights use symmetric per-output-channel scales on the int grid.

Conventions (code, tokens-first):
  activations  x : (..., d_in)          — quantized per-token (last axis) or
                                          per group of ``group_size`` features.
  weights      W : (d_out, d_in)        — quantized per-row (output channel).
  int4 grid: integers in [-(2^{b-1}), 2^{b-1}-1] = [-8, 7] for b=4.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Static description of a quantization scheme."""

    bits: int = 4
    # Activation clip ratio c (paper §2). 1.0 = plain absmax.
    clip_ratio: float = 1.0
    # Optional groupsize along the feature axis (paper Table 2 uses 128 for
    # activations). None = per-token (acts) / per-channel (weights).
    group_size: Optional[int] = None
    # Symmetric grids only (matches QuaRot/LRC setups).
    symmetric: bool = True

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1

    @property
    def qmin(self) -> int:
        return -(2 ** (self.bits - 1))

    @property
    def storage_dtype(self):
        # int8 carries any grid up to 8 bits; wider grids (used e.g. as the
        # ~identity quantizer in ablations) need int32.
        return jnp.int8 if self.bits <= 8 else jnp.int32


def _safe_scale(amax: jnp.ndarray, qmax: int) -> jnp.ndarray:
    """absmax -> positive scale, guarding all-zero slices."""
    amax = jnp.where(amax <= 0.0, 1.0, amax)
    return amax / qmax


# ---------------------------------------------------------------------------
# Weights
# ---------------------------------------------------------------------------


def weight_scales(w: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """Per-output-channel (row) scales, shape (d_out, 1); or per-group
    (d_out, d_in // g) when ``spec.group_size`` is set."""
    if spec.group_size is None:
        amax = jnp.max(jnp.abs(w), axis=1, keepdims=True)
        return _safe_scale(amax, spec.qmax)
    g = spec.group_size
    d_out, d_in = w.shape
    assert d_in % g == 0, (d_in, g)
    amax = jnp.max(jnp.abs(w.reshape(d_out, d_in // g, g)), axis=-1)
    return _safe_scale(amax, spec.qmax)


def quantize_weight_rtn(
    w: jnp.ndarray, spec: QuantSpec, scales: Optional[jnp.ndarray] = None
):
    """Round-to-nearest weight quantization.

    Returns (q int8 carrying b-bit integers, scales float32).
    """
    if scales is None:
        scales = weight_scales(w, spec)
    if spec.group_size is None:
        ws = w / scales
    else:
        g = spec.group_size
        d_out, d_in = w.shape
        ws = (w.reshape(d_out, d_in // g, g) / scales[..., None]).reshape(d_out, d_in)
    q = jnp.clip(jnp.round(ws), spec.qmin, spec.qmax).astype(spec.storage_dtype)
    return q, scales.astype(jnp.float32)


def dequantize_weight(q: jnp.ndarray, scales: jnp.ndarray, spec: QuantSpec):
    if spec.group_size is None:
        return q.astype(scales.dtype) * scales
    g = spec.group_size
    d_out, d_in = q.shape
    w = q.reshape(d_out, d_in // g, g).astype(scales.dtype) * scales[..., None]
    return w.reshape(d_out, d_in)


def pack_int4(q: jnp.ndarray) -> jnp.ndarray:
    """Pack int4 values held in int8 (range [-8, 7]) two-per-byte along the
    LAST axis: out[..., i] holds (q[..., 2i] | q[..., 2i+1] << 4) as uint8."""
    assert q.shape[-1] % 2 == 0
    u = (q.astype(jnp.int32) & 0xF).astype(jnp.uint8)
    lo = u[..., 0::2]
    hi = u[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack_int4`; returns int8 values in [-8, 7].

    All arithmetic stays in (u)int8 — the sign-extension uses the
    (u XOR 8) - 8 identity; a jnp.where/subtract formulation was observed to
    materialize s32 intermediates 8x the packed bytes in the serving HLO."""
    eight = jnp.uint8(8)
    lo = ((packed & jnp.uint8(0xF)) ^ eight).astype(jnp.int8) - jnp.int8(8)
    hi = (((packed >> 4) & jnp.uint8(0xF)) ^ eight).astype(jnp.int8) - jnp.int8(8)
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def act_scales(x: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """Dynamic scales for the on-the-fly quantizer Q_a.

    per-token: (..., 1); per-group: (..., d // g)."""
    if spec.group_size is None:
        amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        return _safe_scale(spec.clip_ratio * amax, spec.qmax)
    g = spec.group_size
    d = x.shape[-1]
    assert d % g == 0, (d, g)
    amax = jnp.max(jnp.abs(x.reshape(*x.shape[:-1], d // g, g)), axis=-1)
    return _safe_scale(spec.clip_ratio * amax, spec.qmax)


def quantize_act(x: jnp.ndarray, spec: QuantSpec):
    """Q_a: returns (q int8, scales f32). Values clipped to the int grid."""
    scales = act_scales(x, spec)
    if spec.group_size is None:
        xs = x / scales
    else:
        g = spec.group_size
        d = x.shape[-1]
        xs = (x.reshape(*x.shape[:-1], d // g, g) / scales[..., None]).reshape(x.shape)
    q = jnp.clip(jnp.round(xs), spec.qmin, spec.qmax).astype(spec.storage_dtype)
    return q, scales.astype(jnp.float32)


def dequantize_act(q: jnp.ndarray, scales: jnp.ndarray, spec: QuantSpec):
    if spec.group_size is None:
        return q.astype(scales.dtype) * scales
    g = spec.group_size
    d = q.shape[-1]
    x = q.reshape(*q.shape[:-1], d // g, g).astype(scales.dtype) * scales[..., None]
    return x.reshape(q.shape)


def fake_quant_act(x: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """Quantize-dequantize in the input dtype (simulation path)."""
    q, s = quantize_act(x.astype(jnp.float32), spec)
    return dequantize_act(q, s, spec).astype(x.dtype)


@partial(jax.jit, static_argnames=("bits", "group_size", "n_grid"))
def _clip_search(x, bits, group_size, n_grid):
    def err_for(c):
        spec = QuantSpec(bits=bits, clip_ratio=c, group_size=group_size)
        return jnp.sum((fake_quant_act(x, spec) - x) ** 2)

    grid = jnp.linspace(0.70, 1.0, n_grid)
    errs = jax.vmap(lambda c: err_for(c))(grid)
    return grid, errs


def search_clip_ratio(
    x: jnp.ndarray,
    bits: int = 4,
    group_size: Optional[int] = None,
    n_grid: int = 16,
) -> float:
    """Paper §2: 'We perform a simple hyper-parameter search for c.'

    Grid-search the clip ratio minimizing quantization MSE on a sample batch.
    """
    grid, errs = _clip_search(x.astype(jnp.float32), bits, group_size, n_grid)
    return float(grid[int(jnp.argmin(errs))])
