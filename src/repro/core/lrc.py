"""LRC — the paper's core algorithm (Algorithms 1-5).

Solves, per layer,

    min_{Ŵ ∈ C(b), U, V}  || W X − Ŵ Q_a(X) − U Vᵀ X ||²          (eq. 2)

by alternating minimization:

  * Init  (Prop 3.4 / Alg 4):  U ← eig_k(Σ_init),  V ← Wᵀ U, with
        Σ_init = W Σx Wᵀ − Sᵀ S,   S = L_y⁻¹ Σxyᵀ Wᵀ,  L_y = chol(Σy).
  * Ŵ-update (Prop 3.1 / Alg 2): quantize the *modified* target
        W̃ = (W − U Vᵀ) Σxy Σy⁻¹
    against the hessian of the QUANTIZED activations Σy (GPTQ by default).
  * (U,V)-update (Prop 3.3 / Alg 3): closed form —
        Σ = Σ1 + Σ2 − Σ3,
        Σ1 = W Σx Wᵀ,  Σ2 = Sᵀ S with S = L_x⁻¹ Σxy Ŵᵀ,
        Σ3 = Ŵ Σxyᵀ Wᵀ + W Σxy Ŵᵀ,
        U = eig_k(Σ),  V = [Wᵀ − Σx⁻¹ Σxy Ŵᵀ] U.

All matrices live in the paper's convention: W (d_out, d_in); statistics are
feature-space (d_in, d_in) second moments from `repro.core.stats`.
Everything runs in float64 (paper §3).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.numerics import ensure_x64
from repro.core.quantizers import QuantSpec, dequantize_weight
from repro.core.stats import CalibStats
from repro.core.gptq import gptq_quantize, rtn_weight_quantize


@dataclasses.dataclass
class LRCResult:
    """Output of the per-layer LRC solve."""

    qweight: jnp.ndarray  # int8 (d_out, d_in) carrying b-bit integers
    scales: jnp.ndarray  # f32 per-row scales
    u: Optional[jnp.ndarray]  # (d_out, k) full precision
    v: Optional[jnp.ndarray]  # (d_in, k)
    losses: list  # reconstruction loss after each stage
    oracle_loss: float  # loss of the unconstrained-W̃ relaxation (Prop 3.4)


# ---------------------------------------------------------------------------
# linear-algebra helpers (f64)
# ---------------------------------------------------------------------------


def _chol(a):
    return jnp.linalg.cholesky(a)


def _tri_solve(l, b, lower=True, trans=False):
    return jax.scipy.linalg.solve_triangular(l, b, lower=lower, trans=1 if trans else 0)


def _chol_solve(l, b):
    """Solve A z = b given lower Cholesky factor l of A."""
    return _tri_solve(l, _tri_solve(l, b, lower=True), lower=True, trans=True)


def _eig_topk(sigma: jnp.ndarray, k: int) -> jnp.ndarray:
    """k unit eigenvectors for the k largest eigenvalues (Prop 3.3 note: Σ is
    symmetric but possibly indefinite; a diagonal shift does not change the
    eigenvectors, so plain eigh ordering suffices)."""
    sigma = 0.5 * (sigma + sigma.T)
    _, vecs = jnp.linalg.eigh(sigma)  # ascending
    return vecs[:, ::-1][:, :k]


# ---------------------------------------------------------------------------
# Algorithm 4 — Init-LR
# ---------------------------------------------------------------------------


def init_lr(w: jnp.ndarray, stats: CalibStats, k: int):
    """Returns (U, V) from the relaxed problem (Prop 3.4)."""
    ensure_x64()
    w = jnp.asarray(w, jnp.float64)
    sigma1 = w @ stats.sxx @ w.T
    ly = _chol(stats.syy)
    s = _tri_solve(ly, stats.sxy.T @ w.T, lower=True)  # L_y⁻¹ Σxyᵀ Wᵀ
    sigma_init = sigma1 - s.T @ s
    u = _eig_topk(sigma_init, k)
    v = w.T @ u
    return u, v


# ---------------------------------------------------------------------------
# Algorithm 2 — Update-Quant (Prop 3.1)
# ---------------------------------------------------------------------------


def modified_target(w, u, v, stats: CalibStats):
    """W̃ = (W − U Vᵀ) Σxy Σy⁻¹ — the unconstrained-optimal weight acting on
    quantized activations given the current low-rank pair."""
    w = jnp.asarray(w, jnp.float64)
    resid = w if u is None else w - u @ v.T
    ly = _chol(stats.syy)
    # W̃ᵀ = Σy⁻¹ Σxyᵀ residᵀ
    wt = _chol_solve(ly, stats.sxy.T @ resid.T)
    return wt.T


def update_quant(
    w,
    u,
    v,
    stats: CalibStats,
    spec: QuantSpec,
    method: str = "gptq",
):
    """Returns (qweight int8, scales, Ŵ dequantized f64)."""
    wt = modified_target(w, u, v, stats)
    if method == "gptq":
        q, s = gptq_quantize(wt, stats.syy, spec)
    elif method == "rtn":
        q, s = rtn_weight_quantize(wt, None, spec)
    else:
        raise ValueError(f"unknown quant method {method!r}")
    w_hat = dequantize_weight(q, s.astype(jnp.float64), spec)
    return q, s, w_hat


# ---------------------------------------------------------------------------
# Algorithm 3 — Update-LR (Prop 3.3)
# ---------------------------------------------------------------------------


def update_lr(w, w_hat, stats: CalibStats, k: int):
    """Closed-form (U, V) given the current quantized Ŵ."""
    ensure_x64()
    w = jnp.asarray(w, jnp.float64)
    w_hat = jnp.asarray(w_hat, jnp.float64)
    sigma1 = w @ stats.sxx @ w.T
    sigma3 = w_hat @ stats.sxy.T @ w.T + w @ stats.sxy @ w_hat.T
    lx = _chol(stats.sxx)
    s = _tri_solve(lx, stats.sxy @ w_hat.T, lower=True)  # L_x⁻¹ Σxy Ŵᵀ
    sigma2 = s.T @ s
    sigma = sigma1 + sigma2 - sigma3
    u = _eig_topk(sigma, k)
    # V = [Wᵀ − Σx⁻¹ Σxy Ŵᵀ] U
    z = _chol_solve(lx, stats.sxy @ w_hat.T)  # Σx⁻¹ Σxy Ŵᵀ
    v = (w.T - z) @ u
    return u, v


# ---------------------------------------------------------------------------
# Reconstruction loss (closed form from the statistics)
# ---------------------------------------------------------------------------


def reconstruction_loss(
    w,
    stats: CalibStats,
    w_hat=None,
    u=None,
    v=None,
) -> float:
    """|| W X − Ŵ Y − U Vᵀ X ||² expanded in the second moments.

    ``w_hat=None`` drops the quantized term; ``u=None`` drops the LR term.
    Normalized per calibration token (divide by count) for scale stability.
    """
    ensure_x64()
    w = jnp.asarray(w, jnp.float64)
    total = jnp.trace(w @ stats.sxx @ w.T)
    if w_hat is not None:
        w_hat = jnp.asarray(w_hat, jnp.float64)
        total = total + jnp.trace(w_hat @ stats.syy @ w_hat.T)
        total = total - 2.0 * jnp.trace(w @ stats.sxy @ w_hat.T)
    if u is not None:
        u = jnp.asarray(u, jnp.float64)
        v = jnp.asarray(v, jnp.float64)
        total = total + jnp.trace((v.T @ stats.sxx @ v) @ (u.T @ u))
        total = total - 2.0 * jnp.trace(u.T @ w @ stats.sxx @ v)
        if w_hat is not None:
            total = total + 2.0 * jnp.trace(u.T @ w_hat @ stats.sxy.T @ v)
    return float(total / jnp.maximum(stats.count, 1.0))


# ---------------------------------------------------------------------------
# Algorithm 1 — full LRC
# ---------------------------------------------------------------------------


def lrc_solve(
    w: jnp.ndarray,
    stats: CalibStats,
    spec: QuantSpec,
    k: int,
    iters: int = 1,
    quant_method: str = "gptq",
) -> LRCResult:
    """Alternating minimization (Algorithm 1).  ``iters`` = T (paper uses 1
    or 5; gains beyond 1 are modest — reproduced in benchmarks)."""
    ensure_x64()
    w = jnp.asarray(w, jnp.float64)
    losses = []

    u, v = init_lr(w, stats, k)

    # Oracle: unconstrained W̃ with the init (U, V) — Prop 3.4's relaxation,
    # i.e. the best achievable with a *perfect* weight quantizer.
    wt0 = modified_target(w, u, v, stats)
    oracle = reconstruction_loss(w, stats, w_hat=wt0, u=u, v=v)

    q = s = w_hat = None
    for _ in range(max(1, iters)):
        q, s, w_hat = update_quant(w, u, v, stats, spec, method=quant_method)
        losses.append(reconstruction_loss(w, stats, w_hat=w_hat, u=u, v=v))
        u, v = update_lr(w, w_hat, stats, k)
        losses.append(reconstruction_loss(w, stats, w_hat=w_hat, u=u, v=v))

    return LRCResult(
        qweight=q,
        scales=s,
        u=u.astype(jnp.float32),
        v=v.astype(jnp.float32),
        losses=losses,
        oracle_loss=oracle,
    )


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------


def quantize_baseline(
    w,
    stats: CalibStats,
    spec: QuantSpec,
    quant_method: str = "gptq",
    hessian: str = "x",
):
    """QuaRot-style baseline: GPTQ/RTN quantization of W, no low-rank term.

    ``hessian='x'`` matches the QuaRot codebase (hessian from unquantized
    activations); ``'y'`` uses quantized-activation statistics (LRC's choice
    when U=V=0)."""
    ensure_x64()
    w = jnp.asarray(w, jnp.float64)
    h = stats.sxx if hessian == "x" else stats.syy
    if quant_method == "gptq":
        q, s = gptq_quantize(w, h, spec)
    else:
        q, s = rtn_weight_quantize(w, None, spec)
    w_hat = dequantize_weight(q, s.astype(jnp.float64), spec)
    return q, s, w_hat


def svd_correction(w, w_hat, k: int):
    """The paper's 'SVD' baseline (LQER-style, Zhang et al. 2024): rank-k SVD
    of the weight residual W − Ŵ, ignoring activation statistics."""
    ensure_x64()
    resid = jnp.asarray(w, jnp.float64) - jnp.asarray(w_hat, jnp.float64)
    uu, ss, vvt = jnp.linalg.svd(resid, full_matrices=False)
    root = jnp.sqrt(ss[:k])
    u = uu[:, :k] * root[None, :]
    v = vvt[:k, :].T * root[None, :]
    return u, v
