"""Quantized linear layer — the paper's computational scheme (Figure 1):

      y = Ŵ · Q_a(x)  +  U Vᵀ x

with Ŵ int4 (packed two-per-byte), Q_a the on-the-fly activation quantizer,
and U, Vᵀ the full-precision low-rank correction acting on the UNQUANTIZED x.

Four execution paths (static ``impl`` field):
  sim    — fake-quant float math; reference semantics for CPU tests/benches.
  int8   — integer GEMM (int8×int8→int32) with per-token rescale; the
           TPU-native lowering used by the dry-run (MXU int8 path).
  pallas — Pallas kernels behind the autotune plan table (kernels/ops.py):
           single-kernel fused forward where the working set fits VMEM,
           prologue→GEMM chain otherwise (the paper's "future work" fusion).
  fused  — force the single-kernel path (kernels/fused_gemm.py): prologue +
           int4 GEMM + LRC epilogue in ONE pallas call, xq never in HBM.

Group-wise activation scales (``act_group``, paper Table 2) run on every
path: the pallas kernels emit/consume the per-group (M, K/g) scale plane
(BK snapped to a multiple of g by the plan layer) — a grouped layer no
longer demotes to the jnp int8 GEMM.

Weight layout in models is (d_in, d_out) with ``y = x @ w``; the LRC solver's
(d_out, d_in) result is transposed at pack time.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional

import jax
import jax.numpy as jnp

if TYPE_CHECKING:  # import-light: the kernel stack loads lazily at apply
    from repro.kernels.context import KernelContext

from repro.core.quantizers import (
    QuantSpec,
    pack_int4,
    unpack_int4,
    quantize_act,
    fake_quant_act,
)


def _static(**kw):
    return dataclasses.field(metadata=dict(static=True), **kw)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QLinear:
    """Pytree holding one quantized weight matrix + its LRC correction."""

    qweight: jnp.ndarray  # uint8 (d_in//2, d_out) — int4 packed along d_in
    w_scale: jnp.ndarray  # f32 (d_out,) per-output-channel
    u: Optional[jnp.ndarray]  # bf16 (d_out, k) or None
    v: Optional[jnp.ndarray]  # bf16 (d_in, k) or None

    bits: int = _static(default=4)
    act_bits: int = _static(default=4)
    act_group: Optional[int] = _static(default=None)
    clip_ratio: float = _static(default=1.0)
    impl: str = _static(default="int8")  # sim | int8 | pallas | fused
    # Kernel execution config: an immutable (hashable) KernelContext rides
    # as pytree-static metadata, so two models in one process can hold
    # different block tables / VMEM budgets without racing any global.
    # None -> the process-default context (repro.kernels.ops.default_context).
    ctx: Optional["KernelContext"] = _static(default=None)
    # Layer name (e.g. the param-tree path) keying per-layer plan overrides
    # in ctx.overrides; None disables name-based lookup (shape-based
    # (K, N, R) overrides still apply).
    name: Optional[str] = _static(default=None)
    # Tensor-parallel placement: "column" (N-sharded W/U, replicated V),
    # "row" (K-sharded W/V, replicated U, one psum) or None (single-device).
    # Set by distributed.tp.shard_params; apply dispatches through
    # tp_qlinear_apply when tagged and a mesh is ambient.
    parallel: Optional[str] = _static(default=None)

    @property
    def d_in(self) -> int:
        # trailing dims: layer-stacked (scan) leaves carry lead dims
        return self.qweight.shape[-2] * 2

    @property
    def d_out(self) -> int:
        return self.qweight.shape[-1]

    @property
    def act_spec(self) -> QuantSpec:
        return QuantSpec(
            bits=self.act_bits, clip_ratio=self.clip_ratio, group_size=self.act_group
        )


def make_qlinear(
    q_out_in: jnp.ndarray,  # int8 (d_out, d_in) from the LRC/GPTQ solver
    scales: jnp.ndarray,  # (d_out, 1)
    u: Optional[jnp.ndarray] = None,
    v: Optional[jnp.ndarray] = None,
    *,
    act_bits: int = 4,
    act_group: Optional[int] = None,
    clip_ratio: float = 1.0,
    impl: str = "sim",
    lr_dtype=jnp.bfloat16,
    ctx: Optional["KernelContext"] = None,
    name: Optional[str] = None,
) -> QLinear:
    q_in_out = jnp.asarray(q_out_in, jnp.int8).T  # (d_in, d_out)
    packed = pack_int4(q_in_out.T).T  # pack along d_in
    return QLinear(
        qweight=packed,
        w_scale=jnp.asarray(scales, jnp.float32).reshape(-1),
        u=None if u is None else jnp.asarray(u, lr_dtype),
        v=None if v is None else jnp.asarray(v, lr_dtype),
        act_bits=act_bits,
        act_group=act_group,
        clip_ratio=clip_ratio,
        impl=impl,
        ctx=ctx,
        name=name,
    )


def _unpack_w(q: QLinear) -> jnp.ndarray:
    """packed (d_in//2, d_out) -> int8 (d_in, d_out)."""
    return unpack_int4(q.qweight.T).T


def _lowrank(q: QLinear, x: jnp.ndarray) -> jnp.ndarray:
    """(x V) Uᵀ on the unquantized activations, in the LR dtype."""
    xv = x.astype(q.v.dtype) @ q.v  # (..., k)
    return xv @ q.u.T.astype(q.v.dtype)  # (..., d_out)


def _apply_sim(q: QLinear, x: jnp.ndarray) -> jnp.ndarray:
    w = _unpack_w(q).astype(jnp.float32) * q.w_scale[None, :]
    xq = fake_quant_act(x, q.act_spec).astype(jnp.float32)
    y = xq @ w
    if q.u is not None:
        y = y + _lowrank(q, x).astype(jnp.float32)
    return y.astype(x.dtype)


def _apply_int8(q: QLinear, x: jnp.ndarray) -> jnp.ndarray:
    """Integer GEMM path. Per-token scales; optional per-group-128 scales."""
    wq = _unpack_w(q)  # int8 (d_in, d_out)
    xq, sx = quantize_act(x, q.act_spec)  # int8, f32
    dims = (((x.ndim - 1,), (0,)), ((), ()))
    if q.act_group is None:
        acc = jax.lax.dot_general(xq, wq, dims, preferred_element_type=jnp.int32)
        y = acc.astype(jnp.float32) * sx * q.w_scale
    else:
        g = q.act_group
        d_in, d_out = wq.shape
        ng = d_in // g
        xg = xq.reshape(*x.shape[:-1], ng, g)
        wg = wq.reshape(ng, g, d_out)
        accg = jnp.einsum(
            "...nk,nkd->...nd", xg, wg, preferred_element_type=jnp.int32
        )
        y = jnp.sum(accg.astype(jnp.float32) * sx[..., None], axis=-2) * q.w_scale
    if q.u is not None:
        y = y + _lowrank(q, x).astype(jnp.float32)
    return y.astype(x.dtype)


def _apply_pallas(q: QLinear, x: jnp.ndarray,
                  kernel_impl: Optional[str] = None) -> jnp.ndarray:
    """Pallas kernel paths.  Execution config comes from the layer's
    KernelContext (``q.ctx``; None -> the process default) with any
    per-layer plan override keyed by ``q.name`` or the layer's (K, N, R)
    shape.  ``kernel_impl=None`` defers to ``ctx.impl`` (usually "auto":
    the plan table with VMEM feasibility); ``"fused"`` pins the
    single-kernel path.

    Precision note: the kernels compute the (xV)Uᵀ correction in f32 VMEM
    from the (bf16-stored) factors, so outputs differ from the int8 path —
    which matmuls in the LR storage dtype — by ~bf16 epsilon of the LR term
    (the kernel paths are the more accurate of the two)."""
    from repro.kernels import ops

    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = ops.w4a4_lrc_forward(
        x2, q.qweight, q.w_scale, q.u, q.v, act_spec=q.act_spec,
        impl=kernel_impl, ctx=q.ctx, layer=q.name,
    )
    return y.reshape(*lead, q.d_out).astype(x.dtype)


def qlinear_apply(q: QLinear, x: jnp.ndarray) -> jnp.ndarray:
    if q.parallel is not None:
        # mesh-tagged layer: run the shard_map TP path (falls back to the
        # plain apply when no mesh is ambient, and strips the tag inside
        # the shard body, so this cannot recurse)
        from repro.distributed.tp import tp_qlinear_apply

        return tp_qlinear_apply(q, x)
    if q.impl == "sim":
        return _apply_sim(q, x)
    if q.impl == "int8":
        return _apply_int8(q, x)
    if q.impl in ("pallas", "fused"):
        # group-wise calibrated layers (paper Table 2) run the kernel paths
        # too: the prologue emits the (M, K/g) scale plane and the GEMM
        # dequantizes per group inside the K loop — no int8 demotion
        return _apply_pallas(q, x, None if q.impl == "pallas" else "fused")
    raise ValueError(f"unknown impl {q.impl!r}")


def apply_linear(w, x: jnp.ndarray) -> jnp.ndarray:
    """Dispatch: plain array → dense matmul; QLinear → W4A4+LRC path."""
    if isinstance(w, QLinear):
        return qlinear_apply(w, x)
    return x @ w.astype(x.dtype)


RETAG_IMPLS = ("sim", "int8", "pallas", "fused", "auto")


def retag_qlinear_impl(params, impl: Optional[str],
                       ctx: Optional["KernelContext"] = None):
    """Switch every QLinear leaf in a param tree to another execution path
    (e.g. the serving engine retags to "pallas" so decode runs the fused
    kernels) and/or attach a :class:`KernelContext`.  Non-QLinear leaves
    pass through unchanged.

    ``impl`` must be one of ``sim | int8 | pallas | fused | auto``, or None
    to leave every leaf's impl untouched (ctx-only attach) — typos raise
    ValueError instead of silently tagging an unusable impl.  ``"auto"``
    resolves at retag time: "pallas" when a compiled backend is attached,
    otherwise each leaf keeps its calibrated impl (the pallas interpreter
    would only slow CPU reference semantics down).  ``ctx`` is attached to
    every leaf when given (None leaves contexts unchanged)."""
    if impl is not None and impl not in RETAG_IMPLS:
        raise ValueError(f"unknown impl {impl!r}; "
                         f"expected one of {RETAG_IMPLS}")
    resolved = impl
    if impl == "auto":
        resolved = "pallas" if jax.default_backend() != "cpu" else None

    def _retag(leaf):
        if isinstance(leaf, QLinear):
            changes = {} if resolved is None else {"impl": resolved}
            if ctx is not None:
                changes["ctx"] = ctx
            return dataclasses.replace(leaf, **changes) if changes else leaf
        return leaf

    return jax.tree.map(_retag, params,
                        is_leaf=lambda l: isinstance(l, QLinear))
