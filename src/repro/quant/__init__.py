from repro.quant.qlinear import QLinear, apply_linear, make_qlinear
