"""Sequential per-layer LRC calibration — the paper's full pipeline:

  (1) QuaRot-style rotation fusion (repro.quant.rotate), then
  (2) "LRC works sequentially through the weight matrices of the model,
       computing activations for each weight matrix, obtaining the
       covariance and cross-covariances matrices needed to apply Algorithm 1
       ... before moving to the next layer."  (paper §3)

The walker keeps a running activation stream X (all calibration sequences),
and after solving each layer's weights it re-propagates the stream through
the QUANTIZED layer, so later layers calibrate against the actual deployed
inputs (same discipline as GPTQ/QuaRot).

Supported families: dense / vlm, ssm (in/out projections), moe (MLA
projections + shared and routed experts with per-expert statistics).
Checkpointed per layer → a killed calibration resumes where it stopped.
"""

from __future__ import annotations

import dataclasses
import pickle
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.lrc import lrc_solve, quantize_baseline, svd_correction
from repro.core.numerics import ensure_x64
from repro.core.quantizers import QuantSpec, dequantize_weight
from repro.core.stats import accumulate_stats, finalize_stats, init_stats
from repro.core.hadamard import apply_rotation
from repro.models.common import (
    attention,
    causal_mask,
    mlp_block,
    prefix_lm_mask,
    rms_norm,
    rope,
)
from repro.models.transformer import embed_tokens
from repro.quant.policy import QuantPolicy
from repro.quant.qlinear import QLinear, apply_linear, make_qlinear
from repro.quant.rotate import rotate_model


# ---------------------------------------------------------------------------
# single-site solver
# ---------------------------------------------------------------------------


def collect_stats(acts, spec_a: QuantSpec, pre_rot: bool = False):
    """acts: (..., d) activation batch → finalized CalibStats (float64)."""
    ensure_x64()
    x = acts.reshape(-1, acts.shape[-1])
    if pre_rot:
        x = apply_rotation(x, x.shape[-1])
    st = init_stats(x.shape[-1])
    chunk = 65536
    for i in range(0, x.shape[0], chunk):
        st = accumulate_stats(st, x[i : i + chunk], spec_a)
    return finalize_stats(st)


def solve_site(w, stats, policy: QuantPolicy, pre_rot: bool = False,
               name: str = None) -> QLinear:
    """w: model-layout (d_in, d_out).  Solves Ŵ, (U, V) per the policy.
    ``name`` tags the QLinear (static metadata) so per-layer plan overrides
    in a KernelContext can target it by layer name."""
    w_paper = jnp.asarray(w, jnp.float64).T  # (d_out, d_in)
    spec_w = QuantSpec(bits=policy.bits)
    k = policy.rank(w.shape[0], w.shape[1])
    if policy.correction == "lrc" and k > 0:
        res = lrc_solve(
            w_paper, stats, spec_w, k=k,
            iters=policy.lrc_iters, quant_method=policy.quant_method,
        )
        q, s, u, v = res.qweight, res.scales, res.u, res.v
    elif policy.correction == "svd" and k > 0:
        q, s, w_hat = quantize_baseline(
            w_paper, stats, spec_w, quant_method=policy.quant_method, hessian="x"
        )
        u, v = svd_correction(w_paper, w_hat, k)
    else:
        q, s, _ = quantize_baseline(
            w_paper, stats, spec_w, quant_method=policy.quant_method, hessian="x"
        )
        u = v = None
    return make_qlinear(
        q, s, u, v,
        act_bits=policy.act_bits,
        # per-layer granularity: the policy's act_group_overrides can give
        # one layer its own scale group (or pin it back to per-token)
        act_group=policy.act_group_for(name),
        clip_ratio=policy.clip_ratio,
        impl=policy.impl,
        name=name,
    )


def _act_spec(policy: QuantPolicy) -> QuantSpec:
    return QuantSpec(
        bits=policy.act_bits, clip_ratio=policy.clip_ratio, group_size=policy.act_group
    )


# ---------------------------------------------------------------------------
# dense / vlm walker
# ---------------------------------------------------------------------------


def _dense_layer_walk(cfg, lp, x, positions, mask, policy):
    """Quantize one dense layer; returns (quantized layer params, new x)."""
    spec_a = _act_spec(policy)
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    st = collect_stats(h, spec_a)
    qattn = {}
    for name in ("wq", "wk", "wv"):
        qattn[name] = solve_site(lp["attn"][name], st, policy,
                                 name=f"attn/{name}")

    # attention with the QUANTIZED projections (deployment-faithful stream)
    b, s, _ = x.shape
    hh, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = apply_linear(qattn["wq"], h).reshape(b, s, hh, hd)
    k = apply_linear(qattn["wk"], h).reshape(b, s, kh, hd)
    v = apply_linear(qattn["wv"], h).reshape(b, s, kh, hd)
    if cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    pre_o = attention(q, k, v, mask, 1.0 / (hd**0.5)).reshape(b, s, hh * hd)

    st_o = collect_stats(pre_o, spec_a)
    qattn["wo"] = solve_site(lp["attn"]["wo"], st_o, policy, name="attn/wo")
    x = x + apply_linear(qattn["wo"], pre_o)

    h2 = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    st2 = collect_stats(h2, spec_a)
    qmlp = {
        "wg": solve_site(lp["mlp"]["wg"], st2, policy, name="mlp/wg"),
        "wu": solve_site(lp["mlp"]["wu"], st2, policy, name="mlp/wu"),
    }
    g = apply_linear(qmlp["wg"], h2)
    u = apply_linear(qmlp["wu"], h2)
    hidden = (jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g, approximate=True)) * u
    st3 = collect_stats(hidden, spec_a)
    qmlp["wd"] = solve_site(lp["mlp"]["wd"], st3, policy, name="mlp/wd")
    x = x + apply_linear(qmlp["wd"], hidden)

    qlp = dict(lp)
    qlp["attn"] = qattn
    qlp["mlp"] = qmlp
    return qlp, x


def _stack_layers(layer_list):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layer_list)


def _quantize_dense(cfg, params, tokens, policy, patches=None, progress=None,
                    resume_dir: Optional[Path] = None):
    x = embed_tokens(cfg, params, tokens).astype(jnp.float32)
    if patches is not None:
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    if cfg.family == "vlm" and patches is not None:
        mask = prefix_lm_mask(s, s, patches.shape[1], 0)
    else:
        mask = causal_mask(s, s, 0)

    new_layers = []
    for l in range(cfg.n_layers):
        ck = resume_dir / f"layer_{l:03d}.pkl" if resume_dir else None
        if ck is not None and ck.exists():
            with open(ck, "rb") as f:
                qlp, x = pickle.load(f)
            qlp = jax.tree.map(jnp.asarray, qlp)
            x = jnp.asarray(x)
        else:
            lp = jax.tree.map(lambda a: a[l], params["layers"])
            qlp, x = _dense_layer_walk(cfg, lp, x, positions, mask, policy)
            if ck is not None:
                ck.parent.mkdir(parents=True, exist_ok=True)
                with open(ck, "wb") as f:
                    pickle.dump(
                        (jax.tree.map(lambda a: jax.device_get(a), qlp),
                         jax.device_get(x)), f)
        new_layers.append(qlp)
        if progress:
            progress(l, cfg.n_layers)
    out = dict(params)
    out["layers"] = _stack_layers(new_layers)
    return out


# ---------------------------------------------------------------------------
# ssm walker
# ---------------------------------------------------------------------------


def _quantize_ssm(cfg, params, tokens, policy, progress=None, resume_dir=None):
    from repro.models.mamba2 import mamba_core

    spec_a = _act_spec(policy)
    x = embed_tokens(cfg, params, tokens).astype(jnp.float32)
    new_layers = []
    for l in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[l], params["layers"])
        h = rms_norm(x, lp["norm"], cfg.norm_eps)
        st = collect_stats(h, spec_a)
        q_in = solve_site(lp["in_proj"], st, policy)
        lp_q = dict(lp, in_proj=q_in)
        y, _ = mamba_core(cfg, lp_q, h, None)
        st2 = collect_stats(y, spec_a)
        q_out = solve_site(lp["out_proj"], st2, policy)
        lp_q["out_proj"] = q_out
        x = x + apply_linear(q_out, y)
        new_layers.append(lp_q)
        if progress:
            progress(l, cfg.n_layers)
    out = dict(params)
    out["layers"] = _stack_layers(new_layers)
    return out


# ---------------------------------------------------------------------------
# moe (deepseek) walker
# ---------------------------------------------------------------------------


def _solve_expert_sites(cfg, experts, x_tok, weights, policy):
    """Per-expert statistics: each routed expert calibrates on the tokens the
    router actually sends it (paper quantizes Mixtral the same way)."""
    spec_a = _act_spec(policy)
    e = cfg.n_experts
    qg, qu, qd = [], [], []
    for ei in range(e):
        sel = weights[:, ei] > 0
        # guard: experts with too few routed tokens fall back to all tokens
        xt = jnp.where(sel[:, None], x_tok, 0.0)
        n_sel = int(jnp.sum(sel))
        xe = x_tok[sel] if n_sel >= 8 else x_tok
        st = collect_stats(xe, spec_a)
        wg, wu, wd = experts["wg"][ei], experts["wu"][ei], experts["wd"][ei]
        qge = solve_site(wg, st, policy)
        que = solve_site(wu, st, policy)
        hidden = jax.nn.silu(apply_linear(qge, xe)) * apply_linear(que, xe)
        st2 = collect_stats(hidden, spec_a)
        qde = solve_site(wd, st2, policy)
        qg.append(qge)
        qu.append(que)
        qd.append(qde)
    stack = lambda qs: jax.tree.map(lambda *xs: jnp.stack(xs), *qs)
    return {"wg": stack(qg), "wu": stack(qu), "wd": stack(qd)}


def _moe_layer_walk(cfg, lp, x, positions, mask, policy, moe: bool):
    from repro.models.mla import mla_attention_block
    from repro.models.moe import router_weights

    spec_a = _act_spec(policy)
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    st_h = collect_stats(h, spec_a)
    qattn = dict(lp["attn"])
    if "wq_a" in qattn:
        qattn["wq_a"] = solve_site(lp["attn"]["wq_a"], st_h, policy)
        cq = rms_norm(apply_linear(qattn["wq_a"], h), lp["attn"]["q_norm"], cfg.norm_eps)
        qattn["wq_b"] = solve_site(lp["attn"]["wq_b"], collect_stats(cq, spec_a), policy)
    else:
        qattn["wq"] = solve_site(lp["attn"]["wq"], st_h, policy)
    qattn["wkv_a"] = solve_site(lp["attn"]["wkv_a"], st_h, policy)
    kv = apply_linear(qattn["wkv_a"], h)
    c_kv = rms_norm(kv[..., : cfg.kv_lora_rank], lp["attn"]["kv_norm"], cfg.norm_eps)
    qattn["wkv_b"] = solve_site(lp["attn"]["wkv_b"], collect_stats(c_kv, spec_a), policy)

    # run quantized MLA to get pre-o activations: reuse block with wo = identity?
    # simpler: temporarily use FP wo to get attn out then subtract — instead we
    # capture pre-o by calling the block internals
    lp_tmp = dict(lp, attn=dict(qattn, wo=jnp.eye(lp["attn"]["wo"].shape[0], dtype=x.dtype)))
    pre_o, _ = mla_attention_block(cfg, lp_tmp["attn"], h, positions, mask, None)
    qattn["wo"] = solve_site(lp["attn"]["wo"], collect_stats(pre_o, spec_a), policy)
    x = x + apply_linear(qattn["wo"], pre_o)

    h2 = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    st2 = collect_stats(h2, spec_a)
    qlp = dict(lp, attn=qattn)
    if moe:
        qmoe = dict(lp["moe"])
        if "shared" in qmoe:
            qsh = {
                "wg": solve_site(qmoe["shared"]["wg"], st2, policy),
                "wu": solve_site(qmoe["shared"]["wu"], st2, policy),
            }
            hid = jax.nn.silu(apply_linear(qsh["wg"], h2)) * apply_linear(qsh["wu"], h2)
            qsh["wd"] = solve_site(qmoe["shared"]["wd"], collect_stats(hid, spec_a), policy)
            qmoe["shared"] = qsh
        xt = h2.reshape(-1, h2.shape[-1])
        weights, _ = router_weights(cfg, lp["moe"], xt)
        qmoe["experts"] = _solve_expert_sites(cfg, lp["moe"]["experts"], xt, weights, policy)
        qlp["moe"] = qmoe
        from repro.models.moe import moe_block

        x = x + moe_block(cfg, qmoe, h2, impl="dense")
    else:
        qmlp = {
            "wg": solve_site(lp["mlp"]["wg"], st2, policy),
            "wu": solve_site(lp["mlp"]["wu"], st2, policy),
        }
        hid = jax.nn.silu(apply_linear(qmlp["wg"], h2)) * apply_linear(qmlp["wu"], h2)
        qmlp["wd"] = solve_site(lp["mlp"]["wd"], collect_stats(hid, spec_a), policy)
        qlp["mlp"] = qmlp
        x = x + apply_linear(qmlp["wd"], hid)
    return qlp, x


def _quantize_moe(cfg, params, tokens, policy, progress=None, resume_dir=None):
    x = embed_tokens(cfg, params, tokens).astype(jnp.float32)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    mask = causal_mask(s, s, 0)
    out = dict(params)
    done = 0
    total = cfg.n_layers
    for group, moe in (("dense_layers", False), ("moe_layers", True)):
        if group not in params:
            continue
        n = jax.tree.leaves(params[group])[0].shape[0]
        new_layers = []
        for l in range(n):
            lp = jax.tree.map(lambda a: a[l], params[group])
            qlp, x = _moe_layer_walk(cfg, lp, x, positions, mask, policy, moe)
            new_layers.append(qlp)
            done += 1
            if progress:
                progress(done, total)
        out[group] = _stack_layers(new_layers)
    return out


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def quantize_model(
    cfg,
    params,
    calib_tokens,
    policy: QuantPolicy,
    rotate: bool = True,
    patches=None,
    progress=None,
    resume_dir: Optional[str] = None,
):
    """Returns params with policy-selected weights replaced by solved
    QLinear leaves.  ``calib_tokens``: (n_seq, S) int32."""
    ensure_x64()
    if rotate:
        params = rotate_model(cfg, params)
    rd = Path(resume_dir) if resume_dir else None
    if cfg.family in ("dense", "vlm"):
        return _quantize_dense(cfg, params, calib_tokens, policy,
                               patches=patches, progress=progress, resume_dir=rd)
    if cfg.family == "ssm":
        return _quantize_ssm(cfg, params, calib_tokens, policy,
                             progress=progress, resume_dir=rd)
    if cfg.family == "moe":
        return _quantize_moe(cfg, params, calib_tokens, policy,
                             progress=progress, resume_dir=rd)
    raise NotImplementedError(
        f"calibration walker not implemented for family {cfg.family!r}"
    )
