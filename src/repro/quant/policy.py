"""Which weights get W4A4 + LRC treatment, and at what rank.

Follows the paper's setup: every transformer linear (attention + MLP +
expert + MLA projections + mamba in/out projections) is quantized; the
embedding table, lm head, positional tables, router, norms, convs and SSM
scan parameters stay in full precision (QuaRot keeps the same split).

``rank_frac`` — the paper's headline knob: low-rank size as a fraction of
min(d_in, d_out) (10% ⇒ >50% gap recovery; 30% ⇒ lossless; Fig. 2).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional


_QUANT_PATTERNS = [
    r"(attn|xattn)/w[qkvo]$",
    r"attn/w(q|kv)_[ab]$",
    r"(mlp|shared)/w[guid]$",
    r"(mlp|shared)/wo$",
    r"experts/w[gud]$",
    r"in_proj$",
    r"out_proj$",
    r"mtp/proj$",
]
_QUANT_RE = re.compile("|".join(f"(?:{p})" for p in _QUANT_PATTERNS))


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    bits: int = 4
    act_bits: int = 4
    act_group: Optional[int] = None  # paper Table 2: 128
    rank_frac: float = 0.10  # 0.0 disables the low-rank correction
    clip_ratio: float = 0.9
    impl: str = "int8"
    lrc_iters: int = 1
    quant_method: str = "gptq"  # gptq | rtn
    correction: str = "lrc"  # lrc | svd | none
    kv_cache_bits: Optional[int] = None  # optional int8 KV-cache quant

    def should_quantize(self, path_str: str, shape) -> bool:
        if len(shape) < 2:
            return False
        return bool(_QUANT_RE.search(path_str))

    def rank(self, d_in: int, d_out: int) -> int:
        if self.rank_frac <= 0:
            return 0
        return max(1, int(round(self.rank_frac * min(d_in, d_out))))


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)
