"""Which weights get W4A4 + LRC treatment, and at what rank.

Follows the paper's setup: every transformer linear (attention + MLP +
expert + MLA projections + mamba in/out projections) is quantized; the
embedding table, lm head, positional tables, router, norms, convs and SSM
scan parameters stay in full precision (QuaRot keeps the same split).

``rank_frac`` — the paper's headline knob: low-rank size as a fraction of
min(d_in, d_out) (10% ⇒ >50% gap recovery; 30% ⇒ lossless; Fig. 2).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional


_QUANT_PATTERNS = [
    r"(attn|xattn)/w[qkvo]$",
    r"attn/w(q|kv)_[ab]$",
    r"(mlp|shared)/w[guid]$",
    r"(mlp|shared)/wo$",
    r"experts/w[gud]$",
    r"in_proj$",
    r"out_proj$",
    r"mtp/proj$",
]
_QUANT_RE = re.compile("|".join(f"(?:{p})" for p in _QUANT_PATTERNS))


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    bits: int = 4
    act_bits: int = 4
    act_group: Optional[int] = None  # paper Table 2: 128
    # Per-layer activation-group overrides keyed by layer name (the
    # calibration walker's tags, e.g. "mlp/wd"): value None forces a layer
    # back to per-token while act_group covers the rest; an int sets that
    # layer's own group.  Stored as a sorted item tuple so the frozen
    # policy stays hashable.
    act_group_overrides: tuple = ()
    rank_frac: float = 0.10  # 0.0 disables the low-rank correction
    clip_ratio: float = 0.9
    impl: str = "int8"
    lrc_iters: int = 1
    quant_method: str = "gptq"  # gptq | rtn
    correction: str = "lrc"  # lrc | svd | none
    kv_cache_bits: Optional[int] = None  # optional int8 KV-cache quant

    def __post_init__(self):
        ovr = self.act_group_overrides
        # normalize ANY accepted spelling — dict, iterable of (name, group)
        # pairs (tuples or JSON-style lists) — to ONE canonical sorted
        # tuple form, so semantically equal policies stay value-equal and
        # hashable regardless of how the caller spelled the overrides
        if isinstance(ovr, dict):
            ovr = ovr.items()
        ovr = tuple(tuple(e) if isinstance(e, (tuple, list)) else e
                    for e in ovr)
        for entry in ovr:
            if (not isinstance(entry, tuple) or len(entry) != 2
                    or not isinstance(entry[0], str)
                    or isinstance(entry[1], bool)  # True would silently
                    # become group size 1 (k % True == 0 always holds)
                    or not (entry[1] is None
                            or (isinstance(entry[1], int) and entry[1] > 0))):
                raise ValueError(
                    f"act_group_overrides entries must map a layer-name "
                    f"string to a positive int group (or None = per-token), "
                    f"got {entry!r}")
        object.__setattr__(self, "act_group_overrides",
                           tuple(sorted(ovr, key=lambda e: e[0])))

    def should_quantize(self, path_str: str, shape) -> bool:
        if len(shape) < 2:
            return False
        return bool(_QUANT_RE.search(path_str))

    def act_group_for(self, name: Optional[str]) -> Optional[int]:
        """The activation scale group for one layer: the per-layer override
        when ``name`` matches one, else the policy-wide ``act_group``.
        Keys match exactly or as a "/"-delimited path suffix, so the
        walker's short tags ("mlp/wd") and the shell's full param-tree
        paths ("layers/mlp/wd") resolve to the same override — the same
        suffix discipline ``should_quantize``'s patterns use."""
        if name is not None:
            for key, group in self.act_group_overrides:
                if name == key or name.endswith("/" + key):
                    return group
        return self.act_group

    def rank(self, d_in: int, d_out: int) -> int:
        if self.rank_frac <= 0:
            return 0
        return max(1, int(round(self.rank_frac * min(d_in, d_out))))


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)
