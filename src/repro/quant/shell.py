"""Structure-only quantization: map a params tree to its quantized layout
(QLinear leaves) without running any calibration.

Used by the dry-run (under ``jax.eval_shape`` → no allocation) so the
512-device serve_step lowers with the REAL W4A4+LRC memory layout: packed
int4 weights, f32 scales, bf16 U/V.  The calibrating quantizer
(repro.quant.calibrate) produces the same structure with solved values.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.policy import QuantPolicy, path_str
from repro.quant.qlinear import QLinear


def quantize_shell(params, policy: QuantPolicy):
    """Replace policy-selected weight leaves with zero-value QLinear pytrees
    of the right shapes/dtypes (leading stack/expert dims preserved)."""

    def convert(path, leaf):
        ps = path_str(path)
        if not policy.should_quantize(ps, leaf.shape):
            return leaf
        *lead, d_in, d_out = leaf.shape
        lead = tuple(lead)
        k = policy.rank(d_in, d_out)
        return QLinear(
            qweight=jnp.zeros(lead + (d_in // 2, d_out), jnp.uint8),
            w_scale=jnp.zeros(lead + (d_out,), jnp.float32),
            u=jnp.zeros(lead + (d_out, k), jnp.bfloat16) if k else None,
            v=jnp.zeros(lead + (d_in, k), jnp.bfloat16) if k else None,
            bits=policy.bits,
            act_bits=policy.act_bits,
            # per-layer granularity, like the calibrating walker — the
            # shell must lower the same (pytree-static) kernel config
            act_group=policy.act_group_for(ps),
            clip_ratio=policy.clip_ratio,
            impl=policy.impl,
            name=ps,  # per-layer KernelContext overrides key on this
        )

    return jax.tree_util.tree_map_with_path(convert, params)


def quantized_param_shapes(cfg, policy: QuantPolicy):
    """ShapeDtypeStruct tree of the quantized model (no allocation)."""
    from repro.models import model as model_lib

    def build(key):
        params = model_lib.init_params(cfg, key)
        return quantize_shell(params, policy)

    return jax.eval_shape(build, jax.ShapeDtypeStruct((2,), jnp.uint32))
