"""QuaRot-style rotation fusion on model parameters (LRC stage 1).

Residual-stream rotation R (Hadamard-structured, orthogonal):
  * RMSNorm γ's are folded into their reader weights (norm becomes pure RMS,
    which commutes with any orthogonal R);
  * readers  (x @ W, x in the stream):  W ← Rᵀ W
  * writers  (y writes to the stream):  W ← W R
  * embedding rows:                     E ← E R
  * lm head: γ_final folded then W ← Rᵀ W; tied embeddings are UNTIED first
    (γ cannot be folded into a shared table) — `unembed` prefers the
    materialized head.

Exactness: model(x) is bit-identical up to float error (tested).  Supported
families: dense / vlm (full residual rotation) and ssm (in/out projections).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.hadamard import hadamard_matrix


def _fold_gamma(w, gamma):
    return (gamma.astype(jnp.float32)[:, None] * w.astype(jnp.float32)).astype(w.dtype)


def _read(w, r):  # W ← Rᵀ W  (stacked (L, d, o) or (d, o))
    w32 = w.astype(jnp.float32)
    return jnp.einsum("ij,...jo->...io", r.T, w32).astype(w.dtype)


def _write(w, r):  # W ← W R
    w32 = w.astype(jnp.float32)
    return jnp.einsum("...di,ij->...dj", w32, r).astype(w.dtype)


def rotate_dense(cfg, params, seed: int = 0):
    """Rotate a dense/vlm transformer's params. Returns new params."""
    d = cfg.d_model
    r = jnp.asarray(hadamard_matrix(d, seed), jnp.float32)
    p = dict(params)
    layers = dict(p["layers"])
    attn = dict(layers["attn"])
    mlp = dict(layers["mlp"])

    gamma_a = layers["attn_norm"]  # (L, d)
    gamma_m = layers["mlp_norm"]

    def fold_stacked(w, gamma):
        w32 = w.astype(jnp.float32)
        return (gamma.astype(jnp.float32)[:, :, None] * w32).astype(w.dtype)

    for k in ("wq", "wk", "wv"):
        attn[k] = _read(fold_stacked(attn[k], gamma_a), r)
    attn["wo"] = _write(attn["wo"], r)
    for k in ("wg", "wu"):
        mlp[k] = _read(fold_stacked(mlp[k], gamma_m), r)
    mlp["wd"] = _write(mlp["wd"], r)
    layers["attn"] = attn
    layers["mlp"] = mlp
    layers["attn_norm"] = jnp.ones_like(gamma_a)
    layers["mlp_norm"] = jnp.ones_like(gamma_m)
    p["layers"] = layers

    # untie + fold final norm into the head, then rotate
    head = p["lm_head"] if "lm_head" in p else p["embed"].T
    head = _fold_gamma(head, p["final_norm"])
    p["lm_head"] = _read(head, r)
    p["final_norm"] = jnp.ones_like(p["final_norm"])
    p["embed"] = _write(p["embed"], r)
    return p


def rotate_ssm(cfg, params, seed: int = 0):
    """Mamba2 stack: rotate the residual stream around in_proj/out_proj.
    (The SSM internals see unrotated activations — LRC targets the two
    projections, DESIGN.md §Arch-applicability.)"""
    d = cfg.d_model
    r = jnp.asarray(hadamard_matrix(d, seed), jnp.float32)
    p = dict(params)
    layers = dict(p["layers"])
    gamma = layers["norm"]  # (L, d) pre-norm, folded into in_proj
    w32 = layers["in_proj"].astype(jnp.float32)
    layers["in_proj"] = _read(
        (gamma.astype(jnp.float32)[:, :, None] * w32).astype(layers["in_proj"].dtype), r
    )
    layers["norm"] = jnp.ones_like(gamma)
    layers["out_proj"] = _write(layers["out_proj"], r)
    p["layers"] = layers
    head = p["lm_head"] if "lm_head" in p else p["embed"].T
    head = _fold_gamma(head, p["final_norm"])
    p["lm_head"] = _read(head, r)
    p["final_norm"] = jnp.ones_like(p["final_norm"])
    p["embed"] = _write(p["embed"], r)
    return p


def rotate_model(cfg, params, seed: int = 0):
    if cfg.family in ("dense", "vlm"):
        return rotate_dense(cfg, params, seed)
    if cfg.family == "ssm":
        return rotate_ssm(cfg, params, seed)
    # moe / hybrid / encdec: rotation fusion is family-specific work beyond
    # the benchmark surface; LRC itself applies regardless (stats absorb the
    # basis).  Returned unchanged.
    return params
