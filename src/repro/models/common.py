"""Shared transformer building blocks (pure functions, bf16-friendly).

Every matmul goes through :func:`repro.quant.qlinear.apply_linear`, which
dispatches on the weight leaf type: a plain array runs a dense matmul; a
``QLinear`` pytree runs the paper's W4A4 + low-rank-correction path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.jaxcompat import get_abstract_mesh
from repro.quant.qlinear import apply_linear


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * gamma.astype(jnp.float32)).astype(dt)


def layer_norm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


def sinusoidal_positions(length: int, dim: int) -> jnp.ndarray:
    """Whisper-style sinusoidal embeddings (length, dim)."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = jnp.arange(length)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs[None, :]
    cos = jnp.cos(ang)[..., :, None, :]  # (..., seq, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


FREE = "free"  # unconstrained marker for shard_hint


def shard_hint(x: jnp.ndarray, axes: tuple) -> jnp.ndarray:
    """Soft sharding constraint (no-op without a mesh).

    §Perf finding: without this, GSPMD shards attention's HEAD_DIM (e.g.
    96→6 per device) instead of the head axis, computing partial logits on
    every device and ALL-REDUCING the full (B,H,S,S) tensor — 256 GiB per
    layer for phi-3 prefill_32k.  Constraining q/k/v to head-sharded layout
    removes that collective entirely and shards the logits 16-way.

    ``axes`` entries: mesh-axis name (shard, with divisibility guard →
    FREE), None (force replicated), or FREE (leave to GSPMD).
    """
    mesh = get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    P = jax.sharding.PartitionSpec
    spec = []
    for dim, a in zip(x.shape, axes):
        if a is None:
            spec.append(None)  # explicit replication
        elif a != FREE and a in mesh.shape and dim % mesh.shape[a] == 0:
            spec.append(a)
        else:
            spec.append(P.UNCONSTRAINED)
    if all(s is P.UNCONSTRAINED for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def attn_qkv_hints(q, k, v):
    """Sharding scheme for attention inputs (B, S, H|K, D):

      * heads divide the model axis → head-sharded (classic TP attention);
      * otherwise, for prefill, shard the QUERY-SEQUENCE over the model axis
        and replicate the (small) K/V — context-parallel attention: logits
        stay seq-sharded, no partial-contraction all-reduce (the smollm-class
        fix, §Perf);
      * decode (q_len == 1) is left to GSPMD (logits are tiny).
    """
    mesh = get_abstract_mesh()
    if mesh is None or not mesh.axis_names or "model" not in mesh.shape:
        return q, k, v
    tp = mesh.shape["model"]
    if q.shape[2] % tp == 0 and k.shape[2] % tp == 0:
        hint = (FREE, FREE, "model", FREE)
        return shard_hint(q, hint), shard_hint(k, hint), shard_hint(v, hint)
    if q.shape[1] > 1 and q.shape[1] % tp == 0:
        q = shard_hint(q, (FREE, "model", None, None))
        k = shard_hint(k, (FREE, FREE, None, None))
        v = shard_hint(v, (FREE, FREE, None, None))
    return q, k, v


def cache_update(cache_arr, update, offset, axis: int = 1):
    """dynamic_update_slice along ``axis`` at ``offset`` with dtype-consistent
    indices (x64 mode in the calibration process must not leak int64)."""
    zero = jnp.zeros((), offset.dtype) if hasattr(offset, "dtype") else 0
    idx = [zero] * cache_arr.ndim
    idx[axis] = offset
    return jax.lax.dynamic_update_slice(cache_arr, update.astype(cache_arr.dtype), tuple(idx))


def causal_mask(q_len: int, kv_len: int, q_offset) -> jnp.ndarray:
    """(q_len, kv_len) boolean mask; query i attends kv j iff j <= i+offset."""
    qi = jnp.arange(q_len)[:, None] + q_offset
    kj = jnp.arange(kv_len)[None, :]
    return kj <= qi


def prefix_lm_mask(q_len: int, kv_len: int, prefix_len: int, q_offset) -> jnp.ndarray:
    """PaliGemma-style: bidirectional over the prefix, causal after."""
    m = causal_mask(q_len, kv_len, q_offset)
    kj = jnp.arange(kv_len)[None, :]
    return m | (kj < prefix_len)


def attention(
    q: jnp.ndarray,  # (B, Sq, H, Dq)
    k: jnp.ndarray,  # (B, Skv, K, Dq)
    v: jnp.ndarray,  # (B, Skv, K, Dv)
    mask,  # (Sq, Skv) or per-row (B, Sq, Skv) bool, or None
    scale: float,
) -> jnp.ndarray:
    """GQA attention: H query heads grouped over K kv heads. Returns
    (B, Sq, H, Dv).  Softmax in f32.

    A 2-D mask is shared across the batch; a 3-D mask carries one (Sq, Skv)
    plane per batch row — the batched-decode case where co-tenant requests
    sit at different sequence lengths.  Masked positions contribute exactly
    0.0 to the output (exp(-1e30 - m) underflows), so results are bitwise
    invariant to whatever finite garbage sits in masked cache slots."""
    b, sq, h, dq = q.shape
    kheads = k.shape[2]
    g = h // kheads
    q = q.reshape(b, sq, kheads, g, dq)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    if mask is not None:
        m = mask[None, None, None] if mask.ndim == 2 else mask[:, None, None]
        logits = jnp.where(m, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h, v.shape[-1])


def sharded_attention(q, k, v, mask, scale: float):
    """:func:`attention` with its partitioning pinned under a mesh.

    Sharding CONSTRAINTS pin tensor layouts but not GSPMD's op strategy —
    left alone it may still split attention's reduction dims (head_dim in
    the logit einsum, sequence in softmax/PV), computing partials plus an
    f32 all-reduce that is not bitwise vs single-device.  Running the whole
    attention in a shard_map makes the partitioning exact by construction:
    batch over "data" and heads over "model" when divisible (both batched
    dims — every (row, head) is computed whole on one shard, zero
    collectives in the body), everything replicated otherwise.  Falls back
    to the plain call when no mesh is ambient."""
    mesh = get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return attention(q, k, v, mask, scale)
    from repro.core.jaxcompat import shard_map

    P = jax.sharding.PartitionSpec
    axes = dict(mesh.shape)
    dp, tp = axes.get("data", 1), axes.get("model", 1)
    bax = "data" if (dp > 1 and q.shape[0] % dp == 0) else None
    hax = "model" if (tp > 1 and q.shape[2] % tp == 0
                      and k.shape[2] % tp == 0) else None
    qs = P(bax, None, hax, None)
    kvs = P(bax, None, hax, None)
    if mask is None:
        ins = (qs, kvs, kvs)
        args = (q, k, v)
        fn = lambda ql, kl, vl: attention(ql, kl, vl, None, scale)
    else:
        ms = P(None, None) if mask.ndim == 2 else P(bax, None, None)
        ins = (qs, kvs, kvs, ms)
        args = (q, k, v, mask)
        fn = lambda ql, kl, vl, ml: attention(ql, kl, vl, ml, scale)
    out = shard_map(fn, mesh=mesh, in_specs=ins,
                    out_specs=P(bax, None, hax, None),
                    check_vma=False,
                    axis_names={a for a in (bax, hax) if a})(*args)
    return out


def mlp_block(p: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    """Gated MLP: SwiGLU (silu) or GeGLU (gelu)."""
    g = apply_linear(p["wg"], x)
    u = apply_linear(p["wu"], x)
    if act == "silu":
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(g, approximate=True) * u
    return apply_linear(p["wd"], h)


def paged_cache_update(
    pages: jnp.ndarray,      # (NP, P, K, hd) one layer's page pool
    update: jnp.ndarray,     # (B, S, K, hd) new k or v rows
    block_table: jnp.ndarray,  # (B, MPB) int32 page ids, 0 = null page
    positions: jnp.ndarray,  # (B, S) absolute token positions
    valid: jnp.ndarray,      # (B, S) bool; False rows write to the null page
) -> jnp.ndarray:
    """Scatter per-token k/v rows into a paged pool.

    Token at absolute position p for batch row b lands in page
    ``block_table[b, p // P]`` at slot ``p % P``.  Invalid rows (padding,
    inactive slots) are redirected to page 0 — the reserved null page that
    the allocator never hands out — so a single fixed-shape scatter serves
    prefill chunks and masked batched decode alike.  Valid writes are
    page-disjoint across requests (each page has exactly one owner), so the
    scatter has no cross-request write conflicts; only null-page writes may
    collide, and nothing ever reads the null page unmasked."""
    b, s = positions.shape
    page_size = pages.shape[1]
    page = jnp.take_along_axis(block_table, positions // page_size, axis=1)
    page = jnp.where(valid, page, 0)
    within = positions % page_size
    return pages.at[page.reshape(-1), within.reshape(-1)].set(
        update.astype(pages.dtype).reshape(b * s, *update.shape[2:]))


def paged_cache_update_quantized(
    pages: jnp.ndarray,      # (NP, P, K, hd_packed) quantized page pool
    scales: jnp.ndarray,     # (NP, P, K, n_groups) f32 scale-plane sidecar
    update: jnp.ndarray,     # (B, S, K, hd) new k or v rows (float)
    block_table: jnp.ndarray,
    positions: jnp.ndarray,
    valid: jnp.ndarray,
    kv_spec,
):
    """Quantize-then-scatter: this step's k/v rows quantize through the
    canonical ``serve.kvquant`` spelling and land — data bytes AND scale
    plane — under exactly the :func:`paged_cache_update` page/slot
    indexing.  Quantization happens per token row BEFORE placement, so the
    stored bytes are invariant to which page a token lands in; the
    engine's bitwise page-placement/co-tenancy invariances carry over to
    quantized specs unchanged."""
    from repro.serve.kvquant import quantize_kv

    b, s = positions.shape
    page_size = pages.shape[1]
    page = jnp.take_along_axis(block_table, positions // page_size, axis=1)
    page = jnp.where(valid, page, 0)
    within = positions % page_size
    q, sc = quantize_kv(update, kv_spec)
    flat_p, flat_w = page.reshape(-1), within.reshape(-1)
    pages = pages.at[flat_p, flat_w].set(
        q.astype(pages.dtype).reshape(b * s, *q.shape[2:]))
    scales = scales.at[flat_p, flat_w].set(
        sc.astype(scales.dtype).reshape(b * s, *sc.shape[2:]))
    return pages, scales


def paged_gqa_attention_block(
    p: dict,
    x: jnp.ndarray,          # (B, S, D)
    positions: jnp.ndarray,  # (B, S)
    valid: jnp.ndarray,      # (B, S)
    cfg,
    mask,                    # (B, S, MPB * P) per-row bool
    pages_k: jnp.ndarray,    # (NP, P, K, hd)
    pages_v: jnp.ndarray,
    block_table: jnp.ndarray,  # (B, MPB)
):
    """GQA attention against a paged KV pool.  Writes this step's k/v into
    the owning pages, gathers each row's pages into a dense (B, MPB*P, ...)
    view, and attends under the caller's per-row mask.  Returns
    (out (B,S,D), new_pages_k, new_pages_v)."""
    b, s, _ = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = apply_linear(p["wq"], x).reshape(b, s, h, hd)
    k = apply_linear(p["wk"], x).reshape(b, s, kh, hd)
    v = apply_linear(p["wv"], x).reshape(b, s, kh, hd)
    q, k, v = attn_qkv_hints(q, k, v)
    if cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    pages_k = paged_cache_update(pages_k, k, block_table, positions, valid)
    pages_v = paged_cache_update(pages_v, v, block_table, positions, valid)
    kc = pages_k[block_table].reshape(b, -1, kh, hd).astype(x.dtype)
    vc = pages_v[block_table].reshape(b, -1, kh, hd).astype(x.dtype)
    out = sharded_attention(q, kc, vc, mask, scale=1.0 / (hd**0.5))
    out = apply_linear(p["wo"], out.reshape(b, s, h * hd))
    return out, pages_k, pages_v


def paged_gqa_attention_block_quantized(
    p: dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    valid: jnp.ndarray,
    cfg,
    mask,
    pages_k: jnp.ndarray,       # (NP, P, K, hd_packed) quantized pool
    pages_v: jnp.ndarray,
    scales_k: jnp.ndarray,      # (NP, P, K, n_groups) f32 sidecar
    scales_v: jnp.ndarray,
    block_table: jnp.ndarray,
    kv_spec,
):
    """The quantized-KV sibling of :func:`paged_gqa_attention_block`: k/v
    quantize at append time (``paged_cache_update_quantized``), the gather
    dequantizes each row's pages through THE canonical
    ``serve.kvquant.dequantize_kv`` spelling, and the identical
    :func:`attention` math runs on the dequantized f32 values — so the jnp
    serving path and the dequant-fused flash kernels attend over bitwise
    the same operands.  The f32/bf16 path stays in the separate function
    above, untouched: a float spec traces exactly the pre-KVSpec graph.

    Returns (out, new_pages_k, new_pages_v, new_scales_k, new_scales_v)."""
    from repro.serve.kvquant import dequantize_kv

    b, s, _ = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = apply_linear(p["wq"], x).reshape(b, s, h, hd)
    k = apply_linear(p["wk"], x).reshape(b, s, kh, hd)
    v = apply_linear(p["wv"], x).reshape(b, s, kh, hd)
    q, k, v = attn_qkv_hints(q, k, v)
    if cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    pages_k, scales_k = paged_cache_update_quantized(
        pages_k, scales_k, k, block_table, positions, valid, kv_spec)
    pages_v, scales_v = paged_cache_update_quantized(
        pages_v, scales_v, v, block_table, positions, valid, kv_spec)
    phd = kv_spec.packed_head_dim(hd)
    n_g = kv_spec.n_groups(hd)
    kc = dequantize_kv(pages_k[block_table].reshape(b, -1, kh, phd),
                       scales_k[block_table].reshape(b, -1, kh, n_g),
                       kv_spec, hd).astype(x.dtype)
    vc = dequantize_kv(pages_v[block_table].reshape(b, -1, kh, phd),
                       scales_v[block_table].reshape(b, -1, kh, n_g),
                       kv_spec, hd).astype(x.dtype)
    out = sharded_attention(q, kc, vc, mask, scale=1.0 / (hd**0.5))
    out = apply_linear(p["wo"], out.reshape(b, s, h * hd))
    return out, pages_k, pages_v, scales_k, scales_v


def gqa_attention_block(
    p: dict,
    x: jnp.ndarray,  # (B, S, D)
    positions: jnp.ndarray,  # (B, S)
    cfg,
    mask,
    cache=None,  # optional dict(k=(B,Smax,K,hd), v=..., offset scalar)
):
    """Returns (out (B,S,D), new_cache)."""
    b, s, _ = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = apply_linear(p["wq"], x).reshape(b, s, h, hd)
    k = apply_linear(p["wk"], x).reshape(b, s, kh, hd)
    v = apply_linear(p["wv"], x).reshape(b, s, kh, hd)
    q, k, v = attn_qkv_hints(q, k, v)  # heads- or seq-sharded (§Perf)
    if cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    new_cache = None
    if cache is not None:
        off = cache["offset"]
        kc = cache_update(cache["k"], k, off)
        vc = cache_update(cache["v"], v, off)
        new_cache = dict(k=kc, v=vc, offset=off + s)
        k, v = kc.astype(x.dtype), vc.astype(x.dtype)
    out = attention(q, k, v, mask, scale=1.0 / (hd**0.5))
    out = apply_linear(p["wo"], out.reshape(b, s, h * hd))
    return out, new_cache
