"""Zamba2-style hybrid: a stack of Mamba2 blocks with a SHARED full-attention
transformer block applied every ``hybrid_attn_every`` layers.

The shared block has a single weight copy (closure-captured, not stacked);
each of its applications keeps its own KV-cache slot.  Inside the layer scan
a ``lax.cond`` gates the shared block — XLA lowers this to a real runtime
conditional, so attention cost is only paid on the layers that use it.

Simplifications vs. the released Zamba2 (noted in DESIGN.md): one shared
block instead of two alternating; the shared block reads the residual stream
directly (no concat-with-embedding projection, no per-invocation LoRA).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import causal_mask, gqa_attention_block, mlp_block, rms_norm
from repro.models.mamba2 import init_mamba_cache, init_mamba_params, mamba_block
from repro.models.remat import maybe_remat, scan_layers
from repro.models.transformer import _init_linear, embed_tokens, unembed


def n_attn_apps(cfg) -> int:
    return cfg.n_layers // cfg.hybrid_attn_every


def init_params(cfg, key, max_seq: int = 0):
    dtype = jnp.dtype(cfg.dtype)
    k_embed, k_layers, k_shared, k_head = jax.random.split(key, 4)
    keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: init_mamba_params(cfg, k, jnp.float32))(keys)
    layers = jax.tree.map(lambda a: a.astype(dtype) if a.dtype == jnp.float32 and a.ndim > 1 else a, layers)
    ks = jax.random.split(k_shared, 8)
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    shared = {
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "attn": {
            "wq": _init_linear(ks[0], cfg.d_model, h * hd, dtype),
            "wk": _init_linear(ks[1], cfg.d_model, kh * hd, dtype),
            "wv": _init_linear(ks[2], cfg.d_model, kh * hd, dtype),
            "wo": _init_linear(ks[3], h * hd, cfg.d_model, dtype),
        },
        "mlp_norm": jnp.ones((cfg.d_model,), dtype),
        "mlp": {
            "wg": _init_linear(ks[4], cfg.d_model, cfg.d_ff, dtype),
            "wu": _init_linear(ks[5], cfg.d_model, cfg.d_ff, dtype),
            "wd": _init_linear(ks[6], cfg.d_ff, cfg.d_model, dtype),
        },
    }
    return {
        "embed": (jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype),
        "layers": layers,
        "shared": shared,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": _init_linear(k_head, cfg.d_model, cfg.vocab_size, dtype),
    }


def _shared_block(cfg, sp, x, positions, mask, attn_cache):
    h = rms_norm(x, sp["attn_norm"], cfg.norm_eps)
    a, new_cache = gqa_attention_block(sp["attn"], h, positions, cfg, mask, attn_cache)
    x = x + a
    h = rms_norm(x, sp["mlp_norm"], cfg.norm_eps)
    x = x + mlp_block(sp["mlp"], h, cfg.act)
    return x, new_cache


def _run(cfg, params, x, positions, mask, caches):
    """caches: None (training) or dict(mamba={conv (L,...), ssm (L,...)},
    attn={k (A,...), v (A,...), offset})."""
    every = cfg.hybrid_attn_every
    shared = params["shared"]
    use_cache = caches is not None
    seq = positions.shape[-1]

    if use_cache:
        attn_k, attn_v = caches["attn"]["k"], caches["attn"]["v"]
        offset = caches["attn"]["offset"]
    else:
        # training still needs the shared attention to run — no kv cache
        attn_k = attn_v = None
        offset = 0

    def body(carry, xs):
        if use_cache:
            x, ak, av = carry
            lp, (conv_c, ssm_c), i = xs
            mcache = dict(conv=conv_c, ssm=ssm_c)
        else:
            x = carry
            lp, i = xs
            mcache = None

        out, new_mcache = mamba_block(cfg, lp, x, mcache)
        x = x + out

        app_idx = i // every
        is_attn = (i % every) == (every - 1)

        if use_cache:

            def with_attn(op):
                x, ak, av = op
                c = dict(
                    k=jax.lax.dynamic_index_in_dim(ak, app_idx, 0, keepdims=False),
                    v=jax.lax.dynamic_index_in_dim(av, app_idx, 0, keepdims=False),
                    offset=offset,
                )
                y, nc = _shared_block(cfg, shared, x, positions, mask, c)
                ak = jax.lax.dynamic_update_index_in_dim(ak, nc["k"], app_idx, 0)
                av = jax.lax.dynamic_update_index_in_dim(av, nc["v"], app_idx, 0)
                return y, ak, av

            x, ak, av = jax.lax.cond(is_attn, with_attn, lambda op: op, (x, ak, av))
            return (x, ak, av), (new_mcache["conv"], new_mcache["ssm"])

        x = jax.lax.cond(
            is_attn,
            lambda z: _shared_block(cfg, shared, z, positions, mask, None)[0],
            lambda z: z,
            x,
        )
        return x, None

    idx = jnp.arange(cfg.n_layers)
    if use_cache:
        (x, ak, av), (nconv, nssm) = scan_layers(
            cfg,
            body,
            (x, attn_k, attn_v),
            (params["layers"], (caches["mamba"]["conv"], caches["mamba"]["ssm"]), idx),
        )
        new_caches = dict(
            mamba=dict(conv=nconv, ssm=nssm),
            attn=dict(k=ak, v=av, offset=offset + seq),
        )
        return x, new_caches
    x, _ = scan_layers(cfg, maybe_remat(cfg, body), x, (params["layers"], idx))
    return x, None


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    apps = n_attn_apps(cfg)
    mc = init_mamba_cache(cfg, batch, dtype)
    return dict(
        mamba=dict(
            conv=jnp.zeros((cfg.n_layers,) + mc["conv"].shape, dtype),
            ssm=jnp.zeros((cfg.n_layers,) + mc["ssm"].shape, jnp.float32),
        ),
        attn=dict(
            k=jnp.zeros((apps, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
            v=jnp.zeros((apps, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
            offset=jnp.zeros((), jnp.int32),
        ),
    )


def forward(cfg, params, tokens):
    x = embed_tokens(cfg, params, tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    mask = causal_mask(s, s, 0)
    x, _ = _run(cfg, params, x, positions, mask, None)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(cfg, params, x)


def prefill(cfg, params, tokens, caches):
    x = embed_tokens(cfg, params, tokens)
    b, s, _ = x.shape
    kv_len = caches["attn"]["k"].shape[2]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    mask = causal_mask(s, kv_len, 0)
    x, caches = _run(cfg, params, x, positions, mask, caches)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(cfg, params, x[:, -1:]), caches


def decode_step(cfg, params, tokens, caches):
    x = embed_tokens(cfg, params, tokens)
    b = x.shape[0]
    offset = caches["attn"]["offset"]
    positions = jnp.broadcast_to(offset, (b, 1))
    kv_len = caches["attn"]["k"].shape[2]
    mask = (jnp.arange(kv_len) <= offset)[None, :]
    x, caches = _run(cfg, params, x, positions, mask, caches)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(cfg, params, x), caches
