"""Per-layer activation-checkpoint policies and the layer-scan dispatcher."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def scan_layers(cfg, body, carry, xs):
    """``lax.scan`` over stacked layer params — or an unrolled python loop
    when cfg.unroll is set.

    Unrolling exists for the dry-run's cost extrapolation: XLA's
    ``cost_analysis`` counts a while-loop body ONCE (trip count is not
    multiplied in), so scanned-layer FLOPs/bytes/collectives are undercounted
    by ~L×.  The dry-run lowers 2 small UNROLLED variants (k1, k2 layers) and
    extrapolates linearly — exact for homogeneous stacks.
    """
    if not getattr(cfg, "unroll", False):
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys_list = []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys_list.append(y)
    if ys_list and ys_list[0] is None:
        return carry, None
    ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys_list)
    return carry, ys


def maybe_remat(cfg, body):
    """Wrap a scan body ``(carry, xs) -> (carry, ys)`` per cfg.remat."""
    if cfg.remat == "none":
        return body
    if cfg.remat == "full":
        return jax.checkpoint(body)
    if cfg.remat == "dots":
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )
    raise ValueError(cfg.remat)
