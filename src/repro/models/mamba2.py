"""Mamba-2 block — SSD (state-space duality) with chunked scan.

Recurrence per head (state N, head dim P):
    s_t = exp(dt_t·A) · s_{t-1} + dt_t · B_t ⊗ x_t          (N, P)
    y_t = C_t · s_t + D · x_t

Chunked algorithm (Dao & Gu 2024): the sequence is split into chunks of Q
steps; within a chunk the contribution is an attention-like quadratic form,
across chunks a short sequential scan carries the (N, P) states.  This keeps
the work O(L·Q·(N+P)) instead of O(L²) — the reason mamba2/zamba2 are the
`long_500k`-eligible architectures.

`ssd_naive` is the step-by-step oracle used in tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import rms_norm
from repro.quant.qlinear import apply_linear


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------


def init_mamba_params(cfg, key, dtype):
    ks = jax.random.split(key, 5)
    d = cfg.d_model
    di = cfg.d_inner
    g, n = cfg.ssm_groups, cfg.ssm_state
    h = cfg.ssm_nheads
    proj_out = 2 * di + 2 * g * n + h  # [z, x, B, C, dt]

    def lin(k, dim_in, dim_out):
        return (jax.random.normal(k, (dim_in, dim_out), jnp.float32) * dim_in**-0.5).astype(dtype)

    return {
        "norm": jnp.ones((d,), dtype),
        "in_proj": lin(ks[0], d, proj_out),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, cfg.conv_dim)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((cfg.conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "out_norm": jnp.ones((di,), dtype),
        "out_proj": lin(ks[2], di, d),
    }


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int, init_state=None):
    """x: (B,L,H,P); dt: (B,L,H); a: (H,) negative; b_mat/c_mat: (B,L,H,N)
    (already broadcast over heads).  Returns (y (B,L,H,P), final_state
    (B,H,N,P))."""
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    f32 = jnp.float32
    xr = x.reshape(bsz, nc, chunk, h, p)
    dtr = dt.reshape(bsz, nc, chunk, h).astype(f32)
    br = b_mat.reshape(bsz, nc, chunk, h, n)
    cr = c_mat.reshape(bsz, nc, chunk, h, n)

    la = dtr * a[None, None, None, :]  # log-decay per step (≤ 0)
    a_cs = jnp.cumsum(la, axis=2)  # inclusive cumsum within chunk
    a_sum = a_cs[:, :, -1, :]  # (B,nc,H)

    # ---- intra-chunk (quadratic) term
    # att(t,s) = C_t·B_s · exp(a_cs[t] - a_cs[s]) · dt_s   for s <= t
    scores = jnp.einsum("bcqhn,bcshn->bchqs", cr.astype(f32), br.astype(f32))
    diff = a_cs[:, :, :, None, :] - a_cs[:, :, None, :, :]  # (B,nc,t,s,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay_ts = jnp.where(
        tri[None, None, :, :, None], jnp.exp(jnp.clip(diff, -60.0, 0.0)), 0.0
    )
    att = (
        scores
        * decay_ts.transpose(0, 1, 4, 2, 3)  # (B,nc,H,t,s)
        * dtr.transpose(0, 1, 3, 2)[:, :, :, None, :]  # dt_s
    )
    y_intra = jnp.einsum("bchqs,bcshp->bcqhp", att, xr.astype(f32))

    # ---- chunk-local final states
    w = jnp.exp(jnp.clip(a_sum[:, :, None, :] - a_cs, -60.0, 0.0)) * dtr  # (B,nc,q,H)
    s_local = jnp.einsum("bcqhn,bcqh,bcqhp->bchnp", br.astype(f32), w, xr.astype(f32))

    # ---- inter-chunk recurrence (sequential scan over nc)
    if init_state is None:
        init_state = jnp.zeros((bsz, h, n, p), f32)

    def step(s_prev, inp):
        a_sum_c, s_local_c = inp  # (B,H), (B,H,N,P)
        s_new = jnp.exp(jnp.clip(a_sum_c, -60.0, 0.0))[..., None, None] * s_prev + s_local_c
        return s_new, s_prev

    # scan over chunk axis: move nc to front
    final_state, s_prevs = jax.lax.scan(
        step,
        init_state.astype(f32),
        (a_sum.transpose(1, 0, 2), s_local.transpose(1, 0, 2, 3, 4)),
    )
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)  # (B,nc,H,N,P) state at chunk start

    y_inter = jnp.einsum(
        "bcqhn,bcqh,bchnp->bcqhp",
        cr.astype(f32),
        jnp.exp(jnp.clip(a_cs, -60.0, 0.0)),
        s_prevs,
    )
    y = (y_intra + y_inter).reshape(bsz, l, h, p)
    return y.astype(x.dtype), final_state


def ssd_naive(x, dt, a, b_mat, c_mat, init_state=None):
    """Sequential oracle (tests)."""
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    f32 = jnp.float32
    s = jnp.zeros((bsz, h, n, p), f32) if init_state is None else init_state.astype(f32)

    def step(s, t_in):
        xt, dtt, bt, ct = t_in
        decay = jnp.exp(dtt.astype(f32) * a)[..., None, None]  # (B,H,1,1)
        upd = dtt.astype(f32)[..., None, None] * bt.astype(f32)[..., None] * xt.astype(f32)[..., None, :]
        s = decay * s + upd
        y = jnp.einsum("bhn,bhnp->bhp", ct.astype(f32), s)
        return s, y

    xs = (
        x.transpose(1, 0, 2, 3),
        dt.transpose(1, 0, 2),
        b_mat.transpose(1, 0, 2, 3),
        c_mat.transpose(1, 0, 2, 3),
    )
    s, ys = jax.lax.scan(step, s, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), s


# ---------------------------------------------------------------------------
# causal depthwise conv
# ---------------------------------------------------------------------------


def conv1d_causal(x, w, b):
    """x: (B, L, C); w: (K, C) depthwise; left-padded causal conv."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32),
        w.astype(jnp.float32)[:, None, :],  # (K, 1, C) HIO for depthwise
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=w.shape[1],
    )
    return (out + b.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# full block
# ---------------------------------------------------------------------------


def _split_proj(cfg, zxbcdt):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_nheads
    z = zxbcdt[..., :di]
    xs = zxbcdt[..., di : 2 * di]
    b_mat = zxbcdt[..., 2 * di : 2 * di + g * n]
    c_mat = zxbcdt[..., 2 * di + g * n : 2 * di + 2 * g * n]
    dt = zxbcdt[..., 2 * di + 2 * g * n :]
    return z, xs, b_mat, c_mat, dt


def _heads(cfg, xs, b_mat, c_mat):
    bsz, l = xs.shape[:2]
    h, p = cfg.ssm_nheads, cfg.ssm_head_dim
    g, n = cfg.ssm_groups, cfg.ssm_state
    xh = xs.reshape(bsz, l, h, p)
    rep = h // g
    bh = jnp.repeat(b_mat.reshape(bsz, l, g, n), rep, axis=2)
    ch = jnp.repeat(c_mat.reshape(bsz, l, g, n), rep, axis=2)
    return xh, bh, ch


def mamba_block(cfg, p, x, cache=None):
    """x: (B, L, D) -> (out, new_cache).

    cache = dict(conv (B, K-1, conv_dim), ssm (B, H, N, P)) for decode; the
    prefill path fills it from the full sequence."""
    in_dtype = x.dtype
    x = rms_norm(x, p["norm"], cfg.norm_eps)  # pre-norm (residual added by caller)
    y, new_cache = mamba_core(cfg, p, x, cache)
    out = apply_linear(p["out_proj"], y)
    return out.astype(in_dtype), new_cache


def mamba_core(cfg, p, x, cache=None):
    """Everything between the pre-norm and out_proj: returns the gated,
    normed SSD output y (B, L, d_inner) — the input of out_proj (captured by
    the LRC calibration walker)."""
    bsz, l, _ = x.shape
    di = cfg.d_inner
    zxbcdt = apply_linear(p["in_proj"], x)
    z, xs, b_mat, c_mat, dt = _split_proj(cfg, zxbcdt)

    xbc_raw = jnp.concatenate([xs, b_mat, c_mat], axis=-1)
    new_cache = None
    if cache is None:
        xbc = conv1d_causal(xbc_raw, p["conv_w"], p["conv_b"])
    else:
        k = cfg.ssm_conv
        hist = jnp.concatenate([cache["conv"].astype(xbc_raw.dtype), xbc_raw], axis=1)
        xbc = conv1d_causal(hist, p["conv_w"], p["conv_b"])[:, k - 1 :]
        new_cache = dict(conv=hist[:, -(k - 1) :].astype(cache["conv"].dtype))
    xbc = jax.nn.silu(xbc)
    xs2 = xbc[..., :di]
    b2 = xbc[..., di : di + cfg.ssm_groups * cfg.ssm_state]
    c2 = xbc[..., di + cfg.ssm_groups * cfg.ssm_state :]

    xh, bh, ch = _heads(cfg, xs2, b2, c2)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    init_state = None if cache is None else cache["ssm"]
    if l == 1 and cache is not None:
        # single-step decode: direct recurrence
        y, s = ssd_naive(xh, dt, a, bh, ch, init_state)
    else:
        chunk = min(cfg.ssm_chunk, l)
        while l % chunk:  # ragged lengths (tests) fall back to a divisor
            chunk -= 1
        y, s = ssd_chunked(xh, dt, a, bh, ch, chunk, init_state)
    if new_cache is not None:
        new_cache["ssm"] = s
    elif cache is not None:
        new_cache = dict(ssm=s)

    y = y + cfg_d_skip(p, xh)
    y = y.reshape(bsz, l, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["out_norm"], cfg.norm_eps)
    return y, new_cache


def cfg_d_skip(p, xh):
    return p["d_skip"][None, None, :, None].astype(jnp.float32) * xh.astype(jnp.float32)


def init_mamba_cache(cfg, batch: int, dtype=jnp.bfloat16):
    return dict(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, cfg.conv_dim), dtype),
        ssm=jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
    )
