"""DeepSeek-V2/V3 decoder: MLA attention + (shared + routed top-k) MoE FFN.

Layer stack = ``n_dense_layers`` leading dense-FFN layers (scanned) followed
by MoE layers (scanned).  Optional MTP (multi-token-prediction, V3): one
extra transformer block predicting token t+2, used as an auxiliary training
loss — see ``mtp_loss``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import causal_mask, mlp_block, rms_norm
from repro.models.remat import maybe_remat, scan_layers
from repro.models.mla import init_mla_params, mla_attention_block
from repro.models.moe import init_moe_params, moe_block
from repro.models.transformer import _init_linear, embed_tokens, unembed


def _init_block(cfg, key, dtype, moe: bool):
    k1, k2 = jax.random.split(key)
    p = {
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "attn": init_mla_params(cfg, k1, dtype),
        "mlp_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if moe:
        p["moe"] = init_moe_params(cfg, k2, dtype)
    else:
        ks = jax.random.split(k2, 3)
        ff = cfg.d_ff_dense or cfg.d_ff
        p["mlp"] = {
            "wg": _init_linear(ks[0], cfg.d_model, ff, dtype),
            "wu": _init_linear(ks[1], cfg.d_model, ff, dtype),
            "wd": _init_linear(ks[2], ff, cfg.d_model, dtype),
        }
    return p


def init_params(cfg, key, max_seq: int = 0):
    dtype = jnp.dtype(cfg.dtype)
    k_embed, k_dense, k_moe, k_head, k_mtp = jax.random.split(key, 5)
    nd = cfg.n_dense_layers
    nm = cfg.n_layers - nd
    params = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": _init_linear(k_head, cfg.d_model, cfg.vocab_size, dtype),
    }
    if nd > 0:
        keys = jax.random.split(k_dense, nd)
        params["dense_layers"] = jax.vmap(
            lambda k: _init_block(cfg, k, jnp.float32, moe=False)
        )(keys)
        params["dense_layers"] = jax.tree.map(lambda a: a.astype(dtype), params["dense_layers"])
    keys = jax.random.split(k_moe, nm)
    params["moe_layers"] = jax.tree.map(
        lambda a: a.astype(dtype),
        jax.vmap(lambda k: _init_block(cfg, k, jnp.float32, moe=True))(keys),
    )
    if cfg.mtp_depth > 0:
        params["mtp"] = {
            "proj": _init_linear(k_mtp, 2 * cfg.d_model, cfg.d_model, dtype),
            "block": _init_block(cfg, k_mtp, dtype, moe=False),
            "norm": jnp.ones((cfg.d_model,), dtype),
        }
    return params


def _block_forward(cfg, lp, x, positions, mask, cache, moe: bool, moe_impl: str,
                   want_stats: bool = False):
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    a, new_cache = mla_attention_block(cfg, lp["attn"], h, positions, mask, cache)
    x = x + a
    h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    drops = jnp.zeros((), jnp.int32) if want_stats else None
    if moe:
        if want_stats:
            y, drops = moe_block(cfg, lp["moe"], h, impl=moe_impl,
                                 return_stats=True)
            x = x + y
        else:
            x = x + moe_block(cfg, lp["moe"], h, impl=moe_impl)
    else:
        x = x + mlp_block(lp["mlp"], h, cfg.act)
    return x, new_cache, drops


def _run_stack(cfg, layers, x, positions, mask, cache, moe: bool, moe_impl: str,
               want_stats: bool = False):
    if cache is None:

        def body(xc, lp):
            y, _, d = _block_forward(cfg, lp, xc, positions, mask, None, moe,
                                     moe_impl, want_stats)
            return y, d

        x, drops = scan_layers(cfg, maybe_remat(cfg, body), x, layers)
        return x, None, (drops.sum() if want_stats else None)

    offset = cache["offset"]

    def body(xc, xs):
        lp, ck, cr = xs
        y, nc, d = _block_forward(
            cfg, lp, xc, positions, mask,
            dict(c_kv=ck, k_rope=cr, offset=offset), moe, moe_impl, want_stats,
        )
        return y, ((nc["c_kv"], nc["k_rope"], d) if want_stats
                   else (nc["c_kv"], nc["k_rope"]))

    ys = scan_layers(cfg, body, x, (layers, cache["c_kv"], cache["k_rope"]))
    if want_stats:
        x, (nk, nr, drops) = ys
        total = drops.sum()
    else:
        x, (nk, nr) = ys
        total = None
    return (x, dict(c_kv=nk, k_rope=nr, offset=offset + positions.shape[-1]),
            total)


def _backbone(cfg, params, x, positions, mask, caches, moe_impl,
              want_stats: bool = False):
    dense_cache = None if caches is None else caches.get("dense")
    moe_cache = None if caches is None else caches["moe"]
    new_caches = {}
    total = jnp.zeros((), jnp.int32) if want_stats else None
    if "dense_layers" in params:
        x, nc, _ = _run_stack(cfg, params["dense_layers"], x, positions, mask,
                              dense_cache, False, moe_impl)
        new_caches["dense"] = nc
    x, nc, drops = _run_stack(cfg, params["moe_layers"], x, positions, mask,
                              moe_cache, True, moe_impl, want_stats)
    new_caches["moe"] = nc
    if want_stats:
        total = total + drops
    return x, (new_caches if caches is not None else None), total


def forward(cfg, params, tokens, moe_impl: str = None, return_hidden: bool = False):
    moe_impl = moe_impl or cfg.moe_impl
    x = embed_tokens(cfg, params, tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    mask = causal_mask(s, s, 0)
    x, _, _ = _backbone(cfg, params, x, positions, mask, None, moe_impl)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(cfg, params, x)
    if return_hidden:
        return logits, x
    return logits


def mtp_logits(cfg, params, tokens, hidden):
    """V3 MTP head: h'_t = block(proj([norm(h_t); emb(tok_{t+1})])) predicts
    token t+2.  hidden: final-norm'd backbone states (B, S, D)."""
    from repro.quant.qlinear import apply_linear

    emb_next = embed_tokens(cfg, params, tokens[:, 1:])  # (B, S-1, D)
    h = hidden[:, :-1]
    z = jnp.concatenate([rms_norm(h, params["mtp"]["norm"], cfg.norm_eps), emb_next], axis=-1)
    z = apply_linear(params["mtp"]["proj"], z)
    b, s, _ = z.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    mask = causal_mask(s, s, 0)
    z, _, _ = _block_forward(cfg, params["mtp"]["block"], z, positions, mask, None, False, "dense")
    return unembed(cfg, params, z)  # (B, S-1, V) — predicts tokens[:, 2:]


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    def stack(n):
        return dict(
            c_kv=jnp.zeros((n, batch, max_seq, cfg.kv_lora_rank), dtype),
            k_rope=jnp.zeros((n, batch, max_seq, cfg.qk_rope_dim), dtype),
            offset=jnp.zeros((), jnp.int32),
        )

    caches = {"moe": stack(cfg.n_layers - cfg.n_dense_layers)}
    if cfg.n_dense_layers > 0:
        caches["dense"] = stack(cfg.n_dense_layers)
    return caches


def _sync_offsets(caches, off):
    for c in caches.values():
        c["offset"] = off
    return caches


def prefill(cfg, params, tokens, caches, moe_impl: str = None):
    moe_impl = moe_impl or cfg.moe_impl
    x = embed_tokens(cfg, params, tokens)
    b, s, _ = x.shape
    kv_len = caches["moe"]["c_kv"].shape[2]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    mask = causal_mask(s, kv_len, 0)
    x, caches, _ = _backbone(cfg, params, x, positions, mask, caches, moe_impl)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(cfg, params, x[:, -1:]), caches


def decode_step(cfg, params, tokens, caches, moe_impl: str = None,
                with_stats: bool = False):
    """``with_stats`` additionally returns ``{"ep_dropped": int32}`` — the
    total capacity-dropped (token, slot) assignments across every MoE layer
    of this step (always 0 on the dense dispatch path)."""
    moe_impl = moe_impl or cfg.moe_impl
    x = embed_tokens(cfg, params, tokens)
    b = x.shape[0]
    offset = caches["moe"]["offset"]
    positions = jnp.broadcast_to(offset, (b, 1))
    kv_len = caches["moe"]["c_kv"].shape[2]
    mask = (jnp.arange(kv_len) <= offset)[None, :]
    x, caches, drops = _backbone(cfg, params, x, positions, mask, caches,
                                 moe_impl, want_stats=with_stats)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(cfg, params, x)
    if with_stats:
        return logits, caches, {"ep_dropped": drops}
    return logits, caches
