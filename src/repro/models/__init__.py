from repro.models.config import ModelConfig, reduced
from repro.models import model
