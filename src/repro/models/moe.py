"""Mixture-of-Experts FFN (DeepSeek-V2/V3 style: shared + routed top-k).

Two dispatch implementations:
  * ``dense``  — every expert computes every token, combined with the routing
                 weight matrix.  Simple and exact; used for CPU smoke tests
                 and small expert counts.
  * ``ep``     — expert-parallel: capacity-based sort dispatch with
                 ``lax.all_to_all`` under shard_map (repro.distributed.ep).
                 Flops-honest at scale; used by the 512-device dry-run.

The routed experts use the config's ``d_expert`` width; shared experts run as
one fused dense MLP of width ``n_shared * d_expert``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import mlp_block
from repro.quant.qlinear import apply_linear


def init_moe_params(cfg, key, dtype):
    ks = jax.random.split(key, 7)
    d, e, fe = cfg.d_model, cfg.n_experts, cfg.d_expert

    def lin(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)

    p = {
        "router": lin(ks[0], (d, e), d**-0.5),
        "experts": {
            "wg": lin(ks[1], (e, d, fe), d**-0.5),
            "wu": lin(ks[2], (e, d, fe), d**-0.5),
            "wd": lin(ks[3], (e, fe, d), fe**-0.5),
        },
    }
    if cfg.n_shared_experts > 0:
        fs = cfg.n_shared_experts * fe
        p["shared"] = {
            "wg": lin(ks[4], (d, fs), d**-0.5),
            "wu": lin(ks[5], (d, fs), d**-0.5),
            "wd": lin(ks[6], (fs, d), fs**-0.5),
        }
    return p


def router_weights(cfg, p, x):
    """x: (..., D) -> (weights (..., E) with exactly top_k nonzeros, idx)."""
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)
    if cfg.router_fn == "sigmoid":  # deepseek-v3
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(scores, cfg.moe_top_k)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)  # renorm
    weights = jnp.zeros_like(scores)
    weights = jnp.put_along_axis(weights, top_idx, top_vals, axis=-1, inplace=False)
    return weights, top_idx


def _expert_matmul(w, x):
    """x: (T, D) against stacked expert weights (E, D, F) -> (T, E, F).
    Supports QLinear experts (leading expert dim vmapped)."""
    from repro.quant.qlinear import QLinear, qlinear_apply

    if isinstance(w, QLinear):
        out = jax.vmap(lambda we: qlinear_apply(we, x))(w)  # (E, T, F)
        return out.transpose(1, 0, 2)
    return jnp.einsum("td,edf->tef", x, w.astype(x.dtype))


def _experts_dense(cfg, p, x, weights):
    """All-experts combine. x: (T, D); weights: (T, E)."""
    we = p["experts"]
    g = _expert_matmul(we["wg"], x)
    u = _expert_matmul(we["wu"], x)
    h = jax.nn.silu(g) * u
    wd = we["wd"]
    from repro.quant.qlinear import QLinear, qlinear_apply

    if isinstance(wd, QLinear):
        y = jax.vmap(qlinear_apply)(wd, h.transpose(1, 0, 2)).transpose(1, 0, 2)
    else:
        y = jnp.einsum("tef,efd->ted", h, wd.astype(x.dtype))
    return jnp.einsum("ted,te->td", y, weights.astype(x.dtype))


def moe_block(cfg, p, x, impl: str = "dense", ep_axis: str | None = None,
              return_stats: bool = False):
    """x: (B, S, D) -> (B, S, D); with ``return_stats`` also an int32 count
    of capacity-dropped (token, slot) assignments (0 on the dense path,
    which has no capacity)."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    weights, top_idx = router_weights(cfg, p, xt)
    dropped = jnp.zeros((), jnp.int32)
    if impl == "dense":
        routed = _experts_dense(cfg, p, xt, weights)
    elif impl == "ep":
        from repro.distributed.ep import experts_ep

        routed = experts_ep(cfg, p, xt, weights, top_idx, axis=ep_axis,
                            with_stats=return_stats)
        if return_stats:
            routed, dropped = routed
    else:
        raise ValueError(impl)
    out = routed
    if "shared" in p:
        out = out + mlp_block(p["shared"], xt, cfg.act)
    out = out.reshape(b, s, d)
    return (out, dropped) if return_stats else out
