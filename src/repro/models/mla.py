"""Multi-head Latent Attention (DeepSeek-V2/V3).

KV states are compressed into a small latent c_kv (kv_lora_rank) plus a
single shared rope-carrying key head; only (c_kv, k_rope) is cached —
the memory win that makes 128-head models servable.  Decode recomputes
per-head K/V from the cached latent ("naive" expansion; the absorbed-matmul
variant is a §Perf hillclimb item).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import FREE, attention, cache_update, rms_norm, rope, shard_hint
from repro.quant.qlinear import apply_linear


def init_mla_params(cfg, key, dtype):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    h = cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim

    def lin(k, di, do):
        return (jax.random.normal(k, (di, do), jnp.float32) * di**-0.5).astype(dtype)

    p = {
        "wkv_a": lin(ks[0], d, cfg.kv_lora_rank + cfg.qk_rope_dim),
        "kv_norm": jnp.ones((cfg.kv_lora_rank,), dtype),
        "wkv_b": lin(ks[1], cfg.kv_lora_rank, h * (cfg.qk_nope_dim + cfg.v_head_dim)),
        "wo": lin(ks[2], h * cfg.v_head_dim, d),
    }
    if cfg.q_lora_rank > 0:
        p["wq_a"] = lin(ks[3], d, cfg.q_lora_rank)
        p["q_norm"] = jnp.ones((cfg.q_lora_rank,), dtype)
        p["wq_b"] = lin(ks[4], cfg.q_lora_rank, h * qk)
    else:
        p["wq"] = lin(ks[5], d, h * qk)
    return p


def _queries(cfg, p, x):
    b, s, _ = x.shape
    h = cfg.n_heads
    if "wq_a" in p:
        cq = rms_norm(apply_linear(p["wq_a"], x), p["q_norm"], cfg.norm_eps)
        q = apply_linear(p["wq_b"], cq)
    else:
        q = apply_linear(p["wq"], x)
    q = shard_hint(
        q.reshape(b, s, h, cfg.qk_nope_dim + cfg.qk_rope_dim),
        (FREE, FREE, "model", FREE),
    )
    return q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim :]


def _latent(cfg, p, x, positions):
    """Returns (c_kv normed (B,S,R), k_rope roped (B,S,rope))."""
    kv = apply_linear(p["wkv_a"], x)
    c_kv = rms_norm(kv[..., : cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., cfg.kv_lora_rank :]
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def _expand_kv(cfg, p, c_kv):
    """latent (B,S,R) -> k_nope (B,S,H,nope), v (B,S,H,v)."""
    b, s, _ = c_kv.shape
    h = cfg.n_heads
    kvb = shard_hint(
        apply_linear(p["wkv_b"], c_kv).reshape(
            b, s, h, cfg.qk_nope_dim + cfg.v_head_dim
        ),
        (FREE, FREE, "model", FREE),
    )
    return kvb[..., : cfg.qk_nope_dim], kvb[..., cfg.qk_nope_dim :]


def _effective_weight(w) -> jnp.ndarray:
    """Dense (d_in, d_out) view of a weight leaf, including a QLinear's
    dequantized matrix + low-rank correction (used by the absorbed path,
    where wkv_b is consumed INSIDE the attention math)."""
    from repro.quant.qlinear import QLinear, _unpack_w

    if isinstance(w, QLinear):
        mat = _unpack_w(w).astype(jnp.float32) * w.w_scale[None, :]
        if w.u is not None:
            mat = mat + w.v.astype(jnp.float32) @ w.u.astype(jnp.float32).T
        return mat
    return w


def mla_attention_absorbed(cfg, p, q_nope, q_rope, c_kv, k_rope, mask):
    """Weight-absorbed MLA attention (DeepSeek's serving trick, §Perf):

    scores_h(t) = (W_k,hᵀ q_nope,h)·c_t + q_rope,h·k_rope,t
    out_h       = W_v,h · (probs_h · C)

    The per-head K/V are NEVER materialized over the sequence — attention
    runs directly against the (R + rope)-dim latent cache.  Cuts the
    O(S·H·(nope+v)) expansion (the dominant bytes of the naive path at 32k)
    to O(S·(R+rope)).
    """
    b, s, h, _ = q_nope.shape
    r = cfg.kv_lora_rank
    wkv = _effective_weight(p["wkv_b"]).astype(jnp.float32)
    wkv = wkv.reshape(r, h, cfg.qk_nope_dim + cfg.v_head_dim)
    w_k = wkv[..., : cfg.qk_nope_dim]  # (R, H, nope)
    w_v = wkv[..., cfg.qk_nope_dim :]  # (R, H, v)

    q_abs = shard_hint(
        jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32), w_k),
        (FREE, FREE, "model", FREE),
    )
    scores = jnp.einsum("bshr,btr->bhst", q_abs, c_kv.astype(jnp.float32))
    scores = scores + jnp.einsum(
        "bshp,btp->bhst", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32)
    )
    scale = 1.0 / ((cfg.qk_nope_dim + cfg.qk_rope_dim) ** 0.5)
    scores = scores * scale
    if mask is not None:
        scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", probs, c_kv.astype(jnp.float32))
    out = jnp.einsum("bshr,rhv->bshv", ctx, w_v)
    return out.astype(q_nope.dtype)


def mla_attention_block(cfg, p, x, positions, mask, cache=None):
    """Returns (out (B,S,D), new_cache).  cache = dict(c_kv (B,Smax,R),
    k_rope (B,Smax,rope), offset)."""
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _queries(cfg, p, x)
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    c_kv, k_rope = _latent(cfg, p, x, positions)

    new_cache = None
    if cache is not None:
        off = cache["offset"]
        ckv_c = cache_update(cache["c_kv"], c_kv, off)
        krope_c = cache_update(cache["k_rope"], k_rope, off)
        new_cache = dict(c_kv=ckv_c, k_rope=krope_c, offset=off + s)
        c_kv, k_rope = ckv_c.astype(x.dtype), krope_c.astype(x.dtype)

    if getattr(cfg, "mla_absorb", False):
        out = mla_attention_absorbed(cfg, p, q_nope, q_rope, c_kv, k_rope, mask)
    else:
        k_nope, v = _expand_kv(cfg, p, c_kv)
        skv = k_nope.shape[1]
        k = jnp.concatenate(
            [k_nope,
             jnp.broadcast_to(k_rope[:, :, None, :], (b, skv, h, cfg.qk_rope_dim))],
            axis=-1,
        )
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        scale = 1.0 / ((cfg.qk_nope_dim + cfg.qk_rope_dim) ** 0.5)
        out = attention(q, k, v, mask, scale)
    out = apply_linear(p["wo"], out.reshape(b, s, h * cfg.v_head_dim))
    return out, new_cache
