"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv audio frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings (B, T_enc, D) with T_enc = seq_len //
``encoder_downsample``.  LayerNorm (γ, β), non-gated GELU MLP, sinusoidal
encoder positions, learned decoder positions, cross-attention with a
once-per-request cached encoder K/V.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (
    attention,
    attn_qkv_hints,
    cache_update,
    causal_mask,
    layer_norm,
    sinusoidal_positions,
)
from repro.models.transformer import _init_linear
from repro.models.remat import maybe_remat, scan_layers
from repro.quant.qlinear import apply_linear

MAX_DEC_POS = 4096  # learned decoder position table size (smoke/serve scale)


def _ln(d, dtype):
    return dict(g=jnp.ones((d,), dtype), b=jnp.zeros((d,), dtype))


def _attn_params(cfg, key, dtype):
    ks = jax.random.split(key, 4)
    h, hd = cfg.n_heads, cfg.head_dim
    return {
        "wq": _init_linear(ks[0], cfg.d_model, h * hd, dtype),
        "wk": _init_linear(ks[1], cfg.d_model, h * hd, dtype),
        "wv": _init_linear(ks[2], cfg.d_model, h * hd, dtype),
        "wo": _init_linear(ks[3], h * hd, cfg.d_model, dtype),
    }


def _mlp_params(cfg, key, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "wi": _init_linear(k1, cfg.d_model, cfg.d_ff, dtype),
        "wo": _init_linear(k2, cfg.d_ff, cfg.d_model, dtype),
    }


def _enc_layer(cfg, key, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": _ln(cfg.d_model, dtype),
        "attn": _attn_params(cfg, k1, dtype),
        "mlp_norm": _ln(cfg.d_model, dtype),
        "mlp": _mlp_params(cfg, k2, dtype),
    }


def _dec_layer(cfg, key, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "attn_norm": _ln(cfg.d_model, dtype),
        "attn": _attn_params(cfg, k1, dtype),
        "xattn_norm": _ln(cfg.d_model, dtype),
        "xattn": _attn_params(cfg, k2, dtype),
        "mlp_norm": _ln(cfg.d_model, dtype),
        "mlp": _mlp_params(cfg, k3, dtype),
    }


def init_params(cfg, key, max_seq: int = 0):
    dtype = jnp.dtype(cfg.dtype)
    n_pos = max(MAX_DEC_POS, max_seq)
    k_emb, k_pos, k_enc, k_dec, k_head = jax.random.split(key, 5)
    enc_keys = jax.random.split(k_enc, cfg.n_encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    return {
        "embed": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype),
        "dec_pos": (jax.random.normal(k_pos, (n_pos, cfg.d_model)) * 0.01).astype(dtype),
        "enc_layers": jax.tree.map(
            lambda a: a.astype(dtype),
            jax.vmap(lambda k: _enc_layer(cfg, k, jnp.float32))(enc_keys),
        ),
        "dec_layers": jax.tree.map(
            lambda a: a.astype(dtype),
            jax.vmap(lambda k: _dec_layer(cfg, k, jnp.float32))(dec_keys),
        ),
        "enc_norm": _ln(cfg.d_model, dtype),
        "dec_norm": _ln(cfg.d_model, dtype),
        "lm_head": _init_linear(k_head, cfg.d_model, cfg.vocab_size, dtype),
    }


def _mha(cfg, p, xq, xkv, mask, cache=None):
    """Full multi-head attention (whisper has H == KV heads)."""
    b, sq, _ = xq.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = apply_linear(p["wq"], xq).reshape(b, sq, h, hd)
    k = apply_linear(p["wk"], xkv).reshape(b, xkv.shape[1], h, hd)
    v = apply_linear(p["wv"], xkv).reshape(b, xkv.shape[1], h, hd)
    q, k, v = attn_qkv_hints(q, k, v)
    new_cache = None
    if cache is not None:
        off = cache["offset"]
        kc = cache_update(cache["k"], k, off)
        vc = cache_update(cache["v"], v, off)
        new_cache = dict(k=kc, v=vc, offset=off + sq)
        k, v = kc.astype(xq.dtype), vc.astype(xq.dtype)
    out = attention(q, k, v, mask, 1.0 / (hd**0.5))
    return apply_linear(p["wo"], out.reshape(b, sq, h * hd)), new_cache


def _gelu_mlp(p, x):
    return apply_linear(p["wo"], jax.nn.gelu(apply_linear(p["wi"], x), approximate=True))


def encode(cfg, params, frames):
    """frames: (B, T_enc, D) precomputed embeddings (conv-stub output)."""
    b, t, d = frames.shape
    x = frames + sinusoidal_positions(t, d).astype(frames.dtype)[None]
    eps = cfg.norm_eps

    def body(xc, lp):
        h = layer_norm(xc, lp["attn_norm"]["g"], lp["attn_norm"]["b"], eps)
        a, _ = _mha(cfg, lp["attn"], h, h, None)
        xc = xc + a
        h = layer_norm(xc, lp["mlp_norm"]["g"], lp["mlp_norm"]["b"], eps)
        xc = xc + _gelu_mlp(lp["mlp"], h)
        return xc, None

    x, _ = scan_layers(cfg, maybe_remat(cfg, body), x, params["enc_layers"])
    return layer_norm(x, params["enc_norm"]["g"], params["enc_norm"]["b"], eps)


def _decoder(cfg, params, x, enc_out, mask, cache=None, pos_offset=0):
    """cache: dict(k,v stacked (L,...), offset) for self-attn; cross-attn
    recomputes K/V from enc_out (cached upstream as enc_out itself)."""
    eps = cfg.norm_eps

    def layer(xc, lp, ck=None, cv=None, offset=None):
        c = None if ck is None else dict(k=ck, v=cv, offset=offset)
        h = layer_norm(xc, lp["attn_norm"]["g"], lp["attn_norm"]["b"], eps)
        a, nc = _mha(cfg, lp["attn"], h, h, mask, c)
        xc = xc + a
        h = layer_norm(xc, lp["xattn_norm"]["g"], lp["xattn_norm"]["b"], eps)
        a, _ = _mha(cfg, lp["xattn"], h, enc_out, None)
        xc = xc + a
        h = layer_norm(xc, lp["mlp_norm"]["g"], lp["mlp_norm"]["b"], eps)
        xc = xc + _gelu_mlp(lp["mlp"], h)
        return xc, nc

    if cache is None:

        def body(xc, lp):
            y, _ = layer(xc, lp)
            return y, None

        x, _ = scan_layers(cfg, maybe_remat(cfg, body), x, params["dec_layers"])
        return x, None

    offset = cache["offset"]

    def body(xc, xs):
        lp, ck, cv = xs
        y, nc = layer(xc, lp, ck, cv, offset)
        return y, (nc["k"], nc["v"])

    x, (nk, nv) = scan_layers(cfg, body, x, (params["dec_layers"], cache["k"], cache["v"]))
    return x, dict(k=nk, v=nv, offset=offset + x.shape[1])


def forward(cfg, params, tokens, frames):
    """Training forward: encoder on frames, teacher-forced decoder on tokens."""
    enc_out = encode(cfg, params, frames)
    b, s = tokens.shape
    x = params["embed"][tokens] + params["dec_pos"][:s][None].astype(params["embed"].dtype)
    mask = causal_mask(s, s, 0)
    x, _ = _decoder(cfg, params, x, enc_out, mask)
    x = layer_norm(x, params["dec_norm"]["g"], params["dec_norm"]["b"], cfg.norm_eps)
    return (x @ params["lm_head"].astype(x.dtype)).astype(jnp.float32)


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16, enc_len: int = 0):
    h, hd = cfg.n_heads, cfg.head_dim
    return dict(
        self=dict(
            k=jnp.zeros((cfg.n_layers, batch, max_seq, h, hd), dtype),
            v=jnp.zeros((cfg.n_layers, batch, max_seq, h, hd), dtype),
            offset=jnp.zeros((), jnp.int32),
        ),
        enc_out=jnp.zeros((batch, enc_len, cfg.d_model), dtype),
    )


def prefill(cfg, params, tokens, cache, frames):
    enc_out = encode(cfg, params, frames)
    cache = dict(cache, enc_out=enc_out.astype(cache["enc_out"].dtype))
    b, s = tokens.shape
    x = params["embed"][tokens] + params["dec_pos"][:s][None].astype(params["embed"].dtype)
    kv_len = cache["self"]["k"].shape[2]
    mask = causal_mask(s, kv_len, 0)
    x, sc = _decoder(cfg, params, x, enc_out, mask, cache["self"])
    cache = dict(cache, self=sc)
    x = layer_norm(x, params["dec_norm"]["g"], params["dec_norm"]["b"], cfg.norm_eps)
    return (x[:, -1:] @ params["lm_head"].astype(x.dtype)).astype(jnp.float32), cache


def decode_step(cfg, params, tokens, cache):
    b = tokens.shape[0]
    offset = cache["self"]["offset"]
    pos_emb = jnp.take(params["dec_pos"], jnp.minimum(offset, params["dec_pos"].shape[0] - 1), axis=0)
    x = params["embed"][tokens] + pos_emb[None, None].astype(params["embed"].dtype)[:, 0]
    kv_len = cache["self"]["k"].shape[2]
    mask = (jnp.arange(kv_len) <= offset)[None, :]
    enc_out = cache["enc_out"].astype(x.dtype)
    x, sc = _decoder(cfg, params, x, enc_out, mask, cache["self"])
    cache = dict(cache, self=sc)
    x = layer_norm(x, params["dec_norm"]["g"], params["dec_norm"]["b"], cfg.norm_eps)
    return (x @ params["lm_head"].astype(x.dtype)).astype(jnp.float32), cache
