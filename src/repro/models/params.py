"""Parameter-count utilities (used for MODEL_FLOPS = 6·N·D in the roofline)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(tree)))


def count_params_config(cfg, active_only: bool = False) -> int:
    """Count params from shape-only init (no allocation)."""
    from repro.models import model as model_lib

    shapes = jax.eval_shape(
        lambda key: model_lib.init_params(cfg, key), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    total = tree_size(shapes)
    if active_only and cfg.n_experts:
        # subtract the inactive routed experts
        def expert_size(tree):
            n = 0
            for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
                keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
                if "experts" in keys:
                    n += int(np.prod(leaf.shape))
            return n

        routed = expert_size(shapes)
        total -= routed * (cfg.n_experts - cfg.moe_top_k) // cfg.n_experts
    return total
