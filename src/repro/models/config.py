"""Unified architecture configuration for the 10 assigned model families."""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    act: str = "silu"  # silu (SwiGLU) | gelu (GeGLU)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    embed_scale: bool = False  # gemma multiplies embeddings by sqrt(d)

    # --- attention variant ---
    attn_kind: str = "gqa"  # gqa | mla | none
    # MLA (deepseek)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    d_expert: int = 0
    n_dense_layers: int = 0  # leading dense FFN layers (deepseek)
    d_ff_dense: int = 0  # FFN width of those leading dense layers
    router_fn: str = "softmax"  # softmax (v2) | sigmoid (v3)
    capacity_factor: float = 1.25
    moe_impl: str = "dense"  # dense (all-experts) | ep (expert-parallel shard_map)

    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 128

    # --- hybrid (zamba2) ---
    hybrid_attn_every: int = 0  # apply the shared attention block every N layers

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_downsample: int = 1  # conv-stub frames = seq_len // this

    # --- VLM (paligemma) ---
    n_prefix_tokens: int = 0  # precomputed patch embeddings (stub frontend)

    # --- MTP (deepseek v3) ---
    mtp_depth: int = 0

    # --- serving optimizations (§Perf hillclimb knobs) ---
    mla_absorb: bool = False  # weight-absorbed MLA attention (deepseek serve)

    # numerics
    dtype: str = "bfloat16"
    # activation checkpointing for the training path: none | full | dots
    remat: str = "none"
    # unroll layer scans (dry-run cost extrapolation only)
    unroll: bool = False

    # ---- derived ----
    @property
    def q_dim(self) -> int:
        if self.attn_kind == "mla":
            return self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def o_in_dim(self) -> int:
        if self.attn_kind == "mla":
            return self.n_heads * self.v_head_dim
        return self.n_heads * self.head_dim

    @property
    def d_inner(self) -> int:  # mamba2
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.ssm_groups * self.ssm_state

    def n_params(self) -> int:
        """Total parameter count (embedding included)."""
        from repro.models.params import count_params_config

        return count_params_config(self)

    def n_active_params(self) -> int:
        """Active-per-token parameters (MoE: only routed top-k + shared)."""
        from repro.models.params import count_params_config

        return count_params_config(self, active_only=True)

    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def has_decode(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    if cfg.n_kv_heads == 0:
        kv_small = 0
    elif cfg.n_kv_heads == 1:
        kv_small = 1  # keep MQA character
    elif cfg.n_kv_heads == cfg.n_heads:
        kv_small = 4  # MHA
    else:
        kv_small = 2  # GQA
    small = dict(
        n_layers=min(cfg.n_layers, 2 if cfg.family != "hybrid" else 4),
        d_model=64,
        n_heads=4,
        n_kv_heads=kv_small,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        dtype="float32",  # tight numerics for CPU smoke tests
    )
    if cfg.attn_kind == "mla":
        small.update(
            q_lora_rank=32, kv_lora_rank=16, qk_rope_dim=8, qk_nope_dim=8,
            v_head_dim=16,
        )
    if cfg.n_experts:
        small.update(n_experts=8, moe_top_k=2, d_expert=32,
                     n_shared_experts=min(cfg.n_shared_experts, 1),
                     n_dense_layers=min(cfg.n_dense_layers, 1), d_ff_dense=128)
    if cfg.ssm_state:
        small.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.hybrid_attn_every:
        small.update(hybrid_attn_every=2)
    if cfg.is_encoder_decoder:
        small.update(n_encoder_layers=2)
    if cfg.n_prefix_tokens:
        small.update(n_prefix_tokens=8)
    if cfg.mtp_depth:
        small.update(mtp_depth=1)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
