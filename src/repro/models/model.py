"""Model facade: family dispatch for init / forward / prefill / decode / loss.

All functions are pure; ``cfg`` is static (hashable dataclass), params/caches
are pytrees.  This is the single surface used by the trainer, the serving
engine, the LRC calibration walker and the dry-run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import deepseek, encdec, hybrid, transformer
from repro.models.mamba2 import init_mamba_cache, mamba_block
from repro.models.common import causal_mask, rms_norm
from repro.models.remat import maybe_remat, scan_layers
from repro.models.transformer import embed_tokens, unembed


# ---------------------------------------------------------------------------
# pure-SSM (mamba2) decoder-only model
# ---------------------------------------------------------------------------


def _ssm_init_params(cfg, key, max_seq=0):
    from repro.models.mamba2 import init_mamba_params
    from repro.models.transformer import _init_linear

    dtype = jnp.dtype(cfg.dtype)
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: init_mamba_params(cfg, k, jnp.float32))(keys)
    layers = jax.tree.map(
        lambda a: a.astype(jnp.dtype(cfg.dtype)) if a.ndim > 1 else a, layers
    )
    return {
        "embed": (jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": _init_linear(k_head, cfg.d_model, cfg.vocab_size, dtype),
    }


def _ssm_run(cfg, params, x, caches):
    if caches is None:

        def body(xc, lp):
            out, _ = mamba_block(cfg, lp, xc, None)
            return xc + out, None

        x, _ = scan_layers(cfg, maybe_remat(cfg, body), x, params["layers"])
        return x, None

    def body(xc, xs):
        lp, conv_c, ssm_c = xs
        out, nc = mamba_block(cfg, lp, xc, dict(conv=conv_c, ssm=ssm_c))
        return xc + out, (nc["conv"], nc["ssm"])

    x, (nconv, nssm) = scan_layers(
        cfg, body, x, (params["layers"], caches["conv"], caches["ssm"])
    )
    return x, dict(conv=nconv, ssm=nssm)


def _ssm_forward(cfg, params, tokens):
    x = embed_tokens(cfg, params, tokens)
    x, _ = _ssm_run(cfg, params, x, None)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(cfg, params, x)


def _ssm_init_cache(cfg, batch, max_seq, dtype=jnp.bfloat16):
    mc = init_mamba_cache(cfg, batch, dtype)
    return dict(
        conv=jnp.zeros((cfg.n_layers,) + mc["conv"].shape, dtype),
        ssm=jnp.zeros((cfg.n_layers,) + mc["ssm"].shape, jnp.float32),
    )


def _ssm_step(cfg, params, tokens, caches):
    x = embed_tokens(cfg, params, tokens)
    x, caches = _ssm_run(cfg, params, x, caches)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(cfg, params, x), caches


# ---------------------------------------------------------------------------
# dispatch tables
# ---------------------------------------------------------------------------


def init_params(cfg, key, max_seq: int = 0):
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return transformer.init_params(cfg, key, max_seq)
    if fam == "moe":
        return deepseek.init_params(cfg, key, max_seq)
    if fam == "ssm":
        return _ssm_init_params(cfg, key, max_seq)
    if fam == "hybrid":
        return hybrid.init_params(cfg, key, max_seq)
    if fam == "encdec":
        return encdec.init_params(cfg, key, max_seq)
    raise ValueError(fam)


def forward(cfg, params, batch):
    """batch: dict(tokens (B,S) [, frames (B,T,D) | patches (B,P,D)])."""
    fam = cfg.family
    tokens = batch["tokens"]
    if fam == "dense":
        return transformer.forward(cfg, params, tokens)
    if fam == "vlm":
        return transformer.forward(cfg, params, tokens, embeds=batch["patches"])
    if fam == "moe":
        return deepseek.forward(cfg, params, tokens, moe_impl=batch.get("moe_impl", "dense"))
    if fam == "ssm":
        return _ssm_forward(cfg, params, tokens)
    if fam == "hybrid":
        return hybrid.forward(cfg, params, tokens)
    if fam == "encdec":
        return encdec.forward(cfg, params, tokens, batch["frames"])
    raise ValueError(fam)


def _ce(logits, labels):
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def loss_fn(cfg, params, batch):
    """Next-token CE over the token stream (frontend prefixes excluded).
    Adds the MTP auxiliary loss for configs with mtp_depth > 0."""
    tokens = batch["tokens"]
    hidden = None
    if cfg.family == "moe" and cfg.mtp_depth > 0 and "mtp" in params:
        logits, hidden = deepseek.forward(
            cfg, params, tokens, moe_impl=batch.get("moe_impl", "dense"),
            return_hidden=True,
        )
    else:
        logits = forward(cfg, params, batch)
    if cfg.family == "vlm":
        logits = logits[:, -tokens.shape[1] :, :]  # token tail after patches
    loss = _ce(logits[:, :-1], tokens[:, 1:])
    if hidden is not None:
        mtp = deepseek.mtp_logits(cfg, params, tokens, hidden)
        loss = loss + 0.3 * _ce(mtp[:, :-1], tokens[:, 2:])  # t -> t+2 targets
    return loss


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16,
               enc_len: int = 0, kv_spec=None):
    """``kv_spec`` is the ONE source of truth for cache storage width when
    given: a float spec routes its dtype to every family's contiguous
    cache (the old split — ssm defaulting bf16 while the engine pinned
    dense caches f32 — is gone); quantized specs apply only to the paged
    pool (recurrent/contiguous state is not int-quantizable) and raise."""
    fam = cfg.family
    if kv_spec is not None:
        if kv_spec.is_quantized:
            raise ValueError(
                f"kv dtype {kv_spec.dtype!r} requires the paged cache "
                f"layout; contiguous/{fam!r} caches support f32/bf16 only")
        dtype = kv_spec.cache_dtype
    if fam in ("dense", "vlm"):
        return transformer.init_cache(cfg, batch, max_seq, dtype)
    if fam == "moe":
        return deepseek.init_cache(cfg, batch, max_seq, dtype)
    if fam == "ssm":
        return _ssm_init_cache(cfg, batch, max_seq, dtype)
    if fam == "hybrid":
        return hybrid.init_cache(cfg, batch, max_seq, dtype)
    if fam == "encdec":
        return encdec.init_cache(cfg, batch, max_seq, dtype, enc_len=enc_len)
    raise ValueError(fam)


# families whose decode state is an attention KV cache with a contiguous
# layout we can page (vs. ssm's recurrent state / MLA's latent cache)
PAGED_FAMILIES = ("dense",)

# families whose decode state is per-row (batch axis 1 on every leaf) and
# carries no shared scalar offset, so independent requests can be stacked
# into one batched decode without model changes
STACKED_FAMILIES = ("ssm",)


def init_paged_cache(cfg, num_pages: int, page_size: int, dtype=jnp.bfloat16,
                     kv_spec=None):
    if cfg.family in PAGED_FAMILIES:
        return transformer.init_paged_cache(cfg, num_pages, page_size, dtype,
                                            kv_spec=kv_spec)
    raise NotImplementedError(
        f"paged KV serving supports families {PAGED_FAMILIES}, not "
        f"{cfg.family!r} (hybrid/moe caches carry a shared scalar offset; "
        f"ssm state is recurrent, not positional)")


def paged_step(cfg, params, tokens, positions, valid, cache, block_table,
               sample_row=None, kv_spec=None):
    """Chunked-prefill / batched-decode step against a paged KV pool; see
    ``transformer.paged_step`` for the contract."""
    if cfg.family in PAGED_FAMILIES:
        return transformer.paged_step(cfg, params, tokens, positions, valid,
                                      cache, block_table, sample_row,
                                      kv_spec=kv_spec)
    raise NotImplementedError(cfg.family)


def insert_cache_row(stacked, one, row: int):
    """Write a B=1 cache pytree into batch row ``row`` of a stacked cache
    (every leaf batched on axis 1, the layout of ``_ssm_init_cache``)."""
    return jax.tree.map(
        lambda full, single: jax.lax.dynamic_update_slice_in_dim(
            full, single.astype(full.dtype), row, axis=1),
        stacked, one)


def prefill(cfg, params, batch, cache):
    fam = cfg.family
    tokens = batch["tokens"]
    if fam == "dense":
        return transformer.prefill(cfg, params, tokens, cache)
    if fam == "vlm":
        return transformer.prefill(cfg, params, tokens, cache, embeds=batch["patches"])
    if fam == "moe":
        return deepseek.prefill(cfg, params, tokens, cache, moe_impl=batch.get("moe_impl", "dense"))
    if fam == "ssm":
        logits, cache = _ssm_step(cfg, params, tokens, cache)
        return logits[:, -1:], cache
    if fam == "hybrid":
        return hybrid.prefill(cfg, params, tokens, cache)
    if fam == "encdec":
        return encdec.prefill(cfg, params, tokens, cache, batch["frames"])
    raise ValueError(fam)


def decode_step(cfg, params, tokens, cache, moe_impl: str = "dense",
                with_stats: bool = False):
    """``with_stats`` (moe only) appends the EP drop-stats dict to the
    return — see ``deepseek.decode_step``."""
    fam = cfg.family
    if with_stats and fam != "moe":
        raise ValueError(f"with_stats is a moe-family knob, not {fam!r}")
    if fam in ("dense", "vlm"):
        return transformer.decode_step(cfg, params, tokens, cache)
    if fam == "moe":
        return deepseek.decode_step(cfg, params, tokens, cache,
                                    moe_impl=moe_impl, with_stats=with_stats)
    if fam == "ssm":
        return _ssm_step(cfg, params, tokens, cache)
    if fam == "hybrid":
        return hybrid.decode_step(cfg, params, tokens, cache)
    if fam == "encdec":
        return encdec.decode_step(cfg, params, tokens, cache)
    raise ValueError(fam)
