"""Dense decoder-only transformer family (smollm / phi-3 / phi-4 / gemma,
plus the paligemma backbone).  Layers are scanned (params stacked on axis 0)
so HLO size and compile time stay flat in depth — required for the 512-device
dry-run of 30-80 layer models.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.remat import maybe_remat, scan_layers
from repro.models.common import (
    causal_mask,
    gqa_attention_block,
    mlp_block,
    paged_gqa_attention_block,
    paged_gqa_attention_block_quantized,
    prefix_lm_mask,
    rms_norm,
)


def _init_linear(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else d_in**-0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def init_layer_params(cfg, key, dtype):
    ks = jax.random.split(key, 8)
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "attn": {
            "wq": _init_linear(ks[0], cfg.d_model, h * hd, dtype),
            "wk": _init_linear(ks[1], cfg.d_model, kh * hd, dtype),
            "wv": _init_linear(ks[2], cfg.d_model, kh * hd, dtype),
            "wo": _init_linear(ks[3], h * hd, cfg.d_model, dtype),
        },
        "mlp_norm": jnp.ones((cfg.d_model,), dtype),
        "mlp": {
            "wg": _init_linear(ks[4], cfg.d_model, cfg.d_ff, dtype),
            "wu": _init_linear(ks[5], cfg.d_model, cfg.d_ff, dtype),
            "wd": _init_linear(ks[6], cfg.d_ff, cfg.d_model, dtype),
        },
    }


def init_params(cfg, key, max_seq: int = 0):
    dtype = jnp.dtype(cfg.dtype)
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer_params(cfg, k, jnp.float32))(layer_keys)
    layers = jax.tree.map(lambda a: a.astype(dtype), layers)
    params = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _init_linear(k_head, cfg.d_model, cfg.vocab_size, dtype)
    return params


def decoder_layer(cfg, lp, x, positions, mask, cache=None):
    """One pre-norm block. Returns (x, new_cache_slice)."""
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    a, new_cache = gqa_attention_block(lp["attn"], h, positions, cfg, mask, cache)
    x = x + a
    h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    x = x + mlp_block(lp["mlp"], h, cfg.act)
    return x, new_cache


def run_layers(cfg, layers, x, positions, mask, cache=None):
    """Scan the stacked layer params over x. cache: dict with k/v stacked
    (L, B, S, K, hd) and scalar 'offset', or None."""
    if cache is None:

        def body(xc, lp):
            y, _ = decoder_layer(cfg, lp, xc, positions, mask, None)
            return y, None

        x, _ = scan_layers(cfg, maybe_remat(cfg, body), x, layers)
        return x, None

    offset = cache["offset"]

    def body(xc, xs):
        lp, ck, cv = xs
        y, nc = decoder_layer(
            cfg, lp, xc, positions, mask, dict(k=ck, v=cv, offset=offset)
        )
        return y, (nc["k"], nc["v"])

    x, (nk, nv) = scan_layers(cfg, body, x, (layers, cache["k"], cache["v"]))
    new_cache = dict(k=nk, v=nv, offset=offset + positions.shape[-1])
    return x, new_cache


def embed_tokens(cfg, params, tokens):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    return x


def unembed(cfg, params, x):
    # rotation fusion (QuaRot) may materialize an explicit lm_head for tied
    # models (final-norm γ cannot be folded into a shared embedding)
    if "lm_head" in params:
        head = params["lm_head"]
    else:
        head = params["embed"].T
    logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits


def forward(cfg, params, tokens, prefix_len: int = 0, embeds=None):
    """Teacher-forcing forward. tokens: (B, S) int32.  ``embeds`` (B, P, D)
    optionally prepends precomputed frontend embeddings (VLM stub)."""
    x = embed_tokens(cfg, params, tokens)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
        prefix_len = max(prefix_len, embeds.shape[1])
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    if prefix_len > 0:
        mask = prefix_lm_mask(s, s, prefix_len, 0)
    else:
        mask = causal_mask(s, s, 0)
    x, _ = run_layers(cfg, params["layers"], x, positions, mask)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(cfg, params, x)


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    kh, hd = cfg.n_kv_heads, cfg.head_dim
    shape = (cfg.n_layers, batch, max_seq, kh, hd)
    return dict(
        k=jnp.zeros(shape, dtype),
        v=jnp.zeros(shape, dtype),
        offset=jnp.zeros((), jnp.int32),
    )


def prefill(cfg, params, tokens, cache, prefix_len: int = 0, embeds=None):
    x = embed_tokens(cfg, params, tokens)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
        prefix_len = max(prefix_len, embeds.shape[1])
    b, s, _ = x.shape
    kv_len = cache["k"].shape[2]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    base = causal_mask(s, kv_len, 0) if prefix_len == 0 else prefix_lm_mask(s, kv_len, prefix_len, 0)
    # mask out not-yet-written cache slots beyond s handled by causal bound
    x, cache = run_layers(cfg, params["layers"], x, positions, base, cache)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(cfg, params, x[:, -1:]), cache


def init_paged_cache(cfg, num_pages: int, page_size: int, dtype=jnp.bfloat16,
                     kv_spec=None):
    """A paged KV pool shared by every in-flight request: page id indexes
    axis 1, page 0 is the reserved null page (never allocated; padding and
    inactive-slot writes are redirected there).

    A quantized ``kv_spec`` stores int8 (or pack_int4'd uint8) pages plus
    f32 scale-plane leaves ``k_scale``/``v_scale`` shaped
    ``(L, NP, P, kh, n_groups)`` — same page axis (1), so the engine's
    page-id rollback and the page-scoped fault surface
    (``FaultInjector.corrupt_pages``) cover the sidecar for free.  A float
    spec routes its dtype and builds exactly the two-leaf pool below."""
    kh, hd = cfg.n_kv_heads, cfg.head_dim
    if kv_spec is not None and kv_spec.is_quantized:
        shape = (cfg.n_layers, num_pages, page_size, kh,
                 kv_spec.packed_head_dim(hd))
        sshape = (cfg.n_layers, num_pages, page_size, kh,
                  kv_spec.n_groups(hd))
        return dict(k=jnp.zeros(shape, kv_spec.pool_dtype),
                    v=jnp.zeros(shape, kv_spec.pool_dtype),
                    k_scale=jnp.zeros(sshape, jnp.float32),
                    v_scale=jnp.zeros(sshape, jnp.float32))
    if kv_spec is not None:
        dtype = kv_spec.cache_dtype
    shape = (cfg.n_layers, num_pages, page_size, kh, hd)
    return dict(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def paged_step(cfg, params, tokens, positions, valid, cache, block_table,
               sample_row=None, kv_spec=None):
    """One forward step against the paged KV pool — the single entry point
    for BOTH chunked prefill (B=1, S=chunk) and batched decode (B=slots,
    S=1), so the serving engine compiles exactly two traces per config.

    tokens (B, S) int32; positions (B, S) absolute token positions;
    valid (B, S) bool (False = padding / inactive slot: the KV write is
    redirected to the null page and the row's output is garbage the caller
    ignores); block_table (B, MPB) int32 page ids.  ``sample_row`` (B,)
    optionally selects one hidden row per batch entry before the unembed
    (the last real prompt token of a final prefill chunk), matching
    ``prefill``'s logits[:, -1:] shape.  Returns (logits (B, S|1, V),
    new_cache)."""
    x = embed_tokens(cfg, params, tokens)
    page_size = cache["k"].shape[2]
    kv_len = block_table.shape[1] * page_size
    kj = jnp.arange(kv_len)
    mask = (kj[None, None, :] <= positions[:, :, None]) & valid[:, :, None]

    # The spec branch happens HERE, at Python trace time: a float (or
    # absent) kv_spec traces exactly the pre-KVSpec graph — no scale
    # leaves, no extra ops — which is what keeps f32 serving bitwise
    # identical under the chaos + crash-recovery contract.
    if kv_spec is not None and kv_spec.is_quantized:

        def qbody(xc, xs):
            lp, pk, pv, sk, sv = xs
            h = rms_norm(xc, lp["attn_norm"], cfg.norm_eps)
            a, npk, npv, nsk, nsv = paged_gqa_attention_block_quantized(
                lp["attn"], h, positions, valid, cfg, mask, pk, pv, sk, sv,
                block_table, kv_spec)
            xc = xc + a
            h = rms_norm(xc, lp["mlp_norm"], cfg.norm_eps)
            xc = xc + mlp_block(lp["mlp"], h, cfg.act)
            return xc, (npk, npv, nsk, nsv)

        x, (nk, nv, nks, nvs) = scan_layers(
            cfg, qbody, x, (params["layers"], cache["k"], cache["v"],
                            cache["k_scale"], cache["v_scale"]))
        new_cache = dict(k=nk, v=nv, k_scale=nks, v_scale=nvs)
    else:

        def body(xc, xs):
            lp, pk, pv = xs
            h = rms_norm(xc, lp["attn_norm"], cfg.norm_eps)
            a, npk, npv = paged_gqa_attention_block(
                lp["attn"], h, positions, valid, cfg, mask, pk, pv,
                block_table)
            xc = xc + a
            h = rms_norm(xc, lp["mlp_norm"], cfg.norm_eps)
            xc = xc + mlp_block(lp["mlp"], h, cfg.act)
            return xc, (npk, npv)

        x, (nk, nv) = scan_layers(
            cfg, body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = dict(k=nk, v=nv)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if sample_row is not None:
        x = jax.vmap(
            lambda xb, r: jax.lax.dynamic_slice_in_dim(xb, r, 1))(x, sample_row)
    return unembed(cfg, params, x), new_cache


def decode_step(cfg, params, tokens, cache):
    """tokens: (B, 1). Attends to cache[0:offset] + self."""
    x = embed_tokens(cfg, params, tokens)
    b = x.shape[0]
    offset = cache["offset"]
    positions = jnp.broadcast_to(offset, (b, 1))
    kv_len = cache["k"].shape[2]
    kj = jnp.arange(kv_len)[None, :]
    mask = kj <= offset  # (1, kv_len)
    x, cache = run_layers(cfg, params["layers"], x, positions, mask, cache)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(cfg, params, x), cache
