"""Fault-tolerant checkpointing (no orbax in this container — pure numpy).

 - per-leaf ``.npy`` files + a JSON manifest with the pytree structure,
 - ATOMIC: written to ``<dir>/.tmp_step_*`` then os.rename'd — a crash
   mid-save never corrupts the latest checkpoint,
 - keep-k rotation, with **crash-debris hygiene**: stray non-``step_*``
   entries and malformed ``step_<garbage>`` names never break the scan, and
   orphaned ``.tmp_step_*`` directories left by a mid-save crash are garbage
   -collected on the next rotation,
 - incomplete checkpoints (missing manifest, missing leaf files, manifest
   missing an expected leaf) raise a structured :class:`CheckpointError`;
   ``CheckpointManager.restore_latest`` falls back step by step to the
   newest checkpoint that loads cleanly,
 - **mesh-elastic restore**: leaves are saved as full logical arrays
   (device_get) and resharded onto the CURRENT mesh/sharding at load — a
   restart on a different device count re-lowers and resumes (tested on
   resized host-device meshes),
 - resume-from-latest scanning.

The serving engine's crash-recovery snapshots (``ServeEngine.snapshot`` /
``restore``) ride on this exact path: the same atomic ``.tmp``-rename save,
the same keep-k rotation, the same incomplete-checkpoint fallback.

At real multi-pod scale the device_get/put pair becomes a per-host sharded
read/write (same manifest format); the single-process container exercises the
full logic minus the multi-host gather.
"""

from __future__ import annotations

import json
import os
import shutil
import warnings
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


class CheckpointError(RuntimeError):
    """A checkpoint directory exists but cannot be loaded (missing
    manifest, missing leaf file, manifest missing an expected leaf) —
    typically debris from a crash mid-save that slipped past the atomic
    rename (e.g. a partially deleted directory)."""


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        names.append("__".join(parts) or "leaf")
        leaves.append(leaf)
    return names, leaves, treedef


def _step_dirs(ckpt_dir) -> Dict[int, Path]:
    """Map step -> checkpoint dir, skipping crash debris: non-``step_*``
    entries, ``step_<non-integer>`` strays, and plain files."""
    d = Path(ckpt_dir)
    if not d.exists():
        return {}
    out: Dict[int, Path] = {}
    for p in d.iterdir():
        if not p.is_dir() or not p.name.startswith("step_"):
            continue
        try:
            step = int(p.name.split("_", 1)[1])
        except ValueError:
            continue  # "step_garbage" debris — never a checkpoint we wrote
        out[step] = p
    return out


def _gc_orphan_tmp(ckpt_dir) -> int:
    """Remove orphaned ``.tmp_step_*`` directories (a crash mid-save left
    them behind; the atomic rename means they are never the latest state).
    Returns the number removed."""
    d = Path(ckpt_dir)
    if not d.exists():
        return 0
    n = 0
    for p in d.iterdir():
        if p.is_dir() and p.name.startswith(".tmp_step_"):
            shutil.rmtree(p, ignore_errors=True)
            n += 1
    return n


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    """Atomic save of a pytree at ``<ckpt_dir>/step_<step>``."""
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    names, leaves, _ = _flatten_with_names(tree)
    manifest = {"step": step, "leaves": []}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append(
            dict(name=name, file=fname, shape=list(arr.shape), dtype=str(arr.dtype))
        )
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return str(final)


def _read_manifest(path: Path) -> dict:
    mf = path / "manifest.json"
    if not mf.exists():
        raise CheckpointError(
            f"checkpoint {path} is incomplete: no manifest.json "
            f"(crash debris?)")
    try:
        return json.loads(mf.read_text())
    except (json.JSONDecodeError, OSError) as e:
        raise CheckpointError(
            f"checkpoint {path} has an unreadable manifest: {e}") from None


def load_leaf(path, name: str) -> np.ndarray:
    """Load ONE named leaf from a checkpoint without reconstructing the
    whole tree (used for variable-shape metadata leaves the ``like``-tree
    protocol cannot express, e.g. the serving snapshot's JSON blob)."""
    path = Path(path)
    manifest = _read_manifest(path)
    for entry in manifest["leaves"]:
        if entry["name"] == name:
            try:
                return np.load(path / entry["file"])
            except (OSError, ValueError) as e:
                raise CheckpointError(
                    f"checkpoint {path} leaf {name!r} is unreadable: {e}"
                ) from None
    raise CheckpointError(
        f"checkpoint {path} is incomplete: manifest has no leaf {name!r}")


def load_checkpoint(path: str, like: Any, shardings: Any = None) -> Any:
    """Restore a pytree saved by :func:`save_checkpoint` into the structure
    of ``like`` (ShapeDtypeStructs or arrays).  ``shardings``: optional tree
    of NamedShardings for the CURRENT mesh — elastic restore.

    Manifest entries not named by ``like`` are ignored; a leaf ``like``
    expects but the manifest lacks raises :class:`CheckpointError` (the
    "checkpoint incomplete" signal ``restore_latest`` falls back on)."""
    path = Path(path)
    manifest = _read_manifest(path)
    names, like_leaves, treedef = _flatten_with_names(like)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    shard_leaves = (
        jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding)
        )
        if shardings is not None
        else [None] * len(like_leaves)
    )
    out = []
    for name, like_leaf, shard in zip(names, like_leaves, shard_leaves):
        entry = by_name.get(name)
        if entry is None:
            raise CheckpointError(
                f"checkpoint {path} is incomplete: manifest is missing "
                f"leaf {name!r} ({len(by_name)} leaves present)")
        try:
            arr = np.load(path / entry["file"])
        except (OSError, ValueError) as e:
            raise CheckpointError(
                f"checkpoint {path} leaf {name!r} is unreadable: {e}"
            ) from None
        expect = tuple(like_leaf.shape)
        if tuple(arr.shape) != expect:
            raise ValueError(f"{name}: checkpoint shape {arr.shape} != {expect}")
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = sorted(_step_dirs(ckpt_dir))
    return steps[-1] if steps else None


class CheckpointManager:
    """save-every-N + keep-k rotation + resume-from-latest (with fallback
    past incomplete checkpoints and crash-debris garbage collection)."""

    def __init__(self, ckpt_dir: str, every: int = 100, keep: int = 3):
        self.dir = Path(ckpt_dir)
        self.every = every
        self.keep = keep

    def maybe_save(self, step: int, tree) -> Optional[str]:
        if step % self.every != 0:
            return None
        return self.save(step, tree)

    def save(self, step: int, tree) -> str:
        """Unconditional atomic save + rotation (``maybe_save`` without the
        every-N gate — the serving engine snapshots on its own schedule)."""
        path = save_checkpoint(self.dir, step, tree)
        self._gc()
        return path

    def _gc(self):
        _gc_orphan_tmp(self.dir)
        by_step = _step_dirs(self.dir)
        for s in sorted(by_step)[: -self.keep]:
            shutil.rmtree(by_step[s], ignore_errors=True)

    def restore_latest(self, like, shardings=None):
        """Load the newest checkpoint that restores cleanly.  An incomplete
        checkpoint (``CheckpointError``) is skipped with a warning naming
        the fallback step; if every candidate is damaged the last error is
        re-raised.  No checkpoints at all -> ``(None, None)``."""
        by_step = _step_dirs(self.dir)
        last_err: Optional[CheckpointError] = None
        for step in sorted(by_step, reverse=True):
            try:
                tree = load_checkpoint(by_step[step], like, shardings)
                return step, tree
            except CheckpointError as e:
                older = [s for s in by_step if s < step]
                fallback = (f"falling back to step {max(older)}" if older
                            else "no older checkpoint to fall back to")
                warnings.warn(f"checkpoint incomplete at step {step} "
                              f"({e}); {fallback}")
                last_err = e
        if last_err is not None:
            raise CheckpointError(
                f"no restorable checkpoint under {self.dir}: every step in "
                f"{sorted(by_step)} is incomplete ({last_err})")
        return None, None
