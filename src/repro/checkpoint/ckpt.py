"""Fault-tolerant checkpointing (no orbax in this container — pure numpy).

 - per-leaf ``.npy`` files + a JSON manifest with the pytree structure,
 - ATOMIC: written to ``<dir>.tmp`` then os.rename'd — a crash mid-save never
   corrupts the latest checkpoint,
 - keep-k rotation,
 - **mesh-elastic restore**: leaves are saved as full logical arrays
   (device_get) and resharded onto the CURRENT mesh/sharding at load — a
   restart on a different device count re-lowers and resumes (tested on
   resized host-device meshes),
 - resume-from-latest scanning.

At real multi-pod scale the device_get/put pair becomes a per-host sharded
read/write (same manifest format); the single-process container exercises the
full logic minus the multi-host gather.
"""

from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        names.append("__".join(parts) or "leaf")
        leaves.append(leaf)
    return names, leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    """Atomic save of a pytree at ``<ckpt_dir>/step_<step>``."""
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    names, leaves, _ = _flatten_with_names(tree)
    manifest = {"step": step, "leaves": []}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append(
            dict(name=name, file=fname, shape=list(arr.shape), dtype=str(arr.dtype))
        )
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return str(final)


def load_checkpoint(path: str, like: Any, shardings: Any = None) -> Any:
    """Restore a pytree saved by :func:`save_checkpoint` into the structure
    of ``like`` (ShapeDtypeStructs or arrays).  ``shardings``: optional tree
    of NamedShardings for the CURRENT mesh — elastic restore."""
    path = Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    names, like_leaves, treedef = _flatten_with_names(like)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    shard_leaves = (
        jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding)
        )
        if shardings is not None
        else [None] * len(like_leaves)
    )
    out = []
    for name, like_leaf, shard in zip(names, like_leaves, shard_leaves):
        entry = by_name[name]
        arr = np.load(path / entry["file"])
        expect = tuple(like_leaf.shape)
        if tuple(arr.shape) != expect:
            raise ValueError(f"{name}: checkpoint shape {arr.shape} != {expect}")
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = sorted(
        int(p.name.split("_")[1]) for p in d.iterdir()
        if p.is_dir() and p.name.startswith("step_")
    )
    return steps[-1] if steps else None


class CheckpointManager:
    """save-every-N + keep-k rotation + resume-from-latest."""

    def __init__(self, ckpt_dir: str, every: int = 100, keep: int = 3):
        self.dir = Path(ckpt_dir)
        self.every = every
        self.keep = keep

    def maybe_save(self, step: int, tree) -> Optional[str]:
        if step % self.every != 0:
            return None
        path = save_checkpoint(self.dir, step, tree)
        self._gc()
        return path

    def _gc(self):
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.dir.iterdir()
            if p.is_dir() and p.name.startswith("step_")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def restore_latest(self, like, shardings=None):
        step = latest_step(self.dir)
        if step is None:
            return None, None
        tree = load_checkpoint(self.dir / f"step_{step:08d}", like, shardings)
        return step, tree
