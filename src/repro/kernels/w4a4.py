"""Fused W4A4 matmul with low-rank epilogue (the paper's §5 "future work").

Computes   out = (Xq · Wq) · s_x · s_w  +  (X V) Uᵀ

  Xq       (M, K)    int8, per-token-quantized activations (int4 grid)
  s_x      (M, 1)    f32 per-token scales — or, with ``group``, the
                     (M, K//group) per-group scale plane (paper Table 2)
  Wpacked  (K//2, N) uint8 — two int4 weights per byte along K
  s_w      (1, N)    f32 per-output-channel scales
  XV       (M, R)    f32 — the small (X V) matmul, precomputed (R ≪ K)
  U        (N, R)    f32/bf16

Grid (M/BM, N/BN, K/BK); K is the reduction axis, innermost.  Per-token, the
int32 accumulator lives in a VMEM scratch and the epilogue rescales once at
the last K step.  GROUP-WISE, the dequant moves INTO the K loop: BK is a
multiple of ``group`` (chunks hold whole scale groups), each K step streams
its (BM, BK//group) slice of the scale plane and accumulates the
group-rescaled partials in an f32 scratch via the canonical
``rowops.gemm_chunk_grouped`` order — the same dots in the same order the
fused kernel issues, which keeps the paths bitwise identical.  Either way
the last K step adds the low-rank tile contribution (XV_tile @ U_tileᵀ)
before the single HBM write of the output tile — the low-rank FLOPs ride
the MXU alongside the quantized GEMM instead of a second HBM pass.

Weight unpacking happens in VMEM: low nibble = even-K rows, high = odd.
TPU adaptation notes: v5e has no int4 MXU — int4 is the STORAGE format
(halving weight HBM traffic, the decode bottleneck); compute runs
int8×int8→int32 on the MXU, matching Ampere's int4-storage/int8-compute
reality the paper measured with Cutlass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.rowops import (gemm_chunk_grouped,
                                  unpack_int4_rows as _unpack_block)


def _body(xq_ref, sx_ref, wp_ref, sw_ref, xv_ref, u_ref, out_ref, acc_ref, *,
          n_k: int, group):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w_blk = _unpack_block(wp_ref[...])  # (BK, BN) int8
    if group is None:
        acc_ref[...] += jax.lax.dot_general(
            xq_ref[...], w_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
    else:
        # dequant in the K loop: this chunk's groups rescaled before the
        # f32 accumulation (canonical order shared with the fused kernel)
        acc_ref[...] += gemm_chunk_grouped(xq_ref[...], w_blk, sx_ref[...],
                                           group)

    @pl.when(k == n_k - 1)
    def _epilogue():
        if group is None:
            out = acc_ref[...].astype(jnp.float32) * sx_ref[...] * sw_ref[...]
        else:
            out = acc_ref[...] * sw_ref[...]  # activation scales already in
        if xv_ref is not None:
            lr = jax.lax.dot_general(
                xv_ref[...].astype(jnp.float32),
                u_ref[...].astype(jnp.float32),
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            out = out + lr
        out_ref[...] = out


def _kernel_lr(xq_ref, sx_ref, wp_ref, sw_ref, xv_ref, u_ref, out_ref, acc_ref,
               *, n_k: int, group):
    _body(xq_ref, sx_ref, wp_ref, sw_ref, xv_ref, u_ref, out_ref, acc_ref,
          n_k=n_k, group=group)


def _kernel_nolr(xq_ref, sx_ref, wp_ref, sw_ref, out_ref, acc_ref, *,
                 n_k: int, group):
    _body(xq_ref, sx_ref, wp_ref, sw_ref, None, None, out_ref, acc_ref,
          n_k=n_k, group=group)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "group", "interpret"),
)
def w4a4_lowrank_matmul_kernel(
    xq: jnp.ndarray,  # (M, K) int8
    sx: jnp.ndarray,  # (M, 1) f32 per-token, or (M, K//group) scale plane
    wpacked: jnp.ndarray,  # (K//2, N) uint8
    sw: jnp.ndarray,  # (1, N) f32
    xv,  # (M, R) f32 or None
    u,  # (N, R) or None
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    group: int = None,  # None = per-token scales; else BK % group == 0
    interpret: bool = True,
):
    m, k = xq.shape
    n = wpacked.shape[1]
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    n_k = k // bk
    with_lr = xv is not None
    if group is None:
        n_sb = 1  # one per-token scale column, pinned across K steps
        sx_spec = pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0))
    else:
        assert bk % group == 0, (bk, group)  # chunks hold whole groups
        assert sx.shape[1] == k // group, (sx.shape, k, group)
        n_sb = bk // group  # this chunk's slice of the scale plane
        sx_spec = pl.BlockSpec((bm, n_sb), lambda i, j, kk: (i, kk))

    grid = (m // bm, n // bn, n_k)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),  # xq
        sx_spec,  # sx (per-token column or per-chunk plane slice)
        pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),  # wpacked
        pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),  # sw
    ]
    operands = [xq, sx, wpacked, sw]
    if with_lr:  # rank-0 calls simply omit the LR operands from the signature
        r = xv.shape[1]
        in_specs += [
            pl.BlockSpec((bm, r), lambda i, j, kk: (i, 0)),  # xv
            pl.BlockSpec((bn, r), lambda i, j, kk: (j, 0)),  # u
        ]
        operands += [xv, u]
        kernel = functools.partial(_kernel_lr, n_k=n_k, group=group)
    else:
        kernel = functools.partial(_kernel_nolr, n_k=n_k, group=group)

    acc_dtype = jnp.int32 if group is None else jnp.float32
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        # Mosaic pipeline: M/N tiles are independent (megacore-splittable);
        # K carries the accumulator and must stay sequential + innermost.
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return out
