"""Explicit, per-layer kernel execution config: :class:`KernelContext`.

The W4A4+LRC dispatch layer used to keep its execution config — the
regime-keyed plan table, the VMEM working-set budgets, the measured-winner
overlay — in module-global mutable state on ``kernels/ops.py``, which
forced one plan table and one budget on every layer of every model in the
process (two ``ServeEngine``s could race each other's globals).  The paper's
pipeline wants the opposite: *per-layer* decisions, because each projection
has its own (K, N, R) shape, rank fraction and rotation flag.

:class:`KernelContext` is the replacement: an immutable (frozen, hashable —
safe as pytree-static metadata and as a jit static argument) value object
holding

  * the regime plan table (decode / mixed / prefill → path + BM/BN/BK/BR),
  * the fused / prologue VMEM working-set budgets,
  * the default kernel impl ("auto" | "fused" | "chained" | "unfused"),
  * the interpret flag (None = auto: interpret on CPU, compiled on TPU),
  * optional per-layer plan overrides keyed by layer name or (K, N, R)
    shape, taking precedence over the table.

Construction::

    ctx = KernelContext()                          # analytic defaults
    ctx = KernelContext.from_json("results/block_table.json")
    ctx = ctx.with_vmem_budgets(fused=4 << 20)     # builders return copies
    ctx = ctx.with_layer_overrides({"mlp/wd": {"path": "chained", "bm": 8}})

Resolution::

    plan = ctx.resolve_plan(m, k, n, r, rotate=True, layer="mlp/wd")
    print(ctx.explain(m, k, n, r, rotate=True))    # per-regime report

``kernels/ops.py`` threads a ``ctx=`` through ``w4a4_lrc_forward`` /
``select_plan`` / ``resolve_plan`` (``None`` → the process-default
context).  The old global setters (``load_block_table`` /
``set_vmem_budgets``) finished their deprecation window and are gone.

Activation-scale granularity rides the same resolution:
``resolve_plan(..., act_group=g)`` snaps BK to a power-of-two multiple of
``g`` (K-chunks must hold whole scale groups), adds the per-group
(M, K/g) scale plane to the VMEM working-set model, and demotes a path
only when no multiple-of-``g`` tiling fits — ``explain(...,
act_group=g)`` reports the snap and any granularity-driven demotion.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from pathlib import Path
from typing import NamedTuple, Optional

from repro.kernels.rowops import (default_proj_tiles,
                                  round_pow2 as _round_pow2,
                                  snap_bk_to_group)

# Default working-set budget of the two-kernel chain's prologue (x row slab
# + rotated-row scratch + xq/sx/xv outputs + double-buffered V tiles).
# Historically this was the ceiling on a WHOLE-VMEM V; V now streams in
# (bk, br) tiles, so the budget gates the row slab instead and the 8 MB
# figure keeps the same "three quarters of a useful VMEM half" intent.
PROLOGUE_V_BYTES_MAX = 8 * 1024 * 1024

# Default working-set ceiling for the single-kernel fused path (resident
# scratch + double-buffered streamed blocks).  ~¾ of a v5e core's 16 MB
# VMEM, leaving room for Mosaic's pipelining overheads.  Tiles shrink to
# fit this before the path demotes (see KernelContext.resolve_plan).
FUSED_VMEM_BYTES_MAX = 12 * 1024 * 1024

# Analytic default execution plans: the kernel path plus (BM, BN, BK, BR).
# decode  (M ≤ 32):  single-kernel fused — the decode hot path is
#                    activation+weight-HBM-bound; tiny M tile, wide N×K
#                    tiles stream the weight matrix.
# mixed   (M ≤ 512): single-kernel fused, balanced tiles.
# prefill (M > 512): single-kernel fused as well since the K-split grid —
#                    the (BM, K) f32 row slab that used to crowd VMEM now
#                    either fits (resident) or is traded for one extra x
#                    read (streamed); the GEMM is MXU-bound at these M, and
#                    fused ≤ chained on activation bytes at every M.
DEFAULT_BLOCK_TABLE = {
    "decode": dict(path="fused", bm=16, bn=256, bk=512, br=512),
    "mixed": dict(path="fused", bm=128, bn=128, bk=256, br=512),
    "prefill": dict(path="fused", bm=256, bn=256, bk=256, br=512),
}

KERNEL_PATHS = ("fused", "chained", "unfused")
IMPLS = ("auto",) + KERNEL_PATHS
REGIMES = tuple(sorted(DEFAULT_BLOCK_TABLE))
VARIANTS = ("resident", "streamed")

_TILE_DIMS_REQUIRED = ("bm", "bn", "bk")
_TILE_DIMS_ALL = ("bm", "bn", "bk", "br")
_PLAN_KEYS = ("path", "bm", "bn", "bk", "br", "variant")
_VMEM_KEYS = ("fused_bytes_max", "prologue_bytes_max")


class Plan(NamedTuple):
    """A resolved execution plan: kernel path, tile dims, and (fused only)
    the prologue variant ("resident" | "streamed")."""
    path: str
    bm: int
    bn: int
    bk: int
    br: int
    variant: Optional[str] = None


def gemm_regime(m: int) -> str:
    if m <= 32:
        return "decode"
    if m <= 512:
        return "mixed"
    return "prefill"


# ---------------------------------------------------------------------------
# VMEM working-set byte models + shrink-to-fit (pure functions of a budget)
# ---------------------------------------------------------------------------


def fused_vmem_bytes(k: int, r: int, bm: int, bn: int, bk: int, br: int,
                     resident: bool, act_group: int = None) -> int:
    """Worst-case VMEM working set of the K-split fused kernel: resident
    scratch plus double-buffered streamed blocks.  ``act_group`` swaps the
    (bm, 1) per-token scale for the (bm, K/g) scale plane."""
    k_pad = k + (-k) % bk
    r_pad = (r + (-r) % br) if r else 0
    n_s = 1 if act_group is None else k_pad // act_group
    res = (
        bm * k_pad          # xq int8 residency
        + bm * n_s * 4      # sx (per-token column or per-group scale plane)
        + bm * bn * 4       # GEMM accumulator (int32 or grouped f32)
    )
    if r:
        res += bm * r_pad * 4  # xv accumulator
    if resident:
        res += bm * k_pad * 4  # f32 (rotated) row slab
    stream = (
        bm * bk * 4         # x chunk (f32 upper bound)
        + (bk // 2) * bn    # packed-weight chunk
        + bn * 4            # sw
        + bm * bn * 4       # out tile
    )
    if r:
        stream += bk * br * 4 + bn * r_pad * 4  # V tile + U slab
    return res + 2 * stream


def prologue_vmem_bytes(k: int, r: int, bm: int, bk: int, br: int,
                        rotate: bool, act_group: int = None) -> int:
    """Working set of the standalone (chained-path) prologue kernel: the x
    row slab, the rotated-row scratch, the xq/sx/xv outputs and the
    double-buffered streamed V tiles."""
    k_pad = k + (-k) % bk if r else k
    r_pad = (r + (-r) % br) if r else 0
    n_s = 1 if act_group is None else k_pad // act_group
    # x slab + q out + s out (per-token column or per-group scale plane)
    b = bm * k_pad * 4 + bm * k_pad + bm * n_s * 4
    if rotate:
        b += bm * k_pad * 4  # rotated-row scratch
    if r:
        b += bm * r_pad * 4 + 2 * (bk * br * 4)  # xv out + V tiles
    return b


def _shrink_to_fit(bytes_fn, tiles: dict, mins: dict, budget: int):
    """Greedily halve tile dims (largest byte saving first, deterministic
    tie-break in ``mins`` key order) until ``bytes_fn(**tiles)`` fits
    ``budget``.  Returns the fitted tiles dict or None."""
    tiles = dict(tiles)
    while bytes_fn(**tiles) > budget:
        best = None
        for dim in mins:
            if tiles[dim] // 2 < mins[dim]:
                continue
            cand = dict(tiles)
            cand[dim] //= 2
            got = bytes_fn(**cand)
            if best is None or got < best[0]:
                best = (got, dim)
        if best is None:
            return None
        tiles[best[1]] //= 2
    return tiles


def _fit_fused(k: int, r: int, bm: int, bn: int, bk: int, br: int,
               rotate: bool, budget: int, variant_pin: str = None,
               act_group: int = None):
    """Feasible (bm, bn, bk, br, variant) for the fused kernel under
    ``budget``, shrinking tiles as needed; None when nothing fits.  The
    resident prologue is preferred (one x read); the streamed variant
    (rotate=False only) trades an extra x read for dropping the f32 row
    slab.  ``variant_pin`` restricts the search to one variant (a
    table/override pin); rotation still forces the resident slab.  With
    group-wise scales (``act_group``) BK starts snapped to a power-of-two
    multiple of the group and can shrink no further than one group — the
    halving search stays closed over the chunks-hold-whole-groups
    constraint."""
    if act_group is not None:
        bk = snap_bk_to_group(bk, act_group)
    mins = dict(bk=act_group if act_group is not None else min(bk, 128),
                br=min(br, 128), bn=min(bn, 128), bm=min(bm, 8))
    variants = ("resident",) if rotate else ("resident", "streamed")
    if variant_pin is not None and not (rotate and variant_pin == "streamed"):
        variants = (variant_pin,)
    for variant in variants:
        def bytes_fn(bm, bn, bk, br, _res=(variant == "resident")):
            return fused_vmem_bytes(k, r, bm, bn, bk, br, _res,
                                    act_group=act_group)
        fit = _shrink_to_fit(bytes_fn, dict(bm=bm, bn=bn, bk=bk, br=br),
                             mins, budget)
        if fit is not None:
            return Plan("fused", fit["bm"], fit["bn"], fit["bk"], fit["br"],
                        variant)
    return None


def _fit_chained(k: int, r: int, bm: int, bn: int, bk: int, br: int,
                 rotate: bool, budget: int, act_group: int = None):
    """Feasible chained-path plan under the prologue budget, or None."""
    if act_group is not None:
        bk = snap_bk_to_group(bk, act_group)
    mins = dict(bk=act_group if act_group is not None else min(bk, 128),
                br=min(br, 128), bm=min(bm, 8))

    def bytes_fn(bm, bk, br):
        return prologue_vmem_bytes(k, r, bm, bk, br, rotate,
                                   act_group=act_group)

    fit = _shrink_to_fit(bytes_fn, dict(bm=bm, bk=bk, br=br), mins, budget)
    if fit is None:
        return None
    return Plan("chained", fit["bm"], bn, fit["bk"], fit["br"], None)


# ---------------------------------------------------------------------------
# validation + freezing helpers (dict in, hashable tuples stored)
# ---------------------------------------------------------------------------


def _check_tile(where: str, dim: str, val) -> None:
    if not isinstance(val, int) or isinstance(val, bool) or val <= 0:
        raise ValueError(f"{where} tile dim {dim!r} must be a positive "
                         f"integer, got {val!r}")


def _validate_table_entry(regime: str, entry, where="block table") -> None:
    if not isinstance(entry, dict):
        raise ValueError(f"regime {regime!r} in {where} must map to an "
                         f"object, got {type(entry).__name__}")
    if entry.get("path") not in KERNEL_PATHS:
        raise ValueError(
            f"unknown kernel path {entry.get('path')!r} for regime "
            f"{regime!r}; expected one of {KERNEL_PATHS}")
    missing = set(_TILE_DIMS_REQUIRED) - set(entry)
    if missing:
        raise ValueError(f"regime {regime!r} missing keys {missing}")
    for dim in _TILE_DIMS_ALL:
        if dim in entry:  # br is optional (pre-K-split tables)
            _check_tile(f"regime {regime!r}", dim, entry[dim])
    if entry.get("variant", None) not in (None,) + VARIANTS:
        raise ValueError(f"regime {regime!r}: unknown prologue variant "
                         f"{entry['variant']!r}; expected one of {VARIANTS}")


def _validate_override_entry(key, entry, where="overrides") -> None:
    if not isinstance(entry, dict):
        raise ValueError(f"override {key!r} in {where} must map to an "
                         f"object, got {type(entry).__name__}")
    unknown = set(entry) - set(_PLAN_KEYS)
    if unknown:
        raise ValueError(f"override {key!r} has unknown plan keys "
                         f"{sorted(unknown)}; expected a subset of "
                         f"{_PLAN_KEYS}")
    if not entry:
        raise ValueError(f"override {key!r} is empty; give at least one of "
                         f"{_PLAN_KEYS}")
    if "path" in entry and entry["path"] not in KERNEL_PATHS:
        raise ValueError(f"override {key!r}: unknown kernel path "
                         f"{entry['path']!r}; expected one of {KERNEL_PATHS}")
    if "variant" in entry and entry["variant"] not in VARIANTS:
        raise ValueError(f"override {key!r}: unknown prologue variant "
                         f"{entry['variant']!r}; expected one of {VARIANTS}")
    for dim in _TILE_DIMS_ALL:
        if dim in entry:
            _check_tile(f"override {key!r}", dim, entry[dim])


def _freeze_entry(entry: dict) -> tuple:
    """Keep only plan keys (autotune rows carry score_us/shape_mknr etc.)
    and freeze to a sorted, hashable item tuple."""
    return tuple(sorted((k, v) for k, v in entry.items() if k in _PLAN_KEYS))


def _override_key(key):
    """Normalize an override key: a layer-name string, or a (K, N, R) shape
    (tuple/list of 3 ints, frozen to a tuple)."""
    if isinstance(key, str):
        return key
    if (isinstance(key, (tuple, list)) and len(key) == 3
            and all(isinstance(d, int) and not isinstance(d, bool)
                    for d in key)):
        return tuple(key)
    raise ValueError(f"override key {key!r} must be a layer-name string or "
                     f"a (K, N, R) int triple")


def _as_mapping(frozen) -> dict:
    return {k: dict(v) for k, v in frozen}


def vmem_budget_arg(text: str) -> int:
    """argparse type for ``--vmem-budget``: a positive integer byte count.
    Rejects non-integer and non-positive values with a clear error."""
    try:
        val = int(text)
    except (TypeError, ValueError):
        raise argparse.ArgumentTypeError(
            f"expected a positive integer number of bytes, got {text!r}")
    if val <= 0:
        raise argparse.ArgumentTypeError(
            f"expected a positive integer number of bytes, got {val}")
    return val


def context_from_flags(block_table=None, vmem_budget=None, impl=None):
    """The one CLI-flags -> KernelContext mapping (serve / roofline /
    benchmarks all share it): ``--block-table`` loads via
    :meth:`KernelContext.from_json`; ``--vmem-budget`` overrides BOTH
    budgets afterwards, so the CLI wins over the table's ``"vmem"`` entry;
    ``--impl`` sets the default kernel path.  Returns None when every flag
    is None (callers then use the process default)."""
    if block_table is None and vmem_budget is None and impl is None:
        return None
    ctx = (KernelContext.from_json(block_table) if block_table
           else KernelContext())
    if vmem_budget is not None:
        ctx = ctx.with_vmem_budgets(fused=vmem_budget, prologue=vmem_budget)
    if impl is not None:
        ctx = ctx.with_impl(impl)
    return ctx


# ---------------------------------------------------------------------------
# the context
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelContext:
    """Immutable per-process/per-layer kernel execution config.  Hashable
    (all state is frozen into tuples), so it rides as pytree-static QLinear
    metadata and as a jit static argument without retrace surprises.

    ``block_table`` / ``overrides`` accept plain dicts at construction and
    are canonicalized; use :meth:`table` / :meth:`layer_overrides` to read
    them back as dicts."""

    block_table: tuple = None  # dict accepted; frozen in __post_init__
    fused_vmem_bytes: int = FUSED_VMEM_BYTES_MAX
    prologue_vmem_bytes: int = PROLOGUE_V_BYTES_MAX
    impl: str = "auto"  # default kernel path: auto | fused | chained | unfused
    interpret: Optional[bool] = None  # None = auto (interpret on CPU)
    overrides: tuple = ()  # per-layer plan overrides (name or (K, N, R))

    def __post_init__(self):
        table = self.block_table
        if table is None:
            table = DEFAULT_BLOCK_TABLE
        if isinstance(table, tuple):
            table = _as_mapping(table)
        if not isinstance(table, dict):
            raise ValueError(f"block_table must be a mapping, got "
                             f"{type(table).__name__}")
        unknown = set(table) - set(REGIMES)
        if unknown:
            raise ValueError(f"unknown regime {sorted(unknown)[0]!r} in "
                             f"block table; expected one of {list(REGIMES)}")
        merged = {r: dict(DEFAULT_BLOCK_TABLE[r]) for r in REGIMES}
        for regime, entry in table.items():
            _validate_table_entry(regime, entry)
            merged[regime] = dict(entry)
        object.__setattr__(self, "block_table", tuple(
            (r, _freeze_entry(merged[r])) for r in REGIMES))

        ovr = self.overrides
        if isinstance(ovr, tuple) and all(
                isinstance(e, tuple) and len(e) == 2 and
                isinstance(e[1], tuple) for e in ovr):
            ovr = _as_mapping(ovr)
        if not isinstance(ovr, dict):
            raise ValueError(f"overrides must be a mapping, got "
                             f"{type(ovr).__name__}")
        frozen = []
        for key, entry in ovr.items():
            key = _override_key(key)
            _validate_override_entry(key, entry)
            frozen.append((key, _freeze_entry(entry)))
        object.__setattr__(self, "overrides",
                           tuple(sorted(frozen, key=lambda e: str(e[0]))))

        for name in ("fused_vmem_bytes", "prologue_vmem_bytes"):
            val = getattr(self, name)
            if not isinstance(val, int) or isinstance(val, bool) or val < 0:
                raise ValueError(f"VMEM budget {name} must be a "
                                 f"non-negative int of bytes, got {val!r}")
        if self.impl not in IMPLS:
            raise ValueError(f"unknown impl {self.impl!r}; "
                             f"expected one of {IMPLS}")
        if self.interpret not in (None, True, False):
            raise ValueError(f"interpret must be None/True/False, got "
                             f"{self.interpret!r}")

    # -- construction --------------------------------------------------------

    @classmethod
    def default(cls) -> "KernelContext":
        return cls()

    @classmethod
    def from_dict(cls, table: dict, where: str = "block table",
                  **changes) -> "KernelContext":
        """Build a context from an already-parsed block-table dict (the
        format ``benchmarks/autotune_blocks.py`` writes): regime entries
        overlay the analytic defaults; the reserved top-level ``"vmem"``
        entry {"fused_bytes_max": .., "prologue_bytes_max": ..} sets the
        budgets; the reserved ``"layers"`` entry maps layer names (or
        "KxNrR" shape strings) to partial plan overrides.  Malformed tables
        raise ValueError and build nothing.  Extra ``changes`` kwargs (e.g.
        ``impl=``) are applied on top."""
        if not isinstance(table, dict):
            raise ValueError(f"{where} must be a JSON object, "
                             f"got {type(table).__name__}")
        vmem = table.get("vmem", {})
        if not isinstance(vmem, dict):
            raise ValueError(f"'vmem' entry in {where} must be "
                             f"an object, got {type(vmem).__name__}")
        unknown = set(vmem) - set(_VMEM_KEYS)
        if unknown:
            raise ValueError(f"unknown vmem budget keys {sorted(unknown)} "
                             f"in {where}; expected {_VMEM_KEYS}")
        for key, val in vmem.items():
            if not isinstance(val, int) or isinstance(val, bool) or val <= 0:
                raise ValueError(f"vmem budget {key!r} must be a positive "
                                 f"int of bytes, got {val!r}")
        layers = table.get("layers", {})
        if not isinstance(layers, dict):
            raise ValueError(f"'layers' entry in {where} must be "
                             f"an object, got {type(layers).__name__}")
        regimes = {k: v for k, v in table.items()
                   if k not in ("vmem", "layers")}
        for regime, entry in regimes.items():
            if regime not in REGIMES:
                raise ValueError(
                    f"unknown regime {regime!r} in {where}; "
                    f"expected one of {list(REGIMES)}")
            _validate_table_entry(regime, entry, where=where)
        kw = dict(
            block_table=regimes,
            overrides=layers,
            fused_vmem_bytes=vmem.get("fused_bytes_max",
                                      FUSED_VMEM_BYTES_MAX),
            prologue_vmem_bytes=vmem.get("prologue_bytes_max",
                                         PROLOGUE_V_BYTES_MAX),
        )
        kw.update(changes)
        return cls(**kw)

    @classmethod
    def from_json(cls, path, **changes) -> "KernelContext":
        """:meth:`from_dict` on a block-table JSON file; unreadable or
        invalid JSON raises ValueError."""
        try:
            table = json.loads(Path(path).read_text())
        except json.JSONDecodeError as e:
            raise ValueError(
                f"block table {path} is not valid JSON: {e}") from e
        except OSError as e:
            raise ValueError(f"cannot read block table {path}: {e}") from e
        return cls.from_dict(table, where=f"block table {path}", **changes)

    # -- builders (all return new contexts) ----------------------------------

    def with_overrides(self, **changes) -> "KernelContext":
        """General field-replace builder; re-validates the result.
        CAUTION: ``overrides=`` REPLACES the whole per-layer override set —
        use :meth:`with_layer_overrides` to MERGE new per-layer pins onto
        the existing ones."""
        return dataclasses.replace(self, **changes)

    def with_block_table(self, table) -> "KernelContext":
        return self.with_overrides(block_table=table)

    def with_vmem_budgets(self, fused: int = None,
                          prologue: int = None) -> "KernelContext":
        """Override the VMEM working-set budgets (bytes); ``None`` leaves a
        budget unchanged."""
        changes = {}
        if fused is not None:
            changes["fused_vmem_bytes"] = fused
        if prologue is not None:
            changes["prologue_vmem_bytes"] = prologue
        return self.with_overrides(**changes) if changes else self

    def with_impl(self, impl: str) -> "KernelContext":
        return self.with_overrides(impl=impl)

    def with_interpret(self, interpret: Optional[bool]) -> "KernelContext":
        return self.with_overrides(interpret=interpret)

    def with_layer_overrides(self, overrides: dict) -> "KernelContext":
        """Merge per-layer plan overrides (keyed by layer name or (K, N, R))
        onto the existing ones."""
        merged = self.layer_overrides()
        for key, entry in overrides.items():
            merged[_override_key(key)] = dict(entry)
        return self.with_overrides(overrides=merged)

    # -- introspection -------------------------------------------------------

    def table(self) -> dict:
        """The effective regime plan table as a plain dict."""
        return _as_mapping(self.block_table)

    def layer_overrides(self) -> dict:
        return _as_mapping(self.overrides)

    def table_entry(self, regime: str) -> dict:
        got = dict(self.block_table).get(regime)
        if got is None:
            raise ValueError(f"unknown regime {regime!r}; "
                             f"expected one of {list(REGIMES)}")
        return dict(got)

    def layer_plan(self, layer: Optional[str], k: int, n: int,
                   r: int = 0) -> Optional[dict]:
        """The per-layer partial plan override for this layer/shape, or
        None.  Lookup precedence: layer name, then the (K, N, R) shape
        triple, then its "KxNrR" string spelling."""
        ovr = dict(self.overrides)
        for key in (layer, (k, n, r), f"{k}x{n}r{r}"):
            if key is not None and key in ovr:
                return dict(ovr[key])
        return None

    def interpret_mode(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        import jax

        return jax.default_backend() == "cpu"

    # -- plan selection / resolution -----------------------------------------

    def select_plan(self, m: int, k: int, n: int, r: int = 0,
                    regime: str = None, layer: str = None) -> Plan:
        """The table execution plan for a (M, K, N, R) problem — per-layer
        override merged over the regime entry, NO VMEM feasibility applied
        (see :meth:`resolve_plan`).

        ``regime`` overrides the M-derived serving regime; unknown strings
        raise.  Blocks are clamped to the actual dims; large ranks shrink BN
        so the U tile + f32 accumulator stay within VMEM."""
        if regime is None:
            regime = gemm_regime(m)
        entry = self.table_entry(regime)
        override = self.layer_plan(layer, k, n, r)
        if override:
            entry.update(override)
        bm = min(entry["bm"], _round_pow2(max(m, 8)))
        bn = min(entry["bn"], _round_pow2(max(n, 8)))
        bk = min(entry["bk"], _round_pow2(max(k, 8)))
        if "br" in entry:
            br = min(entry["br"], _round_pow2(max(r, 8)))
        else:  # pre-K-split tables: the shared kernel default
            br = default_proj_tiles(k, r)[1]
        if r >= 512:
            bn = min(bn, 128)
        return Plan(entry["path"], bm, bn, bk, br, entry.get("variant"))

    def fused_variant(self, k: int, r: int, bm: int, bn: int, bk: int,
                      br: int, rotate: bool, act_group: int = None) -> str:
        """Prologue variant for FORCED-fused execution at fixed tiles:
        resident when it fits the budget (or rotation requires it), else
        streamed."""
        if rotate:
            return "resident"
        if fused_vmem_bytes(k, r, bm, bn, bk, br, True,
                            act_group=act_group) <= self.fused_vmem_bytes:
            return "resident"
        return "streamed"

    def resolve_plan(self, m: int, k: int, n: int, r: int = 0,
                     rotate: bool = False, regime: str = None,
                     layer: str = None, act_group: int = None) -> Plan:
        """The executable plan for a (M, K, N, R) problem: the table plan
        (with any per-layer override) plus per-slab VMEM feasibility —
        tiles shrink to fit the budget first; the path demotes (fused →
        chained → unfused) only when no tiling fits.

        ``act_group`` (group-wise activation scales, paper Table 2) makes
        the granularity a plan axis: BK snaps to a power-of-two multiple of
        the group (K-chunks must hold whole scale groups; ``g = K`` pins
        BK = K, degenerating to per-token), the (M, K/g) scale plane joins
        the working-set model, and BK shrinks no further than one group —
        a path demotes when no multiple-of-g tiling fits its budget."""
        if act_group is not None and k % act_group:
            raise ValueError(f"act_group {act_group} must divide K={k}")
        sel = self.select_plan(m, k, n, r, regime=regime, layer=layer)
        path, bm, bn, bk, br = sel[:5]
        if act_group is not None:
            bk = snap_bk_to_group(bk, act_group)
        if path == "fused":
            # a table/override variant pin constrains the variant search but
            # NEVER bypasses feasibility — tiles still shrink to fit and the
            # path still demotes when nothing fits (rotation forces the
            # resident slab regardless of the pin)
            plan = _fit_fused(k, r, bm, bn, bk, br, rotate,
                              self.fused_vmem_bytes,
                              variant_pin=sel.variant, act_group=act_group)
            if plan is not None:
                return plan
            path = "chained"
        if path == "chained":
            plan = _fit_chained(k, r, bm, bn, bk, br, rotate,
                                self.prologue_vmem_bytes,
                                act_group=act_group)
            if plan is not None:
                return plan
        return Plan("unfused", bm, bn, bk, br, None)

    # -- introspection report -------------------------------------------------

    def explain(self, m: int, k: int, n: int, r: int = 0,
                rotate: bool = False, layer: str = None,
                act_group: int = None) -> str:
        """Human-readable plan-introspection report: for each serving regime,
        the table plan, the per-layer override (if one matches), the
        resolved path/tiles/variant, and the VMEM working set vs. budget.
        The regime the given M falls into is starred.  With ``act_group``
        the report names the granularity constraint (BK snapped to a
        multiple of g, scale plane in the working set) and flags resolved
        plans whose BK the snap changed or whose path demoted under it."""
        mib = 1024 * 1024
        active = gemm_regime(m)
        lines = [
            f"KernelContext.explain(m={m}, k={k}, n={n}, r={r}, "
            f"rotate={rotate}" + (f", layer={layer!r}" if layer else "")
            + (f", act_group={act_group}" if act_group else "") + ")",
            f"  impl={self.impl}  interpret="
            f"{'auto' if self.interpret is None else self.interpret}  "
            f"budgets: fused={self.fused_vmem_bytes / mib:.1f} MiB, "
            f"prologue={self.prologue_vmem_bytes / mib:.1f} MiB",
        ]
        if act_group:
            lines.append(
                f"  act_group={act_group}: bk snaps to a multiple of "
                f"{act_group} (K-chunks hold whole scale groups, floor "
                f"bk={act_group}); the (M, K/{act_group}) f32 scale plane "
                f"joins the working set; a path demotes when no such "
                f"tiling fits its budget")
        override = self.layer_plan(layer, k, n, r)
        if override:
            lines.append(f"  layer override: {override} "
                         f"(override > table > defaults)")
        for regime in ("decode", "mixed", "prefill"):
            entry = self.table_entry(regime)
            table_plan = self.select_plan(m, k, n, r, regime=regime,
                                          layer=layer)
            plan = self.resolve_plan(m, k, n, r, rotate=rotate,
                                     regime=regime, layer=layer,
                                     act_group=act_group)
            if plan.path == "fused":
                need = fused_vmem_bytes(k, r, plan.bm, plan.bn, plan.bk,
                                        plan.br, plan.variant != "streamed",
                                        act_group=act_group)
                budget = self.fused_vmem_bytes
            elif plan.path == "chained":
                need = prologue_vmem_bytes(k, r, plan.bm, plan.bk, plan.br,
                                           rotate, act_group=act_group)
                budget = self.prologue_vmem_bytes
            else:
                need = budget = None
            star = "*" if regime == active else " "
            table_s = (f"{entry['path']} bm={entry['bm']} bn={entry['bn']} "
                       f"bk={entry['bk']}"
                       + (f" br={entry['br']}" if "br" in entry else ""))
            plan_s = (f"{plan.path} bm={plan.bm} bn={plan.bn} bk={plan.bk} "
                      f"br={plan.br}"
                      + (f" variant={plan.variant}" if plan.variant else ""))
            notes = []
            if act_group:
                snapped = snap_bk_to_group(table_plan.bk, act_group)
                if snapped != table_plan.bk:
                    notes.append(f"bk {table_plan.bk}->{snapped} "
                                 f"(multiple of g={act_group})")
                if plan.path != table_plan.path:
                    notes.append(f"demoted {table_plan.path}->{plan.path}: "
                                 f"no multiple-of-{act_group} bk tiling "
                                 f"fits the {table_plan.path} budget")
            note_s = f"  ({'; '.join(notes)})" if notes else ""
            if need is None:
                fit_s = "vmem n/a (jnp fallback path)"
            else:
                fit_s = (f"vmem {need / mib:.2f}/{budget / mib:.2f} MiB "
                         f"({'fits' if need <= budget else 'OVER'})")
            lines.append(f" {star}[{regime:7s}] table: {table_s}  ->  "
                         f"resolved: {plan_s}  [{fit_s}]{note_s}")
        return "\n".join(lines)
