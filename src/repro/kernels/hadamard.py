"""Blocked Walsh-Hadamard transform kernel (QuaRot online rotation, R3/R4).

Applies the normalized WHT over the last (power-of-two) axis of a row tile
held in VMEM: log2(D) butterfly sweeps, no HBM round-trips between stages.
Odd Kronecker factors (d = m·2^k) are applied by the wrapper as a small dense
matmul (repro.core.hadamard semantics).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.rowops import fwht_rows


def _kernel(x_ref, o_ref, *, d: int):
    y = fwht_rows(x_ref[...].astype(jnp.float32), d)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def fwht_kernel(x: jnp.ndarray, bm: int = 256, interpret: bool = True):
    """x: (M, D) with D a power of two; returns x @ H_D (normalized)."""
    m, d = x.shape
    assert d & (d - 1) == 0, d
    assert m % bm == 0, (m, bm)
    return pl.pallas_call(
        functools.partial(_kernel, d=d),
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",),  # M tiles are independent
        ),
        interpret=interpret,
    )(x)
