"""Pallas TPU kernels for the paper's compute hot-spots.

The paper's Limitations section (§5) measures that the *unfused* low-rank
matmul costs 23-52% extra latency even at rank 128 ("data movement is
important, and ... a fused kernel could improve latency") and speculates the
low-rank path "may be computable in parallel with the low-bitwidth
computation".  The serving hot path is now ONE pallas kernel end to end
(`ops.w4a4_lrc_forward`, fused_gemm.py): the grid covers (M-tile, N-tile)
with the K reduction loop inside; the activation prologue (blocked
Walsh-Hadamard rotation, per-token amax/scale + int4-grid quantization, and
the (x·V) low-rank projection) runs on each M-tile's first N visit and
deposits xq/sx/xv into VMEM scratch, from which the int8×int8→int32 MXU GEMM
and the (xV)Uᵀ low-rank epilogue feed directly — the quantized activations
never touch HBM.  Two graceful-degradation paths remain behind the same
entry point:

  chained — prologue.py → w4a4.py, TWO kernels: the prologue emits xq/sx/xv
     in one HBM pass over x, the GEMM+epilogue kernel consumes them (one
     M×K xq round-trip between the two).  Used when the fused working set
     exceeds VMEM, and by default at prefill M where the GEMM is MXU-bound.
  unfused — three activation passes (hadamard.py, actquant.py, per-tile
     projection) + the GEMM kernel.  Used when V alone is past the prologue
     VMEM budget (`ops._PROLOGUE_V_BYTES_MAX`).

Execution plans (kernel path + block sizes) come from a small autotune table
keyed on the (M, K, N, R) serving regime — decode / mixed / prefill
(`ops.select_plan`); measured winners from benchmarks/autotune_blocks.py can
overlay it via `ops.load_block_table(results/block_table.json)`.  All GEMM
operands are zero-padded to block multiples so odd MLP widths take the
pallas path; grids carry Mosaic ``dimension_semantics`` annotations.  All
three paths are bitwise identical in interpret mode: they share the row-tile
bodies in rowops.py and integer accumulation is exact under any K split.

  fused_gemm.py — single-kernel W4A4+LRC forward (prologue + GEMM + epilogue)
  prologue.py — fused rotate → quantize → low-rank-project prologue
  w4a4.py     — fused W4A4 matmul + low-rank epilogue (pl.pallas_call)
  actquant.py — standalone per-token int4/int8 activation quantizer
  hadamard.py — standalone blocked Walsh-Hadamard transform (QuaRot R3/R4)
  rowops.py   — shared row-tile bodies (butterfly, quantize, prologue, unpack)
  ops.py      — jit'd wrappers (padding, plan table, path dispatch)
  ref.py      — pure-jnp oracles for every kernel
"""

from repro.kernels import ops, ref
