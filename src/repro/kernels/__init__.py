"""Pallas TPU kernels for the paper's compute hot-spots.

The paper's Limitations section (§5) measures that the *unfused* low-rank
matmul costs 23-52% extra latency even at rank 128 ("data movement is
important, and ... a fused kernel could improve latency") and speculates the
low-rank path "may be computable in parallel with the low-bitwidth
computation".  The serving hot path is now TWO fused kernels end to end
(`ops.w4a4_lrc_forward`):

  1. prologue.py — fused activation prologue: ONE grid pass over row tiles
     of x held in VMEM applies the blocked Walsh-Hadamard rotation, the
     per-token amax/scale + int4-grid quantization, and the (x·V) low-rank
     projection, emitting xq/sx/xv from a single HBM read of the activations
     (the unfused chain made three passes plus a rotated-x round-trip);
  2. w4a4.py — fused W4A4 GEMM + low-rank epilogue: packed-int4 weights are
     unpacked in VMEM, the int8×int8→int32 MXU GEMM accumulates over K tiles,
     and the epilogue applies the per-token/per-channel rescale AND the
     (xV)Uᵀ term while the output tile is still in VMEM.

Block sizes come from a small autotune table keyed on the (M, K, N, R)
serving regime — decode / mixed / prefill (`ops.select_blocks`); all GEMM
operands are zero-padded to block multiples so odd MLP widths take the
pallas path; grids carry Mosaic ``dimension_semantics`` annotations
(parallel M/N, sequential-innermost K).

  prologue.py — fused rotate → quantize → low-rank-project prologue
  w4a4.py     — fused W4A4 matmul + low-rank epilogue (pl.pallas_call)
  actquant.py — standalone per-token int4/int8 activation quantizer
  hadamard.py — standalone blocked Walsh-Hadamard transform (QuaRot R3/R4)
  ops.py      — jit'd wrappers (padding, block table, interpret fallback)
  ref.py      — pure-jnp oracles for every kernel
"""

from repro.kernels import ops, ref
