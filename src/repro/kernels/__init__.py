"""Pallas TPU kernels for the paper's compute hot-spots.

The paper's Limitations section (§5) measures that the *unfused* low-rank
matmul costs 23-52% extra latency even at rank 128 ("data movement is
important, and ... a fused kernel could improve latency") and speculates the
low-rank path "may be computable in parallel with the low-bitwidth
computation".  `w4a4.py` is exactly that kernel, adapted to the TPU memory
hierarchy: packed-int4 weights are unpacked in VMEM, the int8×int8→int32 MXU
GEMM accumulates over K tiles, and the epilogue applies the per-token/
per-channel rescale AND the (xV)Uᵀ low-rank term while the tile is still in
VMEM — one HBM pass instead of two.

  w4a4.py     — fused W4A4 matmul + low-rank epilogue (pl.pallas_call)
  actquant.py — per-token int4/int8 on-the-fly activation quantizer
  hadamard.py — blocked Walsh-Hadamard transform (QuaRot online rotation)
  ops.py      — jit'd wrappers (padding, interpret-mode fallback on CPU)
  ref.py      — pure-jnp oracles for every kernel
"""

from repro.kernels import ops, ref
