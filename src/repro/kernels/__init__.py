"""Pallas TPU kernels for the paper's compute hot-spots.

The paper's Limitations section (§5) measures that the *unfused* low-rank
matmul costs 23-52% extra latency even at rank 128 ("data movement is
important, and ... a fused kernel could improve latency") and speculates the
low-rank path "may be computable in parallel with the low-bitwidth
computation".  The serving hot path is now ONE pallas kernel end to end
(`ops.w4a4_lrc_forward`, fused_gemm.py) in EVERY regime: the K-split grid
covers (M-tile, N-visit, K-chunk, R-tile); the activation prologue (blocked
Walsh-Hadamard rotation, per-token amax/scale + int4-grid quantization, and
the K-chunked/R-tiled (x·V) low-rank projection) sweeps the K-chunks on
each M-tile's first N visit, the int8×int8→int32 MXU GEMM partial-sums over
the same chunks, and V/W stream per chunk — no operand slab is whole in
VMEM and the quantized activations never touch HBM.  Per-slab VMEM
feasibility (`ops.resolve_plan`) shrinks tiles to fit the budget before the
path ever demotes; two graceful-degradation paths remain behind the same
entry point:

  chained — prologue.py → w4a4.py, TWO kernels: the prologue emits xq/sx/xv
     in one HBM pass over x (V streamed in (bk, br) tiles), the
     GEMM+epilogue kernel consumes them (one M×K xq round-trip between the
     two).  Used when no fused tiling fits the VMEM budget.
  unfused — three activation passes (hadamard.py, actquant.py, tiled
     projection) + the GEMM kernel.  Final fallback when even the prologue
     kernel's row slab cannot fit (`ops.prologue_vmem_budget`).

Execution plans (kernel path + BM/BN/BK/BR tiles) come from a small
autotune table keyed on the (M, K, N, R) serving regime — decode / mixed /
prefill — held in an immutable `context.KernelContext` (block table, VMEM
budgets, default impl, interpret flag, per-layer plan overrides) threaded
through every entry point as `ctx=`; measured winners from
benchmarks/autotune_blocks.py load via
`KernelContext.from_json(results/block_table.json)`, which may also carry
VMEM-budget overrides (a "vmem" entry) and per-layer plan overrides (a
"layers" entry).  Inspect resolution with `ctx.explain(m, k, n, r)`.  (The
old global setters `ops.load_block_table` / `ops.set_vmem_budgets` finished
their deprecation window and were removed.)  All GEMM operands are
zero-padded to block multiples so odd MLP widths take the pallas path;
grids carry Mosaic ``dimension_semantics`` annotations.  All three paths
are bitwise identical in interpret mode: they share the row-tile bodies in
rowops.py (including the canonical chunked projection-accumulation order)
and integer accumulation is exact under any K split.  Activation-scale
granularity is a first-class plan axis: per-token (M, 1) scales or — with
``act_group`` (paper Table 2, g = 128) — a per-group (M, K/g) scale plane,
with BK snapped to a multiple of g so K-chunks hold whole scale groups and
the GEMM dequant moving into the K loop.

  fused_gemm.py — single-kernel W4A4+LRC forward (prologue + GEMM + epilogue)
  prologue.py — fused rotate → quantize → low-rank-project prologue
  w4a4.py     — fused W4A4 matmul + low-rank epilogue (pl.pallas_call)
  actquant.py — standalone per-token int4/int8 activation quantizer
  hadamard.py — standalone blocked Walsh-Hadamard transform (QuaRot R3/R4)
  rowops.py   — shared row-tile bodies (butterfly, quantize, prologue, unpack)
  context.py  — KernelContext: immutable execution config (plan table, VMEM
                budgets, per-layer overrides) + plan resolution/explain
  ops.py      — jit'd wrappers (padding, ctx-based dispatch)
  ref.py      — pure-jnp oracles for every kernel
"""

from repro.kernels import context, ops, ref
from repro.kernels.context import KernelContext, Plan
