"""Flash-attention forward kernel (blocked online-softmax, Pallas).

The dry-run shows prefill cells are memory-bound on the materialized
(B,H,S,S) logits (§Roofline) — e.g. gemma-7b prefill_32k moves TBs of
attention scores through HBM.  This kernel keeps each (BQ × BKV) score tile
in VMEM with running (m, l, acc) statistics, so attention bytes drop from
O(S²) to O(S·D) — the classic flash-attention restructuring, here as the
TPU-native companion of the W4A4 serving path.

Layout: q (B*H, S, D), k/v (B*H, S, D) — the wrapper folds batch/head dims
and un-groups GQA.  Causal masking is computed arithmetically per tile (no
mask tensor in HBM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool,
            bq: int, bkv: int, skv: int):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale  # (BQ, D)
    d = q.shape[-1]
    m = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((bq, 1), jnp.float32)
    acc = jnp.zeros((bq, v_ref.shape[-1]), jnp.float32)  # (BQ, Dv)

    n_kv = skv // bkv

    def body(j, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], j * bkv, bkv, axis=0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], j * bkv, bkv, axis=0)
        s = q @ k.astype(jnp.float32).T  # (BQ, BKV)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            kpos = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + p @ v.astype(jnp.float32)
        return m_new, l, acc

    m, l, acc = jax.lax.fori_loop(0, n_kv, body, (m, l, acc))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, *,
                  scale: float, page_size: int, n_pages: int):
    """One (sequence, kv-head) program of paged decode attention.

    The KV gather happens HERE, per page id from the block table — scores
    stream page-by-page through the online-softmax statistics, so a
    sequence's KV never needs to be contiguous (or even materialized
    gathered) in HBM.  Positions at and past ``length`` are masked to
    NEG_INF, which is what makes the result invariant to whatever garbage
    the unowned / null pages hold."""
    q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, D) grouped queries
    bt = bt_ref[0]  # (MPB,) page ids, null page 0 past the owned prefix
    length = len_ref[0]  # valid kv positions, incl. the current token
    kpool = k_ref[0]  # (NP, P, D) this kv-head's slice of the pool
    vpool = v_ref[0]
    g = q.shape[0]
    m = jnp.full((g, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((g, 1), jnp.float32)
    acc = jnp.zeros((g, vpool.shape[-1]), jnp.float32)

    def body(j, carry):
        m, l, acc = carry
        pid = bt[j]
        k = jax.lax.dynamic_index_in_dim(kpool, pid, 0, keepdims=False)
        v = jax.lax.dynamic_index_in_dim(vpool, pid, 0, keepdims=False)
        s = q @ k.astype(jnp.float32).T  # (G, P)
        kpos = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (g, page_size), 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + p @ v.astype(jnp.float32)
        return m_new, l, acc

    m, l, acc = jax.lax.fori_loop(0, n_pages, body, (m, l, acc))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_flash_attention_kernel(
    q: jnp.ndarray,            # (B, H, D) one decode token per sequence
    k_pages: jnp.ndarray,      # (NP, P, KH, D) shared page pool
    v_pages: jnp.ndarray,      # (NP, P, KH, Dv)
    block_table: jnp.ndarray,  # (B, MPB) int32 page ids (0 = null page)
    lengths: jnp.ndarray,      # (B,) int32 valid kv count, incl. current token
    scale: float,
    interpret: bool = True,
):
    """Decode attention against a PAGED KV pool (the serving engine's cache
    layout): each sequence reads its pages through its block-table row, so
    no per-request contiguous KV copy is ever materialized.  GQA is handled
    natively — grid is (B, KH) and each program computes all H/KH query
    heads of its group against one gathered page stream.  Returns (B, H, Dv).
    """
    b, h, d = q.shape
    n_pages_total, page_size, kh, dv = v_pages.shape
    g = h // kh
    mpb = block_table.shape[1]
    qg = q.reshape(b, kh, g, d)  # heads grouped by kv head
    kp = k_pages.transpose(2, 0, 1, 3)  # (KH, NP, P, D)
    vp = v_pages.transpose(2, 0, 1, 3)
    out = pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale, page_size=page_size,
                          n_pages=mpb),
        grid=(b, kh),
        in_specs=[
            pl.BlockSpec((1, mpb), lambda i, j: (i, 0)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1, 1, g, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, n_pages_total, page_size, d), lambda i, j: (j, 0, 0, 0)),
            pl.BlockSpec((1, n_pages_total, page_size, dv), lambda i, j: (j, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, dv), q.dtype),
        interpret=interpret,
    )(jnp.asarray(block_table, jnp.int32), jnp.asarray(lengths, jnp.int32),
      qg, kp, vp)
    return out.reshape(b, h, dv)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "bq", "bkv", "interpret"))
def flash_attention_kernel(
    q: jnp.ndarray,  # (BH, Sq, D)
    k: jnp.ndarray,  # (BH, Skv, D)
    v: jnp.ndarray,  # (BH, Skv, Dv)
    scale: float,
    causal: bool = True,
    bq: int = 128,
    bkv: int = 128,
    interpret: bool = True,
):
    bh, sq, d = q.shape
    skv = k.shape[1]
    assert sq % bq == 0 and skv % bkv == 0, (sq, skv, bq, bkv)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, bq=bq, bkv=bkv, skv=skv),
        grid=(bh, sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, skv, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, skv, v.shape[-1]), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, v.shape[-1]), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, v.shape[-1]), q.dtype),
        interpret=interpret,
    )(q, k, v)
