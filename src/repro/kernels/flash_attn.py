"""Flash-attention forward kernel (blocked online-softmax, Pallas).

The dry-run shows prefill cells are memory-bound on the materialized
(B,H,S,S) logits (§Roofline) — e.g. gemma-7b prefill_32k moves TBs of
attention scores through HBM.  This kernel keeps each (BQ × BKV) score tile
in VMEM with running (m, l, acc) statistics, so attention bytes drop from
O(S²) to O(S·D) — the classic flash-attention restructuring, here as the
TPU-native companion of the W4A4 serving path.

Layout: q (B*H, S, D), k/v (B*H, S, D) — the wrapper folds batch/head dims
and un-groups GQA.  Causal masking is computed arithmetically per tile (no
mask tensor in HBM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantizers import unpack_int4
from repro.kernels.rowops import dequant_rows_grouped

NEG_INF = -1e30


def _dequant_tile(qrows: jnp.ndarray, srows: jnp.ndarray, *, group: int,
                  packed: bool) -> jnp.ndarray:
    """Dequantize one KV tile inside the online-softmax loop: (rows,
    d_packed) int + (rows, d // group) f32 scales → (rows, d) f32, via
    the canonical ``rowops.dequant_rows_grouped`` spelling (+ the
    ``pack_int4`` nibble unpack for int4 pools).  The scale plane rides
    the loop exactly like ``gemm_chunk_grouped`` carries the activation
    scale plane through the GEMM's K loop — quantized KV never
    round-trips HBM at full width."""
    if packed:
        qrows = unpack_int4(qrows)
    return dequant_rows_grouped(qrows, srows, group)


def _kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool,
            bq: int, bkv: int, skv: int):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale  # (BQ, D)
    d = q.shape[-1]
    m = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((bq, 1), jnp.float32)
    acc = jnp.zeros((bq, v_ref.shape[-1]), jnp.float32)  # (BQ, Dv)

    n_kv = skv // bkv

    def body(j, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], j * bkv, bkv, axis=0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], j * bkv, bkv, axis=0)
        s = q @ k.astype(jnp.float32).T  # (BQ, BKV)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            kpos = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + p @ v.astype(jnp.float32)
        return m_new, l, acc

    m, l, acc = jax.lax.fori_loop(0, n_kv, body, (m, l, acc))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, *,
                  scale: float, page_size: int, n_pages: int):
    """One (sequence, kv-head) program of paged decode attention.

    The KV gather happens HERE, per page id from the block table — scores
    stream page-by-page through the online-softmax statistics, so a
    sequence's KV never needs to be contiguous (or even materialized
    gathered) in HBM.  Positions at and past ``length`` are masked to
    NEG_INF, which is what makes the result invariant to whatever garbage
    the unowned / null pages hold."""
    q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, D) grouped queries
    bt = bt_ref[0]  # (MPB,) page ids, null page 0 past the owned prefix
    length = len_ref[0]  # valid kv positions, incl. the current token
    kpool = k_ref[0]  # (NP, P, D) this kv-head's slice of the pool
    vpool = v_ref[0]
    g = q.shape[0]
    m = jnp.full((g, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((g, 1), jnp.float32)
    acc = jnp.zeros((g, vpool.shape[-1]), jnp.float32)

    def body(j, carry):
        m, l, acc = carry
        pid = bt[j]
        k = jax.lax.dynamic_index_in_dim(kpool, pid, 0, keepdims=False)
        v = jax.lax.dynamic_index_in_dim(vpool, pid, 0, keepdims=False)
        s = q @ k.astype(jnp.float32).T  # (G, P)
        kpos = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (g, page_size), 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + p @ v.astype(jnp.float32)
        return m_new, l, acc

    m, l, acc = jax.lax.fori_loop(0, n_pages, body, (m, l, acc))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def _kernel_quant(q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref, *,
                  scale: float, causal: bool, bq: int, bkv: int, skv: int,
                  group: int, packed: bool):
    """The dense kernel body with quantized K/V: each (BKV, d_packed) tile
    and its scale rows dequantize in-loop (``_dequant_tile``) right before
    the score/accumulate dots — the rest is byte-for-byte the f32 body."""
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale  # (BQ, D)
    d = q.shape[-1]
    m = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((bq, 1), jnp.float32)
    acc = jnp.zeros((bq, d), jnp.float32)

    n_kv = skv // bkv

    def body(j, carry):
        m, l, acc = carry
        kq = jax.lax.dynamic_slice_in_dim(k_ref[0], j * bkv, bkv, axis=0)
        ks = jax.lax.dynamic_slice_in_dim(ks_ref[0], j * bkv, bkv, axis=0)
        vq = jax.lax.dynamic_slice_in_dim(v_ref[0], j * bkv, bkv, axis=0)
        vs = jax.lax.dynamic_slice_in_dim(vs_ref[0], j * bkv, bkv, axis=0)
        k = _dequant_tile(kq, ks, group=group, packed=packed)
        v = _dequant_tile(vq, vs, group=group, packed=packed)
        s = q @ k.T  # (BQ, BKV)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            kpos = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + p @ v
        return m_new, l, acc

    m, l, acc = jax.lax.fori_loop(0, n_kv, body, (m, l, acc))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def _paged_kernel_quant(bt_ref, len_ref, q_ref, k_ref, ks_ref, v_ref,
                        vs_ref, o_ref, *, scale: float, page_size: int,
                        n_pages: int, group: int, packed: bool):
    """The paged gather body with quantized pages: each gathered page's
    data rows AND scale rows index through the same block-table entry, and
    the page dequantizes in-loop before the score/accumulate dots — f32 KV
    never round-trips HBM.  Masking is unchanged (dtype-independent), so
    garbage in unowned/null pages still contributes exactly 0."""
    q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, D)
    bt = bt_ref[0]
    length = len_ref[0]
    kpool = k_ref[0]    # (NP, P, d_packed)
    kspool = ks_ref[0]  # (NP, P, n_groups)
    vpool = v_ref[0]
    vspool = vs_ref[0]
    g = q.shape[0]
    d = q.shape[-1]
    m = jnp.full((g, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((g, 1), jnp.float32)
    acc = jnp.zeros((g, d), jnp.float32)

    def body(j, carry):
        m, l, acc = carry
        pid = bt[j]
        kq = jax.lax.dynamic_index_in_dim(kpool, pid, 0, keepdims=False)
        ks = jax.lax.dynamic_index_in_dim(kspool, pid, 0, keepdims=False)
        vq = jax.lax.dynamic_index_in_dim(vpool, pid, 0, keepdims=False)
        vs = jax.lax.dynamic_index_in_dim(vspool, pid, 0, keepdims=False)
        k = _dequant_tile(kq, ks, group=group, packed=packed)  # (P, D)
        v = _dequant_tile(vq, vs, group=group, packed=packed)
        s = q @ k.T  # (G, P)
        kpos = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (g, page_size), 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + p @ v
        return m_new, l, acc

    m, l, acc = jax.lax.fori_loop(0, n_pages, body, (m, l, acc))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_flash_attention_kernel(
    q: jnp.ndarray,            # (B, H, D) one decode token per sequence
    k_pages: jnp.ndarray,      # (NP, P, KH, D) shared page pool
    v_pages: jnp.ndarray,      # (NP, P, KH, Dv)
    block_table: jnp.ndarray,  # (B, MPB) int32 page ids (0 = null page)
    lengths: jnp.ndarray,      # (B,) int32 valid kv count, incl. current token
    scale: float,
    interpret: bool = True,
):
    """Decode attention against a PAGED KV pool (the serving engine's cache
    layout): each sequence reads its pages through its block-table row, so
    no per-request contiguous KV copy is ever materialized.  GQA is handled
    natively — grid is (B, KH) and each program computes all H/KH query
    heads of its group against one gathered page stream.  Returns (B, H, Dv).
    """
    b, h, d = q.shape
    n_pages_total, page_size, kh, dv = v_pages.shape
    g = h // kh
    mpb = block_table.shape[1]
    qg = q.reshape(b, kh, g, d)  # heads grouped by kv head
    kp = k_pages.transpose(2, 0, 1, 3)  # (KH, NP, P, D)
    vp = v_pages.transpose(2, 0, 1, 3)
    out = pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale, page_size=page_size,
                          n_pages=mpb),
        grid=(b, kh),
        in_specs=[
            pl.BlockSpec((1, mpb), lambda i, j: (i, 0)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1, 1, g, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, n_pages_total, page_size, d), lambda i, j: (j, 0, 0, 0)),
            pl.BlockSpec((1, n_pages_total, page_size, dv), lambda i, j: (j, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, dv), q.dtype),
        interpret=interpret,
    )(jnp.asarray(block_table, jnp.int32), jnp.asarray(lengths, jnp.int32),
      qg, kp, vp)
    return out.reshape(b, h, dv)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "bq", "bkv", "interpret"))
def flash_attention_kernel(
    q: jnp.ndarray,  # (BH, Sq, D)
    k: jnp.ndarray,  # (BH, Skv, D)
    v: jnp.ndarray,  # (BH, Skv, Dv)
    scale: float,
    causal: bool = True,
    bq: int = 128,
    bkv: int = 128,
    interpret: bool = True,
):
    bh, sq, d = q.shape
    skv = k.shape[1]
    assert sq % bq == 0 and skv % bkv == 0, (sq, skv, bq, bkv)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, bq=bq, bkv=bkv, skv=skv),
        grid=(bh, sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, skv, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, skv, v.shape[-1]), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, v.shape[-1]), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, v.shape[-1]), q.dtype),
        interpret=interpret,
    )(q, k, v)


@functools.partial(jax.jit, static_argnames=(
    "scale", "group", "packed", "causal", "bq", "bkv", "interpret"))
def flash_attention_quant_kernel(
    q: jnp.ndarray,         # (BH, Sq, D)
    k_quant: jnp.ndarray,   # (BH, Skv, D | D//2) int8 / packed uint8
    k_scales: jnp.ndarray,  # (BH, Skv, D // group) f32
    v_quant: jnp.ndarray,
    v_scales: jnp.ndarray,
    scale: float,
    group: int,
    packed: bool,
    causal: bool = True,
    bq: int = 128,
    bkv: int = 128,
    interpret: bool = True,
):
    """``flash_attention_kernel`` over quantized K/V: the scale planes ride
    as two extra inputs blocked exactly like their data tensors, and each
    tile dequantizes in VMEM — the f32 KV stream never touches HBM."""
    bh, sq, d = q.shape
    skv = k_quant.shape[1]
    dp = k_quant.shape[-1]
    n_g = k_scales.shape[-1]
    assert sq % bq == 0 and skv % bkv == 0, (sq, skv, bq, bkv)
    return pl.pallas_call(
        functools.partial(_kernel_quant, scale=scale, causal=causal, bq=bq,
                          bkv=bkv, skv=skv, group=group, packed=packed),
        grid=(bh, sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, skv, dp), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, skv, n_g), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, skv, dp), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, skv, n_g), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        interpret=interpret,
    )(q, k_quant, k_scales, v_quant, v_scales)


@functools.partial(jax.jit, static_argnames=(
    "scale", "group", "packed", "interpret"))
def paged_flash_attention_quant_kernel(
    q: jnp.ndarray,            # (B, H, D) one decode token per sequence
    k_pages: jnp.ndarray,      # (NP, P, KH, D | D//2) int8 / packed uint8
    k_scales: jnp.ndarray,     # (NP, P, KH, D // group) f32 scale planes
    v_pages: jnp.ndarray,
    v_scales: jnp.ndarray,
    block_table: jnp.ndarray,  # (B, MPB) int32 page ids (0 = null page)
    lengths: jnp.ndarray,      # (B,) int32 valid kv count, incl. current token
    scale: float,
    group: int,
    packed: bool,
    interpret: bool = True,
):
    """``paged_flash_attention_kernel`` over a QUANTIZED page pool: the
    scale-plane sidecar pools ride as two extra inputs under the same
    block-table indexing, and each gathered page dequantizes in VMEM.
    Returns (B, H, D)."""
    b, h, d = q.shape
    n_pages_total, page_size, kh, dp = k_pages.shape
    n_g = k_scales.shape[-1]
    g = h // kh
    mpb = block_table.shape[1]
    qg = q.reshape(b, kh, g, d)  # heads grouped by kv head
    kp = k_pages.transpose(2, 0, 1, 3)   # (KH, NP, P, dp)
    ksp = k_scales.transpose(2, 0, 1, 3)  # (KH, NP, P, n_g)
    vp = v_pages.transpose(2, 0, 1, 3)
    vsp = v_scales.transpose(2, 0, 1, 3)
    out = pl.pallas_call(
        functools.partial(_paged_kernel_quant, scale=scale,
                          page_size=page_size, n_pages=mpb, group=group,
                          packed=packed),
        grid=(b, kh),
        in_specs=[
            pl.BlockSpec((1, mpb), lambda i, j: (i, 0)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1, 1, g, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, n_pages_total, page_size, dp), lambda i, j: (j, 0, 0, 0)),
            pl.BlockSpec((1, n_pages_total, page_size, n_g), lambda i, j: (j, 0, 0, 0)),
            pl.BlockSpec((1, n_pages_total, page_size, dp), lambda i, j: (j, 0, 0, 0)),
            pl.BlockSpec((1, n_pages_total, page_size, n_g), lambda i, j: (j, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, d), q.dtype),
        interpret=interpret,
    )(jnp.asarray(block_table, jnp.int32), jnp.asarray(lengths, jnp.int32),
      qg, kp, ksp, vp, vsp)
    return out.reshape(b, h, d)
