"""On-the-fly activation quantizer kernel (paper §2: scale-then-round by
c·max|x|).  One pass over the activations in VMEM produces the int grid
values and the scales — this is the "fast (simple!) scheme" the paper
requires for online quantization.  ``group`` switches the (M, 1) per-token
scale for the (M, K // group) per-group scale plane (paper Table 2,
g = 128); the group bodies live in rowops.py and are shared with the
prologue and fused kernels, so all paths quantize bitwise identically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.rowops import scale_round_quantize


def _kernel(x_ref, q_ref, s_ref, *, qmax: int, clip_ratio: float, group):
    x = x_ref[...].astype(jnp.float32)
    q, s = scale_round_quantize(x, qmax, clip_ratio, group=group)
    q_ref[...] = q
    s_ref[...] = s


@functools.partial(jax.jit, static_argnames=("bits", "clip_ratio", "bm",
                                             "group", "interpret"))
def act_quant_kernel(
    x: jnp.ndarray,  # (M, K)
    bits: int = 4,
    clip_ratio: float = 1.0,
    bm: int = 128,
    group: int = None,  # None = per-token; else scales per K group
    interpret: bool = True,
):
    m, k = x.shape
    assert m % bm == 0, (m, bm)
    if group is not None:
        assert k % group == 0, (k, group)
    n_s = 1 if group is None else k // group
    qmax = 2 ** (bits - 1) - 1
    q, s = pl.pallas_call(
        functools.partial(_kernel, qmax=qmax, clip_ratio=clip_ratio,
                          group=group),
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, n_s), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.int8),
            jax.ShapeDtypeStruct((m, n_s), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",),  # M tiles are independent
        ),
        interpret=interpret,
    )(x)
    return q, s
