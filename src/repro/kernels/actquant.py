"""On-the-fly activation quantizer kernel (paper §2: scale-then-round by
c·max|x|).  One pass over the activations in VMEM produces the int grid
values and the per-token scales — this is the "fast (simple!) scheme" the
paper requires for online quantization.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, q_ref, s_ref, *, qmax: int, clip_ratio: float):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    amax = jnp.where(amax <= 0.0, 1.0, amax)
    s = clip_ratio * amax / qmax
    q = jnp.clip(jnp.round(x / s), -qmax - 1, qmax)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = s


@functools.partial(jax.jit, static_argnames=("bits", "clip_ratio", "bm", "interpret"))
def act_quant_kernel(
    x: jnp.ndarray,  # (M, K)
    bits: int = 4,
    clip_ratio: float = 1.0,
    bm: int = 128,
    interpret: bool = True,
):
    m, k = x.shape
    assert m % bm == 0, (m, bm)
    qmax = 2 ** (bits - 1) - 1
    q, s = pl.pallas_call(
        functools.partial(_kernel, qmax=qmax, clip_ratio=clip_ratio),
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.int8),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, s
