"""Pure-jnp oracles for every Pallas kernel (bit-faithful semantics)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.quantizers import unpack_int4


def w4a4_lowrank_matmul_ref(xq, sx, wpacked, sw, xv=None, u=None):
    """Same math as kernels.w4a4 — int8 GEMM, rescale, optional LR term."""
    wq = unpack_int4(wpacked.T).T  # (K, N) int8, even/odd interleave along K
    acc = jnp.dot(
        xq.astype(jnp.int32), wq.astype(jnp.int32)
    )  # exact integer accumulation
    out = acc.astype(jnp.float32) * sx * sw
    if xv is not None:
        out = out + xv.astype(jnp.float32) @ u.astype(jnp.float32).T
    return out


def act_quant_ref(x, bits: int = 4, clip_ratio: float = 1.0):
    qmax = 2 ** (bits - 1) - 1
    x = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    amax = jnp.where(amax <= 0.0, 1.0, amax)
    s = clip_ratio * amax / qmax
    q = jnp.clip(jnp.round(x / s), -qmax - 1, qmax).astype(jnp.int8)
    return q, s


def fwht_ref(x):
    from repro.core.hadamard import fwht

    return fwht(x.astype(jnp.float32)).astype(x.dtype)


def fused_prologue_ref(x, v=None, bits: int = 4, clip_ratio: float = 1.0,
                       rotate: bool = False):
    """Three-pass reference for the fused activation prologue: WHT rotation,
    per-token quantization, and the (x·V) projection, run back-to-back."""
    x = x.astype(jnp.float32)
    if rotate:
        x = fwht_ref(x)
    q, s = act_quant_ref(x, bits=bits, clip_ratio=clip_ratio)
    xv = None if v is None else x @ v.astype(jnp.float32)
    return q, s, xv


def w4a4_lrc_forward_ref(x, wpacked, w_scale, u=None, v=None, bits: int = 4,
                         clip_ratio: float = 1.0, rotate: bool = False):
    """End-to-end oracle for ops.w4a4_lrc_forward: prologue reference chained
    into the GEMM reference — same math as all three kernel paths."""
    xq, sx, xv = fused_prologue_ref(x, v, bits=bits, clip_ratio=clip_ratio,
                                    rotate=rotate)
    return w4a4_lowrank_matmul_ref(xq, sx, wpacked, w_scale.reshape(1, -1),
                                   xv, u)


def flash_attention_ref(q, k, v, scale: float, causal: bool = True):
    """q/k/v: (BH, S, D) — standard softmax attention."""
    s_ = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        qi = jnp.arange(sq)[:, None]
        kj = jnp.arange(sk)[None, :]
        s_ = jnp.where((kj <= qi)[None], s_, -1e30)
    import jax
    p_ = jax.nn.softmax(s_, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p_, v.astype(jnp.float32)).astype(q.dtype)
