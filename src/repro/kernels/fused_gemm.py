"""Single-kernel fused W4A4+LRC forward: prologue + GEMM in ONE pallas call.

PR 1 collapsed rotate → quantize → low-rank-project into one prologue kernel,
but the serving path still chained TWO kernels (prologue → GEMM), so the
quantized activations ``xq`` (and ``sx``/``xv``) made a full M×K HBM
write+read between them.  This kernel closes that gap: the grid covers
(M-tile, N-tile) with the K reduction loop INSIDE the kernel body, and the
activation prologue runs on each M-tile's FIRST visit (N-tile index 0),
depositing ``xq``/``sx``/``xv`` into VMEM scratch that persists across the
M-tile's remaining N-tile visits.  The int4 GEMM and the low-rank epilogue
feed straight from that residency — ``xq`` never touches HBM.

Per grid step (i, j):

  j == 0   : x row tile (bm, K) → rotate → quantize → project
             (kernels/rowops.prologue_rows — the SAME body the two-kernel
             chain runs, so outputs are bitwise identical) → VMEM scratch
  every j  : K-loop over bk chunks of the scratch-resident xq against the
             (K//2, bn) packed-weight slab; int8×int8→int32 accumulation
  epilogue : acc · sx · sw (+ xv Uᵀ) while the output tile is in VMEM

The x row slab, V (whole), and the per-N-tile weight slab must fit VMEM —
the ops-layer wrapper checks the footprint and falls back to the two-kernel
chain (decode/mixed fit comfortably; prefill M-tiles default to the chain,
where the GEMM is MXU-bound anyway and fusion buys bytes, not latency).

K is consumed UNPADDED by the prologue (the rotation/amax must not see pad
columns); xq is zero-padded to the bk multiple on its way into scratch, so
the integer accumulation over padded chunks is exact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.rowops import prologue_rows, unpack_int4_rows


def _body(x_ref, v_ref, wp_ref, sw_ref, u_ref, out_ref, xq_s, sx_s, xv_s, *,
          qmax: int, clip_ratio: float, rotate: bool,
          k: int, k_pad: int, bk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _prologue():
        q, s, xv = prologue_rows(x_ref[...].astype(jnp.float32),
                                 None if v_ref is None else v_ref[...],
                                 qmax, clip_ratio, rotate, k)
        if k_pad > k:
            q = jnp.pad(q, ((0, 0), (0, k_pad - k)))
        xq_s[...] = q
        sx_s[...] = s
        if xv_s is not None:
            xv_s[...] = xv

    n_k = k_pad // bk

    def _k_step(kk, acc):
        w_blk = unpack_int4_rows(wp_ref[pl.ds(kk * (bk // 2), bk // 2), :])
        x_blk = xq_s[:, pl.ds(kk * bk, bk)]
        return acc + jax.lax.dot_general(
            x_blk, w_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )

    bm, bn = out_ref.shape
    acc = jax.lax.fori_loop(
        0, n_k, _k_step, jnp.zeros((bm, bn), jnp.int32))

    out = acc.astype(jnp.float32) * sx_s[...] * sw_ref[...]
    if xv_s is not None:
        out = out + jax.lax.dot_general(
            xv_s[...], u_ref[...].astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    out_ref[...] = out


def _kernel_lr(x_ref, v_ref, wp_ref, sw_ref, u_ref, out_ref,
               xq_s, sx_s, xv_s, **kw):
    _body(x_ref, v_ref, wp_ref, sw_ref, u_ref, out_ref, xq_s, sx_s, xv_s, **kw)


def _kernel_nolr(x_ref, wp_ref, sw_ref, out_ref, xq_s, sx_s, **kw):
    _body(x_ref, None, wp_ref, sw_ref, None, out_ref, xq_s, sx_s, None, **kw)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "clip_ratio", "rotate", "bm", "bn", "bk",
                     "interpret"),
)
def fused_w4a4_lrc_kernel(
    x: jnp.ndarray,  # (M, K) float — K UNPADDED (prologue semantics)
    v,  # (K, R) f32 or None
    wpacked: jnp.ndarray,  # (Kp//2, N) uint8, Kp = K rounded up to bk
    sw: jnp.ndarray,  # (1, N) f32
    u,  # (N, R) f32 or None
    bits: int = 4,
    clip_ratio: float = 1.0,
    rotate: bool = False,
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = True,
):
    """One pallas call for the whole W4A4+LRC forward; returns (M, N) f32."""
    m, k = x.shape
    k_pad = wpacked.shape[0] * 2
    n = wpacked.shape[1]
    assert m % bm == 0 and n % bn == 0 and k_pad % bk == 0, \
        (m, n, k, k_pad, bm, bn, bk)
    assert k_pad >= k, (k_pad, k)
    if rotate:
        assert k & (k - 1) == 0, \
            f"online rotation needs power-of-two K, got {k}"
    qmax = 2 ** (bits - 1) - 1
    with_lr = v is not None

    grid = (m // bm, n // bn)
    kw = dict(qmax=qmax, clip_ratio=clip_ratio, rotate=rotate,
              k=k, k_pad=k_pad, bk=bk)
    in_specs = [
        pl.BlockSpec((bm, k), lambda i, j: (i, 0)),  # x row slab
    ]
    operands = [x]
    if with_lr:
        r = v.shape[1]
        in_specs.append(pl.BlockSpec((k, r), lambda i, j: (0, 0)))  # V whole
        operands.append(v)
    in_specs += [
        pl.BlockSpec((k_pad // 2, bn), lambda i, j: (0, j)),  # W column slab
        pl.BlockSpec((1, bn), lambda i, j: (0, j)),  # sw
    ]
    operands += [wpacked, sw]
    scratch = [
        pltpu.VMEM((bm, k_pad), jnp.int8),  # xq residency
        pltpu.VMEM((bm, 1), jnp.float32),  # sx
    ]
    if with_lr:
        in_specs.append(pl.BlockSpec((bn, r), lambda i, j: (j, 0)))  # u
        operands.append(u)
        scratch.append(pltpu.VMEM((bm, r), jnp.float32))  # xv
        kernel = functools.partial(_kernel_lr, **kw)
    else:
        kernel = functools.partial(_kernel_nolr, **kw)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=scratch,
        # M tiles are independent (megacore-splittable); N visits of one M
        # tile share the prologue's scratch residency and must stay
        # sequential so j==0 writes before j>0 reads.
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
