"""Single-kernel fused W4A4+LRC forward: prologue + GEMM in ONE pallas call.

PR 2 fused the activation prologue into the GEMM kernel, but its (M, N) grid
kept every K-side operand WHOLE in VMEM: the (bm, K) f32 activation slab,
the full K×R V factor, and a (K//2, bn) packed-weight column slab.  Those
residencies were the VMEM ceilings that demoted the fused path exactly in
the paper's headline regime (rank ≈ 10-30% of the weight matrix at large K)
and kept prefill on the two-kernel chain.

This version splits the reduction across the grid: (M-tile, N-visit,
K-chunk, R-tile), K/R innermost, with

  * the packed-weight slab streamed per (K-chunk, N-visit) — (bk//2, bn),
  * V streamed per (K-chunk, R-tile) — (bk, br), never whole,
  * the int4 GEMM partial-summing across K-chunks in a (bm, bn) int32
    scratch accumulator,
  * ``xv`` accumulating across K-chunks in a (bm, r_pad) f32 scratch,
    R-tile by R-tile, via the canonical ``rowops.project_chunk_rows``
    partials in ascending-K order (bitwise-shared with the chained and
    unfused paths),
  * only the inherently-resident pieces left in VMEM scratch: the int8
    ``xq`` row (bm × k_pad bytes — the point of the fusion is that it never
    touches HBM), ``sx`` and ``xv``.

N-visit 0 is the PROLOGUE SWEEP: it walks the K-chunks once before any GEMM
work (the per-token scale needs the whole row's amax before any chunk can be
quantized).  Two prologue variants trade an HBM re-read against VMEM:

  resident — the (possibly rotated) f32 row is stashed in a (bm, k_pad)
      scratch slab during the sweep (rotation REQUIRES this: the cross-chunk
      butterfly stages need every chunk; ``rowops.fwht_intra_rows`` runs per
      chunk at stash time, ``fwht_cross_rows`` at the end of the sweep —
      bitwise equal to the whole-row transform).  x is read from HBM once.
  streamed — no f32 slab: the sweep only folds the per-chunk amax, and the
      first GEMM visit re-streams the x chunks to quantize and project them
      on the fly.  One extra M×K read of x; rotate=False only.

The ops-layer per-slab feasibility model picks the variant (and shrinks
tiles) instead of demoting the path, so the fused kernel now serves all
three regimes — decode, mixed AND prefill — at any rank.

Per grid step (i, j, kk, rr), K_pad = K rounded up to bk, R_pad to br:

  j == 0          : prologue sweep (see above); no output write
  j >= 1, rr == 0 : int8×int8→int32 partial sum of xq[:, kk·bk:] against the
                    streamed weight chunk into the acc scratch
  j == 1          : xv[:, rr·br:] += x_rot chunk · V tile  (projection rides
                    the first GEMM visit, when V streams)
  last (kk, rr)   : epilogue acc·sx·sw (+ xv Uᵀ) → one HBM write of the
                    (bm, bn) output tile for N-visit j-1

K is consumed UNPADDED by the prologue math (zero pad columns are exact for
amax/quantize/project; rotation requires K = K_pad, power of two), so the
integer accumulation over padded chunks is exact and all paths stay bitwise
identical in interpret mode.

GROUP-WISE activation scales (``act_group``, paper Table 2 g = 128): the
(bm, 1) per-token scale becomes the (bm, K_pad/g) scale plane in the same
VMEM scratch, with bk a multiple of g so a K-chunk always holds whole
groups.  The prologue sweep computes each chunk's group scales CHUNK-LOCALLY
(grouped amax needs no cross-chunk fold — the streamed variant drops its
fold entirely), and the dequant moves from the epilogue into the K loop:
each GEMM chunk's int32 group partials rescale by their group's activation
scale before the f32 accumulation (``rowops.gemm_chunk_grouped``, the
canonical order shared with the chained/unfused GEMM), so the accumulator
scratch is f32 and the epilogue multiplies only the weight scales.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.rowops import (
    amax_to_scale,
    default_proj_tiles,
    fwht_cross_rows,
    fwht_intra_rows,
    gemm_chunk_grouped,
    group_amax,
    project_chunk_rows,
    quantize_rows,
    quantize_rows_grouped,
    row_amax,
    unpack_int4_rows,
)

_VARIANTS = ("resident", "streamed")


def _body(x_ref, v_ref, wp_ref, sw_ref, u_ref, out_ref,
          xq_s, sx_s, xv_s, rot_s, acc_s, *,
          qmax: int, clip_ratio: float, rotate: bool, resident: bool,
          k_pad: int, bk: int, br: int, n_k: int, n_r: int, group):
    j = pl.program_id(1)
    kk = pl.program_id(2)
    rr = pl.program_id(3)
    last_kr = (kk == n_k - 1) & (rr == n_r - 1)
    ngc = None if group is None else bk // group  # scale groups per chunk

    # ---- prologue sweep (N-visit 0) -------------------------------------
    if resident:
        @pl.when((j == 0) & (rr == 0))
        def _stash():
            xc = x_ref[...].astype(jnp.float32)
            if rotate:
                xc = fwht_intra_rows(xc, bk)
            rot_s[:, pl.ds(kk * bk, bk)] = xc

        @pl.when((j == 0) & last_kr)
        def _finalize():
            row = rot_s[...]
            if rotate:
                row = fwht_cross_rows(row, k_pad, bk)
                rot_s[...] = row
            if group is None:
                s = amax_to_scale(row_amax(row), qmax, clip_ratio)
                sx_s[...] = s
                xq_s[...] = quantize_rows(row, s, qmax)
            else:
                s = amax_to_scale(group_amax(row, group), qmax, clip_ratio)
                sx_s[...] = s
                xq_s[...] = quantize_rows_grouped(row, s, qmax, group)
    elif group is None:
        @pl.when((j == 0) & (rr == 0))
        def _fold_amax():
            a = row_amax(x_ref[...].astype(jnp.float32))
            prev = jnp.where(kk == 0, jnp.zeros_like(a), sx_s[...])
            amax = jnp.maximum(prev, a)
            # the last chunk's fold doubles as the scale conversion
            sx_s[...] = jnp.where(kk == n_k - 1,
                                  amax_to_scale(amax, qmax, clip_ratio), amax)

        @pl.when((j == 1) & (rr == 0))
        def _quantize_chunk():
            xq_s[:, pl.ds(kk * bk, bk)] = quantize_rows(
                x_ref[...].astype(jnp.float32), sx_s[...], qmax)
    else:
        # streamed + grouped: groups never cross a chunk, so each chunk's
        # scales finalize chunk-locally on the sweep — no cross-chunk fold
        @pl.when((j == 0) & (rr == 0))
        def _group_scales():
            a = group_amax(x_ref[...].astype(jnp.float32), group)
            sx_s[:, pl.ds(kk * ngc, ngc)] = \
                amax_to_scale(a, qmax, clip_ratio)

        @pl.when((j == 1) & (rr == 0))
        def _quantize_chunk_grouped():
            xq_s[:, pl.ds(kk * bk, bk)] = quantize_rows_grouped(
                x_ref[...].astype(jnp.float32),
                sx_s[:, pl.ds(kk * ngc, ngc)], qmax, group)

    # ---- low-rank projection rides the first GEMM visit (V streams) -----
    if xv_s is not None:
        @pl.when(j == 1)
        def _project():
            xc = (rot_s[:, pl.ds(kk * bk, bk)] if resident
                  else x_ref[...].astype(jnp.float32))
            part = project_chunk_rows(xc, v_ref[...])
            prev = xv_s[:, pl.ds(rr * br, br)]
            xv_s[:, pl.ds(rr * br, br)] = jnp.where(kk == 0, part, prev + part)

    # ---- int4 GEMM partial sum over the K-chunks -------------------------
    @pl.when((j >= 1) & (rr == 0))
    def _gemm_chunk():
        @pl.when(kk == 0)
        def _zero():
            acc_s[...] = jnp.zeros_like(acc_s)

        w_blk = unpack_int4_rows(wp_ref[...])
        if group is None:
            acc_s[...] += jax.lax.dot_general(
                xq_s[:, pl.ds(kk * bk, bk)], w_blk, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
        else:
            # dequant in the K loop: the chunk's groups rescale before the
            # f32 accumulation (canonical gemm_chunk_grouped order)
            acc_s[...] += gemm_chunk_grouped(
                xq_s[:, pl.ds(kk * bk, bk)], w_blk,
                sx_s[:, pl.ds(kk * ngc, ngc)], group)

    # ---- epilogue: one HBM write per (M-tile, N-tile) --------------------
    @pl.when((j >= 1) & last_kr)
    def _epilogue():
        if group is None:
            out = acc_s[...].astype(jnp.float32) * sx_s[...] * sw_ref[...]
        else:
            out = acc_s[...] * sw_ref[...]  # activation scales already in
        if xv_s is not None:
            out = out + jax.lax.dot_general(
                xv_s[...], u_ref[...].astype(jnp.float32),
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
        out_ref[...] = out


@functools.partial(
    jax.jit,
    static_argnames=("bits", "clip_ratio", "rotate", "bm", "bn", "bk", "br",
                     "variant", "act_group", "interpret"),
)
def fused_w4a4_lrc_kernel(
    x: jnp.ndarray,  # (M, K) float — K UNPADDED (prologue semantics)
    v,  # (K, R) f32 or None
    wpacked: jnp.ndarray,  # (Kp//2, N) uint8, Kp = K rounded up to bk
    sw: jnp.ndarray,  # (1, N) f32
    u,  # (N, R) f32 or None
    bits: int = 4,
    clip_ratio: float = 1.0,
    rotate: bool = False,
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    br: int = None,  # R-tile of the streamed V (defaults: 512-capped pow2)
    variant: str = "resident",  # resident | streamed prologue (see module doc)
    act_group: int = None,  # None = per-token scales; else bk % act_group == 0
    interpret: bool = True,
):
    """One pallas call for the whole W4A4+LRC forward; returns (M, N) f32."""
    m, k = x.shape
    k_pad = wpacked.shape[0] * 2
    n = wpacked.shape[1]
    assert m % bm == 0 and n % bn == 0 and k_pad % bk == 0, \
        (m, n, k, k_pad, bm, bn, bk)
    assert k_pad >= k, (k_pad, k)
    assert variant in _VARIANTS, variant
    resident = variant == "resident"
    if rotate:
        assert k & (k - 1) == 0, \
            f"online rotation needs power-of-two K, got {k}"
        assert k_pad == k, (k, k_pad)
        assert resident, "rotation's cross-chunk butterflies need the " \
                         "resident row slab"
    if act_group is not None:
        # chunks hold whole scale groups; pad K columns form whole (exact)
        # zero groups whose guarded scale quantizes them to 0
        assert k % act_group == 0, (k, act_group)
        assert bk % act_group == 0, (bk, act_group)
    n_s_pad = 1 if act_group is None else k_pad // act_group
    qmax = 2 ** (bits - 1) - 1
    with_lr = v is not None

    if k_pad > k:
        x = jnp.pad(x, ((0, 0), (0, k_pad - k)))

    r_pad = 0
    if with_lr:
        r = v.shape[1]
        br = default_proj_tiles(k, r, bk, br)[1]
        r_pad = r + (-r) % br
        v = jnp.asarray(v, jnp.float32)
        if (k_pad > k) or (r_pad > r):
            v = jnp.pad(v, ((0, k_pad - k), (0, r_pad - r)))
        if r_pad > r:
            u = jnp.pad(jnp.asarray(u, jnp.float32), ((0, 0), (0, r_pad - r)))
    n_k = k_pad // bk
    n_r = max(r_pad // br, 1) if with_lr else 1

    # N-visit 0 is the prologue sweep; visits 1..n/bn do GEMM work for
    # output column j-1.
    grid = (m // bm, n // bn + 1, n_k, n_r)
    kw = dict(qmax=qmax, clip_ratio=clip_ratio, rotate=rotate,
              resident=resident, k_pad=k_pad, bk=bk, br=br, n_k=n_k, n_r=n_r,
              group=act_group)

    # x chunks stream during the prologue sweep (and, for the streamed
    # variant, again on the first GEMM visit); later visits pin chunk 0 so
    # consecutive fetches dedupe.
    x_reads = (lambda j: j == 0) if resident else (lambda j: j <= 1)
    in_specs = [
        pl.BlockSpec((bm, bk),
                     lambda i, j, kk, rr: (i, jnp.where(x_reads(j), kk, 0))),
    ]
    operands = [x]
    if with_lr:
        in_specs.append(pl.BlockSpec(
            (bk, br),
            lambda i, j, kk, rr: (jnp.where(j == 1, kk, 0),
                                  jnp.where(j == 1, rr, 0))))  # V tile
        operands.append(v)
    in_specs += [
        pl.BlockSpec((bk // 2, bn),
                     lambda i, j, kk, rr: (jnp.where(j == 0, 0, kk),
                                           jnp.maximum(j - 1, 0))),  # W chunk
        pl.BlockSpec((1, bn),
                     lambda i, j, kk, rr: (0, jnp.maximum(j - 1, 0))),  # sw
    ]
    operands += [wpacked, sw]
    scratch = [
        pltpu.VMEM((bm, k_pad), jnp.int8),  # xq residency
        # sx: per-token column (amax accumulator first on the streamed
        # sweep) or the per-group scale plane
        pltpu.VMEM((bm, n_s_pad), jnp.float32),
    ]
    if with_lr:
        in_specs.append(pl.BlockSpec(
            (bn, r_pad), lambda i, j, kk, rr: (jnp.maximum(j - 1, 0), 0)))  # U
        operands.append(u)
        scratch.append(pltpu.VMEM((bm, r_pad), jnp.float32))  # xv accumulator
    if resident:
        scratch.append(pltpu.VMEM((bm, k_pad), jnp.float32))  # f32 row slab
    # GEMM partial sums: int32 per-token (rescale in the epilogue); f32
    # grouped (each chunk's groups rescale before accumulation)
    scratch.append(pltpu.VMEM(
        (bm, bn), jnp.int32 if act_group is None else jnp.float32))

    def kernel(*refs):
        i = 0
        x_ref = refs[i]; i += 1
        v_ref = None
        if with_lr:
            v_ref = refs[i]; i += 1
        wp_ref = refs[i]; i += 1
        sw_ref = refs[i]; i += 1
        u_ref = None
        if with_lr:
            u_ref = refs[i]; i += 1
        out_ref = refs[i]; i += 1
        xq_s = refs[i]; i += 1
        sx_s = refs[i]; i += 1
        xv_s = None
        if with_lr:
            xv_s = refs[i]; i += 1
        rot_s = None
        if resident:
            rot_s = refs[i]; i += 1
        acc_s = refs[i]
        _body(x_ref, v_ref, wp_ref, sw_ref, u_ref, out_ref,
              xq_s, sx_s, xv_s, rot_s, acc_s, **kw)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn),
                               lambda i, j, kk, rr: (i, jnp.maximum(j - 1, 0))),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=scratch,
        # M tiles are independent (megacore-splittable); the N/K/R visits of
        # one M tile share the prologue's scratch residency and the partial
        # sums, and must stay sequential.
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
