"""Fused activation prologue: rotate -> quantize -> low-rank project.

The W4A4+LRC serving path needs three activation-side products before the
quantized GEMM can run:

  x_rot = x @ H          (QuaRot online Walsh-Hadamard rotation, optional)
  xq,sx = Q_a(x_rot)     (per-token int4-grid quantization, paper §2)
  xv    = x_rot @ V      (the low-rank projection half of (xV)Uᵀ)

Unfused these are three independent HBM passes over the activations (plus a
rotated-x round-trip) — exactly the "data movement is important" regime the
paper's §5 measures as a 23-52% latency tax, and LQER identifies as
activation-bandwidth-bound at decode batch sizes.  This kernel performs all
three on a row tile of ``x`` held in VMEM: the grid walks M tiles once, each
tile is read from HBM a single time, and ``xq``/``sx``/``xv`` are emitted
directly — no rotated-x or float intermediate ever returns to HBM.

Semantics are bit-identical to the three-pass reference chain
(`hadamard.fwht_kernel` → `actquant.act_quant_kernel` → ``x_rot @ V``) for
float32 inputs: the butterfly, the amax guard, and the scale-then-round all
reuse the same operation order.

V is kept whole in VMEM (R ≪ K); the ops-layer wrapper falls back to the
unfused path when (K, R) would not fit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.rowops import prologue_rows


def _kernel_lr(x_ref, v_ref, q_ref, s_ref, xv_ref, *,
               qmax: int, clip_ratio: float, rotate: bool, d: int):
    q, s, xv = prologue_rows(x_ref[...].astype(jnp.float32), v_ref[...],
                             qmax, clip_ratio, rotate, d)
    q_ref[...] = q
    s_ref[...] = s
    xv_ref[...] = xv


def _kernel_nolr(x_ref, q_ref, s_ref, *,
                 qmax: int, clip_ratio: float, rotate: bool, d: int):
    q, s, _ = prologue_rows(x_ref[...].astype(jnp.float32), None,
                            qmax, clip_ratio, rotate, d)
    q_ref[...] = q
    s_ref[...] = s


@functools.partial(
    jax.jit,
    static_argnames=("bits", "clip_ratio", "rotate", "bm", "interpret"),
)
def fused_prologue_kernel(
    x: jnp.ndarray,  # (M, K)
    v,  # (K, R) or None
    bits: int = 4,
    clip_ratio: float = 1.0,
    rotate: bool = False,
    bm: int = 128,
    interpret: bool = True,
):
    """One grid pass over row tiles: returns (xq int8, sx (M,1) f32[, xv f32]).

    ``rotate`` applies the normalized WHT over K (requires K a power of two)
    before quantization and projection, matching fwht_kernel → act_quant_kernel
    → x_rot @ V run back-to-back.
    """
    m, k = x.shape
    assert m % bm == 0, (m, bm)
    if rotate:
        assert k & (k - 1) == 0, f"online rotation needs power-of-two K, got {k}"
    qmax = 2 ** (bits - 1) - 1
    grid = (m // bm,)
    semantics = pltpu.TPUCompilerParams(dimension_semantics=("parallel",))

    if v is None:
        q, s = pl.pallas_call(
            functools.partial(_kernel_nolr, qmax=qmax, clip_ratio=clip_ratio,
                              rotate=rotate, d=k),
            grid=grid,
            in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
            out_specs=[
                pl.BlockSpec((bm, k), lambda i: (i, 0)),
                pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((m, k), jnp.int8),
                jax.ShapeDtypeStruct((m, 1), jnp.float32),
            ],
            compiler_params=semantics,
            interpret=interpret,
        )(x)
        return q, s, None

    r = v.shape[1]
    q, s, xv = pl.pallas_call(
        functools.partial(_kernel_lr, qmax=qmax, clip_ratio=clip_ratio,
                          rotate=rotate, d=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),  # x row tile
            pl.BlockSpec((k, r), lambda i: (0, 0)),  # V, whole, reused per tile
        ],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, r), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.int8),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
            jax.ShapeDtypeStruct((m, r), jnp.float32),
        ],
        compiler_params=semantics,
        interpret=interpret,
    )(x, v)
    return q, s, xv
