"""Fused activation prologue: rotate -> quantize -> low-rank project.

The W4A4+LRC serving path needs three activation-side products before the
quantized GEMM can run:

  x_rot = x @ H          (QuaRot online Walsh-Hadamard rotation, optional)
  xq,sx = Q_a(x_rot)     (per-token int4-grid quantization, paper §2)
  xv    = x_rot @ V      (the low-rank projection half of (xV)Uᵀ)

Unfused these are three independent HBM passes over the activations (plus a
rotated-x round-trip) — exactly the "data movement is important" regime the
paper's §5 measures as a 23-52% latency tax, and LQER identifies as
activation-bandwidth-bound at decode batch sizes.  This kernel performs all
three on a row tile of ``x`` held in VMEM: the grid walks M tiles once, each
tile is read from HBM a single time, and ``xq``/``sx``/``xv`` are emitted
directly — no rotated-x or float intermediate ever returns to HBM.

V streaming (K-chunked, R-tiled)
--------------------------------

V is NOT held whole in VMEM: the grid is (M-tile, K-chunk, R-tile) and V
arrives in (bk, br) tiles, so the resident V footprint is one tile instead
of the full K×R×4 bytes — the 8 MB ceiling that used to demote rank ≥ 1024
at large K to the unfused path is gone.  ``xv`` accumulates directly in its
(bm, r_pad) output block (revisited across the K/R steps of one M tile) via
the canonical ``rowops.project_chunk_rows`` partials in ascending-K order —
the SAME dots in the SAME order the single-kernel fused path and the
unfused ``project_rows_tiled`` issue, which is what keeps all three paths
bitwise identical.  ``xq``/``sx`` are computed whole-row on the first
(K-chunk 0, R-tile 0) visit — the x row slab is VMEM-resident anyway.

Semantics are bit-identical to the three-pass reference chain
(`hadamard.fwht_kernel` → `actquant.act_quant_kernel` → tiled ``x_rot @ V``)
for float32 inputs: the butterfly, the amax guard, and the scale-then-round
all reuse the same rowops bodies.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.rowops import (
    default_proj_tiles,
    fwht_rows,
    project_chunk_rows,
    prologue_rows,
    scale_round_quantize,
    snap_bk_to_group,
)


def _kernel_lr(x_ref, v_ref, q_ref, s_ref, xv_ref, rot_ref, *,
               qmax: int, clip_ratio: float, rotate: bool,
               k: int, bk: int, br: int, group):
    kk = pl.program_id(1)
    rr = pl.program_id(2)

    @pl.when((kk == 0) & (rr == 0))
    def _quantize():
        row = x_ref[...].astype(jnp.float32)
        if rotate:
            row = fwht_rows(row, k)
            rot_ref[...] = row
        q, s = scale_round_quantize(row, qmax, clip_ratio, group=group)
        q_ref[...] = q
        s_ref[...] = s

    src = rot_ref if rotate else x_ref
    chunk = src[:, pl.ds(kk * bk, bk)].astype(jnp.float32)
    part = project_chunk_rows(chunk, v_ref[...])
    prev = xv_ref[:, pl.ds(rr * br, br)]
    xv_ref[:, pl.ds(rr * br, br)] = jnp.where(kk == 0, part, prev + part)


def _kernel_nolr(x_ref, q_ref, s_ref, *,
                 qmax: int, clip_ratio: float, rotate: bool, d: int, group):
    q, s, _ = prologue_rows(x_ref[...].astype(jnp.float32), None,
                            qmax, clip_ratio, rotate, d, group=group)
    q_ref[...] = q
    s_ref[...] = s


@functools.partial(
    jax.jit,
    static_argnames=("bits", "clip_ratio", "rotate", "bm", "bk", "br",
                     "act_group", "interpret"),
)
def fused_prologue_kernel(
    x: jnp.ndarray,  # (M, K)
    v,  # (K, R) or None
    bits: int = 4,
    clip_ratio: float = 1.0,
    rotate: bool = False,
    bm: int = 128,
    bk: int = None,  # V-stream K-chunk (defaults per default_proj_tiles)
    br: int = None,  # V-stream R-tile
    act_group: int = None,  # None = per-token scales; else one per K group
    interpret: bool = True,
):
    """One grid pass over row tiles: returns (xq int8, sx f32[, xv f32]).

    ``sx`` is the (M, 1) per-token scale, or — with ``act_group`` — the
    (M, K // act_group) per-group scale plane (groups contiguous along K,
    computed from the VMEM-resident row with the shared rowops bodies).
    ``rotate`` applies the normalized WHT over K (requires K a power of two)
    before quantization and projection, matching fwht_kernel → act_quant_kernel
    → the tiled x_rot @ V run back-to-back.  With a low-rank V the grid is
    (M-tile, K-chunk, R-tile) and V streams in (bk, br) tiles — it is never
    whole in VMEM.
    """
    m, k = x.shape
    assert m % bm == 0, (m, bm)
    if rotate:
        assert k & (k - 1) == 0, f"online rotation needs power-of-two K, got {k}"
    if act_group is not None:
        assert k % act_group == 0, (k, act_group)
    n_s = 1 if act_group is None else k // act_group
    qmax = 2 ** (bits - 1) - 1

    if v is None:
        grid = (m // bm,)
        q, s = pl.pallas_call(
            functools.partial(_kernel_nolr, qmax=qmax, clip_ratio=clip_ratio,
                              rotate=rotate, d=k, group=act_group),
            grid=grid,
            in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
            out_specs=[
                pl.BlockSpec((bm, k), lambda i: (i, 0)),
                pl.BlockSpec((bm, n_s), lambda i: (i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((m, k), jnp.int8),
                jax.ShapeDtypeStruct((m, n_s), jnp.float32),
            ],
            compiler_params=pltpu.TPUCompilerParams(
                dimension_semantics=("parallel",)),
            interpret=interpret,
        )(x)
        return q, s, None

    r = v.shape[1]
    bk, br = default_proj_tiles(k, r, bk, br)
    if act_group is not None:
        bk = snap_bk_to_group(bk, act_group)  # chunks hold whole groups
    k_pad = k + (-k) % bk
    r_pad = r + (-r) % br
    n_s_pad = 1 if act_group is None else k_pad // act_group
    if rotate:
        assert k_pad == k, (k, bk)  # pow2 K, pow2 bk ≤ K always divides
    if k_pad > k:
        x = jnp.pad(x, ((0, 0), (0, k_pad - k)))
    vp = jnp.asarray(v, jnp.float32)
    if (k_pad > k) or (r_pad > r):
        vp = jnp.pad(vp, ((0, k_pad - k), (0, r_pad - r)))

    grid = (m // bm, k_pad // bk, r_pad // br)
    scratch = []
    if rotate:
        scratch.append(pltpu.VMEM((bm, k_pad), jnp.float32))  # rotated row

    def kernel(x_ref, v_ref, q_ref, s_ref, xv_ref, *rest):
        rot_ref = rest[0] if rotate else None
        _kernel_lr(x_ref, v_ref, q_ref, s_ref, xv_ref, rot_ref,
                   qmax=qmax, clip_ratio=clip_ratio, rotate=rotate,
                   k=k, bk=bk, br=br, group=act_group)

    q, s, xv = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # x row slab: same block for every (kk, rr) visit of one M tile
            pl.BlockSpec((bm, k_pad), lambda i, kk, rr: (i, 0)),
            pl.BlockSpec((bk, br), lambda i, kk, rr: (kk, rr)),  # V tile
        ],
        out_specs=[
            pl.BlockSpec((bm, k_pad), lambda i, kk, rr: (i, 0)),
            pl.BlockSpec((bm, n_s_pad), lambda i, kk, rr: (i, 0)),
            # xv doubles as the accumulator: revisited across (kk, rr)
            pl.BlockSpec((bm, r_pad), lambda i, kk, rr: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k_pad), jnp.int8),
            jax.ShapeDtypeStruct((m, n_s_pad), jnp.float32),
            jax.ShapeDtypeStruct((m, r_pad), jnp.float32),
        ],
        scratch_shapes=scratch,
        # M tiles are independent; the (kk, rr) visits of one M tile share
        # the xv block residency and must stay sequential.
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(x, vp)
    return q[:, :k], s[:, :n_s], xv[:, :r]
