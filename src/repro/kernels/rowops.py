"""Shared row-tile compute bodies used by multiple Pallas kernels.

The fused prologue's bitwise-parity contract with the standalone hadamard /
actquant kernels (tests/test_kernels_prologue.py acceptance) holds because
all three import THESE implementations — the butterfly order and the
scale-then-round operation order live in exactly one place.
"""

from __future__ import annotations

import jax.numpy as jnp


def fwht_rows(y: jnp.ndarray, d: int) -> jnp.ndarray:
    """Normalized Walsh-Hadamard transform over the last axis of a (bm, d)
    f32 tile, d a power of two: log2(d) butterfly sweeps in registers/VMEM."""
    bm = y.shape[0]
    h = 1
    while h < d:
        y = y.reshape(bm, d // (2 * h), 2, h)
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.stack([a + b, a - b], axis=2)
        h *= 2
    return y.reshape(bm, d) * (1.0 / (d**0.5))


def scale_round_quantize(x: jnp.ndarray, qmax: int, clip_ratio: float):
    """Paper §2 scale-then-round on the symmetric int grid: per-token amax
    (zero-guarded) → s = c·amax/qmax → q = clip(round(x/s)).
    Returns (q int8, s f32 (bm, 1))."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    amax = jnp.where(amax <= 0.0, 1.0, amax)
    s = clip_ratio * amax / qmax
    q = jnp.clip(jnp.round(x / s), -qmax - 1, qmax)
    return q.astype(jnp.int8), s
