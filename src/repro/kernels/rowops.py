"""Shared row-tile compute bodies used by multiple Pallas kernels.

The bitwise-parity contract between the single-kernel fused forward
(kernels/fused_gemm.py), the two-kernel chain (prologue → w4a4 GEMM) and the
standalone hadamard / actquant kernels (tests/test_kernels_prologue.py and
tests/test_kernels_fused.py acceptance) holds because all of them import
THESE implementations — the butterfly order, the scale-then-round operation
order, the prologue body, the K-chunked/R-tiled projection accumulation and
the int4 nibble layout live in exactly one place.

K-split slab bodies
-------------------

The K-split fused grid streams the activation row in (bm, bk) slabs, so the
whole-row bodies decompose into slab-shaped pieces with EXACTLY the same
float ops:

  * ``fwht_rows(x, d)`` ==(bitwise)== ``fwht_cross_rows`` applied to the
    concatenation of per-chunk ``fwht_intra_rows``: butterflies at distance
    h < bk never cross a bk-aligned chunk boundary, so the first log2(bk)
    sweeps run per chunk; the remaining sweeps pair whole chunks; the
    1/sqrt(d) normalization happens once at the end in both spellings.
  * per-token amax is a max-reduction — chunk-wise ``jnp.maximum`` folding
    is exactly the whole-row max (max is exact on floats).
  * ``q = clip(round(x/s))`` is elementwise — chunk-wise application with
    the whole-row scale is the whole-row quantization.

Group-wise activation scales (paper Table 2, g = 128)
-----------------------------------------------------

Per-group quantization replaces the (bm, 1) per-token scale with a
(bm, d/g) SCALE PLANE: one scale per g contiguous K features.  Scale groups
are aligned to K-chunks (the plan layer snaps bk to a multiple of g, see
``snap_bk_to_group``), so a chunk always holds whole groups and

  * the per-group amax needs NO cross-chunk fold — ``group_amax`` on a
    chunk computes exactly the same reductions as on the whole row,
  * the int8 GEMM must rescale per group BEFORE f32 accumulation: the
    canonical order is ``gemm_chunk_grouped`` (per chunk: int32 dots over
    the groups in ascending-K order, each rescaled and summed in f32) with
    the per-chunk results accumulated across chunks in ascending-K order.
    All three kernel paths issue these same dots in this same order.
  * zero-padded K tails are exact: a padded group's amax is 0, the scale
    guard clamps it to 1, its quantized values are 0, and the group's
    rescaled partial sum is an exact f32 +0.0.

``group = d`` (one group spanning the row) reproduces per-token
quantization bit for bit: the reductions, the guard and the scale·round are
the same scalar ops on the same operands.
  * the (x·V) projection is canonically a (bk, br)-tiled accumulation
    (``project_rows_tiled`` / per-chunk ``project_chunk_rows`` summed in
    ascending-K order) — all three kernel paths issue these same dots in
    this same order, which is what keeps them bitwise identical.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def round_pow2(m: int) -> int:
    """Largest power of two ≤ max(m, 8) (block-size clamp helper)."""
    p = 8
    while p * 2 <= m:
        p *= 2
    return p


def default_proj_tiles(k: int, r: int, bk=None, br=None):
    """Default (bk, br) projection tiles: 512-capped powers of two clamped
    to the problem.  THE one spelling of the default — the prologue and
    fused kernels and the ops-layer plan table all derive their fallback
    tiles from here, so direct kernel callers and the dispatched paths
    agree on the (bk, br) accumulation order the bitwise contract needs."""
    if bk is None:
        bk = min(512, round_pow2(max(k, 8)))
    if br is None:
        br = min(512, round_pow2(max(r, 8)))
    return bk, br


def fwht_rows(y: jnp.ndarray, d: int) -> jnp.ndarray:
    """Normalized Walsh-Hadamard transform over the last axis of a (bm, d)
    f32 tile, d a power of two: log2(d) butterfly sweeps in registers/VMEM."""
    bm = y.shape[0]
    h = 1
    while h < d:
        y = y.reshape(bm, d // (2 * h), 2, h)
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.stack([a + b, a - b], axis=2)
        h *= 2
    return y.reshape(bm, d) * (1.0 / (d**0.5))


def fwht_intra_rows(y: jnp.ndarray, bk: int) -> jnp.ndarray:
    """UNNORMALIZED butterfly sweeps h = 1..bk/2 on one (bm, bk) K-chunk.

    These are exactly the first log2(bk) sweeps of the whole-row transform:
    for h < bk a butterfly pairs elements i and i+h, which live in the same
    bk-aligned chunk, so the sweeps run chunk-local with the identical
    (a+b, a-b) operand pairing."""
    bm = y.shape[0]
    h = 1
    while h < bk:
        y = y.reshape(bm, bk // (2 * h), 2, h)
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.stack([a + b, a - b], axis=2)
        h *= 2
    return y.reshape(bm, bk)


def fwht_cross_rows(y: jnp.ndarray, d: int, bk: int) -> jnp.ndarray:
    """Butterfly sweeps h = bk..d/2 across bk-chunks + the 1/sqrt(d)
    normalization, on a (bm, d) row whose chunks already went through
    :func:`fwht_intra_rows`.  ``fwht_cross_rows(intra-chunks) `` is bitwise
    equal to ``fwht_rows`` on the raw row (same scalar pairings, same op
    order, one trailing normalization multiply in both)."""
    bm = y.shape[0]
    n_c = d // bk
    z = y.reshape(bm, n_c, bk)
    g = 1
    while g < n_c:
        z = z.reshape(bm, n_c // (2 * g), 2, g, bk)
        a = z[:, :, 0]
        b = z[:, :, 1]
        z = jnp.stack([a + b, a - b], axis=2)
        g *= 2
    return z.reshape(bm, d) * (1.0 / (d**0.5))


def row_amax(x: jnp.ndarray) -> jnp.ndarray:
    """Per-token |x| max of a (bm, d) tile -> (bm, 1).  Chunk-wise folding
    with jnp.maximum reproduces the whole-row value exactly."""
    return jnp.max(jnp.abs(x), axis=-1, keepdims=True)


def snap_bk_to_group(bk: int, group: int) -> int:
    """Largest ``group · 2^j ≤ bk`` (minimum ``group``): with group-wise
    activation scales a K-chunk must hold WHOLE scale groups, and the
    power-of-two multiple keeps the plan layer's halving shrink-to-fit
    closed over the constraint (every halving above ``group`` is still a
    multiple of ``group``)."""
    snapped = group
    while snapped * 2 <= bk:
        snapped *= 2
    return snapped


def group_amax(x: jnp.ndarray, group: int) -> jnp.ndarray:
    """Per-group |x| max of a (bm, d) tile -> (bm, d // group).  Groups are
    contiguous along K; because chunks hold whole groups, chunk-wise
    application computes exactly the whole-row result."""
    bm, d = x.shape
    assert d % group == 0, (d, group)
    return jnp.max(jnp.abs(x.reshape(bm, d // group, group)), axis=-1)


def quantize_rows_grouped(x: jnp.ndarray, s: jnp.ndarray, qmax: int,
                          group: int) -> jnp.ndarray:
    """Elementwise q = clip(round(x/s)) with one scale per K group.  Safe to
    apply per chunk with the matching slice of the scale plane."""
    bm, d = x.shape
    xs = x.reshape(bm, d // group, group) / s[..., None]
    return jnp.clip(jnp.round(xs), -qmax - 1, qmax) \
        .astype(jnp.int8).reshape(bm, d)


def amax_to_scale(amax: jnp.ndarray, qmax: int, clip_ratio: float):
    """Paper §2 scale: zero-guarded amax → s = c·amax/qmax."""
    amax = jnp.where(amax <= 0.0, 1.0, amax)
    return clip_ratio * amax / qmax


def quantize_rows(x: jnp.ndarray, s: jnp.ndarray, qmax: int) -> jnp.ndarray:
    """Elementwise q = clip(round(x/s)) on the symmetric int grid — safe to
    apply per K-chunk once the whole-row scale is known."""
    return jnp.clip(jnp.round(x / s), -qmax - 1, qmax).astype(jnp.int8)


def scale_round_quantize(x: jnp.ndarray, qmax: int, clip_ratio: float,
                         group: int = None):
    """amax → scale → round (the composition of the slab bodies).  Per-token
    (``group=None``) returns (q int8, s f32 (bm, 1)); group-wise returns the
    (bm, d // group) scale plane instead."""
    if group is None:
        s = amax_to_scale(row_amax(x), qmax, clip_ratio)
        return quantize_rows(x, s, qmax), s
    s = amax_to_scale(group_amax(x, group), qmax, clip_ratio)
    return quantize_rows_grouped(x, s, qmax, group), s


def gemm_chunk_grouped(xq_chunk: jnp.ndarray, w_chunk: jnp.ndarray,
                       s_chunk: jnp.ndarray, group: int) -> jnp.ndarray:
    """ONE K-chunk of the group-rescaled int4 GEMM: per scale group in
    ascending-K order, an int8×int8→int32 dot rescaled by that group's
    activation scale, summed in f32.  xq_chunk: (bm, bk) int8; w_chunk:
    (bk, bn) int8; s_chunk: (bm, bk // group) f32.  This is THE canonical
    dequant-in-the-K-loop spelling — the fused, chained and unfused GEMMs
    all issue these dots in this order, which keeps grouped outputs bitwise
    identical across paths (cross-chunk accumulation is ascending-K f32
    adds of these per-chunk results).

    The rescale-and-sum over the chunk's groups is ONE ``dot_general``
    contraction (out[m, n] = Σ_g s[m, g] · d[g, m, n]) rather than an
    unrolled mul/add chain — this is load-bearing for the bitwise
    contract: XLA contracts a hand-written ``prev + acc·s`` chain into an
    FMA in one kernel's compilation and not another's, skewing the last
    bit between paths, while the same-shape dot lowers identically in
    every compilation unit (the xv projection's parity rests on the same
    property)."""
    bm, bk = xq_chunk.shape
    n_g = bk // group
    parts = [
        jax.lax.dot_general(
            xq_chunk[:, gi * group:(gi + 1) * group],
            w_chunk[gi * group:(gi + 1) * group, :],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        for gi in range(n_g)  # exact int32 group partials, ascending K
    ]
    stacked = jnp.stack(parts, axis=1).astype(jnp.float32)  # (bm, n_g, bn)
    return jax.lax.dot_general(
        s_chunk, stacked, (((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )


def project_chunk_rows(x_chunk: jnp.ndarray, v_tile: jnp.ndarray):
    """ONE (bm, bk) × (bk, br) projection partial — the canonical dot every
    path issues per (K-chunk, R-tile).  f32 in, f32 out."""
    return jax.lax.dot_general(
        x_chunk, v_tile.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def project_rows_tiled(x: jnp.ndarray, v: jnp.ndarray, bk: int, br: int):
    """The canonical K-chunked, R-tiled (x·V): per R-tile, sum the per-chunk
    dots in ascending-K order.  x: (bm, k_pad) f32, v: (k_pad, r_pad); both
    padded to the tile multiples.  This is the jnp spelling of the exact
    accumulation the kernels perform across grid steps (the unfused path
    runs THIS; the prologue/fused kernels accumulate the same
    ``project_chunk_rows`` partials in the same order)."""
    k_pad = x.shape[1]
    r_pad = v.shape[1]
    assert k_pad % bk == 0 and r_pad % br == 0, (k_pad, r_pad, bk, br)
    cols = []
    for rr in range(r_pad // br):
        acc = None
        for kk in range(k_pad // bk):
            part = project_chunk_rows(
                x[:, kk * bk:(kk + 1) * bk],
                v[kk * bk:(kk + 1) * bk, rr * br:(rr + 1) * br])
            acc = part if acc is None else acc + part
        cols.append(acc)
    return cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)


def prologue_rows(x, v, qmax: int, clip_ratio: float, rotate: bool, d: int,
                  group: int = None):
    """The full activation-prologue row body on a (bm, d) f32 tile: optional
    WHT rotation, per-token (or per-group) quantization, and the (x·V)
    projection.  Returns (q int8, s f32 (bm, 1) or the (bm, d // group)
    scale plane, xv f32 (bm, R) or None)."""
    if rotate:
        x = fwht_rows(x, d)
    q, s = scale_round_quantize(x, qmax, clip_ratio, group=group)
    xv = None
    if v is not None:
        xv = jax.lax.dot_general(
            x, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    return q, s, xv


def dequant_rows_grouped(q: jnp.ndarray, s: jnp.ndarray,
                         group: int) -> jnp.ndarray:
    """THE canonical group dequant body: int rows (bm, d) + the (bm,
    d // group) scale plane → f32 rows, as ONE elementwise multiply over
    the group reshape.  The KV-cache path is built on this — the jnp
    paged serving gather (via ``serve.kvquant.dequantize_kv``) and the
    dequant-fused flash-attention kernels all call it, so the dequantized
    operands entering their attention math are bitwise identical (the
    same single-spelling discipline as :func:`gemm_chunk_grouped`)."""
    bm, d = q.shape
    assert d % group == 0, (d, group)
    x = q.astype(jnp.float32).reshape(bm, d // group, group) * s[..., None]
    return x.reshape(bm, d)


def unpack_int4_rows(wp: jnp.ndarray) -> jnp.ndarray:
    """(BK//2, BN) uint8 -> (BK, BN) int8 in [-8, 7]; even rows = low nibble.
    Packed rows interleave (2i, 2i+1): stack on a new axis, then fold."""
    lo = (wp & 0xF).astype(jnp.int8)
    hi = ((wp >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    bk2, bn = wp.shape
    w = jnp.stack([lo, hi], axis=1)  # (BK//2, 2, BN)
    return w.reshape(bk2 * 2, bn)
