"""Shared row-tile compute bodies used by multiple Pallas kernels.

The bitwise-parity contract between the single-kernel fused forward
(kernels/fused_gemm.py), the two-kernel chain (prologue → w4a4 GEMM) and the
standalone hadamard / actquant kernels (tests/test_kernels_prologue.py and
tests/test_kernels_fused.py acceptance) holds because all of them import
THESE implementations — the butterfly order, the scale-then-round operation
order, the prologue body and the int4 nibble layout live in exactly one
place.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fwht_rows(y: jnp.ndarray, d: int) -> jnp.ndarray:
    """Normalized Walsh-Hadamard transform over the last axis of a (bm, d)
    f32 tile, d a power of two: log2(d) butterfly sweeps in registers/VMEM."""
    bm = y.shape[0]
    h = 1
    while h < d:
        y = y.reshape(bm, d // (2 * h), 2, h)
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.stack([a + b, a - b], axis=2)
        h *= 2
    return y.reshape(bm, d) * (1.0 / (d**0.5))


def scale_round_quantize(x: jnp.ndarray, qmax: int, clip_ratio: float):
    """Paper §2 scale-then-round on the symmetric int grid: per-token amax
    (zero-guarded) → s = c·amax/qmax → q = clip(round(x/s)).
    Returns (q int8, s f32 (bm, 1))."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    amax = jnp.where(amax <= 0.0, 1.0, amax)
    s = clip_ratio * amax / qmax
    q = jnp.clip(jnp.round(x / s), -qmax - 1, qmax)
    return q.astype(jnp.int8), s


def prologue_rows(x, v, qmax: int, clip_ratio: float, rotate: bool, d: int):
    """The full activation-prologue row body on a (bm, d) f32 tile: optional
    WHT rotation, per-token quantization, and the (x·V) projection.
    Returns (q int8, s f32 (bm, 1), xv f32 (bm, R) or None)."""
    if rotate:
        x = fwht_rows(x, d)
    q, s = scale_round_quantize(x, qmax, clip_ratio)
    xv = None
    if v is not None:
        xv = jax.lax.dot_general(
            x, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    return q, s, xv


def unpack_int4_rows(wp: jnp.ndarray) -> jnp.ndarray:
    """(BK//2, BN) uint8 -> (BK, BN) int8 in [-8, 7]; even rows = low nibble.
    Packed rows interleave (2i, 2i+1): stack on a new axis, then fold."""
    lo = (wp & 0xF).astype(jnp.int8)
    hi = ((wp >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    bk2, bn = wp.shape
    w = jnp.stack([lo, hi], axis=1)  # (BK//2, 2, BN)
    return w.reshape(bk2 * 2, bn)
