"""jit'd public wrappers around the Pallas kernels.

Responsibilities: shape padding to block multiples (weights, scales and the
low-rank factors are zero-padded, so odd MLP widths never crash the pallas
path), execution-plan selection per serving regime (decode / mixed /
prefill) — kernel path AND (BM, BN, BK) tiles, overridable from a measured
``results/block_table.json`` via :func:`load_block_table` —, interpret-mode
selection (interpret=True on CPU — validates the kernel bodies; compiled
Mosaic on real TPU), and the end-to-end entry ``w4a4_lrc_forward`` used by
``QLinear(impl="pallas"/"fused")`` and the serving engine.

Three kernel paths, strongest fusion first:

  fused   — ONE pallas kernel (kernels/fused_gemm.py): the activation
            prologue runs on each M-tile's first N visit and the int4 GEMM +
            LRC epilogue feed from the VMEM scratch residency; xq never
            touches HBM.
  chained — TWO kernels (prologue → w4a4 GEMM); xq/sx/xv make one HBM
            round-trip between them.  Fallback when the fused kernel's
            working set (x row slab + V + weight slab) exceeds VMEM.
  unfused — three activation passes (rotate, quantize, project) + the GEMM
            kernel.  Fallback when V alone exceeds the prologue VMEM budget.

All three are bitwise identical in interpret mode: they share the row bodies
in kernels/rowops.py and integer accumulation is exact under any K split.
"""

from __future__ import annotations

import json
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core.quantizers import QuantSpec
from repro.kernels.actquant import act_quant_kernel
from repro.kernels.fused_gemm import fused_w4a4_lrc_kernel
from repro.kernels.hadamard import fwht_kernel
from repro.kernels.prologue import fused_prologue_kernel
from repro.kernels.w4a4 import w4a4_lowrank_matmul_kernel
from repro.kernels.flash_attn import flash_attention_kernel

# V is held whole in VMEM by the fused prologue (both the single-kernel and
# the chained path); past this footprint the wrapper falls back to the
# unfused three-pass chain.
_PROLOGUE_V_BYTES_MAX = 8 * 1024 * 1024

# Working-set ceiling for the single-kernel fused path (x row slab + xq
# scratch + V + weight slab + U/xv/out tiles); past it, auto dispatch takes
# the two-kernel chain.  ~¾ of a v5e core's 16 MB VMEM, leaving room for
# Mosaic's double-buffering of the streamed operands.
_FUSED_VMEM_BYTES_MAX = 12 * 1024 * 1024


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def _round_pow2(m: int) -> int:
    p = 8
    while p * 2 <= m:
        p *= 2
    return p


# ---------------------------------------------------------------------------
# execution-plan autotune table (kernel path + block sizes)
# ---------------------------------------------------------------------------

# Regime-keyed execution plans: the kernel path plus (BM, BN, BK) tiles.
# decode  (M ≤ 32):  single-kernel fused — the decode hot path is
#                    activation+weight-HBM-bound, and the fused kernel's
#                    small x row slab trivially fits VMEM; tiny M tile, wide
#                    N×K tiles stream the weight matrix.
# mixed   (M ≤ 512): single-kernel fused, balanced tiles.
# prefill (M > 512): two-kernel chain — at these M the GEMM is MXU-bound,
#                    fusion saves bytes but no latency, and the (BM, K) f32
#                    row slab would crowd VMEM at large K.
_BLOCK_TABLE = {
    "decode": dict(path="fused", bm=16, bn=256, bk=512),
    "mixed": dict(path="fused", bm=128, bn=128, bk=256),
    "prefill": dict(path="chained", bm=256, bn=256, bk=256),
}

_KERNEL_PATHS = ("fused", "chained", "unfused")

# Measured winners loaded from results/block_table.json (autotune sweep);
# overlays the analytic defaults above.  Populated by load_block_table().
_MEASURED_TABLE: dict = {}


def load_block_table(path) -> dict:
    """Overlay measured autotune winners (benchmarks/autotune_blocks.py →
    results/block_table.json) onto the analytic block table.  Each entry is
    {"regime": {"path": ..., "bm": ..., "bn": ..., "bk": ...}}."""
    table = json.loads(Path(path).read_text())
    for regime, entry in table.items():
        if regime not in _BLOCK_TABLE:
            raise ValueError(
                f"unknown regime {regime!r} in block table {path}; "
                f"expected one of {sorted(_BLOCK_TABLE)}")
        if entry.get("path") not in _KERNEL_PATHS:
            raise ValueError(
                f"unknown kernel path {entry.get('path')!r} for regime "
                f"{regime!r}; expected one of {_KERNEL_PATHS}")
        missing = {"bm", "bn", "bk"} - set(entry)
        if missing:
            raise ValueError(f"regime {regime!r} missing keys {missing}")
    _MEASURED_TABLE.clear()
    _MEASURED_TABLE.update(table)
    return table


def reset_block_table():
    """Drop any loaded measured winners; back to the analytic defaults."""
    _MEASURED_TABLE.clear()


def gemm_regime(m: int) -> str:
    if m <= 32:
        return "decode"
    if m <= 512:
        return "mixed"
    return "prefill"


def select_plan(m: int, k: int, n: int, r: int = 0, regime: str = None):
    """Execution plan (path, BM, BN, BK) for a (M, K, N, R) problem.

    ``regime`` overrides the M-derived serving regime; unknown strings raise.
    Blocks are clamped to the actual dims; large ranks shrink BN so the U
    tile + f32 accumulator stay within VMEM."""
    if regime is None:
        regime = gemm_regime(m)
    elif regime not in _BLOCK_TABLE:
        raise ValueError(f"unknown regime {regime!r}; "
                         f"expected one of {sorted(_BLOCK_TABLE)}")
    entry = _MEASURED_TABLE.get(regime, _BLOCK_TABLE[regime])
    bm = min(entry["bm"], _round_pow2(max(m, 8)))
    bn = min(entry["bn"], _round_pow2(max(n, 8)))
    bk = min(entry["bk"], _round_pow2(max(k, 8)))
    if r >= 512:
        bn = min(bn, 128)
    return entry["path"], bm, bn, bk


def select_blocks(m: int, k: int, n: int, r: int = 0, regime: str = None):
    """(BM, BN, BK) for a (M, K, N, R) problem (see :func:`select_plan`).
    Unknown ``regime`` strings raise ValueError."""
    return select_plan(m, k, n, r, regime=regime)[1:]


def _fused_vmem_bytes(bm: int, k: int, k_pad: int, bn: int, r: int) -> int:
    """Worst-case VMEM working set of the single-kernel fused path."""
    return (
        bm * k * 4          # x row slab (f32 upper bound)
        + bm * k_pad        # xq int8 scratch residency
        + bm * 4            # sx
        + k * r * 4         # V, whole
        + (k_pad // 2) * bn  # packed-weight column slab
        + bn * 4            # sw
        + bn * r * 4        # U tile
        + bm * r * 4        # xv scratch
        + 2 * bm * bn * 4   # out tile + int32 accumulator
    )


# ---------------------------------------------------------------------------
# single-kernel wrappers
# ---------------------------------------------------------------------------


def act_quant(x: jnp.ndarray, spec: QuantSpec, bm: int = 128):
    """Per-token activation quantization. x: (M, K) -> (q int8, s (M,1))."""
    assert spec.group_size is None, "kernel path: per-token scales only"
    xp, m = _pad_to(x, bm, 0)
    q, s = act_quant_kernel(
        xp, bits=spec.bits, clip_ratio=spec.clip_ratio, bm=bm,
        interpret=_interpret(),
    )
    return q[:m], s[:m]


def fwht(x: jnp.ndarray, bm: int = 256):
    xp, m = _pad_to(x, bm, 0)
    return fwht_kernel(xp, bm=bm, interpret=_interpret())[:m]


def fused_prologue(x: jnp.ndarray, v, spec: QuantSpec,
                   rotate: bool = False, bm: int = 128):
    """Single-HBM-pass activation prologue: optional WHT rotation, per-token
    quantization, and the (x·V) projection, from one row-tile read of x.

    x: (M, K); v: (K, R) or None.  Returns (xq, sx, xv-or-None)."""
    assert spec.group_size is None, "kernel path: per-token scales only"
    xp, m = _pad_to(x, bm, 0)
    q, s, xv = fused_prologue_kernel(
        xp, None if v is None else jnp.asarray(v, jnp.float32),
        bits=spec.bits, clip_ratio=spec.clip_ratio, rotate=rotate, bm=bm,
        interpret=_interpret(),
    )
    return q[:m], s[:m], None if xv is None else xv[:m]


# ---------------------------------------------------------------------------
# W4A4 + LRC forward (fused / chained / unfused)
# ---------------------------------------------------------------------------


def _pad_gemm_operands(xq, sx, wpacked, w_scale, u, xv, bm, bn, bk):
    """Zero-pad every GEMM operand to its block multiple.  Zero weight
    nibbles/scales/U-rows contribute nothing, so padded K/N columns are exact;
    padded M rows are sliced off the output."""
    xqp, _ = _pad_to(xq, bm, 0)
    xqp, _ = _pad_to(xqp, bk, 1)
    sxp, _ = _pad_to(sx, bm, 0)
    wp, _ = _pad_to(wpacked, bk // 2, 0)  # K//2 rows
    wp, _ = _pad_to(wp, bn, 1)
    sw, _ = _pad_to(w_scale.reshape(1, -1), bn, 1)
    if u is not None:
        u, _ = _pad_to(jnp.asarray(u, jnp.float32), bn, 0)
        xv, _ = _pad_to(xv, bm, 0)
    return xqp, sxp, wp, sw, u, xv


def _project_tiles(xr, v, bm: int):
    """(x·V) for the unfused fallback, computed per (bm, K) row tile with the
    exact dot the in-kernel prologue issues — keeps the three paths bitwise
    identical (a single whole-M dot may schedule its K reduction differently
    from the kernels' per-tile dots)."""
    tiles = [
        jax.lax.dot_general(
            xr[t:t + bm].astype(jnp.float32), v,
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        )
        for t in range(0, xr.shape[0], bm)
    ]
    return tiles[0] if len(tiles) == 1 else jnp.concatenate(tiles, axis=0)


def _forward_fused(xp, wpacked, w_scale, u, v, act_spec, rotate, bm, bn, bk):
    """Single-kernel path: pad the weight-side operands, hand the UNPADDED-K
    activations to kernels/fused_gemm.py (the in-kernel prologue must not see
    pad columns), emit the output straight from the one pallas call."""
    wp, _ = _pad_to(wpacked, bk // 2, 0)
    wp, _ = _pad_to(wp, bn, 1)
    sw, _ = _pad_to(w_scale.reshape(1, -1), bn, 1)
    up = None
    if v is not None:
        up, _ = _pad_to(jnp.asarray(u, jnp.float32), bn, 0)
        v = jnp.asarray(v, jnp.float32)
    return fused_w4a4_lrc_kernel(
        xp, v, wp, sw, up,
        bits=act_spec.bits, clip_ratio=act_spec.clip_ratio, rotate=rotate,
        bm=bm, bn=bn, bk=bk, interpret=_interpret(),
    )


def w4a4_lrc_forward(
    x: jnp.ndarray,  # (M, K) float
    wpacked: jnp.ndarray,  # (K//2, N) uint8
    w_scale: jnp.ndarray,  # (N,)
    u,  # (N, R) or None
    v,  # (K, R) or None
    act_spec: QuantSpec,
    rotate: bool = False,
    blocks=None,  # optional (bm, bn, bk) override; default: autotune table
    impl: str = "auto",  # auto | fused | chained | unfused
):
    """The full W4A4+LRC serving hot path.

    ``impl="auto"`` follows the block-table plan with VMEM-feasibility
    demotion: single-kernel fused (xq never touches HBM) when the working
    set fits, else the two-kernel prologue → GEMM chain, else (V past the
    prologue budget) the unfused three-pass chain.  Explicit ``impl`` values
    force a path — "fused"/"chained" trust the caller on VMEM fit.

    ``rotate`` applies the online Walsh-Hadamard rotation (K power of two)
    inside the prologue.  All operands are zero-padded to block multiples, so
    arbitrary M/K/N (odd MLP widths included) take the pallas path.  The
    three paths are bitwise identical in interpret mode (shared row bodies,
    exact integer accumulation).
    """
    m0, k = x.shape
    n = wpacked.shape[1]
    r = 0 if v is None else v.shape[-1]
    path, bm, bn, bk = select_plan(m0, k, n, r)
    if blocks is not None:
        bm, bn, bk = blocks

    if impl != "auto":
        if impl not in _KERNEL_PATHS:
            raise ValueError(f"unknown impl {impl!r}; "
                             f"expected auto or one of {_KERNEL_PATHS}")
        path = impl
    else:
        v_fits = r == 0 or (k * r * 4) <= _PROLOGUE_V_BYTES_MAX
        k_pad = k + (-k) % bk
        if path == "fused" and not (
                v_fits
                and _fused_vmem_bytes(bm, k, k_pad, bn, r)
                <= _FUSED_VMEM_BYTES_MAX):
            path = "chained"
        if path == "chained" and not v_fits:
            path = "unfused"

    if rotate:
        assert k & (k - 1) == 0, \
            f"online rotation needs power-of-two K, got {k}"
    assert act_spec.group_size is None, "kernel path: per-token scales only"
    # run the prologue on the M-padded activations directly — its outputs
    # stay bm-aligned so the GEMM padding below never re-pads axis 0
    xp, _ = _pad_to(x, bm, 0)

    if path == "fused":
        out = _forward_fused(xp, wpacked, w_scale, u if r else None,
                             v if r else None, act_spec, rotate, bm, bn, bk)
        return out[:m0, :n]

    if path == "chained":
        xq, sx, xv = fused_prologue_kernel(
            xp, jnp.asarray(v, jnp.float32) if r else None,
            bits=act_spec.bits, clip_ratio=act_spec.clip_ratio,
            rotate=rotate, bm=bm, interpret=_interpret(),
        )
    else:  # unfused: three activation passes (V too large for VMEM residency)
        xr = fwht(xp, bm=bm) if rotate else xp
        xq, sx = act_quant(xr, act_spec, bm=bm)
        xv = _project_tiles(xr, jnp.asarray(v, jnp.float32), bm) if r else None

    xqp, sxp, wp, sw, up, xvp = _pad_gemm_operands(
        xq, sx, wpacked, w_scale, u if r else None, xv, bm, bn, bk)
    out = w4a4_lowrank_matmul_kernel(
        xqp, sxp, wp, sw, xvp, up,
        bm=bm, bn=bn, bk=bk, interpret=_interpret(),
    )
    return out[:m0, :n]


def w4a4_lowrank_matmul(
    x: jnp.ndarray,
    wpacked: jnp.ndarray,
    w_scale: jnp.ndarray,
    u,
    v,
    act_spec: QuantSpec,
    bm: int = None,
    bn: int = None,
    bk: int = None,
):
    """Back-compat alias for :func:`w4a4_lrc_forward` (no online rotation)."""
    blocks = None
    if bm is not None or bn is not None or bk is not None:
        m0, k = x.shape
        n = wpacked.shape[1]
        r = 0 if v is None else v.shape[-1]
        dbm, dbn, dbk = select_blocks(m0, k, n, r)
        blocks = (bm or dbm, bn or dbn, bk or dbk)
    return w4a4_lrc_forward(x, wpacked, w_scale, u, v, act_spec, blocks=blocks)


def flash_attention(q, k, v, scale: float, causal: bool = True,
                    bq: int = 128, bkv: int = 128):
    """GQA flash attention. q: (B, Sq, H, D); k/v: (B, Skv, KH, D[v]).
    Folds batch×head, repeats KV heads across their query group."""
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, k.shape[1], d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, v.shape[1], v.shape[-1])
    bq = min(bq, sq)
    bkv = min(bkv, k.shape[1])
    out = flash_attention_kernel(qf, kf, vf, scale, causal=causal,
                                 bq=bq, bkv=bkv, interpret=_interpret())
    return out.reshape(b, h, sq, -1).transpose(0, 2, 1, 3)
