"""jit'd public wrappers around the Pallas kernels.

Responsibilities: shape padding to block multiples (weights, scales and the
low-rank factors are zero-padded, so odd MLP widths never crash the pallas
path), execution-plan selection per serving regime (decode / mixed /
prefill) — kernel path AND (BM, BN, BK, BR) tiles, overridable from a
measured ``results/block_table.json`` via :func:`load_block_table` —,
per-slab VMEM feasibility (tiles shrink to fit the budget before the path
ever demotes), interpret-mode selection (interpret=True on CPU — validates
the kernel bodies; compiled Mosaic on real TPU), and the end-to-end entry
``w4a4_lrc_forward`` used by ``QLinear(impl="pallas"/"fused")`` and the
serving engine.

Three kernel paths, strongest fusion first:

  fused   — ONE pallas kernel (kernels/fused_gemm.py): K-split (M, N,
            K-chunk, R-tile) grid; the activation prologue sweeps the
            K-chunks on each M-tile's first N visit, the int4 GEMM
            partial-sums across the same chunks, and V/W stream per chunk —
            no operand slab is whole in VMEM and xq never touches HBM.
            Two prologue variants: "resident" (f32 row slab in scratch, one
            x read; required for rotation) and "streamed" (no slab, one
            extra x read).
  chained — TWO kernels (prologue → w4a4 GEMM); xq/sx/xv make one HBM
            round-trip between them.  V streams in (bk, br) tiles here too.
  unfused — three activation passes (rotate, quantize, tiled project) + the
            GEMM kernel.  Final fallback when even the prologue kernel's
            row slab cannot fit.

All three are bitwise identical in interpret mode: they share the row bodies
in kernels/rowops.py (including the canonical K-chunked/R-tiled projection
accumulation order) and integer accumulation is exact under any K split.

VMEM budgets default to the module constants below; override them at
runtime via :func:`set_vmem_budgets`, a ``"vmem"`` entry in the block-table
JSON, or the serve CLI's ``--vmem-budget`` flag (so autotune on real TPUs
can probe them).
"""

from __future__ import annotations

import json
from typing import NamedTuple, Optional
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core.quantizers import QuantSpec
from repro.kernels.actquant import act_quant_kernel
from repro.kernels.fused_gemm import fused_w4a4_lrc_kernel
from repro.kernels.hadamard import fwht_kernel
from repro.kernels.prologue import fused_prologue_kernel
from repro.kernels.rowops import (default_proj_tiles, project_rows_tiled,
                                  round_pow2 as _round_pow2)
from repro.kernels.w4a4 import w4a4_lowrank_matmul_kernel
from repro.kernels.flash_attn import flash_attention_kernel

# Default working-set budget of the two-kernel chain's prologue (x row slab
# + rotated-row scratch + xq/sx/xv outputs + double-buffered V tiles).
# Historically this was the ceiling on a WHOLE-VMEM V; V now streams in
# (bk, br) tiles, so the budget gates the row slab instead and the 8 MB
# figure keeps the same "three quarters of a useful VMEM half" intent.
_PROLOGUE_V_BYTES_MAX = 8 * 1024 * 1024

# Default working-set ceiling for the single-kernel fused path (resident
# scratch + double-buffered streamed blocks).  ~¾ of a v5e core's 16 MB
# VMEM, leaving room for Mosaic's pipelining overheads.  Tiles shrink to
# fit this before the path demotes (see _fit_fused).
_FUSED_VMEM_BYTES_MAX = 12 * 1024 * 1024

# Runtime overrides for the two budgets (set_vmem_budgets / block-table
# "vmem" entry / serve --vmem-budget).  Empty -> the module constants above
# (which tests may monkeypatch directly).
_VMEM_OVERRIDES: dict = {}


def set_vmem_budgets(fused: int = None, prologue: int = None):
    """Override the VMEM working-set budgets (bytes) used by plan
    resolution.  ``None`` leaves a budget at its current default."""
    for key, val in (("fused", fused), ("prologue", prologue)):
        if val is None:
            continue
        if not isinstance(val, int) or isinstance(val, bool) or val < 0:
            raise ValueError(f"{key} VMEM budget must be a non-negative "
                             f"int of bytes, got {val!r}")
        _VMEM_OVERRIDES[key] = val


def fused_vmem_budget() -> int:
    return _VMEM_OVERRIDES.get("fused", _FUSED_VMEM_BYTES_MAX)


def prologue_vmem_budget() -> int:
    return _VMEM_OVERRIDES.get("prologue", _PROLOGUE_V_BYTES_MAX)


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


# ---------------------------------------------------------------------------
# execution-plan autotune table (kernel path + block sizes)
# ---------------------------------------------------------------------------

# Regime-keyed execution plans: the kernel path plus (BM, BN, BK, BR) tiles.
# decode  (M ≤ 32):  single-kernel fused — the decode hot path is
#                    activation+weight-HBM-bound; tiny M tile, wide N×K
#                    tiles stream the weight matrix.
# mixed   (M ≤ 512): single-kernel fused, balanced tiles.
# prefill (M > 512): single-kernel fused as well since the K-split grid —
#                    the (BM, K) f32 row slab that used to crowd VMEM now
#                    either fits (resident) or is traded for one extra x
#                    read (streamed); the GEMM is MXU-bound at these M, and
#                    fused ≤ chained on activation bytes at every M.
_BLOCK_TABLE = {
    "decode": dict(path="fused", bm=16, bn=256, bk=512, br=512),
    "mixed": dict(path="fused", bm=128, bn=128, bk=256, br=512),
    "prefill": dict(path="fused", bm=256, bn=256, bk=256, br=512),
}

_KERNEL_PATHS = ("fused", "chained", "unfused")

# Measured winners loaded from results/block_table.json (autotune sweep);
# overlays the analytic defaults above.  Populated by load_block_table().
_MEASURED_TABLE: dict = {}

_TILE_DIMS_REQUIRED = ("bm", "bn", "bk")
_TILE_DIMS_ALL = ("bm", "bn", "bk", "br")
_VMEM_KEYS = ("fused_bytes_max", "prologue_bytes_max")


def _validate_entry(regime: str, entry, path) -> None:
    if not isinstance(entry, dict):
        raise ValueError(f"regime {regime!r} in block table {path} must map "
                         f"to an object, got {type(entry).__name__}")
    if entry.get("path") not in _KERNEL_PATHS:
        raise ValueError(
            f"unknown kernel path {entry.get('path')!r} for regime "
            f"{regime!r}; expected one of {_KERNEL_PATHS}")
    missing = set(_TILE_DIMS_REQUIRED) - set(entry)
    if missing:
        raise ValueError(f"regime {regime!r} missing keys {missing}")
    for dim in _TILE_DIMS_ALL:
        if dim not in entry:
            continue  # br is optional (pre-K-split tables)
        val = entry[dim]
        if not isinstance(val, int) or isinstance(val, bool) or val <= 0:
            raise ValueError(
                f"regime {regime!r} tile dim {dim!r} must be a positive "
                f"integer, got {val!r}")


def load_block_table(path) -> dict:
    """Overlay measured autotune winners (benchmarks/autotune_blocks.py →
    results/block_table.json) onto the analytic block table.  Each entry is
    {"regime": {"path": ..., "bm": ..., "bn": ..., "bk": ..., "br": ...}}
    (``br`` optional — pre-K-split tables stay loadable).  A reserved
    top-level ``"vmem"`` entry {"fused_bytes_max": ..,
    "prologue_bytes_max": ..} overrides the VMEM budgets.  Malformed tables
    raise ValueError and leave no partial state behind."""
    try:
        table = json.loads(Path(path).read_text())
    except json.JSONDecodeError as e:
        raise ValueError(f"block table {path} is not valid JSON: {e}") from e
    if not isinstance(table, dict):
        raise ValueError(f"block table {path} must be a JSON object, got "
                         f"{type(table).__name__}")
    vmem = table.get("vmem", {})
    if not isinstance(vmem, dict):
        raise ValueError(f"'vmem' entry in block table {path} must be an "
                         f"object, got {type(vmem).__name__}")
    unknown = set(vmem) - set(_VMEM_KEYS)
    if unknown:
        raise ValueError(f"unknown vmem budget keys {sorted(unknown)} in "
                         f"block table {path}; expected {_VMEM_KEYS}")
    for key, val in vmem.items():
        if not isinstance(val, int) or isinstance(val, bool) or val <= 0:
            raise ValueError(f"vmem budget {key!r} must be a positive int "
                             f"of bytes, got {val!r}")
    for regime, entry in table.items():
        if regime == "vmem":
            continue
        if regime not in _BLOCK_TABLE:
            raise ValueError(
                f"unknown regime {regime!r} in block table {path}; "
                f"expected one of {sorted(_BLOCK_TABLE)}")
        _validate_entry(regime, entry, path)
    _MEASURED_TABLE.clear()
    _MEASURED_TABLE.update({k: v for k, v in table.items() if k != "vmem"})
    set_vmem_budgets(fused=vmem.get("fused_bytes_max"),
                     prologue=vmem.get("prologue_bytes_max"))
    return table


def reset_block_table():
    """Drop any loaded measured winners and VMEM-budget overrides; back to
    the analytic defaults."""
    _MEASURED_TABLE.clear()
    _VMEM_OVERRIDES.clear()


def gemm_regime(m: int) -> str:
    if m <= 32:
        return "decode"
    if m <= 512:
        return "mixed"
    return "prefill"


def select_plan(m: int, k: int, n: int, r: int = 0, regime: str = None):
    """Table execution plan (path, BM, BN, BK, BR) for a (M, K, N, R)
    problem — no VMEM feasibility applied (see :func:`resolve_plan`).

    ``regime`` overrides the M-derived serving regime; unknown strings raise.
    Blocks are clamped to the actual dims; large ranks shrink BN so the U
    tile + f32 accumulator stay within VMEM."""
    if regime is None:
        regime = gemm_regime(m)
    elif regime not in _BLOCK_TABLE:
        raise ValueError(f"unknown regime {regime!r}; "
                         f"expected one of {sorted(_BLOCK_TABLE)}")
    entry = _MEASURED_TABLE.get(regime, _BLOCK_TABLE[regime])
    bm = min(entry["bm"], _round_pow2(max(m, 8)))
    bn = min(entry["bn"], _round_pow2(max(n, 8)))
    bk = min(entry["bk"], _round_pow2(max(k, 8)))
    if "br" in entry:
        br = min(entry["br"], _round_pow2(max(r, 8)))
    else:  # pre-K-split tables: the shared kernel default
        br = default_proj_tiles(k, r)[1]
    if r >= 512:
        bn = min(bn, 128)
    return entry["path"], bm, bn, bk, br


def select_blocks(m: int, k: int, n: int, r: int = 0, regime: str = None):
    """(BM, BN, BK, BR) for a (M, K, N, R) problem (see :func:`select_plan`).
    Unknown ``regime`` strings raise ValueError."""
    return select_plan(m, k, n, r, regime=regime)[1:]


# ---------------------------------------------------------------------------
# per-slab VMEM feasibility: shrink tiles to fit, demote only when nothing
# fits
# ---------------------------------------------------------------------------


class Plan(NamedTuple):
    """A resolved execution plan: kernel path, tile dims, and (fused only)
    the prologue variant ("resident" | "streamed")."""
    path: str
    bm: int
    bn: int
    bk: int
    br: int
    variant: Optional[str] = None


def _fused_vmem_bytes(k: int, r: int, bm: int, bn: int, bk: int, br: int,
                      resident: bool) -> int:
    """Worst-case VMEM working set of the K-split fused kernel: resident
    scratch plus double-buffered streamed blocks."""
    k_pad = k + (-k) % bk
    r_pad = (r + (-r) % br) if r else 0
    res = (
        bm * k_pad          # xq int8 residency
        + bm * 4            # sx
        + bm * bn * 4       # int32 GEMM accumulator
    )
    if r:
        res += bm * r_pad * 4  # xv accumulator
    if resident:
        res += bm * k_pad * 4  # f32 (rotated) row slab
    stream = (
        bm * bk * 4         # x chunk (f32 upper bound)
        + (bk // 2) * bn    # packed-weight chunk
        + bn * 4            # sw
        + bm * bn * 4       # out tile
    )
    if r:
        stream += bk * br * 4 + bn * r_pad * 4  # V tile + U slab
    return res + 2 * stream


def _prologue_vmem_bytes(k: int, r: int, bm: int, bk: int, br: int,
                         rotate: bool) -> int:
    """Working set of the standalone (chained-path) prologue kernel: the x
    row slab, the rotated-row scratch, the xq/sx/xv outputs and the
    double-buffered streamed V tiles."""
    k_pad = k + (-k) % bk if r else k
    r_pad = (r + (-r) % br) if r else 0
    b = bm * k_pad * 4 + bm * k_pad + bm * 4  # x slab + q out + s out
    if rotate:
        b += bm * k_pad * 4  # rotated-row scratch
    if r:
        b += bm * r_pad * 4 + 2 * (bk * br * 4)  # xv out + V tiles
    return b


def _shrink_to_fit(bytes_fn, tiles: dict, mins: dict, budget: int):
    """Greedily halve tile dims (largest byte saving first, deterministic
    tie-break in ``mins`` key order) until ``bytes_fn(**tiles)`` fits
    ``budget``.  Returns the fitted tiles dict or None."""
    tiles = dict(tiles)
    while bytes_fn(**tiles) > budget:
        best = None
        for dim in mins:
            if tiles[dim] // 2 < mins[dim]:
                continue
            cand = dict(tiles)
            cand[dim] //= 2
            got = bytes_fn(**cand)
            if best is None or got < best[0]:
                best = (got, dim)
        if best is None:
            return None
        tiles[best[1]] //= 2
    return tiles


def _fit_fused(k: int, r: int, bm: int, bn: int, bk: int, br: int,
               rotate: bool, budget: int):
    """Feasible (bm, bn, bk, br, variant) for the fused kernel under
    ``budget``, shrinking tiles as needed; None when nothing fits.  The
    resident prologue is preferred (one x read); the streamed variant
    (rotate=False only) trades an extra x read for dropping the f32 row
    slab."""
    mins = dict(bk=min(bk, 128), br=min(br, 128), bn=min(bn, 128),
                bm=min(bm, 8))
    variants = ("resident",) if rotate else ("resident", "streamed")
    for variant in variants:
        def bytes_fn(bm, bn, bk, br, _res=(variant == "resident")):
            return _fused_vmem_bytes(k, r, bm, bn, bk, br, _res)
        fit = _shrink_to_fit(bytes_fn, dict(bm=bm, bn=bn, bk=bk, br=br),
                             mins, budget)
        if fit is not None:
            return Plan("fused", fit["bm"], fit["bn"], fit["bk"], fit["br"],
                        variant)
    return None


def _fit_chained(k: int, r: int, bm: int, bn: int, bk: int, br: int,
                 rotate: bool, budget: int):
    """Feasible chained-path plan under the prologue budget, or None."""
    mins = dict(bk=min(bk, 128), br=min(br, 128), bm=min(bm, 8))

    def bytes_fn(bm, bk, br):
        return _prologue_vmem_bytes(k, r, bm, bk, br, rotate)

    fit = _shrink_to_fit(bytes_fn, dict(bm=bm, bk=bk, br=br), mins, budget)
    if fit is None:
        return None
    return Plan("chained", fit["bm"], bn, fit["bk"], fit["br"], None)


def fused_variant(k: int, r: int, bm: int, bn: int, bk: int, br: int,
                  rotate: bool) -> str:
    """Prologue variant for FORCED-fused execution at fixed tiles: resident
    when it fits the budget (or rotation requires it), else streamed."""
    if rotate:
        return "resident"
    if _fused_vmem_bytes(k, r, bm, bn, bk, br, True) <= fused_vmem_budget():
        return "resident"
    return "streamed"


def resolve_plan(m: int, k: int, n: int, r: int = 0, rotate: bool = False,
                 regime: str = None) -> Plan:
    """The executable plan for a (M, K, N, R) problem: the block-table plan
    with per-slab VMEM feasibility applied — tiles shrink to fit the budget
    first; the path demotes (fused → chained → unfused) only when no tiling
    fits."""
    path, bm, bn, bk, br = select_plan(m, k, n, r, regime=regime)
    if path == "fused":
        plan = _fit_fused(k, r, bm, bn, bk, br, rotate, fused_vmem_budget())
        if plan is not None:
            return plan
        path = "chained"
    if path == "chained":
        plan = _fit_chained(k, r, bm, bn, bk, br, rotate,
                            prologue_vmem_budget())
        if plan is not None:
            return plan
    return Plan("unfused", bm, bn, bk, br, None)


# ---------------------------------------------------------------------------
# single-kernel wrappers
# ---------------------------------------------------------------------------


def act_quant(x: jnp.ndarray, spec: QuantSpec, bm: int = 128):
    """Per-token activation quantization. x: (M, K) -> (q int8, s (M,1))."""
    assert spec.group_size is None, "kernel path: per-token scales only"
    xp, m = _pad_to(x, bm, 0)
    q, s = act_quant_kernel(
        xp, bits=spec.bits, clip_ratio=spec.clip_ratio, bm=bm,
        interpret=_interpret(),
    )
    return q[:m], s[:m]


def fwht(x: jnp.ndarray, bm: int = 256):
    xp, m = _pad_to(x, bm, 0)
    return fwht_kernel(xp, bm=bm, interpret=_interpret())[:m]


def fused_prologue(x: jnp.ndarray, v, spec: QuantSpec,
                   rotate: bool = False, bm: int = 128,
                   bk: int = None, br: int = None):
    """Single-HBM-pass activation prologue: optional WHT rotation, per-token
    quantization, and the (x·V) projection, from one row-tile read of x.
    V streams in (bk, br) tiles — it is never whole in VMEM.

    x: (M, K); v: (K, R) or None.  Returns (xq, sx, xv-or-None)."""
    assert spec.group_size is None, "kernel path: per-token scales only"
    xp, m = _pad_to(x, bm, 0)
    q, s, xv = fused_prologue_kernel(
        xp, None if v is None else jnp.asarray(v, jnp.float32),
        bits=spec.bits, clip_ratio=spec.clip_ratio, rotate=rotate, bm=bm,
        bk=bk, br=br, interpret=_interpret(),
    )
    return q[:m], s[:m], None if xv is None else xv[:m]


# ---------------------------------------------------------------------------
# W4A4 + LRC forward (fused / chained / unfused)
# ---------------------------------------------------------------------------


def _pad_gemm_operands(xq, sx, wpacked, w_scale, u, xv, bm, bn, bk, br):
    """Zero-pad every GEMM operand to its block multiple.  Zero weight
    nibbles/scales/U-rows contribute nothing, so padded K/N/R columns are
    exact; padded M rows are sliced off the output."""
    xqp, _ = _pad_to(xq, bm, 0)
    xqp, _ = _pad_to(xqp, bk, 1)
    sxp, _ = _pad_to(sx, bm, 0)
    wp, _ = _pad_to(wpacked, bk // 2, 0)  # K//2 rows
    wp, _ = _pad_to(wp, bn, 1)
    sw, _ = _pad_to(w_scale.reshape(1, -1), bn, 1)
    if u is not None:
        u, _ = _pad_to(jnp.asarray(u, jnp.float32), bn, 0)
        u, _ = _pad_to(u, br, 1)  # R-tile multiple: same epilogue dot shape
        xv, _ = _pad_to(xv, bm, 0)
        xv, _ = _pad_to(xv, br, 1)
    return xqp, sxp, wp, sw, u, xv


def _project_tiles(xr, v, bm: int, bk: int, br: int):
    """(x·V) for the unfused fallback, computed per (bm, K) row tile with
    EXACTLY the K-chunked/R-tiled accumulation the kernels issue
    (rowops.project_rows_tiled) — keeps the three paths bitwise identical.
    Returns the (M, r_pad) projection (padded R columns are exact zeros)."""
    k = xr.shape[1]
    k_pad = k + (-k) % bk
    r = v.shape[1]
    r_pad = r + (-r) % br
    xrp = jnp.pad(xr.astype(jnp.float32), ((0, 0), (0, k_pad - k)))
    vp = jnp.pad(jnp.asarray(v, jnp.float32),
                 ((0, k_pad - k), (0, r_pad - r)))
    tiles = [
        project_rows_tiled(xrp[t:t + bm], vp, bk, br)
        for t in range(0, xr.shape[0], bm)
    ]
    return tiles[0] if len(tiles) == 1 else jnp.concatenate(tiles, axis=0)


def _forward_fused(xp, wpacked, w_scale, u, v, act_spec, rotate,
                   bm, bn, bk, br, variant):
    """Single-kernel path: pad the weight-side operands, hand the UNPADDED-K
    activations to kernels/fused_gemm.py (the in-kernel prologue must not see
    pad columns), emit the output straight from the one pallas call."""
    wp, _ = _pad_to(wpacked, bk // 2, 0)
    wp, _ = _pad_to(wp, bn, 1)
    sw, _ = _pad_to(w_scale.reshape(1, -1), bn, 1)
    up = None
    if v is not None:
        up, _ = _pad_to(jnp.asarray(u, jnp.float32), bn, 0)
        v = jnp.asarray(v, jnp.float32)
    return fused_w4a4_lrc_kernel(
        xp, v, wp, sw, up,
        bits=act_spec.bits, clip_ratio=act_spec.clip_ratio, rotate=rotate,
        bm=bm, bn=bn, bk=bk, br=br, variant=variant, interpret=_interpret(),
    )


def w4a4_lrc_forward(
    x: jnp.ndarray,  # (M, K) float
    wpacked: jnp.ndarray,  # (K//2, N) uint8
    w_scale: jnp.ndarray,  # (N,)
    u,  # (N, R) or None
    v,  # (K, R) or None
    act_spec: QuantSpec,
    rotate: bool = False,
    blocks=None,  # optional (bm, bn, bk[, br]) override; default: plan table
    impl: str = "auto",  # auto | fused | chained | unfused
):
    """The full W4A4+LRC serving hot path.

    ``impl="auto"`` follows the block-table plan with per-slab VMEM
    feasibility (:func:`resolve_plan`): the K-split fused kernel's tiles
    shrink to fit the budget before the path ever demotes, so fused serves
    every regime and rank unless nothing fits; then the two-kernel
    prologue → GEMM chain (V streamed); then the unfused three-pass chain.
    Explicit ``impl`` values force a path — "fused"/"chained" trust the
    caller on VMEM fit.

    ``rotate`` applies the online Walsh-Hadamard rotation (K power of two)
    inside the prologue.  All operands are zero-padded to block multiples, so
    arbitrary M/K/N (odd MLP widths included) take the pallas path.  The
    three paths are bitwise identical in interpret mode (shared row bodies,
    shared K-chunk/R-tile accumulation order, exact integer accumulation).
    """
    m0, k = x.shape
    n = wpacked.shape[1]
    r = 0 if v is None else v.shape[-1]

    variant = None
    if impl == "auto":
        path, bm, bn, bk, br, variant = resolve_plan(m0, k, n, r,
                                                     rotate=rotate)
    elif impl not in _KERNEL_PATHS:
        raise ValueError(f"unknown impl {impl!r}; "
                         f"expected auto or one of {_KERNEL_PATHS}")
    else:
        path = impl
        _, bm, bn, bk, br = select_plan(m0, k, n, r)
    if blocks is not None:
        bm, bn, bk = blocks[:3]
        if len(blocks) > 3:
            br = blocks[3]
        br = min(br, _round_pow2(max(r, 8)))
        variant = None
    if path == "fused" and variant is None:
        variant = fused_variant(k, r, bm, bn, bk, br, rotate)

    if rotate:
        assert k & (k - 1) == 0, \
            f"online rotation needs power-of-two K, got {k}"
    assert act_spec.group_size is None, "kernel path: per-token scales only"
    # run the prologue on the M-padded activations directly — its outputs
    # stay bm-aligned so the GEMM padding below never re-pads axis 0
    xp, _ = _pad_to(x, bm, 0)

    if path == "fused":
        out = _forward_fused(xp, wpacked, w_scale, u if r else None,
                             v if r else None, act_spec, rotate,
                             bm, bn, bk, br, variant)
        return out[:m0, :n]

    if path == "chained":
        xq, sx, xv = fused_prologue_kernel(
            xp, jnp.asarray(v, jnp.float32) if r else None,
            bits=act_spec.bits, clip_ratio=act_spec.clip_ratio,
            rotate=rotate, bm=bm, bk=bk, br=br, interpret=_interpret(),
        )
    else:  # unfused: three activation passes over the row tiles
        xr = fwht(xp, bm=bm) if rotate else xp
        xq, sx = act_quant(xr, act_spec, bm=bm)
        xv = _project_tiles(xr, v, bm, bk, br) if r else None

    xqp, sxp, wp, sw, up, xvp = _pad_gemm_operands(
        xq, sx, wpacked, w_scale, u if r else None, xv, bm, bn, bk, br)
    out = w4a4_lowrank_matmul_kernel(
        xqp, sxp, wp, sw, xvp, up,
        bm=bm, bn=bn, bk=bk, interpret=_interpret(),
    )
    return out[:m0, :n]


def w4a4_lowrank_matmul(
    x: jnp.ndarray,
    wpacked: jnp.ndarray,
    w_scale: jnp.ndarray,
    u,
    v,
    act_spec: QuantSpec,
    bm: int = None,
    bn: int = None,
    bk: int = None,
):
    """Back-compat alias for :func:`w4a4_lrc_forward` (no online rotation)."""
    blocks = None
    if bm is not None or bn is not None or bk is not None:
        m0, k = x.shape
        n = wpacked.shape[1]
        r = 0 if v is None else v.shape[-1]
        dbm, dbn, dbk, _ = select_blocks(m0, k, n, r)
        blocks = (bm or dbm, bn or dbn, bk or dbk)
    return w4a4_lrc_forward(x, wpacked, w_scale, u, v, act_spec, blocks=blocks)


def flash_attention(q, k, v, scale: float, causal: bool = True,
                    bq: int = 128, bkv: int = 128):
    """GQA flash attention. q: (B, Sq, H, D); k/v: (B, Skv, KH, D[v]).
    Folds batch×head, repeats KV heads across their query group."""
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, k.shape[1], d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, v.shape[1], v.shape[-1])
    bq = min(bq, sq)
    bkv = min(bkv, k.shape[1])
    out = flash_attention_kernel(qf, kf, vf, scale, causal=causal,
                                 bq=bq, bkv=bkv, interpret=_interpret())
    return out.reshape(b, h, sq, -1).transpose(0, 2, 1, 3)
