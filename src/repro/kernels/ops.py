"""jit'd public wrappers around the Pallas kernels.

Responsibilities: shape padding to block multiples (weights, scales and the
low-rank factors are zero-padded, so odd MLP widths never crash the pallas
path), execution-plan selection per serving regime (decode / mixed /
prefill) — kernel path AND (BM, BN, BK, BR) tiles —, per-slab VMEM
feasibility (tiles shrink to fit the budget before the path ever demotes),
interpret-mode selection (interpret=True on CPU — validates the kernel
bodies; compiled Mosaic on real TPU), and the end-to-end entry
``w4a4_lrc_forward`` used by ``QLinear(impl="pallas"/"fused")`` and the
serving engine.

ALL execution config lives in an explicit, immutable
:class:`~repro.kernels.context.KernelContext` (block table, VMEM budgets,
default impl, interpret flag, per-layer plan overrides) threaded through
every entry point as ``ctx=``.  ``ctx=None`` falls back to the
process-default context (:func:`default_context`), which is itself an
immutable value — two engines holding different contexts never race each
other.  Build contexts with ``KernelContext()``,
``KernelContext.from_json("results/block_table.json")`` and the
``with_*`` builders; introspect plan resolution with ``ctx.explain(...)``.

Three kernel paths, strongest fusion first:

  fused   — ONE pallas kernel (kernels/fused_gemm.py): K-split (M, N,
            K-chunk, R-tile) grid; the activation prologue sweeps the
            K-chunks on each M-tile's first N visit, the int4 GEMM
            partial-sums across the same chunks, and V/W stream per chunk —
            no operand slab is whole in VMEM and xq never touches HBM.
            Two prologue variants: "resident" (f32 row slab in scratch, one
            x read; required for rotation) and "streamed" (no slab, one
            extra x read).
  chained — TWO kernels (prologue → w4a4 GEMM); xq/sx/xv make one HBM
            round-trip between them.  V streams in (bk, br) tiles here too.
  unfused — three activation passes (rotate, quantize, tiled project) + the
            GEMM kernel.  Final fallback when even the prologue kernel's
            row slab cannot fit.

All three are bitwise identical in interpret mode: they share the row bodies
in kernels/rowops.py (including the canonical K-chunked/R-tiled projection
accumulation order) and integer accumulation is exact under any K split.
That parity contract covers BOTH scale granularities: per-token (M, 1)
scales and — when ``act_spec.group_size`` is set (paper Table 2, g = 128) —
the per-group (M, K/g) scale plane, with the plan layer snapping BK to a
multiple of g so K-chunks hold whole scale groups (see
:meth:`KernelContext.resolve_plan`).

The old module-global mutators (``load_block_table`` / ``set_vmem_budgets``)
finished their one-release deprecation window and are GONE — build a
:class:`KernelContext` (``from_json`` / ``with_vmem_budgets``) and pass it
via ``ctx=``.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.quantizers import QuantSpec
from repro.kernels.actquant import act_quant_kernel
from repro.kernels.context import (
    DEFAULT_BLOCK_TABLE,
    FUSED_VMEM_BYTES_MAX,
    KERNEL_PATHS,
    PROLOGUE_V_BYTES_MAX,
    KernelContext,
    Plan,
    fused_vmem_bytes as _fused_vmem_bytes,
    gemm_regime,
    prologue_vmem_bytes as _prologue_vmem_bytes,
)
from repro.kernels.fused_gemm import fused_w4a4_lrc_kernel
from repro.kernels.hadamard import fwht_kernel
from repro.kernels.prologue import fused_prologue_kernel
from repro.kernels.rowops import (project_rows_tiled,
                                  round_pow2 as _round_pow2,
                                  snap_bk_to_group)
from repro.kernels.w4a4 import w4a4_lowrank_matmul_kernel
from repro.kernels.flash_attn import (flash_attention_kernel,
                                      flash_attention_quant_kernel,
                                      paged_flash_attention_kernel,
                                      paged_flash_attention_quant_kernel)

__all__ = [
    "KernelContext", "Plan", "gemm_regime", "default_context",
    "set_default_context", "select_plan", "select_blocks", "resolve_plan",
    "fused_variant", "fused_vmem_budget", "prologue_vmem_budget",
    "w4a4_lrc_forward", "w4a4_lowrank_matmul", "act_quant", "fwht",
    "fused_prologue", "flash_attention", "flash_attention_quant",
    "paged_flash_attention", "paged_flash_attention_quant",
    # process-default reset (alias of set_default_context(None), used by
    # tests and legacy scripts)
    "reset_block_table",
]

# Back-compat aliases for the analytic default constants (immutable).
_FUSED_VMEM_BYTES_MAX = FUSED_VMEM_BYTES_MAX
_PROLOGUE_V_BYTES_MAX = PROLOGUE_V_BYTES_MAX
_KERNEL_PATHS = KERNEL_PATHS

# ---------------------------------------------------------------------------
# process-default context (the ONLY module state; an immutable value swapped
# atomically — set_default_context is the only writer, every reader goes
# through default_context())
# ---------------------------------------------------------------------------

_DEFAULT_CONTEXT: Optional[KernelContext] = None


def default_context() -> KernelContext:
    """The process-default :class:`KernelContext`, used whenever an entry
    point is called with ``ctx=None``.  Prefer constructing and passing an
    explicit context; this exists so zero-config callers keep working."""
    global _DEFAULT_CONTEXT
    if _DEFAULT_CONTEXT is None:
        _DEFAULT_CONTEXT = KernelContext()
    return _DEFAULT_CONTEXT


def set_default_context(ctx: Optional[KernelContext]) -> KernelContext:
    """Swap the process-default context (``None`` resets to the analytic
    defaults).  Returns the PREVIOUS default so callers can restore it."""
    global _DEFAULT_CONTEXT
    prev = default_context()
    if ctx is not None and not isinstance(ctx, KernelContext):
        raise TypeError(f"expected a KernelContext or None, got "
                        f"{type(ctx).__name__}")
    _DEFAULT_CONTEXT = ctx
    return prev


def _ctx(ctx: Optional[KernelContext]) -> KernelContext:
    return default_context() if ctx is None else ctx


def reset_block_table():
    """Reset the process-default context to the analytic defaults.  Alias
    of ``set_default_context(None)`` — note this resets the WHOLE default
    context (block table, budgets, impl, interpret, layer overrides), a
    superset of what the pre-KernelContext version cleared."""
    set_default_context(None)


def fused_vmem_budget(ctx: KernelContext = None) -> int:
    return _ctx(ctx).fused_vmem_bytes


def prologue_vmem_budget(ctx: KernelContext = None) -> int:
    return _ctx(ctx).prologue_vmem_bytes


def _interpret(ctx: KernelContext = None) -> bool:
    return _ctx(ctx).interpret_mode()


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


# ---------------------------------------------------------------------------
# execution-plan selection / resolution (thin wrappers over the context)
# ---------------------------------------------------------------------------


def select_plan(m: int, k: int, n: int, r: int = 0, regime: str = None,
                ctx: KernelContext = None, layer: str = None) -> Plan:
    """Table execution :class:`Plan` for a (M, K, N, R) problem — per-layer
    override merged over the regime entry, no VMEM feasibility applied (see
    :func:`resolve_plan`).  ``regime`` overrides the M-derived serving
    regime; unknown strings raise."""
    return _ctx(ctx).select_plan(m, k, n, r, regime=regime, layer=layer)


def select_blocks(m: int, k: int, n: int, r: int = 0, regime: str = None,
                  ctx: KernelContext = None, layer: str = None) -> Plan:
    """:class:`Plan` for a (M, K, N, R) problem (alias of
    :func:`select_plan`; read the tiles off ``.bm/.bn/.bk/.br``)."""
    return select_plan(m, k, n, r, regime=regime, ctx=ctx, layer=layer)


def resolve_plan(m: int, k: int, n: int, r: int = 0, rotate: bool = False,
                 regime: str = None, ctx: KernelContext = None,
                 layer: str = None, act_group: int = None) -> Plan:
    """The executable :class:`Plan` for a (M, K, N, R) problem: the
    block-table plan with per-slab VMEM feasibility applied — tiles shrink
    to fit the budget first; the path demotes (fused → chained → unfused)
    only when no tiling fits.  ``act_group`` (per-group activation scales)
    snaps BK to a multiple of the group and adds the (M, K/g) scale plane
    to the working-set model."""
    return _ctx(ctx).resolve_plan(m, k, n, r, rotate=rotate, regime=regime,
                                  layer=layer, act_group=act_group)


def fused_variant(k: int, r: int, bm: int, bn: int, bk: int, br: int,
                  rotate: bool, ctx: KernelContext = None,
                  act_group: int = None) -> str:
    """Prologue variant for FORCED-fused execution at fixed tiles: resident
    when it fits the budget (or rotation requires it), else streamed."""
    return _ctx(ctx).fused_variant(k, r, bm, bn, bk, br, rotate,
                                   act_group=act_group)


# ---------------------------------------------------------------------------
# single-kernel wrappers
# ---------------------------------------------------------------------------


def act_quant(x: jnp.ndarray, spec: QuantSpec, bm: int = 128,
              ctx: KernelContext = None):
    """Activation quantization. x: (M, K) -> (q int8, s).  Per-token
    (``spec.group_size`` None) s is (M, 1); per-group it is the
    (M, K // group) scale plane (K must divide into whole groups)."""
    if spec.group_size is not None:
        assert x.shape[-1] % spec.group_size == 0, \
            f"act_group {spec.group_size} must divide K={x.shape[-1]}"
    xp, m = _pad_to(x, bm, 0)
    q, s = act_quant_kernel(
        xp, bits=spec.bits, clip_ratio=spec.clip_ratio, bm=bm,
        group=spec.group_size, interpret=_interpret(ctx),
    )
    return q[:m], s[:m]


def fwht(x: jnp.ndarray, bm: int = 256, ctx: KernelContext = None):
    xp, m = _pad_to(x, bm, 0)
    return fwht_kernel(xp, bm=bm, interpret=_interpret(ctx))[:m]


def fused_prologue(x: jnp.ndarray, v, spec: QuantSpec,
                   rotate: bool = False, bm: int = 128,
                   bk: int = None, br: int = None,
                   ctx: KernelContext = None):
    """Single-HBM-pass activation prologue: optional WHT rotation, per-token
    or per-group quantization, and the (x·V) projection, from one row-tile
    read of x.  V streams in (bk, br) tiles — it is never whole in VMEM.

    x: (M, K); v: (K, R) or None.  Returns (xq, sx, xv-or-None) — sx is
    (M, 1) per-token or the (M, K // group) scale plane."""
    if spec.group_size is not None:
        assert x.shape[-1] % spec.group_size == 0, \
            f"act_group {spec.group_size} must divide K={x.shape[-1]}"
    xp, m = _pad_to(x, bm, 0)
    q, s, xv = fused_prologue_kernel(
        xp, None if v is None else jnp.asarray(v, jnp.float32),
        bits=spec.bits, clip_ratio=spec.clip_ratio, rotate=rotate, bm=bm,
        bk=bk, br=br, act_group=spec.group_size, interpret=_interpret(ctx),
    )
    return q[:m], s[:m], None if xv is None else xv[:m]


# ---------------------------------------------------------------------------
# W4A4 + LRC forward (fused / chained / unfused)
# ---------------------------------------------------------------------------


def _pad_gemm_operands(xq, sx, wpacked, w_scale, u, xv, bm, bn, bk, br,
                       act_group=None):
    """Zero-pad every GEMM operand to its block multiple.  Zero weight
    nibbles/scales/U-rows contribute nothing, so padded K/N/R columns are
    exact; padded M rows are sliced off the output.  With group-wise scales
    the (M, K/g) plane pads along the group axis too — padded groups hold
    only zero xq columns, so their int32 partials are 0 and the rescaled
    term is an exact f32 +0.0 whatever the pad scale value."""
    xqp, _ = _pad_to(xq, bm, 0)
    xqp, _ = _pad_to(xqp, bk, 1)
    sxp, _ = _pad_to(sx, bm, 0)
    if act_group is not None:
        sxp, _ = _pad_to(sxp, bk // act_group, 1)
    wp, _ = _pad_to(wpacked, bk // 2, 0)  # K//2 rows
    wp, _ = _pad_to(wp, bn, 1)
    sw, _ = _pad_to(w_scale.reshape(1, -1), bn, 1)
    if u is not None:
        u, _ = _pad_to(jnp.asarray(u, jnp.float32), bn, 0)
        u, _ = _pad_to(u, br, 1)  # R-tile multiple: same epilogue dot shape
        xv, _ = _pad_to(xv, bm, 0)
        xv, _ = _pad_to(xv, br, 1)
    return xqp, sxp, wp, sw, u, xv


def _project_tiles(xr, v, bm: int, bk: int, br: int):
    """(x·V) for the unfused fallback, computed per (bm, K) row tile with
    EXACTLY the K-chunked/R-tiled accumulation the kernels issue
    (rowops.project_rows_tiled) — keeps the three paths bitwise identical.
    Returns the (M, r_pad) projection (padded R columns are exact zeros)."""
    k = xr.shape[1]
    k_pad = k + (-k) % bk
    r = v.shape[1]
    r_pad = r + (-r) % br
    xrp = jnp.pad(xr.astype(jnp.float32), ((0, 0), (0, k_pad - k)))
    vp = jnp.pad(jnp.asarray(v, jnp.float32),
                 ((0, k_pad - k), (0, r_pad - r)))
    tiles = [
        project_rows_tiled(xrp[t:t + bm], vp, bk, br)
        for t in range(0, xr.shape[0], bm)
    ]
    return tiles[0] if len(tiles) == 1 else jnp.concatenate(tiles, axis=0)


def _forward_fused(xp, wpacked, w_scale, u, v, act_spec, rotate,
                   bm, bn, bk, br, variant, interpret):
    """Single-kernel path: pad the weight-side operands, hand the UNPADDED-K
    activations to kernels/fused_gemm.py (the in-kernel prologue must not see
    pad columns), emit the output straight from the one pallas call."""
    wp, _ = _pad_to(wpacked, bk // 2, 0)
    wp, _ = _pad_to(wp, bn, 1)
    sw, _ = _pad_to(w_scale.reshape(1, -1), bn, 1)
    up = None
    if v is not None:
        up, _ = _pad_to(jnp.asarray(u, jnp.float32), bn, 0)
        v = jnp.asarray(v, jnp.float32)
    return fused_w4a4_lrc_kernel(
        xp, v, wp, sw, up,
        bits=act_spec.bits, clip_ratio=act_spec.clip_ratio, rotate=rotate,
        bm=bm, bn=bn, bk=bk, br=br, variant=variant,
        act_group=act_spec.group_size, interpret=interpret,
    )


def w4a4_lrc_forward(
    x: jnp.ndarray,  # (M, K) float
    wpacked: jnp.ndarray,  # (K//2, N) uint8
    w_scale: jnp.ndarray,  # (N,)
    u,  # (N, R) or None
    v,  # (K, R) or None
    act_spec: QuantSpec,
    rotate: bool = False,
    blocks=None,  # optional (bm, bn, bk[, br]) override; default: plan table
    impl: str = None,  # None -> ctx.impl; auto | fused | chained | unfused
    ctx: KernelContext = None,  # None -> the process-default context
    layer: str = None,  # per-layer override key into ctx.overrides
):
    """The full W4A4+LRC serving hot path.

    Execution config comes from ``ctx`` (a :class:`KernelContext`; ``None``
    falls back to the process default).  ``impl=None`` defers to
    ``ctx.impl`` (usually ``"auto"``): the block-table plan — with any
    per-layer override for ``layer`` — plus per-slab VMEM feasibility
    (:func:`resolve_plan`): the K-split fused kernel's tiles shrink to fit
    the budget before the path ever demotes, so fused serves every regime
    and rank unless nothing fits; then the two-kernel prologue → GEMM chain
    (V streamed); then the unfused three-pass chain.  Explicit ``impl``
    values force a path — "fused"/"chained" trust the caller on VMEM fit.

    ``rotate`` applies the online Walsh-Hadamard rotation (K power of two)
    inside the prologue.  ``act_spec.group_size`` switches the per-token
    scales for the per-group (M, K/g) scale plane on every path: BK snaps
    to a multiple of g (K-chunks hold whole scale groups) and the GEMM
    dequant moves into the K loop.  All operands are zero-padded to block
    multiples, so arbitrary M/K/N (odd MLP widths included) take the pallas
    path.  The three paths are bitwise identical in interpret mode (shared
    row bodies, shared K-chunk/R-tile accumulation order, exact integer
    accumulation) — under ANY context, since the context only picks the
    tiling.
    """
    ctx = _ctx(ctx)
    m0, k = x.shape
    n = wpacked.shape[1]
    r = 0 if v is None else v.shape[-1]
    group = act_spec.group_size
    if group is not None:
        assert k % group == 0, f"act_group {group} must divide K={k}"

    if impl is None:
        impl = ctx.impl
    variant = None
    if impl == "auto":
        path, bm, bn, bk, br, variant = ctx.resolve_plan(
            m0, k, n, r, rotate=rotate, layer=layer, act_group=group)
    elif impl not in KERNEL_PATHS:
        raise ValueError(f"unknown impl {impl!r}; "
                         f"expected auto or one of {KERNEL_PATHS}")
    else:
        path = impl
        _, bm, bn, bk, br, variant = ctx.select_plan(m0, k, n, r,
                                                     layer=layer)
    if blocks is not None:
        bm, bn, bk = blocks[:3]
        if len(blocks) > 3:
            br = blocks[3]
        br = min(br, _round_pow2(max(r, 8)))
        variant = None
    if group is not None:
        bk = snap_bk_to_group(bk, group)  # K-chunks hold whole scale groups
    if path == "fused" and variant is None:
        variant = ctx.fused_variant(k, r, bm, bn, bk, br, rotate,
                                    act_group=group)

    if rotate:
        assert k & (k - 1) == 0, \
            f"online rotation needs power-of-two K, got {k}"
        if variant == "streamed":
            variant = "resident"  # rotation needs the f32 row slab
    interpret = ctx.interpret_mode()
    # run the prologue on the M-padded activations directly — its outputs
    # stay bm-aligned so the GEMM padding below never re-pads axis 0
    xp, _ = _pad_to(x, bm, 0)

    if path == "fused":
        out = _forward_fused(xp, wpacked, w_scale, u if r else None,
                             v if r else None, act_spec, rotate,
                             bm, bn, bk, br, variant, interpret)
        return out[:m0, :n]

    if path == "chained":
        xq, sx, xv = fused_prologue_kernel(
            xp, jnp.asarray(v, jnp.float32) if r else None,
            bits=act_spec.bits, clip_ratio=act_spec.clip_ratio,
            rotate=rotate, bm=bm, bk=bk, br=br, act_group=group,
            interpret=interpret,
        )
    else:  # unfused: three activation passes over the row tiles
        xr = fwht(xp, bm=bm, ctx=ctx) if rotate else xp
        xq, sx = act_quant(xr, act_spec, bm=bm, ctx=ctx)
        xv = _project_tiles(xr, v, bm, bk, br) if r else None

    xqp, sxp, wp, sw, up, xvp = _pad_gemm_operands(
        xq, sx, wpacked, w_scale, u if r else None, xv, bm, bn, bk, br,
        act_group=group)
    out = w4a4_lowrank_matmul_kernel(
        xqp, sxp, wp, sw, xvp, up,
        bm=bm, bn=bn, bk=bk, group=group, interpret=interpret,
    )
    return out[:m0, :n]


def w4a4_lowrank_matmul(
    x: jnp.ndarray,
    wpacked: jnp.ndarray,
    w_scale: jnp.ndarray,
    u,
    v,
    act_spec: QuantSpec,
    bm: int = None,
    bn: int = None,
    bk: int = None,
    ctx: KernelContext = None,
):
    """Back-compat alias for :func:`w4a4_lrc_forward` (no online rotation)."""
    blocks = None
    if bm is not None or bn is not None or bk is not None:
        m0, k = x.shape
        n = wpacked.shape[1]
        r = 0 if v is None else v.shape[-1]
        d = select_blocks(m0, k, n, r, ctx=ctx)
        blocks = (bm or d.bm, bn or d.bn, bk or d.bk)
    return w4a4_lrc_forward(x, wpacked, w_scale, u, v, act_spec,
                            blocks=blocks, ctx=ctx)


def flash_attention(q, k, v, scale: float, causal: bool = True,
                    bq: int = 128, bkv: int = 128,
                    ctx: KernelContext = None):
    """GQA flash attention. q: (B, Sq, H, D); k/v: (B, Skv, KH, D[v]).
    Folds batch×head, repeats KV heads across their query group."""
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, k.shape[1], d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, v.shape[1], v.shape[-1])
    bq = min(bq, sq)
    bkv = min(bkv, k.shape[1])
    out = flash_attention_kernel(qf, kf, vf, scale, causal=causal,
                                 bq=bq, bkv=bkv, interpret=_interpret(ctx))
    return out.reshape(b, h, sq, -1).transpose(0, 2, 1, 3)


def paged_flash_attention(q, k_pages, v_pages, block_table, lengths,
                          scale: float, ctx: KernelContext = None):
    """Decode attention against the serving engine's paged KV pool.
    q: (B, H, D) one token per sequence; k/v_pages: (NP, P, KH, D[v]);
    block_table: (B, MPB) int32; lengths: (B,) valid kv positions including
    the current token.  The page gather runs inside the kernel — no
    contiguous per-request KV copy is materialized.  Returns (B, H, Dv)."""
    return paged_flash_attention_kernel(
        q, k_pages, v_pages, block_table, lengths, scale,
        interpret=_interpret(ctx))


def flash_attention_quant(q, k_quant, k_scales, v_quant, v_scales,
                          scale: float, kv_spec, causal: bool = True,
                          bq: int = 128, bkv: int = 128,
                          ctx: KernelContext = None):
    """``flash_attention`` over quantized K/V (dense prefill layout).
    q: (B, Sq, H, D); k/v_quant: (B, Skv, KH, D | D//2) int8/packed uint8
    with f32 scale planes (B, Skv, KH, D // group).  ``kv_spec`` is a
    :class:`repro.serve.kvquant.KVSpec`; dequant happens per tile inside
    the kernel, so f32 KV never round-trips HBM."""
    b, sq, h, d = q.shape
    kh = k_quant.shape[2]
    g = h // kh
    skv = k_quant.shape[1]
    group = kv_spec.group_for(d)
    packed = kv_spec.dtype == "int4"

    def fold(t):
        return jnp.repeat(t.transpose(0, 2, 1, 3), g, axis=1) \
            .reshape(b * h, skv, t.shape[-1])

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    out = flash_attention_quant_kernel(
        qf, fold(k_quant), fold(k_scales), fold(v_quant), fold(v_scales),
        scale, group, packed, causal=causal, bq=bq, bkv=bkv,
        interpret=_interpret(ctx))
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


def paged_flash_attention_quant(q, k_pages, k_scales, v_pages, v_scales,
                                block_table, lengths, scale: float, kv_spec,
                                ctx: KernelContext = None):
    """``paged_flash_attention`` over a QUANTIZED page pool.  q: (B, H, D);
    k/v_pages: (NP, P, KH, D | D//2) int8/packed uint8; k/v_scales: the f32
    (NP, P, KH, D // group) scale-plane sidecar indexed by the SAME block
    table.  Pages dequantize per gather inside the kernel (the
    ``gemm_chunk_grouped`` in-loop rescale pattern).  Returns (B, H, D)."""
    d = q.shape[-1]
    return paged_flash_attention_quant_kernel(
        q, k_pages, k_scales, v_pages, v_scales, block_table, lengths,
        scale, kv_spec.group_for(d), kv_spec.dtype == "int4",
        interpret=_interpret(ctx))
