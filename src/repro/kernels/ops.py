"""jit'd public wrappers around the Pallas kernels.

Responsibilities: shape padding to block multiples, interpret-mode selection
(interpret=True on CPU — validates the kernel bodies; compiled Mosaic on real
TPU), and the end-to-end fused entry used by ``QLinear(impl="pallas")``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quantizers import QuantSpec
from repro.kernels.actquant import act_quant_kernel
from repro.kernels.hadamard import fwht_kernel
from repro.kernels.w4a4 import w4a4_lowrank_matmul_kernel
from repro.kernels.flash_attn import flash_attention_kernel


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def act_quant(x: jnp.ndarray, spec: QuantSpec, bm: int = 128):
    """Per-token activation quantization. x: (M, K) -> (q int8, s (M,1))."""
    assert spec.group_size is None, "kernel path: per-token scales only"
    xp, m = _pad_to(x, bm, 0)
    q, s = act_quant_kernel(
        xp, bits=spec.bits, clip_ratio=spec.clip_ratio, bm=bm,
        interpret=_interpret(),
    )
    return q[:m], s[:m]


def fwht(x: jnp.ndarray, bm: int = 256):
    xp, m = _pad_to(x, bm, 0)
    return fwht_kernel(xp, bm=bm, interpret=_interpret())[:m]


def w4a4_lowrank_matmul(
    x: jnp.ndarray,  # (M, K) float
    wpacked: jnp.ndarray,  # (K//2, N) uint8
    w_scale: jnp.ndarray,  # (N,)
    u,  # (N, R) or None
    v,  # (K, R) or None
    act_spec: QuantSpec,
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
):
    """Full fused path: quantize activations, W4A4 GEMM + LR epilogue."""
    m0, k = x.shape
    n = wpacked.shape[1]
    bm = min(bm, _round_pow2(m0))
    bn = min(bn, n)
    bk = min(bk, k)
    assert k % bk == 0 and n % bn == 0, (k, n, bk, bn)

    xq, sx = act_quant(x, act_spec, bm=bm)
    xv = None
    if u is not None:
        xv = (x.astype(jnp.float32) @ v.astype(jnp.float32)).astype(jnp.float32)
        xv, _ = _pad_to(xv, bm, 0)
    xqp, _ = _pad_to(xq, bm, 0)
    sxp, _ = _pad_to(sx, bm, 0)
    out = w4a4_lowrank_matmul_kernel(
        xqp, sxp, wpacked, w_scale.reshape(1, -1),
        xv, u if u is None else jnp.asarray(u, jnp.float32),
        bm=bm, bn=bn, bk=bk, interpret=_interpret(),
    )
    return out[:m0]


def _round_pow2(m: int) -> int:
    p = 8
    while p * 2 <= m:
        p *= 2
    return p


def flash_attention(q, k, v, scale: float, causal: bool = True,
                    bq: int = 128, bkv: int = 128):
    """GQA flash attention. q: (B, Sq, H, D); k/v: (B, Skv, KH, D[v]).
    Folds batch×head, repeats KV heads across their query group."""
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, k.shape[1], d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, v.shape[1], v.shape[-1])
    bq = min(bq, sq)
    bkv = min(bkv, k.shape[1])
    out = flash_attention_kernel(qf, kf, vf, scale, causal=causal,
                                 bq=bq, bkv=bkv, interpret=_interpret())
    return out.reshape(b, h, sq, -1).transpose(0, 2, 1, 3)
