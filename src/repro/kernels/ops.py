"""jit'd public wrappers around the Pallas kernels.

Responsibilities: shape padding to block multiples (weights, scales and the
low-rank factors are zero-padded, so odd MLP widths never crash the pallas
path), block-size selection per serving regime (decode / mixed / prefill),
interpret-mode selection (interpret=True on CPU — validates the kernel
bodies; compiled Mosaic on real TPU), and the end-to-end fused entry
``w4a4_lrc_forward`` used by ``QLinear(impl="pallas")`` and the serving
engine: fused activation prologue (rotate → quantize → low-rank project,
one HBM pass over x) chained into the W4A4 GEMM + low-rank epilogue.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quantizers import QuantSpec
from repro.kernels.actquant import act_quant_kernel
from repro.kernels.hadamard import fwht_kernel
from repro.kernels.prologue import fused_prologue_kernel
from repro.kernels.w4a4 import w4a4_lowrank_matmul_kernel
from repro.kernels.flash_attn import flash_attention_kernel

# V is held whole in VMEM by the fused prologue; past this footprint the
# wrapper falls back to the unfused three-pass chain.
_PROLOGUE_V_BYTES_MAX = 8 * 1024 * 1024


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def _round_pow2(m: int) -> int:
    p = 8
    while p * 2 <= m:
        p *= 2
    return p


# ---------------------------------------------------------------------------
# block-size autotune table
# ---------------------------------------------------------------------------

# Regime-keyed (BM, BN, BK) tiles, replacing the old hard-coded 128/128/256.
# decode  (M ≤ 32):  tiny M tile; wide N×K tiles stream the weight matrix —
#                    the decode hot path is weight-HBM-bound.
# mixed   (M ≤ 512): balanced tiles.
# prefill (M > 512): large M tile; the GEMM is MXU-bound at these M.
_BLOCK_TABLE = {
    "decode": (16, 256, 512),
    "mixed": (128, 128, 256),
    "prefill": (256, 256, 256),
}


def gemm_regime(m: int) -> str:
    if m <= 32:
        return "decode"
    if m <= 512:
        return "mixed"
    return "prefill"


def select_blocks(m: int, k: int, n: int, r: int = 0):
    """(BM, BN, BK) for a (M, K, N, R) problem; clamped to the actual dims.
    Large ranks shrink BN so the U tile + f32 accumulator stay within VMEM."""
    bm, bn, bk = _BLOCK_TABLE[gemm_regime(m)]
    bm = min(bm, _round_pow2(max(m, 8)))
    bn = min(bn, _round_pow2(max(n, 8)))
    bk = min(bk, _round_pow2(max(k, 8)))
    if r >= 512:
        bn = min(bn, 128)
    return bm, bn, bk


# ---------------------------------------------------------------------------
# single-kernel wrappers
# ---------------------------------------------------------------------------


def act_quant(x: jnp.ndarray, spec: QuantSpec, bm: int = 128):
    """Per-token activation quantization. x: (M, K) -> (q int8, s (M,1))."""
    assert spec.group_size is None, "kernel path: per-token scales only"
    xp, m = _pad_to(x, bm, 0)
    q, s = act_quant_kernel(
        xp, bits=spec.bits, clip_ratio=spec.clip_ratio, bm=bm,
        interpret=_interpret(),
    )
    return q[:m], s[:m]


def fwht(x: jnp.ndarray, bm: int = 256):
    xp, m = _pad_to(x, bm, 0)
    return fwht_kernel(xp, bm=bm, interpret=_interpret())[:m]


def fused_prologue(x: jnp.ndarray, v, spec: QuantSpec,
                   rotate: bool = False, bm: int = 128):
    """Single-HBM-pass activation prologue: optional WHT rotation, per-token
    quantization, and the (x·V) projection, from one row-tile read of x.

    x: (M, K); v: (K, R) or None.  Returns (xq, sx, xv-or-None)."""
    assert spec.group_size is None, "kernel path: per-token scales only"
    xp, m = _pad_to(x, bm, 0)
    q, s, xv = fused_prologue_kernel(
        xp, None if v is None else jnp.asarray(v, jnp.float32),
        bits=spec.bits, clip_ratio=spec.clip_ratio, rotate=rotate, bm=bm,
        interpret=_interpret(),
    )
    return q[:m], s[:m], None if xv is None else xv[:m]


# ---------------------------------------------------------------------------
# fused W4A4 + LRC forward
# ---------------------------------------------------------------------------


def _pad_gemm_operands(xq, sx, wpacked, w_scale, u, xv, bm, bn, bk):
    """Zero-pad every GEMM operand to its block multiple.  Zero weight
    nibbles/scales/U-rows contribute nothing, so padded K/N columns are exact;
    padded M rows are sliced off the output."""
    xqp, _ = _pad_to(xq, bm, 0)
    xqp, _ = _pad_to(xqp, bk, 1)
    sxp, _ = _pad_to(sx, bm, 0)
    wp, _ = _pad_to(wpacked, bk // 2, 0)  # K//2 rows
    wp, _ = _pad_to(wp, bn, 1)
    sw, _ = _pad_to(w_scale.reshape(1, -1), bn, 1)
    if u is not None:
        u, _ = _pad_to(jnp.asarray(u, jnp.float32), bn, 0)
        xv, _ = _pad_to(xv, bm, 0)
    return xqp, sxp, wp, sw, u, xv


def w4a4_lrc_forward(
    x: jnp.ndarray,  # (M, K) float
    wpacked: jnp.ndarray,  # (K//2, N) uint8
    w_scale: jnp.ndarray,  # (N,)
    u,  # (N, R) or None
    v,  # (K, R) or None
    act_spec: QuantSpec,
    rotate: bool = False,
    blocks=None,  # optional (bm, bn, bk) override; default: autotune table
):
    """The full W4A4+LRC serving hot path, two kernels end to end:

      1. fused activation prologue — ONE HBM read of x yields the rotated,
         quantized activations and the (x·V) projection;
      2. fused W4A4 GEMM + low-rank epilogue (kernels/w4a4.py).

    ``rotate`` applies the online Walsh-Hadamard rotation (K power of two)
    inside the prologue.  All operands are zero-padded to block multiples, so
    arbitrary M/K/N (odd MLP widths included) take the pallas path.
    """
    m0, k = x.shape
    n = wpacked.shape[1]
    r = 0 if v is None else v.shape[-1]
    bm, bn, bk = blocks if blocks is not None else select_blocks(m0, k, n, r)

    if rotate:
        assert k & (k - 1) == 0, \
            f"online rotation needs power-of-two K, got {k}"
    assert act_spec.group_size is None, "kernel path: per-token scales only"
    # run the prologue on the M-padded activations directly — its outputs
    # stay bm-aligned so the GEMM padding below never re-pads axis 0
    xp, _ = _pad_to(x, bm, 0)
    if r == 0 or (k * r * 4) <= _PROLOGUE_V_BYTES_MAX:
        xq, sx, xv = fused_prologue_kernel(
            xp, jnp.asarray(v, jnp.float32) if r else None,
            bits=act_spec.bits, clip_ratio=act_spec.clip_ratio,
            rotate=rotate, bm=bm, interpret=_interpret(),
        )
    else:  # unfused fallback: V too large for VMEM residency
        xr = fwht(xp, bm=bm) if rotate else xp
        xq, sx = act_quant(xr, act_spec, bm=bm)
        xv = xr.astype(jnp.float32) @ jnp.asarray(v, jnp.float32)

    xqp, sxp, wp, sw, up, xvp = _pad_gemm_operands(
        xq, sx, wpacked, w_scale, u if r else None, xv, bm, bn, bk)
    out = w4a4_lowrank_matmul_kernel(
        xqp, sxp, wp, sw, xvp, up,
        bm=bm, bn=bn, bk=bk, interpret=_interpret(),
    )
    return out[:m0, :n]


def w4a4_lowrank_matmul(
    x: jnp.ndarray,
    wpacked: jnp.ndarray,
    w_scale: jnp.ndarray,
    u,
    v,
    act_spec: QuantSpec,
    bm: int = None,
    bn: int = None,
    bk: int = None,
):
    """Back-compat alias for :func:`w4a4_lrc_forward` (no online rotation)."""
    blocks = None
    if bm is not None or bn is not None or bk is not None:
        m0, k = x.shape
        n = wpacked.shape[1]
        r = 0 if v is None else v.shape[-1]
        dbm, dbn, dbk = select_blocks(m0, k, n, r)
        blocks = (bm or dbm, bn or dbn, bk or dbk)
    return w4a4_lrc_forward(x, wpacked, w_scale, u, v, act_spec, blocks=blocks)


def flash_attention(q, k, v, scale: float, causal: bool = True,
                    bq: int = 128, bkv: int = 128):
    """GQA flash attention. q: (B, Sq, H, D); k/v: (B, Skv, KH, D[v]).
    Folds batch×head, repeats KV heads across their query group."""
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, k.shape[1], d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, v.shape[1], v.shape[-1])
    bq = min(bq, sq)
    bkv = min(bkv, k.shape[1])
    out = flash_attention_kernel(qf, kf, vf, scale, causal=causal,
                                 bq=bq, bkv=bkv, interpret=_interpret())
    return out.reshape(b, h, sq, -1).transpose(0, 2, 1, 3)
