"""Paper Figure 2 — accuracy vs. rank fraction at W4A4 (with and without
activation groups).  Claim: 10% already beats QuaRot; ~30% closes the gap."""

from __future__ import annotations

from benchmarks.common import (
    calib_tokens,
    eval_batches,
    get_bench_model,
    make_policy,
    ppl_and_acc,
    quantize,
    record,
)

FRACS = [0.0, 0.05, 0.10, 0.20, 0.30, 0.50]


def run():
    cfg, params = get_bench_model()
    calib = calib_tokens(cfg)
    evals = eval_batches(cfg)
    fp_ppl, fp_acc = ppl_and_acc(cfg, params, evals)
    rows = [["FP16", "-", round(fp_ppl, 4), round(fp_acc, 4)]]
    curves = {}
    for group in (None, 64):
        for frac in FRACS:
            method = "lrc" if frac > 0 else "quarot"
            qp = quantize(cfg, params, make_policy(method, rank_frac=frac, act_group=group), calib)
            ppl, acc = ppl_and_acc(cfg, qp, evals)
            tag = f"g{group or 0}"
            rows.append([f"LRC[{tag}]" if frac else f"QuaRot[{tag}]",
                         frac, round(ppl, 4), round(acc, 4)])
            curves[(group, frac)] = (ppl, acc)
    record("fig2_rank_sweep", rows, ["method", "rank_frac", "ppl", "acc"])
    return fp_acc, curves


if __name__ == "__main__":
    run()
