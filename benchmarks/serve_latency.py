"""Serving-path latency: chunked prefill + ONE batched decode call per step,
as a function of batch size and page size.

Emits ``results/BENCH_serve.json`` (``results/BENCH_serve_smoke.json`` with
``--smoke``) in the shared ``benchmarks.common.record`` layout; the column
schema is documented in docs/serving.md.  Two kinds of columns:

* **wall-clock** (``prefill_ms_per_token``, ``decode_ms_per_token``) —
  informational.  CPU-interpret wall time is noisy across runners, so the
  CI gate does NOT fail on it.
* **deterministic efficiency** (``decode_calls_per_token``,
  ``prefill_chunks_per_prompt``) — these are exact consequences of the
  engine's batching structure: one batched decode call per engine step
  makes ``decode_calls_per_token == 1/batch`` whatever the token count, and
  chunked prefill issues exactly ``ceil(prompt_len/chunk)`` forwards per
  prompt.  The CI regression gate (``benchmarks.check_regression --serve``)
  fails if either grows — i.e. if batching quietly degenerates back toward
  per-slot decode calls.  Both are token-count invariant, so the --smoke
  rows (fewer new tokens) gate against the committed full baseline.

Run on the reduced smollm config with synthetic FP weights: serving-path
latency structure (calls per token, chunk interleaving, page bookkeeping)
does not depend on the weight values, and FP keeps CI runtime flat.

    PYTHONPATH=src python -m benchmarks.serve_latency [--smoke]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import record
from repro.configs import get_config
from repro.models import model as model_lib
from repro.models.config import reduced
from repro.serve.engine import Request, RequestState, ServeEngine
from repro.serve.kvquant import KVSpec

HEADER = [
    "batch", "page_size", "prefill_chunk", "kv_dtype", "requests",
    "prompt_len", "new_tokens",
    "prefill_ms_per_token", "decode_ms_per_token",
    "decode_calls", "decode_calls_per_token", "prefill_chunks_per_prompt",
    "paged_traces", "kv_bytes_per_token",
]

PROMPT_LEN = 24
MAX_SEQ = 64
# (batch, page_size, prefill_chunk, kv_dtype) — the acceptance grid: decode
# ms/token at B in {1, 4, 16}, a page-size point, a chunked-prefill point,
# and the quantized-KV points (int8 per-head, int4 per-head) whose
# kv_bytes_per_token column the regression gate holds at the >=3x / >=5x
# reductions the paged pools deliver
CASES = [(1, 16, None, "f32"), (4, 16, None, "f32"), (16, 16, None, "f32"),
         (4, 8, None, "f32"), (4, 16, 8, "f32"),
         (4, 16, None, "int8"), (4, 16, None, "int4")]
SMOKE_CASES = [(1, 16, None, "f32"), (4, 16, None, "f32"),
               (4, 16, 8, "f32"), (4, 16, None, "int8")]


def _mk_engine(cfg, params, batch, page_size, chunk, kv_dtype):
    return ServeEngine(cfg, params, batch_slots=batch, max_seq=MAX_SEQ,
                       page_size=page_size, prefill_chunk=chunk,
                       kv_spec=KVSpec.from_flags(kv_dtype, None))


def _drive(cfg, params, batch, page_size, chunk, kv_dtype, new_tokens):
    """One wave of ``batch`` identical-length requests; returns timings and
    the engine for counter inspection."""
    rng = np.random.default_rng(0)
    prompts = [np.asarray(rng.integers(0, cfg.vocab_size, (PROMPT_LEN,)),
                          np.int32) for _ in range(batch)]
    eng = _mk_engine(cfg, params, batch, page_size, chunk, kv_dtype)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=new_tokens))

    t0 = time.perf_counter()
    eng._admit()
    while any(r is not None and r.state is RequestState.PREFILLING
              for r in eng.slot_req):
        eng._prefill_tick()
    t_prefill = time.perf_counter() - t0

    t0 = time.perf_counter()
    done = eng.run()
    t_decode = time.perf_counter() - t0

    assert all(done[i].ok for i in range(batch)), \
        {i: (done[i].status, done[i].error) for i in done}
    assert all(len(done[i].out_tokens) == new_tokens for i in range(batch))
    # pages all came back on the terminal transitions
    assert eng.alloc.free_pages == eng.alloc.capacity
    eng.alloc.check()
    return eng, t_prefill, t_decode


def bench_case(cfg, params, batch, page_size, chunk, kv_dtype, new_tokens):
    fns_traces = None
    # run twice: the first run compiles (the jitted fns are shared
    # process-wide per config, so the second run is pure execution)
    for it in range(2):
        eng, t_prefill, t_decode = _drive(cfg, params, batch, page_size,
                                          chunk, kv_dtype, new_tokens)
        if it == 0:
            fns_traces = dict(eng.health()["traces"])
    # retracing on the measured run would mean the engine's shapes are not
    # stable step-to-step — that is a bug, not a measurement artifact
    assert eng.health()["traces"] == fns_traces, "decode retraced while serving"

    prefill_tokens = batch * PROMPT_LEN
    decode_tokens = batch * (new_tokens - 1)  # first token comes from prefill
    decode_calls = eng.counters["decode_calls"]
    assert decode_calls == new_tokens - 1, (decode_calls, new_tokens)
    chunks = -(-PROMPT_LEN // (chunk or PROMPT_LEN))
    return [
        batch, page_size, 0 if chunk is None else chunk, kv_dtype, batch,
        PROMPT_LEN, new_tokens,
        round(t_prefill * 1e3 / prefill_tokens, 4),
        round(t_decode * 1e3 / decode_tokens, 4),
        decode_calls,
        round(decode_calls / decode_tokens, 6),
        chunks,
        eng.health()["traces"]["paged"],
        eng.health()["kv"]["bytes_per_token"],
    ]


def bench_rows(smoke: bool = False):
    cfg = reduced(get_config("smollm-135m"))
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    cases = SMOKE_CASES if smoke else CASES
    new_tokens = 6 if smoke else 16
    return [bench_case(cfg, params, b, p, c, d, new_tokens)
            for b, p, c, d in cases]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small grid / few tokens for CI; writes "
                         "results/BENCH_serve_smoke.json")
    args = ap.parse_args(argv)
    rows = bench_rows(smoke=args.smoke)
    record("BENCH_serve_smoke" if args.smoke else "BENCH_serve", rows, HEADER)


if __name__ == "__main__":
    main()
