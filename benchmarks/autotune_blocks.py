"""Autotune sweep for the W4A4+LRC kernel execution-plan table.

For each serving regime (decode / mixed / prefill) this harness evaluates
candidate execution plans — kernel path (fused single-kernel vs. the
prologue → GEMM chain) × (BM, BN, BK, BR) tiles — at a representative
(M, K, N, R) shape, scores them, and persists the winners to
``results/block_table.json``, which ``KernelContext.from_json`` overlays
onto the analytic defaults (``launch/serve.py --block-table``).  BK is the
K-chunk of the K-split fused grid (and the chained prologue's V stream),
BR the R-tile of the streamed low-rank factor.

The sweep runs under an explicit ``KernelContext`` built from the CLI flags
(no process-global kernel state is touched), so feasibility is judged
against exactly the budgets that will be persisted.

Two scoring modes:

  --measure    wall-clock the actual kernels.  Meaningful on a real TPU
               (compiled Mosaic); on CPU the pallas interpreter's overhead
               swamps tile effects, so measured winners from a CPU run are
               NOT committed.  Combine with ``--vmem-budget`` (or a "vmem"
               entry written into the table) to probe real-hardware VMEM
               ceilings.
  (default)    analytic: the v5e roofline byte/FLOP model plus the
               per-slab VMEM feasibility check (serving rotates, which pins
               the RESIDENT prologue variant, so fused candidates are
               checked against the resident footprint) — deterministic,
               hardware-free, and the source of the committed table.

    PYTHONPATH=src python -m benchmarks.autotune_blocks [--measure]
        [--out results/block_table.json] [--smoke] [--vmem-budget BYTES]
        [--layers CONFIG]

``--layers <config>`` additionally sweeps the config's ACTUAL per-layer
(K, N, R) shapes (attention + MLP projections at the paper's rank
fraction) and emits a ``"layers"`` override table keyed by the
calibration walker's layer names — the per-layer plan overrides
``KernelContext`` resolves ahead of the regime entries.
"""

from __future__ import annotations

import argparse
import itertools
import json
import time
from pathlib import Path

import numpy as np

from benchmarks.latency_kernels import _roofline_time
from repro.kernels.context import (KernelContext, fused_vmem_bytes,
                                   prologue_vmem_bytes, vmem_budget_arg)

RESULTS = Path(__file__).resolve().parents[1] / "results"

# representative (M, K, N, R) per regime: Llama-7B MLP shapes, rank 128
REGIME_SHAPES = {
    "decode": (16, 4096, 11008, 128),
    "mixed": (256, 4096, 11008, 128),
    "prefill": (2048, 4096, 11008, 128),
}

CANDIDATE_BMS = {"decode": [8, 16, 32], "mixed": [64, 128, 256],
                 "prefill": [128, 256, 512]}
CANDIDATE_BNS = [128, 256, 512]
CANDIDATE_BKS = [128, 256, 512]
CANDIDATE_BRS = [128, 256, 512]


def _candidates(regime, smoke=False):
    bms = CANDIDATE_BMS[regime]
    bns, bks, brs = CANDIDATE_BNS, CANDIDATE_BKS, CANDIDATE_BRS
    if smoke:
        bms, bns, bks, brs = bms[:2], bns[:2], bks[:2], brs[:1]
    for path, bm, bn, bk, br in itertools.product(("fused", "chained"),
                                                  bms, bns, bks, brs):
        yield dict(path=path, bm=bm, bn=bn, bk=bk, br=br)


def _analytic_score_shape(m, k, n, r, cand, ctx: KernelContext):
    """v5e roofline latency of the candidate at one (M, K, N, R) shape;
    infeasible plans score inf.  Serving applies the online rotation, so
    feasibility is checked with rotate=True (the stricter case — it pins
    the resident prologue)."""
    br = min(cand["br"], r) if r else cand["br"]
    path = cand["path"]
    if path == "fused":
        if fused_vmem_bytes(k, r, cand["bm"], cand["bn"], cand["bk"],
                            br, True) > ctx.fused_vmem_bytes:
            return (float("inf"), float("inf"))
    else:
        if prologue_vmem_bytes(k, r, cand["bm"], cand["bk"], br,
                               True) > ctx.prologue_vmem_bytes:
            return (float("inf"), float("inf"))
    # the roofline is tile-agnostic beyond bm (V/U re-reads per M-tile);
    # break byte-model ties toward plans whose tiles divide the problem
    # evenly (fewer ragged edge tiles), then toward LARGER tiles (fewer grid
    # steps — less pipeline/loop overhead, bigger MXU ops)
    t = _roofline_time(m, k, n, r, path, bm=cand["bm"], ctx=ctx)
    waste = sum(((-d) % b) / d
                for d, b in ((m, cand["bm"]), (n, cand["bn"]),
                             (k, cand["bk"])))
    steps = (-(-m // cand["bm"]) * -(-n // cand["bn"]) * -(-k // cand["bk"]))
    return (t * (1.0 + 0.1 * waste), steps)


def _analytic_score(regime, cand, ctx: KernelContext):
    """:func:`_analytic_score_shape` at the regime's representative shape."""
    m, k, n, r = REGIME_SHAPES[regime]
    return _analytic_score_shape(m, k, n, r, cand, ctx)


def _measure_score(regime, cand, ctx: KernelContext, reps=3,
                   scale_down=True):
    """Wall-clock the actual kernel path.  On CPU the shapes are scaled down
    so the interpreter finishes; only TPU numbers are table-worthy."""
    import jax

    from benchmarks.common import make_w4a4_problem
    from repro.kernels import ops

    m, k, n, r = REGIME_SHAPES[regime]
    if scale_down and jax.default_backend() == "cpu":
        m, k, n, r = min(m, 32), min(k, 512), min(n, 512), min(r, 32)
    rng = np.random.default_rng(0)
    spec, x, wp, s, u, v = make_w4a4_problem(rng, m, k, n, r)
    blocks = (min(cand["bm"], m), min(cand["bn"], n), min(cand["bk"], k),
              min(cand["br"], max(r, 8)))

    def f():
        return ops.w4a4_lrc_forward(x, wp, s, u, v, spec,
                                    blocks=blocks, impl=cand["path"],
                                    ctx=ctx)

    try:
        f().block_until_ready()  # compile
    except Exception as e:  # infeasible tiling for this shape
        print(f"    [{regime}] {cand} infeasible: {type(e).__name__}")
        return (float("inf"), float("inf"))
    t0 = time.time()
    for _ in range(reps):
        f().block_until_ready()
    return ((time.time() - t0) / reps, 0)


def layer_shapes(cfg, rank_frac: float = 0.10) -> dict:
    """{layer name: (K, N, R)} for a model config's quantized projections.
    Names use the calibration walker's layer tags ("attn/wq", "mlp/wd", …),
    so an emitted "layers" override table keys directly onto the
    ``QLinear.name`` metadata the walker attaches.  R follows the paper's
    headline rank fraction (rank = round(rank_frac · min(K, N)))."""
    if cfg.family not in ("dense", "vlm"):
        raise ValueError(
            f"per-layer autotune supports dense/vlm configs; "
            f"{cfg.name!r} is family {cfg.family!r}")

    from repro.quant.policy import QuantPolicy

    # THE rank heuristic — reuse the policy's so the swept (K, N, R) set
    # always matches the shapes calibration actually solves
    rank = QuantPolicy(rank_frac=rank_frac).rank

    d, hd = cfg.d_model, cfg.head_dim
    dims = {
        "attn/wq": (d, cfg.n_heads * hd),
        "attn/wk": (d, cfg.n_kv_heads * hd),
        "attn/wv": (d, cfg.n_kv_heads * hd),
        "attn/wo": (cfg.n_heads * hd, d),
        "mlp/wg": (d, cfg.d_ff),
        "mlp/wu": (d, cfg.d_ff),
        "mlp/wd": (cfg.d_ff, d),
    }
    return {name: (k, n, rank(k, n)) for name, (k, n) in dims.items()}


def autotune_layers(config_name: str, smoke: bool = False,
                    ctx: KernelContext = None, rank_frac: float = 0.10,
                    m: int = 16) -> dict:
    """Sweep candidates at each of a model config's ACTUAL (K, N, R) layer
    shapes (decode M — the serving hot path) and return a per-layer
    "layers" override table: {layer name: winning plan}.  Unlike the three
    regime entries, these winners see the layer's true aspect ratio and
    rank, so e.g. the narrow wd projection can pick different tiles than
    the wide wg/wu pair."""
    from repro.configs import get_config

    cfg = get_config(config_name)
    ctx = ctx or KernelContext()
    overrides = {}
    for name, (k, n, r) in layer_shapes(cfg, rank_frac).items():
        best, best_t = None, (float("inf"), float("inf"))
        for cand in _candidates("decode", smoke=smoke):
            t = _analytic_score_shape(m, k, n, r, cand, ctx)
            if t < best_t:
                best, best_t = dict(cand), t
        if best is None:
            # no candidate fits the budget — emit NO override (the layer
            # falls back to the regime entry + resolve_plan's shrink/demote)
            # rather than a None entry from_json would reject
            print(f"[layer {name}] (K, N, R)=({k}, {n}, {r}) no feasible "
                  f"candidate under the sweep budgets; skipped")
            continue
        overrides[name] = best  # plan keys only: loadable as an override
        print(f"[layer {name}] (K, N, R)=({k}, {n}, {r}) winner: {best}")
    return overrides


def autotune_sweep(measure: bool = False, smoke: bool = False,
                   ctx: KernelContext = None) -> dict:
    """Sweep all candidates per regime under ``ctx`` (None -> analytic
    defaults); return {regime: winning plan}."""
    ctx = ctx or KernelContext()
    winners = {}
    score = _measure_score if measure else _analytic_score
    for regime in REGIME_SHAPES:
        best, best_t = None, (float("inf"), float("inf"))
        for cand in _candidates(regime, smoke=smoke):
            t = score(regime, cand, ctx)
            if t < best_t:
                best, best_t = dict(cand), t
        if best is None:
            # every candidate infeasible under the sweep budgets: emit NO
            # entry (from_json then keeps the analytic default for the
            # regime) instead of a None the loader would reject
            print(f"[{regime}] no feasible candidate under the sweep "
                  f"budgets; regime left to the analytic default")
            continue
        best["score_us"] = round(best_t[0] * 1e6, 2) \
            if best_t[0] != float("inf") else None
        best["shape_mknr"] = list(REGIME_SHAPES[regime])
        winners[regime] = best
        print(f"[{regime}] winner: {best}")
    return winners


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--measure", action="store_true",
                    help="wall-clock the kernels instead of the analytic "
                         "roofline score (use on real TPU)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny candidate grid (CI sanity)")
    ap.add_argument("--vmem-budget", type=vmem_budget_arg, default=None,
                    help="override the fused/prologue VMEM working-set "
                         "budgets (positive bytes) for the sweep — probe "
                         "real-TPU ceilings instead of the analytic "
                         "defaults")
    ap.add_argument("--layers", default=None, metavar="CONFIG",
                    help="also emit a per-layer 'layers' override table for "
                         "this model config's actual (K, N, R) set (keys = "
                         "the calibration walker's layer names, e.g. "
                         "attn/wq), loadable via KernelContext.from_json")
    ap.add_argument("--out", default=str(RESULTS / "block_table.json"))
    args = ap.parse_args(argv)

    ctx = KernelContext()
    if args.vmem_budget is not None:
        ctx = ctx.with_vmem_budgets(fused=args.vmem_budget,
                                    prologue=args.vmem_budget)
    winners = autotune_sweep(measure=args.measure, smoke=args.smoke, ctx=ctx)
    if args.layers is not None:
        winners["layers"] = autotune_layers(args.layers, smoke=args.smoke,
                                            ctx=ctx)
    if args.vmem_budget is not None:
        # persist the probed budgets with the winners they were swept
        # under, so KernelContext.from_json replays them at serve time
        # instead of re-shrinking the plans against the default budgets
        winners["vmem"] = dict(fused_bytes_max=args.vmem_budget,
                               prologue_bytes_max=args.vmem_budget)
    out = Path(args.out)
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(winners, indent=2) + "\n")
    print(f"wrote {out}")

    # round-trip through the context loader so a malformed table fails
    # HERE, not at serve time (builds a throwaway context; no global state)
    KernelContext.from_json(out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
