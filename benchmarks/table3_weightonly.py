"""Paper Table 3 — weight-only quantization (Q_a = identity).

Claim: all methods are near-lossless at W4A16 — low-rank correction buys
nothing when activations stay FP."""

from __future__ import annotations

from benchmarks.common import (
    calib_tokens,
    eval_batches,
    get_bench_model,
    make_policy,
    ppl_and_acc,
    quantize,
    record,
)


def run():
    cfg, params = get_bench_model()
    calib = calib_tokens(cfg)
    evals = eval_batches(cfg)
    rows = []
    fp_ppl, fp_acc = ppl_and_acc(cfg, params, evals)
    rows.append(["FP16", round(fp_ppl, 4), round(fp_acc, 4)])
    out = {"FP16": (fp_ppl, fp_acc)}
    for name, method in [("QuaRot", "quarot"), ("SVD", "svd"), ("LRC", "lrc")]:
        qp = quantize(cfg, params, make_policy(method, act_bits=16), calib)
        ppl, acc = ppl_and_acc(cfg, qp, evals)
        rows.append([name, round(ppl, 4), round(acc, 4)])
        out[name] = (ppl, acc)
    record("table3_weightonly", rows, ["method", "ppl", "acc"])
    return out


if __name__ == "__main__":
    run()
