"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV summary lines plus each table's full
CSV; detailed JSON lands in results/."""

from __future__ import annotations

import time


def main() -> None:
    from benchmarks import (
        fig2_rank_sweep,
        fig3_quantizer,
        latency_kernels,
        table1_w4a4,
        table2_groups,
        table3_weightonly,
    )

    summary = []
    for name, mod in [
        ("table1_w4a4", table1_w4a4),
        ("table2_groups", table2_groups),
        ("table3_weightonly", table3_weightonly),
        ("fig2_rank_sweep", fig2_rank_sweep),
        ("fig3_quantizer", fig3_quantizer),
        ("latency_kernels", latency_kernels),
    ]:
        t0 = time.time()
        derived = mod.run()
        us = (time.time() - t0) * 1e6
        summary.append((name, us, _derived_str(name, derived)))
        print()
    print("name,us_per_call,derived")
    for name, us, derived in summary:
        print(f"{name},{us:.0f},{derived}")


def _derived_str(name: str, derived) -> str:
    try:
        if name == "table1_w4a4":
            gap = (derived["FP16"][1] - derived["LRC (1)"][1]) / max(
                1e-9, derived["FP16"][1] - derived["QuaRot"][1]
            )
            return f"lrc_closes_{100 * (1 - gap):.0f}pct_of_gap"
        if name == "fig2_rank_sweep":
            fp_acc, curves = derived
            acc30 = curves[(None, 0.30)][1]
            return f"rank30_acc_within_{abs(fp_acc - acc30):.4f}_of_fp"
        if name == "latency_kernels":
            return "fused_kernel_roofline_table"
    except Exception:  # noqa: BLE001
        pass
    return "ok"


if __name__ == "__main__":
    main()
