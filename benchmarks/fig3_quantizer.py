"""Paper Figure 3 — LRC composed with different weight quantizers (GPTQ vs
RTN) at W4A4.  Claim: LRC always improves its baseline, and the gain is
larger for the weaker quantizer (RTN)."""

from __future__ import annotations

from benchmarks.common import (
    calib_tokens,
    eval_batches,
    get_bench_model,
    make_policy,
    ppl_and_acc,
    quantize,
    record,
)


def run():
    cfg, params = get_bench_model()
    calib = calib_tokens(cfg)
    evals = eval_batches(cfg)
    fp_ppl, fp_acc = ppl_and_acc(cfg, params, evals)
    rows = [["FP16", round(fp_ppl, 4), round(fp_acc, 4)]]
    out = {}
    for qm in ("gptq", "rtn"):
        for corr in ("quarot", "lrc"):
            qp = quantize(cfg, params, make_policy(corr, quant_method=qm), calib)
            ppl, acc = ppl_and_acc(cfg, qp, evals)
            name = f"{qm.upper()}{'+LRC' if corr == 'lrc' else ''}"
            rows.append([name, round(ppl, 4), round(acc, 4)])
            out[(qm, corr)] = (ppl, acc)
    record("fig3_quantizer", rows, ["method", "ppl", "acc"])
    return out


if __name__ == "__main__":
    run()
