"""Paper Table 1 — W4A4, no group-scaling.

Methods: FP16, QuaRot (GPTQ, no correction), SVD (rank 10%), LRC(1), LRC(5).
Claim validated: LRC recovers >50% of the QuaRot→FP gap; SVD does not."""

from __future__ import annotations

import time

from benchmarks.common import (
    calib_tokens,
    eval_batches,
    get_bench_model,
    make_policy,
    ppl_and_acc,
    quantize,
    record,
)


def run():
    cfg, params = get_bench_model()
    calib = calib_tokens(cfg)
    evals = eval_batches(cfg)
    rows = []
    fp_ppl, fp_acc = ppl_and_acc(cfg, params, evals)
    rows.append(["FP16", round(fp_ppl, 4), round(fp_acc, 4), 0.0])
    results = {"FP16": (fp_ppl, fp_acc)}
    for name, method, iters in [
        ("QuaRot", "quarot", 1),
        ("SVD", "svd", 1),
        ("LRC (1)", "lrc", 1),
        ("LRC (5)", "lrc", 5),
    ]:
        t0 = time.time()
        qp = quantize(cfg, params, make_policy(method, lrc_iters=iters), calib)
        ppl, acc = ppl_and_acc(cfg, qp, evals)
        rows.append([name, round(ppl, 4), round(acc, 4), round(time.time() - t0, 1)])
        results[name] = (ppl, acc)

    # paper claim: LRC closes >50% of the accuracy gap vs QuaRot
    gap_quarot = results["FP16"][1] - results["QuaRot"][1]
    gap_lrc = results["FP16"][1] - results["LRC (1)"][1]
    closed = 1.0 - gap_lrc / gap_quarot if gap_quarot > 0 else 1.0
    rows.append(["lrc_gap_closed_frac", round(closed, 3), "", ""])
    record("table1_w4a4", rows, ["method", "ppl", "acc", "quant_seconds"])
    return results


if __name__ == "__main__":
    run()
