"""Paper Tables 6-8 — layer latency vs. rank (Llama matrix sizes).

The paper timed Cutlass int4 on an A100 and found even 128 ranks cost 23-52%
extra latency (unfused second pass).  No TPU is attached here, so we report:

  * the ROOFLINE-MODEL v5e latency of the W4A4+LRC layer on the kernel
    paths — unfused (three activation passes + GEMM), chained (fused
    prologue → GEMM, one M×K xq round-trip between them) and the
    single-kernel K-split fused path in both prologue variants (resident:
    one x read; streamed: two x reads, no f32 row slab in VMEM) — derived
    from exact byte/FLOP counts, including the per-M-tile V/U factor
    streaming the K-split grid implies;
  * the activation-side HBM bytes of each path
    (repro.launch.roofline.prologue_activation_bytes), the columns the CI
    regression gate (benchmarks/check_regression.py) protects;
  * measured CPU wall-clock of the int8 execution path as a sanity ratio
    (relative, not absolute).

``--smoke`` swaps the analytic sweep for an actual-kernel run: the three
paths execute in pallas interpret mode at small decode/mixed shapes PLUS one
rank-1024, large-K shape (K×R×4 = 32 MB — far past the old 8 MB whole-VMEM
V ceiling) that must resolve to the fused path with no demotion, AND one
g=128 group-wise-scale shape that must also resolve fused (grouped layers
used to demote to the jnp int8 GEMM), with bitwise cross-path parity
checked and wall-clock recorded — the CI bench-smoke job runs this and
uploads results/latency_kernels_smoke.json.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import record
from repro.launch.roofline import (HBM_BW, PEAK_FLOPS,
                                   attention_kv_bytes,
                                   ep_combine_bytes_per_token,
                                   prologue_activation_bytes,
                                   prologue_intermediate_bytes,
                                   tp_psum_bytes_per_token)

# (d_in, d_out) from the Llama family, as in paper Tables 6-8
SIZES = [(4096, 11008), (5120, 13824), (8192, 28672)]
RANKS = [0, 128, 256, 512, 1024]
# Three serving regimes: decode (M=16, weight-bound), mixed (M=256), and the
# paper's prefill setting (M=2048+, compute-bound on TPU).  The fusion win
# lives in the memory-bound regimes; at the paper's M the v5e GEMM is
# compute-bound and fusion saves energy/bytes, not latency — which is why
# the K-split fused path (same MXU work, fewer bytes) now wins prefill too.
MS = [16, 256, 2048]

HEADER = [
    "matrix", "ranks",
    "us_unfused", "us_chained", "us_fused",
    "speedup_vs_fp16_unfused", "speedup_vs_fp16_fused",
    "fused_over_chained",
    "act_prologue_kb_unfused", "act_prologue_kb_chained",
    "act_prologue_kb_fused", "act_prologue_byte_ratio",
    # K-split columns: the streamed-prologue fused variant (no f32 row slab
    # in VMEM, one extra x read).  NOTE: the streamed variant only executes
    # with rotate=False (rotation pins the resident slab), so for the
    # rotated rows below these columns are the what-if figure of serving
    # the same shape unrotated — not an attainable plan for that row.
    "us_fused_stream", "act_prologue_kb_fused_stream",
    # Group-wise activation scales (paper Table 2, g=128): the (M, K/128)
    # scale plane rides the chained path's HBM round-trip, so its byte and
    # latency figures grow with K/g; the fused path keeps the plane in
    # VMEM (bytes unchanged), making these the columns where granularity
    # costs show.  Guarded by check_regression like every us_/act_ column.
    "us_chained_g128", "act_prologue_kb_chained_g128",
    # Attention KV bytes at context length M (the row's M doubles as the
    # sequence length) for the serving-side quantized KV cache
    # (repro.serve.kvquant.KVSpec), at the reference attention geometry
    # below: f32 pages, int8 per-head, int4 with g=128 scale groups.  The
    # int8/int4 columns include the f32 scale-plane term, so the ratios
    # they imply (~3.8x / ~7x vs f32) are the honest HBM numbers the
    # paged decode kernel streams.  Guarded by check_regression via the
    # attn_kb_ prefix.
    "attn_kb_f32", "attn_kb_int8", "attn_kb_int4_g128",
    # Tensor-parallel ICI payload per token at the reference TP degree
    # below: the ONE row-parallel psum a sharded W4A4+LRC layer of this
    # row's output width emits (LRC partial merged into the same payload —
    # repro.launch.roofline.tp_psum_bytes_per_token), and the EP combine
    # psum over the row's d_in as the model width.  Guarded by
    # check_regression via the comms_kb_ prefix: collective-payload growth
    # >5% (an extra collective, an un-merged LRC psum) fails CI.
    "comms_kb_psum_tp8", "comms_kb_ep_tp8",
]

TP_REF = 8  # reference TP degree for the comms_kb_ columns


def _comms_kb_cols(k, n):
    """The two comms_kb_ column values for one (d_in=k, d_out=n) row."""
    return [
        round(tp_psum_bytes_per_token(n, TP_REF) / 1024, 2),
        round(ep_combine_bytes_per_token(k, TP_REF) / 1024, 2),
    ]

GROUP_COLUMN_G = 128  # the paper's headline group size for the _g128 columns

# Reference attention geometry for the attn_kb_ columns (Llama-2-70B-style
# GQA: 8 KV heads x 128 head dim) — fixed so the columns compare across rows
# on context length alone.
KV_REF_HEADS = 8
KV_REF_HEAD_DIM = 128
KV_GROUP_G = 128


def _attn_kb_cols(context_len):
    """The three attn_kb_ column values for one row (KiB, rounded)."""
    return [
        round(attention_kv_bytes(context_len, KV_REF_HEADS, KV_REF_HEAD_DIM,
                                 kv_dtype="f32") / 1024, 1),
        round(attention_kv_bytes(context_len, KV_REF_HEADS, KV_REF_HEAD_DIM,
                                 kv_dtype="int8") / 1024, 1),
        round(attention_kv_bytes(context_len, KV_REF_HEADS, KV_REF_HEAD_DIM,
                                 kv_dtype="int4", kv_group=KV_GROUP_G)
              / 1024, 1),
    ]


def _roofline_time(m, k, n, r, path: str, bm: int = None, ctx=None,
                   act_group=None):
    """Bytes + flops → v5e time bound for the W4A4(+LR) layer on one path.

    The K-split grid streams the f32 U/V factors from HBM once per M-tile
    (they are no longer VMEM-resident across the whole problem), so the
    factor traffic scales with ceil(m/bm) — ``bm`` defaults to the plan
    table's M tile for the regime (from ``ctx``; None -> the analytic
    defaults).  ``act_group`` swaps the per-token scale term of the
    intermediate traffic for the (M, K/g) scale plane."""
    if bm is None:
        from repro.kernels.context import KernelContext

        bm = (ctx or KernelContext()).select_plan(m, k, n, r).bm
    n_m = -(-m // bm)
    bytes_w = k * n / 2 + 4 * n  # packed int4 + scales
    bytes_x = m * k * 2  # bf16 activations read
    bytes_out = m * n * 4
    bytes_lr_w = n_m * (k * r + n * r) * 4 if r else 0  # f32 U/V per M-tile
    # xq + sx (per-token column or scale plane) + xv — the shared spelling
    inter = prologue_intermediate_bytes(m, k, r, act_group=act_group)
    total_bytes = bytes_w + bytes_x + bytes_out + bytes_lr_w
    if path in ("chained", "unfused"):
        total_bytes += 2 * inter  # prologue writes xq/sx/xv; GEMM reads back
    if path == "fused_stream":
        total_bytes += bytes_x  # the first GEMM visit re-streams x
    if path == "unfused":
        if r:
            # separate LR pass: re-read x, read+write the output again
            total_bytes += bytes_x + 2 * bytes_out
        total_bytes += 2 * bytes_x  # online-rotation pass: x round-trip
    flops = 2 * m * k * n + (2 * m * (k + n) * r if r else 0)
    # int8 MXU runs ~2x bf16 peak on the GEMM portion
    t_compute = (2 * m * k * n) / (2 * PEAK_FLOPS) \
        + (flops - 2 * m * k * n) / PEAK_FLOPS
    t_mem = total_bytes / HBM_BW
    return max(t_compute, t_mem)


def analytic_rows(ms=MS, sizes=SIZES, ranks=RANKS):
    """The roofline rows — shared by the full benchmark run and the CI
    regression gate (which recomputes them against the committed baseline)."""
    rows = []
    for m in ms:
        for k, n in sizes:
            # fp16 reference roofline: bf16 weights dominate
            t_fp16 = max((2 * m * k * n) / PEAK_FLOPS,
                         (k * n * 2 + m * (k + n) * 2) / HBM_BW)
            for r in ranks:
                t_un = _roofline_time(m, k, n, r, "unfused")
                t_ch = _roofline_time(m, k, n, r, "chained")
                t_fu = _roofline_time(m, k, n, r, "fused")
                t_fs = _roofline_time(m, k, n, r, "fused_stream")
                g = GROUP_COLUMN_G
                t_ch_g = _roofline_time(m, k, n, r, "chained", act_group=g)
                act = {p: prologue_activation_bytes(m, k, r, rotate=True,
                                                    path=p)
                       for p in ("unfused", "chained", "fused",
                                 "fused_stream")}
                act_ch_g = prologue_activation_bytes(
                    m, k, r, rotate=True, path="chained", act_group=g)
                rows.append([
                    f"M{m}_{n}x{k}", r,
                    round(t_un * 1e6, 1), round(t_ch * 1e6, 1),
                    round(t_fu * 1e6, 1),
                    round(t_fp16 / t_un, 2), round(t_fp16 / t_fu, 2),
                    round(t_fu / t_ch, 3),
                    round(act["unfused"] / 1024, 1),
                    round(act["chained"] / 1024, 1),
                    round(act["fused"] / 1024, 1),
                    round(act["chained"] / act["fused"], 2),
                    round(t_fs * 1e6, 1),
                    round(act["fused_stream"] / 1024, 1),
                    round(t_ch_g * 1e6, 1),
                    round(act_ch_g / 1024, 1),
                    *_attn_kb_cols(m),
                    *_comms_kb_cols(k, n),
                ])
    return rows


def smoke_rows(ctx=None):
    """Run the three kernel paths for real (pallas interpret mode): small
    decode/mixed shapes, the rank-1024 large-K no-demotion shape, and a
    g=128 group-wise-scale shape.  Cross-path bitwise parity + wall-clock;
    the big shape additionally asserts that auto dispatch resolves to the
    fused path (the old whole-V VMEM ceiling would have demoted it to
    unfused), and the grouped shape asserts the same (group-wise scales
    used to demote straight to the jnp int8 GEMM).  ``ctx`` is the
    KernelContext to run under (None -> analytic defaults)."""
    from benchmarks.common import make_w4a4_problem
    from repro.kernels import ops
    from repro.kernels.context import KernelContext

    ctx = ctx or KernelContext()
    rng = np.random.default_rng(0)
    rows = []
    # (m, k, n, r, rotate, act_group) — decode and mixed regime shapes, odd
    # N included, the K-split acceptance shape (K×R×4 = 32 MB of V, 4× the
    # old 8 MB whole-VMEM ceiling) and the grouped acceptance shape (g=128
    # scale plane through the fused path).
    shapes = [
        (16, 256, 512, 0, False, None),
        (16, 256, 512, 32, True, None),
        (16, 512, 300, 64, False, None),
        (64, 256, 256, 32, True, None),
        (16, 8192, 256, 1024, True, None),  # previously demoted to unfused
        (16, 512, 256, 32, True, 128),  # previously demoted to jnp int8
    ]
    for m, k, n, r, rot, g in shapes:
        big = k * r * 4 > ctx.prologue_vmem_bytes
        if big or g is not None:
            plan = ctx.resolve_plan(m, k, n, r, rotate=rot, act_group=g)
            assert plan.path == "fused", \
                f"fast-path regression: {(m, k, n, r, g)} resolved to {plan}"
            if g is not None:
                assert plan.bk % g == 0, (plan, g)
        spec, x, wp, s, u, v = make_w4a4_problem(rng, m, k, n, r,
                                                 act_group=g)
        outs, times = {}, {}
        for impl in ("unfused", "chained", "fused", "auto"):
            f = lambda: ops.w4a4_lrc_forward(x, wp, s, u, v, spec,
                                             rotate=rot, impl=impl,
                                             ctx=ctx)
            f().block_until_ready()  # compile
            t0 = time.time()
            out = f().block_until_ready()
            times[impl] = (time.time() - t0) * 1e6
            outs[impl] = np.asarray(out)
        bitwise = (np.array_equal(outs["fused"], outs["chained"])
                   and np.array_equal(outs["fused"], outs["unfused"])
                   and np.array_equal(outs["fused"], outs["auto"]))
        assert bitwise, f"cross-path mismatch at {(m, k, n, r, rot, g)}"
        # the standard columns stay PER-TOKEN for every row (one scale
        # granularity per column — comparable across rows); grouped bytes
        # go only in the dedicated _g128 column
        act_ch = prologue_activation_bytes(m, k, r, rotate=rot,
                                           path="chained")
        act_fu = prologue_activation_bytes(m, k, r, rotate=rot, path="fused")
        act_ch_g = prologue_activation_bytes(
            m, k, r, rotate=rot, path="chained", act_group=GROUP_COLUMN_G)
        rows.append([
            f"M{m}_{n}x{k}_r{r}{'_rot' if rot else ''}"
            + (f"_g{g}" if g else ""),
            r,
            round(times["unfused"], 1), round(times["chained"], 1),
            round(times["fused"], 1),
            "", "", "",
            round(prologue_activation_bytes(m, k, r, rotate=rot,
                                            path="unfused") / 1024, 1),
            round(act_ch / 1024, 1), round(act_fu / 1024, 1),
            round(act_ch / act_fu, 2),
            "",
            round(prologue_activation_bytes(m, k, r, rotate=rot,
                                            path="fused_stream") / 1024, 1),
            "",
            round(act_ch_g / 1024, 1),
            *_attn_kb_cols(m),
            *_comms_kb_cols(k, n),
        ])
    return rows


def run(smoke: bool = False, ctx=None):
    if smoke:
        rows = smoke_rows(ctx=ctx)
        record("latency_kernels_smoke", rows, HEADER)
        return rows

    rows = analytic_rows()
    record("latency_kernels", rows, HEADER)

    # CPU wall sanity (its own table — the roofline columns don't apply):
    # relative cost of the int8 path with/without LR at a small size
    from repro.quant.qlinear import make_qlinear, qlinear_apply

    rng = np.random.default_rng(0)
    d_in, d_out, r = 1024, 2048, 128
    q = jnp.asarray(rng.integers(-8, 8, (d_out, d_in)), jnp.int8)
    s = jnp.ones((d_out, 1), jnp.float32) * 0.02
    x = jnp.asarray(rng.standard_normal((256, d_in)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((d_out, r)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((d_in, r)), jnp.float32)

    def timed(ql):
        f = jax.jit(lambda x: qlinear_apply(ql, x))
        f(x).block_until_ready()
        t0 = time.time()
        for _ in range(10):
            f(x).block_until_ready()
        return (time.time() - t0) / 10 * 1e6

    t0 = timed(make_qlinear(q, s, None, None, impl="int8"))
    t1 = timed(make_qlinear(q, s, u, v, impl="int8", lr_dtype=jnp.float32))
    record("latency_cpu_sanity",
           [["cpu_int8_1024x2048", r, round(t0, 1), round(t1, 1),
             round(t1 / t0, 3)]],
           ["case", "ranks", "us_int8_nolr", "us_int8_lr", "lr_overhead"])
    return rows


if __name__ == "__main__":
    from repro.kernels.context import context_from_flags, vmem_budget_arg

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="run the actual kernels in interpret mode (small "
                         "decode/mixed shapes + the rank-1024 large-K "
                         "no-demotion shape; CI bench-smoke job)")
    ap.add_argument("--block-table", default=None,
                    help="block-table JSON to build the KernelContext the "
                         "smoke runs under (default: analytic defaults)")
    ap.add_argument("--vmem-budget", type=vmem_budget_arg, default=None,
                    help="override both VMEM working-set budgets (positive "
                         "bytes) in the smoke's KernelContext")
    args = ap.parse_args()
    run(smoke=args.smoke,
        ctx=context_from_flags(args.block_table, args.vmem_budget))
