"""Paper Tables 6-8 — layer latency vs. rank (Llama matrix sizes).

The paper timed Cutlass int4 on an A100 and found even 128 ranks cost 23-52%
extra latency (unfused second pass).  No TPU is attached here, so we report:

  * the ROOFLINE-MODEL v5e latency of the unfused layer (int4 GEMM bytes +
    a separate LR pass) vs. the FUSED kernel (one activation read, one output
    write — kernels/w4a4.py), derived from exact byte/FLOP counts;
  * measured CPU wall-clock of the int8 execution path as a sanity ratio
    (relative, not absolute).

Derived column = fused/unfused predicted-latency ratio — the win the paper's
§5 speculates about.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import record
from repro.launch.roofline import HBM_BW, PEAK_FLOPS, prologue_activation_bytes

# (d_in, d_out) from the Llama family, as in paper Tables 6-8
SIZES = [(4096, 11008), (5120, 13824), (8192, 28672)]
RANKS = [0, 128, 256, 512, 1024]
# Three serving regimes: decode (M=16, weight-bound), mixed (M=256), and the
# paper's prefill setting (M=2048+, compute-bound on TPU).  The fusion win
# lives in the memory-bound regimes; at the paper's M the v5e GEMM is
# compute-bound and fusion only saves energy/bytes, not latency.
MS = [16, 256, 2048]


def _roofline_time(m, k, n, r, fused: bool):
    """Bytes + flops → v5e time bound for the W4A4(+LR) layer."""
    bytes_w = k * n / 2 + 4 * n  # packed int4 + scales
    bytes_x = m * k * 2  # bf16 activations read
    bytes_q = m * k  # int8 quantized copy written+read
    bytes_out = m * n * 4
    bytes_lr = (k * r + n * r) * 2 + m * r * 4 if r else 0
    if fused or r == 0:
        total_bytes = bytes_w + bytes_x + bytes_q + bytes_out + bytes_lr
    else:
        # unfused: second pass re-reads x and re-writes the output
        total_bytes = bytes_w + bytes_x + bytes_q + 2 * bytes_out + bytes_lr + bytes_x
    flops = 2 * m * k * n + (2 * m * (k + n) * r if r else 0)
    # int8 MXU runs ~2x bf16 peak on the GEMM portion
    t_compute = (2 * m * k * n) / (2 * PEAK_FLOPS) + (flops - 2 * m * k * n) / PEAK_FLOPS
    t_mem = total_bytes / HBM_BW
    return max(t_compute, t_mem)


def run():
    rows = []
    rng = np.random.default_rng(0)
    for m in MS:
        for k, n in SIZES:
            # fp16 reference roofline: bf16 weights dominate
            t_fp16 = max((2 * m * k * n) / PEAK_FLOPS,
                         (k * n * 2 + m * (k + n) * 2) / HBM_BW)
            for r in RANKS:
                t_unfused = _roofline_time(m, k, n, r, fused=False)
                t_fused = _roofline_time(m, k, n, r, fused=True)
                # activation-prologue HBM traffic (rotate→quantize→project,
                # online-rotated serving path): three passes vs. the fused
                # kernels/prologue.py single pass
                act_unfused = prologue_activation_bytes(m, k, r, rotate=True,
                                                        fused=False)
                act_fused = prologue_activation_bytes(m, k, r, rotate=True,
                                                      fused=True)
                rows.append([
                    f"M{m}_{n}x{k}", r,
                    round(t_unfused * 1e6, 1), round(t_fused * 1e6, 1),
                    round(t_fp16 / t_unfused, 2), round(t_fp16 / t_fused, 2),
                    round(t_fused / t_unfused, 3),
                    round(act_unfused / 1024, 1), round(act_fused / 1024, 1),
                    round(act_unfused / act_fused, 2),
                ])
    # CPU wall sanity: relative cost of the int8 path with/without LR (small size)
    from repro.quant.qlinear import make_qlinear, qlinear_apply

    d_in, d_out, r = 1024, 2048, 128
    q = jnp.asarray(rng.integers(-8, 8, (d_out, d_in)), jnp.int8)
    s = jnp.ones((d_out, 1), jnp.float32) * 0.02
    x = jnp.asarray(rng.standard_normal((256, d_in)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((d_out, r)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((d_in, r)), jnp.float32)

    def timed(ql):
        f = jax.jit(lambda x: qlinear_apply(ql, x))
        f(x).block_until_ready()
        t0 = time.time()
        for _ in range(10):
            f(x).block_until_ready()
        return (time.time() - t0) / 10 * 1e6

    t0 = timed(make_qlinear(q, s, None, None, impl="int8"))
    t1 = timed(make_qlinear(q, s, u, v, impl="int8", lr_dtype=jnp.float32))
    rows.append(["cpu_sim_1024x2048", r, round(t0, 1), round(t1, 1),
                 "", "", round(t1 / t0, 3), "", "", ""])
    record(
        "latency_kernels", rows,
        ["matrix", "ranks", "us_unfused", "us_fused",
         "speedup_vs_fp16_unfused", "speedup_vs_fp16_fused", "fused_over_unfused",
         "act_prologue_kb_unfused", "act_prologue_kb_fused",
         "act_prologue_byte_ratio"],
    )
    return rows


if __name__ == "__main__":
    run()
