"""Shared benchmark infrastructure.

The paper evaluates WikiText-2 PPL + lm-eval accuracy on public LLMs; this
container is offline, so every table is reproduced on a small llama-family
model TRAINED on the synthetic corpus (so quantization deltas move a real
metric), with:

  PPL   — exp(next-token CE) on held-out synthetic text,
  ACC   — next-token top-1 accuracy (the measurable analogue of the paper's
          lm-eval average).

The trained model is cached under results/bench_model so the 6 table/figure
benchmarks share one training run.
"""

from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.quantizers import QuantSpec, pack_int4
from repro.models import model as model_lib
from repro.models.config import reduced
from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint, latest_step
from repro.data.loader import batches, calib_sequences
from repro.quant.calibrate import quantize_model
from repro.quant.policy import QuantPolicy

ROOT = Path(__file__).resolve().parents[1]
RESULTS = ROOT / "results"
BENCH_DIR = RESULTS / "bench_model"

# the benchmark model: llama-family (the paper's Phi-3/Llama setting, scaled)
BENCH_CFG = reduced(
    get_config("smollm-135m"),
    n_layers=4,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=384,
    vocab_size=512,
    tie_embeddings=False,
)
TRAIN_STEPS = 300


def get_bench_model(force: bool = False):
    """Train (or load cached) the shared benchmark model."""
    cfg = BENCH_CFG
    step = latest_step(BENCH_DIR)
    if step is not None and not force:
        like = jax.eval_shape(lambda k: model_lib.init_params(cfg, k),
                              jax.ShapeDtypeStruct((2,), jnp.uint32))
        params = load_checkpoint(BENCH_DIR / f"step_{step:08d}", like)
        return cfg, params
    from repro.train.trainer import train

    state, history, _ = train(cfg, steps=TRAIN_STEPS, global_batch=16,
                              seq_len=64, lr=3e-3,
                              log=lambda s: print(f"[bench-train] {s}"))
    save_checkpoint(BENCH_DIR, TRAIN_STEPS, state.params)
    return cfg, state.params


def eval_batches(cfg, n=4, bsz=8, seq=64, seed=77):
    it = batches(cfg, bsz, seq, seed=seed)
    return [b for _, b in (next(it) for _ in range(n))]


def ppl_and_acc(cfg, params, evals) -> tuple[float, float]:
    total_ll, total_acc, total_n = 0.0, 0.0, 0
    for batch in evals:
        logits = model_lib.forward(cfg, params, batch)
        toks = batch["tokens"]
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
        labels = toks[:, 1:]
        ll = jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
        pred = jnp.argmax(lp, axis=-1)
        total_ll += float(jnp.sum(ll))
        total_acc += float(jnp.sum(pred == labels))
        total_n += labels.size
    return float(np.exp(-total_ll / total_n)), total_acc / total_n


def calib_tokens(cfg, n_seq=24, seq=96):
    return calib_sequences(cfg, n_seq=n_seq, seq_len=seq, seed=123)


def make_policy(method: str, rank_frac: float = 0.10, act_group=None,
                act_bits: int = 4, lrc_iters: int = 1,
                quant_method: str = "gptq") -> QuantPolicy:
    """method: quarot | svd | lrc | rtn"""
    correction = {"quarot": "none", "svd": "svd", "lrc": "lrc", "rtn": "none"}[method]
    qm = "rtn" if method == "rtn" else quant_method
    rf = rank_frac if correction != "none" else 0.0
    return QuantPolicy(
        bits=4, act_bits=act_bits, act_group=act_group, rank_frac=rf,
        clip_ratio=0.9, impl="sim", lrc_iters=lrc_iters,
        quant_method=qm, correction=correction,
    )


def quantize(cfg, params, policy, calib):
    return quantize_model(cfg, params, calib, policy, rotate=True)


def make_w4a4_problem(rng, m: int, k: int, n: int, r: int, act_group=None):
    """Random (spec, x, wpacked, w_scale, u, v) W4A4+LRC problem in the
    layout ops.w4a4_lrc_forward expects — ONE definition shared by the
    bench smoke, the autotune measure mode, and the kernel parity tests, so
    they all exercise the same problem family.  ``act_group`` puts the
    activation quantizer on per-group scales (paper Table 2)."""
    spec = QuantSpec(bits=4, clip_ratio=0.9, group_size=act_group)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    q = jnp.asarray(rng.integers(-8, 8, (n, k)), jnp.int8)
    s = jnp.asarray(rng.uniform(0.01, 0.2, (n,)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((n, r)), jnp.float32) if r else None
    v = jnp.asarray(rng.standard_normal((k, r)), jnp.float32) if r else None
    return spec, x, pack_int4(q).T, s, u, v


def record(table: str, rows, header):
    RESULTS.mkdir(exist_ok=True)
    out = RESULTS / f"{table}.json"
    out.write_text(json.dumps(dict(header=header, rows=rows), indent=2))
    # CSV to stdout per harness contract
    print(f"# {table}")
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    return out
