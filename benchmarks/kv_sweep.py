"""KV-cache quantization accuracy sweep: PPL/ACC deltas of serving the
bench model out of f32 vs int8 vs int4-g128 paged KV pools.

The weight path stays FP — this isolates the KV cache as the only
quantized tensor, so the delta columns are attributable to
``repro.serve.kvquant`` alone (quantize-at-append + dequant fused into the
flash kernels), not to weight quantization.  Each sweep point runs the
REAL serving forward (``model.paged_step`` over a paged pool with per-row
block tables) on the full eval sequences, so quantization error compounds
across positions exactly as it does in the engine; the f32 paged row is
the numerical control — it must sit at the dense-forward reference PPL up
to kernel accumulation order.

Note the bench model's head_dim (32) clamps the requested int4 g=128 to
per-head scales (``KVSpec.group_for``); at real geometries (head_dim >=
128) the same spec yields true 128-wide groups.  The bytes/reduction
columns are reported at BOTH geometries so the accuracy rows and the
acceptance-ratio rows stay in one table.

    PYTHONPATH=src python -m benchmarks.kv_sweep
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (eval_batches, get_bench_model, ppl_and_acc,
                               record)
from repro.models import model as model_lib
from repro.serve.kvquant import KVSpec

PAGE_SIZE = 16
# reference serving geometry for the reduction column (matches the
# attn_kb_ columns in benchmarks/latency_kernels.py)
REF_KV_HEADS, REF_HEAD_DIM = 8, 128

SWEEP = [
    ("f32", KVSpec()),
    ("int8", KVSpec(dtype="int8")),
    ("int4-g128", KVSpec(dtype="int4", group=128)),
]

HEADER = ["kv", "ppl", "acc", "delta_ppl", "delta_acc",
          "kv_bytes_per_token", "ref_bytes_per_token", "ref_reduction_vs_f32"]


def paged_ppl_and_acc(cfg, params, evals, spec: KVSpec):
    """PPL/ACC of the serving path: one full-sequence paged_step per batch
    (chunked prefill with chunk == seq), logits at every position scored as
    next-token CE — the paged analogue of ``benchmarks.common.ppl_and_acc``."""
    step = jax.jit(lambda p, t, pos, v, c, bt: model_lib.paged_step(
        cfg, p, t, pos, v, c, bt, kv_spec=spec)[0])
    total_ll, total_acc, total_n = 0.0, 0.0, 0
    for batch in evals:
        toks = jnp.asarray(batch["tokens"])
        b, s = toks.shape
        per_row = -(-s // PAGE_SIZE)
        num_pages = b * per_row + 1  # page 0 is the reserved null page
        cache = model_lib.init_paged_cache(cfg, num_pages, PAGE_SIZE,
                                           dtype=jnp.float32, kv_spec=spec)
        block_table = jnp.arange(1, num_pages,
                                 dtype=jnp.int32).reshape(b, per_row)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        valid = jnp.ones((b, s), bool)
        logits = step(params, toks, positions, valid, cache, block_table)
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
        labels = toks[:, 1:]
        ll = jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
        pred = jnp.argmax(lp, axis=-1)
        total_ll += float(jnp.sum(ll))
        total_acc += float(jnp.sum(pred == labels))
        total_n += labels.size
    return float(np.exp(-total_ll / total_n)), total_acc / total_n


def run():
    cfg, params = get_bench_model()
    evals = eval_batches(cfg)
    fp_ppl, fp_acc = ppl_and_acc(cfg, params, evals)
    ref_f32 = KVSpec().kv_bytes_per_token(REF_KV_HEADS, REF_HEAD_DIM)
    rows = [["fp-forward", round(fp_ppl, 4), round(fp_acc, 4), 0.0, 0.0,
             "", "", ""]]
    results = {}
    for name, spec in SWEEP:
        ppl, acc = paged_ppl_and_acc(cfg, params, evals, spec)
        bpt = cfg.n_layers * spec.kv_bytes_per_token(cfg.n_kv_heads,
                                                     cfg.head_dim)
        ref = spec.kv_bytes_per_token(REF_KV_HEADS, REF_HEAD_DIM)
        rows.append([name, round(ppl, 4), round(acc, 4),
                     round(ppl - fp_ppl, 4), round(acc - fp_acc, 4),
                     bpt, ref, round(ref_f32 / ref, 2)])
        results[name] = (ppl, acc)
    # the f32 paged row is a numerical control, not a quantization point:
    # it must land on the dense-forward reference up to accumulation order
    assert abs(results["f32"][0] - fp_ppl) < 0.05 * fp_ppl, \
        (results["f32"], fp_ppl)
    record("kv_sweep", rows, HEADER)
    return results


if __name__ == "__main__":
    run()
