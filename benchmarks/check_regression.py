"""CI roofline regression gate for the W4A4+LRC kernel byte model.

Recomputes the analytic roofline rows (benchmarks/latency_kernels.py) from
the CURRENT code and compares them against the committed baseline
``results/latency_kernels.json``:

  * every activation-byte column (``act_prologue_kb_{unfused,chained,fused}``,
    i.e. ``prologue_activation_bytes`` on all three kernel paths) and every
    predicted-latency column may not regress more than ``--tolerance``
    (default 5%) over the baseline;
  * the fused single-kernel path must stay STRICTLY below the chained path's
    activation bytes at decode shapes (the PR acceptance invariant: the M×K
    xq write+read is eliminated).

With ``--serve`` the gate instead compares a freshly measured serving run
(``results/BENCH_serve_smoke.json`` from ``benchmarks.serve_latency
--smoke``) against the committed ``results/BENCH_serve.json``.  Wall-clock
columns are informational (CI runners are too noisy); the gate guards the
DETERMINISTIC efficiency columns — ``decode_calls_per_token`` (must stay
exactly ``1/batch``: one batched decode call per engine step),
``prefill_chunks_per_prompt`` and ``kv_bytes_per_token`` (the quantized-KV
footprint per cached token; growth means the paged pools or scale planes
got fatter) — which are token-count invariant, so smoke rows compare
against the full baseline directly.

Exit status 1 on any violation — wire this after the bench-smoke step in CI.

    PYTHONPATH=src python -m benchmarks.check_regression \
        [--baseline results/latency_kernels.json] [--tolerance 0.05]
    PYTHONPATH=src python -m benchmarks.check_regression --serve \
        [--serve-current results/BENCH_serve_smoke.json]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from benchmarks.latency_kernels import HEADER, analytic_rows

# columns the gate protects: every predicted-latency, activation-byte and
# attention-KV-byte column the CURRENT code emits (lower is better,
# >tolerance growth fails).  Derived from HEADER so a new column added by a
# kernel change is guarded automatically — and a baseline that predates it
# fails with a clear "regenerate" message instead of a KeyError.
_GUARDED = [h for h in HEADER
            if h.startswith("us_") or h.startswith("act_prologue_kb_")
            or h.startswith("attn_kb_") or h.startswith("comms_kb_")]


def check(baseline_path: Path, tolerance: float) -> list[str]:
    try:
        baseline = json.loads(baseline_path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"baseline {baseline_path} is unreadable ({e}); "
                "regenerate it with: PYTHONPATH=src python -m "
                "benchmarks.latency_kernels"]
    if not isinstance(baseline, dict) or "header" not in baseline \
            or "rows" not in baseline:
        return [f"baseline {baseline_path} lacks header/rows; regenerate it "
                "with: PYTHONPATH=src python -m benchmarks.latency_kernels"]
    b_idx = {h: i for i, h in enumerate(baseline["header"])}
    missing = [c for c in _GUARDED + ["matrix", "ranks"] if c not in b_idx]
    if missing:
        return [f"baseline {baseline_path} lacks columns {missing} that the "
                "current benchmark emits — the committed baseline predates "
                "this code; regenerate it with: PYTHONPATH=src python -m "
                "benchmarks.latency_kernels"]
    short = [r for r in baseline["rows"] if len(r) < len(baseline["header"])]
    if short:
        return [f"baseline {baseline_path} has {len(short)} row(s) shorter "
                f"than its header ({len(baseline['header'])} columns); "
                "regenerate it with: PYTHONPATH=src python -m "
                "benchmarks.latency_kernels"]
    b_rows = {(r[b_idx["matrix"]], r[b_idx["ranks"]]): r
              for r in baseline["rows"]}
    c_idx = {h: i for i, h in enumerate(HEADER)}

    failures = []
    matched = 0
    for row in analytic_rows():
        key = (row[c_idx["matrix"]], row[c_idx["ranks"]])
        base = b_rows.get(key)
        if base is None:
            continue  # new shape, nothing to regress against
        matched += 1
        for col in _GUARDED:
            b, c = base[b_idx[col]], row[c_idx[col]]
            if not (isinstance(b, (int, float)) and isinstance(c, (int, float))):
                continue
            if b > 0 and c > b * (1.0 + tolerance):
                failures.append(
                    f"{key[0]} r={key[1]} {col}: {c} vs baseline {b} "
                    f"(+{(c / b - 1) * 100:.1f}% > {tolerance * 100:.0f}%)")
        # decode-shape invariant: the single kernel must beat the chain
        if key[0].startswith("M16_"):
            fu = row[c_idx["act_prologue_kb_fused"]]
            ch = row[c_idx["act_prologue_kb_chained"]]
            if not fu < ch:
                failures.append(
                    f"{key[0]} r={key[1]}: fused activation bytes {fu} kB "
                    f"not strictly below chained {ch} kB")
    if matched == 0:
        failures.append(
            f"no baseline rows matched current shapes — baseline "
            f"{baseline_path} is stale; regenerate it")
    return failures


# serving-efficiency columns the --serve gate protects.  Both are exact
# consequences of the engine's batching structure (see
# benchmarks/serve_latency.py), so ANY growth over baseline is a structural
# regression — but the shared --tolerance still applies for symmetry.
_SERVE_GUARDED = ["decode_calls_per_token", "prefill_chunks_per_prompt",
                  "kv_bytes_per_token"]
_SERVE_KEY = ["batch", "page_size", "prefill_chunk", "kv_dtype"]
_SERVE_REGEN = ("regenerate them with: PYTHONPATH=src python -m "
                "benchmarks.serve_latency (baseline) and "
                "PYTHONPATH=src python -m benchmarks.serve_latency --smoke "
                "(current)")


def _load_table(path: Path, needed: list[str]):
    """Load a benchmarks.common.record() table; return (err, idx, rows)."""
    try:
        data = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return f"{path} is unreadable ({e}); {_SERVE_REGEN}", None, None
    if not isinstance(data, dict) or "header" not in data or "rows" not in data:
        return f"{path} lacks header/rows; {_SERVE_REGEN}", None, None
    idx = {h: i for i, h in enumerate(data["header"])}
    missing = [c for c in needed if c not in idx]
    if missing:
        return (f"{path} lacks columns {missing} — it predates this code; "
                f"{_SERVE_REGEN}"), None, None
    short = [r for r in data["rows"] if len(r) < len(data["header"])]
    if short:
        return (f"{path} has {len(short)} row(s) shorter than its header; "
                f"{_SERVE_REGEN}"), None, None
    return None, idx, data["rows"]


def check_serve(baseline_path: Path, current_path: Path,
                tolerance: float) -> list[str]:
    needed = _SERVE_GUARDED + _SERVE_KEY
    err, b_idx, b_raw = _load_table(baseline_path, needed)
    if err:
        return [err]
    err, c_idx, c_rows = _load_table(current_path, needed)
    if err:
        return [err]
    b_rows = {tuple(r[b_idx[k]] for k in _SERVE_KEY): r for r in b_raw}

    failures = []
    matched = 0
    for row in c_rows:
        key = tuple(row[c_idx[k]] for k in _SERVE_KEY)
        tag = f"B={key[0]} page={key[1]} chunk={key[2]} kv={key[3]}"
        # structural invariant: ONE batched decode call per engine step,
        # independent of any baseline — 1/batch exactly
        cpt = row[c_idx["decode_calls_per_token"]]
        if abs(cpt - 1.0 / key[0]) > 1e-4:
            failures.append(
                f"{tag}: decode_calls_per_token {cpt} != 1/batch "
                f"({1.0 / key[0]:.6f}) — decode is no longer one batched "
                "call per step")
        base = b_rows.get(key)
        if base is None:
            continue  # new grid point, nothing to regress against
        matched += 1
        for col in _SERVE_GUARDED:
            b, c = base[b_idx[col]], row[c_idx[col]]
            if not (isinstance(b, (int, float)) and isinstance(c, (int, float))):
                continue
            if b > 0 and c > b * (1.0 + tolerance):
                failures.append(
                    f"{tag} {col}: {c} vs baseline {b} "
                    f"(+{(c / b - 1) * 100:.1f}% > {tolerance * 100:.0f}%)")
    if matched == 0:
        failures.append(
            f"no baseline rows matched current serve grid — baseline "
            f"{baseline_path} is stale; {_SERVE_REGEN}")
    return failures


def main(argv=None) -> int:
    results = Path(__file__).resolve().parents[1] / "results"
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline",
                    default=str(results / "latency_kernels.json"))
    ap.add_argument("--tolerance", type=float, default=0.05)
    ap.add_argument("--serve", action="store_true",
                    help="gate the serving benchmark instead of the kernel "
                         "roofline (compares --serve-current against "
                         "--serve-baseline)")
    ap.add_argument("--serve-baseline",
                    default=str(results / "BENCH_serve.json"))
    ap.add_argument("--serve-current",
                    default=str(results / "BENCH_serve_smoke.json"))
    args = ap.parse_args(argv)

    if args.serve:
        failures = check_serve(Path(args.serve_baseline),
                               Path(args.serve_current), args.tolerance)
        name = "serving regression gate"
        detail = (f"baseline {args.serve_baseline}, "
                  f"current {args.serve_current}")
    else:
        failures = check(Path(args.baseline), args.tolerance)
        name = "roofline regression gate"
        detail = f"baseline {args.baseline}"
    if failures:
        print(f"{name} FAILED:")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"{name} passed (tolerance {args.tolerance * 100:.0f}%, {detail})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
