"""CI roofline regression gate for the W4A4+LRC kernel byte model.

Recomputes the analytic roofline rows (benchmarks/latency_kernels.py) from
the CURRENT code and compares them against the committed baseline
``results/latency_kernels.json``:

  * every activation-byte column (``act_prologue_kb_{unfused,chained,fused}``,
    i.e. ``prologue_activation_bytes`` on all three kernel paths) and every
    predicted-latency column may not regress more than ``--tolerance``
    (default 5%) over the baseline;
  * the fused single-kernel path must stay STRICTLY below the chained path's
    activation bytes at decode shapes (the PR acceptance invariant: the M×K
    xq write+read is eliminated).

Exit status 1 on any violation — wire this after the bench-smoke step in CI.

    PYTHONPATH=src python -m benchmarks.check_regression \
        [--baseline results/latency_kernels.json] [--tolerance 0.05]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from benchmarks.latency_kernels import HEADER, analytic_rows

# columns the gate protects: every predicted-latency and activation-byte
# column the CURRENT code emits (lower is better, >tolerance growth fails).
# Derived from HEADER so a new column added by a kernel change is guarded
# automatically — and a baseline that predates it fails with a clear
# "regenerate" message instead of a KeyError.
_GUARDED = [h for h in HEADER
            if h.startswith("us_") or h.startswith("act_prologue_kb_")]


def check(baseline_path: Path, tolerance: float) -> list[str]:
    try:
        baseline = json.loads(baseline_path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"baseline {baseline_path} is unreadable ({e}); "
                "regenerate it with: PYTHONPATH=src python -m "
                "benchmarks.latency_kernels"]
    if not isinstance(baseline, dict) or "header" not in baseline \
            or "rows" not in baseline:
        return [f"baseline {baseline_path} lacks header/rows; regenerate it "
                "with: PYTHONPATH=src python -m benchmarks.latency_kernels"]
    b_idx = {h: i for i, h in enumerate(baseline["header"])}
    missing = [c for c in _GUARDED + ["matrix", "ranks"] if c not in b_idx]
    if missing:
        return [f"baseline {baseline_path} lacks columns {missing} that the "
                "current benchmark emits — the committed baseline predates "
                "this code; regenerate it with: PYTHONPATH=src python -m "
                "benchmarks.latency_kernels"]
    short = [r for r in baseline["rows"] if len(r) < len(baseline["header"])]
    if short:
        return [f"baseline {baseline_path} has {len(short)} row(s) shorter "
                f"than its header ({len(baseline['header'])} columns); "
                "regenerate it with: PYTHONPATH=src python -m "
                "benchmarks.latency_kernels"]
    b_rows = {(r[b_idx["matrix"]], r[b_idx["ranks"]]): r
              for r in baseline["rows"]}
    c_idx = {h: i for i, h in enumerate(HEADER)}

    failures = []
    matched = 0
    for row in analytic_rows():
        key = (row[c_idx["matrix"]], row[c_idx["ranks"]])
        base = b_rows.get(key)
        if base is None:
            continue  # new shape, nothing to regress against
        matched += 1
        for col in _GUARDED:
            b, c = base[b_idx[col]], row[c_idx[col]]
            if not (isinstance(b, (int, float)) and isinstance(c, (int, float))):
                continue
            if b > 0 and c > b * (1.0 + tolerance):
                failures.append(
                    f"{key[0]} r={key[1]} {col}: {c} vs baseline {b} "
                    f"(+{(c / b - 1) * 100:.1f}% > {tolerance * 100:.0f}%)")
        # decode-shape invariant: the single kernel must beat the chain
        if key[0].startswith("M16_"):
            fu = row[c_idx["act_prologue_kb_fused"]]
            ch = row[c_idx["act_prologue_kb_chained"]]
            if not fu < ch:
                failures.append(
                    f"{key[0]} r={key[1]}: fused activation bytes {fu} kB "
                    f"not strictly below chained {ch} kB")
    if matched == 0:
        failures.append(
            f"no baseline rows matched current shapes — baseline "
            f"{baseline_path} is stale; regenerate it")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline",
                    default=str(Path(__file__).resolve().parents[1]
                                / "results" / "latency_kernels.json"))
    ap.add_argument("--tolerance", type=float, default=0.05)
    args = ap.parse_args(argv)

    failures = check(Path(args.baseline), args.tolerance)
    if failures:
        print("roofline regression gate FAILED:")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"roofline regression gate passed "
          f"(tolerance {args.tolerance * 100:.0f}%, "
          f"baseline {args.baseline})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
