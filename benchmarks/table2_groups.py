"""Paper Table 2 — W4A4 with activation group-scaling (paper: 128; scaled to
the bench model's d_ff granularity: 64).

Evaluation runs on the FAST PATH: after calibration each quantized model is
retagged to ``impl="fused"`` so every grouped QLinear executes the
single-kernel pallas forward with the (M, K/g) scale plane (interpret mode
on CPU) — the regime this table measures is the one the kernels actually
serve, not the jnp int8 fallback it used to demote to.  The sim-path
numbers are kept alongside as the reference semantics.
"""

from __future__ import annotations

from benchmarks.common import (
    calib_tokens,
    eval_batches,
    get_bench_model,
    make_policy,
    ppl_and_acc,
    quantize,
    record,
)
from repro.quant.qlinear import retag_qlinear_impl

GROUP = 64


def run():
    cfg, params = get_bench_model()
    calib = calib_tokens(cfg)
    evals = eval_batches(cfg)
    rows = []
    fp_ppl, fp_acc = ppl_and_acc(cfg, params, evals)
    rows.append(["FP16", round(fp_ppl, 4), round(fp_acc, 4),
                 round(fp_ppl, 4), round(fp_acc, 4)])
    out = {"FP16": (fp_ppl, fp_acc)}
    for name, method, iters in [
        ("QuaRot", "quarot", 1),
        ("SVD", "svd", 1),
        ("LRC (1)", "lrc", 1),
        ("LRC (5)", "lrc", 5),
    ]:
        qp = quantize(cfg, params, make_policy(method, lrc_iters=iters, act_group=GROUP), calib)
        ppl, acc = ppl_and_acc(cfg, qp, evals)
        # the serving regime: grouped scale plane through the fused kernels
        ppl_f, acc_f = ppl_and_acc(cfg, retag_qlinear_impl(qp, "fused"),
                                   evals)
        rows.append([name, round(ppl, 4), round(acc, 4),
                     round(ppl_f, 4), round(acc_f, 4)])
        out[name] = (ppl_f, acc_f)
    record("table2_groups", rows,
           ["method", "ppl_sim", "acc_sim", "ppl_fused", "acc_fused"])
    return out


if __name__ == "__main__":
    run()
