"""Paper Table 2 — W4A4 with activation group-scaling (paper: 128; scaled to
the bench model's d_ff granularity: 64)."""

from __future__ import annotations

from benchmarks.common import (
    calib_tokens,
    eval_batches,
    get_bench_model,
    make_policy,
    ppl_and_acc,
    quantize,
    record,
)

GROUP = 64


def run():
    cfg, params = get_bench_model()
    calib = calib_tokens(cfg)
    evals = eval_batches(cfg)
    rows = []
    fp_ppl, fp_acc = ppl_and_acc(cfg, params, evals)
    rows.append(["FP16", round(fp_ppl, 4), round(fp_acc, 4)])
    out = {"FP16": (fp_ppl, fp_acc)}
    for name, method, iters in [
        ("QuaRot", "quarot", 1),
        ("SVD", "svd", 1),
        ("LRC (1)", "lrc", 1),
        ("LRC (5)", "lrc", 5),
    ]:
        qp = quantize(cfg, params, make_policy(method, lrc_iters=iters, act_group=GROUP), calib)
        ppl, acc = ppl_and_acc(cfg, qp, evals)
        rows.append([name, round(ppl, 4), round(acc, 4)])
        out[name] = (ppl, acc)
    record("table2_groups", rows, ["method", "ppl", "acc"])
    return out


if __name__ == "__main__":
    run()
