"""Request-lifecycle unit tests: the transition graph, admission control,
deadlines, cancellation, step-limit draining, and health snapshots.

The chaos/fault-injection suite lives in tests/test_serve_faults.py; this
file pins the state machine itself."""

import itertools

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model
from repro.models.config import reduced
from repro.serve.engine import ServeEngine
from repro.serve.lifecycle import (LEGAL_TRANSITIONS, TERMINAL_STATES,
                                   IllegalTransition, Request, RequestRecord,
                                   RequestState)


class FakeClock:
    """Deterministic engine clock; `sleep` advances it (wire it to the
    engine's and injector's sleep_fn to make backoff/slow faults burn
    virtual wall-clock)."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def sleep(self, s: float):
        self.t += s


@pytest.fixture(scope="module")
def served():
    cfg = reduced(get_config("smollm-135m"))
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
               for _ in range(4)]
    return cfg, params, prompts


def _req(rid=0, n=6, **kw):
    return Request(rid=rid, prompt=np.arange(n, dtype=np.int32), **kw)


# -- the transition graph itself -------------------------------------------


def test_every_transition_pair_legal_or_illegal():
    """Exhaustive: every (from, to) pair either advances or raises, exactly
    per LEGAL_TRANSITIONS — no edge is silently accepted."""
    for s1, s2 in itertools.product(RequestState, RequestState):
        req = _req()
        req.state = s1
        if s2 in LEGAL_TRANSITIONS[s1]:
            req.advance(s2, now=1.0)
            assert req.state is s2
        else:
            with pytest.raises(IllegalTransition):
                req.advance(s2, now=1.0)
            assert req.state is s1  # unchanged on refusal


def test_terminal_states_are_absorbing():
    for term in TERMINAL_STATES:
        assert LEGAL_TRANSITIONS[term] == frozenset()
        req = _req()
        req.state = term
        assert req.done
        for s2 in RequestState:
            with pytest.raises(IllegalTransition):
                req.advance(s2)


def test_rejected_only_reachable_from_queued():
    sources = [s for s in RequestState
               if RequestState.REJECTED in LEGAL_TRANSITIONS[s]]
    assert sources == [RequestState.QUEUED]


def test_advance_stamps_timestamps():
    req = _req()
    req.submitted_at = 1.0
    req.advance(RequestState.PREFILLING, now=2.0)
    req.advance(RequestState.DECODING, now=3.0)
    req.first_token_at = 3.0
    req.advance(RequestState.FINISHED, now=5.0)
    rec = RequestRecord.from_request(req)
    assert rec.timings["queue_s"] == pytest.approx(1.0)
    assert rec.timings["first_token_s"] == pytest.approx(2.0)
    assert rec.timings["total_s"] == pytest.approx(4.0)


def test_record_requires_terminal_state():
    req = _req()
    with pytest.raises(IllegalTransition):
        RequestRecord.from_request(req)
    req.advance(RequestState.CANCELLED, now=1.0)
    rec = RequestRecord.from_request(req)
    assert rec.status is RequestState.CANCELLED and not rec.ok
    assert rec.prompt_tokens == 6 and rec.new_tokens == 0


# -- admission control ------------------------------------------------------


def test_submit_rejects_bad_input(served):
    cfg, params, _ = served
    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=8)
    cases = {
        0: (Request(rid=0, prompt=np.zeros(0, np.int32)), "empty_prompt"),
        1: (Request(rid=1, prompt=np.zeros(4, np.float32)), "bad_token_ids"),
        2: (Request(rid=2, prompt=np.full(4, cfg.vocab_size, np.int32)),
            "bad_token_ids"),
        3: (Request(rid=3, prompt=np.arange(8, dtype=np.int32)),
            "prompt_too_long"),  # len == max_seq would overflow the cache
        4: (_req(rid=4, n=4, max_new_tokens=0), "bad_token_budget"),
        5: (_req(rid=5, n=4, deadline_s=-1.0), "bad_deadline"),
    }
    for rid, (req, kind) in cases.items():
        assert eng.submit(req) is False
        rec = eng.records[rid]
        assert rec.status is RequestState.REJECTED and rec.error_kind == kind
    # a valid one still goes through, then its rid is taken
    assert eng.submit(_req(rid=6, n=4)) is True
    dup = _req(rid=6, n=4)
    assert eng.submit(dup) is False  # duplicate while queued
    assert dup.state is RequestState.REJECTED
    assert dup.error_kind == "duplicate_rid"
    done = eng.run()
    assert done[6].ok
    assert eng.submit(_req(rid=6, n=4)) is False  # duplicate vs. records
    assert eng.records[6].ok  # ...which did NOT clobber the finished record


def test_queue_bound_reject_new(served):
    cfg, params, _ = served
    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=16, queue_limit=2)
    assert [eng.submit(_req(rid=i, n=4)) for i in range(4)] == [
        True, True, False, False]
    assert {r: eng.records[r].error_kind for r in (2, 3)} == {
        2: "queue_full", 3: "queue_full"}
    done = eng.run()
    assert done[0].ok and done[1].ok


def test_queue_bound_drop_oldest(served):
    cfg, params, _ = served
    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=16, queue_limit=2,
                      queue_policy="drop_oldest")
    for i in range(4):
        eng.submit(_req(rid=i, n=4))
    # 2 and 3 displaced 0 and 1
    assert eng.records[0].error_kind == "queue_evicted"
    assert eng.records[1].error_kind == "queue_evicted"
    done = eng.run()
    assert done[2].ok and done[3].ok


def test_bad_queue_policy_rejected(served):
    cfg, params, _ = served
    with pytest.raises(ValueError, match="queue_policy"):
        ServeEngine(cfg, params, queue_policy="nope")


# -- cancellation -----------------------------------------------------------


def test_cancel_queued_and_inflight_and_unknown(served):
    cfg, params, prompts = served
    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=32)
    for i, p in enumerate(prompts[:3]):
        eng.submit(Request(rid=i, prompt=p.copy(), max_new_tokens=5))
    assert eng.cancel(99) is False
    assert eng.cancel(2) is True  # still queued
    eng._admit()  # rid 0 prefills into the slot
    assert eng.cancel(0) is True  # in flight, keeps its prefill token
    done = eng.run()
    assert done[2].status is RequestState.CANCELLED and done[2].new_tokens == 0
    assert done[0].status is RequestState.CANCELLED and done[0].new_tokens == 1
    assert done[1].ok and done[1].new_tokens == 5
    assert eng.cancel(1) is False  # already terminal


# -- deadlines --------------------------------------------------------------


def test_deadline_expires_while_queued(served):
    cfg, params, prompts = served
    fc = FakeClock()
    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=32, clock=fc,
                      sleep_fn=fc.sleep)
    eng.submit(Request(rid=0, prompt=prompts[0].copy(), deadline_s=5.0))
    fc.t = 6.0
    done = eng.run()
    rec = done[0]
    assert rec.status is RequestState.TIMED_OUT
    assert rec.error_kind == "deadline" and rec.new_tokens == 0


def test_deadline_expires_in_flight(served):
    cfg, params, prompts = served
    fc = FakeClock()
    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=32, clock=fc,
                      sleep_fn=fc.sleep)
    eng.submit(Request(rid=0, prompt=prompts[0].copy(), max_new_tokens=50,
                       deadline_s=5.0))
    eng._admit()  # prefill at t=0, one token out
    fc.t = 6.0
    done = eng.run()
    rec = done[0]
    assert rec.status is RequestState.TIMED_OUT and rec.error_kind == "deadline"
    assert rec.new_tokens >= 1  # partial output is preserved in the record


def test_default_deadline_applies(served):
    cfg, params, prompts = served
    fc = FakeClock()
    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=32, clock=fc,
                      sleep_fn=fc.sleep, default_deadline_s=5.0)
    eng.submit(Request(rid=0, prompt=prompts[0].copy()))
    fc.t = 6.0
    assert eng.run()[0].status is RequestState.TIMED_OUT


# -- prefill-token termination (the old off-by-one) -------------------------


def test_max_new_tokens_one_yields_one_token(served):
    cfg, params, prompts = served
    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=32)
    eng.submit(Request(rid=0, prompt=prompts[0].copy(), max_new_tokens=1))
    done = eng.run()
    assert done[0].ok and len(done[0].out_tokens) == 1


def test_eos_at_prefill_terminates(served):
    cfg, params, prompts = served
    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=32)
    eng.submit(Request(rid=0, prompt=prompts[0].copy(), max_new_tokens=5))
    first_tok = eng.run()[0].out_tokens[0]

    eng2 = ServeEngine(cfg, params, batch_slots=1, max_seq=32,
                       eos_id=int(first_tok))
    eng2.submit(Request(rid=0, prompt=prompts[0].copy(), max_new_tokens=5))
    rec = eng2.run()[0]
    assert rec.ok and rec.out_tokens == [first_tok]  # EOS honored at prefill


# -- step-limit draining ----------------------------------------------------


def test_step_limit_returns_timed_out_records(served):
    """Requests still occupying slots (or queued) when max_steps trips must
    come back as TIMED_OUT records, not vanish."""
    cfg, params, prompts = served
    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=64)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=prompts[i].copy(), max_new_tokens=40))
    done = eng.run(max_steps=3)
    assert sorted(done) == [0, 1, 2]  # nobody dropped
    assert all(done[i].status is RequestState.TIMED_OUT for i in range(3))
    assert all(done[i].error_kind == "step_limit" for i in range(3))
    assert done[0].new_tokens >= 1  # the in-flight one keeps its tokens
    assert done[2].new_tokens == 0  # the queued ones never started


# -- health -----------------------------------------------------------------


def test_health_snapshot_fields(served):
    cfg, params, prompts = served
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=32)
    h0 = eng.health()
    assert [s["state"] for s in h0["slots"]] == ["idle", "idle"]
    assert h0["queue_depth"] == 0 and not h0["stalled"]
    for i in range(3):
        eng.submit(Request(rid=i, prompt=prompts[i].copy(), max_new_tokens=4))
    eng._admit()
    h1 = eng.health()
    assert [s["state"] for s in h1["slots"]] == ["decoding", "decoding"]
    assert h1["queue_depth"] == 1
    assert h1["counters"]["admitted"] == 2
    eng.run()
    h2 = eng.health()
    assert h2["counters"]["finished"] == 3
    assert h2["counters"]["retries"] == 0
    assert h2["steps_since_progress"] == 0


def test_run_returns_records_not_requests(served):
    cfg, params, prompts = served
    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=32)
    eng.submit(Request(rid=0, prompt=prompts[0].copy(), max_new_tokens=3))
    done = eng.run()
    assert isinstance(done[0], RequestRecord)
    assert done[0].status is RequestState.FINISHED
    assert done[0].prompt_tokens == 6 and done[0].new_tokens == 3
    assert done[0].timings["total_s"] >= 0.0
