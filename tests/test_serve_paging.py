"""Paged-KV serving: allocator invariants (property-tested), one-batched-
decode-call accounting, chunked-prefill interleaving, and the invariance
guarantees that make paging safe — outputs must not depend on page
placement, page size, chunking, or batch co-tenancy."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import model
from repro.models.config import reduced
from repro.serve.engine import Request, RequestState, ServeEngine
from repro.serve.paging import NULL_PAGE, PageAllocator


# ---------------------------------------------------------------------------
# allocator property tests
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(num_pages=st.integers(2, 48), page_size=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1), sidecar=st.booleans())
def test_allocator_roundtrip_never_leaks_or_double_frees(
        num_pages, page_size, seed, sidecar):
    """Random ensure/free interleavings: every invariant in paging.py holds
    after every operation, a refused ensure commits nothing, and freeing
    everything returns the allocator to full capacity.  With ``sidecar``
    (quantized KV specs) the scale-plane accounting must additionally stay
    in LOCKSTEP with the page pool through the whole interleaving —
    ``check()`` asserts both after every single op."""
    alloc = PageAllocator(num_pages, page_size, sidecar=sidecar)
    rng = np.random.default_rng(seed)
    mirror = {}  # rid -> page count we believe it holds
    for _ in range(60):
        rid = int(rng.integers(0, 6))
        if rng.integers(2) and mirror:
            victim = int(rng.choice(sorted(mirror)))
            freed = alloc.free(victim)
            assert freed == mirror.pop(victim)
            # double free is a no-op, never a second refund
            assert alloc.free(victim) == 0
        else:
            n_tokens = int(rng.integers(0, 8 * page_size + 1))
            before = (alloc.free_pages, alloc.holds(rid))
            got = alloc.ensure(rid, n_tokens)
            need = alloc.pages_for(n_tokens) - before[1]
            if got is None:
                # refused: the request outgrew the free list, and nothing
                # was committed (no partial allocation)
                assert need > before[0]
                assert (alloc.free_pages, alloc.holds(rid)) == before
            else:
                assert len(got) == max(need, 0)
                assert NULL_PAGE not in got
                if alloc.holds(rid):
                    mirror[rid] = alloc.holds(rid)
                # idempotent: re-ensuring a covered length allocates nothing
                assert alloc.ensure(rid, n_tokens) == []
        alloc.check()
        assert alloc.used_pages == sum(mirror.values())
    for rid in list(mirror):
        alloc.free(rid)
    alloc.check()
    assert alloc.free_pages == alloc.capacity and alloc.used_pages == 0
    if sidecar:
        # full cycle returned every scale plane too, in the same LIFO order
        assert alloc._side_free == alloc._free
        assert alloc._side_owned == {}


@settings(max_examples=30, deadline=None)
@given(page_size=st.integers(1, 16), n_tokens=st.integers(0, 200))
def test_admission_accounting_is_exact_ceil(page_size, n_tokens):
    """pages_for is exactly the page count a request of n tokens occupies —
    the quantity admission control charges against the free list."""
    alloc = PageAllocator(64, page_size)
    expect = 0 if n_tokens <= 0 else (n_tokens + page_size - 1) // page_size
    assert alloc.pages_for(n_tokens) == expect
    got = alloc.ensure(7, n_tokens)
    if expect <= alloc.capacity:
        assert len(got) == expect == alloc.holds(7) == alloc.used_pages
        assert alloc.free_pages == alloc.capacity - expect
    else:
        assert got is None and alloc.used_pages == 0
    alloc.check()


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------


def _mk(rng, cfg, n, length):
    return [np.asarray(rng.integers(0, cfg.vocab_size, (length,)), np.int32)
            for _ in range(n)]


def test_one_decode_call_per_step_and_trace_count(rng):
    """The tentpole contract: one jitted paged call per engine decode step
    regardless of how many requests are active, and exactly TWO traces per
    engine config (one prefill chunk shape, one (B, 1) decode shape)."""
    cfg = reduced(get_config("smollm-135m"))
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    # unique (max_seq, page_size, batch) so this test owns its jit traces
    eng = ServeEngine(cfg, params, batch_slots=3, max_seq=40, page_size=8)
    assert eng.mode == "paged"
    t0 = eng.health()["traces"]["paged"]
    for i, p in enumerate(_mk(rng, cfg, 3, 7)):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    done = eng.run()
    assert all(done[i].ok and len(done[i].out_tokens) == 4 for i in range(3))
    # 3 requests x 3 decode tokens each ran in 3 batched calls, not 9
    assert eng.counters["decode_calls"] == 3
    assert eng.health()["traces"]["paged"] - t0 == 2
    # terminal transitions returned every page
    stats = eng.health()["kv_pages"]
    assert stats["used"] == 0 and stats["free"] == stats["capacity"]
    eng.alloc.check()


def test_outputs_invariant_to_pages_batch_and_chunking(rng):
    """The serving guarantee that makes paging invisible: tokens depend only
    on (params, prompt, seed) — not on which pages the request landed on,
    the page size, co-tenants, or prefill chunking."""
    cfg = reduced(get_config("smollm-135m"))
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prompts = _mk(rng, cfg, 4, 9)

    def run(batch_slots, page_size, prefill_chunk=None, occupy=0, kv_pages=None):
        eng = ServeEngine(cfg, params, batch_slots=batch_slots, max_seq=32,
                          page_size=page_size, prefill_chunk=prefill_chunk,
                          kv_pages=kv_pages)
        if occupy:
            # fragment the pool before any admission: requests land on
            # different physical pages than in a fresh engine
            assert eng.alloc.ensure(-1, occupy * page_size) is not None
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
        done = eng.run()
        assert all(done[i].ok for i in range(len(prompts))), done
        return [done[i].out_tokens for i in range(len(prompts))]

    base = run(batch_slots=4, page_size=8)
    assert run(batch_slots=1, page_size=8) == base          # co-tenancy
    assert run(batch_slots=2, page_size=5) == base          # page size (non-dividing)
    assert run(batch_slots=4, page_size=8, occupy=3,
               kv_pages=4 * 4 + 1 + 3) == base              # page placement
    assert run(batch_slots=2, page_size=8, prefill_chunk=4) == base  # chunking


def test_chunked_prefill_interleaves_with_decode(rng):
    """A long prompt prefilling in chunks must not stall a co-tenant's
    decode: the short request keeps emitting tokens between chunks."""
    cfg = reduced(get_config("smollm-135m"))
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    long_p, = _mk(rng, cfg, 1, 20)
    short_p, = _mk(rng, cfg, 1, 4)
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=64, page_size=8,
                      prefill_chunk=4)
    eng.submit(Request(rid=0, prompt=long_p, max_new_tokens=5))
    eng.submit(Request(rid=1, prompt=short_p, max_new_tokens=8))
    eng._admit()
    long_req = eng.slot_req[0]
    short_req = eng.slot_req[1]
    assert long_req.rid == 0 and long_req.state is RequestState.PREFILLING
    assert short_req.rid == 1 and short_req.state is RequestState.DECODING
    for _ in range(2):
        eng._prefill_tick()
        eng._step()
    # 3 of 5 chunks done for the long prompt; the short one decoded twice
    assert long_req.state is RequestState.PREFILLING
    assert len(long_req.out_tokens) == 0
    assert len(short_req.out_tokens) == 3
    done = eng.run()
    assert done[0].ok and done[1].ok
    # and chunking changed nothing about the long prompt's tokens
    ref = ServeEngine(cfg, params, batch_slots=2, max_seq=64, page_size=8)
    ref.submit(Request(rid=0, prompt=long_p, max_new_tokens=5))
    assert ref.run()[0].out_tokens == done[0].out_tokens


def test_pool_exhaustion_fails_request_and_frees_pages(rng):
    """An undersized pool: the decode-boundary allocation runs dry, the
    request FAILs with kv_pages_exhausted, and its pages come back."""
    cfg = reduced(get_config("smollm-135m"))
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    p, = _mk(rng, cfg, 1, 8)
    # capacity 3 pages of 4 = positions 0..11; prompt 8 + 5th new token
    # needs a 4th page
    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=32, page_size=4,
                      kv_pages=4, max_retries=0)
    eng.submit(Request(rid=0, prompt=p, max_new_tokens=8))
    done = eng.run()
    assert done[0].status is RequestState.FAILED
    assert done[0].error_kind == "kv_pages_exhausted"
    # prefill token + 4 decode tokens (positions 8..11) fit in 3 pages
    assert len(done[0].out_tokens) == 5
    assert eng.alloc.free_pages == eng.alloc.capacity
    eng.alloc.check()


def test_admission_backpressure_queues_until_pages_free(rng):
    """Two requests whose pages cannot coexist: the second waits in queue
    (FIFO backpressure, not rejection) and completes once the first frees
    its pages."""
    cfg = reduced(get_config("smollm-135m"))
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prompts = _mk(rng, cfg, 2, 10)
    # each needs 3 pages of 4; capacity 4 cannot hold both at once
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=32, page_size=4,
                      kv_pages=5)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=2))
    done = eng.run()
    assert done[0].ok and done[1].ok
    assert eng.alloc.free_pages == eng.alloc.capacity


def test_submit_rejects_prompt_exceeding_pool_capacity(rng):
    """Admission accounting is in PAGES: a prompt that fits max_seq but can
    never fit the pool is rejected up front, not deadlocked in queue."""
    cfg = reduced(get_config("smollm-135m"))
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    p, = _mk(rng, cfg, 1, 12)  # needs 4 pages of 4; capacity is 2
    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=32, page_size=4,
                      kv_pages=3)
    assert not eng.submit(Request(rid=0, prompt=p, max_new_tokens=2))
    assert eng.records[0].status is RequestState.REJECTED
    assert eng.records[0].error_kind == "kv_capacity"


def test_health_surfaces_mode_pages_and_decode_plan():
    cfg = reduced(get_config("smollm-135m"))
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    h = ServeEngine(cfg, params, batch_slots=2, max_seq=32).health()
    assert h["mode"] == "paged"
    assert h["kv_pages"]["capacity"] == h["kv_pages"]["free"]
    assert h["decode_plan"] is None  # FP params resolve no kernel plan

    ssm = reduced(get_config("mamba2-370m"))
    h2 = ServeEngine(ssm, model.init_params(ssm, jax.random.PRNGKey(0)),
                     batch_slots=2, max_seq=32).health()
    assert h2["mode"] == "stacked" and h2["kv_pages"] is None

    with pytest.raises(ValueError, match="prefill_chunk"):
        ServeEngine(ssm, model.init_params(ssm, jax.random.PRNGKey(0)),
                    batch_slots=2, max_seq=32, prefill_chunk=4)


@pytest.mark.parametrize("arch,want_mode", [
    ("smollm-135m", "paged"),       # dense -> paged KV pool
    ("mamba2-370m", "stacked"),     # ssm -> stacked recurrent rows
    ("paligemma-3b", "slots"),      # vlm -> legacy per-slot caches
    ("zamba2-7b", "slots"),         # hybrid -> legacy per-slot caches
    ("deepseek-v2-236b", "slots"),  # moe -> legacy per-slot caches
])
def test_health_mode_covers_every_cache_family(arch, want_mode):
    """Every family maps to exactly one decode-state layout, and health()
    names it: paged pools surface page stats, the others report None."""
    cfg = reduced(get_config(arch))
    eng = ServeEngine(cfg, model.init_params(cfg, jax.random.PRNGKey(0)),
                      batch_slots=2, max_seq=32)
    h = eng.health()
    assert h["mode"] == want_mode
    if want_mode == "paged":
        assert h["kv_pages"]["capacity"] > 0
    else:
        assert h["kv_pages"] is None
    if want_mode == "slots":
        assert len(eng.slot_caches) == 2
    assert h["journal_seq"] is None  # no journal configured


@settings(max_examples=25, deadline=None)
@given(num_pages=st.integers(3, 32), page_size=st.integers(1, 4),
       seed=st.integers(0, 2**31 - 1), stale_or_oob=st.booleans())
def test_free_rejects_corrupt_page_lists(num_pages, page_size, seed,
                                         stale_or_oob):
    """The double-free guard: freeing a rid whose page list holds a page
    already on the free list (or out of range) must raise — silently
    pushing it would break conservation and hand one physical page to two
    requests on the next allocation.  Freeing an unknown rid stays a
    benign no-op."""
    alloc = PageAllocator(num_pages, page_size)
    rng = np.random.default_rng(seed)
    rid = int(rng.integers(0, 4))
    n_tokens = int(rng.integers(1, (num_pages - 1) * page_size + 1))
    got = alloc.ensure(rid, n_tokens)
    assert got, "setup: allocation must succeed for this range"
    if stale_or_oob and alloc.free_pages:
        # a page still on the free list sneaks into the owned list
        alloc._owned[rid].append(alloc._free[-1])
    else:
        # an out-of-range page id (also covers the null page for size-1)
        alloc._owned[rid].append(num_pages + 3)
    with pytest.raises(ValueError, match="double free"):
        alloc.free(rid)
    # unknown rid stays a no-op even with the guard in place
    assert alloc.free(rid + 100) == 0


def test_allocator_snapshot_roundtrip_and_corruption():
    """to_state/from_state preserve the exact free-list order (LIFO
    recycling survives restore); a tampered snapshot is rejected instead
    of silently double-allocating later."""
    alloc = PageAllocator(16, 2)
    alloc.ensure(1, 5)
    alloc.ensure(2, 3)
    alloc.free(1)
    state = alloc.to_state()
    clone = PageAllocator.from_state(state)
    assert clone._free == alloc._free and clone._owned == alloc._owned
    bad = alloc.to_state()
    bad["owned"]["2"].append(bad["free"][0])  # page in two places
    with pytest.raises(ValueError, match="corrupt allocator snapshot"):
        PageAllocator.from_state(bad)


def test_sidecar_snapshot_roundtrip_and_divergence_guards():
    """Quantized-pool allocators: to_state/from_state carry the scale-plane
    sidecar, a pre-sidecar snapshot (no ``sidecar`` key) restores as a plain
    allocator, and both divergence paths are caught — a tampered snapshot
    whose sidecar drifted from the page pool is rejected at restore, and a
    live sidecar double free raises before either pool mutates."""
    alloc = PageAllocator(16, 2, sidecar=True)
    alloc.ensure(1, 5)
    alloc.ensure(2, 3)
    alloc.free(1)
    state = alloc.to_state()
    clone = PageAllocator.from_state(state)
    assert clone.sidecar
    assert clone._side_free == alloc._free
    assert clone._side_owned == alloc._owned
    # pre-sidecar snapshot (PR-8 engines): no sidecar key -> plain allocator
    legacy = {k: v for k, v in state.items()
              if k not in ("sidecar", "side_free", "side_owned")}
    plain = PageAllocator.from_state(legacy)
    assert not plain.sidecar and plain._side_free is None
    # tampered snapshot: sidecar ownership drifts from page ownership
    # (rid 2 holds two pages, so reversing the sidecar list breaks lockstep)
    bad = alloc.to_state()
    assert len(bad["side_owned"]["2"]) == 2
    bad["side_owned"]["2"] = list(reversed(bad["side_owned"]["2"]))
    with pytest.raises(ValueError, match="corrupt allocator snapshot"):
        PageAllocator.from_state(bad)
    # live divergence: a scale plane sneaks back onto the sidecar free list
    alloc2 = PageAllocator(8, 2, sidecar=True)
    alloc2.ensure(3, 4)
    alloc2._side_free.append(alloc2._side_owned[3][0])
    with pytest.raises(ValueError, match="scale-plane double free"):
        alloc2.free(3)
    # the failed free left the data pool untouched (no half-applied state)
    assert alloc2.holds(3) == 2


def test_decode_plan_resolved_at_real_batched_m():
    """The decode-regime bugfix: QLinear decode GEMMs run at M=batch_slots
    (one batched step), so health() must report the plan at that M, not the
    per-slot M=1 the old engine implied."""
    from repro.quant.qlinear import make_qlinear

    cfg = reduced(get_config("smollm-135m"))
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    q = make_qlinear(
        jnp.asarray(rng.integers(-7, 8, (cfg.d_model, cfg.d_model)), jnp.int8),
        jnp.ones((cfg.d_model, 1), jnp.float32), impl="sim")
    params = dict(params, probe=q)
    eng = ServeEngine(cfg, params, batch_slots=16, max_seq=32,
                      kernel_impl=None)
    plan = eng.health()["decode_plan"]
    assert plan["m"] == 16 and plan["regime"] == "decode"
    assert plan["k"] == cfg.d_model and plan["n"] == cfg.d_model
    assert plan["path"] in ("fused", "chained", "unfused")
