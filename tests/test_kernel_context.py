"""The KernelContext execution-config API (PR 4 acceptance):

  * two contexts with different block tables AND VMEM budgets resolve
    DIFFERENT plans for the same shape in one process — no globals race;
  * all three kernel paths stay bitwise identical under any context at a
    fixed tiling;
  * from_json round-trips: malformed tables, partial entries, the reserved
    "vmem" key, the "layers" override table, and override precedence
    (override > table > defaults);
  * hashability / pytree-static QLinear metadata;
  * --vmem-budget CLI validation in serve.py and autotune_blocks.py;
  * the old deprecated global setters are really gone (window expired).
"""

import dataclasses
import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import make_w4a4_problem as _problem
from repro.kernels import ops
from repro.kernels.context import (KernelContext, Plan, gemm_regime,
                                   vmem_budget_arg)

REPO = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# value semantics: construction, builders, hashability
# ---------------------------------------------------------------------------


def test_default_context_is_value_equal_and_hashable():
    a = KernelContext()
    b = KernelContext.default()
    assert a == b and hash(a) == hash(b)
    assert a.table() == b.table()
    c = a.with_vmem_budgets(fused=1 << 20)
    assert c != a and a.fused_vmem_bytes != c.fused_vmem_bytes
    # builders never mutate the receiver
    assert a == KernelContext()
    d = {a: "x", c: "y"}  # usable as dict keys / static jit args
    assert d[KernelContext()] == "x"


def test_with_builders_validate():
    ctx = KernelContext()
    assert ctx.with_impl("fused").impl == "fused"
    assert ctx.with_interpret(True).interpret_mode() is True
    assert ctx.with_interpret(False).interpret_mode() is False
    with pytest.raises(ValueError, match="unknown impl"):
        ctx.with_impl("warp")
    with pytest.raises(ValueError, match="unknown regime"):
        ctx.with_block_table({"decoed": dict(path="fused", bm=8, bn=128,
                                             bk=128)})
    with pytest.raises(ValueError, match="override key"):
        ctx.with_layer_overrides({1.5: {"bm": 8}})
    with pytest.raises(ValueError, match="unknown plan keys"):
        ctx.with_layer_overrides({"l": {"bq": 8}})
    with pytest.raises(ValueError, match="is empty"):
        ctx.with_layer_overrides({"l": {}})


def test_two_contexts_resolve_differently_in_one_process():
    """THE acceptance property: different block tables + budgets in one
    process resolve different plans for the same (M, K, N, R), with no
    global state involved."""
    m, k, n, r = 16, 4096, 11008, 128
    a = KernelContext()
    b = (KernelContext()
         .with_block_table({"decode": dict(path="chained", bm=8, bn=128,
                                           bk=128, br=128)})
         .with_vmem_budgets(fused=1 << 20, prologue=1 << 20))
    pa = a.resolve_plan(m, k, n, r, rotate=True)
    pb = b.resolve_plan(m, k, n, r, rotate=True)
    assert pa.path == "fused"
    assert pb.path == "chained"
    assert pa != pb
    # interleaved resolution (as two engines would) stays stable
    assert a.resolve_plan(m, k, n, r, rotate=True) == pa
    assert b.resolve_plan(m, k, n, r, rotate=True) == pb
    # and the module-level entry points honor ctx= identically
    assert ops.resolve_plan(m, k, n, r, rotate=True, ctx=a) == pa
    assert ops.resolve_plan(m, k, n, r, rotate=True, ctx=b) == pb


def test_select_plan_returns_plan_namedtuple():
    p = ops.select_plan(16, 4096, 11008, 128)
    assert isinstance(p, Plan)
    assert p.path == "fused" and p.bm <= 16
    assert ops.select_blocks(16, 4096, 11008, 128) == p
    assert gemm_regime(16) == "decode"


# ---------------------------------------------------------------------------
# bitwise parity under any context at a fixed tiling (acceptance)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ctxkw", [
    {},
    {"fused_vmem_bytes": 1 << 20, "prologue_vmem_bytes": 1 << 20},
    {"block_table": {"decode": dict(path="chained", bm=8, bn=32, bk=64,
                                    br=8)}},
])
def test_paths_bitwise_identical_under_any_context(rng, ctxkw):
    """The context only picks the tiling; at a FIXED tiling the three paths
    are bitwise identical whatever context they run under."""
    m, k, n, r = 16, 128, 64, 8
    spec, x, wp, s, u, v = _problem(rng, m, k, n, r)
    ctx = KernelContext(**ctxkw)
    blocks = (8, 32, 64, 8)
    outs = [np.asarray(ops.w4a4_lrc_forward(x, wp, s, u, v, spec,
                                            rotate=True, blocks=blocks,
                                            impl=impl, ctx=ctx))
            for impl in ("fused", "chained", "unfused")]
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])
    # and identical to the default-context bits at the same tiling
    base = np.asarray(ops.w4a4_lrc_forward(x, wp, s, u, v, spec,
                                           rotate=True, blocks=blocks,
                                           impl="fused"))
    np.testing.assert_array_equal(outs[0], base)


def test_ctx_impl_sets_default_path(rng):
    """ctx.impl is the default when the caller passes impl=None."""
    spec, x, wp, s, u, v = _problem(rng, 8, 64, 32, 0)
    want = np.asarray(ops.w4a4_lrc_forward(x, wp, s, u, v, spec,
                                           impl="unfused"))
    got = np.asarray(ops.w4a4_lrc_forward(
        x, wp, s, u, v, spec, ctx=KernelContext().with_impl("unfused")))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# from_json round-trip: vmem, layers, partial entries, precedence
# ---------------------------------------------------------------------------


def test_from_json_full_roundtrip(tmp_path):
    table = {
        "decode": dict(path="chained", bm=8, bn=128, bk=128, br=128,
                       score_us=12.3),  # extra autotune keys are dropped
        "vmem": dict(fused_bytes_max=4 << 20, prologue_bytes_max=2 << 20),
        "layers": {
            "mlp/wd": dict(path="fused", bm=8),
            "4096x11008r128": dict(bn=128),
        },
    }
    p = tmp_path / "t.json"
    p.write_text(json.dumps(table))
    ctx = KernelContext.from_json(p)
    assert ctx.fused_vmem_bytes == 4 << 20
    assert ctx.prologue_vmem_bytes == 2 << 20
    assert ctx.table_entry("decode")["path"] == "chained"
    assert "score_us" not in ctx.table_entry("decode")
    # unlisted regimes keep the analytic defaults
    assert ctx.table_entry("mixed") == KernelContext().table_entry("mixed")
    assert ctx.layer_overrides()["mlp/wd"] == dict(path="fused", bm=8)
    # re-serialize what from_json read back in -> equal context
    assert KernelContext.from_json(p) == ctx
    # extra changes kwargs apply on top
    assert KernelContext.from_json(p, impl="chained").impl == "chained"


def test_from_json_committed_table_loads():
    ctx = KernelContext.from_json(REPO / "results" / "block_table.json")
    for regime in ("decode", "mixed", "prefill"):
        assert ctx.table_entry(regime)["path"] == "fused"


@pytest.mark.parametrize("table,msg", [
    ({"vmem": {"fused_bytes_max": 0}}, "positive int"),
    ({"vmem": {"hbm_bytes_max": 1}}, "unknown vmem budget"),
    ({"layers": [1]}, "'layers' entry"),
    ({"layers": {"l": {"bm": "8"}}}, "positive integer"),
    ({"layers": {"l": {"path": "warp"}}}, "unknown kernel path"),
    ({"layers": {"l": {"variant": "laminar"}}}, "unknown prologue variant"),
    ({"layers": {"l": {}}}, "is empty"),
    ({"decode": {"path": "fused", "bm": 8}}, "missing keys"),  # partial
    ({"decode": {"path": "fused", "bm": 8, "bn": 128, "bk": 128,
                 "variant": "steamed"}}, "unknown prologue variant"),
])
def test_from_json_malformed(tmp_path, table, msg):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps(table))
    with pytest.raises(ValueError, match=msg):
        KernelContext.from_json(p)


def test_from_json_missing_file():
    with pytest.raises(ValueError, match="cannot read block table"):
        KernelContext.from_json("/nonexistent/block_table.json")


def test_layer_override_precedence():
    """override > table > defaults, keyed by name, shape triple, or the
    'KxNrR' string spelling; unknown layers fall back to the table."""
    base = KernelContext().with_block_table(
        {"decode": dict(path="chained", bm=16, bn=256, bk=256, br=128)})
    ctx = base.with_layer_overrides({
        "mlp/wd": dict(path="fused", bm=8),
        (4096, 11008, 128): dict(bn=128),
    })
    # name override wins over the table entry; unset keys inherit from it
    p = ctx.select_plan(16, 4096, 11008, 128, layer="mlp/wd")
    assert (p.path, p.bm, p.bn) == ("fused", 8, 256)
    # shape override applies when no name matches
    p = ctx.select_plan(16, 4096, 11008, 128, layer="attn/wq")
    assert (p.path, p.bn) == ("chained", 128)
    p = ctx.select_plan(16, 4096, 11008, 128)  # no layer given: shape only
    assert p.bn == 128
    # neither name nor shape: pure table
    p = ctx.select_plan(16, 512, 512, 0, layer="nope")
    assert (p.path, p.bm) == ("chained", 16)
    # name lookup beats shape lookup
    p2 = ctx.select_plan(16, 4096, 11008, 128, layer="mlp/wd")
    assert p2.path == "fused"
    # string spelling of the shape key round-trips through JSON
    ctx2 = base.with_layer_overrides({"4096x11008r128": dict(bn=128)})
    assert ctx2.select_plan(16, 4096, 11008, 128).bn == 128


def test_variant_pin_constrains_but_never_bypasses_feasibility():
    """A table/override variant pin restricts the variant search; tiles
    still shrink to fit the budget and rotation still forces resident."""
    from repro.kernels.context import fused_vmem_bytes

    big = dict(path="fused", bm=256, bn=256, bk=512, br=512,
               variant="resident")
    k, r = 8192, 1024
    ctx = (KernelContext()
           .with_block_table({"decode": big})
           .with_vmem_budgets(fused=3 << 20))
    sel = ctx.select_plan(16, k, 11008, r)
    assert fused_vmem_bytes(k, r, sel.bm, sel.bn, sel.bk, sel.br, True) \
        > ctx.fused_vmem_bytes  # selected tiles are infeasible as-is
    plan = ctx.resolve_plan(16, k, 11008, r, rotate=True)
    assert (plan.bm, plan.bn, plan.bk, plan.br) != \
        (sel.bm, sel.bn, sel.bk, sel.br)  # shrink-to-fit ran despite the pin
    assert plan.path == "fused" and plan.variant == "resident"
    assert fused_vmem_bytes(k, r, plan.bm, plan.bn, plan.bk, plan.br,
                            True) <= ctx.fused_vmem_bytes
    # a streamed pin under rotation falls back to the resident slab
    ctx2 = ctx.with_layer_overrides({"l": dict(variant="streamed")})
    p2 = ctx2.resolve_plan(16, k, 11008, r, rotate=True, layer="l")
    assert p2.variant == "resident"
    # without rotation the pin holds (and still fits)
    p3 = ctx2.resolve_plan(16, k, 11008, r, rotate=False, layer="l")
    assert p3.path == "fused" and p3.variant == "streamed"
    # an unfittable pin demotes instead of launching an infeasible kernel
    tiny = ctx2.with_vmem_budgets(fused=0)
    assert tiny.resolve_plan(16, k, 11008, r, layer="l").path != "fused"


def test_layer_override_flows_through_resolve_and_forward(rng):
    """A per-layer chained pin actually changes execution (still bitwise
    identical output) through w4a4_lrc_forward's layer=."""
    m, k, n, r = 16, 128, 64, 8
    ctx = KernelContext().with_layer_overrides(
        {"mlp/wd": dict(path="chained")})
    assert ctx.resolve_plan(m, k, n, r, layer="mlp/wd").path == "chained"
    assert ctx.resolve_plan(m, k, n, r).path == "fused"
    spec, x, wp, s, u, v = _problem(rng, m, k, n, r)
    a = np.asarray(ops.w4a4_lrc_forward(x, wp, s, u, v, spec, ctx=ctx,
                                        layer="mlp/wd"))
    b = np.asarray(ops.w4a4_lrc_forward(x, wp, s, u, v, spec, ctx=ctx))
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# explain(): plan introspection
# ---------------------------------------------------------------------------


def test_explain_reports_all_regimes():
    ctx = KernelContext()
    report = ctx.explain(16, 4096, 11008, 128, rotate=True)
    for needle in ("decode", "mixed", "prefill", "fused", "variant=resident",
                   "fits", "12.0 MiB", "*[decode"):
        assert needle in report, needle


def test_explain_shows_override_and_demotion():
    ctx = (KernelContext()
           .with_vmem_budgets(fused=0, prologue=0)
           .with_layer_overrides({"mlp/wd": dict(bm=8)}))
    report = ctx.explain(16, 4096, 11008, 128, rotate=True, layer="mlp/wd")
    assert "layer override" in report
    assert "unfused" in report  # zero budgets demote everything
    assert "layer='mlp/wd'" in report


# ---------------------------------------------------------------------------
# QLinear carries the context as pytree-static metadata
# ---------------------------------------------------------------------------


def test_qlinear_ctx_is_static_and_respected(rng):
    from repro.quant.qlinear import make_qlinear, qlinear_apply

    d_in, d_out, r = 64, 32, 8
    q = jnp.asarray(rng.integers(-8, 8, (d_out, d_in)), jnp.int8)
    s = jnp.asarray(rng.uniform(0.01, 0.1, (d_out, 1)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((d_out, r)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((d_in, r)), jnp.float32)
    ctx_a = KernelContext()
    ctx_b = KernelContext().with_vmem_budgets(fused=0)  # pins chained
    qa = make_qlinear(q, s, u, v, impl="pallas", lr_dtype=jnp.float32,
                      ctx=ctx_a, name="mlp/wd")
    qb = dataclasses.replace(qa, ctx=ctx_b)
    # static metadata: flatten/unflatten round-trips the ctx
    leaves, treedef = jax.tree_util.tree_flatten(qa)
    assert jax.tree_util.tree_unflatten(treedef, leaves).ctx == ctx_a
    # both contexts execute (different plans) and agree bitwise
    x = jnp.asarray(rng.standard_normal((8, d_in)), jnp.float32)
    ya = np.asarray(qlinear_apply(qa, x))
    yb = np.asarray(qlinear_apply(qb, x))
    np.testing.assert_array_equal(ya, yb)
    # jit with the QLinear as a pytree arg: ctx rides as static metadata
    f = jax.jit(qlinear_apply)
    np.testing.assert_array_equal(np.asarray(f(qa, x)), ya)
    np.testing.assert_array_equal(np.asarray(f(qb, x)), yb)


def test_retag_attaches_ctx_and_validates():
    from repro.quant.qlinear import make_qlinear, retag_qlinear_impl

    q = jnp.asarray(np.zeros((16, 32)), jnp.int8)
    s = jnp.ones((16, 1), jnp.float32)
    tree = {"a": make_qlinear(q, s, impl="sim"), "w": jnp.ones((2, 2))}
    ctx = KernelContext().with_impl("chained")
    out = retag_qlinear_impl(tree, "fused", ctx=ctx)
    assert out["a"].impl == "fused" and out["a"].ctx == ctx
    # "auto" on CPU keeps the calibrated impl but still attaches the ctx
    out = retag_qlinear_impl(tree, "auto", ctx=ctx)
    assert out["a"].impl == "sim" and out["a"].ctx == ctx
    # impl=None: ctx-only attach, calibrated impls untouched on ANY backend
    out = retag_qlinear_impl(tree, None, ctx=ctx)
    assert out["a"].impl == "sim" and out["a"].ctx == ctx
    for bad in ("warp", "fussed", "PALLAS", ""):
        with pytest.raises(ValueError, match="unknown impl"):
            retag_qlinear_impl(tree, bad)


def test_serve_engine_accepts_ctx(rng):
    """Two engines with different contexts coexist; decode produces tokens
    under both and no process-global kernel state changes."""
    from repro.configs import get_config
    from repro.models import model as model_lib
    from repro.models.config import reduced
    from repro.serve.engine import Request, ServeEngine

    cfg = reduced(get_config("smollm-135m"))
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    before = ops.default_context()
    ctx_a = KernelContext()
    ctx_b = KernelContext().with_vmem_budgets(fused=1 << 20)
    engines = [ServeEngine(cfg, params, batch_slots=1, max_seq=32,
                           kernel_impl=None, ctx=c) for c in (ctx_a, ctx_b)]
    outs = []
    for eng in engines:
        eng.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                           max_new_tokens=2))
        done = eng.run(max_steps=8)
        outs.append(done[0].out_tokens)
    assert outs[0] == outs[1] and len(outs[0]) >= 2
    assert ops.default_context() == before


# ---------------------------------------------------------------------------
# CLI: --vmem-budget validation (serve.py + autotune_blocks.py)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bad", ["-1", "0", "12MB", "1.5", ""])
def test_vmem_budget_arg_rejects(bad):
    import argparse

    with pytest.raises(argparse.ArgumentTypeError,
                       match="positive integer number of bytes"):
        vmem_budget_arg(bad)
    assert vmem_budget_arg("4096") == 4096


@pytest.mark.parametrize("module", ["repro.launch.serve",
                                    "benchmarks.autotune_blocks"])
def test_cli_rejects_bad_vmem_budget(module):
    """Both CLIs exit with a clear argparse error on a non-positive or
    non-integer --vmem-budget, before any model/sweep work starts."""
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = f"{REPO / 'src'}" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["JAX_PLATFORMS"] = "cpu"
    for bad in ("-5", "huge"):
        proc = subprocess.run(
            [sys.executable, "-m", module, "--vmem-budget", bad],
            capture_output=True, text=True, timeout=120, cwd=REPO, env=env,
        )
        assert proc.returncode == 2, proc.stderr
        assert "positive integer number of bytes" in proc.stderr


def test_serve_build_context_maps_flags(tmp_path):
    from repro.launch.serve import build_context

    assert build_context(None, None) is None
    ctx = build_context(None, 4096)
    assert ctx.fused_vmem_bytes == 4096 and ctx.prologue_vmem_bytes == 4096
    p = tmp_path / "t.json"
    p.write_text(json.dumps({
        "decode": dict(path="chained", bm=8, bn=128, bk=128, br=128),
        "vmem": dict(fused_bytes_max=123, prologue_bytes_max=456),
    }))
    ctx = build_context(str(p), None)
    assert ctx.table_entry("decode")["path"] == "chained"
    assert ctx.fused_vmem_bytes == 123
    # the CLI budget wins over the table's vmem entry
    ctx = build_context(str(p), 789)
    assert ctx.fused_vmem_bytes == 789 and ctx.prologue_vmem_bytes == 789
    # the shared helper also maps --impl (roofline CLI)
    from repro.kernels.context import context_from_flags

    assert context_from_flags() is None
    assert context_from_flags(impl="chained").impl == "chained"


# ---------------------------------------------------------------------------
# removed deprecated setters + state isolation
# ---------------------------------------------------------------------------


def test_deprecated_global_setters_are_gone():
    """The one-release window on the old global mutators is up: the
    attributes no longer exist (callers get a loud AttributeError instead
    of a silently-ignored DeprecationWarning), while the non-deprecated
    process-default helpers stay."""
    assert not hasattr(ops, "load_block_table")
    assert not hasattr(ops, "set_vmem_budgets")
    assert "load_block_table" not in ops.__all__
    assert "set_vmem_budgets" not in ops.__all__
    # the supported replacements remain available
    assert callable(ops.reset_block_table)
    assert callable(ops.set_default_context)
    assert callable(ops.default_context)


def test_default_context_snapshot_restored_between_tests_a():
    """Paired with ..._b below: mutate the default context here; the
    autouse conftest fixture must restore it before the next test."""
    ops.set_default_context(KernelContext().with_vmem_budgets(fused=1))
    assert ops.fused_vmem_budget() == 1


def test_default_context_snapshot_restored_between_tests_b():
    assert ops.fused_vmem_budget() == ops._FUSED_VMEM_BYTES_MAX
