"""Single-kernel fused W4A4+LRC forward (kernels/fused_gemm.py) vs. the
two-kernel chain and the unfused three-pass path: bitwise cross-path parity
(the PR acceptance), the VMEM-budget fallback boundary, the execution-plan
table (select_plan / KernelContext.from_json / unknown-regime errors), and
the CI regression gate.  All kernels run in pallas interpret mode."""

import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import make_w4a4_problem as _problem
from repro.kernels import ops, ref
from repro.kernels.context import KernelContext
from repro.kernels.fused_gemm import fused_w4a4_lrc_kernel

# (per-test isolation of the process-default KernelContext comes from the
# autouse _kernel_state_guard fixture in conftest.py)




# ---------------------------------------------------------------------------
# single kernel vs. two-kernel chain vs. unfused: BITWISE (acceptance)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n,r", [
    (16, 64, 32, 0),      # decode, block-aligned, rank-0
    (8, 256, 100, 16),    # decode, odd MLP width
    (13, 96, 80, 5),      # decode, nothing is a multiple of anything
    (64, 128, 96, 8),     # mixed
    (300, 128, 100, 0),   # mixed, odd N, rank-0
    (520, 128, 72, 6),    # prefill regime
])
@pytest.mark.parametrize("rotate", [False, True])
def test_fused_bitwise_matches_chain_and_unfused(rng, m, k, n, r, rotate):
    if rotate and k & (k - 1):
        pytest.skip("online rotation needs power-of-two K")
    spec, x, wp, s, u, v = _problem(rng, m, k, n, r)
    outs = {
        impl: np.asarray(ops.w4a4_lrc_forward(x, wp, s, u, v, spec,
                                              rotate=rotate, impl=impl))
        for impl in ("fused", "chained", "unfused", "auto")
    }
    np.testing.assert_array_equal(outs["fused"], outs["chained"])
    np.testing.assert_array_equal(outs["fused"], outs["unfused"])
    np.testing.assert_array_equal(outs["fused"], outs["auto"])
    want = np.asarray(ref.w4a4_lrc_forward_ref(
        x, wp, s, u, v, bits=4, clip_ratio=0.9, rotate=rotate))
    assert outs["fused"].shape == (m, n)
    np.testing.assert_allclose(outs["fused"], want, rtol=1e-4, atol=1e-4)


def test_fused_kernel_direct_block_aligned(rng):
    """The raw kernel (no wrapper padding) against the pure-jnp oracle."""
    m, k, n, r = 32, 128, 64, 8
    spec, x, wp, s, u, v = _problem(rng, m, k, n, r)
    out = fused_w4a4_lrc_kernel(
        x, v, wp, s.reshape(1, -1), u,
        bits=4, clip_ratio=0.9, rotate=True, bm=16, bn=32, bk=64,
        interpret=True,
    )
    want = ref.w4a4_lrc_forward_ref(x, wp, s, u, v, bits=4, clip_ratio=0.9,
                                    rotate=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_fused_block_shape_invariance_rank0(rng):
    """Integer accumulation is exact under any K split, so at rank 0 every
    tiling of the fused kernel produces the same bits as the chain."""
    m, k, n = 24, 128, 64
    spec, x, wp, s, u, v = _problem(rng, m, k, n, 0)
    want = np.asarray(ops.w4a4_lrc_forward(x, wp, s, u, v, spec,
                                           impl="chained"))
    for blocks in [(8, 16, 32), (8, 64, 64), (16, 32, 128)]:
        got = np.asarray(ops.w4a4_lrc_forward(x, wp, s, u, v, spec,
                                              blocks=blocks, impl="fused"))
        np.testing.assert_array_equal(got, want)


def test_fused_block_shape_parity_lowrank(rng):
    """With a low-rank term the (bk, br)-chunked xv accumulation is part of
    the canonical math, so bits are identical ACROSS PATHS at one tiling
    (every tiling still agrees within f32 reassociation noise)."""
    m, k, n, r = 24, 128, 64, 8
    spec, x, wp, s, u, v = _problem(rng, m, k, n, r)
    ref_out = None
    for blocks in [(8, 16, 32, 8), (8, 64, 64, 8), (16, 32, 128, 8)]:
        outs = [np.asarray(ops.w4a4_lrc_forward(x, wp, s, u, v, spec,
                                                blocks=blocks, impl=impl))
                for impl in ("fused", "chained", "unfused")]
        np.testing.assert_array_equal(outs[0], outs[1])
        np.testing.assert_array_equal(outs[0], outs[2])
        if ref_out is None:
            ref_out = outs[0]
        else:
            np.testing.assert_allclose(outs[0], ref_out,
                                       rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# the _PROLOGUE_V_BYTES_MAX fallback boundary (satellite acceptance)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("r", [
    1024,  # k·r·4 = 8 MB exactly: ≤ budget, fused/chained stay eligible
    1032,  # just past 8 MB: auto demotes all the way to unfused
])
def test_v_bytes_boundary_bitwise_identical(rng, r):
    """Rank/K combos just under and over the 8 MB V budget produce bitwise
    identical outputs on the fused, chained and unfused paths — crossing the
    auto-dispatch boundary can never change serving results."""
    m, k, n = 8, 2048, 64
    v_bytes = k * r * 4
    assert (v_bytes <= ops._PROLOGUE_V_BYTES_MAX) == (r == 1024)
    spec, x, wp, s, u, v = _problem(rng, m, k, n, r)
    outs = {
        impl: np.asarray(ops.w4a4_lrc_forward(x, wp, s, u, v, spec,
                                              rotate=True, impl=impl))
        for impl in ("fused", "chained", "unfused", "auto")
    }
    np.testing.assert_array_equal(outs["fused"], outs["chained"])
    np.testing.assert_array_equal(outs["fused"], outs["unfused"])
    np.testing.assert_array_equal(outs["fused"], outs["auto"])


def test_fused_vmem_gate_demotes_to_chain(rng):
    """With the fused working-set budget forced to zero (via an explicit
    context — no global is touched), auto dispatch takes the two-kernel
    chain — and the bits cannot change."""
    spec, x, wp, s, u, v = _problem(rng, 16, 128, 64, 8)
    want = np.asarray(ops.w4a4_lrc_forward(x, wp, s, u, v, spec))
    tight = KernelContext().with_vmem_budgets(fused=0)
    assert tight.resolve_plan(16, 128, 64, 8).path == "chained"
    got = np.asarray(ops.w4a4_lrc_forward(x, wp, s, u, v, spec, ctx=tight))
    np.testing.assert_array_equal(got, want)


def test_unknown_impl_raises(rng):
    spec, x, wp, s, u, v = _problem(rng, 8, 64, 32, 0)
    with pytest.raises(ValueError, match="unknown impl"):
        ops.w4a4_lrc_forward(x, wp, s, u, v, spec, impl="warp")


# ---------------------------------------------------------------------------
# execution-plan table: regimes, unknown-regime errors, measured overlays
# ---------------------------------------------------------------------------


def test_select_plan_paths():
    path, bm, *_ = ops.select_plan(16, 4096, 11008, 128)    # decode
    assert path == "fused" and bm <= 16
    path2, *_ = ops.select_plan(256, 4096, 11008, 128)      # mixed
    assert path2 == "fused"
    # prefill flipped to the single-kernel path with the K-split grid
    path3, *_ = ops.select_plan(2048, 4096, 11008, 128)
    assert path3 == "fused"


def test_select_blocks_unknown_regime_raises():
    """select_blocks/select_plan no longer ignore unknown regime strings."""
    with pytest.raises(ValueError, match="unknown regime 'decoed'"):
        ops.select_blocks(16, 4096, 11008, 128, regime="decoed")
    with pytest.raises(ValueError, match="unknown regime"):
        ops.select_plan(16, 4096, 11008, regime="prefil")
    # explicit valid override still works
    assert ops.select_blocks(2048, 4096, 11008, 0, regime="decode") == \
        ops.select_blocks(16, 4096, 11008, 0)


def test_block_table_from_json_roundtrip(tmp_path):
    # no "br": pre-K-split tables stay loadable (br falls back to default)
    table = {"decode": {"path": "chained", "bm": 8, "bn": 128, "bk": 128,
                        "score_us": 1.0}}
    p = tmp_path / "block_table.json"
    p.write_text(json.dumps(table))
    ctx = KernelContext.from_json(p)
    plan = ctx.select_plan(16, 4096, 11008, 128)
    assert (plan.path, plan.bm, plan.bn, plan.bk) == ("chained", 8, 128, 128)
    assert plan.br == 128  # default 512 clamped to the rank's pow2
    # unlisted regimes keep the analytic defaults
    assert ctx.select_plan(256, 4096, 11008, 128).path == "fused"
    # the context is a value: the process default never saw the table
    assert ops.select_plan(16, 4096, 11008, 128).path == "fused"


@pytest.mark.parametrize("table,msg", [
    ({"decoed": {"path": "fused", "bm": 8, "bn": 128, "bk": 128}},
     "unknown regime"),
    ({"decode": {"path": "warp", "bm": 8, "bn": 128, "bk": 128}},
     "unknown kernel path"),
    ({"decode": {"path": "fused", "bm": 8}}, "missing keys"),
])
def test_block_table_rejects_malformed(tmp_path, table, msg):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps(table))
    with pytest.raises(ValueError, match=msg):
        KernelContext.from_json(p)
    # a rejected table builds nothing — the process default is untouched
    assert ops.select_plan(16, 4096, 11008, 128).path == "fused"


def test_autotune_sweep_analytic(tmp_path):
    """The sweep harness produces a loadable table whose decode winner is
    the single-kernel path (it strictly dominates the chain on bytes)."""
    from benchmarks.autotune_blocks import autotune_sweep

    winners = autotune_sweep(measure=False, smoke=True)
    assert set(winners) == {"decode", "mixed", "prefill"}
    assert winners["decode"]["path"] == "fused"
    p = tmp_path / "table.json"
    p.write_text(json.dumps(winners))
    KernelContext.from_json(p)


# ---------------------------------------------------------------------------
# QLinear impl="fused" + engine retag
# ---------------------------------------------------------------------------


def test_qlinear_fused_impl_matches_int8_odd_shapes(rng):
    from repro.quant.qlinear import make_qlinear, qlinear_apply

    d_in, d_out, r = 96, 80, 8
    q = jnp.asarray(rng.integers(-8, 8, (d_out, d_in)), jnp.int8)
    s = jnp.asarray(rng.uniform(0.01, 0.1, (d_out, 1)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((d_out, r)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((d_in, r)), jnp.float32)
    ql = make_qlinear(q, s, u, v, impl="int8", lr_dtype=jnp.float32)
    x = jnp.asarray(rng.standard_normal((13, d_in)), jnp.float32)
    a = qlinear_apply(ql, x)
    b = qlinear_apply(dataclasses.replace(ql, impl="fused"), x)
    c = qlinear_apply(dataclasses.replace(ql, impl="pallas"), x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-3, atol=2e-3)
    # pallas (auto plan) and fused pin the same kernels at this shape
    np.testing.assert_array_equal(np.asarray(b), np.asarray(c))


def test_retag_to_fused(rng):
    from repro.quant.qlinear import make_qlinear, retag_qlinear_impl

    q = jnp.asarray(rng.integers(-8, 8, (16, 32)), jnp.int8)
    s = jnp.ones((16, 1), jnp.float32)
    tree = {"a": make_qlinear(q, s, impl="sim")}
    assert retag_qlinear_impl(tree, "fused")["a"].impl == "fused"
    with pytest.raises(ValueError, match="unknown impl"):
        retag_qlinear_impl(tree, "warp")
    with pytest.raises(ValueError, match="unknown impl"):
        retag_qlinear_impl(tree, "pallsa")  # typo must not tag silently


def test_qlinear_fused_groupwise_runs_kernels(rng):
    """Group-wise calibrated layers no longer demote to the jnp int8 GEMM:
    impl="fused" runs the pallas path with the (M, K/g) scale plane and
    matches the int8 reference semantics (grouped acceptance lives in
    tests/test_kernels_groups.py)."""
    from repro.quant.qlinear import make_qlinear, qlinear_apply

    d_in, d_out, g = 128, 64, 32
    q = jnp.asarray(rng.integers(-8, 8, (d_out, d_in)), jnp.int8)
    s = jnp.asarray(rng.uniform(0.01, 0.1, (d_out, 1)), jnp.float32)
    ql = make_qlinear(q, s, act_group=g, impl="int8")
    x = jnp.asarray(rng.standard_normal((8, d_in)), jnp.float32)
    a = qlinear_apply(ql, x)
    b = qlinear_apply(dataclasses.replace(ql, impl="fused"), x)
    c = qlinear_apply(dataclasses.replace(ql, impl="pallas"), x)
    # rank-0 int math is exact on both paths: same bits as the int8 GEMM
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(b), np.asarray(c))


# ---------------------------------------------------------------------------
# roofline byte model + CI regression gate
# ---------------------------------------------------------------------------


def test_byte_model_fused_strictly_below_chained_decode():
    """Acceptance: the single-kernel path eliminates the M×K xq write+read —
    activation bytes strictly below the PR 1 chained path at decode shapes,
    and independent of rank (everything but x lives in VMEM)."""
    from repro.launch.roofline import prologue_activation_bytes

    for k in (4096, 5120, 8192):
        for r in (0, 128, 256, 512, 1024):
            ch = prologue_activation_bytes(16, k, r, rotate=True,
                                           path="chained")
            fu = prologue_activation_bytes(16, k, r, rotate=True,
                                           path="fused")
            assert fu < ch, (k, r)
            assert fu == 16 * k * 2  # exactly one read of x, nothing else
            assert ch - fu == 2 * (16 * k + 4 * 16 + 4 * 16 * r)


def test_byte_model_unknown_path_raises():
    from repro.launch.roofline import prologue_activation_bytes

    with pytest.raises(ValueError, match="unknown path"):
        prologue_activation_bytes(16, 4096, 128, path="semi-fused")


def test_check_regression_gate(tmp_path):
    """The CI gate passes on a fresh baseline, fails on a regressed one and
    on a fused-not-below-chained violation."""
    from benchmarks.check_regression import check
    from benchmarks.latency_kernels import HEADER, analytic_rows

    rows = analytic_rows(ms=[16], sizes=[(4096, 11008)], ranks=[0, 128])
    good = tmp_path / "good.json"
    good.write_text(json.dumps(dict(header=HEADER, rows=rows)))
    assert check(good, 0.05) == []

    # shrink the baseline's fused byte column by 20% → current code "regressed"
    idx = HEADER.index("act_prologue_kb_fused")
    bad_rows = [list(r) for r in rows]
    for r in bad_rows:
        r[idx] = r[idx] * 0.8
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(dict(header=HEADER, rows=bad_rows)))
    failures = check(bad, 0.05)
    assert failures and all("act_prologue_kb_fused" in f for f in failures)

    # stale baseline (no matching shapes) must fail loudly, not pass silently
    stale = tmp_path / "stale.json"
    stale.write_text(json.dumps(dict(
        header=HEADER,
        rows=[["M999_1x1", 0] + [1.0] * (len(HEADER) - 2)])))
    assert any("stale" in f for f in check(stale, 0.05))


def test_committed_baseline_passes_gate():
    """The checked-in results/latency_kernels.json must be in sync with the
    current byte model — the same invariant the CI job enforces."""
    from pathlib import Path

    from benchmarks.check_regression import check

    baseline = Path(__file__).resolve().parents[1] / "results" / \
        "latency_kernels.json"
    assert check(baseline, 0.05) == []
