"""Group-wise activation scales through the kernel stack (paper Table 2,
g = 128): cross-path bitwise parity with the (M, K/g) scale plane, zero
padding at group boundaries, the g = K per-token degeneracy, bk/g
feasibility snapping in resolve_plan, and the QLinear fast-path acceptance
(grouped layers no longer demote to the jnp int8 GEMM).  All kernels run in
pallas interpret mode."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import make_w4a4_problem as _problem
from repro.core.quantizers import QuantSpec
from repro.kernels import ops
from repro.kernels.context import (KernelContext, fused_vmem_bytes,
                                   prologue_vmem_bytes)
from repro.kernels.fused_gemm import fused_w4a4_lrc_kernel
from repro.kernels.rowops import snap_bk_to_group


# ---------------------------------------------------------------------------
# cross-path bitwise parity with grouped scales (the PR acceptance)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n,r,g", [
    (16, 256, 100, 16, 64),    # decode, odd N, rank > 0
    (13, 192, 80, 5, 64),      # odd everything; K = 3 groups
    (8, 256, 64, 0, 128),      # rank-0
    (64, 512, 96, 8, 128),     # mixed regime, the paper's g
])
@pytest.mark.parametrize("rotate", [False, True])
def test_grouped_bitwise_parity_across_paths(rng, m, k, n, r, g, rotate):
    if rotate and k & (k - 1):
        pytest.skip("online rotation needs power-of-two K")
    spec, x, wp, s, u, v = _problem(rng, m, k, n, r, act_group=g)
    outs = {
        impl: np.asarray(ops.w4a4_lrc_forward(x, wp, s, u, v, spec,
                                              rotate=rotate, impl=impl))
        for impl in ("fused", "chained", "unfused", "auto")
    }
    np.testing.assert_array_equal(outs["fused"], outs["chained"])
    np.testing.assert_array_equal(outs["fused"], outs["unfused"])
    np.testing.assert_array_equal(outs["fused"], outs["auto"])
    assert outs["fused"].shape == (m, n)


def test_grouped_matches_jnp_grouped_reference(rng):
    """The kernel-path grouped math equals the jnp int8 grouped GEMM
    (QLinear impl="int8") semantics: same quantizer grid, same per-group
    rescale — only f32 summation order differs, so allclose."""
    m, k, n, g = 16, 256, 64, 64
    spec, x, wp, s, u, v = _problem(rng, m, k, n, 0, act_group=g)
    got = np.asarray(ops.w4a4_lrc_forward(x, wp, s, u, v, spec,
                                          impl="fused"))
    from repro.core.quantizers import quantize_act, unpack_int4
    xq, sx = quantize_act(x, spec)
    wq = unpack_int4(wp.T).T.astype(jnp.int32)  # (K, N)
    accg = jnp.einsum("mgk,gkn->mgn",
                      xq.reshape(m, k // g, g).astype(jnp.int32),
                      wq.reshape(k // g, g, n))
    want = jnp.sum(accg.astype(jnp.float32) * sx[..., None], axis=1) * s
    np.testing.assert_allclose(got, np.asarray(want), rtol=1e-5, atol=1e-5)


def test_grouped_fused_variants_bitwise_equal(rng):
    """Resident vs. streamed prologue with grouped scales: the streamed
    sweep finalizes each chunk's group scales chunk-locally (no amax fold),
    which must reproduce the resident whole-row group reductions bit for
    bit."""
    m, k, n, r, g = 16, 512, 64, 8, 128
    spec, x, wp, s, u, v = _problem(rng, m, k, n, r, act_group=g)
    sw = s.reshape(1, -1)
    outs = [
        np.asarray(fused_w4a4_lrc_kernel(
            x, v, wp, sw, u, bits=4, clip_ratio=0.9, rotate=False,
            bm=16, bn=32, bk=128, br=8, variant=variant, act_group=g,
            interpret=True))
        for variant in ("resident", "streamed")
    ]
    np.testing.assert_array_equal(outs[0], outs[1])


# ---------------------------------------------------------------------------
# zero padding at a group boundary
# ---------------------------------------------------------------------------


def test_grouped_odd_width_pads_whole_groups(rng):
    """K = 192 with g = 64 under a bk = 128 tiling pads one whole zero
    group (k_pad = 256): the padded group's guarded scale quantizes only
    zeros, its rescaled partial sums are exact +0.0, and all three paths
    stay bitwise identical — with an odd N riding along."""
    m, k, n, r, g = 9, 192, 100, 5, 64
    spec, x, wp, s, u, v = _problem(rng, m, k, n, r, act_group=g)
    blocks = (8, 32, 128, 8)  # bk=128 -> k_pad=256 > K: a zero tail group
    outs = [np.asarray(ops.w4a4_lrc_forward(x, wp, s, u, v, spec,
                                            blocks=blocks, impl=impl))
            for impl in ("fused", "chained", "unfused")]
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])
    # the padded tail changes nothing vs. a tiling with no K padding
    aligned = np.asarray(ops.w4a4_lrc_forward(x, wp, s, u, v, spec,
                                              blocks=(8, 32, 64, 8),
                                              impl="chained"))
    np.testing.assert_allclose(outs[0], aligned, rtol=1e-4, atol=1e-4)


def test_grouped_scale_plane_shape_and_padding(rng):
    """ops.act_quant / ops.fused_prologue emit the unpadded (M, K/g)
    plane; padded groups never leak out."""
    m, k, g = 9, 192, 64
    spec = QuantSpec(bits=4, clip_ratio=0.9, group_size=g)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    q, s = ops.act_quant(x, spec)
    assert q.shape == (m, k) and s.shape == (m, k // g)
    v = jnp.asarray(rng.standard_normal((k, 8)), jnp.float32)
    q2, s2, xv = ops.fused_prologue(x, v, spec, bk=128)
    assert s2.shape == (m, k // g) and xv.shape == (m, 8)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s2))


# ---------------------------------------------------------------------------
# g = K degenerates to per-token
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["fused", "chained", "unfused"])
def test_group_equals_k_degenerates_to_per_token(rng, impl):
    """One group spanning the whole row IS per-token quantization: the
    same reductions, guard and scale·round on the same operands — outputs
    bitwise equal to the per-token path on every impl."""
    m, k, n, r = 8, 128, 64, 8
    spec_g, x, wp, s, u, v = _problem(rng, m, k, n, r, act_group=k)
    spec_t = dataclasses.replace(spec_g, group_size=None)
    got = np.asarray(ops.w4a4_lrc_forward(x, wp, s, u, v, spec_g, impl=impl))
    want = np.asarray(ops.w4a4_lrc_forward(x, wp, s, u, v, spec_t, impl=impl))
    np.testing.assert_array_equal(got, want)


def test_group_equals_k_scale_plane_matches_per_token(rng):
    m, k = 16, 256
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    qg, sg = ops.act_quant(x, QuantSpec(bits=4, clip_ratio=0.9, group_size=k))
    qt, st = ops.act_quant(x, QuantSpec(bits=4, clip_ratio=0.9))
    np.testing.assert_array_equal(np.asarray(qg), np.asarray(qt))
    np.testing.assert_array_equal(np.asarray(sg), np.asarray(st))


# ---------------------------------------------------------------------------
# bk/g feasibility snapping in resolve_plan
# ---------------------------------------------------------------------------


def test_snap_bk_to_group():
    assert snap_bk_to_group(512, 128) == 512   # already a multiple
    assert snap_bk_to_group(512, 96) == 384    # 96 * 2^2
    assert snap_bk_to_group(256, 96) == 192    # 96 * 2
    assert snap_bk_to_group(100, 96) == 96     # floor: one group
    assert snap_bk_to_group(64, 128) == 128    # g > bk snaps UP to g
    assert snap_bk_to_group(4096, 4096) == 4096  # g = K pins bk = K


def test_resolve_plan_snaps_bk_to_group_multiple():
    ctx = KernelContext()
    for g in (96, 128, 512):
        k = g * 20 if g != 512 else g * 8
        plan = ctx.resolve_plan(16, k, 512, 128, act_group=g)
        assert plan.bk % g == 0, (g, plan)
        assert plan.path == "fused"
    # the per-token plan is untouched by the new axis
    assert ctx.resolve_plan(16, 4096, 11008, 128) == \
        ctx.resolve_plan(16, 4096, 11008, 128, act_group=None)


def test_resolve_plan_group_must_divide_k():
    with pytest.raises(ValueError, match="act_group 96 must divide K"):
        KernelContext().resolve_plan(16, 4096, 11008, 128, act_group=96)


def test_resolve_plan_grouped_demotes_when_nothing_fits():
    """bk cannot shrink below one group, so a huge group under a tiny fused
    budget demotes — and the chained fit honors the same constraint."""
    ctx = KernelContext().with_vmem_budgets(fused=1 << 16)
    plan = ctx.resolve_plan(16, 8192, 512, 0, act_group=8192)
    assert plan.path != "fused"
    assert plan.bk % 8192 == 0
    # with both budgets zero the grouped plan lands on unfused, bk snapped
    none = KernelContext().with_vmem_budgets(fused=0, prologue=0) \
        .resolve_plan(16, 1024, 512, 0, act_group=256)
    assert none.path == "unfused" and none.bk % 256 == 0


def test_vmem_models_grow_scale_plane_bytes():
    """The working-set models charge the (bm, K/g) f32 plane: grouped
    footprints exceed per-token by exactly the extra plane bytes."""
    k, r, bm, bn, bk, br, g = 4096, 128, 16, 256, 512, 128, 128
    extra = bm * (k // g - 1) * 4
    assert fused_vmem_bytes(k, r, bm, bn, bk, br, True, act_group=g) \
        - fused_vmem_bytes(k, r, bm, bn, bk, br, True) == extra
    assert prologue_vmem_bytes(k, r, bm, bk, br, False, act_group=g) \
        - prologue_vmem_bytes(k, r, bm, bk, br, False) == extra


def test_explain_reports_group_snap_and_demotion():
    ctx = KernelContext()
    report = ctx.explain(16, 1920, 512, 128, act_group=96)
    assert "act_group=96" in report
    assert "multiple of" in report and "scale plane" in report
    assert "bk 512->384" in report  # decode table bk snapped
    tight = ctx.with_vmem_budgets(fused=0, prologue=0)
    report2 = tight.explain(16, 1920, 512, 128, act_group=96)
    assert "demoted fused->unfused" in report2
    assert "no multiple-of-96 bk tiling" in report2


# ---------------------------------------------------------------------------
# QLinear fast-path acceptance: no int8 demotion for grouped layers
# ---------------------------------------------------------------------------


def test_qlinear_fused_act_group_128_takes_fused_path(rng):
    """QLinear(impl="fused", act_group=128) runs the single-kernel pallas
    path — its output is BITWISE the fused kernel's, not the jnp int8
    GEMM's — and auto dispatch resolves the grouped shape to fused."""
    from repro.quant.qlinear import make_qlinear, qlinear_apply

    d_in, d_out, r, g = 256, 100, 16, 128
    q = jnp.asarray(rng.integers(-8, 8, (d_out, d_in)), jnp.int8)
    s = jnp.asarray(rng.uniform(0.01, 0.1, (d_out, 1)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((d_out, r)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((d_in, r)), jnp.float32)
    ql = make_qlinear(q, s, u, v, act_group=g, impl="fused",
                      lr_dtype=jnp.float32)
    x = jnp.asarray(rng.standard_normal((8, d_in)), jnp.float32)
    got = qlinear_apply(ql, x)

    plan = KernelContext().resolve_plan(8, d_in, d_out, r, act_group=g)
    assert plan.path == "fused" and plan.bk % g == 0
    want = ops.w4a4_lrc_forward(
        x, ql.qweight, ql.w_scale, ql.u, ql.v, act_spec=ql.act_spec,
        impl="fused")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and the reference int8 grouped GEMM agrees within f32-order tolerance
    int8_out = qlinear_apply(dataclasses.replace(ql, impl="int8"), x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(int8_out),
                               rtol=2e-3, atol=2e-3)
