"""Unit tests for the dry-run substrate: HLO collective parsing, roofline
terms, sharding rules (incl. the QLinear-suffix regression of §Perf exp-4),
config registry, and shape applicability."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, applicable, cells
from repro.launch.roofline import (
    _shape_bytes,
    collective_bytes,
    model_flops,
    roofline_from_costs,
)


HLO_SAMPLE = """
HloModule test
ENTRY main {
  %p0 = bf16[128,256]{1,0} parameter(0)
  %p1 = bf16[128,256]{1,0} parameter(1)
  %ar = bf16[128,256]{1,0} all-reduce(%p0), replica_groups={}
  %ag = bf16[128,512]{1,0} all-gather(%p1), dimensions={1}
  %cp = f32[64]{0} collective-permute(%p0), source_target_pairs={{0,1}}
  ROOT %t = (bf16[128,256]{1,0}) tuple(%ar)
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[128,256]{1,0}") == 128 * 256 * 2
    assert _shape_bytes("f32[10]") == 40
    assert _shape_bytes("(bf16[4,4], f32[2])") == 32 + 8
    assert _shape_bytes("u8[8,8]{1,0}") == 64
    assert _shape_bytes("pred[]") == 1


def test_collective_parsing():
    coll = collective_bytes(HLO_SAMPLE)
    assert coll["all-reduce"] == 128 * 256 * 2  # operand p0
    assert coll["all-gather"] == 128 * 256 * 2  # operand p1 (not the result)
    assert coll["collective-permute"] == 128 * 256 * 2
    counts = coll["_counts"]
    assert counts["all-reduce"] == 1 and counts["all-gather"] == 1


def test_roofline_terms_and_bottleneck():
    costs = dict(flops=197e12, bytes=819e9 * 2, coll={"all-reduce": 50e9},
                 coll_counts={"all-reduce": 1})
    rf = roofline_from_costs(costs, model_flops_total=197e12 * 256, n_chips=256)
    assert abs(rf["compute_term_s"] - 1.0) < 1e-9
    assert abs(rf["memory_term_s"] - 2.0) < 1e-9
    assert abs(rf["collective_term_s"] - 1.0) < 1e-9
    assert rf["bottleneck"] == "memory"
    assert abs(rf["useful_flops_ratio"] - 1.0) < 1e-9
    assert abs(rf["roofline_fraction"] - 0.5) < 1e-9


def test_model_flops_regimes():
    cfg = get_config("smollm-135m")
    tr = model_flops(cfg, SHAPES["train_4k"])
    pf = model_flops(cfg, SHAPES["prefill_32k"])
    de = model_flops(cfg, SHAPES["decode_32k"])
    assert tr > pf > de > 0
    # train = 6ND vs prefill 2ND with equal token counts
    assert abs(tr / (6 / 2) / (SHAPES["train_4k"].global_batch * SHAPES["train_4k"].seq_len)
               - pf / (SHAPES["prefill_32k"].global_batch * SHAPES["prefill_32k"].seq_len)) < 1e-3 * pf


def test_shape_applicability():
    assert not applicable(get_config("gemma-7b"), "long_500k")
    assert applicable(get_config("mamba2-370m"), "long_500k")
    assert applicable(get_config("zamba2-7b"), "long_500k")
    assert len(cells(get_config("gemma-7b"))) == 3
    assert len(cells(get_config("zamba2-7b"))) == 4
    # 40 assigned cells - 8 long_500k skips = 32 live
    assert sum(len(cells(get_config(a))) for a in ARCH_IDS) == 32


def test_config_registry_complete():
    assert len(ARCH_IDS) == 10
    for a in ARCH_IDS:
        cfg = get_config(a)
        assert cfg.name == a
        assert cfg.vocab_size > 0 and cfg.n_layers > 0


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------


def _mesh22():
    # AbstractMesh: rule logic only needs axis names/sizes (1-device CPU test)
    from repro.core.jaxcompat import abstract_mesh

    return abstract_mesh((2, 2), ("data", "model"))


def test_param_rules_shard_attention_and_mlp():
    from repro.distributed.sharding import param_pspecs

    mesh = _mesh22()
    tree = {
        "layers": {
            "attn": {"wq": jax.ShapeDtypeStruct((4, 64, 32), jnp.float32),
                     "wo": jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)},
            "mlp": {"wg": jax.ShapeDtypeStruct((4, 64, 128), jnp.float32),
                    "wd": jax.ShapeDtypeStruct((4, 128, 64), jnp.float32)},
        },
        "embed": jax.ShapeDtypeStruct((1000, 64), jnp.float32),
    }
    specs = param_pspecs(tree, mesh, False)
    assert specs["layers"]["attn"]["wq"] == jax.sharding.PartitionSpec(None, None, "model")
    assert specs["layers"]["attn"]["wo"] == jax.sharding.PartitionSpec(None, "model", None)
    assert specs["layers"]["mlp"]["wd"] == jax.sharding.PartitionSpec(None, "model", None)
    assert specs["embed"] == jax.sharding.PartitionSpec("model", None)


def test_param_rules_match_qlinear_fields():
    """Regression for §Perf exp-4: QLinear suffixes must inherit the base
    weight's plan (the inner-$ anchor bug replicated every quantized
    weight)."""
    from repro.distributed.sharding import param_pspecs
    from repro.quant.qlinear import QLinear

    mesh = _mesh22()
    # real layout: (L layers stacked, E experts, d_in//2, d_out)
    ql = QLinear(
        qweight=jax.ShapeDtypeStruct((2, 8, 32, 64), jnp.uint8),
        w_scale=jax.ShapeDtypeStruct((2, 8, 64), jnp.float32),
        u=jax.ShapeDtypeStruct((2, 8, 64, 4), jnp.bfloat16),
        v=jax.ShapeDtypeStruct((2, 8, 64, 4), jnp.bfloat16),
    )
    tree = {"moe_layers": {"moe": {"experts": {"wg": ql}}}}
    specs = param_pspecs(tree, mesh, False)
    got = specs["moe_layers"]["moe"]["experts"]["wg"]
    P = jax.sharding.PartitionSpec
    assert got.qweight == P(None, "model", None, None)  # stacked + EP
    assert got.w_scale == P(None, "model", None)
    assert got.u == P(None, "model", None, None)
    assert got.v == P(None, "model", None, None)


def test_divisibility_fallback():
    from repro.distributed.sharding import ShardingFallback, param_pspecs

    mesh = _mesh22()
    # 3 kv heads * 17 = 51-wide projection: 51 % 2 != 0 -> replicate
    tree = {"layers": {"attn": {"wk": jax.ShapeDtypeStruct((2, 64, 51), jnp.float32)}}}
    with pytest.warns(ShardingFallback) as rec:
        specs = param_pspecs(tree, mesh, False)
    assert specs["layers"]["attn"]["wk"] == jax.sharding.PartitionSpec(None, None, None)
    # the warning is STRUCTURED: tooling (summarize --sharding) reads fields
    w = next(m.message for m in rec if isinstance(m.message, ShardingFallback))
    assert w.path == "layers/attn/wk"
    assert (w.dim_index, w.dim) == (2, 51)
    assert (w.axis, w.axis_size) == ("model", 2)


def test_describe_sharding_captures_fallbacks():
    from repro.distributed.sharding import describe_sharding

    mesh = _mesh22()
    tree = {"layers": {"attn": {"wk": jax.ShapeDtypeStruct((2, 64, 51), jnp.float32),
                                "wq": jax.ShapeDtypeStruct((2, 64, 64), jnp.float32)}}}
    # capture, don't warn: describe_sharding returns the plan as data
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        rows = describe_sharding(tree, mesh)
    by_path = {r["path"]: r for r in rows}
    assert by_path["layers/attn/wq"]["fallbacks"] == []
    fb = by_path["layers/attn/wk"]["fallbacks"]
    assert len(fb) == 1 and fb[0].dim == 51 and fb[0].axis == "model"


# ---------------------------------------------------------------------------
# TP comms-bytes model (the comms_kb_ benchmark columns)
# ---------------------------------------------------------------------------


def test_tp_comms_bytes_model():
    from repro.launch.roofline import (
        ep_combine_bytes_per_token,
        tp_psum_bytes_per_token,
    )

    # no mesh -> no collective -> zero payload
    assert tp_psum_bytes_per_token(1024, 1) == 0.0
    assert ep_combine_bytes_per_token(1024, 1) == 0.0
    # ring all-reduce: each element crosses the wire 2*(tp-1)/tp times, f32
    assert tp_psum_bytes_per_token(1024, 8) == 2 * 7 / 8 * 1024 * 4
    # the EP combine psum has the same shape as a row-parallel psum of d_model
    assert ep_combine_bytes_per_token(512, 4) == tp_psum_bytes_per_token(512, 4)
    # payload grows monotonically with tp (asymptote 2*width*bytes)
    assert (tp_psum_bytes_per_token(256, 2) < tp_psum_bytes_per_token(256, 4)
            < tp_psum_bytes_per_token(256, 8) < 2 * 256 * 4)


@settings(max_examples=20, deadline=None)
@given(b=st.sampled_from([1, 2, 4, 32, 128, 256]), seq=st.booleans())
def test_batch_pspec_never_invalid(b, seq):
    from repro.distributed.sharding import batch_pspec

    mesh = _mesh22()
    spec = batch_pspec(mesh, False, b, shard_seq=seq)
    # divisibility: if batch dim sharded, it must divide the dp size
    if spec[0] is not None:
        size = mesh.shape["data"]
        assert b % size == 0
