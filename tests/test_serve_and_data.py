import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model
from repro.models.config import reduced
from repro.data.tokens import SyntheticCorpus
from repro.data.loader import batches, calib_sequences
from repro.serve.engine import Request, ServeEngine
from repro.serve.sampling import sample_token


def test_corpus_deterministic_and_structured():
    c = SyntheticCorpus(1024, seed=3)
    a = c.sequence(5, 256)
    b = c.sequence(5, 256)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < 1024
    # Zipf head concentration: top-32 tokens cover a large mass
    big = c.batch(0, 16, 256).ravel()
    top = np.bincount(big, minlength=1024).max()
    assert top > len(big) / 1024 * 4


def test_batches_replay_from_step():
    cfg = reduced(get_config("smollm-135m"))
    it1 = batches(cfg, 4, 16, seed=9)
    seq = [next(it1) for _ in range(5)]
    it2 = batches(cfg, 4, 16, seed=9, start_step=3)
    s3, b3 = next(it2)
    assert s3 == 3
    np.testing.assert_array_equal(np.asarray(seq[3][1]["tokens"]), np.asarray(b3["tokens"]))


def test_sampling_modes(rng):
    logits = jnp.asarray(rng.standard_normal((3, 50)), jnp.float32)
    g = sample_token(logits, jax.random.PRNGKey(0), temperature=0.0)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(jnp.argmax(logits, -1)))
    t = sample_token(logits, jax.random.PRNGKey(0), temperature=1.0, top_k=5)
    assert t.shape == (3,)


def test_sampling_topk_halfprec_logits_finite(rng):
    """The top-k mask must be dtype-aware: a -1e30 fill overflows float16
    (max ~6.5e4) to -inf, which can NaN through temperature scaling /
    categorical; bf16 shares the mantissa problem at lower severity."""
    for dtype in (jnp.float16, jnp.bfloat16):
        logits = jnp.asarray(rng.standard_normal((4, 64)) * 8, dtype)
        t = sample_token(logits, jax.random.PRNGKey(1), temperature=0.7, top_k=3)
        assert t.shape == (4,)
        assert bool(jnp.all((t >= 0) & (t < 64)))
        # the dtype-aware mask stays finite (the old -1e30 fill is -inf in f16)
        vals, _ = jax.lax.top_k(logits, 3)
        masked = jnp.where(logits < vals[..., -1:], jnp.finfo(dtype).min, logits)
        assert bool(jnp.all(jnp.isfinite(masked.astype(jnp.float32))))
    assert not np.isfinite(np.float16(-1e30))  # what the fix guards against


@pytest.mark.parametrize("family_arch", ["smollm-135m", "mamba2-370m"])
def test_engine_matches_sequential_greedy(family_arch, rng):
    """Engine output == manual greedy decode — batching must not change
    results."""
    cfg = reduced(get_config(family_arch))
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [np.asarray(rng.integers(0, cfg.vocab_size, (6,)), np.int32)
               for _ in range(3)]

    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=32)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=5))
    done = eng.run()
    assert sorted(done) == [0, 1, 2]
    # run() now returns structured terminal records, all FINISHED here
    assert all(done[i].ok and done[i].retries == 0 for i in range(3))

    # manual single-request reference
    for i, p in enumerate(prompts):
        cache = model.init_cache(cfg, 1, 32, dtype=jnp.float32)
        logits, cache = model.prefill(cfg, params, {"tokens": jnp.asarray(p[None])}, cache)
        toks = [int(jnp.argmax(logits[:, -1], -1)[0])]
        for _ in range(4):
            logits, cache = model.decode_step(
                cfg, params, jnp.asarray([[toks[-1]]], jnp.int32), cache
            )
            toks.append(int(jnp.argmax(logits[:, -1], -1)[0]))
        assert done[i].out_tokens == toks, (i, done[i].out_tokens, toks)


def test_calib_sequences_shape():
    cfg = reduced(get_config("smollm-135m"))
    c = calib_sequences(cfg, n_seq=4, seq_len=64)
    assert c.shape == (4, 64)


def test_grad_compression_close_to_exact():
    """int8-compressed psum ≈ exact mean; error feedback keeps bias ~0 over
    steps."""
    import os
    import subprocess
    import sys

    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.train.compression import compressed_psum, zero_residual

from repro.core.jaxcompat import make_mesh, set_mesh, shard_map
mesh = make_mesh((4,), ("data",))
rng = np.random.default_rng(0)
g_local = jnp.asarray(rng.standard_normal((4, 64, 32)), jnp.float32)

def f(g):
    def inner(gl):
        grads = {"w": gl}
        res = zero_residual(grads)
        out, _ = compressed_psum(grads, res, "data")
        return out["w"]
    return shard_map(inner, mesh=mesh, in_specs=P("data"), out_specs=P("data"))(g)

with set_mesh(mesh):
    out = jax.jit(f)(g_local)
exact = jnp.mean(g_local, axis=0, keepdims=True)
err = float(jnp.abs(out[0] - exact[0]).max()) / float(jnp.abs(exact).max())
print("REL", err)
assert err < 0.05, err
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout
