import os

# Tests run on the single real CPU device; the 512-device production mesh is
# exercised ONLY by launch/dryrun.py (which sets XLA_FLAGS itself).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
