import os
import sys

# Tests run on the single real CPU device; the 512-device production mesh is
# exercised ONLY by launch/dryrun.py (which sets XLA_FLAGS itself).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# The hermetic container has no `hypothesis`; gate the property tests behind
# a deterministic stub rather than losing the whole suite to a collect error.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import importlib.util
    import pathlib

    _spec = importlib.util.spec_from_file_location(
        "hypothesis", pathlib.Path(__file__).parent / "_hypothesis_stub.py"
    )
    _stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    sys.modules["hypothesis"] = _stub

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _kernel_state_guard():
    """Snapshot/restore the only remaining global kernel-dispatch state —
    the process-default KernelContext — so a test that swaps the default
    (ops.set_default_context) can never leak plan state into another test,
    whatever the ordering."""
    from repro.kernels import ops

    saved = ops.default_context()
    yield
    ops.set_default_context(saved)
