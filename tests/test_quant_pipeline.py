"""End-to-end LRC pipeline tests: rotation exactness, calibration walker,
quantized-forward quality ordering (LRC < SVD/none in logits error), and
impl-path equivalence (sim vs int8)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model
from repro.models.config import reduced
from repro.quant.calibrate import quantize_model
from repro.quant.policy import QuantPolicy
from repro.quant.rotate import rotate_model
from repro.quant.qlinear import QLinear


def _tokens(rng, cfg, n_seq=8, seq=32):
    return jnp.asarray(rng.integers(0, cfg.vocab_size, (n_seq, seq)))


@pytest.mark.parametrize("arch", ["smollm-135m", "phi3-mini-3.8b", "gemma-7b", "mamba2-370m"])
def test_rotation_exactness(arch, rng):
    cfg = reduced(get_config(arch))
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": _tokens(rng, cfg)}
    base = model.forward(cfg, params, batch)
    rot = rotate_model(cfg, params)
    out = model.forward(cfg, rot, batch)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base), rtol=1e-3, atol=2e-3)


def _logits_mse(cfg, p_ref, p_test, batch):
    a = model.forward(cfg, p_ref, batch)
    b = model.forward(cfg, p_test, batch)
    return float(jnp.mean((a - b) ** 2))


@pytest.fixture(scope="module")
def smollm_setup():
    rng = np.random.default_rng(7)
    cfg = reduced(get_config("smollm-135m"), n_layers=2, d_model=64)
    params = model.init_params(cfg, jax.random.PRNGKey(1))
    calib = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 64)))
    eval_batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64)))}
    return cfg, params, calib, eval_batch


def test_lrc_beats_baselines_on_model_logits(smollm_setup):
    cfg, params, calib, eval_batch = smollm_setup
    base = dict(bits=4, act_bits=4, impl="sim", quant_method="gptq")
    p_lrc = quantize_model(cfg, params, calib, QuantPolicy(**base, correction="lrc", rank_frac=0.15))
    p_svd = quantize_model(cfg, params, calib, QuantPolicy(**base, correction="svd", rank_frac=0.15))
    p_none = quantize_model(cfg, params, calib, QuantPolicy(**base, correction="none", rank_frac=0.0))
    m_lrc = _logits_mse(cfg, params, p_lrc, eval_batch)
    m_svd = _logits_mse(cfg, params, p_svd, eval_batch)
    m_none = _logits_mse(cfg, params, p_none, eval_batch)
    assert m_lrc < m_none, (m_lrc, m_none)
    assert m_lrc < m_svd, (m_lrc, m_svd)


def test_quantized_prefill_decode_consistency(smollm_setup):
    cfg, params, calib, eval_batch = smollm_setup
    policy = QuantPolicy(impl="sim", correction="lrc", rank_frac=0.15)
    qp = quantize_model(cfg, params, calib, policy)
    toks = eval_batch["tokens"][:, :12]
    full = model.forward(cfg, qp, {"tokens": toks})
    cache = model.init_cache(cfg, toks.shape[0], 12, dtype=jnp.float32)
    logits, cache = model.prefill(cfg, qp, {"tokens": toks[:, :6]}, cache)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(full[:, 5]), rtol=2e-3, atol=2e-3
    )
    for t in range(6, 12):
        logits, cache = model.decode_step(cfg, qp, toks[:, t : t + 1], cache)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full[:, t]), rtol=2e-3, atol=2e-3
        )


def test_int8_impl_matches_sim(smollm_setup):
    cfg, params, calib, eval_batch = smollm_setup
    policy = QuantPolicy(impl="sim", correction="lrc", rank_frac=0.15)
    qp = quantize_model(cfg, params, calib, policy)

    def set_impl(tree, impl):
        return jax.tree.map(
            lambda l: dataclasses.replace(l, impl=impl) if isinstance(l, QLinear) else l,
            tree,
            is_leaf=lambda l: isinstance(l, QLinear),
        )

    # layer level: the two paths compute the SAME integer math
    from repro.quant.qlinear import qlinear_apply

    ql = jax.tree.map(lambda a: a[0], qp["layers"]["attn"]["wq"])
    x = jnp.asarray(np.random.default_rng(3).standard_normal((32, cfg.d_model)), jnp.float32)
    ya = qlinear_apply(ql, x)
    yb = qlinear_apply(dataclasses.replace(ql, impl="int8"), x)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb), rtol=1e-4, atol=1e-4)

    # model level: tiny rescale-order differences amplify chaotically through
    # attention; require high global agreement rather than elementwise equality
    a = np.asarray(model.forward(cfg, qp, eval_batch))
    b = np.asarray(model.forward(cfg, set_impl(qp, "int8"), eval_batch))
    corr = np.corrcoef(a.ravel(), b.ravel())[0, 1]
    # 0.998: the smollm-reduced config lands at ~0.9990 and jitters a few
    # 1e-4 with jax version / CPU math-library differences
    assert corr > 0.998, corr


def test_ssm_calibration_runs(rng):
    cfg = reduced(get_config("mamba2-370m"))
    params = model.init_params(cfg, jax.random.PRNGKey(2))
    calib = _tokens(rng, cfg, 4, 32)
    qp = quantize_model(cfg, params, calib, QuantPolicy(impl="sim", rank_frac=0.1))
    out = model.forward(cfg, qp, {"tokens": calib})
    assert bool(jnp.all(jnp.isfinite(out)))
    # in/out projections got quantized
    assert isinstance(qp["layers"]["in_proj"], QLinear)
    assert isinstance(qp["layers"]["out_proj"], QLinear)


def test_moe_calibration_runs(rng):
    cfg = reduced(get_config("deepseek-v2-236b"), n_layers=2)
    params = model.init_params(cfg, jax.random.PRNGKey(3))
    calib = _tokens(rng, cfg, 4, 32)
    qp = quantize_model(cfg, params, calib, QuantPolicy(impl="sim", rank_frac=0.1),
                        rotate=False)
    out = model.forward(cfg, qp, {"tokens": calib})
    assert bool(jnp.all(jnp.isfinite(out)))
    assert isinstance(qp["moe_layers"]["moe"]["experts"]["wg"], QLinear)
