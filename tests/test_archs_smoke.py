"""Per-architecture smoke tests: reduced same-family configs, one forward /
train-loss step on CPU, shape + finiteness checks, and prefill+decode
consistency against the teacher-forcing forward (catches cache bugs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import model
from repro.models.config import reduced


def make_batch(cfg, rng, bsz=2, seq=16):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (bsz, seq)))}
    if cfg.family == "encdec":
        t_enc = max(4, seq // cfg.encoder_downsample)
        batch["frames"] = jnp.asarray(
            rng.standard_normal((bsz, t_enc, cfg.d_model)), jnp.float32
        )
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((bsz, cfg.n_prefix_tokens, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, key, rng):
    cfg = reduced(get_config(arch))
    params = model.init_params(cfg, key)
    batch = make_batch(cfg, rng)
    logits = jax.jit(lambda p, b: model.forward(cfg, p, b))(params, batch)
    bsz, seq = batch["tokens"].shape
    exp_seq = seq + (cfg.n_prefix_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (bsz, exp_seq, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_loss_and_grads_finite(arch, key, rng):
    cfg = reduced(get_config(arch))
    params = model.init_params(cfg, key)
    batch = make_batch(cfg, rng, bsz=2, seq=8)
    loss, grads = jax.jit(jax.value_and_grad(lambda p: model.loss_fn(cfg, p, batch)))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch, key, rng):
    cfg = reduced(get_config(arch))
    params = model.init_params(cfg, key)
    bsz, seq, pre = 2, 12, 6
    batch = make_batch(cfg, rng, bsz=bsz, seq=seq)
    full = model.forward(cfg, params, batch)  # (B, S(+P), V)
    if cfg.family == "vlm":
        full = full[:, -seq:]

    enc_len = batch["frames"].shape[1] if cfg.family == "encdec" else 0
    cache = model.init_cache(cfg, bsz, max_seq=seq + (cfg.n_prefix_tokens if cfg.family == "vlm" else 0),
                             dtype=jnp.float32, enc_len=enc_len)
    pre_batch = dict(batch, tokens=batch["tokens"][:, :pre])
    logits_last, cache = model.prefill(cfg, params, pre_batch, cache)
    np.testing.assert_allclose(
        np.asarray(logits_last[:, 0]), np.asarray(full[:, pre - 1]), rtol=2e-3, atol=2e-3
    )
    logits_t = logits_last
    for t in range(pre, seq):
        logits_t, cache = model.decode_step(cfg, params, batch["tokens"][:, t : t + 1], cache)
        np.testing.assert_allclose(
            np.asarray(logits_t[:, 0]), np.asarray(full[:, t]), rtol=2e-3, atol=2e-3,
            err_msg=f"{arch} decode step {t}",
        )


def test_full_configs_param_counts():
    """The full (unreduced) configs should land near their published sizes."""
    expected = {
        "smollm-135m": (0.10e9, 0.20e9),
        "phi3-mini-3.8b": (3.0e9, 4.5e9),
        "phi4-mini-3.8b": (3.0e9, 5.2e9),
        "gemma-7b": (7.0e9, 9.5e9),
        "deepseek-v2-236b": (180e9, 260e9),
        "deepseek-v3-671b": (550e9, 720e9),
        "zamba2-7b": (6.0e9, 9.5e9),
        "whisper-medium": (0.60e9, 0.90e9),  # medium is 769M + untied head
        "mamba2-370m": (0.30e9, 0.48e9),
        "paligemma-3b": (2.0e9, 3.5e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
