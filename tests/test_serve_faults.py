"""Chaos suite: seeded deterministic fault injection against ServeEngine.

The acceptance contract: with a FaultInjector targeting K of N requests
(exceptions, NaN/Inf logit bursts, slow steps, cache corruption), the
engine finishes with exactly K structured FAILED/TIMED_OUT records, the
other N-K completions bitwise identical to a fault-free run, no unhandled
exception escaping run(), and bounded-retry counters visible in health().
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model
from repro.models.config import reduced
from repro.serve.engine import ServeEngine
from repro.serve.faults import (FAULT_KINDS, HARD_KINDS, FaultInjector,
                                FaultSpec, InjectedFault)
from repro.serve.lifecycle import Request, RequestState
from repro.serve.sampling import NonFiniteLogitsError, sample_token

from test_serve_lifecycle import FakeClock

N_REQ = 4
NEW_TOKENS = 5


@pytest.fixture(scope="module")
def served():
    cfg = reduced(get_config("smollm-135m"))
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
               for _ in range(N_REQ)]
    return cfg, params, prompts


def _run(cfg, params, prompts, **engine_kw):
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=32, **engine_kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p.copy(), max_new_tokens=NEW_TOKENS))
    return eng, eng.run()


@pytest.fixture(scope="module")
def baseline(served):
    """Fault-free reference run: every request FINISHED."""
    cfg, params, prompts = served
    _, done = _run(cfg, params, prompts)
    assert all(done[i].ok for i in range(N_REQ))
    return {i: list(done[i].out_tokens) for i in range(N_REQ)}


# -- the injector itself ----------------------------------------------------


def test_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultSpec("meteor", "decode", 0)
    with pytest.raises(ValueError, match="phase"):
        FaultSpec("exception", "epilogue", 0)
    with pytest.raises(ValueError, match="sampling"):
        FaultSpec("nan_logits", "sampling", 0)  # no logits at that boundary
    with pytest.raises(ValueError, match="repeat"):
        FaultSpec("exception", "decode", 0, repeat=0)
    with pytest.raises(ValueError, match="seconds"):
        FaultSpec("slow_step", "decode", 0, seconds=-1.0)
    # slow_step is soft (latency only); process_crash kills the process
    # outright — neither is a retryable per-request "hard" failure
    assert set(HARD_KINDS) == set(FAULT_KINDS) - {"slow_step", "process_crash"}


def test_poll_schedule_is_positional():
    spec = FaultSpec("exception", "decode", rid=1, at_call=2, repeat=2)
    inj = FaultInjector([spec])
    # decode hits 0..5 for rid 1: fires exactly on hits 2 and 3
    hits = [inj.poll(1, "decode") for _ in range(6)]
    assert [h is not None for h in hits] == [False, False, True, True, False,
                                             False]
    assert inj.poll(0, "decode") is None  # other rid never fires
    assert inj.poll(1, "prefill") is None  # other phase never fires
    assert inj.fired == [(spec, 2), (spec, 3)]


def test_sample_is_seed_deterministic():
    a = FaultInjector.sample(range(8), k=3, seed=11)
    b = FaultInjector.sample(range(8), k=3, seed=11)
    c = FaultInjector.sample(range(8), k=3, seed=12)
    assert a.specs == b.specs
    assert len(a.targets) == 3 and a.targets <= set(range(8))
    assert all(s.kind in HARD_KINDS for s in a.specs)
    assert c.specs != a.specs  # different seed, different schedule
    with pytest.raises(ValueError):
        FaultInjector.sample(range(4), k=5, seed=0)


def test_corrupt_payloads():
    import jax.numpy as jnp

    logits = jnp.zeros((1, 32), jnp.float32)
    nan = FaultInjector.corrupt_logits(logits, "nan_logits")
    inf = FaultInjector.corrupt_logits(logits, "inf_logits")
    assert bool(jnp.isnan(nan).any()) and not bool(jnp.isnan(nan).all())
    assert bool(jnp.isinf(inf).any())
    cache = {"k": jnp.ones((2, 3)), "offset": jnp.asarray(7, jnp.int32)}
    bad = FaultInjector.corrupt_cache(cache)
    assert bool(jnp.isnan(bad["k"]).all())
    assert int(bad["offset"]) == 7  # int leaves (positions) survive


# -- the chaos matrix -------------------------------------------------------

MATRIX = [
    ("exception", "prefill"),
    ("exception", "decode"),
    ("exception", "sampling"),
    ("nan_logits", "prefill"),
    ("nan_logits", "decode"),
    ("inf_logits", "decode"),
    ("cache_corruption", "decode"),
]


@pytest.mark.parametrize("kind,phase", MATRIX, ids=[f"{k}-{p}" for k, p in MATRIX])
def test_chaos_k_of_n_split_and_parity(served, baseline, kind, phase):
    """K=2 targeted requests fail structurally; the other N-K finish with
    outputs bitwise identical to the fault-free run."""
    cfg, params, prompts = served
    targets = {1, 3}
    # prefill is hit once per request, so its schedule must start at hit 0;
    # decode/sampling are hit repeatedly and can fire mid-request
    inj = FaultInjector([
        FaultSpec(kind, phase, rid,
                  at_call=(rid % 2 if phase != "prefill" else 0), repeat=16)
        for rid in targets
    ])
    eng, done = _run(cfg, params, prompts, injector=inj, max_retries=1)
    assert sorted(done) == list(range(N_REQ))  # nothing vanished
    for rid in range(N_REQ):
        rec = done[rid]
        if rid in targets:
            assert rec.status is RequestState.FAILED, (rid, rec)
            assert rec.error_kind in ("injected", "non_finite_logits")
            assert rec.retries == 1  # bounded budget was spent
            assert rec.error  # captured message
        else:
            assert rec.ok
            assert rec.out_tokens == baseline[rid], (kind, phase, rid)
    h = eng.health()
    assert h["counters"]["failed"] == len(targets)
    assert h["counters"]["finished"] == N_REQ - len(targets)
    assert h["counters"]["retries"] == len(targets)  # visible retry budget
    assert inj.fired  # the schedule actually triggered


def test_chaos_run_is_reproducible(served):
    cfg, params, prompts = served
    outs = []
    for _ in range(2):
        inj = FaultInjector.sample(range(N_REQ), k=2, seed=5)
        _, done = _run(cfg, params, prompts, injector=inj, max_retries=1)
        outs.append({r: (done[r].status, tuple(done[r].out_tokens),
                         done[r].error_kind) for r in done})
    assert outs[0] == outs[1]


def test_sampled_injector_end_to_end(served, baseline):
    cfg, params, prompts = served
    inj = FaultInjector.sample(range(N_REQ), k=2, seed=3)
    eng, done = _run(cfg, params, prompts, injector=inj, max_retries=2)
    failed = {r for r in done if done[r].status is RequestState.FAILED}
    assert failed == inj.targets and len(failed) == 2
    for rid in set(range(N_REQ)) - failed:
        assert done[rid].ok and done[rid].out_tokens == baseline[rid]


# -- retries: recovery and exhaustion ---------------------------------------


def test_transient_fault_recovers_with_retry(served, baseline):
    """A fault that fires once is absorbed by the retry budget: everyone
    finishes, bitwise equal to fault-free, and the retry is accounted."""
    cfg, params, prompts = served
    inj = FaultInjector([FaultSpec("exception", "decode", 1, at_call=1,
                                   repeat=1)])
    eng, done = _run(cfg, params, prompts, injector=inj, max_retries=2)
    assert all(done[i].ok for i in range(N_REQ))
    assert {i: done[i].out_tokens for i in range(N_REQ)} == baseline
    assert done[1].retries == 1 and done[0].retries == 0
    assert eng.health()["counters"]["retries"] == 1


def test_transient_cache_corruption_recovers(served, baseline):
    """Cache corruption is applied to the forward's INPUT, never committed:
    once the fault stops firing, the retry restarts from clean state."""
    cfg, params, prompts = served
    inj = FaultInjector([FaultSpec("cache_corruption", "decode", 2,
                                   at_call=0, repeat=2)])
    _, done = _run(cfg, params, prompts, injector=inj, max_retries=2)
    assert all(done[i].ok for i in range(N_REQ))
    assert done[2].out_tokens == baseline[2]
    assert done[2].retries == 2


def test_retry_budget_boundary(served):
    """repeat == max_retries recovers on the final attempt; repeat ==
    max_retries + 1 exhausts the budget and fails."""
    cfg, params, prompts = served
    for repeat, ok in ((2, True), (3, False)):
        inj = FaultInjector([FaultSpec("exception", "decode", 0,
                                       at_call=0, repeat=repeat)])
        _, done = _run(cfg, params, prompts, injector=inj, max_retries=2)
        assert done[0].ok is ok, (repeat, done[0])
        assert done[0].retries == 2


def test_retry_backoff_is_exponential(served):
    cfg, params, prompts = served
    slept = []
    inj = FaultInjector([FaultSpec("exception", "decode", 0, at_call=0,
                                   repeat=2)])
    _, done = _run(cfg, params, prompts, injector=inj, max_retries=3,
                   retry_backoff_s=0.1, sleep_fn=slept.append)
    assert done[0].ok
    assert slept == pytest.approx([0.1, 0.2])


# -- slow steps + deadlines -------------------------------------------------


def test_slow_step_trips_deadline(served, baseline):
    """A slow fault alone does not fail a request — but paired with a
    per-request deadline it becomes a TIMED_OUT record."""
    cfg, params, prompts = served
    fc = FakeClock()
    inj = FaultInjector([FaultSpec("slow_step", "decode", 1, at_call=0,
                                   repeat=1, seconds=60.0)],
                        sleep_fn=fc.sleep)
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=32, injector=inj,
                      clock=fc, sleep_fn=fc.sleep)
    for i, p in enumerate(prompts):
        # only the targeted request carries a deadline: the injected sleep
        # burns shared wall-clock, which must not expire its neighbors
        eng.submit(Request(rid=i, prompt=p.copy(), max_new_tokens=NEW_TOKENS,
                           deadline_s=30.0 if i == 1 else None))
    done = eng.run()
    assert done[1].status is RequestState.TIMED_OUT
    assert done[1].error_kind == "deadline"
    for rid in (0, 2, 3):
        assert done[rid].ok and done[rid].out_tokens == baseline[rid]


def test_slow_step_without_deadline_is_harmless(served, baseline):
    cfg, params, prompts = served
    fc = FakeClock()
    inj = FaultInjector([FaultSpec("slow_step", "decode", 1, at_call=0,
                                   repeat=3, seconds=60.0)],
                        sleep_fn=fc.sleep)
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=32, injector=inj,
                      clock=fc, sleep_fn=fc.sleep)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p.copy(), max_new_tokens=NEW_TOKENS))
    done = eng.run()
    assert all(done[i].ok for i in range(N_REQ))
    assert {i: done[i].out_tokens for i in range(N_REQ)} == baseline


# -- slot quarantine + stall watchdog ---------------------------------------


def test_slot_death_and_stall_watchdog(served):
    """Permanent prefill faults kill both slots (failure-limit 1); the
    watchdog then aborts run() with a diagnosable report instead of
    spinning to max_steps, and the queued survivors come back TIMED_OUT."""
    cfg, params, prompts = served
    inj = FaultInjector([FaultSpec("exception", "prefill", rid, at_call=0,
                                   repeat=999) for rid in (0, 1)])
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=32, injector=inj,
                      max_retries=0, slot_failure_limit=1)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p.copy(), max_new_tokens=NEW_TOKENS))
    done = eng.run(max_steps=500)
    assert done[0].status is RequestState.FAILED
    assert done[1].status is RequestState.FAILED
    for rid in (2, 3):
        assert done[rid].status is RequestState.TIMED_OUT
        assert done[rid].error_kind == "stall"
    assert eng.stall_report is not None
    assert "slots dead" in eng.stall_report["reason"]
    h = eng.health()
    assert h["dead_slots"] == 2 and h["stalled"]
    assert eng.counters["steps"] < 500  # aborted, did not spin to the limit


def test_failure_streak_resets_on_success(served):
    """One failure then a success must not accumulate toward slot death."""
    cfg, params, prompts = served
    inj = FaultInjector([FaultSpec("exception", "decode", 0, at_call=0,
                                   repeat=16)])
    eng, done = _run(cfg, params, prompts, injector=inj, max_retries=0,
                     slot_failure_limit=2)
    assert done[0].status is RequestState.FAILED
    assert all(done[i].ok for i in (1, 2, 3))
    assert not any(eng.slot_dead)
    assert all(s["fail_streak"] <= 1 for s in eng.health()["slots"])


# -- the sampling guard -----------------------------------------------------


def test_sample_token_finite_guard(rng):
    import jax.numpy as jnp

    logits = jnp.asarray(rng.standard_normal((2, 16)), jnp.float32)
    assert sample_token(logits, jax.random.PRNGKey(0), check_finite=True).shape == (2,)
    bad = logits.at[0, 3].set(float("nan")).at[1, 5].set(float("inf"))
    with pytest.raises(NonFiniteLogitsError, match="1 NaN, 1 Inf"):
        sample_token(bad, jax.random.PRNGKey(0), check_finite=True)
    # guard off: legacy behavior, caller's problem
    sample_token(bad, jax.random.PRNGKey(0))


def test_injected_fault_is_runtime_error():
    assert issubclass(InjectedFault, RuntimeError)
    assert issubclass(NonFiniteLogitsError, FloatingPointError)
