import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hadamard import (
    apply_rotation,
    fwht,
    hadamard_matrix,
    random_orthogonal,
)
from repro.core.rotation import incoherence


# the assigned architectures' residual/ff dims
ARCH_DIMS = [576, 1024, 1536, 2048, 3072, 3584, 5120, 7168, 8192, 14336, 16384, 24576]


@pytest.mark.parametrize("n", [2, 8, 64, 128, 12, 20, 24, 48, 576])
def test_hadamard_matrix_orthogonal(n):
    h = hadamard_matrix(n)
    np.testing.assert_allclose(h @ h.T, np.eye(n), atol=1e-9)


@pytest.mark.parametrize("n", ARCH_DIMS)
def test_arch_dims_rotation_preserves_norm(rng, n):
    # kron of orthogonal factors is orthogonal; verify the applied rotation
    # preserves inner products (no n×n materialization for huge dims)
    x = jnp.asarray(rng.standard_normal((4, n)), jnp.float32)
    y = apply_rotation(x, n)
    gx = np.asarray(x) @ np.asarray(x).T
    gy = np.asarray(y, np.float64) @ np.asarray(y, np.float64).T
    np.testing.assert_allclose(gy, gx, rtol=2e-4, atol=2e-3)


@pytest.mark.parametrize("d", [2, 4, 16, 256])
def test_fwht_matches_matrix(rng, d):
    x = jnp.asarray(rng.standard_normal((3, d)), jnp.float32)
    h = jnp.asarray(hadamard_matrix(d), jnp.float32)
    np.testing.assert_allclose(np.asarray(fwht(x)), np.asarray(x @ h), atol=1e-4)


@pytest.mark.parametrize("n", [12, 24, 576, 1536])
def test_apply_rotation_matches_matrix(rng, n):
    x = jnp.asarray(rng.standard_normal((5, n)), jnp.float32)
    r = jnp.asarray(hadamard_matrix(n), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(apply_rotation(x, n)), np.asarray(x @ r), atol=2e-4
    )


def test_random_orthogonal_deterministic():
    a = random_orthogonal(36, seed=3)
    b = random_orthogonal(36, seed=3)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_allclose(a @ a.T, np.eye(36), atol=1e-10)


def test_rotation_reduces_incoherence(rng):
    # a weight matrix with strong per-channel outliers
    w = rng.standard_normal((128, 128)).astype(np.float32)
    w[:, 3] *= 30.0
    mu_before = incoherence(w)
    r = hadamard_matrix(128)
    mu_after = incoherence(w @ r)
    assert mu_after < 0.5 * mu_before
