"""EP (expert-parallel shard_map) vs dense-dispatch equivalence on a forced
8-device host mesh.  Runs in a subprocess so the 1-device tests elsewhere
keep their platform config."""

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.configs import get_config
from repro.models.config import reduced
from repro.models import moe as moe_lib
import dataclasses

cfg = reduced(get_config("deepseek-v2-236b"), n_experts=8, moe_top_k=2,
              capacity_factor=8.0)  # high capacity => no drops => exact match
key = jax.random.PRNGKey(0)
p = moe_lib.init_moe_params(cfg, key, jnp.float32)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)

from repro.core.jaxcompat import make_mesh, set_mesh
mesh = make_mesh((2, 4), ("data", "model"))
with set_mesh(mesh):
    dense = jax.jit(lambda p, x: moe_lib.moe_block(cfg, p, x, impl="dense"))(p, x)
    ep = jax.jit(lambda p, x: moe_lib.moe_block(cfg, p, x, impl="ep"))(p, x)
err = float(jnp.abs(dense - ep).max())
rel = err / float(jnp.abs(dense).max())
print("ERR", rel)
assert rel < 2e-5, rel

# with a tight capacity factor, EP drops tokens but stays finite
cfg2 = dataclasses.replace(cfg, capacity_factor=0.5)
with set_mesh(mesh):
    ep2 = jax.jit(lambda p, x: moe_lib.moe_block(cfg2, p, x, impl="ep"))(p, x)
assert bool(jnp.all(jnp.isfinite(ep2)))
print("OK")
"""


def test_ep_matches_dense():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout
