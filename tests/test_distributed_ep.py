"""EP (expert-parallel shard_map) vs dense-dispatch equivalence on a forced
8-device host mesh.  Runs in a subprocess so the 1-device tests elsewhere
keep their platform config."""

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.configs import get_config
from repro.models.config import reduced
from repro.models import moe as moe_lib
import dataclasses

cfg = reduced(get_config("deepseek-v2-236b"), n_experts=8, moe_top_k=2,
              capacity_factor=8.0)  # high capacity => no drops => exact match
key = jax.random.PRNGKey(0)
p = moe_lib.init_moe_params(cfg, key, jnp.float32)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)

from repro.core.jaxcompat import make_mesh, set_mesh
mesh = make_mesh((2, 4), ("data", "model"))
with set_mesh(mesh):
    dense = jax.jit(lambda p, x: moe_lib.moe_block(cfg, p, x, impl="dense"))(p, x)
    ep = jax.jit(lambda p, x: moe_lib.moe_block(cfg, p, x, impl="ep"))(p, x)
err = float(jnp.abs(dense - ep).max())
rel = err / float(jnp.abs(dense).max())
print("ERR", rel)
assert rel < 2e-5, rel

# with a tight capacity factor, EP drops tokens but stays finite
cfg2 = dataclasses.replace(cfg, capacity_factor=0.5)
with set_mesh(mesh):
    ep2 = jax.jit(lambda p, x: moe_lib.moe_block(cfg2, p, x, impl="ep"))(p, x)
assert bool(jnp.all(jnp.isfinite(ep2)))
print("OK")
"""


def test_ep_matches_dense():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout


# ---- capacity semantics, single device (no subprocess needed) -----------

def test_capacity_rounds_up_to_eight():
    from repro.distributed.ep import _capacity

    assert _capacity(16, 2, 8, 1.25) == 8      # 5 -> rounds up to 8
    assert _capacity(100, 2, 8, 1.0) == 32     # 25 -> next multiple of 8
    assert _capacity(64, 2, 8, 1.0) == 16      # exact multiple stays put
    assert _capacity(1, 1, 64, 1.0) == 8       # floor: never below 8
    assert _capacity(8, 2, 0, 1.0) == 16       # max(1, e) guards div-by-zero


def _ep_problem(n_experts, capacity_factor, t=16, d=8, h=16, k=2, seed=0):
    """Raw-weight (non-QLinear) experts_ep problem on a 1-device mesh."""
    import types

    import numpy as np

    cfg = types.SimpleNamespace(n_experts=n_experts,
                                capacity_factor=capacity_factor)
    rng = np.random.default_rng(seed)
    p = {"experts": {
        "wg": rng.standard_normal((n_experts, d, h)).astype(np.float32),
        "wu": rng.standard_normal((n_experts, d, h)).astype(np.float32),
        "wd": rng.standard_normal((n_experts, h, d)).astype(np.float32),
    }}
    x = rng.standard_normal((t, d)).astype(np.float32)
    logits = rng.standard_normal((t, n_experts)).astype(np.float32)
    weights = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
    top_idx = np.argsort(-weights, axis=-1)[:, :k].astype(np.int32)
    return cfg, p, x, weights, top_idx


def _run_ep(cfg, p, x, weights, top_idx, with_stats):
    import jax.numpy as jnp

    from repro.core.jaxcompat import make_mesh, set_mesh
    from repro.distributed.ep import experts_ep

    mesh = make_mesh((1,), ("model",))
    with set_mesh(mesh):
        return experts_ep(cfg, {"experts": {k_: jnp.asarray(v) for k_, v in
                                            p["experts"].items()}},
                          jnp.asarray(x), jnp.asarray(weights),
                          jnp.asarray(top_idx), with_stats=with_stats)


def test_ep_overflow_drop_deterministic():
    """Tight capacity: drops happen, are deterministic call-to-call, and
    the drop counter matches the numpy capacity-overflow reference."""
    import numpy as np

    from repro.distributed.ep import _capacity

    cfg, p, x, weights, top_idx = _ep_problem(4, 0.25, t=64)
    cap = _capacity(64, 2, 4, 0.25)
    counts = np.bincount(top_idx.reshape(-1), minlength=4)
    want_dropped = int(np.maximum(0, counts - cap).sum())
    assert want_dropped > 0, "test needs real overflow to mean anything"

    y1, d1 = _run_ep(cfg, p, x, weights, top_idx, with_stats=True)
    y2, d2 = _run_ep(cfg, p, x, weights, top_idx, with_stats=True)
    assert int(d1) == want_dropped, (int(d1), want_dropped)
    assert int(d2) == int(d1)
    assert np.array_equal(np.asarray(y1), np.asarray(y2)), \
        "overflow drop is not deterministic"


def test_ep_prob_weighted_combine_matches_dense():
    """Generous capacity (no drops): EP output equals the dense one-hot
    reference sum_k w[t,e_k] * expert_{e_k}(x_t)."""
    import numpy as np

    cfg, p, x, weights, top_idx = _ep_problem(4, 8.0)
    y, dropped = _run_ep(cfg, p, x, weights, top_idx, with_stats=True)
    assert int(dropped) == 0

    def silu(v):
        return v / (1.0 + np.exp(-v))

    ref = np.zeros_like(x)
    for t_ in range(x.shape[0]):
        for e in top_idx[t_]:
            h = silu(x[t_] @ p["experts"]["wg"][e]) * (x[t_] @ p["experts"]["wu"][e])
            ref[t_] += weights[t_, e] * (h @ p["experts"]["wd"][e])
    err = np.abs(np.asarray(y) - ref).max() / np.abs(ref).max()
    assert err < 1e-5, err
