"""K-split, R-tiled fused grid (PR 3): the slab-shaped row bodies, the
per-slab VMEM feasibility model (shrink-to-fit before demotion), the
prologue-variant selection, the configurable VMEM budgets, the block-table
validation on malformed/partial JSON, the graceful regression gate, and the
acceptance shape — K×R×4 = 32 MB of V executing the fused path with
bitwise cross-path parity.  All kernels run in pallas interpret mode."""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import make_w4a4_problem as _problem
from repro.kernels import ops
from repro.kernels.context import KernelContext
from repro.kernels.fused_gemm import fused_w4a4_lrc_kernel
from repro.kernels.rowops import (
    fwht_cross_rows,
    fwht_intra_rows,
    fwht_rows,
    project_rows_tiled,
)

# (per-test isolation of the process-default KernelContext comes from the
# autouse _kernel_state_guard fixture in conftest.py)


# ---------------------------------------------------------------------------
# slab-shaped row bodies: the K-split decomposition is bitwise exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d,bk", [(64, 64), (128, 32), (256, 64), (64, 8)])
def test_fwht_intra_cross_bitwise_equals_whole_row(rng, d, bk):
    """fwht_cross_rows ∘ per-chunk fwht_intra_rows is BITWISE the whole-row
    transform: butterflies below bk never cross a chunk boundary, so the
    sweep order and operand pairing are identical."""
    x = jnp.asarray(rng.standard_normal((16, d)), jnp.float32)
    want = np.asarray(fwht_rows(x, d))
    chunks = [fwht_intra_rows(x[:, c * bk:(c + 1) * bk], bk)
              for c in range(d // bk)]
    got = np.asarray(fwht_cross_rows(jnp.concatenate(chunks, axis=1), d, bk))
    np.testing.assert_array_equal(got, want)


def test_project_rows_tiled_matches_single_dot(rng):
    """The canonical (bk, br)-tiled projection tracks the single whole-K dot
    within f32 reassociation noise (bits legitimately differ)."""
    x = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((256, 48)), jnp.float32)
    got = np.asarray(project_rows_tiled(x, v, bk=64, br=16))
    np.testing.assert_allclose(got, np.asarray(x @ v), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# K-split fused kernel: multi-chunk/multi-R-tile grids, prologue variants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n,r,blocks", [
    (16, 256, 128, 40, (16, 64, 64, 16)),   # n_k=4, n_r=3 (r_pad=48)
    (24, 128, 96, 8, (8, 32, 32, 8)),       # ragged M, n_k=4
    (16, 512, 64, 96, (16, 64, 128, 32)),   # n_k=4, n_r=3
])
@pytest.mark.parametrize("rotate", [False, True])
def test_ksplit_cross_path_bitwise(rng, m, k, n, r, blocks, rotate):
    """Multi-K-chunk, multi-R-tile tilings: all three paths + auto stay
    bitwise identical (they share the chunked accumulation order)."""
    if rotate and k & (k - 1):
        pytest.skip("online rotation needs power-of-two K")
    spec, x, wp, s, u, v = _problem(rng, m, k, n, r)
    outs = {
        impl: np.asarray(ops.w4a4_lrc_forward(x, wp, s, u, v, spec,
                                              rotate=rotate, blocks=blocks,
                                              impl=impl))
        for impl in ("fused", "chained", "unfused")
    }
    np.testing.assert_array_equal(outs["fused"], outs["chained"])
    np.testing.assert_array_equal(outs["fused"], outs["unfused"])


def test_fused_prologue_variants_bitwise_identical(rng):
    """The resident (f32 row slab) and streamed (x re-read) prologue
    variants compute the same values chunk for chunk."""
    m, k, n, r = 16, 256, 128, 40
    spec, x, wp, s, u, v = _problem(rng, m, k, n, r)
    outs = []
    for variant in ("resident", "streamed"):
        outs.append(np.asarray(fused_w4a4_lrc_kernel(
            x, v, wp, s.reshape(1, -1), u, bits=4, clip_ratio=0.9,
            rotate=False, bm=16, bn=64, bk=64, br=16, variant=variant,
            interpret=True)))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_fused_kernel_rejects_streamed_rotation(rng):
    spec, x, wp, s, u, v = _problem(rng, 16, 64, 32, 8)
    with pytest.raises(AssertionError, match="resident"):
        fused_w4a4_lrc_kernel(x, v, wp, s.reshape(1, -1), u,
                              rotate=True, bm=16, bn=32, bk=64, br=8,
                              variant="streamed", interpret=True)


# ---------------------------------------------------------------------------
# per-slab feasibility: shrink-to-fit, variant pick, demotion ladder,
# and the acceptance shape (no demotion at K×R×4 = 32 MB)
# ---------------------------------------------------------------------------


def test_resolve_plan_acceptance_shape_stays_fused():
    """K=8192, R=1024: V alone is 32 MB — 4× the old whole-VMEM ceiling —
    and every M regime still resolves to the fused path."""
    assert 8192 * 1024 * 4 > ops._PROLOGUE_V_BYTES_MAX
    for m in (16, 256, 2048):
        plan = ops.resolve_plan(m, 8192, 256, 1024, rotate=True)
        assert plan.path == "fused", (m, plan)
        assert plan.variant == "resident"
        assert ops._fused_vmem_bytes(8192, 1024, plan.bm, plan.bn, plan.bk,
                                     plan.br, True) <= ops.fused_vmem_budget()


def test_resolve_plan_shrinks_tiles_before_demoting():
    """A budget too small for the table tiles but big enough for smaller
    ones keeps the fused path with shrunk tiles."""
    full = ops.resolve_plan(2048, 8192, 11008, 1024, rotate=True)
    assert full.path == "fused"
    tight = ops._fused_vmem_bytes(8192, 1024, full.bm, full.bn, full.bk,
                                  full.br, True) - 1
    ctx = KernelContext().with_vmem_budgets(fused=tight)
    shrunk = ops.resolve_plan(2048, 8192, 11008, 1024, rotate=True, ctx=ctx)
    assert shrunk.path == "fused"
    assert (shrunk.bm, shrunk.bn, shrunk.bk, shrunk.br) != \
        (full.bm, full.bn, full.bk, full.br)
    assert ops._fused_vmem_bytes(8192, 1024, shrunk.bm, shrunk.bn,
                                 shrunk.bk, shrunk.br, True) <= tight


def test_resolve_plan_streamed_variant_drops_row_slab():
    """rotate=False: when the resident f32 row slab cannot fit at any
    tiling, the streamed variant keeps the path fused."""
    resident_floor = ops._fused_vmem_bytes(8192, 0, 8, 128, 128, 128, True)
    streamed_floor = ops._fused_vmem_bytes(8192, 0, 8, 128, 128, 128, False)
    assert streamed_floor < resident_floor
    ctx = KernelContext().with_vmem_budgets(fused=resident_floor - 1)
    plan = ctx.resolve_plan(2048, 8192, 11008, 0, rotate=False)
    assert plan.path == "fused" and plan.variant == "streamed"
    # rotation pins the resident slab -> that budget demotes to chained
    plan_rot = ctx.resolve_plan(2048, 8192, 11008, 0, rotate=True)
    assert plan_rot.path == "chained"


def test_resolve_plan_demotion_ladder():
    ctx = KernelContext().with_vmem_budgets(fused=0)
    plan = ctx.resolve_plan(16, 4096, 11008, 128, rotate=True)
    assert plan.path == "chained"
    ctx = ctx.with_vmem_budgets(prologue=0)
    plan = ctx.resolve_plan(16, 4096, 11008, 128, rotate=True)
    assert plan.path == "unfused"


def test_auto_dispatch_shrunk_plan_executes(rng):
    """End to end: a tight budget shrinks the auto plan's tiles and the
    kernel still runs (results match the default-plan bits only within
    tolerance — a different bk legitimately reorders the xv accumulation)."""
    spec, x, wp, s, u, v = _problem(rng, 16, 256, 128, 40)
    want = np.asarray(ops.w4a4_lrc_forward(x, wp, s, u, v, spec,
                                           rotate=True))
    d = ops.resolve_plan(16, 256, 128, 40, rotate=True)
    need = ops._fused_vmem_bytes(256, 40, d.bm, d.bn, d.bk, d.br, True)
    ctx = KernelContext().with_vmem_budgets(fused=need - 1)
    plan = ctx.resolve_plan(16, 256, 128, 40, rotate=True)
    assert plan.path == "fused"
    got = np.asarray(ops.w4a4_lrc_forward(x, wp, s, u, v, spec, rotate=True,
                                          ctx=ctx))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_big_v_executes_fused_with_parity(rng):
    """Interpret-sized spelling of the CI acceptance run (which executes the
    full K=8192 shape): a rank-1024 V at the old budget boundary resolves
    to the fused path and all paths agree bitwise."""
    m, k, n, r = 8, 2048, 64, 1024
    plan = ops.resolve_plan(m, k, n, r, rotate=True)
    assert plan.path == "fused" and plan.variant == "resident"
    spec, x, wp, s, u, v = _problem(rng, m, k, n, r)
    outs = {
        impl: np.asarray(ops.w4a4_lrc_forward(x, wp, s, u, v, spec,
                                              rotate=True, impl=impl))
        for impl in ("fused", "chained", "unfused", "auto")
    }
    np.testing.assert_array_equal(outs["fused"], outs["chained"])
    np.testing.assert_array_equal(outs["fused"], outs["unfused"])
    np.testing.assert_array_equal(outs["fused"], outs["auto"])


# ---------------------------------------------------------------------------
# configurable VMEM budgets (ctx.with_vmem_budgets / block-table "vmem"
# entry)
# ---------------------------------------------------------------------------


def test_vmem_budget_builders_and_validation():
    ctx = KernelContext().with_vmem_budgets(fused=1234567, prologue=7654321)
    assert ctx.fused_vmem_bytes == 1234567
    assert ctx.prologue_vmem_bytes == 7654321
    # None leaves a budget untouched
    assert ctx.with_vmem_budgets(fused=99).prologue_vmem_bytes == 7654321
    with pytest.raises(ValueError, match="budget"):
        KernelContext().with_vmem_budgets(fused=-1)
    with pytest.raises(ValueError, match="budget"):
        KernelContext().with_vmem_budgets(prologue="8MB")


def test_block_table_vmem_entry(tmp_path):
    p = tmp_path / "table.json"
    p.write_text(json.dumps({
        "decode": {"path": "fused", "bm": 16, "bn": 256, "bk": 256,
                   "br": 256},
        "vmem": {"fused_bytes_max": 4 * 1024 * 1024,
                 "prologue_bytes_max": 2 * 1024 * 1024},
    }))
    ctx = KernelContext.from_json(p)
    assert ctx.fused_vmem_bytes == 4 * 1024 * 1024
    assert ctx.prologue_vmem_bytes == 2 * 1024 * 1024
    # the tighter budget flows into plan resolution
    plan = ctx.resolve_plan(16, 8192, 11008, 1024, rotate=True)
    assert ops._fused_vmem_bytes(8192, 1024, plan.bm, plan.bn, plan.bk,
                                 plan.br, True) <= 4 * 1024 * 1024 \
        or plan.path != "fused"


@pytest.mark.parametrize("table,msg", [
    ({"vmem": {"fused_bytes_max": "12MB"}}, "positive int"),
    ({"vmem": {"hbm_bytes_max": 1}}, "unknown vmem budget"),
    ({"vmem": [1, 2]}, "must be an object"),
    ({"decode": {"path": "fused", "bm": 16.5, "bn": 256, "bk": 256}},
     "positive integer"),
    ({"decode": {"path": "fused", "bm": "16", "bn": 256, "bk": 256}},
     "positive integer"),
    ({"decode": {"path": "fused", "bm": 16, "bn": 256, "bk": 256,
                 "br": 0}}, "positive integer"),
    ({"decode": {"path": "fused", "bm": 16, "bn": 256, "bk": 256,
                 "br": True}}, "positive integer"),
    ({"decode": [16, 256, 256]}, "must map to an object"),
])
def test_block_table_malformed_values(tmp_path, table, msg):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps(table))
    with pytest.raises(ValueError, match=msg):
        KernelContext.from_json(p)
    # a rejected table builds nothing — the process default is untouched
    assert ops.select_plan(16, 4096, 11008, 128).path == "fused"
    assert ops.fused_vmem_budget() == ops._FUSED_VMEM_BYTES_MAX


@pytest.mark.parametrize("text,msg", [
    ('{"decode": {"path": "fused", "bm": 16', "not valid JSON"),  # truncated
    ("decode: fused", "not valid JSON"),
    ('["decode"]', "must be a JSON object"),
])
def test_block_table_partial_json(tmp_path, text, msg):
    p = tmp_path / "partial.json"
    p.write_text(text)
    with pytest.raises(ValueError, match=msg):
        KernelContext.from_json(p)
    assert ops.select_plan(16, 4096, 11008, 128).path == "fused"


# ---------------------------------------------------------------------------
# roofline byte model: fused_stream + the prefill crossover (acceptance)
# ---------------------------------------------------------------------------


def test_byte_model_fused_stream():
    from repro.launch.roofline import prologue_activation_bytes

    for m, k, r in [(16, 4096, 128), (2048, 8192, 1024)]:
        a = m * k * 2
        fu = prologue_activation_bytes(m, k, r, rotate=True, path="fused")
        fs = prologue_activation_bytes(m, k, r, rotate=True,
                                       path="fused_stream")
        ch = prologue_activation_bytes(m, k, r, rotate=True, path="chained")
        assert fu == a and fs == 2 * a
        assert fu < fs < ch  # even the extra x read beats the xq round-trip


def test_byte_model_fused_leq_chained_at_prefill_acceptance_shape():
    """Acceptance: at the K=8192, R=1024 prefill shape the fused path's
    activation bytes are ≤ chained (strictly below, by the eliminated
    M×K xq + sx/xv round-trip)."""
    from repro.launch.roofline import prologue_activation_bytes

    m, k, r = 2048, 8192, 1024
    fu = prologue_activation_bytes(m, k, r, rotate=True, path="fused")
    ch = prologue_activation_bytes(m, k, r, rotate=True, path="chained")
    assert fu <= ch
    assert ch - fu == 2 * (m * k + 4 * m + 4 * m * r)


def test_roofline_time_fused_never_worse_than_chained():
    from benchmarks.latency_kernels import _roofline_time

    for m in (16, 256, 2048):
        for k, n in [(4096, 11008), (8192, 28672)]:
            for r in (0, 128, 1024):
                t_fu = _roofline_time(m, k, n, r, "fused")
                t_ch = _roofline_time(m, k, n, r, "chained")
                assert t_fu <= t_ch, (m, k, n, r)


# ---------------------------------------------------------------------------
# regression gate: graceful failure on stale baselines (satellite)
# ---------------------------------------------------------------------------


def test_check_regression_missing_column_fails_gracefully(tmp_path):
    """A committed baseline that predates a new guarded column fails with a
    clear regenerate message — not a KeyError."""
    from benchmarks.check_regression import check
    from benchmarks.latency_kernels import HEADER, analytic_rows

    rows = analytic_rows(ms=[16], sizes=[(4096, 11008)], ranks=[0, 128])
    drop = HEADER.index("us_fused_stream")
    old_header = [h for i, h in enumerate(HEADER) if i != drop]
    old_rows = [[x for i, x in enumerate(r) if i != drop] for r in rows]
    stale = tmp_path / "stale_columns.json"
    stale.write_text(json.dumps(dict(header=old_header, rows=old_rows)))
    failures = check(stale, 0.05)
    assert failures and any("us_fused_stream" in f for f in failures)
    assert any("regenerate" in f for f in failures)


def test_check_regression_short_rows_fail_gracefully(tmp_path):
    from benchmarks.check_regression import check
    from benchmarks.latency_kernels import HEADER

    bad = tmp_path / "short.json"
    bad.write_text(json.dumps(dict(header=HEADER, rows=[["M16_11008x4096"]])))
    failures = check(bad, 0.05)
    assert failures and any("shorter" in f for f in failures)


def test_check_regression_unreadable_baseline(tmp_path):
    from benchmarks.check_regression import check

    bad = tmp_path / "truncated.json"
    bad.write_text('{"header": [')
    failures = check(bad, 0.05)
    assert failures and any("unreadable" in f for f in failures)
