"""Quantized KV cache subsystem: KVSpec geometry/serialization, the
canonical quantize/dequantize spellings, dequant-fused flash kernels
(dense + paged, parity on garbage pools with shuffled page placement),
end-to-end engine behavior (f32 bitwise identity, quantized invariances,
health reporting, family gating), and the roofline attention-bytes model
the acceptance ratios ride on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops
from repro.launch.roofline import attention_kv_bytes
from repro.models import model
from repro.models.config import reduced
from repro.serve import KVSpec, ServeEngine, Request, RequestState
from repro.serve.kvquant import KV_DTYPES, dequantize_kv, quantize_kv

# the sweep every parametrized test below covers: both quantized widths,
# per-head and grouped scales
QSPECS = [KVSpec(dtype="int8"), KVSpec(dtype="int4"),
          KVSpec(dtype="int8", group=8), KVSpec(dtype="int4", group=8)]


# ---------------------------------------------------------------------------
# KVSpec unit tests
# ---------------------------------------------------------------------------


def test_kvspec_validation_and_geometry():
    assert KVSpec().dtype == "f32" and not KVSpec().is_quantized
    assert KVSpec(dtype="bf16").cache_dtype == jnp.bfloat16
    with pytest.raises(ValueError, match="unknown kv dtype"):
        KVSpec(dtype="fp8")
    with pytest.raises(ValueError, match="only applies to quantized"):
        KVSpec(dtype="f32", group=64)
    with pytest.raises(ValueError, match="positive int"):
        KVSpec(dtype="int8", group=-4)
    with pytest.raises(ValueError, match="does not divide"):
        KVSpec(dtype="int8", group=48).group_for(128)
    with pytest.raises(ValueError, match="even head_dim"):
        KVSpec(dtype="int4").packed_head_dim(33)
    with pytest.raises(ValueError, match="no float cache dtype"):
        _ = KVSpec(dtype="int8").cache_dtype
    # group clamps to head_dim: g=128 on a 64-wide head IS per-head
    s = KVSpec(dtype="int4", group=128)
    assert s.group_for(64) == 64 and s.n_groups(64) == 1
    assert s.group_for(256) == 128 and s.n_groups(256) == 2
    assert s.packed_head_dim(64) == 32
    assert KVSpec(dtype="int8").pool_dtype == jnp.int8
    assert KVSpec(dtype="int4").pool_dtype == jnp.uint8
    # float specs have no sidecar
    assert KVSpec().n_groups(64) == 0 and KVSpec(dtype="bf16").n_groups(64) == 0


def test_kvspec_meta_roundtrip_and_backcompat():
    for spec in [KVSpec(), KVSpec(dtype="bf16"), *QSPECS]:
        assert KVSpec.from_meta(spec.to_meta()) == spec
    # pre-KVSpec journals / snapshots carry neither key -> f32 identity
    assert KVSpec.from_meta({}) == KVSpec()
    assert KVSpec.from_meta({"mode": "paged", "seed": 0}) == KVSpec()
    assert KVSpec.from_flags(None, None) == KVSpec()
    assert KVSpec.from_flags("int4", 128) == KVSpec(dtype="int4", group=128)
    assert KVSpec(dtype="int4", group=16).describe() == "int4-g16"
    assert KVSpec(dtype="int8").describe() == "int8"
    assert set(KV_DTYPES) == {"f32", "bf16", "int8", "int4"}


def test_kv_bytes_per_token_acceptance_ratios():
    """The acceptance bars at the reference serving geometry (8 KV heads x
    128 head_dim): int8 cuts attention KV bytes >=3x, int4-g128 >=6x —
    including the f32 scale-plane overhead, not just the payload."""
    kh, hd = 8, 128
    f32 = KVSpec().kv_bytes_per_token(kh, hd)
    i8 = KVSpec(dtype="int8").kv_bytes_per_token(kh, hd)
    i4 = KVSpec(dtype="int4", group=128).kv_bytes_per_token(kh, hd)
    assert f32 == 2 * kh * 4 * hd == 8192
    assert i8 == 2 * kh * (hd + 4)          # int8 payload + one f32 scale
    assert i4 == 2 * kh * (hd // 2 + 4)     # packed nibbles + one f32 scale
    assert f32 / i8 >= 3.0
    assert f32 / i4 >= 6.0
    # the roofline spelling is the same function, scaled by context length
    assert attention_kv_bytes(100, kh, hd, "f32") == 100 * f32
    assert attention_kv_bytes(100, kh, hd, "int8") == 100 * i8
    assert attention_kv_bytes(100, kh, hd, "int4", 128) == 100 * i4
    # and the latency table guards all three columns via the attn_kb_ prefix
    from benchmarks.check_regression import _GUARDED
    from benchmarks.latency_kernels import HEADER
    for col in ("attn_kb_f32", "attn_kb_int8", "attn_kb_int4_g128"):
        assert col in HEADER and col in _GUARDED


# ---------------------------------------------------------------------------
# quantize / dequantize roundtrip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", QSPECS, ids=lambda s: s.describe())
def test_quantize_roundtrip_error_bound(rng, spec):
    """Absmax group quantization: |x - dq(q(x))| <= scale/2 elementwise
    (round-to-nearest), scales are per-group positive, and int4 really
    packs two values per byte."""
    hd = 16
    x = jnp.asarray(rng.standard_normal((3, 5, 2, hd)), jnp.float32) * 4.0
    q, s = quantize_kv(x, spec)
    g = spec.group_for(hd)
    assert s.shape == (3, 5, 2, hd // g)
    assert q.shape == (3, 5, 2, spec.packed_head_dim(hd))
    assert q.dtype == spec.pool_dtype
    back = dequantize_kv(q, s, spec, hd)
    bound = jnp.repeat(s, g, axis=-1) * 0.5 + 1e-6
    assert jnp.all(jnp.abs(back - x) <= bound), \
        float(jnp.max(jnp.abs(back - x) - bound))
    # deterministic: same rows always quantize to the same bytes (the
    # property that extends the engine's placement invariance to pools)
    q2, s2 = quantize_kv(x, spec)
    assert np.array_equal(np.asarray(q), np.asarray(q2))
    assert np.array_equal(np.asarray(s), np.asarray(s2))
    # all-zero rows are the scale guard's edge: exact roundtrip, no NaNs
    zq, zs = quantize_kv(jnp.zeros((2, hd)), spec)
    assert np.all(np.asarray(dequantize_kv(zq, zs, spec, hd)) == 0.0)


# ---------------------------------------------------------------------------
# dequant-fused flash kernels
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", QSPECS, ids=lambda s: s.describe())
def test_dense_quant_kernel_matches_dequant_reference(rng, spec):
    """flash_attention_quant (dequant fused into the online-softmax tiles)
    vs dequantize-then-f32-flash — same math, the fused path just never
    materializes f32 KV."""
    b, sq, skv, h, kh, d = 2, 16, 24, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, skv, kh, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, skv, kh, d)), jnp.float32)
    scale = float(d) ** -0.5  # python float: stays weakly typed under x64
    kq, ks = quantize_kv(k, spec)
    vq, vs = quantize_kv(v, spec)
    out = ops.flash_attention_quant(q, kq, ks, vq, vs, scale, spec,
                                    causal=False, bq=8, bkv=8)
    ref = ops.flash_attention(q, dequantize_kv(kq, ks, spec, d),
                              dequantize_kv(vq, vs, spec, d), scale,
                              causal=False, bq=8, bkv=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("spec", QSPECS, ids=lambda s: s.describe())
def test_paged_quant_kernel_gathers_only_mapped_pages(rng, spec):
    """The paged decode gather over a QUANTIZED pool: unmapped pool slots
    hold garbage, per-sequence block tables use disjoint shuffled page ids,
    and the fused-dequant kernel must still match a dense dequant reference
    — proving it reads (and dequantizes) exactly the mapped pages."""
    b, h, kh, d = 3, 4, 2, 16
    page, mpb, npages = 4, 6, 19
    lens = np.asarray([5, 11, 24], np.int32)
    g = spec.group_for(d)
    kpool = jnp.asarray(rng.standard_normal(
        (npages, page, kh, spec.packed_head_dim(d))) * 40)
    vpool = jnp.asarray(rng.standard_normal(
        (npages, page, kh, spec.packed_head_dim(d))) * 40)
    kpool = kpool.astype(spec.pool_dtype)
    vpool = vpool.astype(spec.pool_dtype)
    kspool = jnp.asarray(rng.standard_normal((npages, page, kh, d // g)),
                         jnp.float32) * 7
    vspool = jnp.asarray(rng.standard_normal((npages, page, kh, d // g)),
                         jnp.float32) * 7
    keys = jnp.asarray(rng.standard_normal((b, mpb * page, kh, d)),
                       jnp.float32)
    vals = jnp.asarray(rng.standard_normal((b, mpb * page, kh, d)),
                       jnp.float32)
    # disjoint shuffled placement: each sequence owns its own slice of a
    # global permutation (two sequences must never share a page id)
    ids = rng.permutation(np.arange(1, npages))
    bt = np.zeros((b, mpb), np.int32)
    for i in range(b):
        need = -(-int(lens[i]) // page)
        mine = ids[i * mpb:(i + 1) * mpb][:need]
        bt[i, :need] = mine
        kq, ks = quantize_kv(keys[i, :need * page], spec)
        vq, vs = quantize_kv(vals[i, :need * page], spec)
        for j, pid in enumerate(mine):
            kpool = kpool.at[pid].set(kq[j * page:(j + 1) * page])
            vpool = vpool.at[pid].set(vq[j * page:(j + 1) * page])
            kspool = kspool.at[pid].set(ks[j * page:(j + 1) * page])
            vspool = vspool.at[pid].set(vs[j * page:(j + 1) * page])
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    scale = float(d) ** -0.5  # python float: stays weakly typed under x64
    out = ops.paged_flash_attention_quant(
        q, kpool, kspool, vpool, vspool, jnp.asarray(bt),
        jnp.asarray(lens), scale, spec)
    # dense reference on the SAME quantized rows, masked to each length
    grp = h // kh
    for i in range(b):
        need = -(-int(lens[i]) // page)
        kq, ks = quantize_kv(keys[i, :need * page], spec)
        vq, vs = quantize_kv(vals[i, :need * page], spec)
        kd = dequantize_kv(kq, ks, spec, d)[:lens[i]]
        vd = dequantize_kv(vq, vs, spec, d)[:lens[i]]
        kf = jnp.repeat(kd, grp, axis=1)  # (S, KH, D) -> (S, H, D)
        vf = jnp.repeat(vd, grp, axis=1)
        logits = jnp.einsum("hd,shd->hs", q[i], kf) * scale
        ref = jnp.einsum("hs,shd->hd", jax.nn.softmax(logits, axis=-1), vf)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def dense():
    cfg = reduced(get_config("smollm-135m"))
    return cfg, model.init_params(cfg, jax.random.PRNGKey(0))


def _serve(cfg, params, prompts, *, new=6, **kw):
    eng = ServeEngine(cfg, params, batch_slots=kw.pop("batch_slots", 4),
                      max_seq=32, seed=3, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=new))
    done = eng.run()
    assert all(done[i].status is RequestState.FINISHED
               for i in range(len(prompts))), done
    return eng, [done[i].out_tokens for i in range(len(prompts))]


def _prompts(rng, cfg, n=4, length=9):
    return [np.asarray(rng.integers(0, cfg.vocab_size, (length,)), np.int32)
            for _ in range(n)]


def test_f32_spec_is_bitwise_identity(dense, rng):
    """The compatibility keystone: serving with an explicit KVSpec('f32')
    traces the exact pre-KVSpec graph — token streams match a no-spec
    engine BITWISE, so the chaos + crash-recovery contract is untouched."""
    cfg, params = dense
    prompts = _prompts(rng, cfg)
    _, base = _serve(cfg, params, prompts)
    eng, toks = _serve(cfg, params, prompts, kv_spec=KVSpec())
    assert toks == base
    assert not eng.alloc.sidecar  # float specs carry no scale sidecar
    assert eng.pool["k"].dtype == jnp.float32
    assert "k_scale" not in eng.pool


def test_bf16_spec_routes_pool_dtype(dense, rng):
    cfg, params = dense
    prompts = _prompts(rng, cfg, n=2)
    eng, toks = _serve(cfg, params, prompts, kv_spec=KVSpec(dtype="bf16"))
    assert eng.pool["k"].dtype == jnp.bfloat16
    assert all(len(t) == 6 for t in toks)
    assert eng.health()["kv"]["layout"] == "bf16"


@pytest.mark.parametrize("spec", [KVSpec(dtype="int8"),
                                  KVSpec(dtype="int4", group=16)],
                         ids=lambda s: s.describe())
def test_quantized_serving_invariances(dense, rng, spec):
    """The guarantees that make paging invisible survive quantization:
    tokens out of a quantized pool depend only on (params, prompt, seed) —
    not page placement, page size, co-tenancy, or prefill chunking.  This
    holds because rows are quantized BEFORE placement, so a token's stored
    bytes are placement-invariant."""
    cfg, params = dense
    prompts = _prompts(rng, cfg)

    def run(batch_slots, page_size, prefill_chunk=None, occupy=0,
            kv_pages=None):
        eng = ServeEngine(cfg, params, batch_slots=batch_slots, max_seq=32,
                          page_size=page_size, prefill_chunk=prefill_chunk,
                          kv_pages=kv_pages, seed=3, kv_spec=spec)
        assert eng.alloc.sidecar
        if occupy:
            assert eng.alloc.ensure(-1, occupy * page_size) is not None
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
        done = eng.run()
        assert all(done[i].ok for i in range(len(prompts))), done
        eng.alloc.check()
        # quantized pools really are quantized end-to-end
        assert eng.pool["k"].dtype == spec.pool_dtype
        assert eng.pool["k_scale"].dtype == jnp.float32
        return [done[i].out_tokens for i in range(len(prompts))]

    base = run(batch_slots=4, page_size=8)
    assert run(batch_slots=1, page_size=8) == base            # co-tenancy
    assert run(batch_slots=2, page_size=5) == base            # page size
    assert run(batch_slots=4, page_size=8, occupy=3,
               kv_pages=4 * 4 + 1 + 3) == base                # placement
    assert run(batch_slots=2, page_size=8, prefill_chunk=4) == base  # chunks


def test_health_reports_kv_scheme(dense, rng):
    cfg, params = dense
    kh, hd, L = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=32)
    kv = eng.health()["kv"]
    assert kv["dtype"] == "f32" and kv["layout"] == "f32"
    assert kv["bytes_per_token"] == L * 2 * kh * 4 * hd
    q = ServeEngine(cfg, params, batch_slots=2, max_seq=32,
                    kv_spec=KVSpec(dtype="int4", group=16))
    kvq = q.health()["kv"]
    assert kvq["layout"] == "int4-g16"
    assert kvq["bytes_per_token"] == \
        L * KVSpec(dtype="int4", group=16).kv_bytes_per_token(kh, hd)
    assert kvq["bytes_per_token"] < kv["bytes_per_token"] / 3
    # stacked (ssm) engines report their actual recurrent-state bytes
    ssm = reduced(get_config("mamba2-370m"))
    s = ServeEngine(ssm, model.init_params(ssm, jax.random.PRNGKey(0)),
                    batch_slots=2, max_seq=32)
    skv = s.health()["kv"]
    assert skv["dtype"] == "f32" and skv["state_bytes_per_slot"] > 0
    assert "bytes_per_token" not in skv


def test_quantized_spec_requires_paged_family(dense):
    """Quantized specs only apply to the paged pool: stacked/slots families
    refuse at construction with an actionable error, and float specs keep
    working everywhere."""
    ssm = reduced(get_config("mamba2-370m"))
    params = model.init_params(ssm, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="requires the paged KV cache"):
        ServeEngine(ssm, params, batch_slots=2, max_seq=32,
                    kv_spec=KVSpec(dtype="int8"))
    # float spec on a stacked family is fine (dtype plumbing, no paging)
    eng = ServeEngine(ssm, params, batch_slots=2, max_seq=32,
                      kv_spec=KVSpec())
    assert eng.health()["kv"]["dtype"] == "f32"


def test_quantized_spec_validates_geometry_eagerly(dense):
    """A group that cannot divide head_dim (or an odd head_dim for int4)
    fails at ServeEngine construction, not at first trace."""
    cfg, params = dense
    bad = cfg.head_dim - 1 if cfg.head_dim % 2 == 0 else cfg.head_dim
    with pytest.raises(ValueError, match="does not divide"):
        ServeEngine(cfg, params, batch_slots=2, max_seq=32,
                    kv_spec=KVSpec(dtype="int8", group=bad))
