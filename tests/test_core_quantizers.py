import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizers import (
    QuantSpec,
    act_scales,
    dequantize_act,
    dequantize_weight,
    fake_quant_act,
    pack_int4,
    quantize_act,
    quantize_weight_rtn,
    search_clip_ratio,
    unpack_int4,
)


@pytest.mark.parametrize("bits", [4, 8])
def test_weight_rtn_roundtrip_error_bound(rng, bits):
    w = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    spec = QuantSpec(bits=bits)
    q, s = quantize_weight_rtn(w, spec)
    wq = dequantize_weight(q, s, spec)
    # RTN error is at most half a step per element
    assert jnp.max(jnp.abs(w - wq)) <= 0.5 * jnp.max(s) + 1e-6
    assert q.dtype == jnp.int8
    assert int(q.max()) <= spec.qmax and int(q.min()) >= spec.qmin


def test_weight_rtn_grouped(rng):
    w = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    spec = QuantSpec(bits=4, group_size=16)
    q, s = quantize_weight_rtn(w, spec)
    assert s.shape == (8, 4)
    wq = dequantize_weight(q, s, spec)
    assert jnp.max(jnp.abs(w - wq)) <= 0.5 * jnp.max(s) + 1e-6


def test_act_quant_per_token(rng):
    x = jnp.asarray(rng.standard_normal((5, 7, 32)), jnp.float32)
    spec = QuantSpec(bits=4)
    q, s = quantize_act(x, spec)
    assert q.shape == x.shape and s.shape == (5, 7, 1)
    xq = dequantize_act(q, s, spec)
    assert jnp.max(jnp.abs(x - xq)) <= 0.5 * jnp.max(s) + 1e-6


def test_act_quant_grouped_matches_per_token_when_group_is_full_dim(rng):
    x = jnp.asarray(rng.standard_normal((9, 32)), jnp.float32)
    a = fake_quant_act(x, QuantSpec(bits=4))
    b = fake_quant_act(x, QuantSpec(bits=4, group_size=32))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0, atol=0)


def test_act_grouped_improves_outlier_error(rng):
    # one huge outlier per token ruins per-token scales; groups isolate it
    x = rng.standard_normal((64, 256)).astype(np.float32)
    x[:, 0] *= 50.0
    x = jnp.asarray(x)
    e_tok = float(jnp.sum((fake_quant_act(x, QuantSpec(bits=4)) - x) ** 2))
    e_grp = float(jnp.sum((fake_quant_act(x, QuantSpec(bits=4, group_size=64)) - x) ** 2))
    assert e_grp < 0.5 * e_tok


def test_clip_search_beats_default_on_heavy_tails(rng):
    x = jnp.asarray(rng.standard_t(df=2, size=(128, 64)), jnp.float32)
    c = search_clip_ratio(x, bits=4)
    assert 0.70 <= c <= 1.0
    e_c = float(jnp.sum((fake_quant_act(x, QuantSpec(bits=4, clip_ratio=c)) - x) ** 2))
    e_1 = float(jnp.sum((fake_quant_act(x, QuantSpec(bits=4, clip_ratio=1.0)) - x) ** 2))
    assert e_c <= e_1 + 1e-6


def test_pack_unpack_int4_roundtrip(rng):
    q = jnp.asarray(rng.integers(-8, 8, size=(6, 64)), jnp.int8)
    packed = pack_int4(q)
    assert packed.shape == (6, 32) and packed.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(unpack_int4(packed)), np.asarray(q))


def test_zero_input_safe():
    x = jnp.zeros((4, 16), jnp.float32)
    q, s = quantize_act(x, QuantSpec(bits=4))
    assert not np.any(np.isnan(np.asarray(s)))
    np.testing.assert_array_equal(np.asarray(q), 0)
