"""Tests of the LRC solver against the paper's propositions and claims."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.numerics import ensure_x64
from repro.core.quantizers import QuantSpec
from repro.core.stats import accumulate_stats, finalize_stats, init_stats
from repro.core.gptq import gptq_quantize, gptq_quantize_np
from repro.core.lrc import (
    init_lr,
    lrc_solve,
    modified_target,
    quantize_baseline,
    reconstruction_loss,
    svd_correction,
    update_lr,
    update_quant,
)

ensure_x64()


def make_calib(rng, n=2048, d=48, outliers=True):
    """Synthetic activations with LLM-like heavy-tailed feature outliers."""
    x = rng.standard_normal((n, d)).astype(np.float64)
    if outliers:
        scale = np.ones(d)
        scale[:: d // 6] = 8.0  # a few high-magnitude channels (pre-rotation LLM acts)
        x = x * scale[None, :]
    return jnp.asarray(x)


def build_stats(x, spec_a, eps_frac=1e-2):
    st = init_stats(x.shape[-1])
    # accumulate in two chunks to exercise the online path
    half = x.shape[0] // 2
    st = accumulate_stats(st, x[:half], spec_a)
    st = accumulate_stats(st, x[half:], spec_a)
    return finalize_stats(st, eps_frac=eps_frac)


@pytest.fixture
def problem(rng):
    d_in, d_out = 48, 40
    x = make_calib(rng, n=2048, d=d_in)
    w = jnp.asarray(rng.standard_normal((d_out, d_in)) / np.sqrt(d_in))
    spec_a = QuantSpec(bits=4)
    stats = build_stats(x, spec_a)
    return w, x, stats


def test_stats_accumulation_matches_direct(rng):
    d = 16
    x = jnp.asarray(rng.standard_normal((500, d)))
    spec = QuantSpec(bits=4)
    st = init_stats(d)
    st = accumulate_stats(st, x[:200], spec)
    st = accumulate_stats(st, x[200:], spec)
    np.testing.assert_allclose(np.asarray(st.sxx), np.asarray(x.T @ x), rtol=1e-10)
    assert float(st.count) == 500


def test_gptq_beats_rtn_on_correlated_inputs(rng):
    """GPTQ's whole point: on correlated X, error-compensated rounding beats RTN."""
    d_in, d_out, n = 32, 24, 4096
    # strongly correlated features
    mix = rng.standard_normal((d_in, d_in)) * 0.3 + np.eye(d_in)
    x = jnp.asarray(rng.standard_normal((n, d_in)) @ mix)
    w = jnp.asarray(rng.standard_normal((d_out, d_in)))
    h = x.T @ x
    spec = QuantSpec(bits=3)  # harder grid makes the difference pronounced

    from repro.core.quantizers import dequantize_weight, quantize_weight_rtn

    q_g, s_g = gptq_quantize(w, h, spec)
    w_g = dequantize_weight(q_g, s_g.astype(jnp.float64), spec)
    q_r, s_r = quantize_weight_rtn(w, spec)
    w_r = dequantize_weight(q_r, s_r.astype(jnp.float64), spec)

    err_g = float(jnp.sum(((w - w_g) @ x.T) ** 2))
    err_r = float(jnp.sum(((w - w_r) @ x.T) ** 2))
    assert err_g < err_r


def test_gptq_jax_matches_numpy_reference(rng):
    d_in, d_out = 24, 12
    x = rng.standard_normal((512, d_in))
    h = x.T @ x
    w = rng.standard_normal((d_out, d_in))
    spec = QuantSpec(bits=4)
    q_j, s_j = gptq_quantize(jnp.asarray(w), jnp.asarray(h), spec)
    q_n, s_n = gptq_quantize_np(w, h, spec, block=8)
    np.testing.assert_allclose(np.asarray(s_j), s_n, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(q_j), q_n)


def test_prop34_init_zero_when_no_activation_quant(rng):
    """Prop 3.4: with Y == X, Σ_init = WX[I - Xᵀ(XXᵀ)⁻¹X]XᵀWᵀ = 0 — no error
    to correct, eigenvalues vanish (up to damping)."""
    d = 24
    x = jnp.asarray(rng.standard_normal((1000, d)))
    w = jnp.asarray(rng.standard_normal((16, d)))
    spec_inf = QuantSpec(bits=16)  # ~identity quantizer
    st = build_stats(x, spec_inf, eps_frac=1e-9)
    u, v = init_lr(w, st, k=4)
    # the relaxation loss should be ≈ 0: perfect W̃ reconstructs WX exactly
    wt = modified_target(w, u, v, st)
    loss = reconstruction_loss(w, st, w_hat=wt, u=u, v=v)
    base = reconstruction_loss(w, st)  # ||WX||² scale
    assert loss < 1e-4 * base


def test_prop33_closed_form_is_stationary(problem, rng):
    """The closed-form (U,V) must satisfy ∂L/∂V = 0 and beat random
    same-rank corrections."""
    w, x, stats = problem
    spec_w = QuantSpec(bits=4)
    u0, v0 = init_lr(w, stats, k=6)
    _, _, w_hat = update_quant(w, u0, v0, stats, spec_w)
    u, v = update_lr(w, w_hat, stats, k=6)
    loss_star = reconstruction_loss(w, stats, w_hat=w_hat, u=u, v=v)

    # stationarity in V: UᵀUVᵀΣx = Uᵀ[WΣx − ŴΣxyᵀ]  (first-order condition)
    lhs = (u.T @ u) @ v.T @ stats.sxx
    rhs = u.T @ (jnp.asarray(w, jnp.float64) @ stats.sxx - w_hat @ stats.sxy.T)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-6, atol=1e-6)

    # optimality against random subspaces of the same rank
    for seed in range(5):
        r = np.random.default_rng(seed)
        ur = jnp.asarray(np.linalg.qr(r.standard_normal((w.shape[0], 6)))[0])
        # best V for that U (normal equation)
        z = jnp.linalg.solve(
            stats.sxx, (jnp.asarray(w, jnp.float64) @ stats.sxx - w_hat @ stats.sxy.T).T @ ur
        )
        loss_r = reconstruction_loss(w, stats, w_hat=w_hat, u=ur, v=z)
        assert loss_star <= loss_r + 1e-9


def test_lrc_beats_quarot_baseline(problem):
    """Paper Table 1 headline at layer level: LRC(k=10%) reconstruction error
    is well below the GPTQ-only baseline."""
    w, x, stats = problem
    spec_w = QuantSpec(bits=4)
    k = max(1, int(0.10 * min(w.shape)))

    _, _, w_base = quantize_baseline(w, stats, spec_w, hessian="x")
    base_loss = reconstruction_loss(w, stats, w_hat=w_base)

    res = lrc_solve(w, stats, spec_w, k=k, iters=1)
    assert res.losses[-1] < base_loss
    fp_loss = 0.0
    # gap closed by ≥ 50% (paper: "reduces the accuracy gap ... by more than 50%")
    assert (base_loss - res.losses[-1]) / (base_loss - fp_loss) > 0.5 or res.losses[
        -1
    ] < 0.5 * base_loss


def test_lrc_rank30pct_nearly_closes_gap(problem):
    w, x, stats = problem
    spec_w = QuantSpec(bits=4)
    k = max(1, int(0.50 * min(w.shape)))
    res = lrc_solve(w, stats, spec_w, k=k, iters=1)
    _, _, w_base = quantize_baseline(w, stats, spec_w, hessian="x")
    base_loss = reconstruction_loss(w, stats, w_hat=w_base)
    assert res.losses[-1] < 0.15 * base_loss


def test_lrc_iterations_do_not_increase_loss(problem):
    w, x, stats = problem
    res = lrc_solve(w, stats, QuantSpec(bits=4), k=6, iters=3)
    # each (U,V) update is a global argmin given Ŵ — loss must not increase
    # across the LR step (quant step is approximate so only check LR steps)
    for t in range(0, len(res.losses) - 1, 2):
        assert res.losses[t + 1] <= res.losses[t] + 1e-9


def test_lrc_beats_svd_correction(problem):
    """Paper: 'a straight-forward approach ... using SVD is not effective'."""
    w, x, stats = problem
    spec_w = QuantSpec(bits=4)
    k = max(1, int(0.10 * min(w.shape)))
    _, _, w_base = quantize_baseline(w, stats, spec_w, hessian="x")
    u_s, v_s = svd_correction(w, w_base, k)
    svd_loss = reconstruction_loss(w, stats, w_hat=w_base, u=u_s, v=v_s)
    res = lrc_solve(w, stats, spec_w, k=k, iters=1)
    assert res.losses[-1] < svd_loss


def test_weight_only_has_little_to_correct(rng):
    """Paper Table 3: with activations in FP, the quantization error is
    already small — 'there is minimal error to correct'.  We check the
    layer-level analogue: the W4A16 baseline error is a small fraction of the
    signal power, and an order of magnitude below the W4A4 baseline error."""
    d_in, d_out = 48, 40
    x = make_calib(rng, n=2048, d=d_in)
    w = jnp.asarray(rng.standard_normal((d_out, d_in)) / np.sqrt(d_in))
    spec_w = QuantSpec(bits=4)

    st_fp = build_stats(x, QuantSpec(bits=16))  # activations ~unquantized
    st_fp_raw = build_stats(x, QuantSpec(bits=16), eps_frac=0.0)  # loss eval
    _, _, w_base_fp = quantize_baseline(w, st_fp, spec_w, hessian="x")
    loss_w4a16 = reconstruction_loss(w, st_fp_raw, w_hat=w_base_fp)
    signal = reconstruction_loss(w, st_fp_raw)  # ||WX||²/n

    st_q = build_stats(x, QuantSpec(bits=4))
    st_q_raw = build_stats(x, QuantSpec(bits=4), eps_frac=0.0)
    _, _, w_base_q = quantize_baseline(w, st_q, spec_w, hessian="x")
    loss_w4a4 = reconstruction_loss(w, st_q_raw, w_hat=w_base_q)

    assert loss_w4a16 < 0.05 * signal  # near-lossless already
    assert loss_w4a16 < 0.5 * loss_w4a4  # activation quant is the dominant error


def test_oracle_loss_lower_bounds_final(problem):
    w, x, stats = problem
    res = lrc_solve(w, stats, QuantSpec(bits=4), k=6, iters=2)
    assert res.oracle_loss <= res.losses[-1] + 1e-9
