"""Training loop, checkpoint/restart (fault tolerance), straggler watchdog,
optimizer behaviour, gradient compression."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.config import reduced
from repro.train.trainer import StragglerWatchdog, train
from repro.train.optimizer import adamw_init, adamw_update, cosine_lr
from repro.train.steps import init_train_state, make_train_step
from repro.checkpoint.ckpt import (
    CheckpointError,
    CheckpointManager,
    latest_step,
    load_checkpoint,
    load_leaf,
    save_checkpoint,
)


@pytest.fixture(scope="module")
def tiny_cfg():
    return reduced(get_config("smollm-135m"), n_layers=2, vocab_size=128)


def test_training_reduces_loss(tiny_cfg):
    _, history, _ = train(tiny_cfg, steps=30, global_batch=8, seq_len=32, lr=3e-3)
    first = float(np.mean(history[:5]))
    last = float(np.mean(history[-5:]))
    assert last < first - 0.2, (first, last)
    # better than uniform over the vocab
    assert last < np.log(tiny_cfg.vocab_size)


def test_checkpoint_roundtrip(tmp_path, tiny_cfg):
    state = init_train_state(tiny_cfg, jax.random.PRNGKey(0))
    p = save_checkpoint(tmp_path / "ck", 7, state)
    restored = load_checkpoint(p, jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_exact_replay(tmp_path, tiny_cfg):
    """Train 20 steps straight vs 10 + resume(10): identical final loss —
    proves (seed, step)-keyed data + checkpointing give exact recovery."""
    _, hist_a, _ = train(tiny_cfg, steps=20, global_batch=4, seq_len=32,
                         ckpt_dir=str(tmp_path / "a"), ckpt_every=100)
    train(tiny_cfg, steps=10, global_batch=4, seq_len=32,
          ckpt_dir=str(tmp_path / "b"), ckpt_every=10)
    _, hist_b, _ = train(tiny_cfg, steps=20, global_batch=4, seq_len=32,
                         ckpt_dir=str(tmp_path / "b"), ckpt_every=10)
    np.testing.assert_allclose(hist_a[-1], hist_b[-1], rtol=1e-4)


def test_keep_k_rotation(tmp_path, tiny_cfg):
    state = init_train_state(tiny_cfg, jax.random.PRNGKey(0))
    mgr = CheckpointManager(tmp_path / "ck", every=1, keep=2)
    for s in range(1, 6):
        mgr.maybe_save(s, state)
    assert latest_step(tmp_path / "ck") == 5
    import os
    kept = [d for d in os.listdir(tmp_path / "ck") if d.startswith("step_")]
    assert len(kept) == 2


def test_debris_never_breaks_the_step_scan(tmp_path):
    """Crash debris in a checkpoint dir — stray files, ``step_<garbage>``
    names, orphaned ``.tmp_step_*`` — must not confuse the scan or the
    rotation."""
    tree = {"w": jnp.arange(4.0)}
    ck = tmp_path / "ck"
    mgr = CheckpointManager(ck, every=1, keep=2)
    for s in (1, 2, 3):
        mgr.maybe_save(s, tree)
    # plant every debris shape a crash can leave behind
    (ck / "step_garbage").mkdir()
    (ck / "step_00000099").write_text("a FILE named like a step dir")
    (ck / "notes.txt").write_text("unrelated")
    (ck / ".tmp_step_00000044").mkdir()
    (ck / ".tmp_step_00000044" / "00000.npy").write_text("partial leaf")
    assert latest_step(ck) == 3
    mgr.maybe_save(4, tree)  # rotation runs the GC
    assert not (ck / ".tmp_step_00000044").exists()
    assert (ck / "step_garbage").exists()  # unknown dirs are left alone
    assert latest_step(ck) == 4
    step, got = mgr.restore_latest({"w": jnp.zeros(4)})
    assert step == 4
    np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(4.0))


def test_restore_latest_falls_back_past_incomplete(tmp_path):
    """Deleting the newest manifest (a sneaky partial-delete crash) makes
    restore_latest warn and fall back to the newest clean step; with every
    step damaged it raises CheckpointError."""
    tree = {"w": jnp.arange(3.0)}
    mgr = CheckpointManager(tmp_path / "ck", every=1, keep=3)
    for s in (1, 2, 3):
        mgr.maybe_save(s, {"w": jnp.arange(3.0) + s})
    (tmp_path / "ck" / "step_00000003" / "manifest.json").unlink()
    with pytest.warns(UserWarning, match="incomplete at step 3"):
        step, got = mgr.restore_latest({"w": jnp.zeros(3)})
    assert step == 2
    np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(3.0) + 2)
    (tmp_path / "ck" / "step_00000002" / "manifest.json").unlink()
    (tmp_path / "ck" / "step_00000001" / "manifest.json").unlink()
    with pytest.warns(UserWarning):
        with pytest.raises(CheckpointError, match="no restorable checkpoint"):
            mgr.restore_latest({"w": jnp.zeros(3)})
    # an EMPTY dir is not an error: resume-from-scratch signal
    empty = CheckpointManager(tmp_path / "nothing", every=1)
    assert empty.restore_latest({"w": jnp.zeros(3)}) == (None, None)


def test_load_leaf_and_missing_leaf_errors(tmp_path):
    """load_leaf pulls one named leaf (the serving snapshot's JSON blob
    rides this); a leaf the like-tree expects but the manifest lacks is
    the structured incomplete signal."""
    p = save_checkpoint(tmp_path / "ck", 1,
                        {"meta": np.arange(7, dtype=np.uint8),
                         "state": {"w": jnp.ones((2, 2))}})
    np.testing.assert_array_equal(load_leaf(p, "meta"),
                                  np.arange(7, dtype=np.uint8))
    with pytest.raises(CheckpointError, match="no leaf 'nope'"):
        load_leaf(p, "nope")
    # extra manifest entries are ignored (how restore skips the meta blob)
    got = load_checkpoint(p, {"state": {"w": jnp.zeros((2, 2))}})
    np.testing.assert_array_equal(np.asarray(got["state"]["w"]), np.ones((2, 2)))
    with pytest.raises(CheckpointError, match="missing leaf"):
        load_checkpoint(p, {"absent": jnp.zeros(1)})


def test_straggler_watchdog_flags_slow_steps():
    wd = StragglerWatchdog(factor=3.0)
    for i in range(20):
        wd.observe(i, 0.1)
    assert wd.observe(20, 1.0)  # injected straggler
    assert wd.flagged and wd.flagged[-1][0] == 20


def test_cosine_lr_schedule():
    assert float(cosine_lr(jnp.asarray(0), 1.0, 10, 100)) == 0.0
    assert abs(float(cosine_lr(jnp.asarray(10), 1.0, 10, 100)) - 1.0) < 1e-6
    end = float(cosine_lr(jnp.asarray(100), 1.0, 10, 100))
    assert end < 0.12


def test_adamw_moves_params_and_clips(tiny_cfg):
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    opt = adamw_init(params)
    grads = {"w": jnp.full((4, 4), 100.0), "b": jnp.full((4,), 100.0)}
    newp, opt, gnorm = adamw_update(params, grads, opt, lr=0.1, clip_norm=1.0)
    assert float(gnorm) > 1.0  # clipping engaged
    assert not np.allclose(np.asarray(newp["w"]), 1.0)


def test_microbatch_accumulation_matches_full_batch(tiny_cfg):
    from repro.data.loader import batches

    state = init_train_state(tiny_cfg, jax.random.PRNGKey(3))
    _, batch = next(batches(tiny_cfg, 8, 32, seed=5))
    s1 = jax.jit(make_train_step(tiny_cfg, microbatches=1, remat="none"))
    s2 = jax.jit(make_train_step(tiny_cfg, microbatches=4, remat="none"))
    st1, m1 = s1(state, batch)
    st2, m2 = s2(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(st1.params), jax.tree.leaves(st2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-5)


def test_remat_matches_no_remat(tiny_cfg):
    from repro.data.loader import batches

    state = init_train_state(tiny_cfg, jax.random.PRNGKey(4))
    _, batch = next(batches(tiny_cfg, 4, 32, seed=6))
    a = jax.jit(make_train_step(tiny_cfg, remat="none"))(state, batch)[1]["loss"]
    b = jax.jit(make_train_step(tiny_cfg, remat="full"))(state, batch)[1]["loss"]
    np.testing.assert_allclose(float(a), float(b), rtol=1e-5)
