"""Training loop, checkpoint/restart (fault tolerance), straggler watchdog,
optimizer behaviour, gradient compression."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.config import reduced
from repro.train.trainer import StragglerWatchdog, train
from repro.train.optimizer import adamw_init, adamw_update, cosine_lr
from repro.train.steps import init_train_state, make_train_step
from repro.checkpoint.ckpt import (
    CheckpointManager,
    latest_step,
    load_checkpoint,
    save_checkpoint,
)


@pytest.fixture(scope="module")
def tiny_cfg():
    return reduced(get_config("smollm-135m"), n_layers=2, vocab_size=128)


def test_training_reduces_loss(tiny_cfg):
    _, history, _ = train(tiny_cfg, steps=30, global_batch=8, seq_len=32, lr=3e-3)
    first = float(np.mean(history[:5]))
    last = float(np.mean(history[-5:]))
    assert last < first - 0.2, (first, last)
    # better than uniform over the vocab
    assert last < np.log(tiny_cfg.vocab_size)


def test_checkpoint_roundtrip(tmp_path, tiny_cfg):
    state = init_train_state(tiny_cfg, jax.random.PRNGKey(0))
    p = save_checkpoint(tmp_path / "ck", 7, state)
    restored = load_checkpoint(p, jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_exact_replay(tmp_path, tiny_cfg):
    """Train 20 steps straight vs 10 + resume(10): identical final loss —
    proves (seed, step)-keyed data + checkpointing give exact recovery."""
    _, hist_a, _ = train(tiny_cfg, steps=20, global_batch=4, seq_len=32,
                         ckpt_dir=str(tmp_path / "a"), ckpt_every=100)
    train(tiny_cfg, steps=10, global_batch=4, seq_len=32,
          ckpt_dir=str(tmp_path / "b"), ckpt_every=10)
    _, hist_b, _ = train(tiny_cfg, steps=20, global_batch=4, seq_len=32,
                         ckpt_dir=str(tmp_path / "b"), ckpt_every=10)
    np.testing.assert_allclose(hist_a[-1], hist_b[-1], rtol=1e-4)


def test_keep_k_rotation(tmp_path, tiny_cfg):
    state = init_train_state(tiny_cfg, jax.random.PRNGKey(0))
    mgr = CheckpointManager(tmp_path / "ck", every=1, keep=2)
    for s in range(1, 6):
        mgr.maybe_save(s, state)
    assert latest_step(tmp_path / "ck") == 5
    import os
    kept = [d for d in os.listdir(tmp_path / "ck") if d.startswith("step_")]
    assert len(kept) == 2


def test_straggler_watchdog_flags_slow_steps():
    wd = StragglerWatchdog(factor=3.0)
    for i in range(20):
        wd.observe(i, 0.1)
    assert wd.observe(20, 1.0)  # injected straggler
    assert wd.flagged and wd.flagged[-1][0] == 20


def test_cosine_lr_schedule():
    assert float(cosine_lr(jnp.asarray(0), 1.0, 10, 100)) == 0.0
    assert abs(float(cosine_lr(jnp.asarray(10), 1.0, 10, 100)) - 1.0) < 1e-6
    end = float(cosine_lr(jnp.asarray(100), 1.0, 10, 100))
    assert end < 0.12


def test_adamw_moves_params_and_clips(tiny_cfg):
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    opt = adamw_init(params)
    grads = {"w": jnp.full((4, 4), 100.0), "b": jnp.full((4,), 100.0)}
    newp, opt, gnorm = adamw_update(params, grads, opt, lr=0.1, clip_norm=1.0)
    assert float(gnorm) > 1.0  # clipping engaged
    assert not np.allclose(np.asarray(newp["w"]), 1.0)


def test_microbatch_accumulation_matches_full_batch(tiny_cfg):
    from repro.data.loader import batches

    state = init_train_state(tiny_cfg, jax.random.PRNGKey(3))
    _, batch = next(batches(tiny_cfg, 8, 32, seed=5))
    s1 = jax.jit(make_train_step(tiny_cfg, microbatches=1, remat="none"))
    s2 = jax.jit(make_train_step(tiny_cfg, microbatches=4, remat="none"))
    st1, m1 = s1(state, batch)
    st2, m2 = s2(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(st1.params), jax.tree.leaves(st2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-5)


def test_remat_matches_no_remat(tiny_cfg):
    from repro.data.loader import batches

    state = init_train_state(tiny_cfg, jax.random.PRNGKey(4))
    _, batch = next(batches(tiny_cfg, 4, 32, seed=6))
    a = jax.jit(make_train_step(tiny_cfg, remat="none"))(state, batch)[1]["loss"]
    b = jax.jit(make_train_step(tiny_cfg, remat="full"))(state, batch)[1]["loss"]
    np.testing.assert_allclose(float(a), float(b), rtol=1e-5)
