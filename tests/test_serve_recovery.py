"""Crash-safe serving: write-ahead journal integrity, engine snapshot /
restore, and the recovery contract — after a mid-flight crash, every
request terminates exactly once with a token stream bitwise identical to
an uninterrupted run (sampling keys depend only on (seed, rid, token
index), so recovery can re-derive any suffix).

Covers the failure surfaces the tentpole names: torn journal tails
(salvaged), mid-journal corruption (refused), stale snapshots (journal
wins; re-prefill), journal-only recovery (no snapshot at all), crashes
mid-decode and mid-prefill under both whole-prompt and chunked prefill,
and the idempotency edges (terminal before crash; stream already
satisfying termination at restore)."""

import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model
from repro.models.config import reduced
from repro.serve import (ErrorKind, FaultInjector, FaultSpec,
                         JournalCorruption, JournalError, JournalWriter,
                         KVSpec, Request, RequestState, ServeEngine,
                         SimulatedCrash, collate, read_journal)


# ---------------------------------------------------------------------------
# journal unit tests (no engine, no jax)
# ---------------------------------------------------------------------------


def _write_records(path, n=5, fsync=False):
    with JournalWriter(path, fsync=fsync) as w:
        w.append("open", mode="paged", seed=0)
        for i in range(n - 1):
            w.append("submit", rid=i, prompt=[1, 2], max_new_tokens=4,
                     temperature=0.0, deadline_s=None)
    return path


def test_journal_roundtrip_and_seq(tmp_path):
    p = _write_records(tmp_path / "wal.log")
    rep = read_journal(p)
    assert rep.torn_tail is None
    assert [r["seq"] for r in rep.records] == list(range(5))
    assert rep.next_seq == 5 and rep.good_bytes == p.stat().st_size
    # reopen resumes the numbering and appends verifiably
    w = JournalWriter.reopen(p, rep, fsync=False)
    assert w.append("terminal", rid=0, status="finished", error_kind=None,
                    error=None, retries=0, n_tokens=0) == 5
    w.close()
    assert len(read_journal(p).records) == 6


def test_journal_refuses_clobber_but_overwrites_on_request(tmp_path):
    p = _write_records(tmp_path / "wal.log")
    with pytest.raises(JournalError, match="already exists"):
        JournalWriter(p, fsync=False)
    w = JournalWriter(p, fsync=False, overwrite=True)
    w.close()
    assert read_journal(p).records == []


def test_torn_tail_is_salvaged_and_truncated(tmp_path):
    """The classic crash shape: the final record is cut mid-write.  Replay
    keeps every intact record, reports the tear, and reopen() truncates
    back to the salvage point so appending continues cleanly."""
    p = _write_records(tmp_path / "wal.log")
    whole = p.read_bytes()
    last = whole.splitlines(keepends=True)[-1]
    for cut in (1, 10, len(last) - 1):  # tear anywhere inside the tail
        p.write_bytes(whole[:-cut])
        rep = read_journal(p)
        assert rep.torn_tail is not None
        assert len(rep.records) == 4
        w = JournalWriter.reopen(p, fsync=False)
        assert w.seq == 4
        w.close()
        assert p.stat().st_size == rep.good_bytes
        p.write_bytes(whole)  # restore for the next cut
    # a corrupt-but-terminated final record is the same salvageable tear
    lines = whole.splitlines(keepends=True)
    p.write_bytes(b"".join(lines[:-1]) + b"deadbeef garbage\n")
    rep = read_journal(p)
    assert rep.torn_tail is not None and len(rep.records) == 4


def test_mid_journal_corruption_refuses_replay(tmp_path):
    """Damage BEFORE the final record is not a torn tail — replaying past
    lost records could double-deliver, so recovery refuses, naming the
    salvage point."""
    p = _write_records(tmp_path / "wal.log")
    lines = p.read_bytes().splitlines(keepends=True)
    # flip a payload byte in record 2 (CRC now mismatches)
    bad = lines[2][:20] + b"X" + lines[2][21:]
    p.write_bytes(b"".join(lines[:2] + [bad] + lines[3:]))
    with pytest.raises(JournalCorruption, match="salvage point"):
        read_journal(p)
    # a vanished whole record is a seq gap, also mid-file damage
    p.write_bytes(b"".join(lines[:2] + lines[3:]))
    with pytest.raises(JournalCorruption, match="sequence gap"):
        read_journal(p)


def test_collate_enforces_delivery_invariants(tmp_path):
    def recs(*events):
        return [dict(seq=i, **e) for i, e in enumerate(events)]

    sub = {"kind": "submit", "rid": 1, "prompt": [1], "max_new_tokens": 4,
           "temperature": 0.0, "deadline_s": None}
    tok = lambda idx: {"kind": "token", "rid": 1, "idx": idx, "token": 9}
    term = {"kind": "terminal", "rid": 1, "status": "finished",
            "error_kind": None, "error": None, "retries": 0, "n_tokens": 1}
    col = collate(recs(sub, tok(0), tok(1), term))
    assert col.tokens[1] == [9, 9] and col.pending() == []
    with pytest.raises(JournalCorruption, match="contiguity"):
        collate(recs(sub, tok(0), tok(2)))
    with pytest.raises(JournalCorruption, match="exactly once"):
        collate(recs(sub, term, dict(term)))
    with pytest.raises(JournalCorruption, match="after its terminal"):
        collate(recs(sub, term, tok(0)))
    with pytest.raises(JournalCorruption, match="unknown rid"):
        collate(recs(tok(0)))
    with pytest.raises(JournalCorruption, match="duplicate submit"):
        collate(recs(sub, dict(sub)))


# ---------------------------------------------------------------------------
# engine crash / restore
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def dense():
    cfg = reduced(get_config("smollm-135m"))
    return cfg, model.init_params(cfg, jax.random.PRNGKey(0))


def _requests(cfg, n=4, base_len=5, new=6):
    rng = np.random.default_rng(11)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        base_len + i).astype(np.int32),
                    max_new_tokens=new)
            for i in range(n)]


def _engine(cfg, params, tmp_path, *, injector=None, journal=True,
            snapshots=True, snapshot_every=2, **kw):
    return ServeEngine(
        cfg, params, batch_slots=2, max_seq=64, seed=3, injector=injector,
        journal=(JournalWriter(tmp_path / "wal.log", fsync=False,
                               overwrite=True) if journal else None),
        snapshot_dir=(str(tmp_path / "snaps") if snapshots else None),
        snapshot_every=(snapshot_every if snapshots else 0), **kw)


def _tick(eng, n=1):
    """Advance the engine loop body n steps WITHOUT run()'s drain-on-
    step-budget semantics — partial progress for snapshot tests."""
    for _ in range(n):
        eng.counters["steps"] += 1
        eng._expire_deadlines()
        eng._admit()
        eng._prefill_tick()
        eng._step()


def _clean_streams(cfg, params, **kw):
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=64, seed=3, **kw)
    for r in _requests(cfg):
        eng.submit(r)
    recs = eng.run()
    assert all(r.status is RequestState.FINISHED for r in recs.values())
    return {rid: r.out_tokens for rid, r in recs.items()}


def _crash_restore_and_check(cfg, params, tmp_path, spec, clean,
                             snapshots=True, **engine_kw):
    eng = _engine(cfg, params, tmp_path,
                  injector=FaultInjector([spec]), snapshots=snapshots,
                  **engine_kw)
    for r in _requests(cfg):
        eng.submit(r)
    with pytest.raises(SimulatedCrash):
        eng.run()
    eng2 = ServeEngine.restore(
        cfg, params, tmp_path / "wal.log",
        snapshot_dir=(str(tmp_path / "snaps") if snapshots else None),
        fsync=False)
    recs = eng2.run()
    eng2.journal.close()
    assert set(recs) == set(clean)
    for rid, toks in clean.items():
        assert recs[rid].status is RequestState.FINISHED
        assert recs[rid].out_tokens == toks, f"rid {rid} diverged"
    col = collate(read_journal(tmp_path / "wal.log").records)
    assert sorted(col.terminals) == sorted(clean)  # exactly once each
    for rid, toks in clean.items():
        assert col.tokens[rid] == toks
    assert len(col.recovers) == 1
    return eng2


def test_crash_mid_decode_recovers_bitwise(dense, tmp_path):
    cfg, params = dense
    clean = _clean_streams(cfg, params)
    _crash_restore_and_check(
        cfg, params, tmp_path,
        FaultSpec(kind="process_crash", phase="decode", rid=2, at_call=2),
        clean)


def test_crash_mid_prefill_chunked_recovers_bitwise(dense, tmp_path):
    """Crash inside a chunked prefill: the snapshot may hold a partial
    prompt (prefill_off > 0); recovery resumes the remaining chunks."""
    cfg, params = dense
    clean = _clean_streams(cfg, params)  # chunking never changes outputs
    _crash_restore_and_check(
        cfg, params, tmp_path,
        FaultSpec(kind="process_crash", phase="prefill", rid=3, at_call=1),
        clean, prefill_chunk=4, snapshot_every=1)


def test_crash_recovers_without_any_snapshot(dense, tmp_path):
    """Journal-only recovery: no snapshot directory at all — every pending
    request re-prefills prompt + journaled tokens from scratch."""
    cfg, params = dense
    clean = _clean_streams(cfg, params)
    eng2 = _crash_restore_and_check(
        cfg, params, tmp_path,
        FaultSpec(kind="process_crash", phase="decode", rid=1, at_call=3),
        clean, snapshots=False)
    assert eng2._ckpt is None


def test_stale_snapshot_degrades_to_reprefill(dense, tmp_path):
    """A snapshot far behind the journal: requests whose streams advanced
    after it must NOT resume from the stale KV — they re-prefill the full
    journaled stream, and the outputs still match bitwise."""
    cfg, params = dense
    clean = _clean_streams(cfg, params)
    eng = _engine(cfg, params, tmp_path, snapshot_every=0)
    for r in _requests(cfg):
        eng.submit(r)
    _tick(eng, 2)
    eng.snapshot()  # an EARLY snapshot ...
    _tick(eng, 2)   # ... that the journal then outruns
    assert any(r is not None and r.out_tokens for r in eng.slot_req)
    eng.journal.close()  # abandon mid-flight: the "crash"
    eng2 = ServeEngine.restore(cfg, params, tmp_path / "wal.log",
                               snapshot_dir=str(tmp_path / "snaps"),
                               fsync=False)
    recs = eng2.run()
    eng2.journal.close()
    for rid, toks in clean.items():
        assert recs[rid].out_tokens == toks
        assert recs[rid].status is RequestState.FINISHED
    # the stale path really ran: in-flight rids were re-enqueued, not
    # resumed from the outdated KV
    col = collate(read_journal(tmp_path / "wal.log").records)
    assert col.recovers and col.recovers[0]["requeued"]


@pytest.mark.parametrize("dtype,group", [("int8", None), ("int4", 16)])
def test_quantized_kv_crash_recovery_bitwise(dense, tmp_path, dtype, group):
    """The PR-8 recovery contract extends to quantized pools: a crash
    mid-decode over int8/int4 pages restores (snapshot carries the
    quantized pool + scale planes bitwise; the journal's open record
    carries the spec, so restore() needs no kv_spec argument) and every
    request continues to the SAME tokens as an uninterrupted quantized
    run."""
    cfg, params = dense
    spec = KVSpec(dtype=dtype, group=group)
    clean = _clean_streams(cfg, params, kv_spec=spec)
    eng2 = _crash_restore_and_check(
        cfg, params, tmp_path,
        FaultSpec(kind="process_crash", phase="decode", rid=2, at_call=2),
        clean, kv_spec=spec)
    # the restored engine really is quantized end-to-end
    assert eng2.kv_spec == spec and eng2.alloc.sidecar
    assert eng2.health()["kv"]["dtype"] == dtype
    # restore() reads the spec from the journal; passing one is an error
    with pytest.raises(JournalError, match="kv_spec"):
        ServeEngine.restore(cfg, params, tmp_path / "wal.log",
                            fsync=False, kv_spec=spec)


def test_stale_snapshot_reprefills_into_quantized_pool(dense, tmp_path):
    """The stale-snapshot degrade path over int8 pages: streams that
    outran the snapshot re-prefill from the journal into a FRESH quantized
    pool (prompt + tokens re-quantized at append), and the continuations
    still match an uninterrupted int8 run bitwise."""
    cfg, params = dense
    spec = KVSpec(dtype="int8")
    clean = _clean_streams(cfg, params, kv_spec=spec)
    eng = _engine(cfg, params, tmp_path, snapshot_every=0, kv_spec=spec)
    for r in _requests(cfg):
        eng.submit(r)
    _tick(eng, 2)
    eng.snapshot()  # an EARLY snapshot ...
    _tick(eng, 2)   # ... that the journal then outruns
    assert any(r is not None and r.out_tokens for r in eng.slot_req)
    eng.journal.close()  # abandon mid-flight: the "crash"
    eng2 = ServeEngine.restore(cfg, params, tmp_path / "wal.log",
                               snapshot_dir=str(tmp_path / "snaps"),
                               fsync=False)
    assert eng2.kv_spec == spec
    recs = eng2.run()
    eng2.journal.close()
    for rid, toks in clean.items():
        assert recs[rid].out_tokens == toks
        assert recs[rid].status is RequestState.FINISHED
    col = collate(read_journal(tmp_path / "wal.log").records)
    assert col.recovers and col.recovers[0]["requeued"]


def test_terminal_before_crash_is_not_replayed(dense, tmp_path):
    """Requests whose terminal record predates the crash re-materialize as
    records without re-running — and keep their original status."""
    cfg, params = dense
    clean = _clean_streams(cfg, params)
    eng = _engine(cfg, params, tmp_path,
                  injector=FaultInjector([FaultSpec(
                      kind="process_crash", phase="decode", rid=3,
                      at_call=4)]))
    reqs = _requests(cfg)
    for r in reqs:
        eng.submit(r)
    eng.cancel(1)  # terminal (CANCELLED) journaled long before the crash
    with pytest.raises(SimulatedCrash):
        eng.run()
    assert 1 in eng.records
    eng2 = ServeEngine.restore(cfg, params, tmp_path / "wal.log",
                               snapshot_dir=str(tmp_path / "snaps"),
                               fsync=False)
    assert eng2.records[1].status is RequestState.CANCELLED
    assert eng2.records[1].error_kind == ErrorKind.CANCELLED
    recs = eng2.run()
    eng2.journal.close()
    assert recs[1].status is RequestState.CANCELLED  # never re-run
    for rid in (0, 2, 3):
        assert recs[rid].out_tokens == clean[rid]
    col = collate(read_journal(tmp_path / "wal.log").records)
    assert len(col.terminals) == 4  # one each, across crash + recovery


def test_already_satisfied_stream_finalizes_without_decoding(dense, tmp_path):
    """If the crash fell between the last token commit and the terminal
    record, the journaled stream already satisfies the termination
    predicate — restore finalizes it immediately instead of decoding an
    extra token."""
    cfg, params = dense
    clean = _clean_streams(cfg, params)
    # build a journal by hand: rid 0's full stream, no terminal
    jpath = tmp_path / "wal.log"
    eng0 = _engine(cfg, params, tmp_path, snapshots=False)
    for r in _requests(cfg):
        eng0.submit(r)
    eng0.run()
    eng0.journal.close()
    rep = read_journal(jpath)
    keep = [r for r in rep.records
            if not (r["kind"] == "terminal" and r["rid"] == 0)]
    # rewrite the journal without rid 0's terminal, reseq'd
    with JournalWriter(jpath, fsync=False, overwrite=True) as w:
        for r in keep:
            fields = {k: v for k, v in r.items() if k not in ("seq", "kind")}
            w.append(r["kind"], **fields)
    eng2 = ServeEngine.restore(cfg, params, jpath, fsync=False)
    # finalized at restore: no queue entry, record present, nothing decoded
    assert 0 in eng2.records
    assert eng2.records[0].status is RequestState.FINISHED
    assert eng2.records[0].out_tokens == clean[0]
    assert all(q.rid != 0 for q in eng2.queue)
    recs = eng2.run()
    eng2.journal.close()
    assert recs[0].out_tokens == clean[0]
    col = collate(read_journal(jpath).records)
    assert sorted(col.terminals) == [0, 1, 2, 3]


def test_snapshot_restore_roundtrip_preserves_engine_state(dense, tmp_path):
    """Snapshot -> restore with no crash in between: allocator state, slot
    placement, counters and the paged pool all survive byte-for-byte (the
    restored engine finishes identically)."""
    cfg, params = dense
    clean = _clean_streams(cfg, params)
    eng = _engine(cfg, params, tmp_path, snapshot_every=0)
    for r in _requests(cfg):
        eng.submit(r)
    _tick(eng, 3)   # partial progress ...
    eng.snapshot()  # ... snapshotted right at the step boundary
    eng.journal.close()
    eng2 = ServeEngine.restore(cfg, params, tmp_path / "wal.log",
                               snapshot_dir=str(tmp_path / "snaps"),
                               fsync=False)
    # in-place resume: the snapshot and journal agree, so decoding slots
    # carry straight on from the restored pool
    resumed = [r for r in eng2.slot_req if r is not None]
    assert resumed, "expected at least one slot resumed in place"
    eng2.alloc.check()
    recs = eng2.run()
    eng2.journal.close()
    for rid, toks in clean.items():
        assert recs[rid].out_tokens == toks


def test_restore_requires_open_record_and_matching_mode(dense, tmp_path):
    cfg, params = dense
    jpath = tmp_path / "wal.log"
    with JournalWriter(jpath, fsync=False) as w:
        w.append("submit", rid=0, prompt=[1], max_new_tokens=1,
                 temperature=0.0, deadline_s=None)
    with pytest.raises(JournalError, match="no open record"):
        ServeEngine.restore(cfg, params, jpath, fsync=False)
    ssm = reduced(get_config("mamba2-370m"))
    ssm_params = model.init_params(ssm, jax.random.PRNGKey(0))
    eng = ServeEngine(ssm, ssm_params, batch_slots=2, max_seq=64, seed=3,
                      journal=JournalWriter(tmp_path / "ssm.log",
                                            fsync=False))
    eng.journal.close()
    with pytest.raises(JournalError, match="mode"):
        ServeEngine.restore(cfg, params, tmp_path / "ssm.log", fsync=False)


def test_stacked_mode_crash_recovery(tmp_path):
    """The recovery contract is family-agnostic: a stacked (ssm) engine
    crashes mid-decode and recovers bitwise too."""
    cfg = reduced(get_config("mamba2-370m"))
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    clean = _clean_streams(cfg, params)
    _crash_restore_and_check(
        cfg, params, tmp_path,
        FaultSpec(kind="process_crash", phase="decode", rid=2, at_call=1),
        clean)


def test_error_kind_taxonomy_is_strings(dense, tmp_path):
    """ErrorKind members serialize and compare as their literal values —
    the property that keeps old string-comparison call sites working and
    journal payloads readable."""
    assert ErrorKind.DEADLINE == "deadline"
    assert str(ErrorKind.SIMULATED_CRASH) == "simulated_crash"
    assert f"{ErrorKind.KV_PAGES_EXHAUSTED}" == "kv_pages_exhausted"
    assert json.loads(json.dumps(ErrorKind.STALL)) == "stall"
    cfg, params = dense
    eng = _engine(cfg, params, tmp_path, snapshots=False)
    bad = Request(rid=9, prompt=np.asarray([1, 2], np.int32),
                  max_new_tokens=0)
    assert not eng.submit(bad)
    assert eng.records[9].error_kind == ErrorKind.BAD_TOKEN_BUDGET
    assert eng.records[9].error_kind == "bad_token_budget"
    eng.journal.close()
    # rejected submits never reach the journal: no submit, no terminal
    col = collate(read_journal(tmp_path / "wal.log").records)
    assert 9 not in col.submits and 9 not in col.terminals
