"""Minimal deterministic stand-in for `hypothesis` (not installed in the
hermetic container — tier-1 must still run the property tests).

Implements exactly the surface this test-suite uses: ``given``, ``settings``
and the ``integers`` / ``sampled_from`` / ``booleans`` strategies (plus
``.map``).  ``given`` draws a fixed number of pseudo-random examples from a
seeded generator, so runs are reproducible; real hypothesis, when available,
is always preferred (see conftest.py).
"""

from __future__ import annotations

import functools
import inspect

import numpy as np

_DEFAULT_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)))


class _strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def sampled_from(options):
        options = list(options)
        return _Strategy(lambda rng: options[int(rng.integers(len(options)))])

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))


strategies = _strategies


def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(**named_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # @settings is applied OUTSIDE @given, so it stamps the wrapper
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples", _DEFAULT_EXAMPLES))
            rng = np.random.default_rng(12345)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in named_strategies.items()}
                fn(*args, **kwargs, **drawn)

        # hide the strategy-drawn parameters from pytest's fixture resolution
        # (functools.wraps exposes the original signature via __wrapped__)
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in named_strategies
        ])
        del wrapper.__wrapped__
        return wrapper

    return deco
