"""Absorbed MLA must be numerically equivalent to the naive expansion."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model
from repro.models.config import reduced


def test_absorbed_equals_naive(rng):
    cfg = reduced(get_config("deepseek-v2-236b"), n_layers=2)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)))}
    naive = model.forward(cfg, params, batch)
    absorbed = model.forward(dataclasses.replace(cfg, mla_absorb=True), params, batch)
    np.testing.assert_allclose(
        np.asarray(naive), np.asarray(absorbed), rtol=2e-3, atol=2e-3
    )


def test_absorbed_decode_matches_naive_decode(rng):
    cfg = reduced(get_config("deepseek-v2-236b"), n_layers=2)
    cfg_a = dataclasses.replace(cfg, mla_absorb=True)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 10)))

    def run(c):
        cache = model.init_cache(c, 2, 10, dtype=jnp.float32)
        logits, cache = model.prefill(c, params, {"tokens": toks[:, :5]}, cache)
        outs = [logits]
        for t in range(5, 10):
            logits, cache = model.decode_step(c, params, toks[:, t : t + 1], cache)
            outs.append(logits)
        return jnp.concatenate([o[:, :1] for o in outs], axis=1)

    a = run(cfg)
    b = run(cfg_a)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3)
