"""Tensor-parallel W4A4+LRC under shard_map (distributed/tp.py) on a forced
8-device host mesh: layer-level numerics contract (column bitwise, row one
psum + ulp drift), trace/HLO collective counts, shape-keyed kernel-plan
resolution at the LOCAL shard shape, sharding-preserving retag, and the
mesh-mode ServeEngine's run-to-run determinism.  Subprocesses, so the
1-device tests elsewhere keep their platform config."""

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import numpy as np
import jax, jax.numpy as jnp

from repro.configs import get_config
from repro.core.jaxcompat import make_mesh, set_mesh
from repro.distributed import tp as tp_lib
from repro.models import model as model_lib
from repro.models.config import reduced
from repro.quant.calibrate import quantize_model
from repro.quant.policy import QuantPolicy
from repro.quant.qlinear import QLinear, qlinear_apply, retag_qlinear_impl

cfg = reduced(get_config("smollm-135m"))
params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
calib = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
# act_group=16 divides every local K slice (wo: 64/4=16, wd: 128/4=32), so
# the row layers shard instead of falling back to replication
q = quantize_model(cfg, params, calib,
                   QuantPolicy(rank_frac=0.10, impl="sim", clip_ratio=0.9,
                               act_group=16))
mesh = make_mesh((2, 4), ("data", "model"))
sp, plan = tp_lib.shard_params(q, mesh)
kinds = {e["path"]: e["parallel"] for e in plan}
assert kinds["layers/attn/wq"] == "column", kinds
assert kinds["layers/attn/wo"] == "row", kinds
assert kinds["layers/mlp/wd"] == "row", kinds
qls = [l for l in jax.tree.leaves(sp, is_leaf=lambda l: isinstance(l, QLinear))
       if isinstance(l, QLinear)]
assert qls and all(l.parallel in ("column", "row", "replicate") for l in qls)

# plan reports per-shard (K, N, R): row-parallel wo splits K by tp=4
wo_entry = next(e for e in plan if e["path"] == "layers/attn/wo")
gk, gn, gr = wo_entry["global_knr"]
lk, ln, lr = wo_entry["local_knr"]
assert (lk, ln, lr) == (gk // 4, gn, gr), wo_entry


def flat(ql, i=0):  # slice one layer out of a stacked (scan) leaf
    return dataclasses.replace(
        ql, qweight=ql.qweight[i], w_scale=ql.w_scale[i],
        u=None if ql.u is None else ql.u[i],
        v=None if ql.v is None else ql.v[i])


def get(tree, path):
    node = tree
    for part in path.split("/"):
        node = node[part]
    return node


rng = np.random.default_rng(0)

# column-parallel: BITWISE vs the single-device jitted apply
col = flat(get(sp, "layers/attn/wq"))
xc = jnp.asarray(rng.standard_normal((8, col.d_in)), jnp.float32)
ref = jax.jit(lambda x: qlinear_apply(tp_lib._strip(col), x))(xc)
with set_mesh(mesh):
    got = jax.jit(lambda x: qlinear_apply(col, x))(xc)
assert np.array_equal(np.asarray(ref), np.asarray(got)), "column not bitwise"

# replicate-tagged: also BITWISE (runs the identical full-shape apply)
rep = dataclasses.replace(col, parallel="replicate")
with set_mesh(mesh):
    got = jax.jit(lambda x: qlinear_apply(rep, x))(xc)
assert np.array_equal(np.asarray(ref), np.asarray(got)), "replicate not bitwise"

# row-parallel: ONE f32 psum, output within ~1 ulp of single-device
row = flat(get(sp, "layers/attn/wo"))
xo = jnp.asarray(rng.standard_normal((8, row.d_in)), jnp.float32)
ref = jax.jit(lambda x: qlinear_apply(tp_lib._strip(row), x))(xo)
with set_mesh(mesh):
    got = jax.jit(lambda x: qlinear_apply(row, x))(xo)
d = float(np.abs(np.asarray(ref) - np.asarray(got)).max())
scale = float(np.abs(np.asarray(ref)).max())
# drift bound: the GEMM partial reassociates in f32 (~eps_f32), but the
# LRC factors are STORED bf16, so K-splitting the x@V contraction re-rounds
# the bf16 partials — a few ulp of the LR dtype is the honest bound
assert d <= max(1e-6, 4 * 2.0 ** -8 * scale), (d, scale)

# trace-level collective counts: row = exactly ONE psum, zero gathers
# (the zero-extra-collective invariant: the LRC partial rides the same psum)
with set_mesh(mesh):
    s_row = str(jax.make_jaxpr(lambda x: qlinear_apply(row, x))(xo))
    s_col = str(jax.make_jaxpr(lambda x: qlinear_apply(col, x))(xc))
assert s_row.count("psum") == 1, s_row.count("psum")
assert "all_gather" not in s_row
assert "psum" not in s_col and "all_gather" not in s_col

# compiled HLO of the row layer: exactly one all-reduce
with set_mesh(mesh):
    hlo = jax.jit(lambda x: qlinear_apply(row, x)).lower(xo).compile().as_text()
n_ar = sum(1 for ln_ in hlo.splitlines()
           if " all-reduce(" in ln_ or " all-reduce-start(" in ln_)
assert n_ar == 1, f"row-parallel layer compiled to {n_ar} all-reduces"

# shape-keyed KernelContext override resolves at the LOCAL (K, N, R)
from repro.kernels.context import KernelContext
ctx = KernelContext().with_layer_overrides({(lk, ln, lr): {"bm": 4}})
p_local = ctx.resolve_plan(8, lk, ln, lr, act_group=row.act_group)
assert p_local.bm == 4, p_local
p_global = ctx.resolve_plan(8, gk, gn, gr, act_group=row.act_group)
assert p_global.bm != 4, "global shape must not hit the local-shape override"

# retag preserves NamedSharding on quantized + low-rank leaves
wq_before = get(sp, "layers/attn/wq")
rt = retag_qlinear_impl(sp, "int8")
wq_after = get(rt, "layers/attn/wq")
assert wq_after.impl == "int8"
assert wq_after.parallel == wq_before.parallel
for f in ("qweight", "w_scale", "u", "v"):
    a, b = getattr(wq_before, f), getattr(wq_after, f)
    if a is None:
        continue
    assert b.sharding == a.sharding, (f, a.sharding, b.sharding)

# infeasible act_group (does not divide K/tp) falls back to replication
q_bad = dataclasses.replace(tp_lib._strip(row), act_group=row.d_in // 4 + 1)
assert not tp_lib.tp_feasible(q_bad, "row", 4)
# ... and per-token scales (act_group=None) refuse row-parallel outright
q_tok = dataclasses.replace(tp_lib._strip(row), act_group=None)
assert not tp_lib.tp_feasible(q_tok, "row", 4)
print("TP_LAYER_OK")
"""

ENGINE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp

from repro.configs import get_config
from repro.core.jaxcompat import make_mesh
from repro.models import model as model_lib
from repro.models.config import reduced
from repro.quant.calibrate import quantize_model
from repro.quant.policy import QuantPolicy
from repro.serve.engine import ServeEngine
from repro.serve.lifecycle import Request

rng = np.random.default_rng(0)

# -- dense: full column+row sharding, run-to-run determinism + health ------
cfg = reduced(get_config("smollm-135m"))
params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
calib = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
q = quantize_model(cfg, params, calib,
                   QuantPolicy(rank_frac=0.10, impl="sim", clip_ratio=0.9,
                               act_group=16))
mesh = make_mesh((2, 4), ("data", "model"))
prompts = [rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
           for _ in range(3)]


def run():
    eng = ServeEngine(cfg, q, batch_slots=2, max_seq=32, seed=0,
                      kernel_impl="auto", mesh=mesh)
    for i, p in enumerate(prompts):
        assert eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    recs = eng.run()
    return eng, {r: recs[r].out_tokens for r in recs}


e1, t1 = run()
e2, t2 = run()
assert all(t1[r] for r in t1)
assert t1 == t2, "mesh engine not run-to-run deterministic"
h = e1.health()["mesh"]
assert h["axes"] == {"data": 2, "model": 4}, h
pk = {p["parallel"] for p in h["decode_plans"].values()}
assert "column" in pk and "row" in pk, pk
# every decode plan resolved at the shard's LOCAL width, not the global one
widths = {cfg.d_model, cfg.d_ff, cfg.n_kv_heads * cfg.head_dim}
for p in h["decode_plans"].values():
    if p["parallel"] == "column":
        assert p["local"]["n"] * 4 in widths, (p, widths)

# -- MoE: expert-parallel decode, deterministic, drop counter surfaces -----
mcfg = reduced(get_config("deepseek-v2-236b"))
mparams = model_lib.init_params(mcfg, jax.random.PRNGKey(0))
mcalib = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, mcfg.vocab_size)
mq = quantize_model(mcfg, mparams, mcalib,
                    QuantPolicy(rank_frac=0.10, impl="sim", clip_ratio=0.9,
                                act_group=16))
mmesh = make_mesh((1, 2), ("data", "model"))


def mrun():
    eng = ServeEngine(mcfg, mq, batch_slots=2, max_seq=32, seed=0,
                      kernel_impl="auto", mesh=mmesh)
    for i, p in enumerate(prompts[:2]):
        assert eng.submit(Request(rid=i, prompt=p, max_new_tokens=3))
    recs = eng.run()
    return eng, {r: recs[r].out_tokens for r in recs}


m1, mt1 = mrun()
m2, mt2 = mrun()
assert mt1 == mt2, "moe mesh engine not run-to-run deterministic"
mh = m1.health()["mesh"]
assert mh["moe_impl"] == "ep", mh
assert mh["ep_dropped"] >= 0
assert any(p["parallel"] == "ep" for p in mh["decode_plans"].values()), mh
print("TP_ENGINE_OK")
"""


def _run(script, marker):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert marker in out.stdout


def test_tp_layer_contract():
    _run(SCRIPT, "TP_LAYER_OK")


def test_tp_engine_determinism():
    _run(ENGINE_SCRIPT, "TP_ENGINE_OK")
